"""L2: the GPT transformer layer in JAX, in every mapping variant DFModel
reasons about (§VII), calling the L1 Pallas kernels.

Build-time only — `aot.py` lowers each variant to HLO text once and the Rust
coordinator executes the artifacts via PJRT; Python is never on the request
path.

Variants (one HLO artifact each; weights are baked in as constants so the
Rust executor only feeds activations):

  * kernel-by-kernel — one artifact per dataflow-graph vertex (Fig. 2D): the
    non-dataflow mapping of Calculon-style models; every intermediate tensor
    crosses DRAM/host between artifacts.
  * vendor 4-partition mapping (§VII-B): P1={LN1,Q,K,V},
    P2={MHA1,Softmax,MHA2,Proj,Add}, P3={LN2,FFN0,GeLU}, P4={FFN1,Add}.
  * DFModel-optimized mapping (§VII-C): Proj co-located with FFN0 —
    P1={LN1,Q,K,V}, P2={MHA1,Softmax,MHA2}, P3={Proj,Add,LN2,FFN0,GeLU},
    P4={FFN1,Add}.
  * fused — the whole layer as one on-chip pipeline (Fig. 2C) built on the
    Pallas flash-attention and fused-FFN kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention
from compile.kernels.fused_ffn import fused_ffn
from compile.kernels.layernorm import layernorm as pallas_layernorm


@dataclasses.dataclass(frozen=True)
class GptConfig:
    """Shape of the (deliberately small) validation GPT layer."""
    d_model: int = 256
    n_heads: int = 4
    seq: int = 128
    d_ff: int = 1024

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# The tiny default used by `make artifacts` and the Rust e2e example.
DEFAULT_CONFIG = GptConfig()


def init_params(cfg: GptConfig, seed: int = 0) -> dict:
    """Deterministic layer weights; scaled for stable f32 numerics."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 16))
    d, f = cfg.d_model, cfg.d_ff

    def w(shape, scale):
        return (jax.random.normal(next(keys), shape, jnp.float32) * scale)

    s_attn = 1.0 / (d ** 0.5)
    s_ffn = 1.0 / (f ** 0.5)
    return {
        "ln1_g": jnp.ones((d,), jnp.float32),
        "ln1_b": jnp.zeros((d,), jnp.float32),
        "wq": w((d, d), s_attn), "bq": w((d,), 0.02),
        "wk": w((d, d), s_attn), "bk": w((d,), 0.02),
        "wv": w((d, d), s_attn), "bv": w((d,), 0.02),
        "wo": w((d, d), s_attn), "bo": w((d,), 0.02),
        "ln2_g": jnp.ones((d,), jnp.float32),
        "ln2_b": jnp.zeros((d,), jnp.float32),
        "w1": w((d, f), s_attn), "b1": w((f,), 0.02),
        "w2": w((f, d), s_ffn), "b2": w((d,), 0.02),
    }


# ---------------------------------------------------------------------------
# Fused (full dataflow) layer — L1 kernels inside.
# ---------------------------------------------------------------------------

def gpt_layer_fused(params: dict, x: jax.Array, cfg: GptConfig) -> jax.Array:
    """Whole layer as one on-chip pipeline, using the Pallas kernels
    (flash attention, fused FFN, and row-blocked LayerNorm)."""
    h = pallas_layernorm(x, params["ln1_g"], params["ln1_b"])
    q = ref.split_heads(h @ params["wq"] + params["bq"], cfg.n_heads)
    k = ref.split_heads(h @ params["wk"] + params["bk"], cfg.n_heads)
    v = ref.split_heads(h @ params["wv"] + params["bv"], cfg.n_heads)
    attn = ref.merge_heads(flash_attention(q, k, v))
    x = x + attn @ params["wo"] + params["bo"]
    h = pallas_layernorm(x, params["ln2_g"], params["ln2_b"])
    return x + fused_ffn(h, params["w1"], params["b1"],
                         params["w2"], params["b2"])


# ---------------------------------------------------------------------------
# Kernel-by-kernel variant: one function per dataflow-graph vertex.
# Each returns/accepts plain arrays; intermediates round-trip through the
# caller (DRAM in the model's terms).
# ---------------------------------------------------------------------------

def make_kernel_by_kernel(params: dict, cfg: GptConfig) -> dict[str, Callable]:
    """Name -> single-kernel function, in dataflow-graph order (Fig. 2A)."""
    n_heads = cfg.n_heads
    scale = 1.0 / (cfg.head_dim ** 0.5)
    p = params

    return {
        # x -> h
        "ln1": lambda x: ref.layernorm(x, p["ln1_g"], p["ln1_b"]),
        # h -> q/k/v  [heads, seq, head_dim]
        "q": lambda h: ref.split_heads(h @ p["wq"] + p["bq"], n_heads),
        "k": lambda h: ref.split_heads(h @ p["wk"] + p["bk"], n_heads),
        "v": lambda h: ref.split_heads(h @ p["wv"] + p["bv"], n_heads),
        # scores = q k^T / sqrt(d)
        "mha1": lambda q, k: jnp.einsum("hqd,hkd->hqk", q, k) * scale,
        "softmax": lambda s: ref.softmax(s, axis=-1),
        # context = probs @ v, merged back to [seq, d_model]
        "mha2": lambda pr, v: ref.merge_heads(
            jnp.einsum("hqk,hkd->hqd", pr, v)),
        "proj": lambda a: a @ p["wo"] + p["bo"],
        "add1": lambda x, y: x + y,
        "ln2": lambda x: ref.layernorm(x, p["ln2_g"], p["ln2_b"]),
        "ffn0": lambda h: h @ p["w1"] + p["b1"],
        "gelu": ref.gelu,
        "ffn1": lambda h: h @ p["w2"] + p["b2"],
        "add2": lambda x, y: x + y,
    }


def run_kernel_by_kernel(params: dict, x: jax.Array, cfg: GptConfig) -> jax.Array:
    """Drive the per-vertex functions in graph order (test oracle for the
    Rust kernel-by-kernel executor)."""
    ks = make_kernel_by_kernel(params, cfg)
    h = ks["ln1"](x)
    q, k, v = ks["q"](h), ks["k"](h), ks["v"](h)
    s = ks["mha1"](q, k)
    pr = ks["softmax"](s)
    a = ks["mha2"](pr, v)
    y = ks["add1"](x, ks["proj"](a))
    h2 = ks["ln2"](y)
    return ks["add2"](y, ks["ffn1"](ks["gelu"](ks["ffn0"](h2))))


# ---------------------------------------------------------------------------
# Partitioned variants (§VII-B vendor mapping, §VII-C DFModel mapping).
# Each partition is one HLO artifact; the on-chip interior of a partition is
# fused (flash attention / fused FFN where the partition contains the chain).
# ---------------------------------------------------------------------------

def make_vendor_partitions(params: dict, cfg: GptConfig) -> dict[str, Callable]:
    """Vendor 4-partition mapping from §VII-B."""
    p, n_heads = params, cfg.n_heads

    def p1(x):  # {LN1, Q, K, V}
        h = ref.layernorm(x, p["ln1_g"], p["ln1_b"])
        return (ref.split_heads(h @ p["wq"] + p["bq"], n_heads),
                ref.split_heads(h @ p["wk"] + p["bk"], n_heads),
                ref.split_heads(h @ p["wv"] + p["bv"], n_heads))

    def p2(x, q, k, v):  # {MHA1, Softmax, MHA2, Proj, Add} — fused attention
        attn = ref.merge_heads(flash_attention(q, k, v))
        return x + attn @ p["wo"] + p["bo"]

    def p3(y):  # {LN2, FFN0, GeLU}
        h = ref.layernorm(y, p["ln2_g"], p["ln2_b"])
        return ref.gelu(h @ p["w1"] + p["b1"])

    def p4(y, h):  # {FFN1, Add}
        return y + h @ p["w2"] + p["b2"]

    return {"p1_qkv": p1, "p2_attn": p2, "p3_ffn0": p3, "p4_ffn1": p4}


def make_dfmodel_partitions(params: dict, cfg: GptConfig) -> dict[str, Callable]:
    """DFModel-optimized mapping (§VII-C): Proj co-located with FFN0 so the
    Proj all-reduce overlaps the FFN0 GEMM."""
    p, n_heads = params, cfg.n_heads

    def p1(x):  # {LN1, Q, K, V}
        h = ref.layernorm(x, p["ln1_g"], p["ln1_b"])
        return (ref.split_heads(h @ p["wq"] + p["bq"], n_heads),
                ref.split_heads(h @ p["wk"] + p["bk"], n_heads),
                ref.split_heads(h @ p["wv"] + p["bv"], n_heads))

    def p2(q, k, v):  # {MHA1, Softmax, MHA2} — fused attention
        return ref.merge_heads(flash_attention(q, k, v))

    def p3(x, attn):  # {Proj, Add, LN2, FFN0, GeLU}
        y = x + attn @ p["wo"] + p["bo"]
        h = ref.layernorm(y, p["ln2_g"], p["ln2_b"])
        return y, ref.gelu(h @ p["w1"] + p["b1"])

    def p4(y, h):  # {FFN1, Add}
        return y + h @ p["w2"] + p["b2"]

    return {"p1_qkv": p1, "p2_attn": p2, "p3_proj_ffn0": p3, "p4_ffn1": p4}


def run_vendor(params: dict, x: jax.Array, cfg: GptConfig) -> jax.Array:
    ps = make_vendor_partitions(params, cfg)
    q, k, v = ps["p1_qkv"](x)
    y = ps["p2_attn"](x, q, k, v)
    return ps["p4_ffn1"](y, ps["p3_ffn0"](y))


def run_dfmodel(params: dict, x: jax.Array, cfg: GptConfig) -> jax.Array:
    ps = make_dfmodel_partitions(params, cfg)
    q, k, v = ps["p1_qkv"](x)
    attn = ps["p2_attn"](q, k, v)
    y, h = ps["p3_proj_ffn0"](x, attn)
    return ps["p4_ffn1"](y, h)
