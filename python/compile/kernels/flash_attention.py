"""L1 Pallas kernel: FlashAttention-style fused attention.

This is the paper's canonical intra-chip dataflow mapping (§II-B, Fig. 2C):
instead of materializing the [seq, seq] score matrix in DRAM the way a
kernel-by-kernel mapping does (Fig. 2D), the MHA1 -> Softmax -> MHA2 chain is
fused on-chip and K/V are *streamed* through the fused pipeline tile by tile
with an online softmax, so the working set is O(block) and lives entirely in
VMEM.

Hardware adaptation (GPU paper -> TPU model, DESIGN.md §Hardware-Adaptation):
  * the CUDA threadblock schedule becomes the Pallas grid
    (head, q_block, k_block) with the k dimension innermost ("arbitrary"
    semantics — it carries the online-softmax state in VMEM scratch);
  * shared-memory tiles become BlockSpec-described VMEM blocks;
  * the matmuls (q @ k^T, p @ v) are shaped for the 128x128 MXU and
    accumulate in f32.

interpret=True is mandatory on this image: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. The structure (BlockSpecs,
scratch, grid) is exactly what a real TPU build would use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, n_k_blocks: int):
    """One (head, q_block, k_block) grid step of the online-softmax fusion.

    VMEM scratch carries the running row-max `m`, row-sum `l`, and the
    un-normalized output accumulator `acc` across the innermost k dimension.
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [block_q, head_dim]
    k = k_ref[0]  # [block_k, head_dim]
    v = v_ref[0]  # [block_k, head_dim]

    # MHA1: scores tile, f32 accumulation for the MXU.
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [block_q, block_k]

    # Online softmax update (FlashAttention-2 recurrence).
    m_prev = m_ref[...]            # [block_q, 1]
    l_prev = l_ref[...]            # [block_q, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)         # [block_q, block_k]
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)

    # MHA2: accumulate the un-normalized context.
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(k_idx == n_k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K) -> jax.Array:
    """Fused scaled-dot-product attention over [heads, seq, head_dim].

    Matches `ref.attention` to f32 tolerance. seq must be divisible by the
    block sizes (pad upstream if not — the AOT model uses compliant shapes).
    """
    heads, seq, head_dim = q.shape
    if k.shape != (heads, seq, head_dim) or v.shape != (heads, seq, head_dim):
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    if seq % block_q or seq % block_k:
        raise ValueError(f"seq={seq} not divisible by blocks ({block_q},{block_k})")

    n_q = seq // block_q
    n_k = seq // block_k
    scale = 1.0 / (head_dim ** 0.5)

    kernel = functools.partial(_flash_kernel, scale=scale, n_k_blocks=n_k)
    grid = (heads, n_q, n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, head_dim), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, head_dim), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, block_k, head_dim), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, seq, head_dim), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),        # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),        # running sum l
            pltpu.VMEM((block_q, head_dim), jnp.float32),  # output accumulator
        ],
        interpret=True,
    )(q, k, v)


def vmem_footprint_bytes(block_q: int, block_k: int, head_dim: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf).

    q + k + v blocks + scores tile + scratch (m, l, acc in f32).
    """
    blocks = (block_q + 2 * block_k) * head_dim * dtype_bytes
    scores = block_q * block_k * 4
    scratch = (block_q * 1 * 2 + block_q * head_dim) * 4
    return blocks + scores + scratch
