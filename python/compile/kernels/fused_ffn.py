"""L1 Pallas kernel: fused position-wise FFN (FFN0 -> GeLU -> FFN1).

The kernel-by-kernel mapping (Fig. 2D) materializes the [seq, d_ff]
activation in DRAM between FFN0 and FFN1; d_ff = 4 * d_model makes that the
largest intermediate in the layer. The fused dataflow mapping (Fig. 2C)
streams the hidden dimension through the GeLU in d_ff-tiles so only a
[block_seq, block_ff] tile is ever live, accumulating the second GEMM's
partial sums in VMEM scratch.

Grid: (seq_block, ff_block) with ff innermost carrying the accumulator —
the same HBM<->VMEM schedule a real TPU build would use; interpret=True for
CPU-PJRT execution (see flash_attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_SEQ = 64
DEFAULT_BLOCK_FF = 256


def _gelu(x):
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, acc_ref, *,
                n_ff_blocks: int):
    """One (seq_block, ff_block) grid step.

    h_j = GeLU(x @ W1[:, j] + b1[j]);  acc += h_j @ W2[j, :]
    The [block_seq, d_ff] hidden activation never exists in full.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]     # [block_seq, d_model]
    w1 = w1_ref[...]   # [d_model, block_ff]
    w2 = w2_ref[...]   # [block_ff, d_model]

    h = jax.lax.dot_general(
        x, w1, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b1_ref[...]
    h = _gelu(h)
    acc_ref[...] += jax.lax.dot_general(
        h.astype(w2.dtype), w2, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == n_ff_blocks - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] + b2_ref[...]).astype(o_ref.dtype)


def fused_ffn(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
              b2: jax.Array, *, block_seq: int = DEFAULT_BLOCK_SEQ,
              block_ff: int = DEFAULT_BLOCK_FF) -> jax.Array:
    """Fused GeLU(x W1 + b1) W2 + b2 over x: [seq, d_model].

    Matches `ref.ffn` to f32 tolerance. seq and d_ff must be divisible by
    the block sizes.
    """
    seq, d_model = x.shape
    d_ff = w1.shape[1]
    if w1.shape != (d_model, d_ff) or w2.shape != (d_ff, d_model):
        raise ValueError(f"weight shapes mismatch: {w1.shape} {w2.shape}")
    if b1.shape != (d_ff,) or b2.shape != (d_model,):
        raise ValueError(f"bias shapes mismatch: {b1.shape} {b2.shape}")
    block_seq = min(block_seq, seq)
    block_ff = min(block_ff, d_ff)
    if seq % block_seq or d_ff % block_ff:
        raise ValueError(
            f"seq={seq}/d_ff={d_ff} not divisible by blocks ({block_seq},{block_ff})")

    n_seq = seq // block_seq
    n_ff = d_ff // block_ff
    kernel = functools.partial(_ffn_kernel, n_ff_blocks=n_ff)

    # b1 is blocked along d_ff; b2 is broadcast to every grid step. Biases are
    # passed as [1, dim] so the VMEM blocks stay 2-D (TPU-friendly layout).
    return pl.pallas_call(
        kernel,
        grid=(n_seq, n_ff),
        in_specs=[
            pl.BlockSpec((block_seq, d_model), lambda i, j: (i, 0)),
            pl.BlockSpec((d_model, block_ff), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_ff), lambda i, j: (0, j)),
            pl.BlockSpec((block_ff, d_model), lambda i, j: (j, 0)),
            pl.BlockSpec((1, d_model), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_seq, d_model), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq, d_model), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_seq, d_model), jnp.float32)],
        interpret=True,
    )(x, w1, b1.reshape(1, d_ff), w2, b2.reshape(1, d_model))


def vmem_footprint_bytes(block_seq: int, block_ff: int, d_model: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf)."""
    x_blk = block_seq * d_model * dtype_bytes
    w_blks = 2 * block_ff * d_model * dtype_bytes
    h_tile = block_seq * block_ff * 4
    acc = block_seq * d_model * 4
    return x_blk + w_blks + h_tile + acc
