"""L1 Pallas kernel: row-blocked LayerNorm.

The LN kernels bracket both fused regions of the GPT layer (Fig. 2A); in
the fused dataflow mapping they run on the vector path of the same spatial
pipeline as the GEMMs, consuming activations a row-tile at a time so the
working set stays in VMEM. Grid: (seq_block,) — each step normalizes a
[block_seq, d_model] tile independently (LayerNorm reduces only across
features, so row tiles are embarrassingly parallel).

interpret=True as everywhere (see flash_attention.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_SEQ = 64


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [block_seq, d]
    mu = jnp.mean(x, axis=1, keepdims=True)
    var = jnp.mean((x - mu) * (x - mu), axis=1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
              eps: float = 1e-5,
              block_seq: int = DEFAULT_BLOCK_SEQ) -> jax.Array:
    """LayerNorm over the last axis of x: [seq, d_model].

    Matches `ref.layernorm` to f32 tolerance; seq must be divisible by
    block_seq (pad upstream otherwise).
    """
    seq, d = x.shape
    if gamma.shape != (d,) or beta.shape != (d,):
        raise ValueError(f"param shapes {gamma.shape}/{beta.shape} != ({d},)")
    block_seq = min(block_seq, seq)
    if seq % block_seq:
        raise ValueError(f"seq={seq} not divisible by block_seq={block_seq}")

    import functools
    kernel = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(seq // block_seq,),
        in_specs=[
            pl.BlockSpec((block_seq, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_seq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq, d), x.dtype),
        interpret=True,
    )(x, gamma.reshape(1, d), beta.reshape(1, d))
