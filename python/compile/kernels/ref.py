"""Pure-jnp reference oracles for the Pallas kernels (L1) and the GPT layer
model (L2).

Everything in this file is straight-line jax.numpy with no Pallas, no custom
control flow — it is the ground truth that `flash_attention.py`,
`fused_ffn.py`, and `model.py` are validated against in pytest/hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    """Numerically-stable softmax (matches the online-softmax kernel)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product attention over [heads, seq, head_dim] tensors.

    attention(Q, K, V) = softmax(Q K^T / sqrt(d)) V  — the §II-A kernel.
    """
    d = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(d))
    probs = softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def gelu(x: jax.Array) -> jax.Array:
    """tanh-approximated GeLU, as used by GPT-2/3."""
    c = jnp.sqrt(jnp.float32(2.0 / jnp.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def ffn(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
        b2: jax.Array) -> jax.Array:
    """Position-wise feed-forward: GeLU(x W1 + b1) W2 + b2."""
    return gelu(x @ w1 + b1) @ w2 + b2


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    """[seq, d_model] -> [heads, seq, head_dim]."""
    seq, d_model = x.shape
    return x.reshape(seq, n_heads, d_model // n_heads).transpose(1, 0, 2)


def merge_heads(x: jax.Array) -> jax.Array:
    """[heads, seq, head_dim] -> [seq, d_model]."""
    h, seq, hd = x.shape
    return x.transpose(1, 0, 2).reshape(seq, h * hd)


def gpt_layer(params: dict, x: jax.Array, n_heads: int) -> jax.Array:
    """One pre-norm GPT transformer layer over x: [seq, d_model].

    Mirrors the Fig. 2A dataflow graph: LN -> {Q,K,V} -> MHA1 -> Softmax ->
    MHA2 -> Proj -> Add -> LN -> FFN0 -> GeLU -> FFN1 -> Add.
    """
    h = layernorm(x, params["ln1_g"], params["ln1_b"])
    q = split_heads(h @ params["wq"] + params["bq"], n_heads)
    k = split_heads(h @ params["wk"] + params["bk"], n_heads)
    v = split_heads(h @ params["wv"] + params["bv"], n_heads)
    attn = merge_heads(attention(q, k, v))
    x = x + attn @ params["wo"] + params["bo"]
    h = layernorm(x, params["ln2_g"], params["ln2_b"])
    return x + ffn(h, params["w1"], params["b1"], params["w2"], params["b2"])
