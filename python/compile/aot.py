"""AOT bridge: lower every GPT-layer mapping variant to HLO *text* and emit a
manifest the Rust runtime uses to load, wire, and execute the artifacts.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run as `python -m compile.aot --outdir ../artifacts` (via `make artifacts`).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange).

    print_large_constants=True is essential: the default HLO printer elides
    big literals as `constant({...})`, silently dropping the baked model
    weights when the text is re-parsed by the Rust loader.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _shape_struct(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


class ArtifactWriter:
    def __init__(self, outdir: str):
        self.outdir = outdir
        self.artifacts = []

    def add(self, name: str, fn, in_shapes, out_shapes):
        """Lower `fn` at the given input shapes and record the artifact."""
        args = [_shape_struct(s) for s in in_shapes]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.outdir, fname), "w") as f:
            f.write(text)
        self.artifacts.append({
            "name": name,
            "file": fname,
            "inputs": [_spec(s) for s in in_shapes],
            "outputs": [_spec(s) for s in out_shapes],
        })
        return name


def build_manifest(cfg: M.GptConfig, outdir: str) -> dict:
    params = M.init_params(cfg)
    d, s, f = cfg.d_model, cfg.seq, cfg.d_ff
    h, hd = cfg.n_heads, cfg.head_dim

    X = [s, d]            # activations [seq, d_model]
    QKV = [h, s, hd]      # per-head tensors
    SC = [h, s, s]        # attention scores/probs
    FF = [s, f]           # FFN hidden

    w = ArtifactWriter(outdir)

    # ---- fused (whole layer, Pallas kernels inside) ----
    w.add("fused_layer", lambda x: (M.gpt_layer_fused(params, x, cfg),),
          [X], [X])

    # ---- kernel-by-kernel (one artifact per graph vertex) ----
    ks = M.make_kernel_by_kernel(params, cfg)
    w.add("kbk_ln1", lambda x: (ks["ln1"](x),), [X], [X])
    w.add("kbk_q", lambda x: (ks["q"](x),), [X], [QKV])
    w.add("kbk_k", lambda x: (ks["k"](x),), [X], [QKV])
    w.add("kbk_v", lambda x: (ks["v"](x),), [X], [QKV])
    w.add("kbk_mha1", lambda q, k: (ks["mha1"](q, k),), [QKV, QKV], [SC])
    w.add("kbk_softmax", lambda x: (ks["softmax"](x),), [SC], [SC])
    w.add("kbk_mha2", lambda p, v: (ks["mha2"](p, v),), [SC, QKV], [X])
    w.add("kbk_proj", lambda a: (ks["proj"](a),), [X], [X])
    w.add("kbk_add1", lambda x, y: (ks["add1"](x, y),), [X, X], [X])
    w.add("kbk_ln2", lambda x: (ks["ln2"](x),), [X], [X])
    w.add("kbk_ffn0", lambda x: (ks["ffn0"](x),), [X], [FF])
    w.add("kbk_gelu", lambda x: (ks["gelu"](x),), [FF], [FF])
    w.add("kbk_ffn1", lambda x: (ks["ffn1"](x),), [FF], [X])
    w.add("kbk_add2", lambda x, y: (ks["add2"](x, y),), [X, X], [X])

    # ---- vendor 4-partition mapping (§VII-B) ----
    vp = M.make_vendor_partitions(params, cfg)
    w.add("vendor_p1_qkv", lambda x: vp["p1_qkv"](x), [X], [QKV] * 3)
    w.add("vendor_p2_attn", lambda x, q, k, v: (vp["p2_attn"](x, q, k, v),),
          [X, QKV, QKV, QKV], [X])
    w.add("vendor_p3_ffn0", lambda y: (vp["p3_ffn0"](y),), [X], [FF])
    w.add("vendor_p4_ffn1", lambda y, hh: (vp["p4_ffn1"](y, hh),), [X, FF], [X])

    # ---- DFModel-optimized mapping (§VII-C) ----
    dp = M.make_dfmodel_partitions(params, cfg)
    w.add("dfm_p1_qkv", lambda x: dp["p1_qkv"](x), [X], [QKV] * 3)
    w.add("dfm_p2_attn", lambda q, k, v: (dp["p2_attn"](q, k, v),),
          [QKV] * 3, [X])
    w.add("dfm_p3_proj_ffn0", lambda x, a: dp["p3_proj_ffn0"](x, a),
          [X, X], [X, FF])
    w.add("dfm_p4_ffn1", lambda y, hh: (dp["p4_ffn1"](y, hh),), [X, FF], [X])

    # Pipelines tell the Rust executor how to wire the artifacts: named
    # buffers, steps in order, final output buffer. "x" is the external input.
    pipelines = {
        "fused": {
            "steps": [{"artifact": "fused_layer", "in": ["x"], "out": ["out"]}],
            "output": "out",
        },
        "kernel_by_kernel": {
            "steps": [
                {"artifact": "kbk_ln1", "in": ["x"], "out": ["h"]},
                {"artifact": "kbk_q", "in": ["h"], "out": ["q"]},
                {"artifact": "kbk_k", "in": ["h"], "out": ["k"]},
                {"artifact": "kbk_v", "in": ["h"], "out": ["v"]},
                {"artifact": "kbk_mha1", "in": ["q", "k"], "out": ["s"]},
                {"artifact": "kbk_softmax", "in": ["s"], "out": ["p"]},
                {"artifact": "kbk_mha2", "in": ["p", "v"], "out": ["a"]},
                {"artifact": "kbk_proj", "in": ["a"], "out": ["pj"]},
                {"artifact": "kbk_add1", "in": ["x", "pj"], "out": ["y"]},
                {"artifact": "kbk_ln2", "in": ["y"], "out": ["h2"]},
                {"artifact": "kbk_ffn0", "in": ["h2"], "out": ["f0"]},
                {"artifact": "kbk_gelu", "in": ["f0"], "out": ["g"]},
                {"artifact": "kbk_ffn1", "in": ["g"], "out": ["f1"]},
                {"artifact": "kbk_add2", "in": ["y", "f1"], "out": ["out"]},
            ],
            "output": "out",
        },
        "vendor": {
            "steps": [
                {"artifact": "vendor_p1_qkv", "in": ["x"], "out": ["q", "k", "v"]},
                {"artifact": "vendor_p2_attn", "in": ["x", "q", "k", "v"],
                 "out": ["y"]},
                {"artifact": "vendor_p3_ffn0", "in": ["y"], "out": ["h"]},
                {"artifact": "vendor_p4_ffn1", "in": ["y", "h"], "out": ["out"]},
            ],
            "output": "out",
        },
        "dfmodel": {
            "steps": [
                {"artifact": "dfm_p1_qkv", "in": ["x"], "out": ["q", "k", "v"]},
                {"artifact": "dfm_p2_attn", "in": ["q", "k", "v"], "out": ["a"]},
                {"artifact": "dfm_p3_proj_ffn0", "in": ["x", "a"],
                 "out": ["y", "h"]},
                {"artifact": "dfm_p4_ffn1", "in": ["y", "h"], "out": ["out"]},
            ],
            "output": "out",
        },
    }

    # Reference input/output for end-to-end numerics checking in Rust.
    x = jax.random.normal(jax.random.PRNGKey(7), (s, d), jnp.float32)
    expected = ref.gpt_layer(params, x, cfg.n_heads)
    np.asarray(x, dtype="<f4").tofile(os.path.join(outdir, "input_x.bin"))
    np.asarray(expected, dtype="<f4").tofile(
        os.path.join(outdir, "expected_out.bin"))

    return {
        "config": {
            "d_model": d, "n_heads": h, "seq": s, "d_ff": f,
            "head_dim": hd, "dtype": "f32",
        },
        "input_file": "input_x.bin",
        "expected_file": "expected_out.bin",
        "tolerance": 2e-4,
        "artifacts": w.artifacts,
        "pipelines": pipelines,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--d-model", type=int, default=M.DEFAULT_CONFIG.d_model)
    ap.add_argument("--n-heads", type=int, default=M.DEFAULT_CONFIG.n_heads)
    ap.add_argument("--seq", type=int, default=M.DEFAULT_CONFIG.seq)
    ap.add_argument("--d-ff", type=int, default=M.DEFAULT_CONFIG.d_ff)
    args = ap.parse_args()

    cfg = M.GptConfig(d_model=args.d_model, n_heads=args.n_heads,
                      seq=args.seq, d_ff=args.d_ff)
    os.makedirs(args.outdir, exist_ok=True)
    manifest = build_manifest(cfg, args.outdir)
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    n = len(manifest["artifacts"])
    print(f"wrote {n} HLO artifacts + manifest.json to {args.outdir}")


if __name__ == "__main__":
    main()
