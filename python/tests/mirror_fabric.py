#!/usr/bin/env python3
"""Python mirror of rust/src/fabric/ — the validation harness the Rust
subsystem's numerics were developed against (run directly: `python3
mirror_fabric.py`; it is not a pytest module).

Defines the same semantics (graph expansion, routing, schedules, packetized
event loop) as the Rust implementation, and validates the numeric
acceptance criteria:
  1. ring-algorithm on ring dims == analytical formula (near-exact)
  2. best-algo on contention-free FC/switch dims within 15% of analytical
  3. select flips algorithms between latency-bound and bandwidth-bound payloads
  4. DGX-1 hybrid cube-mesh quantifiably slower than the fully-connected shortcut
  5. hierarchical (BlueConnect) on a torus matches time_hier
"""
import heapq
import math
from itertools import product

GB = 1e9
NS = 1e-9

# ---- link techs ----
NVLINK4 = dict(bw=900.0 * GB, lat=150.0 * NS)
PCIE4 = dict(bw=25.0 * GB, lat=500.0 * NS)

RING, FC, SWITCH = "ring", "fc", "switch"


class Dim:
    def __init__(self, kind, size, link, cubemesh=False):
        self.kind = kind
        self.size = size
        self.bw = link["bw"]
        self.lat = link["lat"]
        self.cubemesh = cubemesh


def torus2d(x, y, link):
    return [Dim(RING, x, link), Dim(RING, y, link)]


def torus3d(x, y, z, link):
    return [Dim(RING, x, link), Dim(RING, y, link), Dim(RING, z, link)]


def dragonfly(g, n, link):
    return [Dim(FC, g, link), Dim(FC, n, link)]


def dgx1(n, link):
    return [Dim(FC, 8, link, cubemesh=True), Dim(SWITCH, n, link)]


def dgx2(n, link):
    return [Dim(SWITCH, 16, link), Dim(SWITCH, n, link)]


def ring_topo(n, link):
    return [Dim(RING, n, link)]


# ---- analytical model (mirror of collective/mod.rs) ----
AR, AG, RS_, A2A, BC, P2P = "AllReduce", "AllGather", "ReduceScatter", "AllToAll", "Broadcast", "P2P"


def a_time(coll, bytes_, dim):
    k = float(dim.size)
    if dim.size <= 1 or bytes_ <= 0:
        return 0.0
    b, a = dim.bw, dim.lat
    frac = (k - 1.0) / k
    if dim.kind == RING:
        return {
            AR: 2 * frac * bytes_ / b + 2 * (k - 1) * a,
            AG: frac * bytes_ / b + (k - 1) * a,
            RS_: frac * bytes_ / b + (k - 1) * a,
            BC: frac * bytes_ / b + (k - 1) * a,
            A2A: bytes_ * k / (4 * b) + (k - 1) * a,
            P2P: bytes_ / b + a,
        }[coll]
    if dim.kind == FC:
        return {
            AR: 2 * bytes_ / (k * b) + 2 * a,
            AG: bytes_ / (k * b) + a,
            RS_: bytes_ / (k * b) + a,
            BC: 2 * bytes_ / (k * b) + 2 * a,
            A2A: bytes_ / (k * b) + a,
            P2P: bytes_ / b + a,
        }[coll]
    return {
        AR: 2 * frac * bytes_ / b + 2 * a,
        AG: frac * bytes_ / b + a,
        RS_: frac * bytes_ / b + a,
        BC: bytes_ / b + a,
        A2A: frac * bytes_ / b + a,
        P2P: bytes_ / b + 2 * a,
    }[coll]


def a_time_hier(coll, bytes_, dims):
    active = [d for d in dims if d.size > 1]
    if not active or bytes_ <= 0:
        return 0.0
    if coll == AR:
        t, payload = 0.0, bytes_
        for d in active:
            t += a_time(RS_, payload, d)
            payload /= d.size
        for d in reversed(active):
            payload *= d.size
            t += a_time(AG, payload, d)
        return t
    if coll == RS_:
        t, payload = 0.0, bytes_
        for d in active:
            t += a_time(RS_, payload, d)
            payload /= d.size
        return t
    if coll == AG:
        total = math.prod(d.size for d in active)
        payload, t = bytes_ / total, 0.0
        for d in reversed(active):
            payload *= d.size
            t += a_time(AG, payload, d)
        return t
    if coll in (BC, A2A):
        return sum(a_time(coll, bytes_, d) for d in active)
    return max(a_time(P2P, bytes_, d) for d in active)


# ---- fabric graph ----
CUBE_EDGES = [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3),
              (4, 5), (4, 6), (4, 7), (5, 6), (5, 7), (6, 7),
              (0, 4), (1, 5), (2, 6), (3, 7)]
CUBE_RING = [0, 1, 2, 3, 7, 6, 5, 4]


def cube_next():
    """next-hop table within the 8-node cube-mesh, BFS lowest-id tie-break."""
    adj = {i: [] for i in range(8)}
    for a, b in CUBE_EDGES:
        adj[a].append(b)
        adj[b].append(a)
    for i in adj:
        adj[i].sort()
    nxt = [[0] * 8 for _ in range(8)]
    for dst in range(8):
        dist = {dst: 0}
        q = [dst]
        while q:
            u = q.pop(0)
            for v in adj[u]:
                if v not in dist:
                    dist[v] = dist[u] + 1
                    q.append(v)
        for u in range(8):
            if u == dst:
                nxt[u][dst] = u
            else:
                nxt[u][dst] = min(v for v in adj[u] if dist[v] == dist[u] - 1)
    return nxt


CUBE_NEXT = cube_next()


class Graph:
    def __init__(self, dims):
        self.dims = dims
        self.sizes = [d.size for d in dims]
        self.strides = []
        s = 1
        for d in dims:
            self.strides.append(s)
            s *= d.size
        self.n_chips = s
        self.links = []   # (src, dst, bw, lat)
        self.adj = {}
        self.link_ix = {}
        self.switch_base = [None] * len(dims)
        n_nodes = self.n_chips
        for di, d in enumerate(dims):
            if d.kind == SWITCH and d.size > 1:
                self.switch_base[di] = n_nodes
                n_nodes += self.n_chips // d.size
        self.n_nodes = n_nodes
        for di, d in enumerate(dims):
            if d.size <= 1:
                continue
            for line in self.lines(di):
                if d.cubemesh:
                    assert d.size == 8
                    for a, b in CUBE_EDGES:
                        self.add_link(line[a], line[b], d)
                        self.add_link(line[b], line[a], d)
                elif d.kind == RING:
                    k = d.size
                    for c in range(k):
                        self.add_link(line[c], line[(c + 1) % k], d)
                        if k > 2:
                            self.add_link(line[c], line[(c - 1) % k], d)
                elif d.kind == FC:
                    for a in range(d.size):
                        for b in range(d.size):
                            if a != b:
                                self.add_link(line[a], line[b], d)
                else:  # SWITCH
                    sw = self.switch_node(di, line[0])
                    for c in line:
                        self.add_link(c, sw, d)
                        self.add_link(sw, c, d)

    def add_link(self, a, b, d):
        ix = len(self.links)
        self.links.append((a, b, d.bw, d.lat))
        self.adj.setdefault(a, []).append(ix)
        self.link_ix[(a, b)] = ix

    def coords(self, chip):
        return [(chip // self.strides[i]) % self.sizes[i] for i in range(len(self.dims))]

    def chip_at(self, coords):
        return sum(c * s for c, s in zip(coords, self.strides))

    def lines(self, di):
        """all maximal lines along dim di (lists of chip ids, coord order)."""
        others = [range(self.sizes[i]) if i != di else [0] for i in range(len(self.dims))]
        out = []
        for combo in product(*others):
            base = list(combo)
            line = []
            for c in range(self.sizes[di]):
                base[di] = c
                line.append(self.chip_at(base))
            out.append(line)
        return out

    def switch_node(self, di, chip):
        co = self.coords(chip)
        stride, size = self.strides[di], self.sizes[di]
        cid = chip - co[di] * stride
        rank = (cid // (stride * size)) * stride + cid % stride
        return self.switch_base[di] + rank

    def dim_order_path(self, src, dst):
        path = []
        cur = self.coords(src)
        node = src
        dstc = self.coords(dst)
        for di, d in enumerate(self.dims):
            while cur[di] != dstc[di]:
                if d.cubemesh:
                    nxt = CUBE_NEXT[cur[di]][dstc[di]]
                elif d.kind == RING:
                    k = d.size
                    fwd = (dstc[di] - cur[di]) % k
                    bwd = (cur[di] - dstc[di]) % k
                    nxt = (cur[di] + 1) % k if fwd <= bwd else (cur[di] - 1) % k
                elif d.kind == FC:
                    nxt = dstc[di]
                else:  # SWITCH: two links via crossbar
                    nn = node + (dstc[di] - cur[di]) * self.strides[di]
                    sw = self.switch_node(di, node)
                    path.append(self.link_ix[(node, sw)])
                    path.append(self.link_ix[(sw, nn)])
                    node = nn
                    cur[di] = dstc[di]
                    continue
                nn = node + (nxt - cur[di]) * self.strides[di]
                path.append(self.link_ix[(node, nn)])
                node = nn
                cur[di] = nxt
        return path


# ---- schedules ----
class Builder:
    def __init__(self):
        self.msgs = []  # (src, dst, bytes, deps)

    def send(self, src, dst, nbytes, deps):
        self.msgs.append((src, dst, nbytes, list(deps)))
        return len(self.msgs) - 1


def snake_order(g, group):
    gset = sorted(group)
    vdims = varying_dims(g, gset)

    def key(chip):
        co = g.coords(chip)
        k, flip = 0, False
        for di in reversed(vdims):
            c = (g.sizes[di] - 1 - co[di]) if flip else co[di]
            k = k * g.sizes[di] + c
            flip ^= (co[di] % 2 == 1)
        return k

    return sorted(gset, key=key)


def varying_dims(g, group):
    base = g.coords(group[0])
    vd = set()
    for chip in group[1:]:
        for di, c in enumerate(g.coords(chip)):
            if c != base[di]:
                vd.add(di)
    return sorted(vd)


def ring_rs(b, ring, S, init):
    k = len(ring)
    if k < 2 or S <= 0:
        return {c: list(init.get(c, [])) for c in ring}
    chunk = S / k
    prev = {}
    for s in range(k - 1):
        cur = {}
        for i in range(k):
            deps = init.get(ring[i], []) if s == 0 else [prev[(i - 1) % k]]
            cur[i] = b.send(ring[i], ring[(i + 1) % k], chunk, deps)
        prev = cur
    return {ring[i]: [prev[(i - 1) % k]] for i in range(k)}


ring_ag = ring_rs  # identical message structure / cost


def direct_rs(b, group, S, init):
    k = len(group)
    if k < 2 or S <= 0:
        return {c: list(init.get(c, [])) for c in group}
    chunk = S / k
    finals = {c: [] for c in group}
    for i in range(k):
        for s in range(1, k):  # staggered: distinct receive slot per sender
            j = (i + s) % k
            m = b.send(group[i], group[j], chunk, init.get(group[i], []))
            finals[group[j]].append(m)
    return finals


direct_ag = direct_rs


def hd_rs(b, group, S, init):
    k = len(group)
    if k < 2 or S <= 0:
        return {c: list(init.get(c, [])) for c in group}
    assert k & (k - 1) == 0
    recv = {c: init.get(c, []) for c in group}
    d = k // 2
    while d >= 1:
        nxt = {}
        for i in range(k):
            p = i ^ d
            m = b.send(group[i], group[p], S * d / k, recv[group[i]])
            nxt.setdefault(group[p], []).append(m)
        recv = nxt
        d //= 2
    return recv


def hd_ag(b, group, S, init):
    k = len(group)
    if k < 2 or S <= 0:
        return {c: list(init.get(c, [])) for c in group}
    assert k & (k - 1) == 0
    recv = {c: init.get(c, []) for c in group}
    d = 1
    while d < k:
        nxt = {}
        for i in range(k):
            p = i ^ d
            m = b.send(group[i], group[p], S * d / k, recv[group[i]])
            nxt.setdefault(group[p], []).append(m)
        recv = nxt
        d *= 2
    return recv


def shift_a2a(b, group, S, init):
    k = len(group)
    if k < 2 or S <= 0:
        return {c: list(init.get(c, [])) for c in group}
    chunk = S / k
    recv = {c: init.get(c, []) for c in group}
    for r in range(1, k):
        nxt = {}
        for i in range(k):
            m = b.send(group[i], group[(i + r) % k], chunk, recv[group[i]])
            nxt.setdefault(group[(i + r) % k], []).append(m)
        recv = nxt
    return recv


def direct_a2a(b, group, S, init):
    return direct_rs(b, group, S, init)


def chain_bcast(b, ring, S, init):
    k = len(ring)
    if k < 2 or S <= 0:
        return {c: list(init.get(c, [])) for c in ring}
    m = max(16, min(512, 8 * k, math.ceil(S / 4096)))
    chunk = S / m
    finals = {c: [] for c in ring}
    prev_hop = {}
    for c in range(m):
        for h in range(k - 1):
            deps = list(init.get(ring[0], [])) if h == 0 else [prev_hop[h - 1]]
            mid = b.send(ring[h], ring[h + 1], chunk, deps)
            prev_hop[h] = mid
            if c == m - 1:
                finals[ring[h + 1]] = [mid]
    return finals


def scatter_ag_bcast(b, group, S, init):
    k = len(group)
    if k < 2 or S <= 0:
        return {c: list(init.get(c, [])) for c in group}
    chunk = S / k
    got = {}
    for j in range(1, k):
        got[group[j]] = [b.send(group[0], group[j], chunk, init.get(group[0], []))]
    got[group[0]] = list(init.get(group[0], []))
    return direct_ag(b, group, S, got)


def tree_bcast(b, group, S, init):
    k = len(group)
    if k < 2 or S <= 0:
        return {c: list(init.get(c, [])) for c in group}
    assert k & (k - 1) == 0
    got = {group[0]: list(init.get(group[0], []))}
    t = 1
    while t < k:
        for i in range(t):
            m = b.send(group[i], group[i + t], S, got[group[i]])
            got[group[i + t]] = [m]
        t *= 2
    finals = dict(got)
    finals[group[0]] = list(init.get(group[0], []))
    return finals


def sub_order(g, line, di):
    d = g.dims[di]
    if d.cubemesh:
        return [line[i] for i in CUBE_RING]
    return line


def hier_schedule(b, g, coll, group, S):
    vdims = varying_dims(g, group)
    if not vdims:
        return
    part = {}  # per-chip pending deps

    def lines_of(gr, di):
        by = {}
        for c in gr:
            co = g.coords(c)
            keyc = tuple(x for i, x in enumerate(co) if i != di)
            by.setdefault(keyc, []).append(c)
        return [sorted(v, key=lambda ch: g.coords(ch)[di]) for v in by.values()]

    def run_phase(di, fn_ring, fn_other, payload):
        nonlocal part
        nxt = {}
        for line in lines_of(group, di):
            d = g.dims[di]
            if d.kind == RING or d.cubemesh:
                o = sub_order(g, line, di)
                fin = fn_ring(b, o, payload, part)
            else:
                fin = fn_other(b, line, payload, part)
            nxt.update(fin)
        part = nxt

    if coll == AR:
        payload = S
        for di in vdims:
            run_phase(di, ring_rs, direct_rs, payload)
            payload /= g.sizes[di]
        for di in reversed(vdims):
            payload *= g.sizes[di]
            run_phase(di, ring_ag, direct_ag, payload)
    elif coll == RS_:
        payload = S
        for di in vdims:
            run_phase(di, ring_rs, direct_rs, payload)
            payload /= g.sizes[di]
    elif coll == AG:
        payload = S / math.prod(g.sizes[di] for di in vdims)
        for di in reversed(vdims):
            payload *= g.sizes[di]
            run_phase(di, ring_ag, direct_ag, payload)
    elif coll == A2A:
        for di in vdims:
            run_phase(di, shift_a2a, direct_a2a, S)
    elif coll == BC:
        owners = {group[0]}
        for di in vdims:
            for line in lines_of(group, di):
                roots = [c for c in line if c in owners]
                if not roots:
                    continue
                o = sub_order(g, line, di)
                while o[0] != roots[0]:
                    o = o[1:] + o[:1]
                d = g.dims[di]
                if d.kind == FC:
                    scatter_ag_bcast(b, o, S, part)
                else:
                    chain_bcast(b, o, S, part)
                owners.update(line)
    else:  # P2P
        b.send(group[0], group[-1], S, [])


def build_schedule(g, algo, coll, group, S):
    """returns list of msgs or None if infeasible."""
    b = Builder()
    k = len(group)
    if k < 2 or S <= 0:
        return b.msgs
    if coll == P2P:
        b.send(group[0], group[-1], S, [])
        return b.msgs
    if algo == "hier":
        hier_schedule(b, g, coll, group, S)
        return b.msgs
    order = snake_order(g, group)
    if algo == "hd" and (k & (k - 1)) != 0:
        return None
    if coll == AR:
        if algo == "ring":
            fin = ring_rs(b, order, S, {})
            ring_ag(b, order, S, fin)
        elif algo == "hd":
            fin = hd_rs(b, order, S, {})
            hd_ag(b, order, S, fin)
        else:
            fin = direct_rs(b, order, S, {})
            direct_ag(b, order, S, fin)
    elif coll == RS_:
        {"ring": ring_rs, "hd": hd_rs, "direct": direct_rs}[algo](b, order, S, {})
    elif coll == AG:
        {"ring": ring_ag, "hd": hd_ag, "direct": direct_ag}[algo](b, order, S, {})
    elif coll == A2A:
        {"ring": shift_a2a, "hd": shift_a2a, "direct": direct_a2a}[algo](b, order, S, {})
    elif coll == BC:
        if algo == "ring":
            chain_bcast(b, order, S, {})
        elif algo == "hd":
            tree_bcast(b, order, S, {})
        else:
            scatter_ag_bcast(b, order, S, {})
    return b.msgs


# ---- simulator ----
PKT_BYTES = 256e3
MIN_PKTS, MAX_PKTS = 16, 64


def simulate(g, msgs, routing="dimorder"):
    if not msgs:
        return dict(time=0.0, events=0, max_util=0.0)
    n = len(msgs)
    dep_cnt = [len(m[3]) for m in msgs]
    ready_t = [0.0] * n
    dependents = [[] for _ in range(n)]
    for i, m in enumerate(msgs):
        for d in m[3]:
            assert d < i, "deps must point backwards"
            dependents[d].append(i)
    paths = [None] * n
    pkts_left = [0] * n
    free = [0.0] * len(g.links)
    busy = [0.0] * len(g.links)
    heap = []
    seq = 0
    dists = {}

    def dist_to(dst):
        if dst not in dists:
            # BFS over reversed links
            radj = {}
            for ix, (a, bb, _, _) in enumerate(g.links):
                radj.setdefault(bb, []).append((a, ix))
            dd = {dst: 0}
            q = [dst]
            while q:
                u = q.pop(0)
                for v, _ in radj.get(u, []):
                    if v not in dd:
                        dd[v] = dd[u] + 1
                        q.append(v)
            dists[dst] = dd
        return dists[dst]

    def inject(i, t):
        nonlocal seq
        src, dst, nbytes, _ = msgs[i]
        if routing == "dimorder":
            paths[i] = g.dim_order_path(src, dst)
            hops = len(paths[i])
        else:
            hops = dist_to(dst)[src]
        npk = 1 if hops <= 1 else max(MIN_PKTS, min(MAX_PKTS, math.ceil(nbytes / PKT_BYTES)))
        npk = min(npk, max(1, math.ceil(nbytes / 1.0)))  # no zero-size pkts
        pkts_left[i] = npk
        for _ in range(npk):
            heapq.heappush(heap, (t, seq, i, src, 0))
            seq += 1

    def complete(i, t):
        for j in dependents[i]:
            ready_t[j] = max(ready_t[j], t)
            dep_cnt[j] -= 1
            if dep_cnt[j] == 0:
                inject(j, ready_t[j])

    for i in range(n):
        if dep_cnt[i] == 0:
            inject(i, 0.0)
    events = 0
    end = 0.0
    done = 0
    while heap:
        t, _, i, node, hop = heapq.heappop(heap)
        events += 1
        src, dst, nbytes, _ = msgs[i]
        npk_total = pkts_left[i] if hop == 0 else None  # unused
        if node == dst:
            pkts_left[i] -= 1
            end = max(end, t)
            if pkts_left[i] == 0:
                done += 1
                complete(i, t)
            continue
        if routing == "dimorder":
            l = paths[i][hop]
        else:
            dd = dist_to(dst)
            cands = [ix for ix in g.adj[node] if dd.get(g.links[ix][1], 1 << 30) == dd[node] - 1]
            l = min(cands, key=lambda ix: (free[ix], ix))
        a, bnode, bw, lat = g.links[l]
        hops_total = len(paths[i]) if routing == "dimorder" else dist_to(dst)[src]
        npk = 1 if hops_total <= 1 else max(MIN_PKTS, min(MAX_PKTS, math.ceil(nbytes / PKT_BYTES)))
        size = nbytes / npk
        ts = max(t, free[l])
        free[l] = ts + size / bw
        busy[l] += size / bw
        heapq.heappush(heap, (free[l] + lat, seq, i, bnode, hop + 1))
        seq += 1
    assert done == n, f"deadlock: {done}/{n}"
    mx = max((bsy / end for bsy in busy), default=0.0) if end > 0 else 0.0
    return dict(time=end, events=events, max_util=mx)


ALGOS = ["ring", "hd", "direct", "hier"]


def best(g, coll, group, S, dims_for_analytical):
    results = {}
    for a in ALGOS:
        msgs = build_schedule(g, a, coll, group, S)
        if msgs is None:
            continue
        r = simulate(g, msgs)
        results[a] = r["time"]
    ana = a_time_hier(coll, S, dims_for_analytical)
    b = min(results, key=results.get)
    return b, results[b], results, ana


# =====================  validation  =====================
def rel(a, b):
    return abs(a - b) / max(abs(b), 1e-30)


def group_of_dims(g, vdims):
    out = []
    for chip in range(g.n_chips):
        co = g.coords(chip)
        if all(co[i] == 0 for i in range(len(g.dims)) if i not in vdims):
            out.append(chip)
    return out


fails = []


def check(name, cond, detail=""):
    status = "ok " if cond else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not cond:
        fails.append(name)


print("== 1. ring algorithm on ring dims is (near-)exact ==")
for k in [4, 8, 16]:
    for S in [1e6, 64e6]:
        g = Graph(ring_topo(k, NVLINK4))
        msgs = build_schedule(g, "ring", AR, list(range(k)), S)
        t = simulate(g, msgs)["time"]
        ana = a_time(AR, S, g.dims[0])
        check(f"ring({k}) AR S={S:.0e}", rel(t, ana) < 1e-9, f"sim={t:.3e} ana={ana:.3e}")
    for coll in [AG, RS_]:
        g = Graph(ring_topo(8, NVLINK4))
        msgs = build_schedule(g, "ring", coll, list(range(8)), 32e6)
        t = simulate(g, msgs)["time"]
        ana = a_time(coll, 32e6, g.dims[0])
        check(f"ring(8) {coll}", rel(t, ana) < 1e-9, f"sim={t:.3e} ana={ana:.3e}")

print("== 1b. ring dim inside torus2d(4,4), per-dim group ==")
g = Graph(torus2d(4, 4, NVLINK4))
for vd in [0, 1]:
    grp = group_of_dims(g, [vd])
    msgs = build_schedule(g, "ring", AR, grp, 16e6)
    t = simulate(g, msgs)["time"]
    ana = a_time(AR, 16e6, g.dims[vd])
    check(f"torus dim{vd} AR", rel(t, ana) < 1e-9, f"sim={t:.3e} ana={ana:.3e}")

print("== 1c. hier on torus2d(4,4) matches time_hier ==")
for S in [1e6, 64e6]:
    for coll in [AR, AG, RS_]:
        msgs = build_schedule(g, "hier", coll, list(range(16)), S)
        t = simulate(g, msgs)["time"]
        ana = a_time_hier(coll, S, g.dims)
        check(f"torus hier {coll} S={S:.0e}", rel(t, ana) < 0.02, f"sim={t:.3e} ana={ana:.3e} rel={rel(t,ana):.3f}")

print("== 2. FC / switch contention-free dims within 15% ==")
for kind, mk in [("fc", lambda k: [Dim(FC, k, NVLINK4)]), ("sw", lambda k: [Dim(SWITCH, k, NVLINK4)])]:
    for k in [2, 4, 8, 16]:
        for coll in [AR, AG, RS_, A2A, P2P]:
            for S in [16e6, 128e6]:
                g2 = Graph(mk(k))
                bname, t, allr, ana = best(g2, coll, list(range(k)), S, g2.dims)
                check(f"{kind}({k}) {coll} S={S:.0e}", rel(t, ana) < 0.15,
                      f"best={bname} sim={t:.3e} ana={ana:.3e} rel={rel(t,ana):+.3f}")

print("== 3. algorithm selection flips with payload ==")
for topo_name, dims, n in [("ring16", ring_topo(16, NVLINK4), 16),
                           ("torus4x4", torus2d(4, 4, NVLINK4), 16),
                           ("sw16", [Dim(SWITCH, 16, NVLINK4)], 16)]:
    g3 = Graph(dims)
    small = best(g3, AR, list(range(n)), 32e3, g3.dims)
    large = best(g3, AR, list(range(n)), 256e6, g3.dims)
    print(f"  {topo_name}: small(32KB) best={small[0]} {dict((a, f'{t:.2e}') for a, t in small[2].items())}")
    print(f"  {topo_name}: large(256MB) best={large[0]} {dict((a, f'{t:.2e}') for a, t in large[2].items())}")

print("== 4. DGX-1 cube-mesh slower than FC shortcut ==")
g4 = Graph(dgx1(2, NVLINK4))
grp8 = group_of_dims(g4, [0])
for S in [16e6, 128e6]:
    bname, t, allr, _ = best(g4, AR, grp8, S, g4.dims[:1])
    ana_fc = a_time(AR, S, Dim(FC, 8, NVLINK4))
    print(f"  dgx1 node AR S={S:.0e}: best={bname} sim={t:.3e} fc-ana={ana_fc:.3e} gap={t/ana_fc:.2f}x")
    check(f"dgx1 gap S={S:.0e}", t > ana_fc * 1.05, "")

print("== 5. five 64-chip topologies, AR 64MB: sim vs analytical (the figure) ==")
for name, dims in [("torus2d8x8", torus2d(8, 8, NVLINK4)),
                   ("torus3d4", torus3d(4, 4, 4, NVLINK4)),
                   ("dragonfly8x8", dragonfly(8, 8, NVLINK4)),
                   ("dgx1x8", dgx1(8, NVLINK4)),
                   ("dgx2x4", dgx2(4, NVLINK4))]:
    g5 = Graph(dims)
    bname, t, allr, ana = best(g5, AR, list(range(g5.n_chips)), 64e6, g5.dims)
    print(f"  {name:14s} best={bname:6s} sim={t:.4e} ana={ana:.4e} ratio={t/ana:.2f} "
          f"{dict((a, f'{x:.2e}') for a, x in allr.items())}")

print("== 6. determinism ==")
g6 = Graph(torus2d(4, 4, NVLINK4))
m6 = build_schedule(g6, "direct", A2A, list(range(16)), 8e6)
r1 = simulate(g6, m6)
r2 = simulate(g6, m6)
check("deterministic", r1 == r2, f"{r1['time']:.6e}")

print("== 7. adaptive routing sanity (A2A on torus) ==")
tadp = simulate(g6, m6, routing="adaptive")
print(f"  dimorder={r1['time']:.4e} adaptive={tadp['time']:.4e}")

print()
print("FAILURES:", fails if fails else "none")
