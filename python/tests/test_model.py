"""L2 model correctness: every mapping variant computes the same layer.

The four variants (fused, kernel-by-kernel, vendor 4-partition, DFModel
3+1-partition) are different *schedules* of the same dataflow graph — they
must be numerically equivalent to the ref.gpt_layer oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

CFG = M.DEFAULT_CONFIG
PARAMS = M.init_params(CFG)
X = jax.random.normal(jax.random.PRNGKey(7), (CFG.seq, CFG.d_model),
                      jnp.float32)
EXPECTED = ref.gpt_layer(PARAMS, X, CFG.n_heads)

TOL = dict(rtol=2e-4, atol=2e-4)


class TestVariantEquivalence:
    def test_fused_matches_ref(self):
        np.testing.assert_allclose(
            M.gpt_layer_fused(PARAMS, X, CFG), EXPECTED, **TOL)

    def test_kernel_by_kernel_matches_ref(self):
        np.testing.assert_allclose(
            M.run_kernel_by_kernel(PARAMS, X, CFG), EXPECTED, **TOL)

    def test_vendor_partitions_match_ref(self):
        np.testing.assert_allclose(M.run_vendor(PARAMS, X, CFG), EXPECTED, **TOL)

    def test_dfmodel_partitions_match_ref(self):
        np.testing.assert_allclose(M.run_dfmodel(PARAMS, X, CFG), EXPECTED, **TOL)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_all_variants_agree_on_random_inputs(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed),
                              (CFG.seq, CFG.d_model), jnp.float32)
        want = ref.gpt_layer(PARAMS, x, CFG.n_heads)
        np.testing.assert_allclose(M.run_kernel_by_kernel(PARAMS, x, CFG),
                                   want, **TOL)
        np.testing.assert_allclose(M.run_vendor(PARAMS, x, CFG), want, **TOL)
        np.testing.assert_allclose(M.run_dfmodel(PARAMS, x, CFG), want, **TOL)


class TestSmallConfigs:
    @settings(max_examples=4, deadline=None)
    @given(
        n_heads=st.sampled_from([1, 2, 4]),
        seq=st.sampled_from([64, 128]),
        seed=st.integers(0, 2**10),
    )
    def test_fused_matches_ref_across_configs(self, n_heads, seq, seed):
        cfg = M.GptConfig(d_model=64, n_heads=n_heads, seq=seq, d_ff=256)
        params = M.init_params(cfg, seed=seed)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (cfg.seq, cfg.d_model), jnp.float32)
        np.testing.assert_allclose(
            M.gpt_layer_fused(params, x, cfg),
            ref.gpt_layer(params, x, cfg.n_heads), **TOL)


class TestParams:
    def test_init_deterministic(self):
        a = M.init_params(CFG, seed=3)
        b = M.init_params(CFG, seed=3)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])

    def test_param_shapes(self):
        p = PARAMS
        d, f = CFG.d_model, CFG.d_ff
        assert p["wq"].shape == (d, d)
        assert p["w1"].shape == (d, f)
        assert p["w2"].shape == (f, d)
        assert p["ln1_g"].shape == (d,)
