"""L1 layernorm kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.layernorm import layernorm


def rand(seed, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


class TestLayerNorm:
    def test_matches_ref_default(self):
        x = rand(0, (128, 256))
        g = rand(1, (256,), 0.5) + 1.0
        b = rand(2, (256,), 0.1)
        np.testing.assert_allclose(layernorm(x, g, b), ref.layernorm(x, g, b),
                                   rtol=2e-5, atol=2e-5)

    def test_single_block(self):
        x = rand(3, (32, 64))
        g = jnp.ones((64,))
        b = jnp.zeros((64,))
        out = layernorm(x, g, b, block_seq=32)
        np.testing.assert_allclose(out, ref.layernorm(x, g, b), rtol=2e-5, atol=2e-5)
        # normalized rows: zero mean, unit variance
        np.testing.assert_allclose(np.mean(out, axis=1), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.var(out, axis=1), 1.0, atol=1e-3)

    def test_rejects_bad_shapes(self):
        x = rand(4, (64, 64))
        with pytest.raises(ValueError):
            layernorm(x, jnp.ones((32,)), jnp.zeros((64,)))
        with pytest.raises(ValueError):
            layernorm(rand(5, (96, 64)), jnp.ones((64,)), jnp.zeros((64,)),
                      block_seq=64)

    @settings(max_examples=10, deadline=None)
    @given(
        blocks=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([32, 64, 256]),
        block_seq=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_hypothesis(self, blocks, d, block_seq, seed):
        seq = blocks * block_seq
        x = rand(seed, (seq, d), 3.0)
        g = rand(seed + 1, (d,), 0.5) + 1.0
        b = rand(seed + 2, (d,), 0.1)
        out = layernorm(x, g, b, block_seq=block_seq)
        np.testing.assert_allclose(out, ref.layernorm(x, g, b),
                                   rtol=3e-5, atol=3e-5)
