"""AOT path: lowering produces loadable HLO text and a coherent manifest."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M

SMALL = M.GptConfig(d_model=64, n_heads=2, seq=64, d_ff=256)


def build_small(tmpdir):
    manifest = aot.build_manifest(SMALL, tmpdir)
    return manifest


class TestAot:
    def test_manifest_coherent(self):
        with tempfile.TemporaryDirectory() as td:
            m = build_small(td)
            names = {a["name"] for a in m["artifacts"]}
            # every pipeline step references an existing artifact
            for pname, pipe in m["pipelines"].items():
                for step in pipe["steps"]:
                    assert step["artifact"] in names, (pname, step)
            # every artifact file exists and is HLO text
            for a in m["artifacts"]:
                path = os.path.join(td, a["file"])
                assert os.path.exists(path)
                text = open(path).read()
                assert text.startswith("HloModule"), a["name"]
                assert "ENTRY" in text

    def test_pipeline_wiring_is_executable(self):
        # simulate the Rust executor: walk each pipeline, check every input
        # buffer is defined before use and shapes line up.
        with tempfile.TemporaryDirectory() as td:
            m = build_small(td)
            arts = {a["name"]: a for a in m["artifacts"]}
            for pname, pipe in m["pipelines"].items():
                defined = {"x": [SMALL.seq, SMALL.d_model]}
                for step in pipe["steps"]:
                    art = arts[step["artifact"]]
                    assert len(step["in"]) == len(art["inputs"]), (pname, step)
                    assert len(step["out"]) == len(art["outputs"])
                    for buf, spec in zip(step["in"], art["inputs"]):
                        assert buf in defined, (pname, step, buf)
                        assert defined[buf] == spec["shape"], (pname, buf)
                    for buf, spec in zip(step["out"], art["outputs"]):
                        defined[buf] = spec["shape"]
                assert pipe["output"] in defined

    def test_reference_binaries_roundtrip(self):
        with tempfile.TemporaryDirectory() as td:
            m = build_small(td)
            x = np.fromfile(os.path.join(td, m["input_file"]), dtype="<f4")
            out = np.fromfile(os.path.join(td, m["expected_file"]), dtype="<f4")
            assert x.size == SMALL.seq * SMALL.d_model
            assert out.size == SMALL.seq * SMALL.d_model
            assert np.all(np.isfinite(x)) and np.all(np.isfinite(out))

    def test_hlo_text_mentions_expected_structure(self):
        # The Rust integration test (rust/tests/runtime_e2e.rs) covers the
        # text -> PJRT compile -> execute path; here we sanity-check the text
        # itself: entry computation, parameter shapes, and a tuple root (the
        # lowering uses return_tuple=True which the Rust side unwraps).
        with tempfile.TemporaryDirectory() as td:
            m = build_small(td)
            text = open(os.path.join(td, "kbk_ln1.hlo.txt")).read()
            assert "ENTRY" in text
            assert f"f32[{SMALL.seq},{SMALL.d_model}]" in text
            assert "tuple" in text.lower()
            # input binary round-trips against the lowered shapes
            x = np.fromfile(os.path.join(td, m["input_file"]), dtype="<f4")
            spec = next(a for a in m["artifacts"] if a["name"] == "kbk_ln1")
            assert x.size == np.prod(spec["inputs"][0]["shape"])
            _ = jax  # jitted lowering exercised in build_small
