"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/blocks; fixed cases pin the AOT shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention, vmem_footprint_bytes
from compile.kernels import fused_ffn as ffn_mod
from compile.kernels.fused_ffn import fused_ffn


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    def test_matches_ref_default_shape(self):
        q, k, v = (rand(i, (4, 128, 64)) for i in range(3))
        np.testing.assert_allclose(
            flash_attention(q, k, v), ref.attention(q, k, v),
            rtol=2e-5, atol=2e-5)

    def test_single_head(self):
        q, k, v = (rand(i, (1, 64, 32)) for i in range(3))
        np.testing.assert_allclose(
            flash_attention(q, k, v), ref.attention(q, k, v),
            rtol=2e-5, atol=2e-5)

    def test_seq_equals_block(self):
        # degenerate: one q block, one k block — init and finalize same step
        q, k, v = (rand(i, (2, 64, 16)) for i in range(3))
        out = flash_attention(q, k, v, block_q=64, block_k=64)
        np.testing.assert_allclose(out, ref.attention(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_block_smaller_than_seq(self):
        q, k, v = (rand(i, (2, 256, 32)) for i in range(3))
        out = flash_attention(q, k, v, block_q=32, block_k=64)
        np.testing.assert_allclose(out, ref.attention(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_large_magnitude_logits_stable(self):
        # online softmax must not overflow with large score magnitudes
        q, k, v = (rand(i, (2, 128, 32), scale=8.0) for i in range(3))
        out = flash_attention(q, k, v)
        assert np.all(np.isfinite(out))
        np.testing.assert_allclose(out, ref.attention(q, k, v),
                                   rtol=2e-4, atol=2e-4)

    def test_rejects_mismatched_shapes(self):
        q = rand(0, (2, 128, 32))
        k = rand(1, (2, 64, 32))
        with pytest.raises(ValueError):
            flash_attention(q, k, q)
        _ = k  # silence lint

    def test_rejects_indivisible_seq(self):
        q, k, v = (rand(i, (1, 96, 16)) for i in range(3))
        with pytest.raises(ValueError):
            flash_attention(q, k, v, block_q=64, block_k=64)

    @settings(max_examples=12, deadline=None)
    @given(
        heads=st.sampled_from([1, 2, 4]),
        seq_blocks=st.sampled_from([1, 2, 4]),
        head_dim=st.sampled_from([16, 32, 64]),
        block=st.sampled_from([32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_hypothesis(self, heads, seq_blocks, head_dim, block,
                                    seed):
        seq = block * seq_blocks
        q, k, v = (rand(seed + i, (heads, seq, head_dim)) for i in range(3))
        out = flash_attention(q, k, v, block_q=block, block_k=block)
        np.testing.assert_allclose(out, ref.attention(q, k, v),
                                   rtol=3e-5, atol=3e-5)

    def test_vmem_footprint_estimate_fits_tpu_vmem(self):
        # The documented block choice must fit a 16 MiB TPU VMEM with
        # double-buffering headroom (DESIGN.md §Perf).
        assert vmem_footprint_bytes(64, 64, 64) < 16 * 2**20 / 4


# ---------------------------------------------------------------------------
# fused ffn
# ---------------------------------------------------------------------------

class TestFusedFfn:
    def _args(self, seed, seq=128, d=256, f=1024):
        return (rand(seed, (seq, d)), rand(seed + 1, (d, f), 0.05),
                rand(seed + 2, (f,), 0.05), rand(seed + 3, (f, d), 0.05),
                rand(seed + 4, (d,), 0.05))

    def test_matches_ref_default_shape(self):
        x, w1, b1, w2, b2 = self._args(0)
        np.testing.assert_allclose(
            fused_ffn(x, w1, b1, w2, b2), ref.ffn(x, w1, b1, w2, b2),
            rtol=2e-4, atol=2e-4)

    def test_single_ff_block(self):
        x, w1, b1, w2, b2 = self._args(5, seq=64, d=32, f=128)
        out = fused_ffn(x, w1, b1, w2, b2, block_seq=64, block_ff=128)
        np.testing.assert_allclose(out, ref.ffn(x, w1, b1, w2, b2),
                                   rtol=2e-4, atol=2e-4)

    def test_rejects_indivisible_dff(self):
        x, w1, b1, w2, b2 = self._args(6, f=96 * 4)
        with pytest.raises(ValueError):
            fused_ffn(x, w1, b1, w2, b2, block_ff=256)

    def test_rejects_bad_weight_shape(self):
        x, w1, b1, w2, b2 = self._args(7)
        with pytest.raises(ValueError):
            fused_ffn(x, w1.T, b1, w2, b2)

    @settings(max_examples=10, deadline=None)
    @given(
        seq=st.sampled_from([32, 64, 128]),
        d=st.sampled_from([32, 64, 128]),
        ff_blocks=st.sampled_from([1, 2, 4]),
        block_ff=st.sampled_from([64, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_hypothesis(self, seq, d, ff_blocks, block_ff, seed):
        f = block_ff * ff_blocks
        x, w1, b1, w2, b2 = self._args(seed, seq=seq, d=d, f=f)
        out = fused_ffn(x, w1, b1, w2, b2, block_seq=32, block_ff=block_ff)
        np.testing.assert_allclose(out, ref.ffn(x, w1, b1, w2, b2),
                                   rtol=3e-4, atol=3e-4)

    def test_vmem_footprint_estimate(self):
        assert ffn_mod.vmem_footprint_bytes(64, 256, 256) < 16 * 2**20 / 4
