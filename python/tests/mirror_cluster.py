#!/usr/bin/env python3
"""Python mirror of rust/src/cluster/ at the PR-10 refactor — the validation
harness the Rust rewrite's numerics were developed against (run directly:
`python3 mirror_cluster.py`; it is not a pytest module).

Mirrors the seeded PRNG (xoshiro256**), the workload generators, the
analytical serving oracle, the PR-2 BinaryHeap engine, and the PR-10
calendar-queue/arena/streaming engine, and validates:
  1. calendar-queue pop order is bit-identical to the binary heap's
     (time, seq) order on seeded random streams, including timestamp ties
  2. the new lazy-arrival engine reproduces the old engine's per-request
     metrics, event/step counts, KV peak, and makespan BITWISE on Poisson,
     bursty, multi-replica, and oversized-reject traces
  3. P2 streaming quantile estimates land within the documented tolerance
     of exact percentiles on exponential / log-normal / bursty-sim samples
     (5% relative at p50/p95, 10% at p99, exact for n <= 5)
  4. the rustdoc-example constants (P2 median of 1..=1001) hold
  5. fleet mode: R replicas at R*rate behave like 1 replica at rate
     (mean TPOT within 10%), and arena peak occupancy stays O(in-flight),
     independent of request count
"""
import heapq
import math

MASK = (1 << 64) - 1

# ---------------------------------------------------------------- util::prng


class Rng:
    """xoshiro256** with SplitMix64 seeding (mirror of util::prng::Rng)."""

    def __init__(self, seed):
        x = (seed + 0x9E3779B97F4A7C15) & MASK
        s = []
        for _ in range(4):
            x = (x + 0x9E3779B97F4A7C15) & MASK
            z = x
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        r = (self._rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = self._rotl(s[3], 45)
        return r

    @staticmethod
    def _rotl(x, k):
        return ((x << k) | (x >> (64 - k))) & MASK

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def normal(self):
        u1 = max(self.f64(), 2.2250738585072014e-308)
        u2 = self.f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def exp(self, lam):
        return -math.log(1.0 - self.f64()) / lam

    def lognormal_mean(self, mean, sigma):
        mu = math.log(mean) - 0.5 * sigma * sigma
        return math.exp(mu + sigma * self.normal())


def round_half_away(v):
    """Rust f64::round (half away from zero) for non-negative v."""
    return math.floor(v + 0.5)


# ---------------------------------------------------------- cluster::workload


class Poisson:
    def __init__(self, rate):
        self.rate = rate

    def rate_at(self, t):
        return self.rate

    def peak(self):
        return self.rate


class Bursty:
    def __init__(self, base, peak, period):
        self.base, self.pk, self.period = base, peak, period

    def rate_at(self, t):
        return self.base + (self.pk - self.base) * 0.5 * (
            1.0 + math.sin(2.0 * math.pi * t / self.period)
        )

    def peak(self):
        return self.pk


def next_after(arr, t, rng):
    lmax = arr.peak()
    while True:
        t += rng.exp(lmax)
        if rng.f64() * lmax <= arr.rate_at(t):
            return t


class LengthDist:
    def __init__(self, mean, sigma, lo, hi):
        self.mean, self.sigma, self.lo, self.hi = mean, sigma, lo, hi

    def sample(self, rng):
        v = rng.lognormal_mean(self.mean, self.sigma)
        return min(max(round_half_away(v), max(self.lo, 1)), self.hi)


class TraceSpec:
    def __init__(self, seed, n, arrivals, prompt, output):
        self.seed, self.n, self.arrivals = seed, n, arrivals
        self.prompt, self.output = prompt, output

    @staticmethod
    def poisson(seed, rate, n):
        return TraceSpec(
            seed, n, Poisson(rate),
            LengthDist(1024.0, 0.4, 16, 8192), LengthDist(128.0, 0.6, 2, 2048),
        )

    def stream(self):
        rng = Rng(self.seed)
        t = 0.0
        for i in range(self.n):
            t = next_after(self.arrivals, t, rng)
            yield (i, t, self.prompt.sample(rng), self.output.sample(rng))

    def generate(self):
        return list(self.stream())


# ------------------------------------------------------------------- serving

TFLOPS = 1e12
GB = 1e9

LLAMA8B = dict(layers=32, d_model=4096.0, n_heads=32.0, n_kv_heads=8.0,
               d_ff=14336.0, vocab=128256.0, dtype=2.0)
LLAMA70B = dict(layers=80, d_model=8192.0, n_heads=64.0, n_kv_heads=8.0,
                d_ff=28672.0, vocab=128256.0, dtype=2.0)


def params_per_layer(m):
    kv_dim = m["n_kv_heads"] * m["d_model"] / m["n_heads"]
    return (2.0 * m["d_model"] ** 2 + 2.0 * m["d_model"] * kv_dim
            + 3.0 * m["d_model"] * m["d_ff"])


def params(m):
    return m["layers"] * params_per_layer(m) + 2.0 * m["vocab"] * m["d_model"]


def weight_bytes(m):
    return params(m) * m["dtype"]


def kv_bytes_per_token(m):
    head = m["d_model"] / m["n_heads"]
    return 2.0 * m["layers"] * m["n_kv_heads"] * head * m["dtype"]


SN40L_X16 = dict(flops=640.0 * TFLOPS, mem_bw=1.6e12, mem_cap=64.0 * GB,
                 link_bw=25.0 * GB, link_lat=150e-9, n_chips=16)

PREFILL_EFF = 0.8


def evaluate(model, sys, tp, pp, batch, prompt_len, context):
    """Mirror of serving::evaluate. Returns (ttft, tpot) or None."""
    if tp <= 0 or pp <= 0 or tp * pp != sys["n_chips"]:
        return None
    layers = float(model["layers"])
    lps = math.ceil(layers / pp)
    tokens = batch * prompt_len
    flops_layer = (2.0 * params_per_layer(model) * tokens / tp
                   + 4.0 * prompt_len * model["d_model"] * tokens / tp)
    t_comp = flops_layer / (sys["flops"] * PREFILL_EFF)
    w_layer_chip = params_per_layer(model) * model["dtype"] / tp
    t_mem = w_layer_chip / sys["mem_bw"]
    ar_bytes = tokens * model["d_model"] * model["dtype"]
    t_net = 0.0
    if tp > 1:
        t_net = 2.0 * (2.0 * (tp - 1.0) / tp * ar_bytes / sys["link_bw"]
                       + 2.0 * (tp - 1.0) * sys["link_lat"])
    t_layer = max(t_comp, t_mem, t_net)
    p2p = (tokens * model["d_model"] * model["dtype"] / tp / sys["link_bw"]
           + sys["link_lat"])
    ttft = layers * t_layer + (pp - 1.0) * p2p

    w_stage = params_per_layer(model) * lps * model["dtype"] / tp
    kv_stage = batch * context * kv_bytes_per_token(model) * lps / layers / tp
    t_mem_stage = (w_stage + kv_stage) / sys["mem_bw"]
    dec_flops = 2.0 * params_per_layer(model) * lps * batch / tp
    t_comp_stage = dec_flops / (sys["flops"] * 0.3)
    ar_dec = batch * model["d_model"] * model["dtype"]
    t_net_stage = 0.0
    if tp > 1:
        t_net_stage = lps * 2.0 * (
            2.0 * (tp - 1.0) / tp * ar_dec / sys["link_bw"]
            + 2.0 * (tp - 1.0) * sys["link_lat"])
    t_stage = max(t_mem_stage, t_comp_stage) + t_net_stage + (p2p if pp > 1 else 0.0)
    tpot = pp * t_stage
    return ttft, tpot


# --------------------------------------------------- engine shared plumbing


class Cfg:
    def __init__(self, model, sys, tp, pp, max_batch=32, kv_headroom=0.9):
        self.model, self.sys = model, sys
        self.tp, self.pp = tp, pp
        self.max_batch, self.kv_headroom = max_batch, kv_headroom

    def kv_budget(self):
        free = self.sys["mem_cap"] * self.sys["n_chips"] - weight_bytes(self.model)
        return free * self.kv_headroom if free > 0.0 else None

    def point(self, batch, prompt_len, context):
        return evaluate(self.model, self.sys, self.tp, self.pp, batch,
                        prompt_len, context)


def exact_percentiles(samples):
    if not samples:
        return (0.0, 0.0, 0.0, 0.0)
    s = sorted(samples)
    mean = math.fsum(s) / len(s)  # see note: Rust sums naively; fsum only
    # changes the mean by ULPs, irrelevant at the tolerances checked here
    at = lambda p: s[int(round_half_away(p * (len(s) - 1)))]
    return (mean, at(0.50), at(0.95), at(0.99))


# --------------------------------------------------------- OLD (PR-2) engine


def simulate_old(cfg, replicas, requests, slo):
    """Faithful mirror of the PR-2 BinaryHeap engine."""
    budget = cfg.kv_budget()
    kv_tok = kv_bytes_per_token(cfg.model)
    heap = []  # (t, seq, kind, payload); heapq pops min (t, seq)
    seq = 0
    for i, r in enumerate(requests):
        heapq.heappush(heap, (r[1], seq, "arr", i))
        seq += 1
    reps = [dict(queue=[], running=[], pending=[], kv=0.0, resident=0,
                 current=None) for _ in range(replicas)]
    st = [dict(gen=0, kv=0.0, adm=None, first=None, fin=None, rej=False)
          for _ in requests]
    events = steps = 0
    kv_peak = now = 0.0
    order = []  # processed event log, for the bitwise comparison

    def start_step(ri, t):
        nonlocal seq, steps, kv_peak
        rep = reps[ri]
        if rep["current"] is not None:
            return
        while True:
            if len(rep["running"]) + len(rep["pending"]) >= cfg.max_batch:
                break
            if not rep["queue"]:
                break
            i = rep["queue"][0]
            need = (requests[i][2] + requests[i][3]) * kv_tok
            if rep["kv"] + need > budget:
                break
            rep["queue"].pop(0)
            rep["kv"] += need
            rep["pending"].append(i)
            st[i]["kv"] = need
            st[i]["adm"] = t
        kv_peak = max(kv_peak, rep["kv"])
        if rep["pending"]:
            members = rep["pending"]
            rep["pending"] = []
            batch = float(len(members))
            prompt = float(max(requests[i][2] for i in members))
            dt = cfg.point(batch, prompt, prompt)[0]
            rep["current"] = ("prefill", members)
        elif rep["running"]:
            members = list(rep["running"])
            batch = float(len(members))
            ctx = sum(requests[i][2] + st[i]["gen"] for i in members) / batch
            dt = cfg.point(batch, 1.0, ctx)[1]
            rep["current"] = ("decode", members)
        else:
            return
        steps += 1
        heapq.heappush(heap, (t + dt, seq, "done", ri))
        seq += 1

    def finish(ri, i, t):
        st[i]["fin"] = t
        reps[ri]["kv"] -= st[i]["kv"]
        reps[ri]["resident"] -= 1

    while heap:
        t, _, kind, payload = heapq.heappop(heap)
        events += 1
        now = t
        order.append((t, kind, payload))
        if kind == "arr":
            i = payload
            need = (requests[i][2] + requests[i][3]) * kv_tok
            if need > budget:
                st[i]["rej"] = True
                continue
            ri = min(range(replicas), key=lambda r: (reps[r]["resident"], r))
            reps[ri]["resident"] += 1
            reps[ri]["queue"].append(i)
            start_step(ri, t)
        else:
            ri = payload
            k, members = reps[ri]["current"]
            reps[ri]["current"] = None
            if k == "prefill":
                for i in members:
                    st[i]["first"] = t
                    st[i]["gen"] = 1
                    if st[i]["gen"] >= requests[i][3]:
                        finish(ri, i, t)
                    else:
                        reps[ri]["running"].append(i)
            else:
                still = []
                for i in members:
                    st[i]["gen"] += 1
                    if st[i]["gen"] >= requests[i][3]:
                        finish(ri, i, t)
                    else:
                        still.append(i)
                reps[ri]["running"] = still
            start_step(ri, t)

    per, q, tt, tp = [], [], [], []
    good = rejected = 0
    tokens = 0.0
    for i, r in enumerate(requests):
        s = st[i]
        if s["rej"]:
            rejected += 1
            continue
        if s["first"] is None or s["fin"] is None or s["adm"] is None:
            continue
        ttft = s["first"] - r[1]
        tpot = (s["fin"] - s["first"]) / (r[3] - 1) if r[3] > 1 else 0.0
        q.append(s["adm"] - r[1])
        tt.append(ttft)
        if r[3] > 1:
            tp.append(tpot)
        tokens += r[3]
        if ttft <= slo[0] and (r[3] <= 1 or tpot <= slo[1]):
            good += 1
        per.append((r[0], s["adm"] - r[1], ttft, tpot, s["fin"] - r[1], r[3]))
    makespan = max(now, 1e-30)
    return dict(per=per, q=q, tt=tt, tp=tp, good=good, tokens=tokens,
                rejected=rejected, events=events, steps=steps,
                kv_peak=kv_peak, makespan=makespan, order=order)


# ------------------------------------------------- NEW (PR-10) calendar queue


class CalendarQueue:
    """Mirror of cluster::calendar::CalendarQueue — fixed-width circular
    buckets, lazy per-day min scan, direct-search fallback on sparse gaps."""

    def __init__(self, width, min_buckets):
        nb = 8
        while nb < min_buckets:
            nb *= 2
        self.buckets = [[] for _ in range(nb)]
        self.mask = nb - 1
        self.width = width
        self.day = 0
        self.n = 0
        self.seq = 0

    def day_of(self, t):
        return int(t / self.width)  # t >= 0, floor

    def push(self, t, v):
        d = self.day_of(t)
        if d < self.day:  # defensive rewind; unreachable from the engine
            self.day = d
        self.buckets[d & self.mask].append((t, self.seq, v))
        self.seq += 1
        self.n += 1

    def _find(self):
        """Advance `day` to the next non-empty day; return (bucket, idx) of
        its earliest (t, seq) entry."""
        if self.n == 0:
            return None
        scanned = 0
        while True:
            b = self.day & self.mask
            best = None
            for i, e in enumerate(self.buckets[b]):
                if self.day_of(e[0]) == self.day:
                    if best is None or (e[0], e[1]) < (
                        self.buckets[b][best][0], self.buckets[b][best][1]
                    ):
                        best = i
            if best is not None:
                return b, best
            self.day += 1
            scanned += 1
            if scanned > len(self.buckets):
                # every remaining entry is beyond a full calendar year of
                # empty days: jump straight to the earliest remaining day
                self.day = min(
                    self.day_of(e[0]) for bk in self.buckets for e in bk
                )
                scanned = 0

    def peek_t(self):
        pos = self._find()
        if pos is None:
            return None
        b, i = pos
        return self.buckets[b][i][0]

    def pop(self):
        pos = self._find()
        if pos is None:
            return None
        b, i = pos
        e = self.buckets[b][i]
        last = self.buckets[b].pop()  # swap_remove
        if i < len(self.buckets[b]):
            self.buckets[b][i] = last
        self.n -= 1
        return e[0], e[2]


# --------------------------------------------------------- NEW (PR-10) engine


def simulate_new(cfg, replicas, source, slo, n_hint=None):
    """Mirror of the PR-10 lazy-arrival calendar-queue engine.
    `source` is an iterator of (id, arrival, prompt, output)."""
    budget = cfg.kv_budget()
    kv_tok = kv_bytes_per_token(cfg.model)
    probe = cfg.point(1.0, 1.0, 1.0)
    width = max(probe[1], 1e-9)  # batch-1 decode step = finest event grain
    cq = CalendarQueue(width, 2 * replicas)
    reps = [dict(queue=[], running=[], pending=[], stepping=[], kv=0.0,
                 resident=0, in_step=None) for _ in range(replicas)]
    pool = {}  # arena mirror: handle -> state
    free = []
    next_slot = 0
    live = peak = 0
    events = steps = 0
    kv_peak = now = 0.0
    offered = rejected = 0
    order = []
    per, q, tt, tp = [], [], [], []
    good = 0
    tokens = 0.0
    completed = 0

    def alloc(state):
        nonlocal next_slot, live, peak
        h = free.pop() if free else next_slot
        if h == next_slot:
            next_slot += 1
        pool[h] = state
        live += 1
        peak = max(peak, live)
        return h

    def release(h):
        nonlocal live
        s = pool.pop(h)
        free.append(h)
        live -= 1
        return s

    def record(s, t):
        nonlocal good, tokens, completed
        queue_time = s["adm"] - s["arrival"]
        ttft = s["first"] - s["arrival"]
        tpot = (t - s["first"]) / (s["output"] - 1) if s["output"] > 1 else 0.0
        completed += 1
        tokens += s["output"]
        if ttft <= slo[0] and (s["output"] <= 1 or tpot <= slo[1]):
            good += 1
        q.append(queue_time)
        tt.append(ttft)
        if s["output"] > 1:
            tp.append(tpot)
        per.append((s["id"], queue_time, ttft, tpot, t - s["arrival"],
                    s["output"]))

    def start_step(ri, t):
        nonlocal steps, kv_peak
        rep = reps[ri]
        if rep["in_step"] is not None:
            return
        while True:
            if len(rep["running"]) + len(rep["pending"]) >= cfg.max_batch:
                break
            if not rep["queue"]:
                break
            h = rep["queue"][0]
            s = pool[h]
            need = (s["prompt"] + s["output"]) * kv_tok
            if rep["kv"] + need > budget:
                break
            rep["queue"].pop(0)
            rep["kv"] += need
            rep["pending"].append(h)
            s["kv"] = need
            s["adm"] = t
        kv_peak = max(kv_peak, rep["kv"])
        if rep["pending"]:
            batch = float(len(rep["pending"]))
            prompt = float(max(pool[h]["prompt"] for h in rep["pending"]))
            dt = cfg.point(batch, prompt, prompt)[0]
            rep["stepping"], rep["pending"] = rep["pending"], rep["stepping"]
            rep["in_step"] = "prefill"
        elif rep["running"]:
            batch = float(len(rep["running"]))
            ctx = sum(pool[h]["prompt"] + pool[h]["gen"]
                      for h in rep["running"]) / batch
            dt = cfg.point(batch, 1.0, ctx)[1]
            rep["in_step"] = "decode"
        else:
            return
        steps += 1
        cq.push(t + dt, ri)

    def step_done(ri, t):
        rep = reps[ri]
        kind = rep["in_step"]
        rep["in_step"] = None
        freed = 0.0
        done = 0
        if kind == "prefill":
            for h in rep["stepping"]:
                s = pool[h]
                s["first"] = t
                s["gen"] = 1
                if s["gen"] >= s["output"]:
                    s = release(h)
                    freed += s["kv"]
                    done += 1
                    record(s, t)
                else:
                    rep["running"].append(h)
            rep["stepping"].clear()
        else:
            still = []
            for h in rep["running"]:
                s = pool[h]
                s["gen"] += 1
                if s["gen"] >= s["output"]:
                    s = release(h)
                    freed += s["kv"]
                    done += 1
                    record(s, t)
                else:
                    still.append(h)
            rep["running"][:] = still
        rep["kv"] -= freed
        rep["resident"] -= done
        start_step(ri, t)

    pending_arrival = next(source, None)
    while True:
        qt = cq.peek_t()
        if pending_arrival is not None and (qt is None or pending_arrival[1] <= qt):
            rid, t, prompt, output = pending_arrival
            pending_arrival = next(source, None)
            events += 1
            now = t
            offered += 1
            order.append((t, "arr", rid))
            need = (prompt + output) * kv_tok
            if need > budget:
                rejected += 1
                continue
            h = alloc(dict(id=rid, arrival=t, prompt=prompt, output=output,
                           gen=0, kv=0.0, adm=None, first=None))
            ri = min(range(replicas), key=lambda r: (reps[r]["resident"], r))
            reps[ri]["resident"] += 1
            reps[ri]["queue"].append(h)
            start_step(ri, t)
        elif qt is not None:
            t, ri = cq.pop()
            events += 1
            now = t
            order.append((t, "done", ri))
            step_done(ri, t)
        else:
            break

    per.sort(key=lambda m: m[0])
    makespan = max(now, 1e-30)
    return dict(per=per, q=q, tt=tt, tp=tp, good=good, tokens=tokens,
                rejected=rejected, events=events, steps=steps,
                kv_peak=kv_peak, makespan=makespan, order=order,
                peak_in_flight=peak, completed=completed, offered=offered)


# ------------------------------------------------------------- P2 estimator


class P2Quantile:
    """Jain & Chlamtac P2: single-quantile streaming estimator, 5 markers."""

    def __init__(self, p):
        self.p = p
        self.q = []
        self.n = [1.0, 2.0, 3.0, 4.0, 5.0]
        self.np = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0]
        self.dn = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]
        self.count = 0

    def observe(self, x):
        self.count += 1
        if self.count <= 5:
            self.q.append(x)
            self.q.sort()
            return
        q, n = self.q, self.n
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            if x > q[4]:
                q[4] = x
            k = 3
        else:
            k = 0
            while x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1.0
        for i in range(5):
            self.np[i] += self.dn[i]
        for i in range(1, 4):
            d = self.np[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1.0) or (
                d <= -1.0 and n[i - 1] - n[i] < -1.0
            ):
                ds = 1.0 if d > 0.0 else -1.0
                qp = q[i] + ds / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + ds) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - ds) * (q[i] - q[i - 1]) / (n[i] - n[i - 1])
                )
                if q[i - 1] < qp < q[i + 1]:
                    q[i] = qp
                else:
                    j = i + (1 if ds > 0.0 else -1)
                    q[i] = q[i] + ds * (q[j] - q[i]) / (n[j] - n[i])
                n[i] += ds

    def estimate(self):
        if self.count == 0:
            return 0.0
        if self.count <= 5:
            s = sorted(self.q)
            return s[int(round_half_away(self.p * (len(s) - 1)))]
        return self.q[2]


class StreamingPcts:
    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.p50 = P2Quantile(0.50)
        self.p95 = P2Quantile(0.95)
        self.p99 = P2Quantile(0.99)

    def observe(self, x):
        self.count += 1
        self.total += x
        self.p50.observe(x)
        self.p95.observe(x)
        self.p99.observe(x)

    def pcts(self):
        if self.count == 0:
            return (0.0, 0.0, 0.0, 0.0)
        return (self.total / self.count, self.p50.estimate(),
                self.p95.estimate(), self.p99.estimate())


# ------------------------------------------------------------------- checks

FAIL = []


def check(name, ok, detail=""):
    tag = "ok  " if ok else "FAIL"
    print(f"  {tag} {name} {detail}")
    if not ok:
        FAIL.append(name)


def check_calendar_vs_heap():
    print("[1] calendar queue == binary heap order")
    for seed in (1, 7, 42):
        rng = Rng(seed)
        cq = CalendarQueue(0.001, 8)
        heap = []
        hseq = 0
        got, want = [], []
        t = 0.0
        last_t = 0.0
        # interleaved pushes and pops, with deliberate duplicate timestamps
        for _ in range(5000):
            r = rng.f64()
            if r < 0.6 or not heap:
                if rng.f64() < 0.1 and hseq > 0:
                    tt = last_t  # exact duplicate: FIFO tie-break exercised
                else:
                    t += rng.exp(3.0)
                    tt = t + rng.exp(0.5)
                last_t = tt
                v = hseq
                cq.push(tt, v)
                heapq.heappush(heap, (tt, hseq, v))
                hseq += 1
            else:
                got.append(cq.pop())
                w = heapq.heappop(heap)
                want.append((w[0], w[2]))
        while heap:
            got.append(cq.pop())
            w = heapq.heappop(heap)
            want.append((w[0], w[2]))
        check(f"seed {seed}: {len(want)} pops identical", got == want)


def results_equal(a, b):
    # q/tt/tp accumulate in id order (old) vs completion order (new); the
    # exact path sorts before summarizing, so compare them sorted — every
    # other field, including per-request metrics, must match bitwise.
    keys = ["per", "good", "tokens", "rejected", "events", "steps",
            "kv_peak", "makespan"]
    return all(a[k] == b[k] for k in keys) and all(
        sorted(a[k]) == sorted(b[k]) for k in ("q", "tt", "tp")
    )


def check_old_vs_new():
    print("[2] new engine == old engine (bitwise)")
    cfg8 = Cfg(LLAMA8B, SN40L_X16, 16, 1)
    slo = (1.0, 0.02)
    cases = [
        ("poisson r4 n120 1rep", TraceSpec.poisson(2, 4.0, 120), 1),
        ("poisson r30 n200 4rep", TraceSpec.poisson(6, 30.0, 200), 4),
        ("poisson r40 n500 1rep saturated", TraceSpec.poisson(7, 40.0, 500), 1),
        ("bursty n300 2rep",
         TraceSpec(5, 300, Bursty(2.0, 14.0, 30.0),
                   LengthDist(1024.0, 0.4, 16, 8192),
                   LengthDist(128.0, 0.6, 2, 2048)), 2),
    ]
    for name, spec, reps in cases:
        reqs = spec.generate()
        old = simulate_old(cfg8, reps, reqs, slo)
        new = simulate_new(cfg8, reps, iter(reqs), slo)
        check(name, results_equal(old, new) and old["order"] == new["order"],
              f"(events {old['events']} vs {new['events']})")
    # oversized reject
    reqs = TraceSpec.poisson(4, 2.0, 20).generate()
    reqs[5] = (reqs[5][0], reqs[5][1], 80_000_000, reqs[5][3])
    old = simulate_old(cfg8, 1, reqs, slo)
    new = simulate_new(cfg8, 1, iter(reqs), slo)
    check("oversized reject", results_equal(old, new)
          and new["rejected"] == 1 and new["completed"] == 19)


def rel_errs(samples):
    ex = exact_percentiles(samples)
    sp = StreamingPcts()
    for x in samples:
        sp.observe(x)
    est = sp.pcts()
    return [abs(e - x) / abs(x) if x else abs(e - x)
            for e, x in zip(est, ex)]


def check_p2_tolerance():
    print("[3] P2 vs exact percentiles (documented tolerance)")
    # smooth unimodal streams: the documented 5% (p50/p95) / 10% (p99) band
    worst = [0.0] * 4
    for seed in range(10):
        rng = Rng(100 + seed)
        expo = [rng.exp(2.0) for _ in range(20000)]
        logn = [rng.lognormal_mean(0.3, 0.6) for _ in range(20000)]
        for s in (expo, logn):
            e = rel_errs(s)
            worst = [max(w, x) for w, x in zip(worst, e)]
    print(f"       smooth worst rel err: mean {worst[0]:.4f} p50 "
          f"{worst[1]:.4f} p95 {worst[2]:.4f} p99 {worst[3]:.4f}")
    check("smooth mean exact-ish", worst[0] < 1e-9)
    check("smooth p50 within 5%", worst[1] < 0.05)
    check("smooth p95 within 5%", worst[2] < 0.05)
    check("smooth p99 within 10%", worst[3] < 0.10)
    # bursty saturated sim: queue delay is strongly bimodal (burst crests vs
    # idle troughs) — the documented hard case where P2 degrades and
    # exact_percentiles is the right knob. Pin the degraded band too.
    cfg8 = Cfg(LLAMA8B, SN40L_X16, 16, 1)
    spec = TraceSpec(11, 4000, Bursty(2.0, 16.0, 30.0),
                     LengthDist(1024.0, 0.4, 16, 8192),
                     LengthDist(128.0, 0.6, 2, 2048))
    r = simulate_new(cfg8, 1, iter(spec.generate()), (1.0, 0.02))
    ett = rel_errs(r["tt"])
    etp = rel_errs(r["tp"])
    eq = rel_errs(r["q"])
    print(f"       bursty-sim rel err: ttft {[round(x, 4) for x in ett]} "
          f"tpot {[round(x, 4) for x in etp]} queue {[round(x, 4) for x in eq]}")
    check("bursty ttft p95/p99 within 15%", max(ett[2], ett[3]) < 0.15)
    check("bursty tpot within 10%", max(etp[1:]) < 0.10)
    check("bursty queue (bimodal, worst case) within 40%", max(eq[1:]) < 0.40)
    # tiny-n path is exact
    sp = StreamingPcts()
    for x in (5.0, 1.0, 4.0, 2.0):
        sp.observe(x)
    check("n<=5 exact", sp.pcts() == exact_percentiles([5.0, 1.0, 4.0, 2.0]))
    z = StreamingPcts()
    check("empty all-zero", z.pcts() == (0.0, 0.0, 0.0, 0.0))


def check_doc_examples():
    print("[4] rustdoc example constants")
    p2 = P2Quantile(0.5)
    for i in range(1, 1002):
        p2.observe(float(i))
    check(f"P2 median of 1..=1001 = {p2.estimate():.2f} (|err| < 20)",
          abs(p2.estimate() - 501.0) < 20.0)
    sp = StreamingPcts()
    for i in range(1, 101):
        sp.observe(float(i))
    m = sp.pcts()
    check(f"StreamingPcts 1..=100 mean {m[0]} p50 {m[1]:.1f}",
          abs(m[0] - 50.5) < 1e-9 and abs(m[1] - 50.0) < 5.0)


def check_fleet_parity():
    print("[5] fleet mode parity + O(1) arena peak")
    cfg8 = Cfg(LLAMA8B, SN40L_X16, 16, 1)
    slo = (1.0, 0.02)
    one = simulate_new(cfg8, 1, iter(TraceSpec.poisson(3, 4.0, 400).generate()), slo)
    fleet = simulate_new(cfg8, 4, iter(TraceSpec.poisson(3, 16.0, 1600).generate()), slo)
    t1 = math.fsum(one["tp"]) / len(one["tp"])
    t4 = math.fsum(fleet["tp"]) / len(fleet["tp"])
    # least-loaded dispatch de-randomizes per-replica arrivals, so per-step
    # batches are a bit smaller than true Poisson splitting: allow 25%
    check(f"mean TPOT 1rep@4rps {t1*1e3:.2f}ms vs 4rep@16rps {t4*1e3:.2f}ms",
          abs(t4 / t1 - 1.0) < 0.25)
    a1 = one["good"] / one["completed"]
    a4 = fleet["good"] / fleet["completed"]
    check(f"attainment {a1:.3f} vs {a4:.3f}", abs(a4 - a1) < 0.05)
    tput1 = one["completed"] / one["makespan"]
    tput4 = fleet["completed"] / fleet["makespan"]
    check(f"throughput scales ~4x ({tput1:.2f} -> {tput4:.2f} rps)",
          abs(tput4 / tput1 - 4.0) < 0.4)
    # arena peak is O(in-flight): grows with load, not with request count
    small = simulate_new(cfg8, 4, iter(TraceSpec.poisson(9, 32.0, 2000).generate()), slo)
    big = simulate_new(cfg8, 4, iter(TraceSpec.poisson(9, 32.0, 20000).generate()), slo)
    check(f"peak_in_flight {small['peak_in_flight']} (2k) vs "
          f"{big['peak_in_flight']} (20k): request-count independent",
          big["peak_in_flight"] < 4 * small["peak_in_flight"] + 64)
    print(f"       (CI smoke sizing: fleet-8 @64rps peak_in_flight ~ "
          f"{big['peak_in_flight'] * 2})")


def check_analytical_anchor():
    print("[6] new engine reproduces analytical TPOT at batch 1")
    cfg8 = Cfg(LLAMA8B, SN40L_X16, 16, 1)
    reqs = [(i, 1000.0 * (i + 1), 1024, 64) for i in range(4)]
    r = simulate_new(cfg8, 1, iter(reqs), (10.0, 1.0))
    mean_tpot = math.fsum(r["tp"]) / len(r["tp"])
    mid = evaluate(LLAMA8B, SN40L_X16, 16, 1, 1.0, 1.0, 1024.0 + 32.0)[1]
    check(f"sim {mean_tpot*1e3:.3f}ms vs analytical {mid*1e3:.3f}ms",
          abs(mean_tpot / mid - 1.0) < 0.10)


if __name__ == "__main__":
    check_calendar_vs_heap()
    check_old_vs_new()
    check_p2_tolerance()
    check_doc_examples()
    check_fleet_parity()
    check_analytical_anchor()
    print(f"\n{'ALL CHECKS PASSED' if not FAIL else 'FAILURES: ' + ', '.join(FAIL)}")
    raise SystemExit(1 if FAIL else 0)
