//! Offline stub of the `xla` (xla-rs) API surface used by
//! `dfmodel::runtime::pjrt` (DESIGN.md §Substitutions).
//!
//! The real crate links libxla/PJRT, which cannot be built in the offline
//! tier-1 environment. This stub has the same types and signatures so
//! `cargo build --features pjrt` still type-checks the PJRT-backed path;
//! every entry point fails at *runtime* with a clear message. To execute on
//! PJRT for real, point the `xla` path dependency in `rust/Cargo.toml` at
//! the actual crate (and reconcile any upstream API drift there).

use std::fmt;

/// Error for every stub entry point (and the real crate's error slot).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err() -> Error {
    Error(
        "xla stub: built without a real PJRT runtime; point the `xla` path \
         dependency at the real crate (see DESIGN.md §Substitutions)"
            .to_string(),
    )
}

/// Element types `Literal::to_vec` can extract.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// A host-side tensor literal.
#[derive(Debug, Clone)]
pub struct Literal {
    data_f32: Vec<f32>,
    dims: Vec<usize>,
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal { data_f32: values.to_vec(), dims: vec![values.len()] }
    }

    /// Reinterpret with the given dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data_f32.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data_f32.len()
            )));
        }
        Ok(Literal {
            data_f32: self.data_f32.clone(),
            dims: dims.iter().map(|&d| d as usize).collect(),
        })
    }

    /// Logical dimensions of this literal.
    pub fn dims(&self) -> Result<Vec<usize>, Error> {
        Ok(self.dims.clone())
    }

    /// Total payload size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.data_f32.len() * 4
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(stub_err())
    }

    /// Extract the flattened payload.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(stub_err())
    }
}

/// Parsed HLO module (the text interchange format of `make artifacts`).
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(stub_err())
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device-resident buffer produced by execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(stub_err())
    }
}

/// A compiled, loaded executable.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute on the given argument literals; outer Vec is per-device.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(stub_err())
    }
}

/// A PJRT client bound to one platform.
#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Connect to the host CPU platform.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(stub_err())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(stub_err())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_plumbing_works_without_pjrt() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims().unwrap(), vec![2, 3]);
        assert_eq!(r.size_bytes(), 24);
        assert!(lit.reshape(&[7]).is_err());
    }

    #[test]
    fn runtime_entry_points_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }
}
