//! Calculon-like analytical model of LLM training (Isaev et al. [39]).
//!
//! Kernel-by-kernel (non-dataflow) execution: every kernel round-trips its
//! operands through DRAM (Fig. 2D), per-kernel time is the roofline max of
//! compute and memory, TP emits Megatron's two all-reduces per layer per
//! pass, PP adds the pipeline bubble, DP adds the gradient all-reduce.

use crate::graph::gpt::GptConfig;
use crate::system::SystemSpec;

/// Degrees + batch for one Calculon evaluation point.
#[derive(Debug, Clone, Copy)]
pub struct CalculonPoint {
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
    /// Global batch in sequences.
    pub global_batch: f64,
    /// Microbatch in sequences.
    pub microbatch: f64,
}

/// Per-iteration latency breakdown (the Fig. 8 stacked bars).
#[derive(Debug, Clone, Copy, Default)]
pub struct CalculonBreakdown {
    pub fwd: f64,
    pub bwd: f64,
    pub bubble: f64,
    pub tp_comm: f64,
    pub pp_comm: f64,
    pub dp_comm: f64,
}

impl CalculonBreakdown {
    pub fn total(&self) -> f64 {
        self.fwd + self.bwd + self.bubble + self.tp_comm + self.pp_comm + self.dp_comm
    }
}

/// Kernel-by-kernel achievable efficiency on a GEMM-heavy layer (matches
/// Calculon's default achievable-MFU-style derate).
pub const KBK_COMPUTE_EFF: f64 = 0.62;

/// One training iteration under the Calculon model. Returns None when the
/// per-chip training state exceeds DRAM capacity.
pub fn iteration(
    cfg: &GptConfig,
    sys: &SystemSpec,
    pt: &CalculonPoint,
) -> Option<CalculonBreakdown> {
    let (tp, pp, dp) = (pt.tp as f64, pt.pp as f64, pt.dp as f64);
    assert_eq!(pt.tp * pt.pp * pt.dp, sys.n_chips(), "degrees must use all chips");

    // memory capacity: weights + grads + optimizer state, sharded TP×PP
    let state_bytes = cfg.params() * cfg.dtype_bytes * 8.0 / (tp * pp);
    if state_bytes > sys.memory.capacity.raw() {
        return None;
    }

    let layers_per_stage = (cfg.layers as f64 / pp).ceil();
    let tokens_micro = pt.microbatch * cfg.seq;
    let h = cfg.d_model;

    // ---- per-layer forward: compute (roofline vs memory) ----
    let flops_layer = (24.0 * h * h + 4.0 * cfg.seq * h) * tokens_micro / tp;
    let t_comp = flops_layer / (sys.chip.compute_flops().raw() * KBK_COMPUTE_EFF);
    // kernel-by-kernel DRAM traffic: weights once + ~14 intermediate
    // tensors read+written (2x), scores tensor pair dominates at long seq
    let act = tokens_micro * h * cfg.dtype_bytes / tp;
    let scores = pt.microbatch * cfg.n_heads * cfg.seq * cfg.seq * cfg.dtype_bytes / tp;
    let weights_layer = 12.0 * h * h * cfg.dtype_bytes / tp;
    let dram_layer = weights_layer + 2.0 * (12.0 * act + 2.0 * scores + 2.0 * act * 4.0);
    let t_mem = dram_layer / sys.memory.bandwidth.raw();
    let t_layer_fwd = t_comp.max(t_mem);

    // ---- TP communication: 2 all-reduces per layer per pass ----
    // ring all-reduce over the TP group on the system's link tech
    let ar_bytes = tokens_micro * h * cfg.dtype_bytes;
    let t_ar = if pt.tp > 1 {
        2.0 * (tp - 1.0) / tp * ar_bytes / sys.link.bandwidth.raw()
    } else {
        0.0
    };
    let tp_comm_layer = 2.0 * t_ar;

    // ---- pipeline composition ----
    let micro_count = (pt.global_batch / (dp * pt.microbatch)).max(1.0);
    let stage_fwd = layers_per_stage * t_layer_fwd;
    let stage_tp = layers_per_stage * tp_comm_layer;
    let fwd = micro_count * stage_fwd;
    let bwd = 2.0 * fwd;
    let bubble = (pp - 1.0) * 3.0 * (stage_fwd + stage_tp);
    let tp_comm = micro_count * stage_tp * 3.0;

    // p2p activations between stages, fwd + bwd
    let pp_comm = if pt.pp > 1 {
        2.0 * micro_count * (act * tp) / sys.link.bandwidth.raw() / tp
    } else {
        0.0
    };

    // DP gradient all-reduce (exposed; Calculon reports it separately)
    let dp_comm = if pt.dp > 1 {
        let grad = cfg.params() * cfg.dtype_bytes / (tp * pp);
        2.0 * (dp - 1.0) / dp * grad / sys.link.bandwidth.raw()
    } else {
        0.0
    };

    Some(CalculonBreakdown { fwd, bwd, bubble, tp_comm, pp_comm, dp_comm })
}

/// Achieved system FLOP/s utilization for a Calculon point.
pub fn utilization(cfg: &GptConfig, sys: &SystemSpec, pt: &CalculonPoint) -> Option<f64> {
    let b = iteration(cfg, sys, pt)?;
    let tokens = pt.global_batch * cfg.seq;
    let useful = cfg.train_flops_per_token() * tokens;
    Some(useful / b.total() / sys.peak_flops().raw())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gpt::gpt3_1t;
    use crate::system::{chip, interconnect, memory, topology, SystemSpec};

    fn a100_cluster(n: usize) -> SystemSpec {
        let link = interconnect::nvlink4();
        SystemSpec::new(
            chip::a100(),
            memory::hbm3(),
            link.clone(),
            topology::dgx1(n / 8, &link),
        )
    }

    fn pt(tp: usize, pp: usize, dp: usize) -> CalculonPoint {
        CalculonPoint { tp, pp, dp, global_batch: 2048.0, microbatch: 1.0 }
    }

    #[test]
    fn more_pp_means_more_bubble() {
        let cfg = gpt3_1t();
        let sys = a100_cluster(1024);
        let b1 = iteration(&cfg, &sys, &pt(8, 32, 4)).unwrap();
        let b2 = iteration(&cfg, &sys, &pt(8, 64, 2)).unwrap();
        assert!(b2.bubble > b1.bubble);
    }

    #[test]
    fn tp_comm_grows_with_tp() {
        let cfg = gpt3_1t();
        let sys = a100_cluster(1024);
        let b1 = iteration(&cfg, &sys, &pt(8, 32, 4)).unwrap();
        let b2 = iteration(&cfg, &sys, &pt(32, 32, 1)).unwrap();
        assert!(b2.tp_comm > b1.tp_comm);
    }

    #[test]
    fn capacity_gate() {
        let cfg = gpt3_1t();
        let mut sys = a100_cluster(1024);
        sys.memory.capacity = crate::util::units::Bytes::new(1e9);
        assert!(iteration(&cfg, &sys, &pt(8, 32, 4)).is_none());
    }

    #[test]
    fn utilization_in_plausible_mfu_band() {
        let cfg = gpt3_1t();
        let sys = a100_cluster(1024);
        let u = utilization(&cfg, &sys, &pt(8, 32, 4)).unwrap();
        assert!(u > 0.1 && u < 0.62, "utilization {u}");
    }

    #[test]
    fn bwd_is_twice_fwd() {
        let cfg = gpt3_1t();
        let sys = a100_cluster(1024);
        let b = iteration(&cfg, &sys, &pt(8, 32, 4)).unwrap();
        assert!((b.bwd / b.fwd - 2.0).abs() < 1e-9);
    }
}
