//! Reimplementations of the performance models DFModel is validated
//! against (§VI-A, Figs 6–8): Calculon [39] (kernel-by-kernel LLM training
//! co-design model) and Rail-Only [79] (reduced-connectivity network
//! model). Both are *independent* analytical models — they share only the
//! workload configs with the DFModel path, so the Fig. 7/8 error-margin
//! comparisons are meaningful.

pub mod calculon;
pub mod railonly;
