//! Rail-Only network model (Wang et al. [79]): GPUs are grouped into
//! high-bandwidth (NVLink) domains of size `hb`; across domains only
//! rail links connect GPUs of equal rank. The claim reproduced in Fig. 7:
//! shrinking the HB domain barely hurts LLM training because TP stays
//! inside the domain and DP/PP traffic rides the rails.

use crate::graph::gpt::GptConfig;
use crate::system::{LinkTech, SystemSpec};

#[derive(Debug, Clone, Copy)]
pub struct RailOnlyPoint {
    /// High-bandwidth domain size (GPUs under one NVLink switch).
    pub hb_domain: usize,
    pub global_batch: f64,
    pub microbatch: f64,
}

/// The degrees Rail-Only assigns for a given HB-domain size: TP fills the
/// domain, PP capped at 16 stages, DP takes the rest. Exposed so the Fig. 7
/// comparison can force DFModel onto identical degrees.
pub fn degrees(cfg: &GptConfig, n_chips: usize, hb_domain: usize) -> (usize, usize, usize) {
    let n = n_chips as f64;
    let tp = hb_domain as f64;
    let pp = (cfg.layers as f64).min((n / tp).max(1.0)).min(16.0);
    let dp = (n / (tp * pp)).max(1.0);
    (tp as usize, pp as usize, dp as usize)
}

/// Training-iteration time under the Rail-Only model. TP = min(hb, 8·k)
/// stays in-domain; PP/DP degrees fill the remaining chips; cross-domain
/// collectives use the rail bandwidth.
pub fn iteration_time(
    cfg: &GptConfig,
    sys: &SystemSpec,
    rail: &LinkTech,
    pt: &RailOnlyPoint,
) -> Option<f64> {
    let (tpi, ppi, dpi) = degrees(cfg, sys.n_chips(), pt.hb_domain);
    let (tp, pp, dp) = (tpi as f64, ppi as f64, dpi as f64);
    // same training-state capacity gate as the other models
    if cfg.params() * cfg.dtype_bytes * 8.0 / (tp * pp) > sys.memory.capacity.raw() {
        return None;
    }

    let tokens_micro = pt.microbatch * cfg.seq;
    let h = cfg.d_model;
    let flops_layer = (24.0 * h * h + 4.0 * cfg.seq * h) * tokens_micro / tp;
    let t_layer = flops_layer / (sys.chip.compute_flops().raw() * super::calculon::KBK_COMPUTE_EFF);

    // TP all-reduces on the in-domain (NVLink) bandwidth
    let ar_bytes = tokens_micro * h * cfg.dtype_bytes;
    let t_ar_layer =
        if tp > 1.0 { 4.0 * (tp - 1.0) / tp * ar_bytes / sys.link.bandwidth.raw() } else { 0.0 };

    let layers_per_stage = (cfg.layers as f64 / pp).ceil();
    let micro_count = (pt.global_batch / (dp * pt.microbatch)).max(1.0);
    let stage = layers_per_stage * (t_layer + t_ar_layer);
    let fwd_bwd = 3.0 * micro_count * stage;
    let bubble = 3.0 * (pp - 1.0) * stage;

    // PP p2p + DP gradient all-reduce ride the rails (cross-domain links)
    let act = tokens_micro * h * cfg.dtype_bytes / tp;
    let pp_comm = if pp > 1.0 { 2.0 * micro_count * act / rail.bandwidth.raw() } else { 0.0 };
    let dp_comm = if dp > 1.0 {
        let grad = cfg.params() * cfg.dtype_bytes / (tp * pp);
        2.0 * (dp - 1.0) / dp * grad / rail.bandwidth.raw()
    } else {
        0.0
    };

    Some(fwd_bwd + bubble + pp_comm + dp_comm)
}

/// Utilization under the Rail-Only model.
pub fn utilization(
    cfg: &GptConfig,
    sys: &SystemSpec,
    rail: &LinkTech,
    pt: &RailOnlyPoint,
) -> Option<f64> {
    let t = iteration_time(cfg, sys, rail, pt)?;
    let useful = cfg.train_flops_per_token() * pt.global_batch * cfg.seq;
    Some(useful / t / sys.peak_flops().raw())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gpt::gpt3_1t;
    use crate::system::{chip, interconnect, memory, topology, SystemSpec};

    fn h100_cluster() -> SystemSpec {
        let link = interconnect::nvlink4();
        SystemSpec::new(
            chip::h100(),
            memory::hbm3(),
            link.clone(),
            topology::dgx2(64, &link),
        )
    }

    #[test]
    fn shrinking_hb_domain_changes_perf_mildly() {
        // the Rail-Only headline: modest degradation as the HB domain
        // shrinks from 256 to 8
        let cfg = gpt3_1t();
        let sys = h100_cluster();
        let rail = interconnect::nvlink4();
        let base = RailOnlyPoint { hb_domain: 256, global_batch: 2048.0, microbatch: 1.0 };
        // hb = 8 is capacity-infeasible for the 1T model (125 GB state >
        // 96 GB HBM); 16 is the smallest feasible domain
        let small = RailOnlyPoint { hb_domain: 16, ..base };
        let u_big = utilization(&cfg, &sys, &rail, &base).unwrap();
        let u_small = utilization(&cfg, &sys, &rail, &small).unwrap();
        assert!(u_big > 0.0 && u_small > 0.0);
        let ratio = u_small / u_big;
        assert!(ratio > 0.5, "rail-only degradation too steep: {ratio}");
    }

    #[test]
    fn slower_rails_hurt() {
        let cfg = gpt3_1t();
        let sys = h100_cluster();
        let pt = RailOnlyPoint { hb_domain: 16, global_batch: 2048.0, microbatch: 1.0 };
        let fast = utilization(&cfg, &sys, &interconnect::nvlink4(), &pt).unwrap();
        let slow = utilization(&cfg, &sys, &interconnect::pcie4(), &pt).unwrap();
        assert!(fast >= slow);
    }
}
