//! The declarative input side of the facade: [`Scenario`] — one serializable
//! description of (workload, system, knobs) plus per-goal options — built
//! either from a JSON file or through the builder methods
//! (`Scenario::llm("gpt3-1t").on(...).calibrated_fabric()`), and
//! round-trippable through `util::json` so one scenario file drives the
//! CLI, the examples, the figures, and the tests.

use crate::collective::Collective;
use crate::explore::{ChipCfg, MemCfg, SearchSpace, WorkloadSpec};
use crate::fabric::{Algo, CalibrateOpts, Routing, SimConfig};
use crate::graph::gpt::{self, GptConfig};
use crate::graph::llama::{self, LlamaConfig};
use crate::graph::{dlrm, fft, hpl, moe, DataflowGraph};
use crate::interchip::InterChipOptions;
use crate::serving::ServingSystem;
use crate::system::{chip, interconnect, memory, topology};
use crate::system::{ChipSpec, LinkTech, MemoryTech, SystemSpec, Topology};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::{bail, err};

/// What to do with the scenario — mirrors the CLI subcommands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Goal {
    /// Map a training workload onto a system (optimize / DSE point).
    Map,
    /// Analytical serving point (§VIII-A).
    Serve,
    /// Request-level cluster serving simulation.
    Simulate,
    /// SLO-aware capacity planning over the platform catalog.
    Plan,
    /// Link-level collective simulation on one topology.
    Fabric,
    /// Pareto-frontier exploration of a parameterized design space.
    Explore,
}

impl Goal {
    pub fn name(self) -> &'static str {
        match self {
            Goal::Map => "map",
            Goal::Serve => "serve",
            Goal::Simulate => "simulate",
            Goal::Plan => "plan",
            Goal::Fabric => "fabric",
            Goal::Explore => "explore",
        }
    }

    pub fn parse(s: &str) -> Option<Goal> {
        match s {
            "map" | "optimize" | "dse" => Some(Goal::Map),
            "serve" => Some(Goal::Serve),
            "simulate" => Some(Goal::Simulate),
            "plan" => Some(Goal::Plan),
            "fabric" => Some(Goal::Fabric),
            "explore" => Some(Goal::Explore),
            _ => None,
        }
    }
}

/// The workload under study: a training workload (`Map` goal) or a Llama
/// serving model (`Serve`/`Simulate`/`Plan` goals).
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadCfg {
    /// GPT-family LLM training, model by name (`gpt3-175b|gpt3-1t|gpt-100t`).
    Gpt { model: String, batch: f64 },
    /// GPT training with an explicit architecture (`"model": "custom"`).
    GptCustom { cfg: GptConfig, batch: f64 },
    Dlrm { batch: f64 },
    Hpl,
    Fft,
    Moe { batch: f64 },
    /// Llama-3 serving model by name (`8b|70b|405b|68m`).
    Llama { model: String },
}

/// Resolve a GPT model name (the three paper configurations).
pub fn gpt_by_name(name: &str) -> Result<GptConfig> {
    Ok(match name {
        "gpt3-175b" => gpt::gpt3_175b(),
        "gpt3-1t" => gpt::gpt3_1t(),
        "gpt-100t" => gpt::gpt_100t(),
        other => bail!("unknown gpt model '{other}' (known: gpt3-175b gpt3-1t gpt-100t)"),
    })
}

/// Resolve a Llama model name (the §VIII serving family).
pub fn llama_by_name(name: &str) -> Result<LlamaConfig> {
    Ok(match name {
        "8b" => llama::llama3_8b(),
        "70b" => llama::llama3_70b(),
        "405b" => llama::llama3_405b(),
        "68m" => llama::llama_68m(),
        other => bail!("unknown llama model '{other}' (known: 8b 70b 405b 68m)"),
    })
}

/// A `Map`-goal workload resolved into the pipeline layer's input.
pub(crate) enum BuiltWorkload {
    Gpt { cfg: GptConfig, batch: f64 },
    Graph { graph: DataflowGraph, passes: f64, max_dp: usize },
}

impl WorkloadCfg {
    /// Short human description for reports.
    pub fn describe(&self) -> String {
        match self {
            WorkloadCfg::Gpt { model, batch } => format!("gpt {model} (batch {batch})"),
            WorkloadCfg::GptCustom { cfg, batch } => {
                format!("gpt custom[{}L,h={}] (batch {batch})", cfg.layers, cfg.d_model)
            }
            WorkloadCfg::Dlrm { batch } => format!("dlrm (batch {batch})"),
            WorkloadCfg::Hpl => "hpl".into(),
            WorkloadCfg::Fft => "fft".into(),
            WorkloadCfg::Moe { batch } => format!("moe (batch {batch})"),
            WorkloadCfg::Llama { model } => format!("llama {model} serving"),
        }
    }

    /// The DSE sweep axis this workload belongs to, if any.
    pub fn dse_kind(&self) -> Option<crate::dse::Workload> {
        match self {
            WorkloadCfg::Gpt { .. } | WorkloadCfg::GptCustom { .. } => {
                Some(crate::dse::Workload::Llm)
            }
            WorkloadCfg::Dlrm { .. } => Some(crate::dse::Workload::Dlrm),
            WorkloadCfg::Hpl => Some(crate::dse::Workload::Hpl),
            WorkloadCfg::Fft => Some(crate::dse::Workload::Fft),
            WorkloadCfg::Moe { .. } | WorkloadCfg::Llama { .. } => None,
        }
    }

    pub(crate) fn build(&self, knobs: &Knobs) -> Result<BuiltWorkload> {
        Ok(match self {
            WorkloadCfg::Gpt { model, batch } => {
                BuiltWorkload::Gpt { cfg: gpt_by_name(model)?, batch: *batch }
            }
            WorkloadCfg::GptCustom { cfg, batch } => {
                BuiltWorkload::Gpt { cfg: *cfg, batch: *batch }
            }
            WorkloadCfg::Dlrm { batch } => BuiltWorkload::Graph {
                graph: dlrm::dlrm_graph(&dlrm::dlrm_793b(), *batch),
                passes: 3.0,
                max_dp: knobs.max_dp.unwrap_or(64),
            },
            WorkloadCfg::Hpl => BuiltWorkload::Graph {
                graph: hpl::hpl_graph(&hpl::hpl_5m()),
                passes: 1.0,
                max_dp: knobs.max_dp.unwrap_or(1),
            },
            WorkloadCfg::Fft => BuiltWorkload::Graph {
                graph: fft::fft_graph(&fft::fft_1t()),
                passes: 1.0,
                max_dp: knobs.max_dp.unwrap_or(1),
            },
            WorkloadCfg::Moe { batch } => BuiltWorkload::Graph {
                graph: moe::moe_layer_graph(&moe::moe_gpt_1t(), *batch),
                passes: 3.0,
                max_dp: knobs.max_dp.unwrap_or(64),
            },
            WorkloadCfg::Llama { model } => {
                bail!("llama {model} is a serving workload; use goal serve/simulate/plan")
            }
        })
    }

    pub(crate) fn llama_config(&self) -> Result<LlamaConfig> {
        match self {
            WorkloadCfg::Llama { model } => llama_by_name(model),
            other => bail!("this goal needs a llama serving workload, got '{}'", other.describe()),
        }
    }

    /// The explorer's workload spec for this workload (`Explore` goal):
    /// the DSE axis plus the architecture/batch/state knobs it carries.
    /// Knobs the explorer cannot thread (calibrated collectives, forced or
    /// capped degrees) are rejected instead of silently ignored.
    pub(crate) fn explore_spec(&self, knobs: &Knobs) -> Result<WorkloadSpec> {
        use crate::dse::Workload;
        if knobs.collective != CollectiveCfg::Analytical {
            bail!(
                "explore always prices collectives analytically; drop the calibrated \
                 collective model from the scenario"
            );
        }
        if knobs.force_degrees.is_some() || knobs.max_pp.is_some() || knobs.max_dp.is_some() {
            bail!(
                "explore optimizes TP/PP/DP per candidate; forced/capped degrees \
                 (force_*, max_pp, max_dp) are not supported for the explore goal"
            );
        }
        let state = knobs.state_bytes_per_weight_byte;
        Ok(match self {
            WorkloadCfg::Gpt { model, batch } => WorkloadSpec {
                kind: Workload::Llm,
                gpt: Some(gpt_by_name(model)?),
                batch: Some(*batch),
                state_bytes_per_weight_byte: state,
            },
            WorkloadCfg::GptCustom { cfg, batch } => WorkloadSpec {
                kind: Workload::Llm,
                gpt: Some(*cfg),
                batch: Some(*batch),
                state_bytes_per_weight_byte: state,
            },
            WorkloadCfg::Dlrm { batch } => WorkloadSpec {
                kind: Workload::Dlrm,
                gpt: None,
                batch: Some(*batch),
                state_bytes_per_weight_byte: state,
            },
            WorkloadCfg::Hpl => WorkloadSpec {
                kind: Workload::Hpl,
                gpt: None,
                batch: None,
                state_bytes_per_weight_byte: state,
            },
            WorkloadCfg::Fft => WorkloadSpec {
                kind: Workload::Fft,
                gpt: None,
                batch: None,
                state_bytes_per_weight_byte: state,
            },
            WorkloadCfg::Moe { .. } | WorkloadCfg::Llama { .. } => bail!(
                "workload '{}' has no design-space axis; explore needs gpt/dlrm/hpl/fft",
                self.describe()
            ),
        })
    }

    /// Name-level validation for the `Map` goal — the cheap twin of
    /// [`WorkloadCfg::build`] that does not materialize any graph.
    pub(crate) fn check_for_map(&self) -> Result<()> {
        match self {
            WorkloadCfg::Gpt { model, .. } => gpt_by_name(model).map(|_| ()),
            WorkloadCfg::Llama { model } => {
                bail!("llama {model} is a serving workload; use goal serve/simulate/plan")
            }
            _ => Ok(()),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            WorkloadCfg::Gpt { model, batch } => Json::obj(vec![
                ("kind", Json::from("gpt")),
                ("model", Json::from(model.as_str())),
                ("batch", Json::from(*batch)),
            ]),
            WorkloadCfg::GptCustom { cfg, batch } => Json::obj(vec![
                ("kind", Json::from("gpt")),
                ("model", Json::from("custom")),
                ("layers", Json::from(cfg.layers)),
                ("d_model", Json::from(cfg.d_model)),
                ("n_heads", Json::from(cfg.n_heads)),
                ("seq", Json::from(cfg.seq)),
                ("d_ff", Json::from(cfg.d_ff)),
                ("vocab", Json::from(cfg.vocab)),
                ("dtype_bytes", Json::from(cfg.dtype_bytes)),
                ("batch", Json::from(*batch)),
            ]),
            WorkloadCfg::Dlrm { batch } => Json::obj(vec![
                ("kind", Json::from("dlrm")),
                ("batch", Json::from(*batch)),
            ]),
            WorkloadCfg::Hpl => Json::obj(vec![("kind", Json::from("hpl"))]),
            WorkloadCfg::Fft => Json::obj(vec![("kind", Json::from("fft"))]),
            WorkloadCfg::Moe { batch } => Json::obj(vec![
                ("kind", Json::from("moe")),
                ("batch", Json::from(*batch)),
            ]),
            WorkloadCfg::Llama { model } => Json::obj(vec![
                ("kind", Json::from("llama")),
                ("model", Json::from(model.as_str())),
            ]),
        }
    }
}

/// Topology description: explicit per-dim sizes, or a total chip count
/// balanced by `topology::by_name`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologyCfg {
    pub kind: String,
    /// Explicit per-dim sizes; empty when `chips` drives a balanced build.
    pub dims: Vec<usize>,
    /// Total chip count for balanced construction (`topology::by_name`).
    pub chips: Option<usize>,
}

impl TopologyCfg {
    pub fn build(&self, link: &LinkTech) -> Result<Topology> {
        if let Some(n) = self.chips {
            return topology::by_name(&self.kind, n, link).ok_or_else(|| {
                err!(
                    "no '{}' topology at {n} chips (families: ring torus2d torus3d dragonfly \
                     dgx1 dgx2; dgx1 needs chips%8==0, dgx2 chips%16==0)",
                    self.kind
                )
            });
        }
        Ok(match (self.kind.as_str(), self.dims.as_slice()) {
            ("ring", [n]) => topology::ring(*n, link),
            ("torus2d", [x, y]) => topology::torus2d(*x, *y, link),
            ("torus3d", [x, y, z]) => topology::torus3d(*x, *y, *z, link),
            ("dragonfly", [g, n]) => topology::dragonfly(*g, *n, link),
            ("dgx1", [n]) => topology::dgx1(*n, link),
            ("dgx2", [n]) => topology::dgx2(*n, link),
            (k, d) => bail!("bad topology {k} with dims {d:?}"),
        })
    }
}

/// The system under study, by component name (resolved against the paper's
/// catalogs at evaluation time).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SystemCfg {
    pub chip: String,
    pub memory: String,
    pub link: String,
    pub topology: TopologyCfg,
}

impl Default for SystemCfg {
    fn default() -> Self {
        SystemCfg::new("sn10", "ddr4", "pcie4")
    }
}

impl SystemCfg {
    /// A system on an 8-chip ring (override with the topology builders).
    pub fn new(chip: &str, memory: &str, link: &str) -> SystemCfg {
        SystemCfg {
            chip: chip.into(),
            memory: memory.into(),
            link: link.into(),
            topology: TopologyCfg { kind: "ring".into(), dims: vec![8], chips: None },
        }
    }

    /// The §VIII-A serving platform: 16 SN40L on the RDU fabric.
    pub fn sn40l_x16() -> SystemCfg {
        SystemCfg::new("sn40l", "sn40l-hbm", "rdu").ring(16)
    }

    pub fn ring(mut self, n: usize) -> Self {
        self.topology = TopologyCfg { kind: "ring".into(), dims: vec![n], chips: None };
        self
    }

    pub fn torus2d(mut self, x: usize, y: usize) -> Self {
        self.topology = TopologyCfg { kind: "torus2d".into(), dims: vec![x, y], chips: None };
        self
    }

    pub fn torus3d(mut self, x: usize, y: usize, z: usize) -> Self {
        self.topology = TopologyCfg { kind: "torus3d".into(), dims: vec![x, y, z], chips: None };
        self
    }

    pub fn dragonfly(mut self, group: usize, n_groups: usize) -> Self {
        self.topology =
            TopologyCfg { kind: "dragonfly".into(), dims: vec![group, n_groups], chips: None };
        self
    }

    /// Balanced topology of a family at a total chip count.
    pub fn topo(mut self, kind: &str, chips: usize) -> Self {
        self.topology = TopologyCfg { kind: kind.into(), dims: Vec::new(), chips: Some(chips) };
        self
    }

    pub fn build(&self) -> Result<SystemSpec> {
        let link = link_by_name(&self.link)?;
        Ok(SystemSpec::new(
            chip_by_name(&self.chip)?,
            memory_by_name(&self.memory)?,
            link.clone(),
            self.topology.build(&link)?,
        ))
    }

    /// The serving view of this system: one replica spanning the topology's
    /// chips, decode streaming from this memory technology.
    pub fn build_serving(&self) -> Result<ServingSystem> {
        let mem = memory_by_name(&self.memory)?;
        let link = link_by_name(&self.link)?;
        let topo = self.topology.build(&link)?;
        Ok(ServingSystem {
            chip: chip_by_name(&self.chip)?,
            mem_bw: mem.bandwidth.raw(),
            mem_cap: mem.capacity.raw(),
            link,
            n_chips: topo.n_chips(),
        })
    }

    pub fn build_topology(&self) -> Result<(Topology, LinkTech)> {
        let link = link_by_name(&self.link)?;
        Ok((self.topology.build(&link)?, link))
    }

    pub fn to_json(&self) -> Json {
        let mut topo = vec![("kind", Json::from(self.topology.kind.as_str()))];
        if !self.topology.dims.is_empty() {
            topo.push(("dims", Json::arr(self.topology.dims.iter().map(|&d| Json::from(d)))));
        }
        if let Some(n) = self.topology.chips {
            topo.push(("chips", Json::from(n)));
        }
        Json::obj(vec![
            ("chip", Json::from(self.chip.as_str())),
            ("memory", Json::from(self.memory.as_str())),
            ("link", Json::from(self.link.as_str())),
            ("topology", Json::obj(topo)),
        ])
    }
}

/// Resolve an accelerator-chip name (Table V + the §VII/§VIII RDUs).
pub fn chip_by_name(name: &str) -> Result<ChipSpec> {
    Ok(match name {
        "h100" => chip::h100(),
        "a100" => chip::a100(),
        "tpuv4" => chip::tpu_v4(),
        "sn10" => chip::sn10(),
        "sn30" => chip::sn30(),
        "sn40l" => chip::sn40l(),
        "wse2" => chip::wse2(),
        other => bail!("unknown chip '{other}' (known: h100 a100 tpuv4 sn10 sn30 sn40l wse2)"),
    })
}

/// Resolve a memory-technology name.
pub fn memory_by_name(name: &str) -> Result<MemoryTech> {
    Ok(match name {
        "ddr4" => memory::ddr4(),
        "hbm3" => memory::hbm3(),
        "sn40l-hbm" => memory::sn40l_hbm(),
        "2d-ddr" => memory::mem2d_ddr(),
        "2.5d-hbm" => memory::mem25d_hbm(),
        "3d-stacked" => memory::mem3d_stacked(),
        other => bail!(
            "unknown memory '{other}' (known: ddr4 hbm3 sn40l-hbm 2d-ddr 2.5d-hbm 3d-stacked)"
        ),
    })
}

/// Resolve an interconnect-technology name.
pub fn link_by_name(name: &str) -> Result<LinkTech> {
    Ok(match name {
        "pcie4" => interconnect::pcie4(),
        "nvlink4" => interconnect::nvlink4(),
        "rdu" => interconnect::rdu_fabric(),
        other => bail!("unknown link '{other}' (known: pcie4 nvlink4 rdu)"),
    })
}

/// Resolve a collective name (`dfmodel fabric --coll ...` / fabric
/// scenarios).
pub fn collective_by_name(name: &str) -> Result<Collective> {
    Ok(match name {
        "allreduce" => Collective::AllReduce,
        "allgather" => Collective::AllGather,
        "reducescatter" => Collective::ReduceScatter,
        "alltoall" => Collective::AllToAll,
        "broadcast" => Collective::Broadcast,
        "p2p" => Collective::P2P,
        other => bail!(
            "unknown collective '{other}' (known: allreduce allgather reducescatter alltoall \
             broadcast p2p)"
        ),
    })
}

/// Which collective-cost model prices the mapping decisions.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum CollectiveCfg {
    /// Closed-form α-β formulas (§IV-B).
    #[default]
    Analytical,
    /// Fabric-simulation-calibrated costs (`fabric::select`).
    Calibrated { max_group: usize, seed: u64, routing: String },
}

impl CollectiveCfg {
    /// Calibration with the default guard (groups ≤ 64 chips, dim-ordered
    /// routing, seed 0).
    pub fn calibrated() -> CollectiveCfg {
        CollectiveCfg::Calibrated { max_group: 64, seed: 0, routing: "dimorder".into() }
    }
}

/// Mapping knobs threaded into the inter-chip optimizer.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Knobs {
    pub collective: CollectiveCfg,
    /// Restrict to one (tp, pp, dp) combination (§VII case studies).
    pub force_degrees: Option<(usize, usize, usize)>,
    /// DRAM bytes of training state per byte of bf16 weights.
    pub state_bytes_per_weight_byte: Option<f64>,
    pub max_pp: Option<usize>,
    pub max_dp: Option<usize>,
}

impl Knobs {
    /// The inter-chip options these knobs select (unset knobs keep the
    /// optimizer defaults, so a default `Scenario` matches the legacy
    /// free-function path bit for bit).
    pub fn interchip_options(&self) -> InterChipOptions {
        let mut o = InterChipOptions::default();
        if let Some(v) = self.state_bytes_per_weight_byte {
            o.state_bytes_per_weight_byte = v;
        }
        o.force_degrees = self.force_degrees;
        if let Some(v) = self.max_pp {
            o.max_pp = v;
        }
        if let Some(v) = self.max_dp {
            o.max_dp = v;
        }
        o
    }

    /// Calibration options when the calibrated collective model is chosen.
    pub fn calibrate_opts(&self) -> Result<Option<CalibrateOpts>> {
        match &self.collective {
            CollectiveCfg::Analytical => Ok(None),
            CollectiveCfg::Calibrated { max_group, seed, routing } => {
                let routing = Routing::parse(routing).ok_or_else(|| {
                    err!("unknown routing '{routing}' (known: dimorder adaptive)")
                })?;
                Ok(Some(CalibrateOpts {
                    max_group: *max_group,
                    sim: SimConfig { routing, seed: *seed, ..Default::default() },
                    ..Default::default()
                }))
            }
        }
    }

    pub fn options_json(&self) -> Json {
        let mut kv: Vec<(&str, Json)> = Vec::new();
        if let Some((tp, pp, dp)) = self.force_degrees {
            kv.push(("force_tp", Json::from(tp)));
            kv.push(("force_pp", Json::from(pp)));
            kv.push(("force_dp", Json::from(dp)));
        }
        if let Some(v) = self.state_bytes_per_weight_byte {
            kv.push(("state_bytes_per_weight_byte", Json::from(v)));
        }
        if let Some(v) = self.max_pp {
            kv.push(("max_pp", Json::from(v)));
        }
        if let Some(v) = self.max_dp {
            kv.push(("max_dp", Json::from(v)));
        }
        Json::obj(kv)
    }

    pub fn collective_json(&self) -> Json {
        match &self.collective {
            CollectiveCfg::Analytical => Json::obj(vec![("model", Json::from("analytical"))]),
            CollectiveCfg::Calibrated { max_group, seed, routing } => Json::obj(vec![
                ("model", Json::from("calibrated")),
                ("max_group", Json::from(*max_group)),
                ("seed", Json::from(*seed as usize)),
                ("routing", Json::from(routing.as_str())),
            ]),
        }
    }
}

/// One analytical serving point (§VIII-A).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingCfg {
    pub tp: usize,
    pub pp: usize,
    pub batch: f64,
    pub prompt: f64,
    /// Decode context length (tokens already in the KV cache).
    pub context: f64,
}

impl Default for ServingCfg {
    fn default() -> Self {
        ServingCfg { tp: 16, pp: 1, batch: 1.0, prompt: 1024.0, context: 1024.0 }
    }
}

/// Cluster simulation / capacity-planning options.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterCfg {
    /// Fleet size: identical replicas simulated in one process (JSON also
    /// accepts the alias `fleet`).
    pub replicas: usize,
    /// Iteration-level cap on concurrently running sequences.
    pub max_batch: usize,
    pub requests: usize,
    /// Retain every sample for exact percentiles (O(requests) memory)
    /// instead of the default streaming P² estimates.
    pub exact_percentiles: bool,
    pub seed: u64,
    /// Arrival process: `poisson` | `bursty`.
    pub arrivals: String,
    /// Offered load (requests/s) for `simulate`.
    pub rate: f64,
    /// Bursty-cycle period (s).
    pub period: f64,
    pub prompt_mean: f64,
    pub output_mean: f64,
    pub slo_ttft: f64,
    pub slo_tpot: f64,
    /// Planner target load (requests/s).
    pub qps: f64,
    /// Required fraction of completions meeting both SLOs.
    pub attainment: f64,
    /// Candidates kept in the plan report.
    pub top: usize,
}

impl Default for ClusterCfg {
    fn default() -> Self {
        ClusterCfg {
            replicas: 1,
            max_batch: 32,
            requests: 200,
            exact_percentiles: false,
            seed: 17,
            arrivals: "poisson".into(),
            rate: 4.0,
            period: 60.0,
            prompt_mean: 1024.0,
            output_mean: 128.0,
            slo_ttft: 1.0,
            slo_tpot: 0.02,
            qps: 2.0,
            attainment: 0.9,
            top: 12,
        }
    }
}

impl ClusterCfg {
    /// Validate the simulation traffic shape: a zero/negative/NaN rate or
    /// bursty period would panic or hang the trace generator.
    pub(crate) fn check_traffic(&self) -> Result<()> {
        if !(self.rate.is_finite() && self.rate > 0.0) {
            bail!("cluster rate must be a positive request rate, got {}", self.rate);
        }
        match self.arrivals.as_str() {
            "poisson" => {}
            "bursty" => {
                if !(self.period.is_finite() && self.period > 0.0) {
                    bail!("bursty period must be a positive duration, got {}", self.period);
                }
            }
            other => bail!("unknown arrival process '{other}' (known: poisson bursty)"),
        }
        Ok(())
    }

    /// Validate the planner target load (it seeds a Poisson trace).
    pub(crate) fn check_plan(&self) -> Result<()> {
        if !(self.qps.is_finite() && self.qps > 0.0) {
            bail!("plan qps must be a positive request rate, got {}", self.qps);
        }
        Ok(())
    }
}

/// One collective simulation on the scenario's topology.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricCfg {
    pub collective: String,
    /// Payload bytes per chip.
    pub bytes: f64,
    pub routing: String,
    pub seed: u64,
    /// Restrict to one algorithm family (`ring|hd|direct|hier`).
    pub algo: Option<String>,
}

impl Default for FabricCfg {
    fn default() -> Self {
        FabricCfg {
            collective: "allreduce".into(),
            bytes: 64e6,
            routing: "dimorder".into(),
            seed: 0,
            algo: None,
        }
    }
}

/// Search-space axes and driver knobs of the `Explore` goal. The workload
/// under exploration comes from the scenario's [`WorkloadCfg`]; the axes
/// here parameterize the *systems* (the default is the §VI-C 80-system
/// paper grid).
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreOptions {
    pub chips: Vec<ChipCfg>,
    pub mems: Vec<MemCfg>,
    pub links: Vec<String>,
    pub topologies: Vec<String>,
    pub chip_counts: Vec<usize>,
    /// Per-candidate batch overrides (`None` entries defer to the
    /// workload's batch).
    pub batches: Vec<Option<f64>>,
    /// Skip candidates whose roofline bound is already dominated.
    pub prune: bool,
    /// Stop evaluating after visiting this many candidates.
    pub budget: Option<usize>,
    /// Frontier rows kept in the report.
    pub top: usize,
}

impl Default for ExploreOptions {
    fn default() -> Self {
        let s = SearchSpace::paper_grid(crate::dse::Workload::Llm);
        ExploreOptions {
            chips: s.chips,
            mems: s.mems,
            links: s.links,
            topologies: s.topologies,
            chip_counts: s.chip_counts,
            batches: s.batches,
            prune: true,
            budget: None,
            top: 16,
        }
    }
}

impl ExploreOptions {
    /// The search space these axes describe for the scenario's workload.
    pub(crate) fn space(&self, workload: &WorkloadCfg, knobs: &Knobs) -> Result<SearchSpace> {
        Ok(SearchSpace {
            workload: workload.explore_spec(knobs)?,
            chips: self.chips.clone(),
            mems: self.mems.clone(),
            links: self.links.clone(),
            topologies: self.topologies.clone(),
            chip_counts: self.chip_counts.clone(),
            batches: self.batches.clone(),
        })
    }

    pub(crate) fn settings(&self) -> crate::explore::ExploreSettings {
        crate::explore::ExploreSettings {
            prune: self.prune,
            budget: self.budget,
            ..Default::default()
        }
    }

    /// Axis-level validation without evaluating anything.
    pub(crate) fn check(&self, workload: &WorkloadCfg, knobs: &Knobs) -> Result<()> {
        self.space(workload, knobs)?.candidates()?;
        if self.top == 0 {
            bail!("explore top must be >= 1");
        }
        Ok(())
    }
}

/// Observability options (the `"trace"` block in scenario JSON): when
/// enabled, `evaluate` arms a [`crate::obs`] span/metric capture around the
/// run and attaches it to the report (`Report.stats`, the span-tree render
/// footer, and `obs::chrome_trace` export). Off by default — the untraced
/// path costs one atomic flag check per probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceOptions {
    /// Capture spans and metrics during `evaluate`.
    pub enabled: bool,
}

/// Explain-layer options (the `"explain"` block in scenario JSON): when
/// enabled, `evaluate` arms the [`crate::explain`] collector around the run
/// and attaches roofline attribution, the optimizer decision audit, and
/// knob elasticities to the report (`Report.explain`). Off by default —
/// the unexplained path costs one atomic flag check per hook and produces
/// bit-identical reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExplainOptions {
    /// Build `Report.explain` during `evaluate`.
    pub enabled: bool,
    /// Rejected candidates kept per audited optimizer phase (and kernels
    /// shown per attribution render).
    pub top: usize,
    /// Run the finite-difference sensitivity sweep (several extra
    /// evaluations); disable for cheap attribution-only runs.
    pub sensitivity: bool,
}

impl Default for ExplainOptions {
    fn default() -> Self {
        ExplainOptions { enabled: false, top: 5, sensitivity: true }
    }
}

/// One declarative experiment: workload + system + knobs + per-goal
/// options. Build with the constructors below, or parse from JSON; run
/// with [`Scenario::evaluate`](crate::api::Scenario::evaluate).
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub goal: Goal,
    pub workload: WorkloadCfg,
    pub system: SystemCfg,
    pub knobs: Knobs,
    pub serving: ServingCfg,
    pub cluster: ClusterCfg,
    pub fabric: FabricCfg,
    pub explore: ExploreOptions,
    /// Run the [`crate::lint`] pre-flight in `evaluate` (default `true`);
    /// disable with [`Scenario::no_lint`] or `"lint": false` in JSON.
    pub lint: bool,
    /// Span/metric capture options; enable with [`Scenario::traced`] or
    /// `"trace": {"enabled": true}` in JSON (CLI: `--trace` / `--stats`).
    pub trace: TraceOptions,
    /// Explain-layer options; enable with [`Scenario::explained`] or
    /// `"explain": {"enabled": true}` in JSON (CLI: `dfmodel explain`).
    pub explain: ExplainOptions,
}

impl Scenario {
    fn base(goal: Goal, workload: WorkloadCfg) -> Scenario {
        Scenario {
            goal,
            workload,
            system: SystemCfg::default(),
            knobs: Knobs::default(),
            serving: ServingCfg::default(),
            cluster: ClusterCfg::default(),
            fabric: FabricCfg::default(),
            explore: ExploreOptions::default(),
            lint: true,
            trace: TraceOptions::default(),
            explain: ExplainOptions::default(),
        }
    }

    /// GPT-family training scenario (`gpt3-175b|gpt3-1t|gpt-100t`).
    pub fn llm(model: &str) -> Scenario {
        Scenario::base(Goal::Map, WorkloadCfg::Gpt { model: model.into(), batch: 64.0 })
    }

    /// GPT training with an explicit architecture.
    pub fn llm_custom(cfg: GptConfig) -> Scenario {
        Scenario::base(Goal::Map, WorkloadCfg::GptCustom { cfg, batch: 64.0 })
    }

    /// The 793B DLRM training iteration (§VI-C.2).
    pub fn dlrm() -> Scenario {
        Scenario::base(Goal::Map, WorkloadCfg::Dlrm { batch: 65_536.0 })
    }

    /// The 5M² HPL solve (§VI-C.3).
    pub fn hpl() -> Scenario {
        Scenario::base(Goal::Map, WorkloadCfg::Hpl)
    }

    /// The 1T-point FFT (§VI-C.4).
    pub fn fft() -> Scenario {
        Scenario::base(Goal::Map, WorkloadCfg::Fft)
    }

    /// One MoE layer (3 passes, like DLRM).
    pub fn moe() -> Scenario {
        Scenario::base(Goal::Map, WorkloadCfg::Moe { batch: 1.0 })
    }

    /// Llama serving scenario (`8b|70b|405b`) on the §VIII SN40L platform.
    pub fn llama(model: &str) -> Scenario {
        let mut s = Scenario::base(Goal::Serve, WorkloadCfg::Llama { model: model.into() });
        s.system = SystemCfg::sn40l_x16();
        s
    }

    /// Evaluate on this system instead of the default.
    pub fn on(mut self, system: SystemCfg) -> Scenario {
        self.system = system;
        self
    }

    /// Global batch (training) or serving batch (llama scenarios).
    /// HPL/FFT have fixed paper problem sizes, so batch is a no-op there.
    pub fn batch(mut self, batch: f64) -> Scenario {
        match &mut self.workload {
            WorkloadCfg::Gpt { batch: b, .. }
            | WorkloadCfg::GptCustom { batch: b, .. }
            | WorkloadCfg::Dlrm { batch: b }
            | WorkloadCfg::Moe { batch: b } => *b = batch,
            WorkloadCfg::Hpl | WorkloadCfg::Fft => {}
            WorkloadCfg::Llama { .. } => self.serving.batch = batch,
        }
        self
    }

    /// Skip the [`crate::lint`] pre-flight in `evaluate` (expert escape
    /// hatch for deliberately degenerate inputs).
    pub fn no_lint(mut self) -> Scenario {
        self.lint = false;
        self
    }

    /// Capture spans + metrics during `evaluate` and attach them to the
    /// report (`Report.stats`); see [`crate::obs`].
    pub fn traced(mut self) -> Scenario {
        self.trace.enabled = true;
        self
    }

    /// Attach the explain layer (attribution + optimizer audit +
    /// sensitivity) to the report (`Report.explain`); see [`crate::explain`].
    pub fn explained(mut self) -> Scenario {
        self.explain.enabled = true;
        self
    }

    /// Rejected candidates kept per audited phase (implies
    /// [`Scenario::explained`]).
    pub fn explain_top(mut self, top: usize) -> Scenario {
        self.explain.enabled = true;
        self.explain.top = top;
        self
    }

    /// Price collectives with the fabric simulator's calibration table.
    pub fn calibrated_fabric(mut self) -> Scenario {
        self.knobs.collective = CollectiveCfg::calibrated();
        self
    }

    /// Force the (TP, PP, DP) degrees (§VII case studies).
    pub fn forced(mut self, tp: usize, pp: usize, dp: usize) -> Scenario {
        self.knobs.force_degrees = Some((tp, pp, dp));
        self
    }

    /// Serving TP×PP split (must cover the system's chip group).
    pub fn serving_split(mut self, tp: usize, pp: usize) -> Scenario {
        self.serving.tp = tp;
        self.serving.pp = pp;
        self
    }

    /// Prompt length and decode context of the serving point.
    pub fn prompt_context(mut self, prompt: f64, context: f64) -> Scenario {
        self.serving.prompt = prompt;
        self.serving.context = context;
        self
    }

    /// Latency SLOs for goodput accounting and planning.
    pub fn slo(mut self, ttft: f64, tpot: f64) -> Scenario {
        self.cluster.slo_ttft = ttft;
        self.cluster.slo_tpot = tpot;
        self
    }

    /// Switch to the cluster simulation goal at an offered load.
    pub fn simulate_traffic(mut self, rate: f64, requests: usize) -> Scenario {
        self.goal = Goal::Simulate;
        self.cluster.rate = rate;
        self.cluster.requests = requests;
        self
    }

    /// Fleet size for the simulation goal: `n` identical replicas in one
    /// process, arrivals load-balanced to the least-loaded replica.
    pub fn fleet(mut self, n: usize) -> Scenario {
        self.cluster.replicas = n;
        self
    }

    /// Opt the simulation into exact percentiles (retains every latency
    /// sample; see `ClusterCfg::exact_percentiles`).
    pub fn exact_percentiles(mut self) -> Scenario {
        self.cluster.exact_percentiles = true;
        self
    }

    /// Switch to the capacity-planning goal at a target load.
    pub fn plan_for(mut self, qps: f64) -> Scenario {
        self.goal = Goal::Plan;
        self.cluster.qps = qps;
        self
    }

    /// Switch to the fabric-simulation goal for one collective sweep.
    pub fn fabric_sweep(mut self, collective: &str, bytes: f64) -> Scenario {
        self.goal = Goal::Fabric;
        self.fabric.collective = collective.into();
        self.fabric.bytes = bytes;
        self
    }

    /// Switch to the design-space-exploration goal: Pareto frontier of the
    /// given system axes for this scenario's workload.
    pub fn explore(mut self, opts: ExploreOptions) -> Scenario {
        self.goal = Goal::Explore;
        self.explore = opts;
        self
    }

    /// Validate every name and knob without running anything (and without
    /// materializing workload graphs). `parse` calls this;
    /// builder-constructed scenarios get the same errors from `evaluate`.
    pub fn check(&self) -> Result<()> {
        self.system.build()?;
        match self.goal {
            Goal::Map => {
                self.workload.check_for_map()?;
            }
            Goal::Serve | Goal::Simulate | Goal::Plan => {
                self.workload.llama_config()?;
                if self.goal == Goal::Simulate {
                    self.cluster.check_traffic()?;
                }
                if self.goal == Goal::Plan {
                    self.cluster.check_plan()?;
                }
            }
            Goal::Fabric => {
                collective_by_name(&self.fabric.collective)?;
                if Routing::parse(&self.fabric.routing).is_none() {
                    bail!("unknown routing '{}' (known: dimorder adaptive)", self.fabric.routing);
                }
                if let Some(a) = &self.fabric.algo {
                    if Algo::parse(a).is_none() {
                        bail!("unknown algo '{a}' (known: ring hd direct hier)");
                    }
                }
            }
            Goal::Explore => {
                self.explore.check(&self.workload, &self.knobs)?;
            }
        }
        let _ = self.knobs.calibrate_opts()?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("goal", Json::from(self.goal.name())),
            ("workload", self.workload.to_json()),
            ("system", self.system.to_json()),
            ("options", self.knobs.options_json()),
            ("collective", self.knobs.collective_json()),
            ("serving", serving_json(&self.serving)),
            ("cluster", cluster_json(&self.cluster)),
            ("fabric", fabric_json(&self.fabric)),
            ("explore", explore_json(&self.explore)),
        ];
        if !self.lint {
            kv.push(("lint", Json::Bool(false)));
        }
        if self.trace != TraceOptions::default() {
            kv.push(("trace", trace_json(&self.trace)));
        }
        if self.explain != ExplainOptions::default() {
            kv.push(("explain", explain_opts_json(&self.explain)));
        }
        Json::obj(kv)
    }

    pub fn parse(text: &str) -> Result<Scenario> {
        let j = Json::parse(text).map_err(|e| err!("scenario: {e}"))?;
        Scenario::from_json(&j)
    }

    pub fn load(path: &std::path::Path) -> Result<Scenario> {
        let text =
            std::fs::read_to_string(path).map_err(|e| err!("read {}: {e}", path.display()))?;
        Scenario::parse(&text)
    }

    pub fn from_json(j: &Json) -> Result<Scenario> {
        let s = Scenario::from_json_unchecked(j)?;
        s.check()?;
        Ok(s)
    }

    /// [`Scenario::from_json`] without the [`Scenario::check`] pass — the
    /// lint engine uses this so it can diagnose scenarios `check` rejects.
    pub fn from_json_unchecked(j: &Json) -> Result<Scenario> {
        let goal = match j.get("goal").and_then(|v| v.as_str()) {
            None => Goal::Map,
            Some(g) => Goal::parse(g).ok_or_else(|| {
                err!("unknown goal '{g}' (known: map serve simulate plan fabric)")
            })?,
        };
        let wj = j.get("workload").unwrap_or(&Json::Null);
        let workload = parse_workload(wj)?;
        let system = parse_system(j.get("system").unwrap_or(&Json::Null))?;
        let mut knobs = parse_options(j.get("options").unwrap_or(&Json::Null))?;
        knobs.collective = parse_collective_cfg(j.get("collective").unwrap_or(&Json::Null))?;
        // legacy schema: dlrm/moe configs may carry max_dp in the workload obj
        if knobs.max_dp.is_none() {
            knobs.max_dp = wj.get("max_dp").and_then(|v| v.as_usize());
        }
        let serving = parse_serving(j.get("serving").unwrap_or(&Json::Null));
        let cluster = parse_cluster(j.get("cluster").unwrap_or(&Json::Null));
        let fabric = parse_fabric(j.get("fabric").unwrap_or(&Json::Null));
        let explore = parse_explore(j.get("explore").unwrap_or(&Json::Null))?;
        let lint = j.get("lint").and_then(|v| v.as_bool()).unwrap_or(true);
        let trace = parse_trace(j.get("trace").unwrap_or(&Json::Null));
        let explain = parse_explain_opts(j.get("explain").unwrap_or(&Json::Null));
        Ok(Scenario {
            goal,
            workload,
            system,
            knobs,
            serving,
            cluster,
            fabric,
            explore,
            lint,
            trace,
            explain,
        })
    }
}

fn parse_trace(j: &Json) -> TraceOptions {
    let d = TraceOptions::default();
    TraceOptions { enabled: j.get("enabled").and_then(|v| v.as_bool()).unwrap_or(d.enabled) }
}

fn trace_json(t: &TraceOptions) -> Json {
    Json::obj(vec![("enabled", Json::Bool(t.enabled))])
}

fn parse_explain_opts(j: &Json) -> ExplainOptions {
    let d = ExplainOptions::default();
    ExplainOptions {
        enabled: j.get("enabled").and_then(|v| v.as_bool()).unwrap_or(d.enabled),
        top: j.get("top").and_then(|v| v.as_usize()).unwrap_or(d.top),
        sensitivity: j.get("sensitivity").and_then(|v| v.as_bool()).unwrap_or(d.sensitivity),
    }
}

fn explain_opts_json(e: &ExplainOptions) -> Json {
    Json::obj(vec![
        ("enabled", Json::Bool(e.enabled)),
        ("top", Json::from(e.top)),
        ("sensitivity", Json::Bool(e.sensitivity)),
    ])
}

fn parse_workload(j: &Json) -> Result<WorkloadCfg> {
    let kind = j.get("kind").and_then(|v| v.as_str()).unwrap_or("gpt");
    Ok(match kind {
        "gpt" => {
            let model = j.get("model").and_then(|v| v.as_str()).unwrap_or("gpt3-175b");
            let batch = j.get("batch").and_then(|v| v.as_f64()).unwrap_or(64.0);
            if model == "custom" {
                let cfg = GptConfig {
                    layers: j.get("layers").and_then(|v| v.as_usize()).unwrap_or(96),
                    d_model: j.get("d_model").and_then(|v| v.as_f64()).unwrap_or(12288.0),
                    n_heads: j.get("n_heads").and_then(|v| v.as_f64()).unwrap_or(96.0),
                    seq: j.get("seq").and_then(|v| v.as_f64()).unwrap_or(2048.0),
                    d_ff: j.get("d_ff").and_then(|v| v.as_f64()).unwrap_or(4.0 * 12288.0),
                    vocab: j.get("vocab").and_then(|v| v.as_f64()).unwrap_or(50257.0),
                    dtype_bytes: j.get("dtype_bytes").and_then(|v| v.as_f64()).unwrap_or(2.0),
                };
                WorkloadCfg::GptCustom { cfg, batch }
            } else {
                gpt_by_name(model)?;
                WorkloadCfg::Gpt { model: model.into(), batch }
            }
        }
        "dlrm" => {
            WorkloadCfg::Dlrm { batch: j.get("batch").and_then(|v| v.as_f64()).unwrap_or(65_536.0) }
        }
        "hpl" => WorkloadCfg::Hpl,
        "fft" => WorkloadCfg::Fft,
        "moe" => WorkloadCfg::Moe { batch: j.get("batch").and_then(|v| v.as_f64()).unwrap_or(1.0) },
        "llama" => {
            let model = j.get("model").and_then(|v| v.as_str()).unwrap_or("8b");
            llama_by_name(model)?;
            WorkloadCfg::Llama { model: model.into() }
        }
        other => bail!("unknown workload kind '{other}'"),
    })
}

fn parse_system(j: &Json) -> Result<SystemCfg> {
    let t = j.get("topology").unwrap_or(&Json::Null);
    let mut topology = TopologyCfg {
        kind: t.get("kind").and_then(|v| v.as_str()).unwrap_or("ring").to_string(),
        dims: t
            .get("dims")
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
            .unwrap_or_default(),
        chips: t.get("chips").and_then(|v| v.as_usize()),
    };
    if topology.dims.is_empty() && topology.chips.is_none() {
        topology.dims = vec![8];
    }
    Ok(SystemCfg {
        chip: j.get("chip").and_then(|v| v.as_str()).unwrap_or("sn10").to_string(),
        memory: j.get("memory").and_then(|v| v.as_str()).unwrap_or("ddr4").to_string(),
        link: j.get("link").and_then(|v| v.as_str()).unwrap_or("pcie4").to_string(),
        topology,
    })
}

fn parse_options(j: &Json) -> Result<Knobs> {
    let tp = j.get("force_tp").and_then(|v| v.as_usize());
    let pp = j.get("force_pp").and_then(|v| v.as_usize());
    let dp = j.get("force_dp").and_then(|v| v.as_usize());
    let force_degrees = if let (Some(tp), Some(pp), Some(dp)) = (tp, pp, dp) {
        Some((tp, pp, dp))
    } else if tp.is_some() || pp.is_some() || dp.is_some() {
        bail!("force_tp/force_pp/force_dp must be given together")
    } else {
        None
    };
    Ok(Knobs {
        collective: CollectiveCfg::Analytical,
        force_degrees,
        state_bytes_per_weight_byte: j
            .get("state_bytes_per_weight_byte")
            .and_then(|v| v.as_f64()),
        max_pp: j.get("max_pp").and_then(|v| v.as_usize()),
        max_dp: j.get("max_dp").and_then(|v| v.as_usize()),
    })
}

fn parse_collective_cfg(j: &Json) -> Result<CollectiveCfg> {
    match j.get("model").and_then(|v| v.as_str()) {
        None | Some("analytical") => Ok(CollectiveCfg::Analytical),
        Some("calibrated") => Ok(CollectiveCfg::Calibrated {
            max_group: j.get("max_group").and_then(|v| v.as_usize()).unwrap_or(64),
            seed: j.get("seed").and_then(|v| v.as_usize()).unwrap_or(0) as u64,
            routing: j.get("routing").and_then(|v| v.as_str()).unwrap_or("dimorder").to_string(),
        }),
        Some(other) => bail!("unknown collective model '{other}' (known: analytical calibrated)"),
    }
}

fn parse_serving(j: &Json) -> ServingCfg {
    let d = ServingCfg::default();
    ServingCfg {
        tp: j.get("tp").and_then(|v| v.as_usize()).unwrap_or(d.tp),
        pp: j.get("pp").and_then(|v| v.as_usize()).unwrap_or(d.pp),
        batch: j.get("batch").and_then(|v| v.as_f64()).unwrap_or(d.batch),
        prompt: j.get("prompt").and_then(|v| v.as_f64()).unwrap_or(d.prompt),
        context: j.get("context").and_then(|v| v.as_f64()).unwrap_or(d.context),
    }
}

fn serving_json(s: &ServingCfg) -> Json {
    Json::obj(vec![
        ("tp", Json::from(s.tp)),
        ("pp", Json::from(s.pp)),
        ("batch", Json::from(s.batch)),
        ("prompt", Json::from(s.prompt)),
        ("context", Json::from(s.context)),
    ])
}

fn parse_cluster(j: &Json) -> ClusterCfg {
    let d = ClusterCfg::default();
    ClusterCfg {
        // `fleet` is the preferred alias for replica count; `replicas`
        // stays accepted (and is what cluster_json emits) for back-compat
        replicas: j
            .get("fleet")
            .or_else(|| j.get("replicas"))
            .and_then(|v| v.as_usize())
            .unwrap_or(d.replicas),
        max_batch: j.get("max_batch").and_then(|v| v.as_usize()).unwrap_or(d.max_batch),
        requests: j.get("requests").and_then(|v| v.as_usize()).unwrap_or(d.requests),
        exact_percentiles: j
            .get("exact_percentiles")
            .and_then(|v| v.as_bool())
            .unwrap_or(d.exact_percentiles),
        seed: j.get("seed").and_then(|v| v.as_usize()).map(|v| v as u64).unwrap_or(d.seed),
        arrivals: j.get("arrivals").and_then(|v| v.as_str()).unwrap_or(&d.arrivals).to_string(),
        rate: j.get("rate").and_then(|v| v.as_f64()).unwrap_or(d.rate),
        period: j.get("period").and_then(|v| v.as_f64()).unwrap_or(d.period),
        prompt_mean: j.get("prompt_mean").and_then(|v| v.as_f64()).unwrap_or(d.prompt_mean),
        output_mean: j.get("output_mean").and_then(|v| v.as_f64()).unwrap_or(d.output_mean),
        slo_ttft: j.get("slo_ttft").and_then(|v| v.as_f64()).unwrap_or(d.slo_ttft),
        slo_tpot: j.get("slo_tpot").and_then(|v| v.as_f64()).unwrap_or(d.slo_tpot),
        qps: j.get("qps").and_then(|v| v.as_f64()).unwrap_or(d.qps),
        attainment: j.get("attainment").and_then(|v| v.as_f64()).unwrap_or(d.attainment),
        top: j.get("top").and_then(|v| v.as_usize()).unwrap_or(d.top),
    }
}

fn cluster_json(c: &ClusterCfg) -> Json {
    Json::obj(vec![
        ("replicas", Json::from(c.replicas)),
        ("max_batch", Json::from(c.max_batch)),
        ("requests", Json::from(c.requests)),
        ("exact_percentiles", Json::from(c.exact_percentiles)),
        ("seed", Json::from(c.seed as usize)),
        ("arrivals", Json::from(c.arrivals.as_str())),
        ("rate", Json::from(c.rate)),
        ("period", Json::from(c.period)),
        ("prompt_mean", Json::from(c.prompt_mean)),
        ("output_mean", Json::from(c.output_mean)),
        ("slo_ttft", Json::from(c.slo_ttft)),
        ("slo_tpot", Json::from(c.slo_tpot)),
        ("qps", Json::from(c.qps)),
        ("attainment", Json::from(c.attainment)),
        ("top", Json::from(c.top)),
    ])
}

fn parse_fabric(j: &Json) -> FabricCfg {
    let d = FabricCfg::default();
    FabricCfg {
        collective: collective_name(j, &d),
        bytes: j.get("bytes").and_then(|v| v.as_f64()).unwrap_or(d.bytes),
        routing: j.get("routing").and_then(|v| v.as_str()).unwrap_or(&d.routing).to_string(),
        seed: j.get("seed").and_then(|v| v.as_usize()).map(|v| v as u64).unwrap_or(d.seed),
        algo: j.get("algo").and_then(|v| v.as_str()).map(|s| s.to_string()),
    }
}

fn collective_name(j: &Json, d: &FabricCfg) -> String {
    j.get("collective").and_then(|v| v.as_str()).unwrap_or(&d.collective).to_string()
}

fn fabric_json(f: &FabricCfg) -> Json {
    let mut kv = vec![
        ("collective", Json::from(f.collective.as_str())),
        ("bytes", Json::from(f.bytes)),
        ("routing", Json::from(f.routing.as_str())),
        ("seed", Json::from(f.seed as usize)),
    ];
    if let Some(a) = &f.algo {
        kv.push(("algo", Json::from(a.as_str())));
    }
    Json::obj(kv)
}

fn explore_json(e: &ExploreOptions) -> Json {
    let mut kv = vec![
        ("chips", Json::arr(e.chips.iter().map(ChipCfg::to_json))),
        ("mems", Json::arr(e.mems.iter().map(MemCfg::to_json))),
        ("links", Json::arr(e.links.iter().map(|l| Json::from(l.as_str())))),
        ("topologies", Json::arr(e.topologies.iter().map(|t| Json::from(t.as_str())))),
        ("chip_counts", Json::arr(e.chip_counts.iter().map(|&c| Json::from(c)))),
        (
            "batches",
            Json::arr(e.batches.iter().map(|b| match b {
                Some(v) => Json::from(*v),
                None => Json::Null,
            })),
        ),
        ("prune", Json::from(e.prune)),
        ("top", Json::from(e.top)),
    ];
    if let Some(b) = e.budget {
        kv.push(("budget", Json::from(b)));
    }
    Json::obj(kv)
}

fn parse_explore(j: &Json) -> Result<ExploreOptions> {
    let d = ExploreOptions::default();
    if matches!(j, Json::Null) {
        return Ok(d);
    }
    let str_list = |key: &str, dft: Vec<String>| -> Result<Vec<String>> {
        match j.get(key).and_then(|v| v.as_array()) {
            Some(a) => a
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(|s| s.to_string())
                        .ok_or_else(|| err!("explore {key} entries must be strings, got {s}"))
                })
                .collect(),
            None => Ok(dft),
        }
    };
    let chips = match j.get("chips").and_then(|v| v.as_array()) {
        Some(a) => a.iter().map(ChipCfg::from_json).collect::<Result<Vec<_>>>()?,
        None => d.chips,
    };
    let mems = match j.get("mems").and_then(|v| v.as_array()) {
        Some(a) => a.iter().map(MemCfg::from_json).collect::<Result<Vec<_>>>()?,
        None => d.mems,
    };
    let chip_counts = match j.get("chip_counts").and_then(|v| v.as_array()) {
        Some(a) => a
            .iter()
            .map(|c| {
                c.as_f64()
                    .filter(|v| v.fract() == 0.0 && *v >= 0.0)
                    .map(|v| v as usize)
                    .ok_or_else(|| err!("explore chip_counts entries must be chip counts, got {c}"))
            })
            .collect::<Result<Vec<_>>>()?,
        None => d.chip_counts,
    };
    let batches = match j.get("batches").and_then(|v| v.as_array()) {
        Some(a) => a
            .iter()
            .map(|b| match b {
                Json::Null => Ok(None),
                Json::Num(v) => Ok(Some(*v)),
                other => bail!("explore batches entries must be numbers or null, got {other}"),
            })
            .collect::<Result<Vec<_>>>()?,
        None => d.batches,
    };
    let prune = match j.get("prune") {
        None => d.prune,
        Some(v) => v.as_bool().ok_or_else(|| err!("explore prune must be a boolean, got {v}"))?,
    };
    let budget = match j.get("budget") {
        None => None,
        Some(v) => Some(
            v.as_f64()
                .filter(|b| b.fract() == 0.0 && *b >= 0.0)
                .map(|b| b as usize)
                .ok_or_else(|| err!("explore budget must be a candidate count, got {v}"))?,
        ),
    };
    let top = match j.get("top") {
        None => d.top,
        Some(v) => v
            .as_f64()
            .filter(|t| t.fract() == 0.0 && *t >= 0.0)
            .map(|t| t as usize)
            .ok_or_else(|| err!("explore top must be a row count, got {v}"))?,
    };
    Ok(ExploreOptions {
        chips,
        mems,
        links: str_list("links", d.links)?,
        topologies: str_list("topologies", d.topologies)?,
        chip_counts,
        batches,
        prune,
        budget,
        top,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_match_legacy_config_defaults() {
        let s = Scenario::llm("gpt3-175b");
        assert_eq!(s.goal, Goal::Map);
        assert_eq!(s.system, SystemCfg::default());
        assert_eq!(s.system.build().unwrap().n_chips(), 8);
        assert_eq!(s.knobs.interchip_options().state_bytes_per_weight_byte, 8.0);
    }

    #[test]
    fn serde_roundtrips_every_goal() {
        let scenarios = [
            Scenario::llm("gpt3-1t")
                .batch(2048.0)
                .on(SystemCfg::new("h100", "hbm3", "nvlink4").torus2d(32, 32)),
            Scenario::dlrm().calibrated_fabric(),
            Scenario::hpl().forced(4, 1, 2),
            Scenario::llama("8b").serving_split(4, 4).prompt_context(2048.0, 512.0),
            Scenario::llama("70b").plan_for(2.0).slo(2.0, 0.05),
            Scenario::llama("8b").simulate_traffic(8.0, 100),
            Scenario::llama("8b").simulate_traffic(64.0, 100_000).fleet(8),
            Scenario::llama("8b").simulate_traffic(4.0, 200).exact_percentiles(),
            Scenario::llm("gpt3-175b").on(SystemCfg::default()).fabric_sweep("alltoall", 16e6),
            Scenario::llm("gpt3-175b").traced(),
            Scenario::llama("8b").traced().no_lint(),
            Scenario::hpl().explore(ExploreOptions {
                chip_counts: vec![64, 256],
                batches: vec![None, Some(128.0)],
                budget: Some(40),
                ..Default::default()
            }),
        ];
        for s in scenarios {
            let text = s.to_json().pretty();
            let back = Scenario::parse(&text).expect("roundtrip parse");
            assert_eq!(s, back, "scenario changed across serde:\n{text}");
        }
    }

    #[test]
    fn fleet_alias_sets_replica_count() {
        let s = Scenario::llama("8b").simulate_traffic(8.0, 100).fleet(6);
        let mut text = s.to_json().pretty();
        assert!(text.contains("\"replicas\""), "canonical key is still replicas");
        text = text.replace("\"replicas\"", "\"fleet\"");
        let back = Scenario::parse(&text).expect("fleet alias parses");
        assert_eq!(back.cluster.replicas, 6);
    }

    #[test]
    fn custom_gpt_roundtrips() {
        let cfg = GptConfig {
            layers: 4,
            d_model: 1024.0,
            n_heads: 8.0,
            seq: 512.0,
            d_ff: 4096.0,
            vocab: 1000.0,
            dtype_bytes: 2.0,
        };
        let s = Scenario::llm_custom(cfg).batch(8.0);
        let back = Scenario::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn parse_rejects_bad_names() {
        assert!(Scenario::parse(r#"{"system": {"chip": "zz80"}}"#).is_err());
        assert!(Scenario::parse(r#"{"workload": {"kind": "prolog"}}"#).is_err());
        assert!(Scenario::parse(r#"{"workload": {"kind": "gpt", "model": "gpt5"}}"#).is_err());
        assert!(Scenario::parse(r#"{"goal": "teleport"}"#).is_err());
        assert!(Scenario::parse(r#"{"options": {"force_tp": 8}}"#).is_err());
        assert!(Scenario::parse(r#"{"goal": "explore", "explore": {"chips": ["z80"]}}"#).is_err());
        assert!(
            Scenario::parse(r#"{"goal": "explore", "explore": {"batches": ["4096"]}}"#).is_err(),
            "a stringly batch must not silently become the default"
        );
        assert!(
            Scenario::parse(r#"{"goal": "explore", "explore": {"chip_counts": [64.5]}}"#)
                .is_err()
        );
        assert!(
            Scenario::parse(r#"{"goal": "explore", "explore": {"budget": "40"}}"#).is_err(),
            "a stringly budget must not silently disable the cap"
        );
        assert!(
            Scenario::parse(r#"{"goal": "explore", "options": {"force_tp": 2, "force_pp": 2, "force_dp": 2}}"#)
                .is_err(),
            "forced degrees are rejected for the explore goal"
        );
        assert!(
            Scenario::parse(r#"{"goal": "explore", "explore": {"topologies": ["moebius"]}}"#)
                .is_err()
        );
        assert!(
            Scenario::parse(r#"{"goal": "explore", "workload": {"kind": "llama"}}"#).is_err(),
            "serving workloads have no explore axis"
        );
        assert!(Scenario::parse("not json").is_err());
        let e = Scenario::parse(r#"{"collective": {"model": "psychic"}}"#).unwrap_err();
        assert!(e.to_string().contains("psychic"), "{e}");
    }

    #[test]
    fn legacy_experiment_schema_still_parses() {
        let s = Scenario::parse(
            r#"{
              "workload": {"kind": "gpt", "model": "gpt3-175b", "batch": 64},
              "system": {"chip": "sn10", "memory": "ddr4", "link": "pcie4",
                         "topology": {"kind": "ring", "dims": [8]}},
              "options": {"force_tp": 8, "force_pp": 1, "force_dp": 1}
            }"#,
        )
        .unwrap();
        assert_eq!(s.goal, Goal::Map);
        assert_eq!(s.knobs.force_degrees, Some((8, 1, 1)));
        assert_eq!(s.system.build().unwrap().n_chips(), 8);
    }

    #[test]
    fn balanced_topology_by_chip_count() {
        let s = SystemCfg::new("h100", "hbm3", "nvlink4").topo("torus2d", 16);
        let sys = s.build().unwrap();
        assert_eq!(sys.n_chips(), 16);
        let back =
            Scenario::parse(&Scenario::llm("gpt3-175b").on(s.clone()).to_json().to_string())
                .unwrap();
        assert_eq!(back.system, s);
    }

    #[test]
    fn serving_system_has_sn40l_memory() {
        let sys = SystemCfg::sn40l_x16().build_serving().unwrap();
        assert_eq!(sys.n_chips, 16);
        assert!(sys.mem_bw > 1e12, "SN40L HBM-class bandwidth expected");
        assert!(sys.mem_cap > 1e9);
    }
}
