//! The output side of the facade: [`Mapping`] (what the optimizer chose)
//! and [`Report`] (what it achieves), with stable accessors, a JSON
//! emitter (`--json` on every CLI subcommand), and a human rendering.

use std::fmt::Write as _;

use crate::cluster::engine::Pcts;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::units::{fmt_bw, fmt_time};

use super::scenario::Goal;

/// The mapping decisions behind a report: parallelization degrees, the
/// per-kernel shard schemes, pipeline stages, and fused on-chip partitions.
#[derive(Debug, Clone, PartialEq)]
pub struct Mapping {
    pub tp: usize,
    pub pp: usize,
    /// Data-parallel degree (replica count for serving goals).
    pub dp: usize,
    /// Pipeline stages of the inter-chip pass.
    pub n_stages: usize,
    /// Fused partitions of the intra-chip pass (0 for serving goals).
    pub n_partitions: usize,
    /// (kernel, scheme) pairs of the chosen sharding (empty for serving).
    pub schemes: Vec<(String, String)>,
    /// Whether collective costs came from the fabric calibration.
    pub calibrated: bool,
}

impl Mapping {
    pub fn degrees(&self) -> (usize, usize, usize) {
        (self.tp, self.pp, self.dp)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tp", Json::from(self.tp)),
            ("pp", Json::from(self.pp)),
            ("dp", Json::from(self.dp)),
            ("n_stages", Json::from(self.n_stages)),
            ("n_partitions", Json::from(self.n_partitions)),
            ("calibrated", Json::from(self.calibrated)),
            (
                "schemes",
                Json::Obj(
                    self.schemes.iter().map(|(k, v)| (k.clone(), Json::from(v.as_str()))).collect(),
                ),
            ),
        ])
    }
}

/// Throughput/cost/power outcome of a `Map` scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfReport {
    /// Wall-clock of one training iteration / one solve (seconds).
    pub step_time: f64,
    /// Achieved / peak throughput of the whole system.
    pub utilization: f64,
    pub achieved_flops: f64,
    /// Achieved GFLOP/s per dollar.
    pub cost_eff: f64,
    /// Achieved GFLOP/s per watt.
    pub power_eff: f64,
    /// (compute, memory, network) fractional latency breakdown.
    pub breakdown: (f64, f64, f64),
}

impl PerfReport {
    pub fn to_json(&self) -> Json {
        let (c, m, n) = self.breakdown;
        Json::obj(vec![
            ("step_time_s", Json::from(self.step_time)),
            ("utilization", Json::from(self.utilization)),
            ("achieved_flops", Json::from(self.achieved_flops)),
            ("cost_eff_gflops_per_usd", Json::from(self.cost_eff)),
            ("power_eff_gflops_per_w", Json::from(self.power_eff)),
            (
                "breakdown",
                Json::obj(vec![
                    ("compute", Json::from(c)),
                    ("memory", Json::from(m)),
                    ("network", Json::from(n)),
                ]),
            ),
        ])
    }
}

/// Analytical serving metrics of a `Serve` scenario (§VIII-A).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingReport {
    pub ttft: f64,
    pub prefill_tps: f64,
    pub tpot: f64,
    pub decode_tps: f64,
    pub prefill_breakdown: (f64, f64, f64),
    pub decode_breakdown: (f64, f64, f64),
}

fn breakdown_json(b: (f64, f64, f64)) -> Json {
    Json::obj(vec![
        ("compute", Json::from(b.0)),
        ("memory", Json::from(b.1)),
        ("network", Json::from(b.2)),
    ])
}

impl ServingReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ttft_s", Json::from(self.ttft)),
            ("prefill_tps", Json::from(self.prefill_tps)),
            ("tpot_s", Json::from(self.tpot)),
            ("decode_tps", Json::from(self.decode_tps)),
            ("prefill_breakdown", breakdown_json(self.prefill_breakdown)),
            ("decode_breakdown", breakdown_json(self.decode_breakdown)),
        ])
    }
}

fn pcts_json(p: &Pcts) -> Json {
    Json::obj(vec![
        ("mean", Json::from(p.mean)),
        ("p50", Json::from(p.p50)),
        ("p95", Json::from(p.p95)),
        ("p99", Json::from(p.p99)),
    ])
}

/// Aggregate outcome of a `Simulate` scenario (cluster engine).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterReport {
    pub offered: usize,
    pub completed: usize,
    pub rejected: usize,
    pub makespan: f64,
    pub throughput_rps: f64,
    pub goodput_rps: f64,
    pub slo_attainment: f64,
    pub output_tokens_per_s: f64,
    pub kv_peak_frac: f64,
    pub events: u64,
    pub steps: u64,
    /// High-water mark of simultaneously in-flight requests (engine memory
    /// footprint in request-state units, independent of trace length).
    pub peak_in_flight: usize,
    /// Whether the percentile blocks are exact or P² streaming estimates.
    pub exact_percentiles: bool,
    pub queue: Pcts,
    pub ttft: Pcts,
    pub tpot: Pcts,
}

impl ClusterReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered", Json::from(self.offered)),
            ("completed", Json::from(self.completed)),
            ("rejected", Json::from(self.rejected)),
            ("makespan_s", Json::from(self.makespan)),
            ("throughput_rps", Json::from(self.throughput_rps)),
            ("goodput_rps", Json::from(self.goodput_rps)),
            ("slo_attainment", Json::from(self.slo_attainment)),
            ("output_tokens_per_s", Json::from(self.output_tokens_per_s)),
            ("kv_peak_frac", Json::from(self.kv_peak_frac)),
            ("events", Json::from(self.events as usize)),
            ("steps", Json::from(self.steps as usize)),
            ("peak_in_flight", Json::from(self.peak_in_flight)),
            ("exact_percentiles", Json::from(self.exact_percentiles)),
            ("queue", pcts_json(&self.queue)),
            ("ttft", pcts_json(&self.ttft)),
            ("tpot", pcts_json(&self.tpot)),
        ])
    }
}

/// One evaluated fleet of a `Plan` scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanCandidate {
    pub platform: String,
    pub group: usize,
    pub tp: usize,
    pub pp: usize,
    pub replicas: usize,
    pub chips_total: usize,
    pub usd_per_hour: f64,
    pub capex_usd: f64,
    pub slo_attainment: f64,
    pub ttft_p99: f64,
    pub tpot_p99: f64,
    pub meets_target: bool,
}

impl PlanCandidate {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("platform", Json::from(self.platform.as_str())),
            ("group", Json::from(self.group)),
            ("tp", Json::from(self.tp)),
            ("pp", Json::from(self.pp)),
            ("replicas", Json::from(self.replicas)),
            ("chips_total", Json::from(self.chips_total)),
            ("usd_per_hour", Json::from(self.usd_per_hour)),
            ("capex_usd", Json::from(self.capex_usd)),
            ("slo_attainment", Json::from(self.slo_attainment)),
            ("ttft_p99_s", Json::from(self.ttft_p99)),
            ("tpot_p99_s", Json::from(self.tpot_p99)),
            ("meets_target", Json::from(self.meets_target)),
        ])
    }
}

/// Outcome of a `Plan` scenario: the cheapest feasible fleet plus the top
/// of the ranking.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanReport {
    pub qps: f64,
    pub slo_ttft: f64,
    pub slo_tpot: f64,
    pub attainment: f64,
    /// Total candidates evaluated.
    pub candidates: usize,
    /// Cheapest fleet meeting the target, if any.
    pub best: Option<PlanCandidate>,
    /// Cheapest-first ranking (bounded by the scenario's `top`).
    pub top: Vec<PlanCandidate>,
}

impl PlanReport {
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("qps", Json::from(self.qps)),
            ("slo_ttft_s", Json::from(self.slo_ttft)),
            ("slo_tpot_s", Json::from(self.slo_tpot)),
            ("attainment", Json::from(self.attainment)),
            ("candidates", Json::from(self.candidates)),
            ("feasible", Json::from(self.best.is_some())),
        ];
        if let Some(b) = &self.best {
            kv.push(("best", b.to_json()));
        }
        kv.push(("top", Json::arr(self.top.iter().map(|c| c.to_json()))));
        Json::obj(kv)
    }
}

/// One algorithm's simulated outcome in a `Fabric` scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricAlgoEval {
    pub algo: String,
    pub time: f64,
    /// `time / analytical - 1`.
    pub vs_analytical: f64,
    pub max_link_util: f64,
    pub msgs: usize,
    pub packets: u64,
}

/// Outcome of a `Fabric` scenario: every algorithm family raced against
/// the analytical α-β model on one topology.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricReport {
    pub topology: String,
    pub chips: usize,
    pub nodes: usize,
    pub links: usize,
    pub bisection_bytes_per_s: f64,
    pub collective: String,
    pub bytes: f64,
    pub routing: String,
    pub analytical: f64,
    /// Fastest algorithm family name.
    pub best: String,
    /// Fastest-first evaluations.
    pub evals: Vec<FabricAlgoEval>,
}

impl FabricReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("topology", Json::from(self.topology.as_str())),
            ("chips", Json::from(self.chips)),
            ("nodes", Json::from(self.nodes)),
            ("links", Json::from(self.links)),
            ("bisection_bytes_per_s", Json::from(self.bisection_bytes_per_s)),
            ("collective", Json::from(self.collective.as_str())),
            ("bytes", Json::from(self.bytes)),
            ("routing", Json::from(self.routing.as_str())),
            ("analytical_s", Json::from(self.analytical)),
            ("best", Json::from(self.best.as_str())),
            (
                "evals",
                Json::arr(self.evals.iter().map(|e| {
                    Json::obj(vec![
                        ("algo", Json::from(e.algo.as_str())),
                        ("time_s", Json::from(e.time)),
                        ("vs_analytical", Json::from(e.vs_analytical)),
                        ("max_link_util", Json::from(e.max_link_util)),
                        ("msgs", Json::from(e.msgs)),
                        ("packets", Json::from(e.packets as usize)),
                    ])
                })),
            ),
        ])
    }
}

/// One design point of an `Explore` scenario's Pareto frontier.
#[derive(Debug, Clone, PartialEq)]
pub struct ExplorePoint {
    pub chip: String,
    pub topo: String,
    pub mem: String,
    pub link: String,
    /// Effective batch override (None = the workload's default).
    pub batch: Option<f64>,
    pub dataflow: bool,
    pub utilization: f64,
    pub cost_eff: f64,
    pub power_eff: f64,
}

impl ExplorePoint {
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("chip", Json::from(self.chip.as_str())),
            ("topo", Json::from(self.topo.as_str())),
            ("mem", Json::from(self.mem.as_str())),
            ("link", Json::from(self.link.as_str())),
        ];
        if let Some(b) = self.batch {
            kv.push(("batch", Json::from(b)));
        }
        kv.push(("dataflow", Json::from(self.dataflow)));
        kv.push(("utilization", Json::from(self.utilization)));
        kv.push(("cost_eff", Json::from(self.cost_eff)));
        kv.push(("power_eff", Json::from(self.power_eff)));
        Json::obj(kv)
    }
}

/// Outcome of an `Explore` scenario: coverage counters plus the exact
/// Pareto frontier over (utilization, cost efficiency, power efficiency).
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreReport {
    /// Enumerated candidates of the search space.
    pub candidates: usize,
    /// Unique optimizer evaluations performed.
    pub evaluated: usize,
    /// Candidates answered by the memoized cache.
    pub cache_hits: usize,
    /// Candidates skipped by the dominated-bound rule.
    pub pruned: usize,
    /// Candidates skipped by the evaluation budget.
    pub skipped_budget: usize,
    /// Visited candidates with no feasible mapping.
    pub infeasible: usize,
    /// Feasible points not on the frontier.
    pub dominated: usize,
    /// Full frontier size (the `frontier` rows are bounded by `top`).
    pub frontier_size: usize,
    /// Utilization-sorted frontier rows.
    pub frontier: Vec<ExplorePoint>,
    /// Dataflow / non-dataflow ratios of the per-objective maxima —
    /// (utilization, cost-eff, power-eff), the §VI-C headline claims.
    /// Conservative under pruning: pruned candidates contribute their
    /// upper bounds to the non-dataflow side.
    pub ratios: Option<(f64, f64, f64)>,
    /// Per-axis-value coverage: how each chip / memory / link / topology
    /// value split across evaluated, cache-hit, pruned, and budget-skipped
    /// candidates (deterministic order — see [`crate::explore::AxisStat`]).
    pub axes: Vec<crate::explore::AxisStat>,
}

impl ExploreReport {
    /// Condense an explorer outcome, keeping the top frontier rows.
    pub fn from_outcome(out: &crate::explore::ExploreOutcome, top: usize) -> ExploreReport {
        let mut idx = out.frontier.clone();
        idx.sort_by(|&a, &b| {
            let (pa, pb) = (&out.points[a], &out.points[b]);
            pb.utilization
                .total_cmp(&pa.utilization)
                .then(pb.cost_eff.total_cmp(&pa.cost_eff))
                .then(pa.chip.cmp(&pb.chip))
        });
        let frontier = idx
            .iter()
            .take(top)
            .map(|&i| {
                let p = &out.points[i];
                ExplorePoint {
                    chip: p.chip.clone(),
                    topo: p.topo.clone(),
                    mem: p.mem.clone(),
                    link: p.link.clone(),
                    batch: out.point_batches[i],
                    dataflow: p.dataflow,
                    utilization: p.utilization,
                    cost_eff: p.cost_eff,
                    power_eff: p.power_eff,
                }
            })
            .collect();
        ExploreReport {
            candidates: out.candidates,
            evaluated: out.evaluated,
            cache_hits: out.cache_hits,
            pruned: out.pruned,
            skipped_budget: out.skipped_budget,
            infeasible: out.infeasible,
            dominated: out.dominated(),
            frontier_size: out.frontier.len(),
            frontier,
            ratios: out.frontier_ratios().map(|r| (r[0], r[1], r[2])),
            axes: out.axes.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("candidates", Json::from(self.candidates)),
            ("evaluated", Json::from(self.evaluated)),
            ("cache_hits", Json::from(self.cache_hits)),
            ("pruned", Json::from(self.pruned)),
            ("skipped_budget", Json::from(self.skipped_budget)),
            ("infeasible", Json::from(self.infeasible)),
            ("dominated", Json::from(self.dominated)),
            ("frontier_size", Json::from(self.frontier_size)),
            ("frontier", Json::arr(self.frontier.iter().map(ExplorePoint::to_json))),
        ];
        if !self.axes.is_empty() {
            kv.push(("axes", Json::arr(self.axes.iter().map(crate::explore::AxisStat::to_json))));
        }
        if let Some((u, c, p)) = self.ratios {
            kv.push((
                "ratios",
                Json::obj(vec![
                    ("utilization", Json::from(u)),
                    ("cost_eff", Json::from(c)),
                    ("power_eff", Json::from(p)),
                ]),
            ));
        }
        Json::obj(kv)
    }

    /// Human rendering — the CLI report section and the `"explore"` figure
    /// share this single formatter.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "explore  : {} candidates | {} evaluated | {} cache hits | {} pruned | {} \
             budget-skipped",
            self.candidates, self.evaluated, self.cache_hits, self.pruned, self.skipped_budget
        );
        let _ = writeln!(
            s,
            "frontier : {} point(s) | {} dominated | {} infeasible",
            self.frontier_size, self.dominated, self.infeasible
        );
        for a in &self.axes {
            let _ = writeln!(
                s,
                "  axis {:<4} {:<14} : {} evaluated | {} cache hits | {} pruned | {} \
                 budget-skipped",
                a.axis, a.value, a.evaluated, a.cache_hits, a.pruned, a.skipped_budget
            );
        }
        s.push_str(&self.frontier_table().render());
        if let Some((u, c, p)) = self.ratios {
            let _ = writeln!(
                s,
                "dataflow vs non-dataflow maxima: {u:.2}x util | {c:.2}x GFLOP/s/$ | {p:.2}x \
                 GFLOP/s/W (paper: 1.52x / 1.59x / 1.6x)"
            );
        }
        s
    }

    /// The frontier rows as a table (also the `"explore"` figure's CSV).
    pub fn frontier_table(&self) -> Table {
        let mut t = Table::new(
            "Pareto frontier — utilization | GFLOP/s/$ | GFLOP/s/W",
            &["chip", "topo", "mem", "link", "batch", "exec", "util", "cost_eff", "power_eff"],
        );
        for p in &self.frontier {
            t.row(&[
                p.chip.clone(),
                p.topo.clone(),
                p.mem.clone(),
                p.link.clone(),
                match p.batch {
                    Some(b) => format!("{b:.0}"),
                    None => "default".into(),
                },
                if p.dataflow { "dataflow".into() } else { "kernel".into() },
                format!("{:.3}", p.utilization),
                format!("{:.3}", p.cost_eff),
                format!("{:.3}", p.power_eff),
            ]);
        }
        t
    }
}

/// What a [`Scenario`](crate::api::Scenario) achieved: the chosen
/// [`Mapping`] plus one section per goal. Sections absent for other goals
/// are `None`; the accessors below are the stable query surface.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    pub goal: Goal,
    pub workload: String,
    pub system: String,
    pub mapping: Option<Mapping>,
    pub perf: Option<PerfReport>,
    pub serving: Option<ServingReport>,
    pub cluster: Option<ClusterReport>,
    pub plan: Option<PlanReport>,
    pub fabric: Option<FabricReport>,
    pub explore: Option<ExploreReport>,
    /// Explain-layer section (attribution + decision audit + sensitivity)
    /// — `Some` only when the scenario was evaluated with
    /// [`Scenario::explained`](crate::api::Scenario::explained) (CLI:
    /// `dfmodel explain`). `None` otherwise, so unexplained reports are
    /// bit-identical to pre-explain ones.
    pub explain: Option<crate::explain::ExplainReport>,
    /// Pre-flight lint diagnostics (warnings only — errors abort
    /// `evaluate` before a report exists). Empty when linting is off.
    pub lint: crate::lint::LintReport,
    /// Instrumentation capture (span tree + metrics) — `Some` only when
    /// the scenario was evaluated with tracing on
    /// ([`Scenario::traced`](crate::api::Scenario::traced) or the CLI's
    /// `--trace`/`--stats`). `None` otherwise, so untraced reports are
    /// bit-identical to pre-instrumentation ones.
    pub stats: Option<crate::obs::Capture>,
}

impl Report {
    /// The chosen (TP, PP, DP) degrees, when a mapping was made.
    pub fn degrees(&self) -> Option<(usize, usize, usize)> {
        self.mapping.as_ref().map(Mapping::degrees)
    }

    /// Training-throughput utilization (`Map` goal).
    pub fn utilization(&self) -> Option<f64> {
        self.perf.as_ref().map(|p| p.utilization)
    }

    /// Iteration/solve wall-clock (`Map` goal).
    pub fn step_time(&self) -> Option<f64> {
        self.perf.as_ref().map(|p| p.step_time)
    }

    /// The cheapest feasible fleet (`Plan` goal).
    pub fn feasible_plan(&self) -> Option<&PlanCandidate> {
        self.plan.as_ref().and_then(|p| p.best.as_ref())
    }

    /// The Pareto-frontier rows (`Explore` goal), best utilization first.
    pub fn frontier(&self) -> Option<&[ExplorePoint]> {
        self.explore.as_ref().map(|e| e.frontier.as_slice())
    }

    /// Best frontier utilization (`Explore` goal).
    pub fn best_utilization(&self) -> Option<f64> {
        self.explore.as_ref().and_then(|e| e.frontier.first()).map(|p| p.utilization)
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("goal", Json::from(self.goal.name())),
            ("workload", Json::from(self.workload.as_str())),
            ("system", Json::from(self.system.as_str())),
        ];
        if let Some(m) = &self.mapping {
            kv.push(("mapping", m.to_json()));
        }
        if let Some(p) = &self.perf {
            kv.push(("perf", p.to_json()));
        }
        if let Some(s) = &self.serving {
            kv.push(("serving", s.to_json()));
        }
        if let Some(c) = &self.cluster {
            kv.push(("cluster", c.to_json()));
        }
        if let Some(p) = &self.plan {
            kv.push(("plan", p.to_json()));
        }
        if let Some(f) = &self.fabric {
            kv.push(("fabric", f.to_json()));
        }
        if let Some(e) = &self.explore {
            kv.push(("explore", e.to_json()));
        }
        if let Some(e) = &self.explain {
            kv.push(("explain", e.to_json()));
        }
        if !self.lint.is_clean() {
            kv.push(("lint", self.lint.to_json()));
        }
        if let Some(c) = &self.stats {
            kv.push(("stats", c.metrics_json()));
        }
        Json::obj(kv)
    }

    /// Human rendering (the CLI's default output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "workload: {}", self.workload);
        let _ = writeln!(s, "system  : {}", self.system);
        if let Some(m) = &self.mapping {
            let _ = writeln!(s, "degrees : TP={} PP={} DP={}", m.tp, m.pp, m.dp);
            if m.n_stages > 0 || m.n_partitions > 0 {
                let _ = writeln!(
                    s,
                    "mapping : {} pipeline stage(s) | {} fused partition(s) | collectives {}",
                    m.n_stages,
                    m.n_partitions,
                    if m.calibrated { "calibrated" } else { "analytical" }
                );
            }
        }
        if let Some(p) = &self.perf {
            let _ = writeln!(s, "step time: {}", fmt_time(p.step_time));
            let _ = writeln!(s, "utilization: {:.3}", p.utilization);
            let (c, m, n) = p.breakdown;
            let _ = writeln!(s, "breakdown: compute {c:.2} | memory {m:.2} | network {n:.2}");
            let _ = writeln!(
                s,
                "efficiency: {:.3} GFLOP/s/$ | {:.3} GFLOP/s/W",
                p.cost_eff, p.power_eff
            );
        }
        if let Some(v) = &self.serving {
            let _ = writeln!(s, "TTFT: {}", fmt_time(v.ttft));
            let _ = writeln!(s, "prefill: {:.0} tok/s", v.prefill_tps);
            let _ = writeln!(s, "TPOT: {}", fmt_time(v.tpot));
            let _ = writeln!(s, "decode: {:.0} tok/s", v.decode_tps);
        }
        if let Some(c) = &self.cluster {
            render_cluster(c, &mut s);
        }
        if let Some(p) = &self.plan {
            render_plan(p, &mut s);
        }
        if let Some(f) = &self.fabric {
            render_fabric(f, &mut s);
        }
        if let Some(e) = &self.explore {
            render_explore(e, &mut s);
        }
        if let Some(e) = &self.explain {
            s.push_str(&e.render(e.audit.as_ref().map_or(5, |a| a.top)));
        }
        // stable machine-parsed tail: lint warnings, then the span-tree /
        // metrics footer — nothing prints after the stats block
        for d in &self.lint.diags {
            let _ = writeln!(s, "{}", d.render());
        }
        if let Some(c) = &self.stats {
            s.push_str(&c.span_tree());
            s.push_str(&c.metrics_text());
        }
        s
    }
}

fn render_explore(e: &ExploreReport, s: &mut String) {
    s.push_str(&e.render());
}

fn render_cluster(c: &ClusterReport, s: &mut String) {
    let _ = writeln!(
        s,
        "requests : {} offered | {} completed | {} rejected | makespan {}",
        c.offered,
        c.completed,
        c.rejected,
        fmt_time(c.makespan)
    );
    let _ = writeln!(
        s,
        "rates    : {:.2} rps throughput | {:.2} rps goodput | {:.1}% in SLO | {:.0} tok/s out",
        c.throughput_rps,
        c.goodput_rps,
        c.slo_attainment * 100.0,
        c.output_tokens_per_s
    );
    let _ = writeln!(
        s,
        "engine   : {} events | {} steps | KV peak {:.1}% | {} in-flight peak{}",
        c.events,
        c.steps,
        c.kv_peak_frac * 100.0,
        c.peak_in_flight,
        if c.exact_percentiles { "" } else { " | P2 percentiles" }
    );
    for (name, p) in [("queue", &c.queue), ("TTFT", &c.ttft), ("TPOT", &c.tpot)] {
        let _ = writeln!(
            s,
            "{name:<9}: mean {} | p50 {} | p95 {} | p99 {}",
            fmt_time(p.mean),
            fmt_time(p.p50),
            fmt_time(p.p95),
            fmt_time(p.p99)
        );
    }
}

fn render_plan(p: &PlanReport, s: &mut String) {
    let mut t = Table::new(
        "Capacity plan — cheapest fleets first",
        &["fleet", "chips", "$/hr", "capex $", "SLO att.", "TTFT p99", "TPOT p99", "meets"],
    );
    for c in &p.top {
        let marker = if p.best.as_ref() == Some(c) { " <== plan" } else { "" };
        t.row(&[
            format!("{}x{} TP{}xPP{} r{}", c.platform, c.group, c.tp, c.pp, c.replicas),
            format!("{}", c.chips_total),
            format!("{:.2}", c.usd_per_hour),
            format!("{:.0}", c.capex_usd),
            format!("{:.1}%", c.slo_attainment * 100.0),
            fmt_time(c.ttft_p99),
            fmt_time(c.tpot_p99),
            format!("{}{}", if c.meets_target { "yes" } else { "no" }, marker),
        ]);
    }
    s.push_str(&t.render());
    match &p.best {
        Some(c) => {
            let _ = writeln!(
                s,
                "plan: {} x{} per replica, TP{}xPP{}, {} replica(s) = {} chips, ${:.2}/hr \
                 (capex ${:.0})",
                c.platform,
                c.group,
                c.tp,
                c.pp,
                c.replicas,
                c.chips_total,
                c.usd_per_hour,
                c.capex_usd
            );
        }
        None => {
            let _ = writeln!(
                s,
                "no fleet in the catalog meets {} rps at TTFT<={}s / TPOT<={}s ({}% attainment)",
                p.qps,
                p.slo_ttft,
                p.slo_tpot,
                p.attainment * 100.0
            );
        }
    }
}

fn render_fabric(f: &FabricReport, s: &mut String) {
    let _ = writeln!(
        s,
        "fabric : {} | {} chips | {} nodes | {} links | bisection {} | routing {}",
        f.topology,
        f.chips,
        f.nodes,
        f.links,
        fmt_bw(f.bisection_bytes_per_s),
        f.routing
    );
    let _ = writeln!(
        s,
        "collective: {} {:.2} MB/chip | analytical {}",
        f.collective,
        f.bytes / 1e6,
        fmt_time(f.analytical)
    );
    let _ = writeln!(
        s,
        "{:<8} {:>12} {:>10} {:>9} {:>8} {:>9}",
        "algo", "simulated", "vs-ana", "max-link", "msgs", "packets"
    );
    for e in &f.evals {
        let _ = writeln!(
            s,
            "{:<8} {:>12} {:>9.1}% {:>8.0}% {:>8} {:>9}",
            e.algo,
            fmt_time(e.time),
            e.vs_analytical * 100.0,
            e.max_link_util * 100.0,
            e.msgs,
            e.packets
        );
    }
    if let Some(b) = f.evals.first() {
        let _ = writeln!(
            s,
            "best: {} at {} ({:+.1}% vs analytical)",
            b.algo,
            fmt_time(b.time),
            b.vs_analytical * 100.0
        );
    }
}
