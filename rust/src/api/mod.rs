//! # The Scenario → Mapping → Report facade
//!
//! One typed, serializable entry point for everything DFModel can
//! co-optimize: build a [`Scenario`] (workload + system + knobs — via the
//! builder or a JSON file), call [`Scenario::evaluate`], and read the
//! resulting [`Report`] (with its [`Mapping`]) through stable accessors or
//! as JSON.
//!
//! ```text
//!   Scenario ──evaluate()──▶ internals (pub(crate))          ──▶ Report
//!   workload ─┐              interchip::optimize (§IV)            mapping (TP/PP/DP,
//!   system   ─┼─▶ build ───▶ intrachip::optimize_intra (§V)       schemes, stages,
//!   knobs    ─┘              fabric::calibrate_system             partitions)
//!   serving/cluster/fabric   serving::evaluate (§VIII-A)          perf | serving |
//!   options                  cluster::{engine, planner}           cluster | plan |
//!                            fabric::{sim, select}                fabric sections
//! ```
//!
//! The legacy free functions (`dse::evaluate_point*`,
//! `interchip::optimize`, `intrachip::optimize_intra`,
//! `fabric::calibrate_system`) are `pub(crate)` internals; external
//! callers go through this module — either the scenario path or the typed
//! wrappers ([`evaluate_design`], [`map_graph`], [`map_chip`],
//! [`calibrate`]) for single-pass studies.

pub mod report;
pub mod scenario;

pub use report::{
    ClusterReport, ExplorePoint, ExploreReport, FabricAlgoEval, FabricReport, Mapping, PerfReport,
    PlanCandidate, PlanReport, Report, ServingReport,
};
pub use scenario::{
    ClusterCfg, CollectiveCfg, ExplainOptions, ExploreOptions, FabricCfg, Goal, Knobs, Scenario,
    ServingCfg, SystemCfg, TopologyCfg, TraceOptions, WorkloadCfg,
};

use crate::dse::{DesignPoint, Workload};
use crate::fabric::CalibrateOpts;
use crate::graph::DataflowGraph;
use crate::interchip::{InterChipMapping, InterChipOptions};
use crate::intrachip::{IntraChipMapping, IntraChipOptions};
use crate::system::{ChipSpec, MemoryTech, SystemSpec};
use crate::util::error::Result;
use crate::{bail, err};

use scenario::BuiltWorkload;

/// Evaluate one DSE workload on one explicit system design point; `None`
/// when infeasible. The facade over the `pub(crate)`
/// `dse::evaluate_point`.
pub fn evaluate_design(w: Workload, sys: &SystemSpec) -> Option<DesignPoint> {
    crate::dse::evaluate_point(w, sys)
}

/// [`evaluate_design`] with the system's collective costs recalibrated by
/// the fabric simulator first.
pub fn evaluate_design_calibrated(
    w: Workload,
    sys: &SystemSpec,
    opts: &CalibrateOpts,
) -> Option<DesignPoint> {
    crate::dse::evaluate_point_calibrated(w, sys, opts)
}

/// The §IV inter-chip pass on an explicit graph: TP/PP/DP degrees,
/// per-kernel sharding, pipeline stages. `None` when no plan satisfies the
/// capacity constraints.
pub fn map_graph(
    g: &DataflowGraph,
    sys: &SystemSpec,
    opts: &InterChipOptions,
) -> Option<InterChipMapping> {
    crate::interchip::optimize(g, sys, opts)
}

/// The §V intra-chip pass on one chip's (already sharded) subgraph: kernel
/// fusion into sequential partitions under SRAM/DRAM constraints.
pub fn map_chip(
    g: &DataflowGraph,
    chip: &ChipSpec,
    memory: &MemoryTech,
    opts: &IntraChipOptions,
) -> Option<IntraChipMapping> {
    crate::intrachip::optimize_intra(g, chip, memory, opts)
}

/// The system with its collective model swapped for a fabric calibration
/// of its own topology.
pub fn calibrate(sys: &SystemSpec, opts: &CalibrateOpts) -> SystemSpec {
    crate::fabric::calibrate_system(sys, opts)
}

/// The §VI-C 80-system sweep for one workload (facade over `dse::sweep`).
pub fn sweep(w: Workload) -> Vec<DesignPoint> {
    crate::dse::sweep(w)
}

/// JSON rendering of DSE design points (`dfmodel dse --json`).
pub fn design_points_json(w: Workload, points: &[DesignPoint]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("workload", Json::from(w.name())),
        (
            "points",
            Json::arr(points.iter().map(|p| {
                Json::obj(vec![
                    ("chip", Json::from(p.chip.as_str())),
                    ("topo", Json::from(p.topo.as_str())),
                    ("mem", Json::from(p.mem.as_str())),
                    ("link", Json::from(p.link.as_str())),
                    ("dataflow", Json::from(p.dataflow)),
                    ("utilization", Json::from(p.utilization)),
                    ("cost_eff", Json::from(p.cost_eff)),
                    ("power_eff", Json::from(p.power_eff)),
                    ("achieved_flops", Json::from(p.achieved_flops)),
                    (
                        "breakdown",
                        Json::obj(vec![
                            ("compute", Json::from(p.breakdown.0)),
                            ("memory", Json::from(p.breakdown.1)),
                            ("network", Json::from(p.breakdown.2)),
                        ]),
                    ),
                ])
            })),
        ),
    ])
}

impl Scenario {
    /// Run the scenario end to end and return its [`Report`]. Errors carry
    /// the reason (bad name, infeasible split, capacity violation) instead
    /// of a bare `None`.
    pub fn evaluate(&self) -> Result<Report> {
        if !self.trace.enabled && !self.explain.enabled {
            return self.evaluate_inner();
        }
        if self.explain.enabled {
            match self.goal {
                Goal::Map | Goal::Serve | Goal::Explore => {}
                g => bail!("explain supports the map/serve/explore goals, not '{}'", g.name()),
            }
            if self.explain.top == 0 {
                bail!("explain top must be >= 1");
            }
        }
        // arm thread-scoped captures around the evaluation and attach them
        // to the report — everything else is bit-identical to the plain
        // path (instrumentation never feeds back into the math)
        let trace_session = self.trace.enabled.then(crate::obs::start_capture);
        let explain_session = self.explain.enabled.then(crate::explain::start);
        let mut out = self.evaluate_inner();
        // disarm both collectors before the sensitivity sweep inside
        // build_explain: its perturbed re-evaluations must not pollute
        // this run's capture
        let store = explain_session.map(crate::explain::finish);
        let capture = trace_session.map(crate::obs::finish_capture);
        if let Ok(rep) = &mut out {
            if let Some(store) = store {
                let section = self.build_explain(store, rep);
                rep.explain = Some(section);
            }
            rep.stats = capture;
        }
        out
    }

    fn evaluate_inner(&self) -> Result<Report> {
        let _root = crate::obs::span("scenario.evaluate");
        // lint pre-flight (opt out with `no_lint`): errors abort before any
        // optimizer runs; warnings ride along on the report. Beyond that,
        // no upfront check(): every eval path validates what it touches
        // with the same errors, so nothing is built twice.
        let lint = if self.lint {
            let _s = crate::obs::span("lint");
            crate::lint::lint_scenario(self)
        } else {
            Default::default()
        };
        if lint.has_errors() {
            bail!("scenario fails lint:\n{}", lint.render());
        }
        let mut rep = {
            let _goal = crate::obs::span(self.goal.name());
            match self.goal {
                Goal::Map => self.eval_map(),
                Goal::Serve => self.eval_serve(),
                Goal::Simulate => self.eval_simulate(),
                Goal::Plan => self.eval_plan(),
                Goal::Fabric => self.eval_fabric(),
                Goal::Explore => self.eval_explore(),
            }?
        };
        rep.lint = lint;
        Ok(rep)
    }

    fn report_base(&self, system: String) -> Report {
        Report {
            goal: self.goal,
            workload: self.workload.describe(),
            system,
            mapping: None,
            perf: None,
            serving: None,
            cluster: None,
            plan: None,
            fabric: None,
            explore: None,
            explain: None,
            lint: Default::default(),
            stats: None,
        }
    }

    /// Assemble the `Report.explain` section from the finished collector
    /// store, running the sensitivity sweep when the options ask for it.
    fn build_explain(
        &self,
        store: crate::explain::Store,
        rep: &Report,
    ) -> crate::explain::ExplainReport {
        let audit = crate::explain::ledger::build(&store.phases, self.explain.top);
        let mut sensitivity = Vec::new();
        if self.explain.sensitivity {
            sensitivity = match self.goal {
                Goal::Map => rep
                    .perf
                    .as_ref()
                    .map_or_else(Vec::new, |p| self.map_sensitivity(p.step_time)),
                Goal::Serve => rep
                    .serving
                    .as_ref()
                    .map_or_else(Vec::new, |v| self.serve_sensitivity(v.tpot)),
                _ => Vec::new(),
            };
        }
        crate::explain::ExplainReport {
            attribution: store.attribution,
            audit,
            sensitivity,
            frontier_tags: store.frontier_tags,
        }
    }

    /// Elasticities of the step time w.r.t. the system knobs, from extra
    /// (unexplained, untraced) evaluations at perturbed systems.
    fn map_sensitivity(&self, base: f64) -> Vec<crate::explain::Elasticity> {
        use crate::explain::sensitivity::{rank, scaled_system, Knob, REL_STEP};
        use crate::explain::Elasticity;
        let Ok(base_sys) = self.system.build() else { return Vec::new() };
        let Ok(cal) = self.knobs.calibrate_opts() else { return Vec::new() };
        let eval = |sys: &SystemSpec| -> Option<f64> {
            let sys = match &cal {
                None => sys.clone(),
                Some(opts) => crate::fabric::calibrate_system(sys, opts),
            };
            self.run_map(&sys).ok().flatten().map(|r| r.step_time)
        };
        let (xp, xm) = (1.0 + REL_STEP, 1.0 - REL_STEP);
        let mut rows = Vec::new();
        for knob in [Knob::Flops, Knob::MemBw, Knob::MemCap, Knob::LinkBw, Knob::Sram] {
            let plus = eval(&scaled_system(&base_sys, knob, xp));
            let minus = eval(&scaled_system(&base_sys, knob, xm));
            rows.push(Elasticity::central(knob.name(), (1.0, xp, xm), base, plus, minus));
        }
        // the chip-count knob is discrete: rebuild the same topology family
        // at 2n / n/2 chips (balanced construction; unrealizable counts —
        // e.g. dgx1 off a multiple of 8 — leave that side infeasible)
        let n = base_sys.n_chips();
        let chips_eval = |m: usize| -> Option<f64> {
            if m == n {
                return None;
            }
            let sys = self.system_with_chips(m).build().ok()?;
            eval(&sys)
        };
        let (np, nm) = (n * 2, (n / 2).max(1));
        let plus = chips_eval(np);
        let minus = chips_eval(nm);
        let probes = (n as f64, np as f64, nm as f64);
        rows.push(Elasticity::central("chips", probes, base, plus, minus));
        rank(&mut rows);
        rows
    }

    /// Elasticities of TPOT w.r.t. the serving-platform knobs.
    fn serve_sensitivity(&self, base: f64) -> Vec<crate::explain::Elasticity> {
        use crate::explain::sensitivity::{rank, scaled_serving, Knob, REL_STEP};
        use crate::explain::Elasticity;
        let (Ok(sys), Ok(model)) = (self.system.build_serving(), self.workload.llama_config())
        else {
            return Vec::new();
        };
        let pt = self.serving_point();
        let eval = |s: &crate::serving::ServingSystem| {
            crate::serving::evaluate(&model, s, &pt).ok().map(|m| m.tpot)
        };
        let (xp, xm) = (1.0 + REL_STEP, 1.0 - REL_STEP);
        let mut rows = Vec::new();
        for knob in [Knob::Flops, Knob::MemBw, Knob::MemCap, Knob::LinkBw, Knob::Sram] {
            let plus = eval(&scaled_serving(&sys, knob, xp));
            let minus = eval(&scaled_serving(&sys, knob, xm));
            rows.push(Elasticity::central(knob.name(), (1.0, xp, xm), base, plus, minus));
        }
        rank(&mut rows);
        rows
    }

    /// This scenario's system with the topology rebuilt for `chips` total
    /// chips (balanced `topology::by_name` construction, same family).
    fn system_with_chips(&self, chips: usize) -> SystemCfg {
        let mut cfg = self.system.clone();
        cfg.topology = TopologyCfg {
            kind: cfg.topology.kind.clone(),
            dims: Vec::new(),
            chips: Some(chips),
        };
        cfg
    }

    /// The map-goal optimizer pass on an explicit system: `Ok(None)` when
    /// no plan satisfies the capacity constraints. Shared by `eval_map` and
    /// the sensitivity sweep's perturbed re-evaluations.
    fn run_map(&self, sys: &SystemSpec) -> Result<Option<crate::pipeline::StepResult>> {
        let opts = self.knobs.interchip_options();
        Ok(match self.workload.build(&self.knobs)? {
            BuiltWorkload::Gpt { cfg, batch } => {
                crate::pipeline::llm_training_opts(&cfg, sys, batch, &opts)
            }
            BuiltWorkload::Graph { graph, passes, max_dp } => {
                // graph workloads default to the legacy state factor (bf16
                // weights + grads, §VI-C) unless the knob overrides it
                let mut gopts = opts.clone();
                gopts.max_dp = max_dp;
                if self.knobs.state_bytes_per_weight_byte.is_none() {
                    gopts.state_bytes_per_weight_byte = 2.0;
                }
                crate::pipeline::workload_pass_opts(&graph, sys, passes, &gopts)
            }
        })
    }

    fn eval_map(&self) -> Result<Report> {
        let base_sys = self.system.build()?;
        let (sys, calibrated) = match self.knobs.calibrate_opts()? {
            None => (base_sys, false),
            Some(opts) => (crate::fabric::calibrate_system(&base_sys, &opts), true),
        };
        let r = self.run_map(&sys)?;
        let r = r.ok_or_else(|| {
            err!(
                "no feasible mapping for {} on {} (capacity constraints)",
                self.workload.describe(),
                sys.describe()
            )
        })?;
        let (c, m, n) = r.breakdown_frac();
        let mut rep = self.report_base(sys.describe());
        rep.mapping = Some(Mapping {
            tp: r.tp,
            pp: r.pp,
            dp: r.dp,
            n_stages: r.mapping.n_stages,
            n_partitions: r.mapping.n_partitions,
            schemes: r.mapping.schemes.clone(),
            calibrated,
        });
        rep.perf = Some(PerfReport {
            step_time: r.step_time,
            utilization: r.utilization,
            achieved_flops: r.achieved_flops,
            cost_eff: r.achieved_flops / 1e9 / sys.price_usd().raw(),
            power_eff: r.achieved_flops / 1e9 / sys.power_w().raw(),
            breakdown: (c, m, n),
        });
        Ok(rep)
    }

    /// The scenario's serving operating point.
    fn serving_point(&self) -> crate::serving::ServingPoint {
        crate::serving::ServingPoint {
            tp: self.serving.tp,
            pp: self.serving.pp,
            batch: self.serving.batch,
            prompt_len: self.serving.prompt,
            context: self.serving.context,
        }
    }

    fn eval_serve(&self) -> Result<Report> {
        let sys = self.system.build_serving()?;
        let model = self.workload.llama_config()?;
        let pt = self.serving_point();
        let m = crate::serving::evaluate(&model, &sys, &pt)?;
        if crate::explain::enabled() {
            let attr = crate::explain::attribution::from_serving(&m);
            crate::explain::with_store(|s| s.attribution = Some(attr));
            audit_serving_splits(&model, &sys, &pt, &m);
        }
        let mut rep = self.report_base(format!("{} x{}", sys.chip.name, sys.n_chips));
        rep.mapping = Some(Mapping {
            tp: pt.tp,
            pp: pt.pp,
            dp: 1,
            n_stages: pt.pp,
            n_partitions: 0,
            schemes: Vec::new(),
            calibrated: false,
        });
        rep.serving = Some(ServingReport {
            ttft: m.ttft,
            prefill_tps: m.prefill_tps,
            tpot: m.tpot,
            decode_tps: m.decode_tps,
            prefill_breakdown: m.prefill_breakdown,
            decode_breakdown: m.decode_breakdown,
        });
        Ok(rep)
    }

    fn eval_simulate(&self) -> Result<Report> {
        use crate::cluster::engine::{simulate_stream, ReplicaConfig, SimOptions, Slo};
        use crate::cluster::workload::{Arrivals, LengthDist, TraceSpec};
        let sys = self.system.build_serving()?;
        let model = self.workload.llama_config()?;
        let c = &self.cluster;
        c.check_traffic()?;
        let mut cfg = ReplicaConfig::new(model, sys, self.serving.tp, self.serving.pp);
        cfg.max_batch = c.max_batch;
        let arrivals = match c.arrivals.as_str() {
            "poisson" => Arrivals::Poisson { rate: c.rate },
            "bursty" => {
                Arrivals::Bursty { base: c.rate * 0.25, peak: c.rate * 1.75, period: c.period }
            }
            other => bail!("unknown arrival process '{other}' (known: poisson bursty)"),
        };
        let spec = TraceSpec {
            seed: c.seed,
            n_requests: c.requests,
            arrivals,
            prompt: LengthDist { mean: c.prompt_mean, sigma: 0.4, min: 16, max: 8192 },
            output: LengthDist { mean: c.output_mean, sigma: 0.6, min: 2, max: 2048 },
        };
        let slo = Slo { ttft: c.slo_ttft, tpot: c.slo_tpot };
        // streaming by default: the trace is never materialized, so the
        // request count only affects runtime, not memory
        let opts = SimOptions { exact_percentiles: c.exact_percentiles };
        let r = simulate_stream(&cfg, c.replicas, &spec, &slo, &opts)?;
        let mut rep = self.report_base(format!(
            "{} x{} (TP{}xPP{}) x {} replica(s)",
            cfg.sys.chip.name, cfg.sys.n_chips, cfg.tp, cfg.pp, c.replicas
        ));
        rep.mapping = Some(Mapping {
            tp: cfg.tp,
            pp: cfg.pp,
            dp: c.replicas,
            n_stages: cfg.pp,
            n_partitions: 0,
            schemes: Vec::new(),
            calibrated: false,
        });
        rep.cluster = Some(ClusterReport {
            offered: r.n_offered,
            completed: r.n_completed,
            rejected: r.n_rejected,
            makespan: r.makespan,
            throughput_rps: r.throughput_rps,
            goodput_rps: r.goodput_rps,
            slo_attainment: r.slo_attainment,
            output_tokens_per_s: r.output_tokens_per_s,
            kv_peak_frac: r.kv_peak_frac,
            events: r.events,
            steps: r.steps,
            peak_in_flight: r.peak_in_flight,
            exact_percentiles: r.exact_percentiles,
            queue: r.queue,
            ttft: r.ttft,
            tpot: r.tpot,
        });
        Ok(rep)
    }

    fn eval_plan(&self) -> Result<Report> {
        use crate::cluster::engine::Slo;
        use crate::cluster::planner::{plan, FleetPlan, PlanTarget, PlanTraffic};
        let model = self.workload.llama_config()?;
        let c = &self.cluster;
        c.check_plan()?;
        let target = PlanTarget {
            qps: c.qps,
            slo: Slo { ttft: c.slo_ttft, tpot: c.slo_tpot },
            attainment: c.attainment,
        };
        let mut traffic =
            PlanTraffic { seed: c.seed, n_requests: c.requests, ..Default::default() };
        traffic.prompt.mean = c.prompt_mean;
        traffic.output.mean = c.output_mean;
        let res = plan(&model, &target, &traffic);
        let cand = |f: &FleetPlan| PlanCandidate {
            platform: f.platform.clone(),
            group: f.group,
            tp: f.tp,
            pp: f.pp,
            replicas: f.replicas,
            chips_total: f.chips_total,
            usd_per_hour: f.usd_per_hour,
            capex_usd: f.capex_usd,
            slo_attainment: f.report.slo_attainment,
            ttft_p99: f.report.ttft.p99,
            tpot_p99: f.report.tpot.p99,
            meets_target: f.meets_target,
        };
        let mut rep = self.report_base("serving-platform catalog".into());
        rep.plan = Some(PlanReport {
            qps: c.qps,
            slo_ttft: c.slo_ttft,
            slo_tpot: c.slo_tpot,
            attainment: c.attainment,
            candidates: res.candidates.len(),
            best: res.best.map(|i| cand(&res.candidates[i])),
            top: res.candidates.iter().take(c.top).map(cand).collect(),
        });
        Ok(rep)
    }

    fn eval_explore(&self) -> Result<Report> {
        if self.explore.top == 0 {
            bail!("explore top must be >= 1");
        }
        let space = self.explore.space(&self.workload, &self.knobs)?;
        let outcome = crate::explore::explore(&space, &self.explore.settings())?;
        if crate::explain::enabled() {
            // When the evaluator's sequential fast path ran candidates on
            // this (armed) thread, their per-candidate optimizer hooks
            // landed in the store; an explore report explains the frontier,
            // not one arbitrary candidate, so drop those captures.
            crate::explain::with_store(|s| {
                s.attribution = None;
                s.phases.clear();
            });
            crate::explain::record_frontier_tags(crate::explore::frontier_tags(
                &outcome,
                self.explore.top,
            ));
        }
        let mut rep = self.report_base(format!(
            "{}-candidate search space ({} chips x {} mems x {} links x {} topologies x {} \
             counts x {} batches)",
            outcome.candidates,
            self.explore.chips.len(),
            self.explore.mems.len(),
            self.explore.links.len(),
            self.explore.topologies.len(),
            self.explore.chip_counts.len(),
            self.explore.batches.len()
        ));
        rep.explore = Some(ExploreReport::from_outcome(&outcome, self.explore.top));
        Ok(rep)
    }

    fn eval_fabric(&self) -> Result<Report> {
        use crate::fabric::{self, Algo, Routing, SimConfig};
        let (topo, _link) = self.system.build_topology()?;
        let f = &self.fabric;
        let coll = scenario::collective_by_name(&f.collective)?;
        let routing = Routing::parse(&f.routing)
            .ok_or_else(|| err!("unknown routing '{}' (known: dimorder adaptive)", f.routing))?;
        let cfg = SimConfig { routing, seed: f.seed, ..Default::default() };
        let g = fabric::FabricGraph::new(&topo);
        let dims: Vec<&crate::system::Dim> = topo.dims.iter().collect();
        let ana =
            crate::collective::time_hier(coll, crate::util::units::Bytes::new(f.bytes), &dims)
                .raw();
        let group: Vec<usize> = (0..topo.n_chips()).collect();
        let mut evals = fabric::evaluate_algos(&g, &group, coll, f.bytes, &cfg);
        if let Some(name) = &f.algo {
            let a = Algo::parse(name)
                .ok_or_else(|| err!("unknown algo '{name}' (known: ring hd direct hier)"))?;
            evals.retain(|e| e.algo == a);
        }
        if evals.is_empty() {
            bail!("no feasible algorithm for {coll:?} on {}", topo.name);
        }
        let mut rep = self.report_base(topo.name.clone());
        rep.fabric = Some(FabricReport {
            topology: topo.name.clone(),
            chips: topo.n_chips(),
            nodes: g.n_nodes(),
            links: g.links.len(),
            bisection_bytes_per_s: topo.bisection_bytes_per_s().raw(),
            collective: f.collective.clone(),
            bytes: f.bytes,
            routing: f.routing.clone(),
            analytical: ana,
            best: evals[0].algo.name().to_string(),
            evals: evals
                .iter()
                .map(|e| FabricAlgoEval {
                    algo: e.algo.name().to_string(),
                    time: e.time,
                    vs_analytical: e.time / ana - 1.0,
                    max_link_util: e.max_link_util,
                    msgs: e.msgs,
                    packets: e.packets,
                })
                .collect(),
        });
        Ok(rep)
    }
}

/// Record the `serving.split` audit phase: every alternative TP×PP split
/// that covers the chip group, scored by TPOT and dominated by the decode
/// phase's binding resource (callers gate on `explain::enabled`).
fn audit_serving_splits(
    model: &crate::graph::llama::LlamaConfig,
    sys: &crate::serving::ServingSystem,
    chosen: &crate::serving::ServingPoint,
    m: &crate::serving::ServingMetrics,
) {
    let dom = |b: (f64, f64, f64)| {
        if b.0 >= b.1 && b.0 >= b.2 {
            "compute"
        } else if b.1 >= b.2 {
            "dram"
        } else {
            "interchip"
        }
    };
    crate::explain::ledger::record_winner(
        "serving.split",
        format!("TP{}xPP{}", chosen.tp, chosen.pp),
        m.tpot,
        dom(m.decode_breakdown),
    );
    let n = sys.n_chips;
    for tp in 1..=n {
        if n % tp != 0 {
            continue;
        }
        let pp = n / tp;
        if tp == chosen.tp && pp == chosen.pp {
            continue;
        }
        let alt = crate::serving::ServingPoint { tp, pp, ..*chosen };
        match crate::serving::evaluate(model, sys, &alt) {
            Ok(am) => crate::explain::ledger::record_candidate(
                "serving.split",
                format!("TP{tp}xPP{pp}"),
                Some(am.tpot),
                dom(am.decode_breakdown),
            ),
            Err(_) => crate::explain::ledger::record_candidate(
                "serving.split",
                format!("TP{tp}xPP{pp}"),
                None,
                "infeasible-split",
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::{chip, interconnect, memory, topology};

    /// The four paper workloads must reproduce `dse::evaluate_point` bit
    /// for bit through the facade (same code path, same numbers).
    #[test]
    fn facade_matches_legacy_dse_points_on_paper_workloads() {
        let cases: [(Workload, Scenario, SystemCfg); 4] = [
            (
                Workload::Llm,
                Scenario::llm("gpt3-1t").batch(2048.0),
                SystemCfg::new("h100", "hbm3", "nvlink4").torus2d(32, 32),
            ),
            (
                Workload::Dlrm,
                Scenario::dlrm(),
                SystemCfg::new("sn30", "hbm3", "nvlink4").torus2d(32, 32),
            ),
            (
                Workload::Hpl,
                Scenario::hpl(),
                SystemCfg::new("tpuv4", "ddr4", "pcie4").torus2d(32, 32),
            ),
            (
                Workload::Fft,
                Scenario::fft(),
                SystemCfg::new("tpuv4", "hbm3", "nvlink4").torus2d(32, 32),
            ),
        ];
        for (w, scenario, syscfg) in cases {
            let sys = syscfg.build().unwrap();
            let legacy = crate::dse::evaluate_point(w, &sys);
            let facade = scenario.on(syscfg).evaluate();
            match (legacy, facade) {
                (Some(p), Ok(r)) => {
                    let perf = r.perf.as_ref().expect("map goal fills perf");
                    assert_eq!(perf.utilization, p.utilization, "{w:?} utilization");
                    assert_eq!(perf.cost_eff, p.cost_eff, "{w:?} cost_eff");
                    assert_eq!(perf.power_eff, p.power_eff, "{w:?} power_eff");
                    assert_eq!(perf.breakdown, p.breakdown, "{w:?} breakdown");
                    assert!(r.degrees().is_some());
                }
                (None, Err(_)) => {} // infeasible either way is consistent
                (l, f) => panic!("{w:?}: legacy {l:?} vs facade {f:?} disagree on feasibility"),
            }
        }
    }

    /// `map_chip` is the same optimizer as the `pub(crate)` internal.
    #[test]
    fn map_chip_matches_internal_optimizer() {
        use crate::intrachip::IntraChipOptions;
        let g = crate::graph::gpt::gpt_layer_graph(&crate::graph::gpt::gpt3_175b(), 1.0);
        let c = chip::sn10();
        let mem = memory::ddr4();
        let a = map_chip(&g, &c, &mem, &IntraChipOptions::default()).unwrap();
        let b = crate::intrachip::optimize_intra(&g, &c, &mem, &IntraChipOptions::default())
            .unwrap();
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.assignment.part, b.assignment.part);
    }

    /// Scenario serde round-trip: same scenario in, identical report out.
    #[test]
    fn roundtripped_scenario_reports_identically() {
        let s = Scenario::llm("gpt3-175b")
            .batch(64.0)
            .on(SystemCfg::new("sn10", "ddr4", "pcie4").ring(8));
        let back = Scenario::parse(&s.to_json().pretty()).unwrap();
        assert_eq!(s, back);
        let a = s.evaluate().unwrap();
        let b = back.evaluate().unwrap();
        assert_eq!(a, b, "round-tripped scenario must evaluate identically");
        assert!(a.utilization().unwrap() > 0.0);
        let (tp, pp, dp) = a.degrees().unwrap();
        assert_eq!(tp * pp * dp, 8);
    }

    /// The mapping section carries schemes/stages/partitions.
    #[test]
    fn map_report_exposes_mapping_detail() {
        let r = Scenario::llm("gpt3-175b").evaluate().unwrap();
        let m = r.mapping.as_ref().unwrap();
        assert!(m.n_stages >= 1);
        assert!(m.n_partitions >= 1);
        assert!(!m.schemes.is_empty(), "LLM mapping must report per-kernel schemes");
        let json = r.to_json();
        assert!(json.get("mapping").is_some());
        assert!(json.get("perf").unwrap().get("utilization").is_some());
    }

    /// Serve goal matches `serving::evaluate` directly.
    #[test]
    fn serve_scenario_matches_serving_model() {
        let r = Scenario::llama("8b").evaluate().unwrap();
        let v = r.serving.as_ref().unwrap();
        let sys = crate::serving::sn40l_x16();
        let m = crate::serving::evaluate(
            &crate::graph::llama::llama3_8b(),
            &sys,
            &crate::serving::ServingPoint {
                tp: 16,
                pp: 1,
                batch: 1.0,
                prompt_len: 1024.0,
                context: 1024.0,
            },
        )
        .unwrap();
        assert_eq!(v.ttft, m.ttft);
        assert_eq!(v.tpot, m.tpot);
        assert_eq!(v.decode_tps, m.decode_tps);
    }

    /// An infeasible serving split surfaces the descriptive error.
    #[test]
    fn infeasible_split_reports_reason() {
        let e = Scenario::llama("8b").serving_split(5, 2).evaluate().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("TP5") && msg.contains("PP2"), "{msg}");
        assert!(msg.contains("16-chip"), "{msg}");
    }

    /// The calibrated-knob path reaches the fabric and changes the model.
    #[test]
    fn calibrated_fabric_knob_threads_through() {
        let s = Scenario::llm("gpt3-175b").calibrated_fabric();
        let r = s.evaluate().unwrap();
        assert!(r.mapping.unwrap().calibrated);
        // the analytical twin of the same scenario differs only in knobs
        let a = Scenario::llm("gpt3-175b").evaluate().unwrap();
        assert!(!a.mapping.unwrap().calibrated);
    }

    /// Fabric goal reproduces `evaluate_algos` through the facade.
    #[test]
    fn fabric_scenario_races_algorithms() {
        let s = Scenario::llm("gpt3-175b")
            .on(SystemCfg::new("h100", "hbm3", "nvlink4").torus2d(4, 4))
            .fabric_sweep("allreduce", 16e6);
        let r = s.evaluate().unwrap();
        let f = r.fabric.as_ref().unwrap();
        assert_eq!(f.chips, 16);
        assert_eq!(f.evals.len(), 4, "all four families run on a torus");
        assert!(f.evals.windows(2).all(|w| w[0].time <= w[1].time));
        assert_eq!(f.best, f.evals[0].algo);
        assert!(f.analytical > 0.0);
    }

    /// Explore goal runs the explorer and fills the explore section with
    /// consistent counters and a sorted frontier.
    #[test]
    fn explore_scenario_reports_frontier() {
        use crate::explore::{ChipCfg, MemCfg};
        let opts = ExploreOptions {
            chips: vec![ChipCfg::named("sn10"), ChipCfg::named("h100")],
            mems: vec![MemCfg::named("ddr4"), MemCfg::named("hbm3")],
            links: vec!["pcie4".into()],
            topologies: vec!["ring".into()],
            chip_counts: vec![8],
            batches: vec![None],
            prune: false,
            budget: None,
            top: 8,
        };
        let r = Scenario::llm("gpt3-175b").batch(16.0).explore(opts).evaluate().unwrap();
        assert_eq!(r.goal, Goal::Explore);
        let e = r.explore.as_ref().expect("explore section");
        assert_eq!(e.candidates, 4);
        assert_eq!(e.candidates, e.evaluated + e.cache_hits + e.pruned + e.skipped_budget);
        assert!(e.frontier_size >= 1);
        assert!(!e.frontier.is_empty());
        // frontier rows sorted by utilization, best first
        for w in r.frontier().unwrap().windows(2) {
            assert!(w[0].utilization >= w[1].utilization);
        }
        assert_eq!(r.best_utilization(), Some(e.frontier[0].utilization));
        assert!(r.to_json().get("explore").unwrap().get("frontier").is_some());
    }

    /// Tracing captures the phase spans + counters and never perturbs the
    /// numbers: stripping `stats` restores bit-parity with the untraced run.
    #[test]
    fn traced_evaluation_captures_phases_without_changing_the_report() {
        let s = Scenario::llm("gpt3-175b");
        let plain = s.evaluate().unwrap();
        let mut traced = s.traced().evaluate().unwrap();
        let cap = traced.stats.take().expect("traced run fills Report.stats");
        assert_eq!(traced, plain, "tracing must not change any report bit");
        let shape = cap.structure();
        for phase in ["scenario.evaluate", "lint", "map", "interchip", "intrachip", "pipeline_dp"] {
            assert!(shape.contains(phase), "missing span '{phase}' in:\n{shape}");
        }
        assert_eq!(cap.counter("pipeline.evaluations"), Some(1));
    }

    /// Explaining fills attribution + audit + sensitivity and never
    /// perturbs the numbers: stripping `explain` restores bit-parity with
    /// the plain run.
    #[test]
    fn explained_evaluation_fills_sections_without_changing_the_report() {
        let s = Scenario::llm("gpt3-175b");
        let plain = s.evaluate().unwrap();
        let mut ex = s.explained().evaluate().unwrap();
        let section = ex.explain.take().expect("explained run fills Report.explain");
        assert_eq!(ex, plain, "explain must not change any report bit");
        let a = section.attribution.expect("map goal records attribution");
        assert!(
            (a.levels.sum() - a.total).abs() <= 1e-9 * a.total.max(1.0),
            "levels {} vs total {}",
            a.levels.sum(),
            a.total
        );
        assert_eq!(a.total, plain.step_time().unwrap());
        let audit = section.audit.expect("audit ledger");
        assert!(
            audit.phases.iter().any(|p| !p.rejected.is_empty()),
            "at least one phase must carry rejected candidates"
        );
        assert_eq!(section.sensitivity.len(), 6, "five continuous knobs + chips");
        assert!(section.sensitivity.iter().any(|e| e.elasticity.is_some()));
    }

    /// Serve-goal explain: two-phase attribution, the TP×PP split audit,
    /// and serving-knob elasticities.
    #[test]
    fn explained_serve_records_split_audit() {
        let r = Scenario::llama("8b").explained().evaluate().unwrap();
        let e = r.explain.as_ref().unwrap();
        let a = e.attribution.as_ref().unwrap();
        assert!((a.levels.sum() - a.total).abs() <= 1e-9 * a.total);
        assert_eq!(a.kernels.len(), 2, "prefill + decode rows");
        let audit = e.audit.as_ref().unwrap();
        let split = audit.phases.iter().find(|p| p.phase == "serving.split").unwrap();
        // divisor splits of the 16-chip group minus the chosen TP16xPP1
        assert_eq!(split.considered, 4);
        assert!(split.best.is_some());
        assert!(split.rejected.iter().all(|c| !c.dominating.is_empty()));
        assert!(!e.sensitivity.is_empty());
    }

    /// Explain on an unsupported goal is a descriptive error, not a panic.
    #[test]
    fn explained_unsupported_goal_errors() {
        let e = Scenario::llama("8b")
            .simulate_traffic(1.0, 10)
            .explained()
            .evaluate()
            .unwrap_err();
        assert!(e.to_string().contains("explain supports"), "{e}");
    }

    /// evaluate_design wrapper mirrors the internal point evaluation.
    #[test]
    fn evaluate_design_wrapper_works() {
        let link = interconnect::nvlink4();
        let sys = SystemSpec::new(
            chip::h100(),
            memory::hbm3(),
            link.clone(),
            topology::torus2d(32, 32, &link),
        );
        let p = evaluate_design(Workload::Llm, &sys).expect("feasible");
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
    }
}
