//! Parallelization-plan enumeration (§IV-C): every network dimension is
//! assigned to exactly one of {TP, PP, DP}; a dimension cannot be split.
//! The TP/PP/DP degrees are the products of the dims assigned to each axis.

use crate::system::topology::{Dim, Topology};

/// One (TP, PP, DP) plan with its dim assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParallelismPlan {
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
    /// Indices into `topology.dims` per axis.
    pub tp_dims: Vec<usize>,
    pub pp_dims: Vec<usize>,
    pub dp_dims: Vec<usize>,
}

impl ParallelismPlan {
    pub fn tp_dims_ref<'a>(&self, t: &'a Topology) -> Vec<&'a Dim> {
        self.tp_dims.iter().map(|&i| &t.dims[i]).collect()
    }

    pub fn pp_dims_ref<'a>(&self, t: &'a Topology) -> Vec<&'a Dim> {
        self.pp_dims.iter().map(|&i| &t.dims[i]).collect()
    }

    pub fn dp_dims_ref<'a>(&self, t: &'a Topology) -> Vec<&'a Dim> {
        self.dp_dims.iter().map(|&i| &t.dims[i]).collect()
    }

    pub fn describe(&self) -> String {
        format!("TP={} PP={} DP={}", self.tp, self.pp, self.dp)
    }
}

/// All 3^d assignments of the topology's d dims to {TP, PP, DP}.
/// Deduplicated by (tp, pp, dp, assignment) — dims of size 1 are pinned to
/// TP so they do not generate spurious duplicates.
pub fn enumerate_plans(t: &Topology) -> Vec<ParallelismPlan> {
    let d = t.dims.len();
    let mut plans = Vec::new();
    let n_assign = 3usize.pow(d as u32);
    'outer: for code in 0..n_assign {
        let mut c = code;
        let (mut tp, mut pp, mut dp) = (1usize, 1usize, 1usize);
        let mut tp_dims = Vec::new();
        let mut pp_dims = Vec::new();
        let mut dp_dims = Vec::new();
        for (i, dim) in t.dims.iter().enumerate() {
            let axis = c % 3;
            c /= 3;
            if dim.size == 1 && axis != 0 {
                // canonical placement for degenerate dims
                continue 'outer;
            }
            match axis {
                0 => {
                    tp = tp.checked_mul(dim.size).expect("tp overflow");
                    tp_dims.push(i);
                }
                1 => {
                    pp *= dim.size;
                    pp_dims.push(i);
                }
                _ => {
                    dp *= dim.size;
                    dp_dims.push(i);
                }
            }
        }
        plans.push(ParallelismPlan { tp, pp, dp, tp_dims, pp_dims, dp_dims });
    }
    plans
}

/// Plans filtered to those feasible for a workload: PP cannot exceed the
/// number of pipeline-partitionable units, and DP cannot exceed the number
/// of independent batch items.
pub fn feasible_plans(
    t: &Topology,
    max_pp_units: usize,
    max_dp: usize,
) -> Vec<ParallelismPlan> {
    enumerate_plans(t)
        .into_iter()
        .filter(|p| p.pp <= max_pp_units.max(1) && p.dp <= max_dp.max(1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::interconnect::nvlink4;
    use crate::system::topology::{ring, torus2d};

    #[test]
    fn single_ring_has_three_plans() {
        let t = ring(8, &nvlink4());
        let plans = enumerate_plans(&t);
        assert_eq!(plans.len(), 3);
        let degrees: Vec<(usize, usize, usize)> =
            plans.iter().map(|p| (p.tp, p.pp, p.dp)).collect();
        assert!(degrees.contains(&(8, 1, 1)));
        assert!(degrees.contains(&(1, 8, 1)));
        assert!(degrees.contains(&(1, 1, 8)));
    }

    #[test]
    fn torus_generates_nine_plans() {
        let t = torus2d(4, 2, &nvlink4());
        let plans = enumerate_plans(&t);
        assert_eq!(plans.len(), 9);
        // the §VII-D plan: TP over the 4-ring, DP over the 2-ring
        assert!(plans.iter().any(|p| p.tp == 4 && p.pp == 1 && p.dp == 2));
        // degrees always multiply to the chip count
        assert!(plans.iter().all(|p| p.tp * p.pp * p.dp == 8));
    }

    #[test]
    fn feasibility_filter() {
        let t = torus2d(4, 2, &nvlink4());
        let plans = feasible_plans(&t, 1, 2);
        assert!(plans.iter().all(|p| p.pp == 1 && p.dp <= 2));
        assert!(!plans.is_empty());
    }

    #[test]
    fn dims_partition_is_exact() {
        let t = torus2d(4, 2, &nvlink4());
        for p in enumerate_plans(&t) {
            let mut all: Vec<usize> =
                p.tp_dims.iter().chain(&p.pp_dims).chain(&p.dp_dims).copied().collect();
            all.sort_unstable();
            assert_eq!(all, vec![0, 1]);
        }
    }
}
