//! The inter-chip optimizer: plan loop × sharding selection × stage DP.

use super::parallelism::{feasible_plans, ParallelismPlan};
use super::{latency_vectors, InterChipMapping, StageMetrics};
use crate::graph::DataflowGraph;
use crate::sharding;
use crate::solver;
use crate::system::SystemSpec;
use crate::util::units::Seconds;

/// Options for `optimize`.
#[derive(Debug, Clone)]
pub struct InterChipOptions {
    /// Upper bound on PP (number of pipeline-partitionable units, e.g.
    /// model layers).
    pub max_pp: usize,
    /// Upper bound on DP (independent batch items).
    pub max_dp: usize,
    /// Restrict to one (tp, pp, dp) combination (§VII case studies).
    pub force_degrees: Option<(usize, usize, usize)>,
    /// DRAM bytes of training state per byte of (bf16) weights: weights +
    /// grads + fp32 optimizer moments ≈ 8×.
    pub state_bytes_per_weight_byte: f64,
    /// Coordinate-descent restarts / sweeps for sharding selection.
    pub restarts: usize,
    pub sweeps: usize,
    /// Use exhaustive sharding enumeration when the label space is below
    /// this size (exact certification).
    pub exhaustive_below: f64,
}

impl Default for InterChipOptions {
    fn default() -> Self {
        InterChipOptions {
            max_pp: usize::MAX,
            max_dp: usize::MAX,
            force_degrees: None,
            state_bytes_per_weight_byte: 8.0,
            restarts: 6,
            sweeps: 40,
            exhaustive_below: 50_000.0,
        }
    }
}

/// Run the §IV optimization: returns the best mapping across all feasible
/// plans, or None if no plan satisfies the capacity constraints.
/// (`pub(crate)` — the public seam is `api::map_graph`.)
pub(crate) fn optimize(
    g: &DataflowGraph,
    sys: &SystemSpec,
    opts: &InterChipOptions,
) -> Option<InterChipMapping> {
    let order = g.topo_order().expect("graph must be a DAG");
    let plans = feasible_plans(&sys.topology, opts.max_pp.min(g.n_kernels()), opts.max_dp);
    let mut best: Option<InterChipMapping> = None;
    let mut space_log10 = 0.0f64;

    for plan in &plans {
        if let Some((tp, pp, dp)) = opts.force_degrees {
            if plan.tp != tp || plan.pp != pp || plan.dp != dp {
                continue;
            }
        }
        let (scheme_idx, shard_space) = select_sharding(g, sys, plan, opts);
        // accumulate explored-space size: schemes × stage compositions
        let stage_space = ln_choose(g.n_kernels().saturating_sub(1), plan.pp.saturating_sub(1))
            / std::f64::consts::LN_10;
        space_log10 = space_log10.max(shard_space + stage_space);

        let vectors = latency_vectors(g, sys, plan, &scheme_idx);
        let Some((t_cri, stage_of, stages)) =
            partition_stages(g, sys, plan, &scheme_idx, &vectors, &order, opts)
        else {
            if crate::explain::enabled() {
                crate::explain::ledger::record_candidate(
                    "interchip.plan",
                    plan.describe(),
                    None,
                    "dram-capacity",
                );
            }
            continue;
        };
        if crate::explain::enabled() {
            crate::explain::ledger::record_candidate(
                "interchip.plan",
                plan.describe(),
                Some(t_cri.raw()),
                crate::explain::ledger::stages_dominator(&stages),
            );
        }

        let cand = InterChipMapping {
            plan: plan.clone(),
            scheme_idx,
            stage_of,
            stages,
            t_cri,
            vectors,
            space_log10,
        };
        if best.as_ref().map_or(true, |b| cand.t_cri < b.t_cri) {
            best = Some(cand);
        }
    }
    if let Some(b) = &mut best {
        b.space_log10 = space_log10;
        if crate::explain::enabled() {
            crate::explain::ledger::record_winner(
                "interchip.plan",
                b.plan.describe(),
                b.t_cri.raw(),
                crate::explain::ledger::stages_dominator(&b.stages),
            );
        }
    }
    best
}

fn ln_choose(n: usize, k: usize) -> f64 {
    if k == 0 || k >= n {
        return 0.0;
    }
    let ln_fact = |m: usize| (1..=m).map(|x| (x as f64).ln()).sum::<f64>();
    ln_fact(n) - ln_fact(k) - ln_fact(n - k)
}

/// Precomputed sharding cost tables, shared by `select_sharding` and the
/// explain-layer's `audit_sharding` so the audit scores candidates with
/// exactly the objective the optimizer minimized.
struct ShardingCosts {
    /// Per-kernel scheme tables.
    scheme_tbl: Vec<Vec<sharding::ShardScheme>>,
    /// Scheme count per kernel.
    n_labels: Vec<usize>,
    /// Inherent collective time (Eq. 5) + per-chip compute time under the
    /// scheme (replicated schemes pay full compute — this is what makes the
    /// optimizer shard the big GEMMs and replicate only the cheap LNs), plus
    /// an infinitesimal weight-pressure tie-break so equal-communication
    /// schemes prefer sharded weights (less DRAM).
    inherent: Vec<Vec<f64>>,
    /// Conversion cost per tensor per (src label, dst label) (Eq. 6).
    conv: Vec<Vec<Vec<f64>>>,
    /// Incident-tensor indices per kernel.
    edges_of: Vec<Vec<usize>>,
}

impl ShardingCosts {
    fn build(g: &DataflowGraph, sys: &SystemSpec, plan: &ParallelismPlan) -> ShardingCosts {
        let tp = plan.tp;
        let tp_dims = plan.tp_dims_ref(&sys.topology);
        let n = g.n_kernels();
        let chip_flops = sys.chip.compute_flops();
        let model = &sys.collective_model;

        let scheme_tbl: Vec<Vec<sharding::ShardScheme>> =
            g.kernels.iter().map(|k| sharding::schemes_for(&k.kind, tp)).collect();
        let n_labels: Vec<usize> = scheme_tbl.iter().map(|s| s.len()).collect();
        let inherent: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let out_bytes = super::kernel_out_bytes(g, crate::graph::KernelId(i));
                let k = &g.kernels[i];
                scheme_tbl[i]
                    .iter()
                    .map(|s| {
                        sharding::inherent_time_model(model, s, out_bytes, k.weight_bytes, &tp_dims)
                            .raw()
                            + k.flops * s.flops_factor / chip_flops.raw()
                            + k.weight_bytes * s.weight_factor * 1e-24
                    })
                    .collect()
            })
            .collect();
        let conv: Vec<Vec<Vec<f64>>> = g
            .tensors
            .iter()
            .map(|t| {
                scheme_tbl[t.src.0]
                    .iter()
                    .map(|from| {
                        scheme_tbl[t.dst.0]
                            .iter()
                            .map(|to| {
                                sharding::conversion_time_model(
                                    model,
                                    from.out_layout,
                                    to.in_layout,
                                    t.bytes,
                                    &tp_dims,
                                )
                                .raw()
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        let mut edges_of: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (j, t) in g.tensors.iter().enumerate() {
            edges_of[t.src.0].push(j);
            edges_of[t.dst.0].push(j);
        }
        ShardingCosts { scheme_tbl, n_labels, inherent, conv, edges_of }
    }

    fn total(&self, g: &DataflowGraph, labels: &[usize]) -> f64 {
        let mut c: f64 = labels.iter().enumerate().map(|(i, &l)| self.inherent[i][l]).sum();
        for (j, t) in g.tensors.iter().enumerate() {
            c += self.conv[j][labels[t.src.0]][labels[t.dst.0]];
        }
        c
    }
}

/// Choose a sharding scheme per kernel minimizing total communication
/// (inherent Eq. 5 + conversions Eq. 6). Exact (exhaustive) below the
/// configured space size, coordinate descent with restarts otherwise.
/// Returns (labels, log10 of the sharding space size).
pub fn select_sharding(
    g: &DataflowGraph,
    sys: &SystemSpec,
    plan: &ParallelismPlan,
    opts: &InterChipOptions,
) -> (Vec<usize>, f64) {
    let costs = ShardingCosts::build(g, sys, plan);
    let total = |labels: &[usize]| costs.total(g, labels);

    let space = solver::label_space_size(&costs.n_labels);
    let labels = if space <= opts.exhaustive_below {
        solver::exhaustive_labels(&costs.n_labels, |ls| total(ls)).1
    } else {
        let unary = |i: usize, l: usize| costs.inherent[i][l];
        let local = |i: usize, ls: &[usize]| {
            costs.edges_of[i]
                .iter()
                .map(|&j| {
                    let t = &g.tensors[j];
                    costs.conv[j][ls[t.src.0]][ls[t.dst.0]]
                })
                .sum()
        };
        let ics = solver::Ics {
            n_labels: &costs.n_labels,
            unary: &unary,
            local: &local,
            total: &total,
        };
        solver::coordinate_descent(&ics, opts.restarts, opts.sweeps, 0x5eed).1
    };
    (labels, space.log10())
}

/// Explain-layer audit of a chosen sharding: records the winner and, per
/// kernel, the best single-scheme swap as a rejected candidate — its score
/// is the full objective under the swap and its dominating term names
/// whether the inherent-collective or the conversion delta killed it.
/// No-op unless an explain session is armed on this thread.
pub(crate) fn audit_sharding(
    g: &DataflowGraph,
    sys: &SystemSpec,
    plan: &ParallelismPlan,
    labels: &[usize],
) {
    if !crate::explain::enabled() {
        return;
    }
    use crate::explain::ledger::{record_candidate, record_winner};
    let costs = ShardingCosts::build(g, sys, plan);
    let base = costs.total(g, labels);

    let inherent_sum: f64 = labels.iter().enumerate().map(|(i, &l)| costs.inherent[i][l]).sum();
    let winner_dom = if inherent_sum >= base - inherent_sum { "inherent" } else { "conversion" };
    record_winner(
        "interchip.sharding",
        format!("chosen labeling ({} kernels)", g.n_kernels()),
        base,
        winner_dom,
    );

    for (i, k) in g.kernels.iter().enumerate() {
        let cur = labels[i];
        // best alternative label for kernel i, holding all others fixed
        let mut best: Option<(usize, f64, f64)> = None; // (label, d_inherent, d_conv)
        for l in 0..costs.n_labels[i] {
            if l == cur {
                continue;
            }
            let d_inherent = costs.inherent[i][l] - costs.inherent[i][cur];
            let mut d_conv = 0.0;
            for &j in &costs.edges_of[i] {
                let t = &g.tensors[j];
                let (s_cur, d_cur) = (labels[t.src.0], labels[t.dst.0]);
                let s_new = if t.src.0 == i { l } else { s_cur };
                let d_new = if t.dst.0 == i { l } else { d_cur };
                d_conv += costs.conv[j][s_new][d_new] - costs.conv[j][s_cur][d_cur];
            }
            let d = d_inherent + d_conv;
            if best.is_none_or(|(_, bi, bc)| d < bi + bc) {
                best = Some((l, d_inherent, d_conv));
            }
        }
        let Some((alt, d_inherent, d_conv)) = best else {
            continue; // single-scheme kernel: nothing was rejected
        };
        let dom = if d_inherent.abs() >= d_conv.abs() { "inherent" } else { "conversion" };
        record_candidate(
            "interchip.sharding",
            format!(
                "{}: {} -> {}",
                k.name, costs.scheme_tbl[i][cur].name, costs.scheme_tbl[i][alt].name
            ),
            Some(base + d_inherent + d_conv),
            dom,
        );
    }
}

/// Exact contiguous-DP stage partitioning over topological order,
/// minimizing the max per-stage critical time (Eq. 7), with the per-chip
/// DRAM training-state capacity as a feasibility constraint.
#[allow(clippy::too_many_arguments)]
fn partition_stages(
    g: &DataflowGraph,
    sys: &SystemSpec,
    plan: &ParallelismPlan,
    scheme_idx: &[usize],
    vectors: &super::LatencyVectors,
    order: &[crate::graph::KernelId],
    opts: &InterChipOptions,
) -> Option<(Seconds, Vec<usize>, Vec<StageMetrics>)> {
    let n = g.n_kernels();
    let pp = plan.pp;
    // topo position of each kernel
    let mut pos = vec![0usize; n];
    for (p, k) in order.iter().enumerate() {
        pos[k.0] = p;
    }

    // prefix sums over topo positions
    let mut pre_c = vec![0.0f64; n + 1];
    let mut pre_n = vec![0.0f64; n + 1];
    let mut pre_w = vec![0.0f64; n + 1];
    for (p, k) in order.iter().enumerate() {
        let i = k.0;
        let tp = plan.tp;
        let schemes = sharding::schemes_for(&g.kernels[i].kind, tp);
        let s = &schemes[scheme_idx[i]];
        // conversion of incoming tensors charged to the consumer's stage
        let conv_in: f64 = g.in_edges(*k).map(|(tid, _)| vectors.h_m[tid.0]).sum();
        pre_c[p + 1] = pre_c[p] + vectors.h_c[i];
        pre_n[p + 1] = pre_n[p] + vectors.h_n[i] + conv_in;
        pre_w[p + 1] = pre_w[p] + sharding::sharded_weights(&g.kernels[i], s);
    }
    // tensor endpoints in topo positions with their p2p time
    let spans: Vec<(usize, usize, f64)> = g
        .tensors
        .iter()
        .enumerate()
        .map(|(j, t)| {
            let (a, b) = (pos[t.src.0], pos[t.dst.0]);
            (a.min(b), a.max(b), vectors.h_p[j])
        })
        .collect();

    let d_cap = sys.memory.capacity.raw();
    let state_factor = opts.state_bytes_per_weight_byte;
    let cost_fn = |a: usize, b: usize| -> f64 {
        // per-chip training state of this stage must fit DRAM
        let weights = pre_w[b] - pre_w[a];
        if weights * state_factor > d_cap {
            return f64::INFINITY;
        }
        let t_comp = pre_c[b] - pre_c[a];
        let t_net = pre_n[b] - pre_n[a];
        let mut t_p2p = 0.0;
        if pp > 1 {
            for &(s, d, h) in &spans {
                // tensor alive in this segment and crossing a boundary
                let alive = s < b && d >= a;
                let inside = s >= a && d < b;
                if alive && !inside {
                    t_p2p += h;
                }
            }
        }
        t_comp.max(t_net).max(t_p2p)
    };

    // Precompute the segment-cost table once: the DP probes each (a, b)
    // max_parts times and the p2p term is O(m) per probe — table lookup
    // keeps the whole pass at O(n²·m + pp·n²).
    let table: Vec<Vec<f64>> =
        (0..n).map(|a| (a + 1..=n).map(|b| cost_fn(a, b)).collect()).collect();
    let cost = |a: usize, b: usize| table[a][b - a - 1];

    let (t_cri, bounds) = solver::partition_min_max(n, pp, cost)?;
    let part_of_pos = solver::bounds_to_assignment(n, &bounds);
    let mut stage_of = vec![0usize; n];
    for (p, k) in order.iter().enumerate() {
        stage_of[k.0] = part_of_pos[p];
    }
    // per-stage metrics
    let n_stages = bounds.len();
    let mut stages = vec![StageMetrics::default(); n_stages];
    for (si, &start) in bounds.iter().enumerate() {
        let end = bounds.get(si + 1).copied().unwrap_or(n);
        stages[si].t_comp = Seconds::new(pre_c[end] - pre_c[start]);
        stages[si].t_net = Seconds::new(pre_n[end] - pre_n[start]);
        if pp > 1 {
            for &(s, d, h) in &spans {
                let alive = s < end && d >= start;
                let inside = s >= start && d < end;
                if alive && !inside {
                    stages[si].t_p2p += Seconds::new(h);
                }
            }
        }
    }
    Some((Seconds::new(t_cri), stage_of, stages))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gpt::{gpt3_175b, gpt_coarse_graph, gpt_layer_graph};
    use crate::system::{chip, interconnect, memory, topology, SystemSpec};
    use crate::util::units::Bytes;

    fn sn10_ring8() -> SystemSpec {
        SystemSpec::new(
            chip::sn10(),
            memory::ddr4(),
            interconnect::pcie4(),
            topology::ring(8, &interconnect::pcie4()),
        )
    }

    /// Hand-build the expert Megatron labeling [62], [75]: QKV
    /// column-sharded, attention head-sharded, Proj/FFN1 contraction-sharded
    /// (partial sums -> all-reduce), everything else replicated.
    fn megatron_labels(g: &crate::graph::DataflowGraph, tp: usize) -> Vec<usize> {
        g.kernels
            .iter()
            .map(|k| {
                let schemes = crate::sharding::schemes_for(&k.kind, tp);
                let want = if k.name.ends_with(".Q")
                    || k.name.ends_with(".K")
                    || k.name.ends_with(".V")
                    || k.name.ends_with("FFN0")
                {
                    "col"
                } else if k.name.ends_with("Proj") || k.name.ends_with("FFN1") {
                    "kdim"
                } else if k.name.contains("MHA") || k.name.contains("Softmax") {
                    "head"
                } else if k.name.contains("GeLU") {
                    "col"
                } else {
                    "rep"
                };
                schemes.iter().position(|s| s.name == want).unwrap_or(0)
            })
            .collect()
    }

    #[test]
    fn matches_expert_megatron_partitioning() {
        // §VI-A validation: (a) the expert Megatron hand-mapping emits
        // exactly 2 forward all-reduces (4 per fwd+bwd iteration), and
        // (b) DFModel's optimizer finds a sharding at least as cheap as the
        // expert's (it finds the RS/AG decomposition with identical cost).
        let g = gpt_layer_graph(&gpt3_175b(), 1.0);
        let sys = sn10_ring8();
        let plans = crate::interchip::enumerate_plans(&sys.topology);
        let plan = plans.iter().find(|p| p.tp == 8).unwrap();

        let hand = megatron_labels(&g, 8);
        let hand_map = InterChipMapping {
            plan: plan.clone(),
            scheme_idx: hand.clone(),
            stage_of: vec![0; g.n_kernels()],
            stages: vec![],
            t_cri: Seconds::ZERO,
            vectors: crate::interchip::latency_vectors(&g, &sys, plan, &hand),
            space_log10: 0.0,
        };
        assert_eq!(hand_map.count_allreduces(&g, 8), 2, "expert mapping = 2 fwd all-reduces");

        let opts = InterChipOptions { force_degrees: Some((8, 1, 1)), ..Default::default() };
        let m = optimize(&g, &sys, &opts).expect("mapping");
        let opt_comm = m.total_net_time();
        let hand_comm = hand_map.total_net_time();
        assert!(
            opt_comm <= hand_comm * 1.0001,
            "optimizer ({opt_comm:.6e}) must match/beat expert ({hand_comm:.6e})"
        );
        // and not unrealistically cheaper: within 2x of the expert bound
        assert!(opt_comm >= hand_comm * 0.5, "optimizer comm {opt_comm:.3e} vs {hand_comm:.3e}");
    }

    #[test]
    fn pp_partition_balances_layers() {
        let cfg = gpt3_175b();
        let g = gpt_coarse_graph(&cfg, 1.0);
        let sys = SystemSpec::new(
            chip::a100(),
            memory::hbm3(),
            interconnect::nvlink4(),
            topology::torus2d(8, 12, &interconnect::nvlink4()),
        );
        let opts = InterChipOptions {
            force_degrees: Some((8, 12, 1)),
            ..Default::default()
        };
        let m = optimize(&g, &sys, &opts).expect("mapping");
        assert_eq!(m.stages.len(), 12);
        // 96 layers over 12 stages: 8 per stage, balanced compute
        let comps: Vec<f64> = m.stages.iter().map(|s| s.t_comp.raw()).collect();
        let (min, max) = comps
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &c| (lo.min(c), hi.max(c)));
        assert!(max / min < 1.05, "unbalanced stages: {comps:?}");
    }

    #[test]
    fn best_plan_beats_forced_bad_plan() {
        let g = gpt_coarse_graph(&gpt3_175b(), 1.0);
        let sys = sn10_ring8();
        let free = optimize(&g, &sys, &InterChipOptions::default()).unwrap();
        let forced = optimize(
            &g,
            &sys,
            &InterChipOptions { force_degrees: Some((8, 1, 1)), ..Default::default() },
        )
        .unwrap();
        assert!(free.t_cri <= forced.t_cri + Seconds::new(1e-12));
    }

    #[test]
    fn dram_capacity_rules_out_infeasible_plans() {
        // 1T model on 8 chips with tiny DRAM: nothing fits
        let g = gpt_coarse_graph(&crate::graph::gpt::gpt3_1t(), 1.0);
        let mut sys = sn10_ring8();
        sys.memory.capacity = Bytes::new(1e9); // 1 GB
        let m = optimize(&g, &sys, &InterChipOptions::default());
        assert!(m.is_none());
    }

    #[test]
    fn space_accounting_positive() {
        let g = gpt_layer_graph(&gpt3_175b(), 1.0);
        let sys = sn10_ring8();
        let m = optimize(&g, &sys, &InterChipOptions::default()).unwrap();
        assert!(m.space_log10 > 0.0);
    }
}
