//! Inter-chip optimization (§IV): choose TP/PP/DP degrees over the network
//! dimensions, a sharding scheme per kernel, and a pipeline-stage
//! assignment, minimizing the max per-stage critical time (Eq. 7).
//!
//! Decomposition (DESIGN.md §Optimization): plans are enumerated exactly
//! (every assignment of network dims to parallelism axes, §IV-C's
//! one-dim-one-strategy rule); per plan, sharding selection is a pairwise
//! discrete optimization solved by coordinate descent with restarts
//! (exhaustively certified on small graphs); stage partitioning is an exact
//! contiguous DP over topological order.

pub mod optimizer;
pub mod parallelism;

pub use optimizer::InterChipOptions;
pub use parallelism::{enumerate_plans, ParallelismPlan};

/// `pub(crate)`: external callers go through `api::map_graph` or a
/// `api::Scenario` — the facade is the only public optimization seam.
pub(crate) use optimizer::optimize;

use crate::graph::DataflowGraph;
use crate::sharding::{self, ShardScheme};
use crate::system::SystemSpec;
use crate::util::units::{Bytes, Flop, Seconds};

/// Per-kernel / per-tensor latency vectors of the §IV-B formulation.
///
/// These are raw `f64` seconds, not typed [`Seconds`]: the stage-DP and
/// sharding solvers consume them as prefix-summable cost arrays (a solver
/// boundary), so each entry is produced with `.raw()` from a typed time.
#[derive(Debug, Clone)]
pub struct LatencyVectors {
    /// h_c[i]: compute time of kernel i spread over the TP group (Eq. §IV-B.1).
    pub h_c: Vec<f64>,
    /// h_n[i]: inherent collective time of kernel i's chosen scheme (Eq. 5).
    pub h_n: Vec<f64>,
    /// h_m[j]: layout-conversion time of tensor j (Eq. 6).
    pub h_m: Vec<f64>,
    /// h_p[j]: point-to-point time of tensor j across PP stages.
    pub h_p: Vec<f64>,
}

/// Metrics of one pipeline stage under the performance model of Fig. 5.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageMetrics {
    pub t_comp: Seconds,
    pub t_net: Seconds,
    pub t_p2p: Seconds,
}

impl StageMetrics {
    /// Eq. 7: the critical time bottlenecking the stage.
    pub fn t_cri(&self) -> Seconds {
        self.t_comp.max(self.t_net).max(self.t_p2p)
    }
}

/// Result of the inter-chip pass ((2) in Fig. 1).
#[derive(Debug, Clone)]
pub struct InterChipMapping {
    pub plan: ParallelismPlan,
    /// Chosen scheme index per kernel (into `schemes_for(kind, tp)`).
    pub scheme_idx: Vec<usize>,
    /// Stage of each kernel (indices into topo order positions!).
    pub stage_of: Vec<usize>,
    pub stages: Vec<StageMetrics>,
    /// max_i t_cri (the §IV objective; time per pipeline input).
    pub t_cri: Seconds,
    /// Latency vectors under the chosen schemes.
    pub vectors: LatencyVectors,
    /// Design-space size explored (for the paper's O(10^x) accounting).
    pub space_log10: f64,
}

impl InterChipMapping {
    /// Total inherent + conversion communication time per input.
    pub fn total_net_time(&self) -> f64 {
        self.vectors.h_n.iter().sum::<f64>() + self.vectors.h_m.iter().sum::<f64>()
    }

    /// Number of all-reduce-class collectives the chosen sharding emits
    /// (the §VI-A validation counts these).
    pub fn count_allreduces(&self, g: &DataflowGraph, tp: usize) -> usize {
        use crate::collective::Collective;
        let mut n = 0;
        for (i, k) in g.kernels.iter().enumerate() {
            let schemes = sharding::schemes_for(&k.kind, tp);
            if let Some((op, _)) = schemes[self.scheme_idx[i]].inherent {
                if op == Collective::AllReduce {
                    n += 1;
                }
            }
        }
        for t in &g.tensors {
            let from = scheme_of(g, &self.scheme_idx, t.src.0, tp).out_layout;
            let to = scheme_of(g, &self.scheme_idx, t.dst.0, tp).in_layout;
            if sharding::conversion_op(from, to) == Some(Collective::AllReduce) {
                n += 1;
            }
        }
        n
    }
}

/// Scheme chosen for kernel `k` under a TP degree.
pub fn scheme_of(g: &DataflowGraph, scheme_idx: &[usize], k: usize, tp: usize) -> ShardScheme {
    let schemes = sharding::schemes_for(&g.kernels[k].kind, tp);
    schemes[scheme_idx[k]].clone()
}

/// The full-size output-tensor bytes of kernel `k` (replicated out-edges
/// share one size; kernels with no out edge produce the graph output —
/// approximated by their largest in-edge).
pub fn kernel_out_bytes(g: &DataflowGraph, k: crate::graph::KernelId) -> f64 {
    let out = g.out_edges(k).map(|(_, t)| t.bytes).fold(0.0f64, f64::max);
    if out > 0.0 {
        out
    } else {
        g.in_edges(k).map(|(_, t)| t.bytes).fold(0.0f64, f64::max)
    }
}

/// Compute the latency vectors for a given plan + scheme choice (Eqs. 5/6 +
/// §IV-B.1 compute model + p2p model).
pub fn latency_vectors(
    g: &DataflowGraph,
    sys: &SystemSpec,
    plan: &ParallelismPlan,
    scheme_idx: &[usize],
) -> LatencyVectors {
    let tp = plan.tp;
    let tp_dims = plan.tp_dims_ref(&sys.topology);
    let pp_dims = plan.pp_dims_ref(&sys.topology);
    let chip_flops = sys.chip.compute_flops();

    let model = &sys.collective_model;
    let mut h_c = Vec::with_capacity(g.n_kernels());
    let mut h_n = Vec::with_capacity(g.n_kernels());
    for (i, k) in g.kernels.iter().enumerate() {
        let schemes = sharding::schemes_for(&k.kind, tp);
        let s = &schemes[scheme_idx[i]];
        // §IV-B.1: FLOP / (n_tp · t_lim · t_flop); a replicated scheme does
        // not divide its compute (flops_factor = 1), a sharded one divides
        // by tp (flops_factor = 1/tp) — per-chip time either way.
        // Flop / FlopPerSec = Seconds, flattened to raw for the solvers.
        h_c.push((Flop::new(k.flops * s.flops_factor) / chip_flops).raw());
        let out_bytes = kernel_out_bytes(g, crate::graph::KernelId(i));
        h_n.push(
            sharding::inherent_time_model(model, s, out_bytes, k.weight_bytes, &tp_dims).raw(),
        );
    }
    let _ = tp; // degree itself is folded into flops_factor

    let mut h_m = Vec::with_capacity(g.n_tensors());
    let mut h_p = Vec::with_capacity(g.n_tensors());
    for t in &g.tensors {
        let from = scheme_of(g, scheme_idx, t.src.0, tp);
        let to = scheme_of(g, scheme_idx, t.dst.0, tp);
        h_m.push(
            sharding::conversion_time_model(
                model,
                from.out_layout,
                to.in_layout,
                t.bytes,
                &tp_dims,
            )
            .raw(),
        );
        // p2p across pipeline stages: the (sharded) tensor moves once
        let sharded = t.bytes * from.out_bytes_factor;
        h_p.push(if plan.pp > 1 {
            model
                .time_hier(crate::collective::Collective::P2P, Bytes::new(sharded), &pp_dims)
                .raw()
        } else {
            0.0
        });
    }
    LatencyVectors { h_c, h_n, h_m, h_p }
}

/// Apply a sharding choice to a graph: per-chip FLOP/weights/tensor sizes
/// ((2) in Fig. 1 — the input to the intra-chip pass), plus the per-kernel
/// network time (inherent + incoming conversions) charged to each kernel.
pub fn shard_graph(
    g: &DataflowGraph,
    sys: &SystemSpec,
    plan: &ParallelismPlan,
    scheme_idx: &[usize],
) -> (DataflowGraph, Vec<f64>) {
    let tp = plan.tp;
    let v = latency_vectors(g, sys, plan, scheme_idx);
    let mut out = g.clone();
    for (i, k) in out.kernels.iter_mut().enumerate() {
        let schemes = sharding::schemes_for(&k.kind, tp);
        let s = &schemes[scheme_idx[i]];
        k.flops *= s.flops_factor;
        k.weight_bytes *= s.weight_factor;
        // shrink the GEMM dims the scheme divides so the utilization model
        // sees per-chip shapes (approximate: scale the widest dim)
        if let crate::graph::KernelKind::Gemm { b, m, k: kk, n } = &mut k.kind {
            match s.name {
                "row" => *m /= tp as f64,
                "col" => *n /= tp as f64,
                "head" => *b = (*b / tp as f64).max(1.0),
                "kdim" => *kk /= tp as f64,
                _ => {}
            }
        }
    }
    for (j, t) in out.tensors.iter_mut().enumerate() {
        let s = scheme_of(g, scheme_idx, t.src.0, tp);
        t.bytes *= s.out_bytes_factor;
        let _ = j;
    }
    let mut net = vec![0.0; g.n_kernels()];
    for (i, nt) in net.iter_mut().enumerate() {
        *nt = v.h_n[i];
    }
    for (j, t) in g.tensors.iter().enumerate() {
        net[t.dst.0] += v.h_m[j];
    }
    (out, net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gpt::{gpt3_175b, gpt_layer_graph};
    use crate::system::{chip, interconnect, memory, topology, SystemSpec};

    fn sys8() -> SystemSpec {
        SystemSpec::new(
            chip::sn10(),
            memory::ddr4(),
            interconnect::pcie4(),
            topology::ring(8, &interconnect::pcie4()),
        )
    }

    #[test]
    fn latency_vectors_shapes_and_positivity() {
        let g = gpt_layer_graph(&gpt3_175b(), 1.0);
        let sys = sys8();
        let plans = enumerate_plans(&sys.topology);
        let plan = plans.iter().find(|p| p.tp == 8).unwrap();
        let schemes = vec![0usize; g.n_kernels()];
        let v = latency_vectors(&g, &sys, plan, &schemes);
        assert_eq!(v.h_c.len(), g.n_kernels());
        assert_eq!(v.h_m.len(), g.n_tensors());
        assert!(v.h_c.iter().all(|&t| t >= 0.0));
        assert!(v.h_c.iter().sum::<f64>() > 0.0);
        // pp == 1 -> no p2p
        assert!(v.h_p.iter().all(|&t| t == 0.0));
    }

    #[test]
    fn stage_metrics_critical_time() {
        let m = StageMetrics {
            t_comp: Seconds::new(3.0),
            t_net: Seconds::new(5.0),
            t_p2p: Seconds::new(1.0),
        };
        assert_eq!(m.t_cri(), Seconds::new(5.0));
    }

    #[test]
    fn compute_time_scales_inverse_tp() {
        let g = gpt_layer_graph(&gpt3_175b(), 1.0);
        let sys = sys8();
        let plans = enumerate_plans(&sys.topology);
        let p8 = plans.iter().find(|p| p.tp == 8).unwrap();
        let p1 = plans.iter().find(|p| p.tp == 1 && p.dp == 8).unwrap();
        let schemes = vec![0usize; g.n_kernels()];
        let v8 = latency_vectors(&g, &sys, p8, &schemes);
        let v1 = latency_vectors(&g, &sys, p1, &schemes);
        let r = v1.h_c.iter().sum::<f64>() / v8.h_c.iter().sum::<f64>();
        assert!((r - 8.0).abs() < 1e-9);
    }
}
