//! Graph-level lint rules (`DF-G001`..`DF-G004`) over the dataflow-graph
//! IR, plus the `{"graph": {...}}` side format `dfmodel lint` accepts for
//! linting hand-written graphs without a scenario around them.
//!
//! Rule order is deliberate: reference checks (G001) run first and gate
//! the cycle check (G002), because `topo_order` indexes kernel ids and
//! would panic on a dangling reference.

use super::LintReport;
use crate::bail;
use crate::graph::{DataflowGraph, Kernel, KernelId, KernelKind, Tensor};
use crate::util::error::Result;
use crate::util::json::Json;

/// Structural and dimensional rules over one dataflow graph
/// (`DF-G001`..`DF-G004`).
pub fn lint_graph(g: &DataflowGraph) -> LintReport {
    let mut r = LintReport::default();
    lint_graph_into(g, &mut r);
    r
}

/// [`lint_graph`], appending into an existing report (the scenario driver).
pub(crate) fn lint_graph_into(g: &DataflowGraph, r: &mut LintReport) {
    let gname = if g.name.is_empty() { "graph" } else { g.name.as_str() };
    if g.kernels.is_empty() {
        r.error("DF-G001", gname, "graph has no kernels");
        return;
    }
    let mut refs_ok = true;
    for t in &g.tensors {
        for (end, id) in [("src", t.src), ("dst", t.dst)] {
            if id.0 >= g.kernels.len() {
                refs_ok = false;
                r.error(
                    "DF-G001",
                    format!("tensor '{}'", t.name),
                    format!(
                        "{end} kernel id {} is out of range (the graph has {} kernel(s))",
                        id.0,
                        g.kernels.len()
                    ),
                );
            }
        }
        if !(t.bytes.is_finite() && t.bytes > 0.0) {
            r.error(
                "DF-G003",
                format!("tensor '{}'", t.name),
                format!("tensor bytes must be positive and finite, got {}", t.bytes),
            );
        }
    }
    for k in &g.kernels {
        lint_kernel(k, r);
    }
    if !refs_ok {
        return; // topo_order would index out of range
    }
    let mut self_loop = false;
    for t in &g.tensors {
        if t.src == t.dst {
            self_loop = true;
            r.error(
                "DF-G002",
                format!("tensor '{}'", t.name),
                format!("self-loop: src and dst are both kernel id {}", t.src.0),
            );
        }
    }
    if !self_loop {
        if let Err(e) = g.topo_order() {
            r.error("DF-G002", gname, e.to_string());
        }
    }
}

/// DF-G004 on one kernel: kind dimensions, flops, and weights must be
/// finite; dimensions positive, flops/weights nonnegative.
fn lint_kernel(k: &Kernel, r: &mut LintReport) {
    let ctx = format!("kernel '{}'", k.name);
    for (dim, v) in kind_dims(&k.kind) {
        if !(v.is_finite() && v > 0.0) {
            r.error(
                "DF-G004",
                ctx.as_str(),
                format!("{dim} must be positive and finite, got {v}"),
            );
        }
    }
    if let KernelKind::Elementwise { flop_per_elem, .. } = k.kind {
        if !(flop_per_elem.is_finite() && flop_per_elem >= 0.0) {
            r.error(
                "DF-G004",
                ctx.as_str(),
                format!("flop_per_elem must be nonnegative and finite, got {flop_per_elem}"),
            );
        }
    }
    for (field, v) in [("flops", k.flops), ("weight_bytes", k.weight_bytes)] {
        if !(v.is_finite() && v >= 0.0) {
            r.error(
                "DF-G004",
                ctx.as_str(),
                format!("{field} must be nonnegative and finite, got {v}"),
            );
        }
    }
}

/// The positive-dimension fields of a kernel kind, by name.
fn kind_dims(kind: &KernelKind) -> Vec<(&'static str, f64)> {
    match *kind {
        KernelKind::Gemm { b, m, k, n } => vec![("b", b), ("m", m), ("k", k), ("n", n)],
        KernelKind::Softmax { rows, cols } => vec![("rows", rows), ("cols", cols)],
        KernelKind::Elementwise { elems, .. } => vec![("elems", elems)],
        KernelKind::LayerNorm { rows, cols } => vec![("rows", rows), ("cols", cols)],
        KernelKind::Embedding { lookups, dim } => vec![("lookups", lookups), ("dim", dim)],
        KernelKind::Fft { points, batch } => vec![("points", points), ("batch", batch)],
        KernelKind::Transpose { elems } => vec![("elems", elems)],
        KernelKind::FusedLayer { tokens, width } => vec![("tokens", tokens), ("width", width)],
    }
}

/// Parse the `{"graph": ...}` side format: `name`, a `kernels` array
/// (`{name, kind, <dims>, flops?, weight_bytes?}` — dims default to 1,
/// `flops` defaults to the kind's formula) and a `tensors` array
/// (`{name, src, dst, bytes}` with kernel *indices*; out-of-range indices
/// parse fine so DF-G001 can report them).
pub fn graph_from_json(j: &Json) -> Result<DataflowGraph> {
    let name = j.get("name").and_then(|v| v.as_str()).unwrap_or("graph").to_string();
    let Some(kjs) = j.get("kernels").and_then(|v| v.as_array()) else {
        bail!("graph needs a 'kernels' array");
    };
    let mut kernels = Vec::with_capacity(kjs.len());
    for (i, kj) in kjs.iter().enumerate() {
        kernels.push(kernel_from_json(kj, i)?);
    }
    let mut tensors = Vec::new();
    if let Some(tjs) = j.get("tensors").and_then(|v| v.as_array()) {
        for (i, tj) in tjs.iter().enumerate() {
            let end = |key: &str| -> Result<KernelId> {
                match tj.get(key).and_then(|v| v.as_usize()) {
                    Some(id) => Ok(KernelId(id)),
                    None => bail!("tensor {i}: '{key}' must be a kernel index"),
                }
            };
            tensors.push(Tensor {
                name: tj
                    .get("name")
                    .and_then(|v| v.as_str())
                    .map_or_else(|| format!("t{i}"), str::to_string),
                src: end("src")?,
                dst: end("dst")?,
                bytes: tj.get("bytes").and_then(|v| v.as_f64()).unwrap_or(1.0),
            });
        }
    }
    Ok(DataflowGraph { name, kernels, tensors })
}

/// One kernel of the side format; `i` names anonymous kernels `k{i}`.
fn kernel_from_json(kj: &Json, i: usize) -> Result<Kernel> {
    let f = |key: &str, dft: f64| kj.get(key).and_then(|v| v.as_f64()).unwrap_or(dft);
    let kind = match kj.get("kind").and_then(|v| v.as_str()).unwrap_or("gemm") {
        "gemm" => {
            KernelKind::Gemm { b: f("b", 1.0), m: f("m", 1.0), k: f("k", 1.0), n: f("n", 1.0) }
        }
        "softmax" => KernelKind::Softmax { rows: f("rows", 1.0), cols: f("cols", 1.0) },
        "elementwise" => KernelKind::Elementwise {
            elems: f("elems", 1.0),
            flop_per_elem: f("flop_per_elem", 1.0),
        },
        "layernorm" => KernelKind::LayerNorm { rows: f("rows", 1.0), cols: f("cols", 1.0) },
        "embedding" => KernelKind::Embedding { lookups: f("lookups", 1.0), dim: f("dim", 1.0) },
        "fft" => KernelKind::Fft { points: f("points", 1.0), batch: f("batch", 1.0) },
        "transpose" => KernelKind::Transpose { elems: f("elems", 1.0) },
        "fused_layer" => {
            KernelKind::FusedLayer { tokens: f("tokens", 1.0), width: f("width", 1.0) }
        }
        other => bail!(
            "kernel {i}: unknown kind '{other}' (known: gemm softmax elementwise \
             layernorm embedding fft transpose fused_layer)"
        ),
    };
    Ok(Kernel {
        name: kj
            .get("name")
            .and_then(|v| v.as_str())
            .map_or_else(|| format!("k{i}"), str::to_string),
        flops: kj.get("flops").and_then(|v| v.as_f64()).unwrap_or_else(|| kind.flops()),
        weight_bytes: f("weight_bytes", 0.0),
        kind,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn two_kernel_graph() -> DataflowGraph {
        let mut b = GraphBuilder::new("t");
        let a = b.kernel("a", KernelKind::Gemm { b: 1.0, m: 2.0, k: 2.0, n: 2.0 }, 0.0);
        let c = b.kernel("c", KernelKind::Softmax { rows: 2.0, cols: 2.0 }, 0.0);
        b.tensor("ac", a, c, 16.0);
        b.build()
    }

    #[test]
    fn valid_graph_is_clean() {
        assert!(lint_graph(&two_kernel_graph()).is_clean());
    }

    #[test]
    fn dangling_reference_is_g001_and_gates_the_cycle_check() {
        let mut g = two_kernel_graph();
        g.tensors.push(Tensor {
            name: "bad".into(),
            src: KernelId(0),
            dst: KernelId(9),
            bytes: 8.0,
        });
        let r = lint_graph(&g);
        assert_eq!(r.codes(), vec!["DF-G001"], "{:?}", r.diags);
    }

    #[test]
    fn self_loop_and_cycle_are_g002() {
        let mut g = two_kernel_graph();
        g.tensors.push(Tensor {
            name: "loop".into(),
            src: KernelId(1),
            dst: KernelId(1),
            bytes: 8.0,
        });
        assert_eq!(lint_graph(&g).codes(), vec!["DF-G002"]);
        let mut g = two_kernel_graph();
        g.tensors.push(Tensor {
            name: "back".into(),
            src: KernelId(1),
            dst: KernelId(0),
            bytes: 8.0,
        });
        assert_eq!(lint_graph(&g).codes(), vec!["DF-G002"]);
    }

    #[test]
    fn zero_tensor_bytes_is_g003() {
        let mut g = two_kernel_graph();
        g.tensors[0].bytes = 0.0;
        let r = lint_graph(&g);
        assert_eq!(r.codes(), vec!["DF-G003"]);
        assert!(r.diags[0].context.contains("ac"));
    }

    #[test]
    fn bad_kernel_dims_are_g004() {
        let mut g = two_kernel_graph();
        g.kernels[0].kind = KernelKind::Gemm { b: 1.0, m: 0.0, k: 2.0, n: f64::NAN };
        let r = lint_graph(&g);
        assert_eq!(r.codes(), vec!["DF-G004"]);
        assert_eq!(r.n_errors(), 2, "{:?}", r.diags);
    }

    #[test]
    fn side_format_parses_and_defaults() {
        let j = Json::parse(
            r#"{"name": "mini",
                "kernels": [{"name": "mm", "kind": "gemm", "m": 4, "k": 4, "n": 4},
                            {"kind": "softmax", "rows": 4, "cols": 4}],
                "tensors": [{"src": 0, "dst": 1, "bytes": 64}]}"#,
        )
        .unwrap();
        let g = graph_from_json(&j).unwrap();
        assert_eq!(g.name, "mini");
        assert_eq!(g.kernels[0].flops, 2.0 * 4.0 * 4.0 * 4.0);
        assert_eq!(g.kernels[1].name, "k1");
        assert_eq!(g.tensors[0].name, "t0");
        assert!(lint_graph(&g).is_clean());
    }

    #[test]
    fn side_format_rejects_unknown_kind_and_missing_ends() {
        let j = Json::parse(r#"{"kernels": [{"kind": "conv9d"}]}"#).unwrap();
        assert!(graph_from_json(&j).is_err());
        let j = Json::parse(r#"{"kernels": [{}], "tensors": [{"src": 0}]}"#).unwrap();
        assert!(graph_from_json(&j).is_err());
    }
}
