//! Static analysis for scenarios, graphs, and mappings — the engine behind
//! `dfmodel lint` and the opt-out pre-flight gate in
//! [`Scenario::evaluate`](crate::api::Scenario::evaluate).
//!
//! Every rule has a stable `DF-XNNN` code (`G` graph, `S` system, `M`
//! mapping, `C` catch-all) and a severity: **errors** describe inputs that
//! would fail or panic at evaluation time and block `evaluate`; **warnings**
//! describe suspicious-but-evaluable inputs and ride along in the
//! [`Report`](crate::api::Report)'s `lint` section. The catalog lives in
//! `DESIGN.md` ("Static analysis"); every rule has a fixture under
//! `examples/scenarios/bad/` that triggers exactly its code.
//!
//! ```text
//!   DF-C001  error  scenario fails semantic validation (check() catch-all)
//!   DF-G001  error  tensor references a kernel id out of range / empty graph
//!   DF-G002  error  self-loop tensor or dependency cycle
//!   DF-G003  error  tensor bytes not positive and finite
//!   DF-G004  error  kernel dimensions/flops/weights not positive and finite
//!   DF-S001  error  nonpositive size on a system axis (dims, overrides)
//!   DF-S002  warn   memory-hierarchy inversion (link faster than DRAM, ...)
//!   DF-S003  error  topology dims contradict the explicit chip count
//!   DF-S004  warn   power/price override far off the Fig. 9 regression
//!   DF-M001  error  forced TP*PP*DP degrees do not cover the chip count
//!   DF-M002  error  serving TP*PP split does not cover the chip group
//!   DF-M003  error  weights + KV cache exceed the group's device memory
//!   DF-M004  warn   a kernel's weights oversubscribe dataflow-chip SRAM
//! ```

#![forbid(unsafe_code)]
#![deny(missing_docs)]

pub mod graph;

pub use graph::{graph_from_json, lint_graph};

use crate::api::scenario::BuiltWorkload;
use crate::api::{ExploreOptions, Goal, Scenario, SystemCfg, TopologyCfg};
use crate::explore::ChipCfg;
use crate::system::{chip, ExecutionModel};
use crate::util::json::Json;
use crate::util::units::MB;

/// How serious a diagnostic is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Suspicious but evaluable; reported, never blocks evaluation.
    Warning,
    /// Would fail (or panic) at evaluation time; blocks `evaluate`.
    Error,
}

impl Severity {
    /// Lowercase name used in renderings and JSON (`warning` / `error`).
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One lint finding: a stable code, a severity, the offending element, and
/// a human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct Diag {
    /// Stable diagnostic code (`DF-XNNN`); grep-able and CI-stable.
    pub code: &'static str,
    /// Error (blocks evaluation) or warning (reported only).
    pub severity: Severity,
    /// What the finding is about (kernel, field, or axis name).
    pub context: String,
    /// Human-readable explanation with the offending values.
    pub message: String,
}

impl Diag {
    /// One-line rendering: `error[DF-G001] tensor 't3': ...`.
    pub fn render(&self) -> String {
        format!("{}[{}] {}: {}", self.severity.name(), self.code, self.context, self.message)
    }
}

/// The result of linting one scenario or graph: every finding, in rule
/// order. `Default` is the clean (empty) report.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LintReport {
    /// Every finding, errors and warnings interleaved in rule order.
    pub diags: Vec<Diag>,
}

impl LintReport {
    fn push(&mut self, severity: Severity, code: &'static str, context: String, message: String) {
        self.diags.push(Diag { code, severity, context, message });
    }

    fn error(&mut self, code: &'static str, context: impl Into<String>, msg: impl Into<String>) {
        self.push(Severity::Error, code, context.into(), msg.into());
    }

    fn warning(&mut self, code: &'static str, context: impl Into<String>, msg: impl Into<String>) {
        self.push(Severity::Warning, code, context.into(), msg.into());
    }

    /// Number of error-severity findings.
    pub fn n_errors(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    /// Number of warning-severity findings.
    pub fn n_warnings(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// True when at least one finding is an error (evaluation would be
    /// blocked).
    pub fn has_errors(&self) -> bool {
        self.n_errors() > 0
    }

    /// True when there are no findings at all (not even warnings).
    pub fn is_clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// The distinct codes present, in first-occurrence order.
    pub fn codes(&self) -> Vec<&'static str> {
        let mut seen = Vec::new();
        for d in &self.diags {
            if !seen.contains(&d.code) {
                seen.push(d.code);
            }
        }
        seen
    }

    /// `clean` / `2 error(s), 1 warning(s)` one-phrase summary.
    pub fn summary(&self) -> String {
        if self.is_clean() {
            return "clean".into();
        }
        format!("{} error(s), {} warning(s)", self.n_errors(), self.n_warnings())
    }

    /// Multi-line rendering: one line per finding plus a summary line.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for d in &self.diags {
            s.push_str(&d.render());
            s.push('\n');
        }
        s.push_str(&self.summary());
        s.push('\n');
        s
    }

    /// Machine-readable form: `{errors, warnings, diagnostics: [...]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("errors", Json::from(self.n_errors())),
            ("warnings", Json::from(self.n_warnings())),
            (
                "diagnostics",
                Json::arr(self.diags.iter().map(|d| {
                    Json::obj(vec![
                        ("code", Json::from(d.code)),
                        ("severity", Json::from(d.severity.name())),
                        ("context", Json::from(d.context.as_str())),
                        ("message", Json::from(d.message.as_str())),
                    ])
                })),
            ),
        ])
    }
}

/// Lint one parsed JSON document: either the `{"graph": {...}}` side format
/// (graph rules only) or a scenario object (the full rule set). Semantic
/// parse failures surface as a `DF-C001` error instead of aborting, so
/// `dfmodel lint` can report on files that `Scenario::parse` rejects.
pub fn lint_json(j: &Json) -> LintReport {
    if let Some(gj) = j.get("graph") {
        let mut r = LintReport::default();
        match graph::graph_from_json(gj) {
            Ok(g) => graph::lint_graph_into(&g, &mut r),
            Err(e) => r.error("DF-G001", "graph", format!("unparseable graph: {e}")),
        }
        return r;
    }
    match Scenario::from_json_unchecked(j) {
        Ok(s) => lint_scenario(&s),
        Err(e) => {
            let mut r = LintReport::default();
            r.error("DF-C001", "scenario", e.to_string());
            r
        }
    }
}

/// Run every lint rule that applies to the scenario's goal. Pure analysis:
/// nothing is evaluated, and nothing here panics on degenerate inputs (the
/// zero-size topology pre-checks run *before* any catalog build).
pub fn lint_scenario(s: &Scenario) -> LintReport {
    let mut r = LintReport::default();
    lint_topology(&s.system.topology, &mut r);
    match s.goal {
        Goal::Map => {
            lint_system_hierarchy(&s.system, &mut r);
            lint_forced_degrees(s, &mut r);
            lint_map_workload(s, &mut r);
        }
        Goal::Serve | Goal::Simulate => {
            lint_system_hierarchy(&s.system, &mut r);
            lint_serving_split(s, &mut r);
            if s.goal == Goal::Serve {
                lint_kv_capacity(s, &mut r);
            }
        }
        Goal::Plan | Goal::Fabric => {}
        Goal::Explore => lint_explore_axes(&s.explore, &mut r),
    }
    // DF-C001 catch-all: anything check() rejects that no specific rule
    // claimed. Skipped once an error is recorded — both to keep one root
    // cause per report and because check() builds the system, which would
    // panic on the degenerate inputs the rules above just flagged.
    if !r.has_errors() {
        if let Err(e) = s.check() {
            r.error("DF-C001", "scenario", e.to_string());
        }
    }
    r
}

/// The chip count the topology description pins down, when it is
/// well-formed: the explicit `chips` count, else the product of the dims.
fn configured_chips(t: &TopologyCfg) -> Option<usize> {
    match t.chips {
        Some(n) if n >= 1 => Some(n),
        Some(_) => None,
        None if t.dims.is_empty() || t.dims.contains(&0) => None,
        None => Some(t.dims.iter().product()),
    }
}

/// DF-S001 (zero topology sizes) + DF-S003 (dims contradict `chips`).
fn lint_topology(t: &TopologyCfg, r: &mut LintReport) {
    for (i, &d) in t.dims.iter().enumerate() {
        if d == 0 {
            r.error(
                "DF-S001",
                format!("topology dim {i}"),
                format!("'{}' dimension sizes must be >= 1 chip", t.kind),
            );
        }
    }
    if t.chips == Some(0) {
        r.error("DF-S001", "topology chips", "the total chip count must be >= 1");
    }
    let Some(n) = t.chips.filter(|&n| n >= 1) else { return };
    if t.dims.is_empty() || t.dims.contains(&0) {
        return;
    }
    let prod: usize = t.dims.iter().product();
    if prod != n {
        r.error(
            "DF-S003",
            "topology",
            format!(
                "dims {:?} multiply to {prod} chip(s) but 'chips' says {n}; \
                 drop one of the two",
                t.dims
            ),
        );
    }
}

/// DF-S002 (warning): the memory hierarchy is inverted — a link faster
/// than the DRAM it drains, or SRAM at least as large as DRAM capacity.
/// Evaluates fine, but the §IV/§V cost model assumes the usual ordering.
fn lint_system_hierarchy(sys: &SystemCfg, r: &mut LintReport) {
    use crate::api::scenario::{chip_by_name, link_by_name, memory_by_name};
    let chip = chip_by_name(&sys.chip).ok();
    let mem = memory_by_name(&sys.memory).ok();
    let link = link_by_name(&sys.link).ok();
    if let (Some(l), Some(m)) = (&link, &mem) {
        if l.bandwidth > m.bandwidth {
            r.warning(
                "DF-S002",
                "system",
                format!(
                    "link '{}' ({:.0} GB/s) is faster than memory '{}' ({:.0} GB/s); \
                     the network would drain DRAM faster than it fills",
                    sys.link,
                    l.bandwidth.raw() / 1e9,
                    sys.memory,
                    m.bandwidth.raw() / 1e9
                ),
            );
        }
    }
    if let (Some(c), Some(m)) = (&chip, &mem) {
        if c.sram_bytes >= m.capacity {
            r.warning(
                "DF-S002",
                "system",
                format!(
                    "chip '{}' SRAM ({:.0} MB) is at least memory '{}' capacity ({:.0} MB); \
                     the on-chip tier should be the small one",
                    sys.chip,
                    c.sram_bytes.raw() / MB,
                    sys.memory,
                    m.capacity.raw() / MB
                ),
            );
        }
    }
}

/// DF-M001: forced (TP, PP, DP) degrees that are zero or do not multiply
/// to the configured chip count can never match a feasible plan.
fn lint_forced_degrees(s: &Scenario, r: &mut LintReport) {
    let Some((tp, pp, dp)) = s.knobs.force_degrees else { return };
    if tp == 0 || pp == 0 || dp == 0 {
        r.error(
            "DF-M001",
            "options",
            format!("forced degrees TP{tp} x PP{pp} x DP{dp} must all be >= 1"),
        );
        return;
    }
    let Some(n) = configured_chips(&s.system.topology) else { return };
    if tp * pp * dp != n {
        r.error(
            "DF-M001",
            "options",
            format!(
                "forced degrees TP{tp} x PP{pp} x DP{dp} use {} chip(s) but the \
                 topology has {n}; no plan can match",
                tp * pp * dp
            ),
        );
    }
}

/// DF-M002: the serving TP×PP split must cover the chip group exactly.
fn lint_serving_split(s: &Scenario, r: &mut LintReport) {
    let Some(n) = configured_chips(&s.system.topology) else { return };
    let (tp, pp) = (s.serving.tp, s.serving.pp);
    if tp == 0 || pp == 0 || tp * pp != n {
        r.error(
            "DF-M002",
            "serving",
            format!(
                "serving split TP{tp}xPP{pp} covers {} chip(s) but tp*pp must \
                 equal the {n}-chip group",
                tp * pp
            ),
        );
    }
}

/// DF-M003: resident weights plus the KV cache at the requested batch and
/// context must fit in the chip group's total device memory.
fn lint_kv_capacity(s: &Scenario, r: &mut LintReport) {
    use crate::api::scenario::memory_by_name;
    let Ok(model) = s.workload.llama_config() else { return };
    let Ok(mem) = memory_by_name(&s.system.memory) else { return };
    let Some(n) = configured_chips(&s.system.topology) else { return };
    let kv = s.serving.batch * s.serving.context * model.kv_bytes_per_token();
    let need = model.weight_bytes() + kv;
    let total = mem.capacity.raw() * n as f64;
    if need > total {
        r.error(
            "DF-M003",
            "serving",
            format!(
                "weights ({:.1} GB) + KV cache at batch {} x context {} ({:.1} GB) \
                 exceed the {n}-chip group's {:.1} GB device memory",
                model.weight_bytes() / 1e9,
                s.serving.batch,
                s.serving.context,
                kv / 1e9,
                total / 1e9
            ),
        );
    }
}

/// `Map`-goal workload rules: the graph rules (DF-G001..G004) on the
/// materialized dataflow graph, plus DF-M004 (SRAM oversubscription on
/// dataflow chips). Name errors are left to the DF-C001 catch-all.
fn lint_map_workload(s: &Scenario, r: &mut LintReport) {
    use crate::api::scenario::chip_by_name;
    let Ok(built) = s.workload.build(&s.knobs) else { return };
    let g = match built {
        BuiltWorkload::Gpt { cfg, batch } => crate::graph::gpt::gpt_layer_graph(&cfg, batch),
        BuiltWorkload::Graph { graph, .. } => graph,
    };
    graph::lint_graph_into(&g, r);
    let Ok(chip) = chip_by_name(&s.system.chip) else { return };
    if !matches!(chip.execution, ExecutionModel::Dataflow) {
        return;
    }
    let Some(n) = configured_chips(&s.system.topology) else { return };
    // most optimistic bound: even fully TP-sharded across all n chips, the
    // heaviest kernel's weight shard must fit in one chip's SRAM
    let heaviest = g.kernels.iter().max_by(|a, b| a.weight_bytes.total_cmp(&b.weight_bytes));
    let Some(k) = heaviest else { return };
    let per_chip = k.weight_bytes / n as f64;
    if per_chip > chip.sram_bytes.raw() {
        r.warning(
            "DF-M004",
            format!("kernel '{}'", k.name),
            format!(
                "holds {:.0} MB of weights per chip even sharded across all {n} \
                 chip(s), over the {:.0} MB SRAM of dataflow chip '{}'; the fused \
                 mapping will spill",
                per_chip / MB,
                chip.sram_bytes.raw() / MB,
                s.system.chip
            ),
        );
    }
}

/// Explore-axis rules: DF-S001 (nonpositive custom-chip/memory overrides,
/// zero chip counts) and DF-S004 (power/price overrides far off the Fig. 9
/// regression the rest of the catalog follows).
fn lint_explore_axes(e: &ExploreOptions, r: &mut LintReport) {
    for c in &e.chips {
        let ChipCfg::Custom { name, compute_tflops, sram_mb, tiles, power_w, price_usd, .. } = c
        else {
            continue;
        };
        let ctx = format!("explore chip '{name}'");
        if !(compute_tflops.is_finite() && *compute_tflops > 0.0) {
            r.error(
                "DF-S001",
                ctx.as_str(),
                format!("compute_tflops must be positive, got {compute_tflops}"),
            );
        }
        if !(sram_mb.is_finite() && *sram_mb > 0.0) {
            r.error("DF-S001", ctx.as_str(), format!("sram_mb must be positive, got {sram_mb}"));
        }
        if *tiles == Some(0) {
            r.error("DF-S001", ctx.as_str(), "tiles must be >= 1");
        }
        for (field, v) in [("power_w", power_w), ("price_usd", price_usd)] {
            let Some(v) = v else { continue };
            if !(v.is_finite() && *v > 0.0) {
                r.error(
                    "DF-S001",
                    ctx.as_str(),
                    format!("{field} override must be positive, got {v}"),
                );
            }
        }
        if compute_tflops.is_finite() && *compute_tflops > 0.0 {
            let flops = compute_tflops * 1e12;
            let checks = [
                ("power_w", power_w, chip::costpower_estimate_w(flops), "W"),
                ("price_usd", price_usd, chip::costpower_estimate_usd(flops), "$"),
            ];
            for (field, v, est, unit) in checks {
                let Some(v) = v.filter(|v| v.is_finite() && *v > 0.0) else { continue };
                let ratio = (v / est).max(est / v);
                if ratio > OUTLIER_RATIO {
                    r.warning(
                        "DF-S004",
                        ctx.as_str(),
                        format!(
                            "{field} override {v:.0} {unit} is {ratio:.0}x off the Fig. 9 \
                             regression estimate ({est:.0} {unit}) for {compute_tflops:.0} \
                             TFLOPS; cost/power efficiency axes will be skewed"
                        ),
                    );
                }
            }
        }
    }
    for m in &e.mems {
        let overrides = [("bandwidth_gbs", m.bandwidth_gbs), ("capacity_gb", m.capacity_gb)];
        for (field, v) in overrides {
            let Some(v) = v else { continue };
            if !(v.is_finite() && v > 0.0) {
                r.error(
                    "DF-S001",
                    format!("explore memory '{}'", m.name),
                    format!("{field} override must be positive, got {v}"),
                );
            }
        }
    }
    for (i, &c) in e.chip_counts.iter().enumerate() {
        if c == 0 {
            r.error("DF-S001", format!("chip_counts[{i}]"), "chip counts must be >= 1");
        }
    }
}

/// Overrides more than this factor off the Fig. 9 estimate draw DF-S004.
/// The catalog's own worst case (H100 at ~14x the regression) stays
/// comfortably inside, so only genuinely implausible overrides warn.
const OUTLIER_RATIO: f64 = 30.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scenarios_are_clean() {
        for s in [Scenario::llm("gpt3-175b"), Scenario::llama("8b"), Scenario::hpl()] {
            let r = lint_scenario(&s);
            assert!(r.is_clean(), "{:?}: {:?}", s.goal, r.diags);
        }
    }

    #[test]
    fn zero_topology_dim_is_s001_not_a_panic() {
        let mut s = Scenario::llm("gpt3-175b");
        s.system.topology.dims = vec![0];
        let r = lint_scenario(&s);
        assert_eq!(r.codes(), vec!["DF-S001"], "{:?}", r.diags);
        assert!(r.has_errors());
    }

    #[test]
    fn dims_vs_chips_contradiction_is_s003() {
        let mut s = Scenario::llm("gpt3-175b");
        s.system.topology.dims = vec![4, 4];
        s.system.topology.chips = Some(32);
        let r = lint_scenario(&s);
        assert_eq!(r.codes(), vec!["DF-S003"], "{:?}", r.diags);
    }

    #[test]
    fn inverted_hierarchy_is_a_warning_only() {
        let s = Scenario::llm("gpt3-175b").on(SystemCfg::new("h100", "ddr4", "nvlink4").ring(8));
        let r = lint_scenario(&s);
        assert_eq!(r.codes(), vec!["DF-S002"], "{:?}", r.diags);
        assert!(!r.has_errors());
        assert_eq!(r.n_warnings(), 1);
    }

    #[test]
    fn forced_degree_mismatch_is_m001() {
        let s = Scenario::llm("gpt3-175b").forced(4, 1, 1);
        let r = lint_scenario(&s);
        assert_eq!(r.codes(), vec!["DF-M001"], "{:?}", r.diags);
    }

    #[test]
    fn serving_split_message_names_the_split_and_group() {
        let s = Scenario::llama("8b").serving_split(5, 2);
        let r = lint_scenario(&s);
        assert_eq!(r.codes(), vec!["DF-M002"]);
        let msg = &r.diags[0].message;
        assert!(msg.contains("TP5") && msg.contains("PP2") && msg.contains("16-chip"), "{msg}");
    }

    #[test]
    fn kv_overflow_is_m003() {
        let mut s = Scenario::llama("405b");
        s.serving.batch = 512.0;
        s.serving.context = 131_072.0;
        let r = lint_scenario(&s);
        assert_eq!(r.codes(), vec!["DF-M003"], "{:?}", r.diags);
    }

    #[test]
    fn unknown_chip_falls_through_to_c001() {
        let mut s = Scenario::llm("gpt3-175b");
        s.system.chip = "h1000".into();
        let r = lint_scenario(&s);
        assert_eq!(r.codes(), vec!["DF-C001"], "{:?}", r.diags);
        assert!(r.diags[0].message.contains("h1000"));
    }

    #[test]
    fn report_counts_and_summary() {
        let mut r = LintReport::default();
        assert!(r.is_clean());
        assert_eq!(r.summary(), "clean");
        r.warning("DF-S002", "system", "w");
        r.error("DF-S001", "topology", "e");
        assert_eq!((r.n_errors(), r.n_warnings()), (1, 1));
        assert!(r.has_errors() && !r.is_clean());
        assert_eq!(r.summary(), "1 error(s), 1 warning(s)");
        let j = r.to_json();
        assert_eq!(j.get("errors").and_then(|v| v.as_usize()), Some(1));
        assert!(r.render().contains("error[DF-S001] topology: e"));
    }

    #[test]
    fn lint_json_dispatches_on_graph_key() {
        let g = Json::parse(r#"{"graph": {"kernels": [], "tensors": []}}"#).unwrap();
        let r = lint_json(&g);
        assert_eq!(r.codes(), vec!["DF-G001"]);
        let s = Json::parse(r#"{"system": {"chip": "zz80"}}"#).unwrap();
        let r = lint_json(&s);
        assert_eq!(r.codes(), vec!["DF-C001"]);
    }

    #[test]
    fn explore_outlier_override_is_s004() {
        let mut s = Scenario::llm("gpt3-175b").explore(ExploreOptions::default());
        s.explore.chips.push(ChipCfg::Custom {
            name: "hot".into(),
            compute_tflops: 2000.0,
            sram_mb: 256.0,
            dataflow: false,
            tiles: None,
            power_w: Some(5.0),
            price_usd: None,
        });
        let r = lint_scenario(&s);
        assert_eq!(r.codes(), vec!["DF-S004"], "{:?}", r.diags);
        assert!(!r.has_errors());
    }

    #[test]
    fn catalog_chips_pass_the_outlier_threshold() {
        // the regression floor puts H100 ~14x over the estimate; the 30x
        // threshold must not flag any real Table V chip
        for c in crate::system::chip::table_v() {
            let est = chip::costpower_estimate_w(c.compute_flops().raw());
            let p = c.power_w.raw();
            let ratio = (p / est).max(est / p);
            assert!(ratio <= OUTLIER_RATIO, "{}: {ratio:.1}x", c.name);
        }
    }
}
