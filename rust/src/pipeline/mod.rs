//! Whole-workload performance composition: the inter-chip mapping ((2) in
//! Fig. 1) feeds the intra-chip pass ((3)), and the combined mapping gives
//! iteration time, throughput utilization, and the compute/memory/network
//! latency breakdown the DSE heat maps report.

use crate::graph::gpt::{gpt_layer_graph, GptConfig};
use crate::graph::DataflowGraph;
use crate::interchip::{self, InterChipOptions};
use crate::intrachip::{self, IntraChipOptions};
use crate::sharding;
use crate::system::SystemSpec;
use crate::util::units::{Bytes, Seconds};

/// Summary of the mapping decisions behind a [`StepResult`], surfaced by
/// the `api` facade's `Mapping` type.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MappingSummary {
    /// (kernel name, chosen sharding scheme name) on the optimized graph
    /// (the fine layer graph for LLM training, the whole graph otherwise).
    pub schemes: Vec<(String, String)>,
    /// Pipeline stages of the inter-chip pass.
    pub n_stages: usize,
    /// Fused partitions of the intra-chip pass.
    pub n_partitions: usize,
}

/// (kernel name, scheme name) pairs for a chosen sharding.
fn scheme_names(g: &DataflowGraph, scheme_idx: &[usize], tp: usize) -> Vec<(String, String)> {
    g.kernels
        .iter()
        .enumerate()
        .map(|(i, k)| {
            let schemes = sharding::schemes_for(&k.kind, tp);
            (k.name.clone(), schemes[scheme_idx[i]].name.to_string())
        })
        .collect()
}

/// Result of evaluating one workload on one system design point.
///
/// This is a *reporting boundary*: every field is a raw `f64` (seconds,
/// FLOP, FLOP/s) so it can flow straight into JSON reports and figure
/// tables. Typed quantities are flattened with `.raw()` on the way in.
#[derive(Debug, Clone)]
pub struct StepResult {
    /// Wall-clock of one training iteration / one solve (seconds).
    pub step_time: f64,
    /// Useful FLOP per step (algorithmic, not hardware-inflated).
    pub useful_flops: f64,
    /// Achieved / peak throughput of the whole system.
    pub utilization: f64,
    /// Absolute achieved FLOP/s.
    pub achieved_flops: f64,
    /// (compute, memory, network) seconds attributed per iteration.
    pub breakdown: (f64, f64, f64),
    /// The chosen parallelism degrees.
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
    /// Sharding/stage/fusion decisions behind the numbers.
    pub mapping: MappingSummary,
}

impl StepResult {
    /// Fractional latency breakdown (sums to 1).
    pub fn breakdown_frac(&self) -> (f64, f64, f64) {
        let (c, m, n) = self.breakdown;
        let t = (c + m + n).max(1e-30);
        (c / t, m / t, n / t)
    }
}

/// LLM training evaluation (GPT family): coarse inter-chip optimization
/// over layers, fine intra-chip optimization of the sharded layer, pipeline
/// + data-parallel composition.
///
/// `global_batch` in sequences; microbatch is 1 sequence (Megatron-style).
pub fn llm_training(
    cfg: &GptConfig,
    sys: &SystemSpec,
    global_batch: f64,
) -> Option<StepResult> {
    llm_training_opts(cfg, sys, global_batch, &InterChipOptions::default())
}

/// `llm_training` with caller-controlled inter-chip options (e.g. the §VIII-C
/// study keeps only bf16 weights resident: state factor 2). The caller's
/// `max_pp`/`max_dp` act as caps on the model-derived bounds (layers /
/// global batch), so facade knobs tighten rather than vanish.
pub fn llm_training_opts(
    cfg: &GptConfig,
    sys: &SystemSpec,
    global_batch: f64,
    base_opts: &InterChipOptions,
) -> Option<StepResult> {
    let micro_batch = 1.0;
    let coarse = crate::graph::gpt::gpt_coarse_graph(cfg, micro_batch);
    let inter_opts = InterChipOptions {
        max_pp: base_opts.max_pp.min(cfg.layers),
        max_dp: base_opts.max_dp.min(global_batch as usize),
        ..base_opts.clone()
    };
    let inter = {
        let _s = crate::obs::span("interchip");
        interchip::optimize(&coarse, sys, &inter_opts)?
    };
    llm_training_with_mapping(cfg, sys, global_batch, &coarse, &inter)
}

/// As `llm_training` but with a caller-chosen inter-chip mapping (§VII
/// forced-degree studies).
pub fn llm_training_forced(
    cfg: &GptConfig,
    sys: &SystemSpec,
    global_batch: f64,
    degrees: (usize, usize, usize),
) -> Option<StepResult> {
    let coarse = crate::graph::gpt::gpt_coarse_graph(cfg, 1.0);
    let inter_opts = InterChipOptions {
        max_pp: cfg.layers,
        max_dp: global_batch as usize,
        force_degrees: Some(degrees),
        ..Default::default()
    };
    let inter = {
        let _s = crate::obs::span("interchip");
        interchip::optimize(&coarse, sys, &inter_opts)?
    };
    llm_training_with_mapping(cfg, sys, global_batch, &coarse, &inter)
}

fn llm_training_with_mapping(
    cfg: &GptConfig,
    sys: &SystemSpec,
    global_batch: f64,
    _coarse: &DataflowGraph,
    inter: &interchip::InterChipMapping,
) -> Option<StepResult> {
    let (tp, pp, dp) = (inter.plan.tp, inter.plan.pp, inter.plan.dp);
    // layers in the busiest stage
    let mut stage_layers = vec![0usize; inter.stages.len()];
    for &s in &inter.stage_of {
        stage_layers[s] += 1;
    }
    let max_layers = stage_layers.iter().copied().max().unwrap_or(cfg.layers);

    // fine-grained intra-chip optimization on one TP-sharded layer:
    // re-run sharding selection on the fine layer graph under the SAME plan
    // (its TP dims), then shard per-chip quantities. The fine microbatch is
    // raised until batch×heads ≥ tp so attention head-sharding stays
    // expressible at large TP (Megatron's heads-divisibility rule);
    // per-layer time is normalized back per microbatch.
    let m_fine = ((tp as f64 / cfg.n_heads).ceil()).max(1.0);
    let span_intra = crate::obs::span("intrachip");
    let fine = gpt_layer_graph(cfg, m_fine);
    let fine_plan = inter.plan.clone();
    let (fine_schemes, _space) = interchip::optimizer::select_sharding(
        &fine,
        sys,
        &fine_plan,
        &InterChipOptions::default(),
    );
    let (sharded, net_time) = interchip::shard_graph(&fine, sys, &fine_plan, &fine_schemes);
    let intra = intrachip::optimize_intra(
        &sharded,
        &sys.chip,
        &sys.memory,
        &IntraChipOptions { net_time, ..Default::default() },
    )?;
    drop(span_intra);
    let _span_dp = crate::obs::span("pipeline_dp");

    // per-microbatch stage time: fused-partition pipeline over the stage's
    // layers, bottlenecked by inter-chip p2p if present
    let per_layer = intra.total_time / m_fine;
    let stage_time = (per_layer * max_layers as f64)
        .max(inter.stages.iter().map(|s| s.t_p2p.raw()).fold(0.0, f64::max));

    // pipeline fill: m microbatches per replica; fwd+bwd = 3x compute
    let micro_per_replica = (global_batch / dp as f64).max(1.0);
    let fwd = (micro_per_replica + pp as f64 - 1.0) * stage_time;
    let mut step = 3.0 * fwd;

    // data-parallel gradient all-reduce over the DP dims (overlappable with
    // the backward pass; only the excess is exposed)
    let mut dp_exposed = 0.0;
    if dp > 1 {
        let dp_dims = inter.plan.dp_dims_ref(&sys.topology);
        let grad_bytes = cfg.params() * cfg.dtype_bytes / (tp as f64 * pp as f64);
        let t_dp = sys
            .collective_model
            .time_hier(crate::collective::Collective::AllReduce, Bytes::new(grad_bytes), &dp_dims)
            .raw();
        let bwd = 2.0 * fwd;
        dp_exposed = (t_dp - bwd).max(0.0);
        step += dp_exposed;
    }

    if crate::explain::enabled() {
        let comp = crate::explain::attribution::StepComposition {
            step,
            bubble: 3.0 * (pp as f64 - 1.0) * stage_time,
            dp_exposed,
            intra_fraction: (per_layer * max_layers as f64 / stage_time.max(1e-30)).min(1.0),
        };
        crate::explain::attribution::record_map(&sharded, &intra, sys, &comp);
        interchip::optimizer::audit_sharding(&fine, sys, &fine_plan, &fine_schemes);
        crate::explain::ledger::record_pipeline_stages(&inter.stages, &inter.stage_of);
    }

    let tokens = global_batch * cfg.seq;
    let useful = cfg.train_flops_per_token() * tokens;
    let achieved = useful / step;
    let peak = sys.peak_flops().raw();

    // breakdown scaled from the per-layer intra metrics (+ inter-chip p2p
    // as network)
    let (c, m, n) = intra.breakdown();
    let scale = step / per_layer.max(1e-30) / (max_layers as f64).max(1.0);
    let _ = scale;
    let tot = (c + m + n).max(1e-30);
    let breakdown = (step * c / tot, step * m / tot, step * n / tot);

    crate::obs::counter("pipeline.evaluations", 1);
    crate::obs::observe_seconds("pipeline.step_seconds", Seconds::new(step));

    Some(StepResult {
        step_time: step,
        useful_flops: useful,
        utilization: achieved / peak,
        achieved_flops: achieved,
        breakdown,
        tp,
        pp,
        dp,
        mapping: MappingSummary {
            schemes: scheme_names(&fine, &fine_schemes, tp),
            n_stages: inter.stages.len(),
            n_partitions: intra.assignment.n_used(),
        },
    })
}

/// Generic single-pass workload evaluation (DLRM iteration, HPL solve,
/// FFT transform): inter-chip optimization of the whole graph, intra-chip
/// refinement of the per-chip shard, `passes`× the compute (e.g. 3 for
/// fwd+bwd training).
pub fn workload_pass(
    g: &DataflowGraph,
    sys: &SystemSpec,
    passes: f64,
    max_dp: usize,
) -> Option<StepResult> {
    let inter_opts =
        InterChipOptions { max_dp, state_bytes_per_weight_byte: 2.0, ..Default::default() };
    workload_pass_opts(g, sys, passes, &inter_opts)
}

/// `workload_pass` with caller-controlled inter-chip options (the facade's
/// forced-degree / state-bytes knobs for non-GPT workloads).
pub fn workload_pass_opts(
    g: &DataflowGraph,
    sys: &SystemSpec,
    passes: f64,
    inter_opts: &InterChipOptions,
) -> Option<StepResult> {
    let inter = {
        let _s = crate::obs::span("interchip");
        interchip::optimize(g, sys, inter_opts)?
    };
    let (tp, pp, dp) = (inter.plan.tp, inter.plan.pp, inter.plan.dp);

    let span_intra = crate::obs::span("intrachip");
    let (sharded, net_time) = interchip::shard_graph(g, sys, &inter.plan, &inter.scheme_idx);
    let intra = intrachip::optimize_intra(
        &sharded,
        &sys.chip,
        &sys.memory,
        &IntraChipOptions { net_time, ..Default::default() },
    )?;
    drop(span_intra);
    let _span_dp = crate::obs::span("pipeline_dp");

    let stage_time = intra
        .total_time
        .max(inter.stages.iter().map(|s| s.t_p2p.raw()).fold(0.0, f64::max));
    let step = passes * stage_time * pp as f64 / pp as f64 * (pp as f64); // fill + drain ≈ pp stages sequential for one pass
    let step = if pp > 1 { step } else { passes * stage_time };

    if crate::explain::enabled() {
        // one pass works for `passes * stage_time`; the other (pp-1)
        // sequential stages of the fill/drain approximation are bubble
        let comp = crate::explain::attribution::StepComposition {
            step,
            bubble: if pp > 1 { passes * stage_time * (pp as f64 - 1.0) } else { 0.0 },
            dp_exposed: 0.0,
            intra_fraction: (intra.total_time / stage_time.max(1e-30)).min(1.0),
        };
        crate::explain::attribution::record_map(&sharded, &intra, sys, &comp);
        interchip::optimizer::audit_sharding(g, sys, &inter.plan, &inter.scheme_idx);
        crate::explain::ledger::record_pipeline_stages(&inter.stages, &inter.stage_of);
    }

    let useful = passes * g.total_flops() / dp as f64 * dp as f64;
    let achieved = useful / step;
    let (c, m, n) = intra.breakdown();
    let tot = (c + m + n).max(1e-30);
    crate::obs::counter("pipeline.evaluations", 1);
    crate::obs::observe_seconds("pipeline.step_seconds", Seconds::new(step));
    Some(StepResult {
        step_time: step,
        useful_flops: useful,
        utilization: achieved / sys.peak_flops().raw(),
        achieved_flops: achieved,
        breakdown: (step * c / tot, step * m / tot, step * n / tot),
        tp,
        pp,
        dp,
        mapping: MappingSummary {
            schemes: scheme_names(g, &inter.scheme_idx, tp),
            n_stages: inter.stages.len(),
            n_partitions: intra.assignment.n_used(),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gpt::gpt3_175b;
    use crate::system::{chip, interconnect, memory, topology, SystemSpec};

    fn rdu_system(n: usize) -> SystemSpec {
        let link = interconnect::pcie4();
        SystemSpec::new(chip::sn10(), memory::ddr4(), link.clone(), topology::ring(n, &link))
    }

    #[test]
    fn llm_training_utilization_sane() {
        let cfg = gpt3_175b();
        let sys = rdu_system(8);
        let r = llm_training(&cfg, &sys, 64.0).expect("feasible");
        assert!(r.utilization > 0.01 && r.utilization <= 1.0, "util = {}", r.utilization);
        assert!(r.step_time > 0.0);
        assert_eq!(r.tp * r.pp * r.dp, 8);
    }

    #[test]
    fn dataflow_chip_beats_kernel_by_kernel_chip_on_llm() {
        // the §VI-C headline: RDUs (dataflow) achieve higher utilization
        // than a kernel-by-kernel chip with identical paper specs
        let cfg = gpt3_175b();
        let link = interconnect::pcie4();
        let mut kbk_chip = chip::sn10();
        kbk_chip.execution = crate::system::ExecutionModel::KernelByKernel;
        let df_sys = rdu_system(8);
        let kbk_sys = SystemSpec::new(
            kbk_chip,
            memory::ddr4(),
            link.clone(),
            topology::ring(8, &link),
        );
        let df = llm_training(&cfg, &df_sys, 64.0).unwrap();
        let kbk = llm_training(&cfg, &kbk_sys, 64.0).unwrap();
        assert!(
            df.utilization > kbk.utilization,
            "dataflow {} <= kbk {}",
            df.utilization,
            kbk.utilization
        );
    }

    #[test]
    fn forced_degrees_respected() {
        let cfg = gpt3_175b();
        let sys = rdu_system(8);
        let r = llm_training_forced(&cfg, &sys, 64.0, (8, 1, 1)).unwrap();
        assert_eq!((r.tp, r.pp, r.dp), (8, 1, 1));
    }

    #[test]
    fn workload_pass_runs_fft() {
        let g = crate::graph::fft::fft_graph(&crate::graph::fft::fft_1t());
        let link = interconnect::nvlink4();
        let sys = SystemSpec::new(
            chip::h100(),
            memory::hbm3(),
            link.clone(),
            topology::torus2d(32, 32, &link),
        );
        let r = workload_pass(&g, &sys, 1.0, 1).expect("feasible");
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
    }

    #[test]
    fn breakdown_fracs_sum_to_one() {
        let cfg = gpt3_175b();
        let sys = rdu_system(8);
        let r = llm_training(&cfg, &sys, 64.0).unwrap();
        let (c, m, n) = r.breakdown_frac();
        assert!((c + m + n - 1.0).abs() < 1e-9);
    }
}
