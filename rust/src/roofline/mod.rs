//! Hierarchical roofline analysis (Fig. 18, after Williams et al. [80]):
//! a mapping has two operational intensities — FLOP per DRAM byte and FLOP
//! per network byte — and its achieved throughput is capped by peak
//! compute, the memory roof OI_mem × d_bw, and the network roof
//! OI_net × n_bw. Both OIs share one achieved-throughput point.

use crate::system::SystemSpec;
use crate::util::units::{Bytes, BytesPerSec, Flop, FlopPerSec, Seconds};

/// One mapping's position on the hierarchical roofline.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub name: String,
    /// FLOP per DRAM byte.
    pub oi_mem: f64,
    /// FLOP per network byte.
    pub oi_net: f64,
    /// Modeled achieved FLOP/s (per chip).
    pub achieved: f64,
}

/// Which roof binds a mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
    Network,
}

/// Per-chip roofline model.
#[derive(Debug, Clone)]
pub struct Roofline {
    pub peak_flops: FlopPerSec,
    pub mem_bw: BytesPerSec,
    pub net_bw: BytesPerSec,
}

impl Roofline {
    pub fn of_system(sys: &SystemSpec) -> Self {
        Roofline {
            peak_flops: sys.chip.compute_flops(),
            mem_bw: sys.memory.bandwidth,
            net_bw: sys.link.bandwidth,
        }
    }

    /// Attainable FLOP/s at the given operational intensities. The OIs are
    /// dimensionless FLOP-per-byte ratios, so the roof products go through
    /// the raw-`f64` escape hatch (OI × bandwidth = compute rate).
    pub fn attainable(&self, oi_mem: f64, oi_net: f64) -> FlopPerSec {
        self.peak_flops
            .min(FlopPerSec::new(oi_mem * self.mem_bw.raw()))
            .min(FlopPerSec::new(oi_net * self.net_bw.raw()))
    }

    /// Which roof binds at these intensities.
    pub fn bound(&self, oi_mem: f64, oi_net: f64) -> Bound {
        let mem = FlopPerSec::new(oi_mem * self.mem_bw.raw());
        let net = FlopPerSec::new(oi_net * self.net_bw.raw());
        if self.peak_flops <= mem && self.peak_flops <= net {
            Bound::Compute
        } else if mem <= net {
            Bound::Memory
        } else {
            Bound::Network
        }
    }

    /// Build a point from a mapping's totals (per chip, per input). The
    /// resulting OIs and achieved rate are raw `f64`s (reporting boundary).
    pub fn point(&self, name: &str, flops: Flop, dram_bytes: Bytes, net_bytes: Bytes, time: Seconds)
        -> RooflinePoint
    {
        let flops = flops.raw();
        let (dram_bytes, net_bytes) = (dram_bytes.raw(), net_bytes.raw());
        let oi_mem = if dram_bytes > 0.0 { flops / dram_bytes } else { f64::INFINITY };
        let oi_net = if net_bytes > 0.0 { flops / net_bytes } else { f64::INFINITY };
        RooflinePoint { name: name.into(), oi_mem, oi_net, achieved: flops / time.raw() }
    }

    /// Ridge OI (memory): where the memory roof meets peak (dimensionless
    /// FLOP/byte).
    pub fn ridge_mem(&self) -> f64 {
        self.peak_flops.raw() / self.mem_bw.raw()
    }

    pub fn ridge_net(&self) -> f64 {
        self.peak_flops.raw() / self.net_bw.raw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rl() -> Roofline {
        Roofline {
            peak_flops: FlopPerSec::new(300e12),
            mem_bw: BytesPerSec::new(200e9),
            net_bw: BytesPerSec::new(25e9),
        }
    }

    #[test]
    fn attainable_min_of_roofs() {
        let r = rl();
        // low OI: memory-bound
        assert_eq!(r.attainable(10.0, 1e9).raw(), 10.0 * 200e9);
        // low net OI: network-bound
        assert_eq!(r.attainable(1e9, 100.0).raw(), 100.0 * 25e9);
        // both high: compute-bound
        assert_eq!(r.attainable(1e9, 1e9).raw(), 300e12);
    }

    #[test]
    fn bound_classification() {
        let r = rl();
        assert_eq!(r.bound(1.0, 1e9), Bound::Memory);
        assert_eq!(r.bound(1e9, 1.0), Bound::Network);
        assert_eq!(r.bound(1e9, 1e9), Bound::Compute);
    }

    #[test]
    fn ridge_points() {
        let r = rl();
        assert_eq!(r.ridge_mem(), 1500.0);
        assert_eq!(r.ridge_net(), 12000.0);
    }

    #[test]
    fn point_construction() {
        let r = rl();
        let p = r.point("m", Flop::new(1e12), Bytes::new(1e9), Bytes::new(1e8), Seconds::new(0.01));
        assert_eq!(p.oi_mem, 1000.0);
        assert_eq!(p.oi_net, 10000.0);
        assert_eq!(p.achieved, 1e14);
        // achieved can never exceed attainable by construction of the model
        assert!(p.achieved <= r.attainable(p.oi_mem, p.oi_net).raw() * 1.67);
    }
}
