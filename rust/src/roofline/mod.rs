//! Hierarchical roofline analysis (Fig. 18, after Williams et al. [80]):
//! a mapping has two operational intensities — FLOP per DRAM byte and FLOP
//! per network byte — and its achieved throughput is capped by peak
//! compute, the memory roof OI_mem × d_bw, and the network roof
//! OI_net × n_bw. Both OIs share one achieved-throughput point.

use crate::system::SystemSpec;

/// One mapping's position on the hierarchical roofline.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub name: String,
    /// FLOP per DRAM byte.
    pub oi_mem: f64,
    /// FLOP per network byte.
    pub oi_net: f64,
    /// Modeled achieved FLOP/s (per chip).
    pub achieved: f64,
}

/// Which roof binds a mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
    Network,
}

/// Per-chip roofline model.
#[derive(Debug, Clone)]
pub struct Roofline {
    pub peak_flops: f64,
    pub mem_bw: f64,
    pub net_bw: f64,
}

impl Roofline {
    pub fn of_system(sys: &SystemSpec) -> Self {
        Roofline {
            peak_flops: sys.chip.compute_flops(),
            mem_bw: sys.memory.bandwidth,
            net_bw: sys.link.bandwidth,
        }
    }

    /// Attainable FLOP/s at the given operational intensities.
    pub fn attainable(&self, oi_mem: f64, oi_net: f64) -> f64 {
        self.peak_flops.min(oi_mem * self.mem_bw).min(oi_net * self.net_bw)
    }

    /// Which roof binds at these intensities.
    pub fn bound(&self, oi_mem: f64, oi_net: f64) -> Bound {
        let mem = oi_mem * self.mem_bw;
        let net = oi_net * self.net_bw;
        if self.peak_flops <= mem && self.peak_flops <= net {
            Bound::Compute
        } else if mem <= net {
            Bound::Memory
        } else {
            Bound::Network
        }
    }

    /// Build a point from a mapping's totals (per chip, per input).
    pub fn point(&self, name: &str, flops: f64, dram_bytes: f64, net_bytes: f64, time: f64)
        -> RooflinePoint
    {
        let oi_mem = if dram_bytes > 0.0 { flops / dram_bytes } else { f64::INFINITY };
        let oi_net = if net_bytes > 0.0 { flops / net_bytes } else { f64::INFINITY };
        RooflinePoint { name: name.into(), oi_mem, oi_net, achieved: flops / time }
    }

    /// Ridge OI (memory): where the memory roof meets peak.
    pub fn ridge_mem(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    pub fn ridge_net(&self) -> f64 {
        self.peak_flops / self.net_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rl() -> Roofline {
        Roofline { peak_flops: 300e12, mem_bw: 200e9, net_bw: 25e9 }
    }

    #[test]
    fn attainable_min_of_roofs() {
        let r = rl();
        // low OI: memory-bound
        assert_eq!(r.attainable(10.0, 1e9), 10.0 * 200e9);
        // low net OI: network-bound
        assert_eq!(r.attainable(1e9, 100.0), 100.0 * 25e9);
        // both high: compute-bound
        assert_eq!(r.attainable(1e9, 1e9), 300e12);
    }

    #[test]
    fn bound_classification() {
        let r = rl();
        assert_eq!(r.bound(1.0, 1e9), Bound::Memory);
        assert_eq!(r.bound(1e9, 1.0), Bound::Network);
        assert_eq!(r.bound(1e9, 1e9), Bound::Compute);
    }

    #[test]
    fn ridge_points() {
        let r = rl();
        assert_eq!(r.ridge_mem(), 1500.0);
        assert_eq!(r.ridge_net(), 12000.0);
    }

    #[test]
    fn point_construction() {
        let r = rl();
        let p = r.point("m", 1e12, 1e9, 1e8, 0.01);
        assert_eq!(p.oi_mem, 1000.0);
        assert_eq!(p.oi_net, 10000.0);
        assert_eq!(p.achieved, 1e14);
        // achieved can never exceed attainable by construction of the model
        assert!(p.achieved <= r.attainable(p.oi_mem, p.oi_net) * 1.67);
    }
}
