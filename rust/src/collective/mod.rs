//! Analytical collective-communication cost models (§IV-B.2), adapted from
//! Thakur et al. [77] and BlueConnect [19], parameterized by the 1-D
//! topology kind of each network dimension and composed hierarchically the
//! ASTRA-sim way [71]: a collective over several dims runs phase-by-phase
//! with per-phase shrinking payloads (reduce-scatter down, all-gather up).
//!
//! Conventions: `bytes` is the per-chip buffer size S; returned times are
//! seconds = bandwidth term + latency (α) term.

use std::collections::HashMap;

use crate::system::topology::{Dim, DimFabric, DimKind};
use crate::util::units::{Bytes, Seconds};

/// Collective operations DFModel's sharding strategies emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    AllReduce,
    AllGather,
    ReduceScatter,
    Broadcast,
    AllToAll,
    /// Point-to-point between adjacent pipeline stages.
    P2P,
}

/// Time for `coll` over one network dimension. The per-chip buffer is a
/// typed [`Bytes`] quantity and the result a typed [`Seconds`] — the α-β
/// formulas below only type-check because `Bytes / BytesPerSec = Seconds`.
pub fn time(coll: Collective, bytes: Bytes, dim: &Dim) -> Seconds {
    let k = dim.size as f64;
    if dim.size <= 1 || bytes <= Bytes::ZERO {
        return Seconds::ZERO;
    }
    let b = dim.link_bw;
    let a = dim.latency;
    let frac = (k - 1.0) / k;
    match (coll, dim.kind) {
        // ---- ring (pipelined chunked algorithms) ----
        (Collective::AllReduce, DimKind::Ring) => 2.0 * frac * bytes / b + 2.0 * (k - 1.0) * a,
        (Collective::AllGather, DimKind::Ring)
        | (Collective::ReduceScatter, DimKind::Ring)
        | (Collective::Broadcast, DimKind::Ring) => frac * bytes / b + (k - 1.0) * a,
        // bidirectional ring bisection limits all-to-all: average hop k/4
        (Collective::AllToAll, DimKind::Ring) => bytes * k / (4.0 * b) + (k - 1.0) * a,
        (Collective::P2P, DimKind::Ring) => bytes / b + a,

        // ---- fully connected (direct algorithms use all k−1 links) ----
        (Collective::AllReduce, DimKind::FullyConnected) => 2.0 * bytes / (k * b) + 2.0 * a,
        (Collective::AllGather, DimKind::FullyConnected)
        | (Collective::ReduceScatter, DimKind::FullyConnected) => bytes / (k * b) + a,
        (Collective::Broadcast, DimKind::FullyConnected) => 2.0 * bytes / (k * b) + 2.0 * a,
        (Collective::AllToAll, DimKind::FullyConnected) => bytes / (k * b) + a,
        (Collective::P2P, DimKind::FullyConnected) => bytes / b + a,

        // ---- switch (non-blocking crossbar, node-port limited) ----
        (Collective::AllReduce, DimKind::Switch) => 2.0 * frac * bytes / b + 2.0 * a,
        (Collective::AllGather, DimKind::Switch)
        | (Collective::ReduceScatter, DimKind::Switch) => frac * bytes / b + a,
        (Collective::Broadcast, DimKind::Switch) => bytes / b + a,
        (Collective::AllToAll, DimKind::Switch) => frac * bytes / b + a,
        (Collective::P2P, DimKind::Switch) => bytes / b + 2.0 * a,
    }
}

/// Hierarchical collective over several dims (BlueConnect decomposition).
///
/// * AllReduce: reduce-scatter down the dims with payload shrinking by each
///   dim's size, then all-gather back up — the payload seen by dim i is
///   S / Π_{j<i} k_j.
/// * AllGather / ReduceScatter / Broadcast: phase per dim with shrinking
///   (resp. growing) payloads.
/// * AllToAll: payload stays S per phase (every chip still exchanges its
///   full buffer within each dim).
pub fn time_hier(coll: Collective, bytes: Bytes, dims: &[&Dim]) -> Seconds {
    let active: Vec<&Dim> = dims.iter().copied().filter(|d| d.size > 1).collect();
    if active.is_empty() || bytes <= Bytes::ZERO {
        return Seconds::ZERO;
    }
    match coll {
        Collective::AllReduce => {
            let mut t = Seconds::ZERO;
            let mut payload = bytes;
            // reduce-scatter down
            for d in &active {
                t += time(Collective::ReduceScatter, payload, d);
                payload /= d.size as f64;
            }
            // all-gather up
            for d in active.iter().rev() {
                payload *= d.size as f64;
                t += time(Collective::AllGather, payload, d);
            }
            t
        }
        Collective::ReduceScatter => {
            let mut t = Seconds::ZERO;
            let mut payload = bytes;
            for d in &active {
                t += time(Collective::ReduceScatter, payload, d);
                payload /= d.size as f64;
            }
            t
        }
        Collective::AllGather => {
            let total: f64 = active.iter().map(|d| d.size as f64).product();
            let mut payload = bytes / total;
            let mut t = Seconds::ZERO;
            for d in active.iter().rev() {
                payload *= d.size as f64;
                t += time(Collective::AllGather, payload, d);
            }
            t
        }
        Collective::Broadcast => {
            active.iter().map(|d| time(Collective::Broadcast, bytes, d)).sum()
        }
        Collective::AllToAll => {
            active.iter().map(|d| time(Collective::AllToAll, bytes, d)).sum()
        }
        Collective::P2P => {
            // one hop through the slowest dim on the path
            active
                .iter()
                .map(|d| time(Collective::P2P, bytes, d))
                .fold(Seconds::ZERO, Seconds::max)
        }
    }
}

/// Effective chips participating across dims.
pub fn group_size(dims: &[&Dim]) -> usize {
    dims.iter().map(|d| d.size).product()
}

// ---------------------------------------------------------------------------
// Calibrated collective model (fed by `fabric::select::calibrate`).
// ---------------------------------------------------------------------------

/// Canonical key of a dim group: the sorted multiset of (wiring code, size,
/// link-bandwidth bits, link-latency bits) over the active (size > 1) dims.
/// Congruent dims of one topology share a key and dim order does not
/// matter, so a calibration built from one subgroup applies to every
/// congruent subgroup — while same-shaped dims on *different* link
/// technologies never alias.
pub type DimsKey = Vec<(u8, usize, u64, u64)>;

/// Key for a dim slice (see [`DimsKey`]).
pub fn dims_key(dims: &[&Dim]) -> DimsKey {
    let mut key: DimsKey = dims
        .iter()
        .filter(|d| d.size > 1)
        .map(|d| {
            let kind = match d.kind {
                DimKind::Ring => 0u8,
                DimKind::FullyConnected => 1,
                DimKind::Switch => 2,
            };
            let code = if d.fabric == DimFabric::CubeMesh { kind + 4 } else { kind };
            (code, d.size, d.link_bw.to_bits(), d.latency.to_bits())
        })
        .collect();
    key.sort_unstable();
    key
}

/// One calibration breakpoint: simulated / analytical time at `bytes`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalPoint {
    pub bytes: f64,
    pub ratio: f64,
}

/// Fabric-simulation calibration table: per (collective, dim-group key),
/// ratio breakpoints over payload size. Lookups interpolate the ratio
/// linearly in log-payload and clamp beyond the calibrated range, so the
/// calibrated time inherits the analytical model's shape between (and
/// outside) breakpoints.
#[derive(Debug, Clone, Default)]
pub struct Calibration {
    /// Per dim-group key, a small (collective → breakpoints) list — keyed
    /// this way so lookups borrow the caller's key instead of cloning it
    /// (the optimizer queries this on its inner sharding loops).
    points: HashMap<DimsKey, Vec<(Collective, Vec<CalPoint>)>>,
}

impl Calibration {
    pub fn insert(&mut self, coll: Collective, key: DimsKey, mut pts: Vec<CalPoint>) {
        pts.retain(|p| p.bytes > 0.0 && p.ratio.is_finite() && p.ratio > 0.0);
        pts.sort_by(|a, b| a.bytes.total_cmp(&b.bytes));
        if pts.is_empty() {
            return;
        }
        let slot = self.points.entry(key).or_default();
        match slot.iter().position(|(c, _)| *c == coll) {
            Some(i) => slot[i].1 = pts,
            None => slot.push((coll, pts)),
        }
    }

    /// Number of calibrated (collective, dim-group) tables.
    pub fn len(&self) -> usize {
        self.points.values().map(|v| v.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Whether any collective is calibrated for this dim-group key (lets
    /// `fabric::select::calibrate` skip congruent subsets it already swept).
    pub fn contains_key(&self, key: &DimsKey) -> bool {
        self.points.contains_key(key)
    }

    /// Simulated/analytical ratio for (coll, key) at a payload, or None if
    /// that group was never calibrated.
    pub fn ratio(&self, coll: Collective, key: &DimsKey, bytes: f64) -> Option<f64> {
        let pts = &self.points.get(key)?.iter().find(|(c, _)| *c == coll)?.1;
        let first = pts.first()?;
        if pts.len() == 1 || bytes <= first.bytes {
            return Some(first.ratio);
        }
        let last = pts.last().expect("non-empty");
        if bytes >= last.bytes {
            return Some(last.ratio);
        }
        let i = pts.partition_point(|p| p.bytes < bytes);
        let (lo, hi) = (&pts[i - 1], &pts[i]);
        let t = (bytes.ln() - lo.bytes.ln()) / (hi.bytes.ln() - lo.bytes.ln());
        Some(lo.ratio + t * (hi.ratio - lo.ratio))
    }
}

/// Which collective-cost model downstream passes (sharding selection, the
/// inter-chip optimizer, the DP gradient term) consult.
#[derive(Debug, Clone, Default)]
pub enum CollectiveModel {
    /// The closed-form α-β formulas in this module.
    #[default]
    Analytical,
    /// Analytical times rescaled by fabric-simulation ratios; groups the
    /// table does not cover fall back to analytical.
    Calibrated(Calibration),
}

impl CollectiveModel {
    /// `time_hier` under this model. The calibration table itself stays in
    /// raw `f64` payload space (a serialization-adjacent boundary), so the
    /// lookup goes through `.raw()`.
    pub fn time_hier(&self, coll: Collective, bytes: Bytes, dims: &[&Dim]) -> Seconds {
        let base = time_hier(coll, bytes, dims);
        match self {
            CollectiveModel::Analytical => base,
            CollectiveModel::Calibrated(c) => {
                base * c.ratio(coll, &dims_key(dims), bytes.raw()).unwrap_or(1.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::interconnect::{nvlink4, pcie4};
    use crate::system::topology::{Dim, DimKind};

    fn ring(k: usize) -> Dim {
        Dim::new(DimKind::Ring, k, &nvlink4())
    }
    fn fc(k: usize) -> Dim {
        Dim::new(DimKind::FullyConnected, k, &nvlink4())
    }
    fn sw(k: usize) -> Dim {
        Dim::new(DimKind::Switch, k, &nvlink4())
    }

    #[test]
    fn single_chip_is_free() {
        for coll in [
            Collective::AllReduce,
            Collective::AllGather,
            Collective::AllToAll,
            Collective::Broadcast,
        ] {
            assert_eq!(time(coll, Bytes::new(1e9), &ring(1)), Seconds::ZERO);
        }
    }

    #[test]
    fn ring_allreduce_matches_2x_bandwidth_rule() {
        let d = ring(8);
        let s = Bytes::new(1e9);
        let t = time(Collective::AllReduce, s, &d);
        let bw_term = 2.0 * (7.0 / 8.0) * s / d.link_bw;
        assert!((t - bw_term) < 16.0 * d.latency + Seconds::new(1e-12));
        assert!(t >= bw_term);
    }

    #[test]
    fn allreduce_is_twice_allgather_bandwidth() {
        let d = ring(16);
        let s = Bytes::new(1e8);
        let ar = time(Collective::AllReduce, s, &d);
        let ag = time(Collective::AllGather, s, &d);
        assert!((ar / ag - 2.0).abs() < 0.01);
    }

    #[test]
    fn fc_alltoall_beats_ring_alltoall() {
        let s = Bytes::new(1e9);
        let t_ring = time(Collective::AllToAll, s, &ring(32));
        let t_fc = time(Collective::AllToAll, s, &fc(32));
        // direct links give ~k²/4 advantage over the ring bisection
        assert!(t_ring / t_fc > 50.0, "ring {t_ring} fc {t_fc}");
    }

    #[test]
    fn switch_alltoall_between_ring_and_fc() {
        let s = Bytes::new(1e9);
        let t_ring = time(Collective::AllToAll, s, &ring(32));
        let t_sw = time(Collective::AllToAll, s, &sw(32));
        let t_fc = time(Collective::AllToAll, s, &fc(32));
        assert!(t_fc < t_sw && t_sw < t_ring);
    }

    #[test]
    fn hierarchical_allreduce_less_than_flat_ring() {
        // 1024 chips: 32×32 hierarchical vs one 1024-ring — the hierarchy
        // cuts the latency term and the second-phase payload
        let d1 = ring(32);
        let d2 = ring(32);
        let flat = ring(1024);
        let s = Bytes::new(1e9);
        let hier = time_hier(Collective::AllReduce, s, &[&d1, &d2]);
        let one = time(Collective::AllReduce, s, &flat);
        assert!(hier < one, "hier {hier} flat {one}");
    }

    #[test]
    fn hier_allreduce_on_single_dim_equals_flat() {
        let d = ring(8);
        let s = Bytes::new(1e9);
        let a = time_hier(Collective::AllReduce, s, &[&d]);
        let b = time(Collective::ReduceScatter, s, &d) + time(Collective::AllGather, s, &d);
        assert!((a - b).abs() < Seconds::new(1e-15));
    }

    #[test]
    fn slower_links_cost_more() {
        let fast = Dim::new(DimKind::Ring, 8, &nvlink4());
        let slow = Dim::new(DimKind::Ring, 8, &pcie4());
        let s = Bytes::new(1e9);
        let r = time(Collective::AllReduce, s, &slow) / time(Collective::AllReduce, s, &fast);
        // 900/25 = 36× bandwidth ratio dominates
        assert!(r > 30.0, "ratio {r}");
    }

    #[test]
    fn p2p_picks_slowest_hop() {
        let d1 = Dim::new(DimKind::Ring, 8, &nvlink4());
        let d2 = Dim::new(DimKind::Ring, 8, &pcie4());
        let t = time_hier(Collective::P2P, Bytes::new(1e6), &[&d1, &d2]);
        assert!((t - time(Collective::P2P, Bytes::new(1e6), &d2)).abs() < Seconds::new(1e-15));
    }

    #[test]
    fn group_size_products() {
        let (a, b) = (ring(4), sw(8));
        assert_eq!(group_size(&[&a, &b]), 32);
    }

    #[test]
    fn dims_key_is_order_insensitive_and_drops_singletons() {
        let (a, b, one) = (ring(4), sw(8), ring(1));
        assert_eq!(dims_key(&[&a, &b]), dims_key(&[&b, &a, &one]));
        assert_ne!(dims_key(&[&a]), dims_key(&[&b]));
        assert!(dims_key(&[&one]).is_empty());
    }

    #[test]
    fn calibration_interpolates_and_clamps() {
        let d = ring(8);
        let key = dims_key(&[&d]);
        let mut c = Calibration::default();
        c.insert(
            Collective::AllReduce,
            key.clone(),
            vec![CalPoint { bytes: 1e6, ratio: 2.0 }, CalPoint { bytes: 1e8, ratio: 4.0 }],
        );
        assert_eq!(c.len(), 1);
        let r = |b: f64| c.ratio(Collective::AllReduce, &key, b).unwrap();
        assert!((r(1e3) - 2.0).abs() < 1e-12, "clamped low");
        assert!((r(1e9) - 4.0).abs() < 1e-12, "clamped high");
        assert!((r(1e7) - 3.0).abs() < 1e-12, "log-midpoint");
        // uncalibrated (collective, key) pairs fall back
        assert!(c.ratio(Collective::AllGather, &key, 1e7).is_none());

        let model = CollectiveModel::Calibrated(c);
        let s = Bytes::new(1e7);
        let base = time_hier(Collective::AllReduce, s, &[&d]);
        assert!(
            (model.time_hier(Collective::AllReduce, s, &[&d]) - 3.0 * base).abs()
                < Seconds::new(1e-12)
        );
        // uncalibrated collectives under a calibrated model stay analytical
        let ag = time_hier(Collective::AllGather, s, &[&d]);
        assert_eq!(model.time_hier(Collective::AllGather, s, &[&d]), ag);
        let ana = CollectiveModel::Analytical;
        assert_eq!(ana.time_hier(Collective::AllReduce, s, &[&d]), base);
    }
}
