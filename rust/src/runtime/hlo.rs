//! Parser for the HLO *text* interchange format emitted by
//! `python/compile/aot.py` (`as_hlo_text(print_large_constants=True)`).
//!
//! Covers the compact printer form the AOT bridge produces: a `HloModule`
//! header line, then named computations (`ENTRY` marks the entry) whose
//! instructions read `[ROOT] name = type opcode(operands), attr=..., ...`.
//! Layout annotations (`{1,0}`) describe physical placement only and are
//! skipped — the interpreter works on logical row-major values. `/*...*/`
//! comments (the printer's `/*index=5*/` hints inside wide tuple types) are
//! treated as whitespace.
//!
//! Large constants (baked model weights) arrive as single multi-megabyte
//! lines, so parsing is cursor-based over the whole file rather than
//! line-based.

use crate::util::error::Result;
use crate::{bail, ensure, err};
use std::collections::HashMap;

/// Element type of an array value (the subset the artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
    Pred,
}

/// An HLO shape: array with dims, or tuple.
#[derive(Debug, Clone, PartialEq)]
pub enum Ty {
    Arr { dtype: Dtype, dims: Vec<usize> },
    Tuple(Vec<Ty>),
}

/// Flattened tensor payload.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Pred(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A logical row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor { dims: vec![], data: Data::F32(vec![x]) }
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// A runtime value: array or tuple (while-loop state, multi-output roots).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Arr(Tensor),
    Tuple(Vec<Value>),
}

/// Instruction attributes (unused fields stay at their defaults).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Attrs {
    pub dimensions: Vec<usize>,
    pub index: usize,
    pub direction: String,
    pub to_apply: String,
    pub condition: String,
    pub body: String,
    pub true_computation: String,
    pub false_computation: String,
    pub branch_computations: Vec<String>,
    pub dynamic_slice_sizes: Vec<usize>,
    pub lhs_batch_dims: Vec<usize>,
    pub lhs_contracting_dims: Vec<usize>,
    pub rhs_batch_dims: Vec<usize>,
    pub rhs_contracting_dims: Vec<usize>,
}

/// One parsed instruction. Operands are resolved to instruction indices in
/// the owning computation at parse time (the printer emits operands before
/// their uses).
#[derive(Debug, Clone)]
pub struct Instr {
    pub name: String,
    pub opcode: String,
    pub ty: Ty,
    pub operands: Vec<usize>,
    /// `parameter(N)` number.
    pub param: Option<usize>,
    /// Parsed `constant(...)` payload.
    pub literal: Option<Tensor>,
    pub attrs: Attrs,
}

/// A named computation (region): instructions in definition order.
#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    /// Parameter number -> instruction index.
    pub params: Vec<usize>,
    pub root: usize,
}

/// A parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModule {
    pub comps: Vec<Computation>,
    pub entry: usize,
    by_name: HashMap<String, usize>,
}

impl HloModule {
    /// Index of a computation by its printed name.
    pub fn comp_index(&self, name: &str) -> Result<usize> {
        self.by_name
            .get(name)
            .copied()
            .ok_or_else(|| err!("unknown computation '{name}'"))
    }

    pub fn entry_comp(&self) -> &Computation {
        &self.comps[self.entry]
    }

    pub fn parse(text: &str) -> Result<HloModule> {
        let mut c = Cursor::new(text);
        c.expect("HloModule")?;
        c.skip_line(); // module name + entry_computation_layout etc.

        let mut comps: Vec<Computation> = Vec::new();
        let mut by_name: HashMap<String, usize> = HashMap::new();
        let mut entry: Option<usize> = None;
        loop {
            c.skip_ws();
            if c.eof() {
                break;
            }
            let mut word = c.ident()?;
            let mut is_entry = false;
            if word == "ENTRY" {
                is_entry = true;
                word = c.ident()?;
            }
            let comp_name = word.to_string();
            c.expect("{")?;

            let mut instrs: Vec<Instr> = Vec::new();
            let mut names: HashMap<String, usize> = HashMap::new();
            let mut root: Option<usize> = None;
            let mut params: Vec<(usize, usize)> = Vec::new();
            loop {
                c.skip_ws();
                if c.peek() == b'}' {
                    c.bump();
                    break;
                }
                let (ins, is_root) = parse_instr(&mut c, &names)
                    .map_err(|e| e.context(format!("in computation '{comp_name}'")))?;
                let idx = instrs.len();
                if let Some(p) = ins.param {
                    params.push((p, idx));
                }
                if is_root {
                    root = Some(idx);
                }
                names.insert(ins.name.clone(), idx);
                instrs.push(ins);
            }
            ensure!(!instrs.is_empty(), "computation '{comp_name}' is empty");
            params.sort();
            for (k, &(num, _)) in params.iter().enumerate() {
                ensure!(num == k, "computation '{comp_name}': parameter numbers not contiguous");
            }
            let params: Vec<usize> = params.into_iter().map(|(_, i)| i).collect();
            let root = root.unwrap_or(instrs.len() - 1);
            if is_entry {
                ensure!(entry.is_none(), "multiple ENTRY computations");
                entry = Some(comps.len());
            }
            by_name.insert(comp_name.clone(), comps.len());
            comps.push(Computation { name: comp_name, instrs, params, root });
        }
        ensure!(!comps.is_empty(), "module has no computations");
        // the printer always marks the entry; fall back to the last one
        let entry = entry.unwrap_or(comps.len() - 1);
        Ok(HloModule { comps, entry, by_name })
    }
}

// ---------------------------------------------------------------------------
// Cursor
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    t: &'a str,
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(t: &'a str) -> Cursor<'a> {
        Cursor { t, i: 0 }
    }

    fn eof(&self) -> bool {
        self.i >= self.t.len()
    }

    fn peek(&self) -> u8 {
        *self.t.as_bytes().get(self.i).unwrap_or(&0)
    }

    fn bump(&mut self) {
        self.i += 1;
    }

    fn rest(&self) -> &'a str {
        self.t.get(self.i..).unwrap_or("")
    }

    fn error(&self, msg: &str) -> crate::util::error::Error {
        let near: String = self.rest().chars().take(40).collect();
        err!("hlo parse error at byte {}: {msg} near {near:?}", self.i)
    }

    fn skip_ws(&mut self) {
        let b = self.t.as_bytes();
        while self.i < b.len() {
            let ch = b[self.i];
            if ch == b' ' || ch == b'\t' || ch == b'\r' || ch == b'\n' {
                self.i += 1;
            } else if ch == b'/' && b.get(self.i + 1) == Some(&b'*') {
                match self.t[self.i + 2..].find("*/") {
                    Some(j) => self.i += 2 + j + 2,
                    None => self.i = b.len(),
                }
            } else {
                break;
            }
        }
    }

    fn skip_line(&mut self) {
        match self.rest().find('\n') {
            Some(j) => self.i += j + 1,
            None => self.i = self.t.len(),
        }
    }

    fn expect(&mut self, tok: &str) -> Result<()> {
        self.skip_ws();
        if self.rest().starts_with(tok) {
            self.i += tok.len();
            Ok(())
        } else {
            Err(self.error(&format!("expected {tok:?}")))
        }
    }

    /// Identifier: `[A-Za-z0-9_.-]+` (covers `region_0.43`, `-inf` is NOT
    /// an identifier use-case — constants are parsed separately).
    fn ident(&mut self) -> Result<&'a str> {
        self.skip_ws();
        let b = self.t.as_bytes();
        let start = self.i;
        while self.i < b.len() {
            let ch = b[self.i];
            if ch.is_ascii_alphanumeric() || ch == b'_' || ch == b'.' || ch == b'-' {
                self.i += 1;
            } else {
                break;
            }
        }
        if self.i == start {
            return Err(self.error("expected identifier"));
        }
        Ok(&self.t[start..self.i])
    }

    /// Consume `open ... close` (nesting-aware); returns the inner text.
    fn balanced(&mut self, open: u8, close: u8) -> Result<&'a str> {
        self.skip_ws();
        if self.peek() != open {
            return Err(self.error(&format!("expected '{}'", char::from(open))));
        }
        self.bump();
        let start = self.i;
        let mut depth = 1usize;
        let b = self.t.as_bytes();
        while self.i < b.len() {
            let ch = b[self.i];
            if ch == open {
                depth += 1;
            } else if ch == close {
                depth -= 1;
                if depth == 0 {
                    let s = &self.t[start..self.i];
                    self.i += 1;
                    return Ok(s);
                }
            }
            self.i += 1;
        }
        Err(self.error("unbalanced delimiter"))
    }
}

// ---------------------------------------------------------------------------
// Grammar pieces
// ---------------------------------------------------------------------------

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(tok.parse::<usize>().map_err(|_| err!("bad integer '{tok}'"))?);
    }
    Ok(out)
}

fn parse_type(c: &mut Cursor) -> Result<Ty> {
    c.skip_ws();
    if c.peek() == b'(' {
        c.bump();
        let mut elems = Vec::new();
        c.skip_ws();
        if c.peek() == b')' {
            c.bump();
            return Ok(Ty::Tuple(elems));
        }
        loop {
            elems.push(parse_type(c)?);
            c.skip_ws();
            if c.peek() == b',' {
                c.bump();
                continue;
            }
            c.expect(")")?;
            return Ok(Ty::Tuple(elems));
        }
    }
    let dt = c.ident()?;
    let dtype = match dt {
        "f32" => Dtype::F32,
        "s32" => Dtype::S32,
        "pred" => Dtype::Pred,
        other => bail!("unsupported dtype '{other}'"),
    };
    let dims = parse_usize_list(c.balanced(b'[', b']')?)?;
    c.skip_ws();
    if c.peek() == b'{' {
        // layout annotation: physical order only, logically irrelevant
        c.balanced(b'{', b'}')?;
    }
    Ok(Ty::Arr { dtype, dims })
}

/// Parse a `constant(...)` payload: a scalar (`0.125`, `-inf`, `true`) or a
/// nested-brace array literal; numbers are flattened in row-major order.
fn parse_literal(ty: &Ty, text: &str) -> Result<Tensor> {
    let Ty::Arr { dtype, dims } = ty else {
        bail!("tuple-typed constants are not supported");
    };
    let want: usize = dims.iter().product();
    let toks = text
        .split(|ch: char| ch == '{' || ch == '}' || ch == ',' || ch.is_ascii_whitespace())
        .filter(|t| !t.is_empty());
    let data = match dtype {
        Dtype::Pred => {
            let mut v = Vec::with_capacity(want);
            for t in toks {
                match t {
                    "true" => v.push(true),
                    "false" => v.push(false),
                    other => bail!("bad pred literal '{other}'"),
                }
            }
            Data::Pred(v)
        }
        Dtype::S32 => {
            let mut v = Vec::with_capacity(want);
            for t in toks {
                v.push(t.parse::<i32>().map_err(|_| err!("bad s32 literal '{t}'"))?);
            }
            Data::I32(v)
        }
        Dtype::F32 => {
            let mut v = Vec::with_capacity(want);
            for t in toks {
                // f32::from_str accepts "inf", "-inf", "nan", exponents
                v.push(t.parse::<f32>().map_err(|_| err!("bad f32 literal '{t}'"))?);
            }
            Data::F32(v)
        }
    };
    ensure!(
        data.len() == want,
        "constant has {} elements, shape {dims:?} wants {want}",
        data.len()
    );
    Ok(Tensor { dims: dims.clone(), data })
}

fn parse_instr(c: &mut Cursor, names: &HashMap<String, usize>) -> Result<(Instr, bool)> {
    let mut name = c.ident()?;
    let mut is_root = false;
    if name == "ROOT" {
        is_root = true;
        name = c.ident()?;
    }
    c.expect("=")?;
    let ty = parse_type(c)?;
    let opcode = c.ident()?;
    let inner = c.balanced(b'(', b')')?;

    let mut operands = Vec::new();
    let mut param = None;
    let mut literal = None;
    match opcode {
        "constant" => {
            literal = Some(parse_literal(&ty, inner).map_err(|e| e.context(name))?);
        }
        "parameter" => {
            param = Some(
                inner
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| err!("{name}: bad parameter number '{inner}'"))?,
            );
        }
        _ => {
            for tok in inner.split(',') {
                let tok = tok.trim();
                if tok.is_empty() {
                    continue;
                }
                let idx = names
                    .get(tok)
                    .copied()
                    .ok_or_else(|| err!("{name}: operand '{tok}' used before defined"))?;
                operands.push(idx);
            }
        }
    }

    let mut attrs = Attrs::default();
    loop {
        c.skip_ws();
        if c.peek() != b',' {
            break;
        }
        c.bump();
        let key = c.ident()?;
        c.expect("=")?;
        c.skip_ws();
        if c.peek() == b'{' {
            let inner = c.balanced(b'{', b'}')?;
            match key {
                "dimensions" => attrs.dimensions = parse_usize_list(inner)?,
                "dynamic_slice_sizes" => attrs.dynamic_slice_sizes = parse_usize_list(inner)?,
                "lhs_batch_dims" => attrs.lhs_batch_dims = parse_usize_list(inner)?,
                "lhs_contracting_dims" => attrs.lhs_contracting_dims = parse_usize_list(inner)?,
                "rhs_batch_dims" => attrs.rhs_batch_dims = parse_usize_list(inner)?,
                "rhs_contracting_dims" => attrs.rhs_contracting_dims = parse_usize_list(inner)?,
                "branch_computations" => {
                    attrs.branch_computations = inner
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect()
                }
                _ => {} // metadata, sharding, ... — irrelevant to semantics
            }
        } else {
            let val = c.ident()?;
            match key {
                "index" => {
                    attrs.index =
                        val.parse().map_err(|_| err!("{name}: bad index '{val}'"))?
                }
                "direction" => attrs.direction = val.to_string(),
                "to_apply" => attrs.to_apply = val.to_string(),
                "condition" => attrs.condition = val.to_string(),
                "body" => attrs.body = val.to_string(),
                "true_computation" => attrs.true_computation = val.to_string(),
                "false_computation" => attrs.false_computation = val.to_string(),
                _ => {}
            }
        }
    }

    Ok((
        Instr {
            name: name.to_string(),
            opcode: opcode.to_string(),
            ty,
            operands,
            param,
            literal,
            attrs,
        },
        is_root,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "HloModule jit_f, entry_computation_layout={(f32[2,3]{1,0})->(f32[2,3]{1,0})}\n\
\n\
region_0.2 {\n\
  Arg_0.3 = f32[] parameter(0)\n\
  Arg_1.4 = f32[] parameter(1)\n\
  ROOT add.5 = f32[] add(Arg_0.3, Arg_1.4)\n\
}\n\
\n\
ENTRY main.9 {\n\
  Arg_0.1 = f32[2,3]{1,0} parameter(0)\n\
  constant.6 = f32[] constant(0)\n\
  reduce.7 = f32[2]{0} reduce(Arg_0.1, constant.6), dimensions={1}, to_apply=region_0.2\n\
  ROOT tuple.8 = (f32[2]{0}) tuple(reduce.7)\n\
}\n";

    #[test]
    fn parses_tiny_module() {
        let m = HloModule::parse(TINY).unwrap();
        assert_eq!(m.comps.len(), 2);
        assert_eq!(m.entry_comp().name, "main.9");
        assert_eq!(m.entry_comp().params.len(), 1);
        let red = &m.entry_comp().instrs[2];
        assert_eq!(red.opcode, "reduce");
        assert_eq!(red.attrs.dimensions, vec![1]);
        assert_eq!(red.attrs.to_apply, "region_0.2");
        assert_eq!(red.operands, vec![0, 1]);
        assert_eq!(m.comp_index("region_0.2").unwrap(), 0);
        assert!(m.comp_index("nope").is_err());
    }

    #[test]
    fn parses_types_and_literals() {
        let mut c = Cursor::new("(s32[], f32[4,2]{1,0}, /*index=2*/pred[])");
        let ty = parse_type(&mut c).unwrap();
        match ty {
            Ty::Tuple(elems) => {
                assert_eq!(elems.len(), 3);
                assert_eq!(elems[0], Ty::Arr { dtype: Dtype::S32, dims: vec![] });
                assert_eq!(elems[1], Ty::Arr { dtype: Dtype::F32, dims: vec![4, 2] });
            }
            _ => panic!("expected tuple"),
        }

        let ty = Ty::Arr { dtype: Dtype::F32, dims: vec![2, 2] };
        let t = parse_literal(&ty, "{ { 1, -2.5 }, { -inf, 3e-2 } }").unwrap();
        match t.data {
            Data::F32(v) => {
                assert_eq!(v[0], 1.0);
                assert_eq!(v[1], -2.5);
                assert!(v[2].is_infinite() && v[2] < 0.0);
                assert!((v[3] - 0.03).abs() < 1e-7);
            }
            _ => panic!("expected f32"),
        }
        let bad = parse_literal(&ty, "{ 1, 2, 3 }");
        assert!(bad.is_err(), "element count must match shape");
    }

    #[test]
    fn rejects_malformed_modules() {
        assert!(HloModule::parse("not an hlo module").is_err());
        assert!(HloModule::parse("HloModule x\nc {\n}\n").is_err(), "empty computation");
        let fwd = "HloModule x\nENTRY e {\n  a = f32[] add(b, b)\n  b = f32[] parameter(0)\n}\n";
        assert!(HloModule::parse(fwd).is_err(), "operand before definition");
    }

    #[test]
    fn parses_every_committed_artifact() {
        let Some(dir) = super::super::find_artifacts() else {
            eprintln!("artifacts/ not built — skipping");
            return;
        };
        let man = super::super::Manifest::load(&dir).unwrap();
        for a in &man.artifacts {
            let text = std::fs::read_to_string(dir.join(&a.file)).unwrap();
            let m = HloModule::parse(&text).unwrap_or_else(|e| panic!("{}: {e}", a.file));
            assert_eq!(m.entry_comp().params.len(), a.inputs.len(), "{}", a.file);
        }
    }
}
