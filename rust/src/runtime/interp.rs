//! Pure-Rust HLO interpreter backend — the default executor, so the full
//! manifest→compile→execute→verify path runs offline with zero external
//! dependencies (DESIGN.md §Substitutions: replaces the `xla` PJRT crate).
//!
//! Implements the opcode set the AOT artifacts use (elementwise arithmetic,
//! `dot` in full generality, `reduce`, `broadcast`/`transpose`/`reshape`,
//! dynamic (update-)slice, `select`/`compare`/`convert`, and the control
//! flow Pallas `interpret=True` lowers to: `call`, `while`, `conditional`).
//! Values are logical row-major tensors; layout annotations were discarded
//! at parse time. Accumulations (dot, reduce-add) run in f64 for headroom
//! against the f32 oracle tolerance.

use super::backend::{Backend, Executable, TensorBuf};
use super::hlo::{Attrs, Computation, Data, Dtype, HloModule, Instr, Tensor, Ty, Value};
use crate::util::error::{Context, Result};
use crate::{bail, ensure, err};
use std::path::Path;

/// Safety cap for `while` trip counts (a malformed artifact must fail,
/// not hang CI).
const MAX_WHILE_ITERS: usize = 1_000_000;

/// The interpreter backend: compiles by parsing the HLO text.
#[derive(Debug, Clone, Copy, Default)]
pub struct InterpBackend;

impl Backend for InterpBackend {
    fn name(&self) -> &'static str {
        "interp"
    }

    fn compile(&self, artifact: &str, path: &Path) -> Result<Box<dyn Executable>> {
        let text = std::fs::read_to_string(path)
            .context(format!("read artifact '{artifact}' at {}", path.display()))?;
        let module = HloModule::parse(&text)
            .map_err(|e| e.context(format!("parse artifact '{artifact}'")))?;
        Ok(Box::new(InterpExecutable { module }))
    }
}

/// A parsed module ready to interpret.
pub struct InterpExecutable {
    module: HloModule,
}

impl Executable for InterpExecutable {
    fn execute(&self, args: &[&TensorBuf]) -> Result<Vec<TensorBuf>> {
        let _span = crate::obs::span("runtime.execute");
        let entry = self.module.entry_comp();
        ensure!(
            args.len() == entry.params.len(),
            "entry computation '{}' takes {} parameters, got {}",
            entry.name,
            entry.params.len(),
            args.len()
        );
        let mut vals = Vec::with_capacity(args.len());
        for (k, a) in args.iter().enumerate() {
            let pins = &entry.instrs[entry.params[k]];
            if let Ty::Arr { dims, .. } = &pins.ty {
                ensure!(
                    dims == &a.shape,
                    "parameter {k} wants shape {dims:?}, got {:?}",
                    a.shape
                );
            }
            vals.push(Value::Arr(Tensor {
                dims: a.shape.clone(),
                data: Data::F32(a.data.clone()),
            }));
        }
        let root = eval_comp(&self.module, self.module.entry, &vals)?;
        let items = match root {
            Value::Tuple(items) => items,
            v => vec![v], // tolerate non-tuple roots
        };
        items.into_iter().map(value_to_buf).collect()
    }
}

fn value_to_buf(v: Value) -> Result<TensorBuf> {
    match v {
        Value::Arr(Tensor { dims, data: Data::F32(data) }) => {
            Ok(TensorBuf { shape: dims, data })
        }
        Value::Arr(_) => Err(err!("artifact output is not f32")),
        Value::Tuple(_) => Err(err!("artifact output is a nested tuple")),
    }
}

// ---------------------------------------------------------------------------
// Evaluator
// ---------------------------------------------------------------------------

fn eval_comp(m: &HloModule, ci: usize, args: &[Value]) -> Result<Value> {
    let c = &m.comps[ci];
    ensure!(
        args.len() == c.params.len(),
        "computation '{}' wants {} args, got {}",
        c.name,
        c.params.len(),
        args.len()
    );
    let mut env: Vec<Option<Value>> = vec![None; c.instrs.len()];
    if crate::obs::enabled() {
        crate::obs::counter("runtime.instrs", c.instrs.len() as u64);
    }
    for i in 0..c.instrs.len() {
        let v = eval_instr(m, c, i, args, &env)
            .map_err(|e| e.context(format!("{}.{}", c.name, c.instrs[i].name)))?;
        env[i] = Some(v);
    }
    env[c.root]
        .take()
        .ok_or_else(|| err!("computation '{}' produced no root value", c.name))
}

fn eval_instr(
    m: &HloModule,
    c: &Computation,
    i: usize,
    args: &[Value],
    env: &[Option<Value>],
) -> Result<Value> {
    let ins = &c.instrs[i];
    let get = |k: usize| operand(ins, env, k);
    let arr = |k: usize| operand_arr(ins, env, k);

    match ins.opcode.as_str() {
        "parameter" => {
            let p = ins.param.ok_or_else(|| err!("parameter without a number"))?;
            args.get(p).cloned().ok_or_else(|| err!("parameter {p} out of range"))
        }
        "constant" => Ok(Value::Arr(
            ins.literal.clone().ok_or_else(|| err!("constant without payload"))?,
        )),
        "tuple" => {
            let mut items = Vec::with_capacity(ins.operands.len());
            for k in 0..ins.operands.len() {
                items.push(get(k)?.clone());
            }
            Ok(Value::Tuple(items))
        }
        "get-tuple-element" => match get(0)? {
            Value::Tuple(items) => items
                .get(ins.attrs.index)
                .cloned()
                .ok_or_else(|| err!("tuple index {} out of range", ins.attrs.index)),
            Value::Arr(_) => Err(err!("get-tuple-element on an array")),
        },
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" => {
            Ok(Value::Arr(binary(ins.opcode.as_str(), arr(0)?, arr(1)?)?))
        }
        "compare" => Ok(Value::Arr(compare(&ins.attrs.direction, arr(0)?, arr(1)?)?)),
        "select" => Ok(Value::Arr(select(arr(0)?, arr(1)?, arr(2)?)?)),
        "exponential" | "sqrt" | "rsqrt" | "tanh" | "negate" | "log" | "abs" => {
            Ok(Value::Arr(unary(ins.opcode.as_str(), arr(0)?)?))
        }
        "convert" => {
            let Ty::Arr { dtype, .. } = &ins.ty else {
                bail!("convert to tuple type");
            };
            Ok(Value::Arr(convert(arr(0)?, *dtype)))
        }
        "reshape" => {
            let Ty::Arr { dims, .. } = &ins.ty else {
                bail!("reshape to tuple type");
            };
            let t = arr(0)?;
            ensure!(
                dims.iter().product::<usize>() == t.elements(),
                "reshape {:?} -> {dims:?} changes element count",
                t.dims
            );
            Ok(Value::Arr(Tensor { dims: dims.clone(), data: t.data.clone() }))
        }
        "broadcast" => {
            let Ty::Arr { dims, .. } = &ins.ty else {
                bail!("broadcast to tuple type");
            };
            Ok(Value::Arr(broadcast(arr(0)?, &ins.attrs.dimensions, dims)?))
        }
        "transpose" => Ok(Value::Arr(transpose(arr(0)?, &ins.attrs.dimensions)?)),
        "dot" => Ok(Value::Arr(dot(arr(0)?, arr(1)?, &ins.attrs)?)),
        "reduce" => {
            ensure!(ins.operands.len() == 2, "variadic reduce unsupported");
            let rci = m.comp_index(&ins.attrs.to_apply)?;
            Ok(Value::Arr(reduce(m, rci, arr(0)?, arr(1)?, &ins.attrs.dimensions)?))
        }
        "dynamic-slice" => {
            let t = arr(0)?;
            let mut starts = Vec::with_capacity(ins.operands.len() - 1);
            for k in 1..ins.operands.len() {
                starts.push(scalar_i32(arr(k)?)?);
            }
            Ok(Value::Arr(dyn_slice(t, &starts, &ins.attrs.dynamic_slice_sizes)?))
        }
        "dynamic-update-slice" => {
            let t = arr(0)?;
            let u = arr(1)?;
            let mut starts = Vec::with_capacity(ins.operands.len() - 2);
            for k in 2..ins.operands.len() {
                starts.push(scalar_i32(arr(k)?)?);
            }
            Ok(Value::Arr(dyn_update_slice(t, u, &starts)?))
        }
        "call" => {
            let tgt = m.comp_index(&ins.attrs.to_apply)?;
            let mut a = Vec::with_capacity(ins.operands.len());
            for k in 0..ins.operands.len() {
                a.push(get(k)?.clone());
            }
            eval_comp(m, tgt, &a)
        }
        "while" => {
            let cond = m.comp_index(&ins.attrs.condition)?;
            let body = m.comp_index(&ins.attrs.body)?;
            let mut state = get(0)?.clone();
            let mut iters = 0usize;
            loop {
                let keep = eval_comp(m, cond, std::slice::from_ref(&state))?;
                if !scalar_pred(&keep)? {
                    break;
                }
                state = eval_comp(m, body, std::slice::from_ref(&state))?;
                iters += 1;
                ensure!(iters < MAX_WHILE_ITERS, "while exceeded {MAX_WHILE_ITERS} iterations");
            }
            Ok(state)
        }
        "conditional" => {
            let sel = arr(0)?;
            let (comp_name, operand_k) = match &sel.data {
                Data::Pred(v) if v.len() == 1 => {
                    ensure!(
                        !ins.attrs.true_computation.is_empty(),
                        "pred conditional without true_computation"
                    );
                    if v[0] {
                        (ins.attrs.true_computation.clone(), 1)
                    } else {
                        (ins.attrs.false_computation.clone(), 2)
                    }
                }
                Data::I32(v) if v.len() == 1 => {
                    let n = ins.attrs.branch_computations.len();
                    ensure!(n > 0, "indexed conditional without branch_computations");
                    // XLA: any out-of-range index (including negative) runs
                    // the LAST branch
                    let idx = if v[0] < 0 || v[0] as usize >= n { n - 1 } else { v[0] as usize };
                    (ins.attrs.branch_computations[idx].clone(), idx + 1)
                }
                _ => bail!("conditional selector must be a scalar pred or s32"),
            };
            let tgt = m.comp_index(&comp_name)?;
            let branch_arg = get(operand_k)?.clone();
            eval_comp(m, tgt, std::slice::from_ref(&branch_arg))
        }
        other => Err(err!("unhandled opcode '{other}'")),
    }
}

// ---------------------------------------------------------------------------
// Operand / scalar helpers
// ---------------------------------------------------------------------------

fn operand<'a>(ins: &Instr, env: &'a [Option<Value>], k: usize) -> Result<&'a Value> {
    let idx = *ins.operands.get(k).ok_or_else(|| err!("missing operand {k}"))?;
    env.get(idx)
        .and_then(|v| v.as_ref())
        .ok_or_else(|| err!("operand {k} not yet evaluated"))
}

fn operand_arr<'a>(ins: &Instr, env: &'a [Option<Value>], k: usize) -> Result<&'a Tensor> {
    match operand(ins, env, k)? {
        Value::Arr(t) => Ok(t),
        Value::Tuple(_) => Err(err!("operand {k} is a tuple, expected array")),
    }
}

fn scalar_i32(t: &Tensor) -> Result<i32> {
    match &t.data {
        Data::I32(v) if v.len() == 1 => Ok(v[0]),
        _ => Err(err!("expected a scalar s32, got {:?} elements", t.data.len())),
    }
}

fn scalar_pred(v: &Value) -> Result<bool> {
    match v {
        Value::Arr(Tensor { data: Data::Pred(p), .. }) if p.len() == 1 => Ok(p[0]),
        _ => Err(err!("expected a scalar pred")),
    }
}

fn scalar_f32(t: &Tensor) -> Result<f32> {
    match &t.data {
        Data::F32(v) if v.len() == 1 => Ok(v[0]),
        _ => Err(err!("expected a scalar f32")),
    }
}

// ---------------------------------------------------------------------------
// Shape/index machinery (logical row-major)
// ---------------------------------------------------------------------------

fn strides_of(dims: &[usize]) -> Vec<usize> {
    let mut st = vec![0usize; dims.len()];
    let mut acc = 1usize;
    for i in (0..dims.len()).rev() {
        st[i] = acc;
        acc *= dims[i];
    }
    st
}

fn product(dims: &[usize]) -> usize {
    dims.iter().product()
}

/// Unravel `flat` into `coords` over `dims` (row-major).
fn unravel(mut flat: usize, dims: &[usize], coords: &mut [usize]) {
    for i in (0..dims.len()).rev() {
        coords[i] = flat % dims[i];
        flat /= dims[i];
    }
}

/// Gather a new payload: `map[oi]` is the source flat index of output `oi`.
fn apply_map(data: &Data, map: &[usize]) -> Data {
    match data {
        Data::F32(v) => Data::F32(map.iter().map(|&i| v[i]).collect()),
        Data::I32(v) => Data::I32(map.iter().map(|&i| v[i]).collect()),
        Data::Pred(v) => Data::Pred(map.iter().map(|&i| v[i]).collect()),
    }
}

// ---------------------------------------------------------------------------
// Ops
// ---------------------------------------------------------------------------

fn binary(op: &str, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    ensure!(
        a.dims == b.dims,
        "{op}: shape mismatch {:?} vs {:?}",
        a.dims,
        b.dims
    );
    let data = match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => Data::F32(
            x.iter()
                .zip(y)
                .map(|(p, q)| match op {
                    "add" => p + q,
                    "subtract" => p - q,
                    "multiply" => p * q,
                    "divide" => p / q,
                    "maximum" => p.max(*q),
                    _ => p.min(*q),
                })
                .collect(),
        ),
        (Data::I32(x), Data::I32(y)) => Data::I32(
            x.iter()
                .zip(y)
                .map(|(p, q)| match op {
                    "add" => p.wrapping_add(*q),
                    "subtract" => p.wrapping_sub(*q),
                    "multiply" => p.wrapping_mul(*q),
                    "divide" => {
                        if *q == 0 {
                            0
                        } else {
                            p.wrapping_div(*q)
                        }
                    }
                    "maximum" => (*p).max(*q),
                    _ => (*p).min(*q),
                })
                .collect(),
        ),
        _ => bail!("{op}: operands must both be f32 or both s32"),
    };
    Ok(Tensor { dims: a.dims.clone(), data })
}

fn unary(op: &str, a: &Tensor) -> Result<Tensor> {
    match &a.data {
        Data::F32(x) => {
            let f: fn(f32) -> f32 = match op {
                "exponential" => |v| v.exp(),
                "sqrt" => |v| v.sqrt(),
                "rsqrt" => |v| 1.0 / v.sqrt(),
                "tanh" => |v| v.tanh(),
                "negate" => |v| -v,
                "log" => |v| v.ln(),
                _ => |v| v.abs(),
            };
            Ok(Tensor { dims: a.dims.clone(), data: Data::F32(x.iter().map(|&v| f(v)).collect()) })
        }
        Data::I32(x) if op == "negate" => Ok(Tensor {
            dims: a.dims.clone(),
            data: Data::I32(x.iter().map(|&v| v.wrapping_neg()).collect()),
        }),
        Data::I32(x) if op == "abs" => Ok(Tensor {
            dims: a.dims.clone(),
            data: Data::I32(x.iter().map(|&v| v.wrapping_abs()).collect()),
        }),
        _ => Err(err!("{op}: unsupported operand dtype")),
    }
}

#[derive(Clone, Copy)]
enum Cmp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

fn compare(direction: &str, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    ensure!(a.dims == b.dims, "compare: shape mismatch");
    let c = match direction {
        "EQ" => Cmp::Eq,
        "NE" => Cmp::Ne,
        "LT" => Cmp::Lt,
        "LE" => Cmp::Le,
        "GT" => Cmp::Gt,
        "GE" => Cmp::Ge,
        other => bail!("compare: unknown direction '{other}'"),
    };
    fn apply<T: PartialOrd + PartialEq + Copy>(c: Cmp, p: T, q: T) -> bool {
        match c {
            Cmp::Eq => p == q,
            Cmp::Ne => p != q,
            Cmp::Lt => p < q,
            Cmp::Le => p <= q,
            Cmp::Gt => p > q,
            Cmp::Ge => p >= q,
        }
    }
    let out = match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => {
            x.iter().zip(y).map(|(p, q)| apply(c, *p, *q)).collect()
        }
        (Data::I32(x), Data::I32(y)) => {
            x.iter().zip(y).map(|(p, q)| apply(c, *p, *q)).collect()
        }
        _ => bail!("compare: operands must both be f32 or both s32"),
    };
    Ok(Tensor { dims: a.dims.clone(), data: Data::Pred(out) })
}

fn select(p: &Tensor, on_true: &Tensor, on_false: &Tensor) -> Result<Tensor> {
    ensure!(on_true.dims == on_false.dims, "select: branch shape mismatch");
    let Data::Pred(pv) = &p.data else {
        bail!("select: predicate is not pred-typed");
    };
    let n = on_true.elements();
    ensure!(
        pv.len() == n || pv.len() == 1,
        "select: predicate has {} elements, operands {n}",
        pv.len()
    );
    let pick = |i: usize| -> bool {
        if pv.len() == 1 {
            pv[0]
        } else {
            pv[i]
        }
    };
    let data = match (&on_true.data, &on_false.data) {
        (Data::F32(t), Data::F32(f)) => {
            Data::F32((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        (Data::I32(t), Data::I32(f)) => {
            Data::I32((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        (Data::Pred(t), Data::Pred(f)) => {
            Data::Pred((0..n).map(|i| if pick(i) { t[i] } else { f[i] }).collect())
        }
        _ => bail!("select: branch dtype mismatch"),
    };
    Ok(Tensor { dims: on_true.dims.clone(), data })
}

fn convert(a: &Tensor, to: Dtype) -> Tensor {
    let data = match (&a.data, to) {
        (Data::F32(v), Dtype::F32) => Data::F32(v.clone()),
        (Data::F32(v), Dtype::S32) => Data::I32(v.iter().map(|&x| x as i32).collect()),
        (Data::F32(v), Dtype::Pred) => Data::Pred(v.iter().map(|&x| x != 0.0).collect()),
        (Data::I32(v), Dtype::F32) => Data::F32(v.iter().map(|&x| x as f32).collect()),
        (Data::I32(v), Dtype::S32) => Data::I32(v.clone()),
        (Data::I32(v), Dtype::Pred) => Data::Pred(v.iter().map(|&x| x != 0).collect()),
        (Data::Pred(v), Dtype::F32) => {
            Data::F32(v.iter().map(|&x| if x { 1.0 } else { 0.0 }).collect())
        }
        (Data::Pred(v), Dtype::S32) => {
            Data::I32(v.iter().map(|&x| i32::from(x)).collect())
        }
        (Data::Pred(v), Dtype::Pred) => Data::Pred(v.clone()),
    };
    Tensor { dims: a.dims.clone(), data }
}

/// HLO broadcast: operand dim `i` maps to output dim `bdims[i]`; all other
/// output dims replicate.
fn broadcast(t: &Tensor, bdims: &[usize], out_dims: &[usize]) -> Result<Tensor> {
    ensure!(
        t.dims.len() == bdims.len(),
        "broadcast: operand rank {} vs {} mapped dims",
        t.dims.len(),
        bdims.len()
    );
    let ost = strides_of(&t.dims);
    for (i, &d) in bdims.iter().enumerate() {
        ensure!(
            d < out_dims.len() && t.dims[i] == out_dims[d],
            "broadcast: operand dim {i} ({}) does not fit output dim {d} of {out_dims:?}",
            t.dims[i]
        );
        if i > 0 {
            ensure!(bdims[i - 1] < d, "broadcast: dimensions must be increasing");
        }
    }
    let n = product(out_dims);
    let mut map = vec![0usize; n];
    let mut coords = vec![0usize; out_dims.len()];
    for (oi, slot) in map.iter_mut().enumerate() {
        unravel(oi, out_dims, &mut coords);
        let mut off = 0usize;
        for (i, &d) in bdims.iter().enumerate() {
            off += coords[d] * ost[i];
        }
        *slot = off;
    }
    Ok(Tensor { dims: out_dims.to_vec(), data: apply_map(&t.data, &map) })
}

/// HLO transpose: output dim `i` is operand dim `perm[i]`.
fn transpose(t: &Tensor, perm: &[usize]) -> Result<Tensor> {
    ensure!(perm.len() == t.dims.len(), "transpose: rank mismatch");
    let mut seen = vec![false; perm.len()];
    for &p in perm {
        ensure!(p < perm.len() && !seen[p], "transpose: bad permutation {perm:?}");
        seen[p] = true;
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| t.dims[p]).collect();
    let ist = strides_of(&t.dims);
    let n = product(&out_dims);
    let mut map = vec![0usize; n];
    let mut coords = vec![0usize; out_dims.len()];
    for (oi, slot) in map.iter_mut().enumerate() {
        unravel(oi, &out_dims, &mut coords);
        let mut off = 0usize;
        for (i, &p) in perm.iter().enumerate() {
            off += coords[i] * ist[p];
        }
        *slot = off;
    }
    Ok(Tensor { dims: out_dims, data: apply_map(&t.data, &map) })
}

/// General `dot`: result dims are (batch, lhs free, rhs free) in dimension-
/// number order; f64 accumulation.
fn dot(lhs: &Tensor, rhs: &Tensor, at: &Attrs) -> Result<Tensor> {
    let (Data::F32(lf), Data::F32(rf)) = (&lhs.data, &rhs.data) else {
        bail!("dot: operands must be f32");
    };
    let ld = &lhs.dims;
    let rd = &rhs.dims;
    let lb = &at.lhs_batch_dims;
    let lc = &at.lhs_contracting_dims;
    let rb = &at.rhs_batch_dims;
    let rc = &at.rhs_contracting_dims;
    ensure!(lb.len() == rb.len(), "dot: batch dim arity mismatch");
    ensure!(lc.len() == rc.len(), "dot: contracting dim arity mismatch");
    for (i, &d) in lb.iter().enumerate() {
        ensure!(ld[d] == rd[rb[i]], "dot: batch dim size mismatch");
    }
    for (i, &d) in lc.iter().enumerate() {
        ensure!(ld[d] == rd[rc[i]], "dot: contracting dim size mismatch");
    }
    let lfree: Vec<usize> =
        (0..ld.len()).filter(|d| !lb.contains(d) && !lc.contains(d)).collect();
    let rfree: Vec<usize> =
        (0..rd.len()).filter(|d| !rb.contains(d) && !rc.contains(d)).collect();
    let mut out_dims: Vec<usize> = lb.iter().map(|&d| ld[d]).collect();
    out_dims.extend(lfree.iter().map(|&d| ld[d]));
    out_dims.extend(rfree.iter().map(|&d| rd[d]));
    let contract: Vec<usize> = lc.iter().map(|&d| ld[d]).collect();

    let ls = strides_of(ld);
    let rs = strides_of(rd);
    let n_out = product(&out_dims);
    let n_con = product(&contract);
    let mut out = vec![0f32; n_out];
    let mut coords = vec![0usize; out_dims.len()];
    for (oi, slot) in out.iter_mut().enumerate() {
        unravel(oi, &out_dims, &mut coords);
        let mut lbase = 0usize;
        let mut rbase = 0usize;
        let mut k = 0usize;
        for (bi, &d) in lb.iter().enumerate() {
            lbase += coords[k] * ls[d];
            rbase += coords[k] * rs[rb[bi]];
            k += 1;
        }
        for &d in &lfree {
            lbase += coords[k] * ls[d];
            k += 1;
        }
        for &d in &rfree {
            rbase += coords[k] * rs[d];
            k += 1;
        }
        let mut acc = 0f64;
        if contract.len() == 1 {
            // the common single-contraction fast path
            let sl = ls[lc[0]];
            let sr = rs[rc[0]];
            for ci in 0..n_con {
                acc += f64::from(lf[lbase + ci * sl]) * f64::from(rf[rbase + ci * sr]);
            }
        } else {
            let mut ccoords = vec![0usize; contract.len()];
            for ci in 0..n_con {
                unravel(ci, &contract, &mut ccoords);
                let mut loff = 0usize;
                let mut roff = 0usize;
                for (j, &cc) in ccoords.iter().enumerate() {
                    loff += cc * ls[lc[j]];
                    roff += cc * rs[rc[j]];
                }
                acc += f64::from(lf[lbase + loff]) * f64::from(rf[rbase + roff]);
            }
        }
        *slot = acc as f32;
    }
    Ok(Tensor { dims: out_dims, data: Data::F32(out) })
}

fn reduce(
    m: &HloModule,
    rci: usize,
    t: &Tensor,
    init: &Tensor,
    rdims: &[usize],
) -> Result<Tensor> {
    let Data::F32(src) = &t.data else {
        bail!("reduce: only f32 operands supported");
    };
    let init_v = scalar_f32(init)?;
    for &d in rdims {
        ensure!(d < t.dims.len(), "reduce: dim {d} out of range");
    }
    let kept: Vec<usize> = (0..t.dims.len()).filter(|d| !rdims.contains(d)).collect();
    let out_dims: Vec<usize> = kept.iter().map(|&d| t.dims[d]).collect();
    let red_dims: Vec<usize> = rdims.iter().map(|&d| t.dims[d]).collect();
    let ist = strides_of(&t.dims);
    let n_out = product(&out_dims);
    let n_red = product(&red_dims);

    let rcomp = &m.comps[rci];
    let rroot = &rcomp.instrs[rcomp.root];
    let root_op = rroot.opcode.as_str();
    // The fast path is only valid for a *trivial* reducer — its root combines
    // exactly the two region parameters. Anything fancier (scaled sums etc.)
    // must go through the generic eval_comp fold.
    let trivial = rcomp.params.len() == 2 && {
        let mut ops = rroot.operands.clone();
        let mut ps = rcomp.params.clone();
        ops.sort_unstable();
        ps.sort_unstable();
        ops == ps
    };
    let fast: Option<fn(f32, f32) -> f32> = if !trivial {
        None
    } else {
        match root_op {
            "add" => Some(|a, b| a + b),
            "maximum" => Some(|a, b| a.max(b)),
            "minimum" => Some(|a, b| a.min(b)),
            "multiply" => Some(|a, b| a * b),
            _ => None,
        }
    };

    let mut out = vec![init_v; n_out];
    let mut ocoords = vec![0usize; out_dims.len()];
    let mut rcoords = vec![0usize; red_dims.len()];
    for (oi, slot) in out.iter_mut().enumerate() {
        unravel(oi, &out_dims, &mut ocoords);
        let mut base = 0usize;
        for (j, &d) in kept.iter().enumerate() {
            base += ocoords[j] * ist[d];
        }
        if let Some(f) = fast {
            // f64 accumulation for the add-reduction hot path
            if root_op == "add" {
                let mut acc = f64::from(init_v);
                for ri in 0..n_red {
                    unravel(ri, &red_dims, &mut rcoords);
                    let mut off = 0usize;
                    for (j, &cc) in rcoords.iter().enumerate() {
                        off += cc * ist[rdims[j]];
                    }
                    acc += f64::from(src[base + off]);
                }
                *slot = acc as f32;
            } else {
                let mut acc = init_v;
                for ri in 0..n_red {
                    unravel(ri, &red_dims, &mut rcoords);
                    let mut off = 0usize;
                    for (j, &cc) in rcoords.iter().enumerate() {
                        off += cc * ist[rdims[j]];
                    }
                    acc = f(acc, src[base + off]);
                }
                *slot = acc;
            }
        } else {
            // generic reducer: fold scalars through the sub-computation
            let mut acc = init_v;
            for ri in 0..n_red {
                unravel(ri, &red_dims, &mut rcoords);
                let mut off = 0usize;
                for (j, &cc) in rcoords.iter().enumerate() {
                    off += cc * ist[rdims[j]];
                }
                let r = eval_comp(
                    m,
                    rci,
                    &[
                        Value::Arr(Tensor::scalar_f32(acc)),
                        Value::Arr(Tensor::scalar_f32(src[base + off])),
                    ],
                )?;
                acc = match r {
                    Value::Arr(ref rt) => scalar_f32(rt)?,
                    _ => bail!("reducer returned a tuple"),
                };
            }
            *slot = acc;
        }
    }
    Ok(Tensor { dims: out_dims, data: Data::F32(out) })
}

/// Start indices are clamped into `[0, dim - size]` (XLA semantics).
fn clamp_starts(starts: &[i32], dims: &[usize], sizes: &[usize]) -> Vec<usize> {
    starts
        .iter()
        .enumerate()
        .map(|(d, &s)| (s.max(0) as usize).min(dims[d] - sizes[d]))
        .collect()
}

fn dyn_slice(t: &Tensor, starts: &[i32], sizes: &[usize]) -> Result<Tensor> {
    ensure!(
        starts.len() == t.dims.len() && sizes.len() == t.dims.len(),
        "dynamic-slice: rank mismatch"
    );
    for (d, &sz) in sizes.iter().enumerate() {
        ensure!(sz <= t.dims[d], "dynamic-slice: size {sz} exceeds dim {d}");
    }
    let base = clamp_starts(starts, &t.dims, sizes);
    let ist = strides_of(&t.dims);
    let n = product(sizes);
    let mut map = vec![0usize; n];
    let mut coords = vec![0usize; sizes.len()];
    for (oi, slot) in map.iter_mut().enumerate() {
        unravel(oi, sizes, &mut coords);
        let mut off = 0usize;
        for (d, &c) in coords.iter().enumerate() {
            off += (base[d] + c) * ist[d];
        }
        *slot = off;
    }
    Ok(Tensor { dims: sizes.to_vec(), data: apply_map(&t.data, &map) })
}

fn dyn_update_slice(t: &Tensor, u: &Tensor, starts: &[i32]) -> Result<Tensor> {
    ensure!(
        starts.len() == t.dims.len() && u.dims.len() == t.dims.len(),
        "dynamic-update-slice: rank mismatch"
    );
    for (d, &sz) in u.dims.iter().enumerate() {
        ensure!(sz <= t.dims[d], "dynamic-update-slice: update exceeds dim {d}");
    }
    let base = clamp_starts(starts, &t.dims, &u.dims);
    let ist = strides_of(&t.dims);
    let n = product(&u.dims);
    let mut out = t.data.clone();
    let mut coords = vec![0usize; u.dims.len()];
    for ui in 0..n {
        unravel(ui, &u.dims, &mut coords);
        let mut off = 0usize;
        for (d, &c) in coords.iter().enumerate() {
            off += (base[d] + c) * ist[d];
        }
        match (&mut out, &u.data) {
            (Data::F32(o), Data::F32(s)) => o[off] = s[ui],
            (Data::I32(o), Data::I32(s)) => o[off] = s[ui],
            (Data::Pred(o), Data::Pred(s)) => o[off] = s[ui],
            _ => bail!("dynamic-update-slice: dtype mismatch"),
        }
    }
    Ok(Tensor { dims: t.dims.clone(), data: out })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(hlo: &str, args: &[TensorBuf]) -> Vec<TensorBuf> {
        let module = HloModule::parse(hlo).expect("parse");
        let exe = InterpExecutable { module };
        let refs: Vec<&TensorBuf> = args.iter().collect();
        exe.execute(&refs).expect("execute")
    }

    fn buf(shape: &[usize], data: &[f32]) -> TensorBuf {
        TensorBuf::new(shape.to_vec(), data.to_vec())
    }

    #[test]
    fn matmul_and_bias() {
        // y = x @ w + b with w=[[1,2],[3,4]] (baked constant), b=[10, 20]
        let hlo = "HloModule jit_f, entry_computation_layout={(f32[2,2]{1,0})->(f32[2,2]{1,0})}\n\
ENTRY main.9 {\n\
  Arg_0.1 = f32[2,2]{1,0} parameter(0)\n\
  constant.2 = f32[2,2]{1,0} constant({ { 1, 2 }, { 3, 4 } })\n\
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, constant.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n\
  constant.4 = f32[2]{0} constant({10, 20})\n\
  broadcast.5 = f32[2,2]{1,0} broadcast(constant.4), dimensions={1}\n\
  add.6 = f32[2,2]{1,0} add(dot.3, broadcast.5)\n\
  ROOT tuple.7 = (f32[2,2]{1,0}) tuple(add.6)\n\
}\n";
        let out = run(hlo, &[buf(&[2, 2], &[1.0, 0.0, 0.0, 1.0])]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![2, 2]);
        assert_eq!(out[0].data, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn batched_dot_matches_attention_scores() {
        // scores[h,q,k] = sum_d q[h,q,d] * k[h,k,d]  (the MHA1 form)
        let hlo = "HloModule jit_f, entry_computation_layout={(f32[2,2,2]{2,1,0}, f32[2,2,2]{2,1,0})->(f32[2,2,2]{2,1,0})}\n\
ENTRY main.5 {\n\
  Arg_0.1 = f32[2,2,2]{2,1,0} parameter(0)\n\
  Arg_1.2 = f32[2,2,2]{2,1,0} parameter(1)\n\
  dot.3 = f32[2,2,2]{2,1,0} dot(Arg_0.1, Arg_1.2), lhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_batch_dims={0}, rhs_contracting_dims={2}\n\
  ROOT tuple.4 = (f32[2,2,2]{2,1,0}) tuple(dot.3)\n\
}\n";
        let q = buf(&[2, 2, 2], &[1., 2., 3., 4., 5., 6., 7., 8.]);
        let k = buf(&[2, 2, 2], &[1., 0., 0., 1., 1., 1., 2., 0.]);
        let out = run(hlo, &[q, k]);
        // head 0: [[1,2],[3,4]] @ [[1,0],[0,1]]^T = [[1,2],[3,4]]
        // head 1: [[5,6],[7,8]] @ [[1,1],[2,0]]^T = [[11,10],[15,14]]
        assert_eq!(out[0].data, vec![1., 2., 3., 4., 11., 10., 15., 14.]);
    }

    #[test]
    fn softmax_reduce_exp_divide() {
        let hlo = "HloModule jit_f, entry_computation_layout={(f32[1,3]{1,0})->(f32[1,3]{1,0})}\n\
region_0.2 {\n\
  Arg_0.3 = f32[] parameter(0)\n\
  Arg_1.4 = f32[] parameter(1)\n\
  ROOT maximum.5 = f32[] maximum(Arg_0.3, Arg_1.4)\n\
}\n\
region_1.6 {\n\
  Arg_0.7 = f32[] parameter(0)\n\
  Arg_1.8 = f32[] parameter(1)\n\
  ROOT add.9 = f32[] add(Arg_0.7, Arg_1.8)\n\
}\n\
ENTRY main.20 {\n\
  Arg_0.1 = f32[1,3]{1,0} parameter(0)\n\
  constant.10 = f32[] constant(-inf)\n\
  reduce.11 = f32[1]{0} reduce(Arg_0.1, constant.10), dimensions={1}, to_apply=region_0.2\n\
  broadcast.12 = f32[1,3]{1,0} broadcast(reduce.11), dimensions={0}\n\
  subtract.13 = f32[1,3]{1,0} subtract(Arg_0.1, broadcast.12)\n\
  exponential.14 = f32[1,3]{1,0} exponential(subtract.13)\n\
  constant.15 = f32[] constant(0)\n\
  reduce.16 = f32[1]{0} reduce(exponential.14, constant.15), dimensions={1}, to_apply=region_1.6\n\
  broadcast.17 = f32[1,3]{1,0} broadcast(reduce.16), dimensions={0}\n\
  divide.18 = f32[1,3]{1,0} divide(exponential.14, broadcast.17)\n\
  ROOT tuple.19 = (f32[1,3]{1,0}) tuple(divide.18)\n\
}\n";
        let out = run(hlo, &[buf(&[1, 3], &[0.0, f32::ln(2.0), f32::ln(3.0)])]);
        let got = &out[0].data;
        let want = [1.0 / 6.0, 2.0 / 6.0, 3.0 / 6.0];
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < 1e-6, "{got:?}");
        }
    }

    #[test]
    fn while_loop_accumulates() {
        // state (i, acc): while i < 4 { acc += 2*i; i += 1 } from (0, 0)
        let hlo = "HloModule jit_f, entry_computation_layout={(f32[]{})->(f32[]{})}\n\
body.1 {\n\
  arg_tuple.2 = (s32[], f32[]) parameter(0)\n\
  get-tuple-element.3 = s32[] get-tuple-element(arg_tuple.2), index=0\n\
  get-tuple-element.4 = f32[] get-tuple-element(arg_tuple.2), index=1\n\
  constant.5 = s32[] constant(1)\n\
  add.6 = s32[] add(get-tuple-element.3, constant.5)\n\
  convert.7 = f32[] convert(get-tuple-element.3)\n\
  constant.8 = f32[] constant(2)\n\
  multiply.9 = f32[] multiply(convert.7, constant.8)\n\
  add.10 = f32[] add(get-tuple-element.4, multiply.9)\n\
  ROOT tuple.11 = (s32[], f32[]) tuple(add.6, add.10)\n\
}\n\
cond.12 {\n\
  arg_tuple.13 = (s32[], f32[]) parameter(0)\n\
  get-tuple-element.14 = s32[] get-tuple-element(arg_tuple.13), index=0\n\
  constant.15 = s32[] constant(4)\n\
  ROOT compare.16 = pred[] compare(get-tuple-element.14, constant.15), direction=LT\n\
}\n\
ENTRY main.30 {\n\
  Arg_0.1 = f32[] parameter(0)\n\
  constant.17 = s32[] constant(0)\n\
  tuple.18 = (s32[], f32[]) tuple(constant.17, Arg_0.1)\n\
  while.19 = (s32[], f32[]) while(tuple.18), condition=cond.12, body=body.1\n\
  get-tuple-element.20 = f32[] get-tuple-element(while.19), index=1\n\
  ROOT tuple.21 = (f32[]) tuple(get-tuple-element.20)\n\
}\n";
        let out = run(hlo, &[buf(&[], &[1.0])]);
        // 1 + (0 + 2 + 4 + 6) = 13
        assert_eq!(out[0].data, vec![13.0]);
        assert_eq!(out[0].shape, Vec::<usize>::new());
    }

    #[test]
    fn indexed_conditional_picks_branch() {
        // branch 0 doubles, branch 1 negates; s32 selector clamps like XLA
        let hlo2 = "HloModule jit_f, entry_computation_layout={(f32[2]{0}, f32[]{})->(f32[2]{0})}\n\
branch_a.1 {\n\
  Arg_.2 = f32[2]{0} parameter(0)\n\
  ROOT add.3 = f32[2]{0} add(Arg_.2, Arg_.2)\n\
}\n\
branch_b.4 {\n\
  Arg_.5 = f32[2]{0} parameter(0)\n\
  ROOT negate.6 = f32[2]{0} negate(Arg_.5)\n\
}\n\
ENTRY main.20 {\n\
  Arg_0.1 = f32[2]{0} parameter(0)\n\
  Arg_1.2 = f32[] parameter(1)\n\
  convert.3 = s32[] convert(Arg_1.2)\n\
  conditional.4 = f32[2]{0} conditional(convert.3, Arg_0.1, Arg_0.1), branch_computations={branch_a.1, branch_b.4}\n\
  ROOT tuple.5 = (f32[2]{0}) tuple(conditional.4)\n\
}\n";
        let out = run(hlo2, &[buf(&[2], &[3.0, -1.0]), buf(&[], &[0.0])]);
        assert_eq!(out[0].data, vec![6.0, -2.0], "branch 0 doubles");
        let out = run(hlo2, &[buf(&[2], &[3.0, -1.0]), buf(&[], &[1.0])]);
        assert_eq!(out[0].data, vec![-3.0, 1.0], "branch 1 negates");
        let out = run(hlo2, &[buf(&[2], &[3.0, -1.0]), buf(&[], &[9.0])]);
        assert_eq!(out[0].data, vec![-3.0, 1.0], "index clamps to last branch");
        // XLA: a NEGATIVE out-of-range index also runs the LAST branch
        let out = run(hlo2, &[buf(&[2], &[3.0, -1.0]), buf(&[], &[-3.0])]);
        assert_eq!(out[0].data, vec![-3.0, 1.0], "negative index runs last branch");
    }

    #[test]
    fn dynamic_slice_clamps_and_updates() {
        let hlo = "HloModule jit_f, entry_computation_layout={(f32[4]{0}, f32[]{})->(f32[2]{0})}\n\
ENTRY main.9 {\n\
  Arg_0.1 = f32[4]{0} parameter(0)\n\
  Arg_1.2 = f32[] parameter(1)\n\
  convert.3 = s32[] convert(Arg_1.2)\n\
  dynamic-slice.4 = f32[2]{0} dynamic-slice(Arg_0.1, convert.3), dynamic_slice_sizes={2}\n\
  constant.5 = f32[2]{0} constant({100, 200})\n\
  add.6 = f32[2]{0} add(dynamic-slice.4, constant.5)\n\
  ROOT tuple.7 = (f32[2]{0}) tuple(add.6)\n\
}\n";
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let out = run(hlo, &[buf(&[4], &x), buf(&[], &[1.0])]);
        assert_eq!(out[0].data, vec![102.0, 203.0]);
        // start 9 clamps to 2 (= 4 - 2)
        let out = run(hlo, &[buf(&[4], &x), buf(&[], &[9.0])]);
        assert_eq!(out[0].data, vec![103.0, 204.0]);
    }

    #[test]
    fn transpose_matches_row_major_semantics() {
        let t = Tensor { dims: vec![2, 3], data: Data::F32(vec![1., 2., 3., 4., 5., 6.]) };
        let r = transpose(&t, &[1, 0]).unwrap();
        assert_eq!(r.dims, vec![3, 2]);
        match r.data {
            Data::F32(v) => assert_eq!(v, vec![1., 4., 2., 5., 3., 6.]),
            _ => panic!(),
        }
        assert!(transpose(&t, &[0, 0]).is_err());
    }

    #[test]
    fn dynamic_update_slice_writes_window() {
        let t = Tensor { dims: vec![2, 3], data: Data::F32(vec![0.; 6]) };
        let u = Tensor { dims: vec![1, 2], data: Data::F32(vec![7., 8.]) };
        let r = dyn_update_slice(&t, &u, &[1, 1]).unwrap();
        match r.data {
            Data::F32(v) => assert_eq!(v, vec![0., 0., 0., 0., 7., 8.]),
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let hlo = "HloModule jit_f, entry_computation_layout={(f32[2]{0})->(f32[2]{0})}\n\
ENTRY main.3 {\n\
  Arg_0.1 = f32[2]{0} parameter(0)\n\
  ROOT tuple.2 = (f32[2]{0}) tuple(Arg_0.1)\n\
}\n";
        let module = HloModule::parse(hlo).unwrap();
        let exe = InterpExecutable { module };
        assert!(exe.execute(&[]).is_err(), "arity");
        let wrong = buf(&[3], &[0.0; 3]);
        assert!(exe.execute(&[&wrong]).is_err(), "shape");
        let right = buf(&[2], &[0.0; 2]);
        assert!(exe.execute(&[&right]).is_ok());
    }
}
