//! Manifest loader for the AOT artifact directory (artifacts/manifest.json,
//! written by python/compile/aot.py), parsed with the in-tree JSON module.

use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Input shapes (f32).
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// One step of a pipeline: run `artifact` on named buffers.
#[derive(Debug, Clone)]
pub struct PipelineStep {
    pub artifact: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub steps: Vec<PipelineStep>,
    pub output: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub d_model: usize,
    pub n_heads: usize,
    pub seq: usize,
    pub d_ff: usize,
    pub input_file: String,
    pub expected_file: String,
    pub tolerance: f64,
    pub input_shape: Vec<usize>,
    pub artifacts: Vec<ArtifactSpec>,
    pub pipelines: BTreeMap<String, PipelineSpec>,
}

fn shape_of(v: &Json) -> Result<Vec<usize>> {
    v.get("shape")
        .and_then(|s| s.as_array())
        .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
        .ok_or_else(|| err!("bad shape spec"))
}

fn strings(v: &Json) -> Vec<String> {
    v.as_array()
        .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| err!("manifest: {e}"))?;
        let cfg = j.get("config").ok_or_else(|| err!("manifest: missing config"))?;
        let u = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(|v| v.as_usize()).ok_or_else(|| err!("config.{k} missing"))
        };
        let (d_model, n_heads, seq, d_ff) =
            (u("d_model")?, u("n_heads")?, u("seq")?, u("d_ff")?);

        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(|v| v.as_array()).unwrap_or(&[]) {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| err!("artifact missing name"))?;
            let file = a
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| err!("artifact {name}: missing file"))?;
            let inputs = a
                .get("inputs")
                .and_then(|v| v.as_array())
                .map(|xs| xs.iter().map(shape_of).collect::<Result<Vec<_>>>())
                .transpose()?
                .unwrap_or_default();
            let outputs = a
                .get("outputs")
                .and_then(|v| v.as_array())
                .map(|xs| xs.iter().map(shape_of).collect::<Result<Vec<_>>>())
                .transpose()?
                .unwrap_or_default();
            artifacts.push(ArtifactSpec {
                name: name.to_string(),
                file: file.to_string(),
                inputs,
                outputs,
            });
        }

        let mut pipelines = BTreeMap::new();
        if let Some(Json::Obj(kv)) = j.get("pipelines") {
            for (pname, p) in kv {
                let mut steps = Vec::new();
                for s in p.get("steps").and_then(|v| v.as_array()).unwrap_or(&[]) {
                    steps.push(PipelineStep {
                        artifact: s
                            .get("artifact")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| err!("{pname}: step missing artifact"))?
                            .to_string(),
                        inputs: strings(s.get("in").unwrap_or(&Json::Null)),
                        outputs: strings(s.get("out").unwrap_or(&Json::Null)),
                    });
                }
                let output = p
                    .get("output")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| err!("{pname}: missing output"))?
                    .to_string();
                pipelines.insert(pname.clone(), PipelineSpec { steps, output });
            }
        }

        Ok(Manifest {
            d_model,
            n_heads,
            seq,
            d_ff,
            input_file: j
                .get("input_file")
                .and_then(|v| v.as_str())
                .unwrap_or("input_x.bin")
                .to_string(),
            expected_file: j
                .get("expected_file")
                .and_then(|v| v.as_str())
                .unwrap_or("expected_out.bin")
                .to_string(),
            tolerance: j.get("tolerance").and_then(|v| v.as_f64()).unwrap_or(2e-4),
            input_shape: vec![seq, d_model],
            artifacts,
            pipelines,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Structural check: every pipeline references known artifacts, buffers
    /// are defined before use, and arities line up.
    pub fn validate(&self) -> Result<()> {
        for (pname, p) in &self.pipelines {
            let mut defined: Vec<&str> = vec!["x"];
            for s in &p.steps {
                let art = self
                    .artifact(&s.artifact)
                    .ok_or_else(|| err!("{pname}: unknown artifact '{}'", s.artifact))?;
                if s.inputs.len() != art.inputs.len() || s.outputs.len() != art.outputs.len() {
                    return Err(err!("{pname}: arity mismatch at '{}'", s.artifact));
                }
                for b in &s.inputs {
                    if !defined.contains(&b.as_str()) {
                        return Err(err!("{pname}: buffer '{b}' used before defined"));
                    }
                }
                for b in &s.outputs {
                    defined.push(b);
                }
            }
            if !defined.contains(&p.output.as_str()) {
                return Err(err!("{pname}: output '{}' never produced", p.output));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"d_model": 64, "n_heads": 2, "seq": 32, "d_ff": 256,
                 "head_dim": 32, "dtype": "f32"},
      "input_file": "input_x.bin",
      "expected_file": "expected_out.bin",
      "tolerance": 2e-4,
      "artifacts": [
        {"name": "a1", "file": "a1.hlo.txt",
         "inputs": [{"shape": [32, 64], "dtype": "f32"}],
         "outputs": [{"shape": [32, 64], "dtype": "f32"}]}
      ],
      "pipelines": {
        "p": {"steps": [{"artifact": "a1", "in": ["x"], "out": ["out"]}],
               "output": "out"}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.d_model, 64);
        assert_eq!(m.input_shape, vec![32, 64]);
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.pipelines["p"].steps.len(), 1);
        m.validate().unwrap();
    }

    #[test]
    fn validate_rejects_undefined_buffer() {
        let bad = SAMPLE.replace("\"in\": [\"x\"]", "\"in\": [\"nope\"]");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_unknown_artifact() {
        let bad = SAMPLE.replace("{\"artifact\": \"a1\"", "{\"artifact\": \"zz\"");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = Path::new("artifacts");
        if p.join("manifest.json").exists() {
            let m = Manifest::load(p).unwrap();
            m.validate().unwrap();
            assert!(m.pipelines.contains_key("fused"));
            assert!(m.pipelines.contains_key("kernel_by_kernel"));
            assert!(m.pipelines.contains_key("vendor"));
            assert!(m.pipelines.contains_key("dfmodel"));
        }
    }
}
