//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` (python/compile/aot.py) and executes the GPT-layer
//! mapping variants from the Rust hot path — Python is never on the
//! request path.
//!
//! The executor interprets the manifest's pipeline wiring generically:
//! named buffers flow between steps, so the same code runs the fused
//! (1 partition), vendor (4 partitions), DFModel (4 partitions), and
//! kernel-by-kernel (14 steps) mappings, and reports the host-visible
//! intermediate traffic each incurs — the Fig. 2C-vs-2D contrast, executed
//! for real.

pub mod manifest;

pub use manifest::{ArtifactSpec, Manifest, PipelineSpec, PipelineStep};

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Compiled artifacts + manifest, ready to execute.
pub struct Runtime {
    pub manifest: Manifest,
    dir: PathBuf,
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

/// Execution statistics of one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineStats {
    pub steps: usize,
    /// Bytes of intermediate tensors that crossed the host boundary
    /// (the analytical model's matrix-D traffic, measured).
    pub intermediate_bytes: f64,
    pub wall: Duration,
}

impl Runtime {
    /// Load the manifest and compile every artifact needed by `pipelines`
    /// (all pipelines when empty).
    pub fn load(dir: &Path, pipelines: &[&str]) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;
        let needed: Vec<String> = if pipelines.is_empty() {
            manifest.artifacts.iter().map(|a| a.name.clone()).collect()
        } else {
            let mut v = Vec::new();
            for p in pipelines {
                let spec = manifest
                    .pipelines
                    .get(*p)
                    .ok_or_else(|| anyhow!("unknown pipeline '{p}'"))?;
                for s in &spec.steps {
                    if !v.contains(&s.artifact) {
                        v.push(s.artifact.clone());
                    }
                }
            }
            v
        };
        let mut executables = BTreeMap::new();
        for name in needed {
            let art = manifest
                .artifact(&name)
                .ok_or_else(|| anyhow!("artifact '{name}' missing from manifest"))?;
            let path = dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {}: {e}", art.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e}"))?;
            executables.insert(name, exe);
        }
        Ok(Runtime { manifest, dir: dir.to_path_buf(), client, executables })
    }

    /// The reference input (f32 LE) written by the AOT step.
    pub fn reference_input(&self) -> Result<Vec<f32>> {
        read_f32(&self.dir.join(&self.manifest.input_file))
    }

    /// The oracle output for the reference input.
    pub fn expected_output(&self) -> Result<Vec<f32>> {
        read_f32(&self.dir.join(&self.manifest.expected_file))
    }

    /// Execute a pipeline on `x` (flattened f32 of the manifest input
    /// shape). Returns the flattened output and traffic/wall stats.
    pub fn run_pipeline(&self, pipeline: &str, x: &[f32]) -> Result<(Vec<f32>, PipelineStats)> {
        let spec = self
            .manifest
            .pipelines
            .get(pipeline)
            .ok_or_else(|| anyhow!("unknown pipeline '{pipeline}'"))?;
        let in_shape = &self.manifest.input_shape;
        let expect: usize = in_shape.iter().product();
        if x.len() != expect {
            bail!("input length {} != {:?}", x.len(), in_shape);
        }
        let t0 = Instant::now();
        let mut buffers: BTreeMap<String, xla::Literal> = BTreeMap::new();
        let dims: Vec<i64> = in_shape.iter().map(|&d| d as i64).collect();
        buffers.insert(
            "x".into(),
            xla::Literal::vec1(x).reshape(&dims).map_err(|e| anyhow!("reshape x: {e}"))?,
        );

        let mut intermediate_bytes = 0.0;
        for step in &spec.steps {
            let exe = self
                .executables
                .get(&step.artifact)
                .ok_or_else(|| anyhow!("artifact '{}' not compiled", step.artifact))?;
            let args: Vec<&xla::Literal> = step
                .inputs
                .iter()
                .map(|b| {
                    buffers
                        .get(b)
                        .ok_or_else(|| anyhow!("buffer '{b}' undefined at '{}'", step.artifact))
                })
                .collect::<Result<_>>()?;
            let result = exe
                .execute::<&xla::Literal>(&args)
                .map_err(|e| anyhow!("execute {}: {e}", step.artifact))?;
            let root = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch {}: {e}", step.artifact))?;
            // every artifact returns a tuple (return_tuple=True in aot.py)
            let outs = root.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
            if outs.len() != step.outputs.len() {
                bail!(
                    "step '{}': {} outputs, manifest says {}",
                    step.artifact,
                    outs.len(),
                    step.outputs.len()
                );
            }
            for (name, lit) in step.outputs.iter().zip(outs) {
                intermediate_bytes += lit.size_bytes() as f64;
                buffers.insert(name.clone(), lit);
            }
        }
        let out = buffers
            .get(&spec.output)
            .ok_or_else(|| anyhow!("pipeline output '{}' missing", spec.output))?;
        let values = out.to_vec::<f32>().map_err(|e| anyhow!("read output: {e}"))?;
        Ok((
            values,
            PipelineStats {
                steps: spec.steps.len(),
                intermediate_bytes,
                wall: t0.elapsed(),
            },
        ))
    }

    /// Verify a pipeline against the AOT oracle; returns max |err|.
    pub fn verify_pipeline(&self, pipeline: &str) -> Result<f64> {
        let x = self.reference_input()?;
        let want = self.expected_output()?;
        let (got, _) = self.run_pipeline(pipeline, &x)?;
        if got.len() != want.len() {
            bail!("output length {} != expected {}", got.len(), want.len());
        }
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0f64, f64::max);
        Ok(max_err)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let raw = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    if raw.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), raw.len());
    }
    Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_f32_roundtrip() {
        let dir = std::env::temp_dir().join("dfmodel_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("v.bin");
        let vals = [1.5f32, -2.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32(&p).unwrap(), vals);
    }

    #[test]
    fn read_f32_rejects_ragged() {
        let dir = std::env::temp_dir().join("dfmodel_rt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 5]).unwrap();
        assert!(read_f32(&p).is_err());
    }
}
