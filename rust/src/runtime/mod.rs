//! Runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` (python/compile/aot.py) and executes the GPT-layer
//! mapping variants from the Rust hot path — Python is never on the
//! request path.
//!
//! Execution is delegated to a pluggable [`Backend`] (the executor
//! abstraction separating dataflow planning from execution): the default
//! [`InterpBackend`] is a pure-Rust HLO interpreter that runs offline with
//! zero dependencies; `--features pjrt` adds `pjrt::PjrtBackend` wrapping
//! the `xla` PJRT client.
//!
//! The executor interprets the manifest's pipeline wiring generically:
//! named buffers flow between steps, so the same code runs the fused
//! (1 partition), vendor (4 partitions), DFModel (4 partitions), and
//! kernel-by-kernel (14 steps) mappings, and reports the host-visible
//! intermediate traffic each incurs — the Fig. 2C-vs-2D contrast, executed
//! for real.

pub mod backend;
pub mod hlo;
pub mod interp;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{Backend, Executable, TensorBuf};
pub use interp::InterpBackend;
pub use manifest::{ArtifactSpec, Manifest, PipelineSpec, PipelineStep};

use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Compiled artifacts + manifest, ready to execute.
pub struct Runtime {
    pub manifest: Manifest,
    dir: PathBuf,
    backend_name: &'static str,
    executables: BTreeMap<String, Box<dyn Executable>>,
}

/// Execution statistics of one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineStats {
    pub steps: usize,
    /// Bytes of intermediate tensors that crossed the host boundary
    /// (the analytical model's matrix-D traffic, measured).
    pub intermediate_bytes: f64,
    pub wall: Duration,
}

/// Locate the artifact directory: `$DFMODEL_ARTIFACTS`, `artifacts/`, or
/// `../artifacts/` (tests run with the package root `rust/` as cwd while
/// `make artifacts` writes to the repository root).
pub fn find_artifacts() -> Option<PathBuf> {
    let mut candidates: Vec<PathBuf> = Vec::new();
    if let Ok(p) = std::env::var("DFMODEL_ARTIFACTS") {
        candidates.push(PathBuf::from(p));
    }
    candidates.push(PathBuf::from("artifacts"));
    candidates.push(PathBuf::from("../artifacts"));
    candidates.into_iter().find(|p| p.join("manifest.json").exists())
}

impl Runtime {
    /// Load with the default pure-Rust interpreter backend.
    pub fn load(dir: &Path, pipelines: &[&str]) -> Result<Self> {
        Self::load_with(dir, pipelines, &InterpBackend)
    }

    /// Load the manifest and compile every artifact needed by `pipelines`
    /// (all pipelines when empty) with an explicit backend.
    pub fn load_with(dir: &Path, pipelines: &[&str], backend: &dyn Backend) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let needed: Vec<String> = if pipelines.is_empty() {
            manifest.artifacts.iter().map(|a| a.name.clone()).collect()
        } else {
            let mut v = Vec::new();
            for p in pipelines {
                let spec = manifest
                    .pipelines
                    .get(*p)
                    .ok_or_else(|| err!("unknown pipeline '{p}'"))?;
                for s in &spec.steps {
                    if !v.contains(&s.artifact) {
                        v.push(s.artifact.clone());
                    }
                }
            }
            v
        };
        let mut executables = BTreeMap::new();
        for name in needed {
            let art = manifest
                .artifact(&name)
                .ok_or_else(|| err!("artifact '{name}' missing from manifest"))?;
            let exe = backend.compile(&name, &dir.join(&art.file))?;
            executables.insert(name, exe);
        }
        Ok(Runtime {
            manifest,
            dir: dir.to_path_buf(),
            backend_name: backend.name(),
            executables,
        })
    }

    /// The reference input (f32 LE) written by the AOT step.
    pub fn reference_input(&self) -> Result<Vec<f32>> {
        read_f32(&self.dir.join(&self.manifest.input_file))
    }

    /// The oracle output for the reference input.
    pub fn expected_output(&self) -> Result<Vec<f32>> {
        read_f32(&self.dir.join(&self.manifest.expected_file))
    }

    /// Execute a pipeline on `x` (flattened f32 of the manifest input
    /// shape). Returns the flattened output and traffic/wall stats.
    pub fn run_pipeline(&self, pipeline: &str, x: &[f32]) -> Result<(Vec<f32>, PipelineStats)> {
        let spec = self
            .manifest
            .pipelines
            .get(pipeline)
            .ok_or_else(|| err!("unknown pipeline '{pipeline}'"))?;
        let in_shape = &self.manifest.input_shape;
        let expect: usize = in_shape.iter().product();
        if x.len() != expect {
            bail!("input length {} != {:?}", x.len(), in_shape);
        }
        let t0 = Instant::now();
        let mut buffers: BTreeMap<String, TensorBuf> = BTreeMap::new();
        buffers.insert("x".into(), TensorBuf::new(in_shape.clone(), x.to_vec()));

        let mut intermediate_bytes = 0.0;
        for step in &spec.steps {
            let exe = self
                .executables
                .get(&step.artifact)
                .ok_or_else(|| err!("artifact '{}' not compiled", step.artifact))?;
            let mut args: Vec<&TensorBuf> = Vec::with_capacity(step.inputs.len());
            for b in &step.inputs {
                let buf = buffers
                    .get(b)
                    .ok_or_else(|| err!("buffer '{b}' undefined at '{}'", step.artifact))?;
                args.push(buf);
            }
            let outs = exe
                .execute(&args)
                .map_err(|e| e.context(format!("step '{}'", step.artifact)))?;
            if outs.len() != step.outputs.len() {
                bail!(
                    "step '{}': {} outputs, manifest says {}",
                    step.artifact,
                    outs.len(),
                    step.outputs.len()
                );
            }
            for (name, out) in step.outputs.iter().zip(outs) {
                intermediate_bytes += out.size_bytes() as f64;
                buffers.insert(name.clone(), out);
            }
        }
        let out = buffers
            .get(&spec.output)
            .ok_or_else(|| err!("pipeline output '{}' missing", spec.output))?;
        Ok((
            out.data.clone(),
            PipelineStats {
                steps: spec.steps.len(),
                intermediate_bytes,
                wall: t0.elapsed(),
            },
        ))
    }

    /// Verify a pipeline against the AOT oracle; returns max |err|.
    pub fn verify_pipeline(&self, pipeline: &str) -> Result<f64> {
        let x = self.reference_input()?;
        let want = self.expected_output()?;
        let (got, _) = self.run_pipeline(pipeline, &x)?;
        if got.len() != want.len() {
            bail!("output length {} != expected {}", got.len(), want.len());
        }
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| f64::from((a - b).abs()))
            .fold(0.0f64, f64::max);
        Ok(max_err)
    }

    /// Name of the backend that compiled this runtime's executables.
    pub fn platform(&self) -> String {
        self.backend_name.to_string()
    }
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let raw = std::fs::read(path).context(format!("read {}", path.display()))?;
    if raw.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), raw.len());
    }
    Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_f32_roundtrip() {
        let dir = std::env::temp_dir().join("dfmodel_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("v.bin");
        let vals = [1.5f32, -2.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32(&p).unwrap(), vals);
    }

    #[test]
    fn read_f32_rejects_ragged() {
        let dir = std::env::temp_dir().join("dfmodel_rt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 5]).unwrap();
        assert!(read_f32(&p).is_err());
    }

    #[test]
    fn load_reports_missing_dir() {
        let e = Runtime::load(Path::new("/nonexistent/artifacts"), &[]).unwrap_err();
        assert!(e.to_string().contains("manifest.json"), "{e}");
    }
}
