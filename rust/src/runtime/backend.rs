//! Pluggable execution backends (the StreamTensor-style split between
//! dataflow *planning* and *execution*): a [`Backend`] compiles one
//! HLO-text artifact into an [`Executable`]; the [`Runtime`](super::Runtime)
//! wires executables together along the manifest's pipelines with named
//! buffers.
//!
//! Two implementations ship in-tree:
//!  * [`interp::InterpBackend`](super::interp::InterpBackend) — pure-Rust
//!    HLO interpreter, the default; runs offline with zero dependencies;
//!  * `pjrt::PjrtBackend` — wraps the `xla` crate's PJRT CPU client,
//!    behind `--features pjrt` (feature-gated, so not doc-linked here).

use crate::util::error::Result;
use std::path::Path;

/// A flattened f32 tensor with its logical (row-major) shape — the buffer
/// currency that flows between pipeline steps.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorBuf {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorBuf {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> TensorBuf {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorBuf { shape, data }
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Host-visible payload size (f32).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Compiles HLO-text artifacts into executables.
pub trait Backend {
    /// Short backend name for diagnostics ("interp", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Compile the artifact `artifact` whose HLO text lives at `path`.
    fn compile(&self, artifact: &str, path: &Path) -> Result<Box<dyn Executable>>;
}

/// A compiled artifact. `execute` takes the entry computation's parameters
/// in positional order (by reference — pipeline buffers are reused across
/// steps without copying) and returns the root tuple's elements (every AOT
/// artifact returns a tuple — `return_tuple=True` in `aot.py`).
pub trait Executable {
    fn execute(&self, args: &[&TensorBuf]) -> Result<Vec<TensorBuf>>;
}
