//! PJRT-backed execution (`--features pjrt`): compiles the HLO-text
//! artifacts on the `xla` crate's PJRT CPU client. Offline builds link the
//! in-tree `xla-stub`, which type-checks this path but errors at runtime;
//! point the `xla` path dependency at the real crate to execute on PJRT
//! (DESIGN.md §Substitutions).

use super::backend::{Backend, Executable, TensorBuf};
use crate::err;
use crate::util::error::Result;
use std::path::Path;

/// Backend wrapping one PJRT client.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    /// Connect to the host CPU platform.
    pub fn cpu() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt: {e}"))?;
        Ok(PjrtBackend { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&self, artifact: &str, path: &Path) -> Result<Box<dyn Executable>> {
        let path_str = path.to_str().ok_or_else(|| err!("artifact path not utf-8"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| err!("parse {artifact}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| err!("compile {artifact}: {e}"))?;
        Ok(Box::new(PjrtExecutable { exe }))
    }
}

struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable for PjrtExecutable {
    fn execute(&self, args: &[&TensorBuf]) -> Result<Vec<TensorBuf>> {
        let mut lits = Vec::with_capacity(args.len());
        for a in args {
            let dims: Vec<i64> = a.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&a.data)
                .reshape(&dims)
                .map_err(|e| err!("reshape argument: {e}"))?;
            lits.push(lit);
        }
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let result = self
            .exe
            .execute::<&xla::Literal>(&refs)
            .map_err(|e| err!("execute: {e}"))?;
        let root = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| err!("execution produced no output buffer"))?
            .to_literal_sync()
            .map_err(|e| err!("fetch result: {e}"))?;
        // every artifact returns a tuple (return_tuple=True in aot.py)
        let outs = root.to_tuple().map_err(|e| err!("untuple result: {e}"))?;
        let mut bufs = Vec::with_capacity(outs.len());
        for lit in outs {
            let shape = lit.dims().map_err(|e| err!("result shape: {e}"))?;
            let data = lit.to_vec::<f32>().map_err(|e| err!("read result: {e}"))?;
            bufs.push(TensorBuf::new(shape, data));
        }
        Ok(bufs)
    }
}
