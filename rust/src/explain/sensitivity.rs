//! Sensitivity analysis: central-finite-difference elasticities of the
//! objective (step time for map goals, TPOT for serve goals) with respect
//! to each `SystemSpec` knob.
//!
//! The elasticity is the dimensionless local slope on log-log axes,
//!
//! ```text
//! e = ((f(x₊) − f(x₋)) / f(x₀)) · (x₀ / (x₊ − x₋))
//! ```
//!
//! generalized to asymmetric steps (the chip-count knob perturbs ×2 / ÷2
//! because chip counts are discrete powers of two in the topology
//! families; continuous knobs use ±5% relative steps). `e = −1` means
//! "doubling this knob halves the objective" — the knob the design is
//! bound on; `e ≈ 0` means the knob has slack. Knobs whose perturbed
//! evaluation is infeasible (or impossible, e.g. halving a 1-chip axis)
//! report `elasticity: null` and rank last.

use crate::system::SystemSpec;
use crate::util::json::Json;

/// Relative step used for continuous knobs (±5%).
pub const REL_STEP: f64 = 0.05;

/// One knob's ranked elasticity row.
#[derive(Debug, Clone, PartialEq)]
pub struct Elasticity {
    /// Knob name (`flops`, `mem_bw`, `mem_capacity`, `link_bw`, `sram`,
    /// `chips`).
    pub knob: &'static str,
    /// The central-difference elasticity; `None` when a perturbed side was
    /// infeasible.
    pub elasticity: Option<f64>,
    /// Objective at the base point (seconds).
    pub base: f64,
    /// Objective at the increased knob, when feasible.
    pub plus: Option<f64>,
    /// Objective at the decreased knob, when feasible.
    pub minus: Option<f64>,
    /// Relative step actually used on the + side (e.g. 0.05, or 1.0 for
    /// the ×2 chip-count step).
    pub rel_step: f64,
}

impl Elasticity {
    /// Build a row from the three objective evaluations. `x0`, `xp`, `xm`
    /// are the knob values (base / increased / decreased); a `None`
    /// objective marks that side infeasible and yields a `None`
    /// elasticity.
    pub(crate) fn central(
        knob: &'static str,
        (x0, xp, xm): (f64, f64, f64),
        base: f64,
        plus: Option<f64>,
        minus: Option<f64>,
    ) -> Elasticity {
        let elasticity = match (plus, minus) {
            (Some(fp), Some(fm)) if base > 0.0 && xp > xm => {
                Some(((fp - fm) / base) * (x0 / (xp - xm)))
            }
            _ => None,
        };
        Elasticity { knob, elasticity, base, plus, minus, rel_step: (xp - x0) / x0 }
    }

    /// JSON row; infeasible sides serialize as `null` (never `Infinity`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("knob", Json::from(self.knob)),
            ("elasticity", self.elasticity.map_or(Json::Null, Json::from)),
            ("base_s", Json::from(self.base)),
            ("plus_s", self.plus.map_or(Json::Null, Json::from)),
            ("minus_s", self.minus.map_or(Json::Null, Json::from)),
            ("rel_step", Json::from(self.rel_step)),
        ])
    }

    /// Compact `knob e=-0.82` cell for the one-line render.
    pub fn render(&self) -> String {
        match self.elasticity {
            Some(e) => format!("{} e={e:+.2}", self.knob),
            None => format!("{} e=n/a", self.knob),
        }
    }
}

/// Rank rows by |elasticity| descending; `None` rows last (stable within
/// ties).
pub(crate) fn rank(rows: &mut [Elasticity]) {
    rows.sort_by(|a, b| {
        match (a.elasticity, b.elasticity) {
            (Some(x), Some(y)) => y
                .abs()
                .partial_cmp(&x.abs())
                .unwrap_or(std::cmp::Ordering::Equal),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => std::cmp::Ordering::Equal,
        }
        .then_with(|| a.knob.cmp(b.knob))
    });
}

/// The continuous `SystemSpec` knobs the sensitivity pass perturbs (chip
/// count is handled separately — it rebuilds the topology).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum Knob {
    /// Peak chip FLOP/s (`chip.tflop_per_tile`).
    Flops,
    /// DRAM bandwidth (`memory.bandwidth`).
    MemBw,
    /// DRAM capacity (`memory.capacity`).
    MemCap,
    /// Inter-chip link bandwidth (`link.bandwidth` and every topology
    /// dimension's `link_bw`).
    LinkBw,
    /// On-chip SRAM capacity (`chip.sram_bytes`).
    Sram,
}

impl Knob {
    /// Report name of the knob.
    pub(crate) fn name(self) -> &'static str {
        match self {
            Knob::Flops => "flops",
            Knob::MemBw => "mem_bw",
            Knob::MemCap => "mem_capacity",
            Knob::LinkBw => "link_bw",
            Knob::Sram => "sram",
        }
    }
}

/// Clone `sys` with one knob scaled by `factor`. Calibrated collective
/// tables are *not* re-simulated — for `Calibrated` systems the link-bw
/// elasticity reflects only the analytical terms (documented in
/// DESIGN.md).
pub(crate) fn scaled_system(sys: &SystemSpec, knob: Knob, factor: f64) -> SystemSpec {
    use crate::util::units::{Bytes, BytesPerSec, FlopPerSec};
    let mut s = sys.clone();
    match knob {
        Knob::Flops => {
            s.chip.tflop_per_tile = FlopPerSec::new(s.chip.tflop_per_tile.raw() * factor);
        }
        Knob::MemBw => {
            s.memory.bandwidth = BytesPerSec::new(s.memory.bandwidth.raw() * factor);
        }
        Knob::MemCap => {
            s.memory.capacity = Bytes::new(s.memory.capacity.raw() * factor);
        }
        Knob::LinkBw => {
            s.link.bandwidth = BytesPerSec::new(s.link.bandwidth.raw() * factor);
            for d in &mut s.topology.dims {
                d.link_bw = BytesPerSec::new(d.link_bw.raw() * factor);
            }
        }
        Knob::Sram => {
            s.chip.sram_bytes = Bytes::new(s.chip.sram_bytes.raw() * factor);
        }
    }
    s
}

/// Clone a serving platform with one knob scaled by `factor` (`MemCap`
/// perturbs the per-chip device-memory capacity; chip count has no serving
/// analogue because TP×PP must cover the group exactly).
pub(crate) fn scaled_serving(
    sys: &crate::serving::ServingSystem,
    knob: Knob,
    factor: f64,
) -> crate::serving::ServingSystem {
    use crate::util::units::{Bytes, BytesPerSec, FlopPerSec};
    let mut s = sys.clone();
    match knob {
        Knob::Flops => {
            s.chip.tflop_per_tile = FlopPerSec::new(s.chip.tflop_per_tile.raw() * factor);
        }
        Knob::MemBw => s.mem_bw *= factor,
        Knob::MemCap => s.mem_cap *= factor,
        Knob::LinkBw => {
            s.link.bandwidth = BytesPerSec::new(s.link.bandwidth.raw() * factor);
        }
        Knob::Sram => {
            s.chip.sram_bytes = Bytes::new(s.chip.sram_bytes.raw() * factor);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn central_difference_recovers_power_law_exponent() {
        // f(x) = x^-1 has elasticity −1 everywhere; ±5% central difference
        // lands within O(step²).
        let x0 = 10.0;
        let (xp, xm) = (x0 * (1.0 + REL_STEP), x0 * (1.0 - REL_STEP));
        let f = |x: f64| 1.0 / x;
        let e = Elasticity::central("flops", (x0, xp, xm), f(x0), Some(f(xp)), Some(f(xm)));
        let got = e.elasticity.expect("feasible both sides");
        assert!((got - (-1.0)).abs() < 1e-2, "e = {got}");
    }

    #[test]
    fn infeasible_sides_yield_null_and_rank_last() {
        let mut rows = vec![
            Elasticity::central("sram", (1.0, 1.05, 0.95), 2.0, None, Some(2.0)),
            Elasticity::central("mem_bw", (1.0, 1.05, 0.95), 2.0, Some(1.9), Some(2.1)),
        ];
        assert_eq!(rows[0].elasticity, None);
        rank(&mut rows);
        assert_eq!(rows[0].knob, "mem_bw");
        assert_eq!(rows[1].knob, "sram");
        let j = rows[1].to_json();
        assert_eq!(j.get("elasticity"), Some(&Json::Null));
    }

    #[test]
    fn scaled_system_scales_every_topology_dim() {
        let sys = crate::dse::dse_systems_1024()[0].clone();
        let up = scaled_system(&sys, Knob::LinkBw, 2.0);
        assert!((up.link.bandwidth.raw() - sys.link.bandwidth.raw() * 2.0).abs() < 1.0);
        for (a, b) in up.topology.dims.iter().zip(&sys.topology.dims) {
            assert!((a.link_bw.raw() - b.link_bw.raw() * 2.0).abs() < 1.0);
        }
    }
}
