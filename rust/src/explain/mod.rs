//! The explain layer: observability into the *model's* decisions, not just
//! our code's phases (that is `obs`). Three views, all opt-in through
//! `Scenario::explained()` / `dfmodel explain` and zero-cost when off:
//!
//! 1. **Roofline attribution** ([`Attribution`]): the predicted step time
//!    decomposed per kernel and per hierarchy level — compute / SRAM /
//!    DRAM / inter-chip collectives / pipeline bubble — with the binding
//!    resource named. Shares sum to the total by construction (each level
//!    is a disjoint slice of the step-time composition), within 1e-9.
//! 2. **Optimizer decision audit** ([`AuditLedger`]): the top-K rejected
//!    candidates of each optimization phase (inter-chip plan loop,
//!    sharding selection, intra-chip fusion DP, pipeline stage DP) with
//!    their scores and the dominating term that killed each.
//! 3. **Sensitivity analysis** ([`Elasticity`]): central-finite-difference
//!    elasticities of the objective w.r.t. each `SystemSpec` knob, ranked.
//!
//! The collector follows the `obs` pattern: a relaxed atomic guards the
//! disabled path (one load, no allocation), recording goes to a
//! thread-local store, and the `!Send` session token ties start/finish to
//! one thread. Hooks in `pipeline`, `interchip`, and `intrachip` check
//! [`enabled`] before building any strings.

pub mod attribution;
pub mod ledger;
pub mod sensitivity;

pub use attribution::{Attribution, KernelShare, Levels, RooflineTag};
pub use ledger::{AuditEntry, AuditLedger, AuditPhase};
pub use sensitivity::Elasticity;

use crate::util::json::Json;
use std::cell::RefCell;
use std::fmt::Write as _;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of live capture sessions across all threads. Zero = every hook
/// is a single relaxed load.
static ACTIVE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static STORE: RefCell<Option<Store>> = const { RefCell::new(None) };
}

/// Everything the hooks record during one explained evaluation.
#[derive(Debug, Default)]
pub(crate) struct Store {
    pub(crate) attribution: Option<Attribution>,
    pub(crate) phases: Vec<ledger::PhaseAcc>,
    pub(crate) frontier_tags: Vec<String>,
}

/// Whether the *current thread* is recording an explain capture. The fast
/// path (no session anywhere) is one relaxed atomic load; hooks must check
/// this before building candidate strings.
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed) != 0 && STORE.with(|s| s.borrow().is_some())
}

/// Run `f` against the thread's store if a session is armed.
pub(crate) fn with_store<R>(f: impl FnOnce(&mut Store) -> R) -> Option<R> {
    if ACTIVE.load(Ordering::Relaxed) == 0 {
        return None;
    }
    STORE.with(|s| s.borrow_mut().as_mut().map(f))
}

/// Token for one explain capture; `!Send` so finish happens on the
/// recording thread.
pub(crate) struct ExplainSession {
    _not_send: PhantomData<*const ()>,
}

/// Arm the collector on this thread. Panics on nested sessions (one
/// explained evaluation at a time per thread).
pub(crate) fn start() -> ExplainSession {
    STORE.with(|s| {
        let mut slot = s.borrow_mut();
        assert!(slot.is_none(), "nested explain sessions are not supported");
        *slot = Some(Store::default());
    });
    ACTIVE.fetch_add(1, Ordering::Relaxed);
    ExplainSession { _not_send: PhantomData }
}

/// Disarm the collector and return what the hooks recorded.
pub(crate) fn finish(session: ExplainSession) -> Store {
    drop(session);
    ACTIVE.fetch_sub(1, Ordering::Relaxed);
    STORE.with(|s| s.borrow_mut().take()).expect("explain session store vanished")
}

/// Record the explorer's frontier attribution tags (explore goal only).
pub(crate) fn record_frontier_tags(tags: Vec<String>) {
    with_store(|s| s.frontier_tags = tags);
}

/// The `Report.explain` section: attribution + audit + sensitivity (map /
/// serve goals) or frontier tags (explore goal).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ExplainReport {
    /// Per-kernel / per-level step-time decomposition.
    pub attribution: Option<Attribution>,
    /// Rejected-candidate ledger of the optimizer phases.
    pub audit: Option<AuditLedger>,
    /// Ranked elasticities of the objective w.r.t. the system knobs.
    pub sensitivity: Vec<Elasticity>,
    /// One-line attribution tags for Pareto-frontier points.
    pub frontier_tags: Vec<String>,
}

impl ExplainReport {
    /// Stable JSON form: keys recursively sorted (`Json::sorted`) so
    /// explain exports diff cleanly across runs.
    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(&str, Json)> = Vec::new();
        if let Some(a) = &self.attribution {
            kv.push(("attribution", a.to_json()));
        }
        if let Some(l) = &self.audit {
            kv.push(("audit", l.to_json()));
        }
        if !self.sensitivity.is_empty() {
            kv.push(("sensitivity", Json::arr(self.sensitivity.iter().map(|e| e.to_json()))));
        }
        if !self.frontier_tags.is_empty() {
            kv.push((
                "frontier_tags",
                Json::arr(self.frontier_tags.iter().map(|t| Json::from(t.as_str()))),
            ));
        }
        Json::obj(kv).sorted()
    }

    /// Human rendering, appended to `Report::render` before the lint /
    /// stats footer.
    pub fn render(&self, top: usize) -> String {
        let mut s = String::new();
        if let Some(a) = &self.attribution {
            s.push_str(&a.render(top));
        }
        if let Some(l) = &self.audit {
            s.push_str(&l.render());
        }
        if !self.sensitivity.is_empty() {
            let _ = writeln!(
                s,
                "sensitivity : {}",
                self.sensitivity
                    .iter()
                    .map(Elasticity::render)
                    .collect::<Vec<_>>()
                    .join(" | ")
            );
        }
        if !self.frontier_tags.is_empty() {
            s.push_str("frontier attribution:\n");
            for t in &self.frontier_tags {
                let _ = writeln!(s, "  {t}");
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_path_is_off_by_default() {
        assert!(!enabled());
        assert!(with_store(|_| ()).is_none());
    }

    #[test]
    fn session_arms_and_disarms_this_thread() {
        let sess = start();
        assert!(enabled());
        ledger::record_candidate("interchip.plan", "TP2xPP1xDP1".into(), Some(1.0), "compute");
        let store = finish(sess);
        assert!(!enabled());
        assert_eq!(store.phases.len(), 1);
        assert_eq!(store.phases[0].considered, 1);
    }

    #[test]
    fn other_threads_stay_unarmed_during_a_session() {
        let sess = start();
        let other = std::thread::spawn(enabled).join().unwrap();
        assert!(!other, "worker threads must not record into the session");
        finish(sess);
    }
}
