//! Roofline attribution: slice the predicted step time into disjoint
//! per-hierarchy-level shares (compute / SRAM / DRAM / inter-chip /
//! pipeline bubble) and per-kernel shares, naming the binding resource.
//!
//! The decomposition is exact by construction: the pipeline composition is
//! `step = work + bubble + dp_exposed`, the work slice splits into the
//! intra-chip fraction and the p2p excess, and the intra-chip fraction is
//! distributed over partitions proportionally to their critical times
//! (which sum to the intra total). Every split conserves the total, so
//! `levels.sum() == total` to floating-point rounding (≪ 1e-9 relative).
//!
//! SRAM has no *time* term in DFModel (§V treats SRAM as a capacity
//! constraint: a fusion that exceeds SRAM is infeasible, it is never
//! slowed down), so the SRAM share is structurally zero; the level is kept
//! in the schema to make that explicit.

use crate::graph::DataflowGraph;
use crate::intrachip::IntraChipMapping;
use crate::roofline::{Bound, Roofline};
use crate::system::SystemSpec;
use crate::util::json::Json;
use crate::util::units::fmt_time;
use std::fmt::Write as _;

/// Seconds of the step attributed to each hierarchy level.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Levels {
    /// Tile compute (partitions whose critical time is `t_comp`).
    pub compute: f64,
    /// Always 0: SRAM is a capacity constraint, not a time term (see the
    /// module docs).
    pub sram: f64,
    /// DRAM streaming (partitions bound by `t_mem`).
    pub dram: f64,
    /// Inter-chip collectives + conversions + p2p excess + exposed DP
    /// all-reduce.
    pub interchip: f64,
    /// Pipeline fill/drain bubble.
    pub bubble: f64,
}

impl Levels {
    /// Total attributed seconds — equals the step time within rounding.
    pub fn sum(&self) -> f64 {
        self.compute + self.sram + self.dram + self.interchip + self.bubble
    }

    /// The level with the largest share.
    pub fn binding(&self) -> &'static str {
        let pairs = [
            ("compute", self.compute),
            ("sram", self.sram),
            ("dram", self.dram),
            ("interchip", self.interchip),
            ("bubble", self.bubble),
        ];
        pairs
            .iter()
            .fold(("compute", f64::MIN), |acc, &(n, v)| if v > acc.1 { (n, v) } else { acc })
            .0
    }

    /// JSON object with one `*_s` key per level (sums to `total_s`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("compute_s", Json::from(self.compute)),
            ("sram_s", Json::from(self.sram)),
            ("dram_s", Json::from(self.dram)),
            ("interchip_s", Json::from(self.interchip)),
            ("bubble_s", Json::from(self.bubble)),
        ])
    }
}

/// One kernel's slice of the step time.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelShare {
    /// Kernel name on the optimized (sharded) graph.
    pub name: String,
    /// Intra-chip partition the kernel was fused into.
    pub partition: usize,
    /// Seconds of the step attributed to this kernel.
    pub seconds: f64,
    /// Binding resource of its partition (`compute` / `dram` /
    /// `interchip`).
    pub bound: &'static str,
}

impl KernelShare {
    /// JSON row of the `kernels` array.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            ("partition", Json::from(self.partition)),
            ("seconds", Json::from(self.seconds)),
            ("bound", Json::from(self.bound)),
        ])
    }
}

/// Where the per-chip pass sits on the chip roofline (compute vs DRAM
/// side; the network roof needs byte counts the mapping does not expose).
#[derive(Debug, Clone, PartialEq)]
pub struct RooflineTag {
    /// Operational intensity of the per-chip pass, FLOP per DRAM byte.
    pub oi_mem: f64,
    /// The chip's memory ridge point (peak FLOP/s ÷ DRAM bandwidth).
    pub ridge_mem: f64,
    /// Which side of the ridge the pass sits on (`compute` / `memory`).
    pub bound: &'static str,
}

impl RooflineTag {
    /// JSON form of the roofline tag.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("oi_mem_flop_per_byte", Json::from(self.oi_mem)),
            ("ridge_mem_flop_per_byte", Json::from(self.ridge_mem)),
            ("bound", Json::from(self.bound)),
        ])
    }
}

/// The full attribution of one evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct Attribution {
    /// Total predicted step time (seconds) — the quantity the level and
    /// kernel shares sum to.
    pub total: f64,
    /// Level with the largest share.
    pub binding: &'static str,
    /// Per-hierarchy-level seconds.
    pub levels: Levels,
    /// Per-kernel seconds, sorted by share descending.
    pub kernels: Vec<KernelShare>,
    /// Chip-roofline position of the per-chip pass, when derivable.
    pub roofline: Option<RooflineTag>,
}

impl Attribution {
    /// JSON form (`explain.attribution`).
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("total_s", Json::from(self.total)),
            ("binding", Json::from(self.binding)),
            ("levels", self.levels.to_json()),
            ("kernels", Json::arr(self.kernels.iter().map(KernelShare::to_json))),
        ];
        if let Some(r) = &self.roofline {
            kv.push(("roofline", r.to_json()));
        }
        Json::obj(kv)
    }

    /// Human rendering (top `top` kernels).
    pub fn render(&self, top: usize) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "attribution : total {} | binding {}",
            fmt_time(self.total),
            self.binding
        );
        let pct = |v: f64| 100.0 * v / self.total.max(1e-30);
        let _ = writeln!(
            s,
            "  levels    : compute {:.1}% | sram {:.1}% | dram {:.1}% | interchip {:.1}% | bubble {:.1}%",
            pct(self.levels.compute),
            pct(self.levels.sram),
            pct(self.levels.dram),
            pct(self.levels.interchip),
            pct(self.levels.bubble),
        );
        if let Some(r) = &self.roofline {
            let _ = writeln!(
                s,
                "  roofline  : OI {:.1} FLOP/B vs ridge {:.1} ({}-side)",
                r.oi_mem, r.ridge_mem, r.bound
            );
        }
        for k in self.kernels.iter().take(top) {
            let _ = writeln!(
                s,
                "  kernel    : {:<24} {:>6.2}% ({})",
                k.name,
                pct(k.seconds),
                k.bound
            );
        }
        s
    }
}

/// Binding resource of one intra-chip partition, with the same tie-break
/// order as `IntraChipMapping::breakdown` so the level sums agree with the
/// Fig. 11/13/15/17 splits.
pub(crate) fn partition_bound(p: &crate::intrachip::PartitionMetrics) -> &'static str {
    if p.t_comp >= p.t_mem && p.t_comp >= p.t_net {
        "compute"
    } else if p.t_mem >= p.t_net {
        "dram"
    } else {
        "interchip"
    }
}

/// How the pipeline composed the step time out of its slices. All fields
/// in seconds except `intra_fraction`.
#[derive(Debug, Clone, Copy)]
pub(crate) struct StepComposition {
    /// Total step time.
    pub step: f64,
    /// Pipeline fill/drain bubble seconds.
    pub bubble: f64,
    /// Exposed (non-overlapped) data-parallel all-reduce seconds.
    pub dp_exposed: f64,
    /// Fraction of the steady-state work slice governed by the intra-chip
    /// pass (the rest is p2p-bound stage-time excess), in [0, 1].
    pub intra_fraction: f64,
}

/// Record the attribution of a map-goal evaluation into the armed store.
/// `g` is the sharded graph the intra-chip pass optimized.
pub(crate) fn record_map(
    g: &DataflowGraph,
    intra: &IntraChipMapping,
    sys: &SystemSpec,
    comp: &StepComposition,
) {
    let work = (comp.step - comp.bubble - comp.dp_exposed).max(0.0);
    let work_intra = work * comp.intra_fraction.clamp(0.0, 1.0);
    let p2p_excess = work - work_intra;

    let sum_t: f64 = intra.partitions.iter().map(|p| p.t_cri()).sum();
    let sum_t = sum_t.max(1e-30);
    let mut levels = Levels {
        interchip: p2p_excess + comp.dp_exposed,
        bubble: comp.bubble,
        ..Levels::default()
    };
    let members = intra.assignment.members();
    let mut kernels: Vec<KernelShare> = Vec::new();
    for (pi, p) in intra.partitions.iter().enumerate() {
        let share = work_intra * p.t_cri() / sum_t;
        let bound = partition_bound(p);
        match bound {
            "compute" => levels.compute += share,
            "dram" => levels.dram += share,
            _ => levels.interchip += share,
        }
        // split the partition's share over its member kernels by FLOP
        // (uniform when the partition has no FLOPs at all)
        let ks = members.get(pi).cloned().unwrap_or_default();
        if ks.is_empty() {
            continue;
        }
        let flops: Vec<f64> = ks.iter().map(|&k| g.kernels[k].flops).collect();
        let fsum: f64 = flops.iter().sum();
        for (&k, &f) in ks.iter().zip(&flops) {
            let w = if fsum > 0.0 { f / fsum } else { 1.0 / ks.len() as f64 };
            kernels.push(KernelShare {
                name: g.kernels[k].name.clone(),
                partition: pi,
                seconds: share * w,
                bound,
            });
        }
    }
    kernels.sort_by(|a, b| {
        b.seconds.partial_cmp(&a.seconds).unwrap_or(std::cmp::Ordering::Equal)
    });

    let roofline = {
        let r = Roofline::of_system(sys);
        let dram = intra.total_dram_traffic();
        let flops = g.total_flops();
        (dram > 0.0).then(|| RooflineTag {
            oi_mem: flops / dram,
            ridge_mem: r.ridge_mem(),
            bound: match r.bound(flops / dram, f64::INFINITY) {
                Bound::Compute => "compute",
                _ => "memory",
            },
        })
    };

    let binding = levels.binding();
    super::with_store(|s| {
        s.attribution = Some(Attribution {
            total: comp.step,
            binding,
            levels,
            kernels: kernels.clone(),
            roofline: roofline.clone(),
        });
    });
}

/// Attribution of a serving point: two rows (prefill / decode), their
/// breakdown fractions scaled to TTFT / TPOT seconds.
pub(crate) fn from_serving(m: &crate::serving::ServingMetrics) -> Attribution {
    let mut levels = Levels::default();
    let mut kernels = Vec::new();
    for (name, total, (c, mem, net)) in m.phase_rows() {
        levels.compute += total * c;
        levels.dram += total * mem;
        levels.interchip += total * net;
        let bound = if c >= mem && c >= net {
            "compute"
        } else if mem >= net {
            "dram"
        } else {
            "interchip"
        };
        kernels.push(KernelShare { name: name.into(), partition: 0, seconds: total, bound });
    }
    let binding = levels.binding();
    Attribution { total: m.ttft + m.tpot, binding, levels, kernels, roofline: None }
}
