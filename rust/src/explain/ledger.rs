//! The optimizer decision audit: each optimization phase records the
//! candidates it weighed, and the ledger keeps the top-K rejected ones
//! with the dominating term that killed each.
//!
//! Phases (in ledger order):
//! - `interchip.plan` — every (TP, PP, DP) plan of the §IV loop; rejected
//!   plans carry their critical time and the binding stage's dominating
//!   term, infeasible ones the capacity constraint that excluded them.
//! - `interchip.sharding` — per-kernel best single-swap alternatives to
//!   the chosen sharding labeling, dominated by the inherent-collective or
//!   conversion cost delta.
//! - `intrachip.partition` — adjacent-partition merge candidates of the §V
//!   fusion DP with the merged segment's binding resource.
//! - `pipeline.dp` — the winning plan's pipeline stages; the binding stage
//!   wins, the others are the slack the stage DP equalized against.
//! - `serving.split` — alternative TP×PP splits of the serving chip group,
//!   scored by TPOT and dominated by the decode phase's binding resource.

use crate::util::json::Json;
use std::fmt::Write as _;

/// Canonical phase ordering for reports.
const PHASE_ORDER: [&str; 5] = [
    "interchip.plan",
    "interchip.sharding",
    "intrachip.partition",
    "pipeline.dp",
    "serving.split",
];

/// One candidate the optimizer weighed.
#[derive(Debug, Clone, PartialEq)]
pub struct AuditEntry {
    /// Human-readable candidate description.
    pub candidate: String,
    /// Candidate score in seconds (lower is better); `None` = infeasible.
    pub score: Option<f64>,
    /// The term that dominated the decision (e.g. `compute`, `p2p`,
    /// `dram-capacity`, `conversion`).
    pub dominating: String,
}

impl AuditEntry {
    /// JSON row; infeasible candidates carry `"score_s": null` plus
    /// `"feasible": false` (never a raw `Infinity`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("candidate", Json::from(self.candidate.as_str())),
            ("score_s", self.score.map_or(Json::Null, Json::from)),
            ("feasible", Json::from(self.score.is_some())),
            ("dominating", Json::from(self.dominating.as_str())),
        ])
    }
}

/// Accumulator for one phase inside the thread-local store.
#[derive(Debug, Default)]
pub(crate) struct PhaseAcc {
    pub(crate) phase: &'static str,
    /// Total candidates weighed (entries may be capped later, this never
    /// is).
    pub(crate) considered: usize,
    pub(crate) best: Option<AuditEntry>,
    pub(crate) entries: Vec<AuditEntry>,
}

fn acc<'a>(store: &'a mut super::Store, phase: &'static str) -> &'a mut PhaseAcc {
    if let Some(i) = store.phases.iter().position(|p| p.phase == phase) {
        &mut store.phases[i]
    } else {
        store.phases.push(PhaseAcc { phase, ..PhaseAcc::default() });
        store.phases.last_mut().expect("just pushed")
    }
}

/// Record one weighed candidate (hooks must gate on `explain::enabled`).
pub(crate) fn record_candidate(
    phase: &'static str,
    candidate: String,
    score: Option<f64>,
    dominating: impl Into<String>,
) {
    let dominating = dominating.into();
    super::with_store(|s| {
        let a = acc(s, phase);
        a.considered += 1;
        a.entries.push(AuditEntry { candidate, score, dominating });
    });
}

/// Record the winning candidate of a phase.
pub(crate) fn record_winner(
    phase: &'static str,
    candidate: String,
    score: f64,
    dominating: impl Into<String>,
) {
    let dominating = dominating.into();
    super::with_store(|s| {
        acc(s, phase).best = Some(AuditEntry { candidate, score: Some(score), dominating });
    });
}

/// Record the winning plan's pipeline stages as the `pipeline.dp` phase:
/// the binding stage is the winner, every other stage a "rejected"
/// candidate whose slack the stage DP equalized.
pub(crate) fn record_pipeline_stages(
    stages: &[crate::interchip::StageMetrics],
    stage_of: &[usize],
) {
    let Some((bi, _)) = stages
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.t_cri().partial_cmp(&b.1.t_cri()).unwrap_or(std::cmp::Ordering::Equal))
    else {
        return;
    };
    let n_kernels = |si: usize| stage_of.iter().filter(|&&s| s == si).count();
    for (si, st) in stages.iter().enumerate() {
        let cand = format!("stage {si} ({} kernels)", n_kernels(si));
        let dom = stage_dominator(st);
        if si == bi {
            record_winner("pipeline.dp", cand, st.t_cri().raw(), dom);
        } else {
            record_candidate("pipeline.dp", cand, Some(st.t_cri().raw()), dom);
        }
    }
}

/// Dominating term of one pipeline stage (`compute` / `collective` /
/// `p2p`).
pub(crate) fn stage_dominator(s: &crate::interchip::StageMetrics) -> &'static str {
    let (c, n, p) = (s.t_comp.raw(), s.t_net.raw(), s.t_p2p.raw());
    if c >= n && c >= p {
        "compute"
    } else if n >= p {
        "collective"
    } else {
        "p2p"
    }
}

/// Dominating term of the binding stage of a staged plan.
pub(crate) fn stages_dominator(stages: &[crate::interchip::StageMetrics]) -> &'static str {
    stages
        .iter()
        .max_by(|a, b| a.t_cri().partial_cmp(&b.t_cri()).unwrap_or(std::cmp::Ordering::Equal))
        .map_or("compute", stage_dominator)
}

/// One phase of the assembled ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct AuditPhase {
    /// Phase name (see the module docs).
    pub phase: String,
    /// Total candidates the phase weighed.
    pub considered: usize,
    /// The winning candidate.
    pub best: Option<AuditEntry>,
    /// Top-K rejected candidates, best (lowest score) first, infeasible
    /// last.
    pub rejected: Vec<AuditEntry>,
}

impl AuditPhase {
    /// JSON form of one phase.
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("phase", Json::from(self.phase.as_str())),
            ("considered", Json::from(self.considered)),
        ];
        if let Some(b) = &self.best {
            kv.push(("best", b.to_json()));
        }
        kv.push(("rejected", Json::arr(self.rejected.iter().map(AuditEntry::to_json))));
        Json::obj(kv)
    }
}

/// The assembled decision audit (`explain.audit`).
#[derive(Debug, Clone, PartialEq)]
pub struct AuditLedger {
    /// The K in top-K rejected candidates per phase.
    pub top: usize,
    /// Phases in canonical order.
    pub phases: Vec<AuditPhase>,
}

impl AuditLedger {
    /// JSON form (`explain.audit`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("top", Json::from(self.top)),
            ("phases", Json::arr(self.phases.iter().map(AuditPhase::to_json))),
        ])
    }

    /// Human rendering: one line per phase.
    pub fn render(&self) -> String {
        let mut s = format!("audit (top {} per phase):\n", self.top);
        for p in &self.phases {
            let best = p.best.as_ref().map_or("-".to_string(), |b| {
                format!(
                    "{} {} ({})",
                    b.candidate,
                    b.score.map_or("-".into(), |v| format!("{v:.3e}s")),
                    b.dominating
                )
            });
            let _ = write!(s, "  {:<20} {} candidates | best {best}", p.phase, p.considered);
            if !p.rejected.is_empty() {
                let rej: Vec<String> = p
                    .rejected
                    .iter()
                    .map(|e| match e.score {
                        Some(v) => format!("{} {:.3e}s ({})", e.candidate, v, e.dominating),
                        None => format!("{} infeasible ({})", e.candidate, e.dominating),
                    })
                    .collect();
                let _ = write!(s, " | rejected: {}", rej.join(", "));
            }
            s.push('\n');
        }
        s
    }
}

/// Assemble the ledger from the raw per-phase accumulators: canonical
/// phase order, winner dropped from the rejected list, rejected sorted by
/// score ascending (infeasible last) and capped at `top`.
pub(crate) fn build(phases: &[PhaseAcc], top: usize) -> Option<AuditLedger> {
    if phases.is_empty() {
        return None;
    }
    let rank = |name: &str| PHASE_ORDER.iter().position(|&p| p == name).unwrap_or(usize::MAX);
    let mut order: Vec<usize> = (0..phases.len()).collect();
    order.sort_by_key(|&i| (rank(phases[i].phase), phases[i].phase));
    let assembled = order
        .into_iter()
        .map(|i| {
            let acc = &phases[i];
            let mut rejected: Vec<AuditEntry> = acc
                .entries
                .iter()
                .filter(|e| acc.best.as_ref().is_none_or(|b| b.candidate != e.candidate))
                .cloned()
                .collect();
            rejected.sort_by(|a, b| match (a.score, b.score) {
                (Some(x), Some(y)) => {
                    x.partial_cmp(&y).unwrap_or(std::cmp::Ordering::Equal)
                }
                (Some(_), None) => std::cmp::Ordering::Less,
                (None, Some(_)) => std::cmp::Ordering::Greater,
                (None, None) => a.candidate.cmp(&b.candidate),
            });
            rejected.truncate(top);
            AuditPhase {
                phase: acc.phase.to_string(),
                considered: acc.considered,
                best: acc.best.clone(),
                rejected,
            }
        })
        .collect();
    Some(AuditLedger { top, phases: assembled })
}
