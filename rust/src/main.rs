//! `dfmodel` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   catalog                       print the Table V chip catalog
//!   figure <id>|--all             regenerate paper figures/tables (results/)
//!   optimize [--chips N ...]      optimize a GPT mapping and print it
//!   dse --workload llm|dlrm|hpl|fft   run the 80-config sweep
//!   serve [--tp N --pp N ...]     serving model (Fig. 20 style point)
//!   simulate [--qps R ...]        request-level cluster serving simulation
//!   plan --qps R --slo-ttft S --slo-tpot S   SLO-aware capacity planner
//!   fabric [--topo F --chips N --coll C ...]  link-level collective simulation
//!   topo [--topo F --chips N]     topology facts (links, bisection bandwidth)
//!   run-pipeline <name>           execute an AOT pipeline via PJRT
//!   verify                        verify every pipeline against the oracle

use dfmodel::figures;
use dfmodel::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("catalog") => {
            print!("{}", figures::table5());
            0
        }
        Some("figure") => cmd_figure(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("dse") => cmd_dse(&args),
        Some("serve") => cmd_serve(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("plan") => cmd_plan(&args),
        Some("fabric") => cmd_fabric(&args),
        Some("topo") => cmd_topo(&args),
        Some("run") => cmd_run(&args),
        Some("run-pipeline") => cmd_run_pipeline(&args),
        Some("verify") => cmd_verify(&args),
        _ => {
            eprintln!(
                "usage: dfmodel <catalog|figure|optimize|dse|serve|simulate|plan|fabric|topo|run|run-pipeline|verify> [options]\n\
                 figures: {}",
                figures::ALL.join(" ")
            );
            2
        }
    };
    std::process::exit(code);
}

fn cmd_figure(args: &Args) -> i32 {
    let ids: Vec<String> = if args.has_flag("all") {
        figures::ALL.iter().map(|s| s.to_string()).collect()
    } else if args.positional.is_empty() {
        eprintln!("figure: need an id or --all (ids: {})", figures::ALL.join(" "));
        return 2;
    } else {
        args.positional.clone()
    };
    let mut failed = 0;
    for id in &ids {
        match figures::generate(id) {
            Ok(out) => println!("{out}"),
            Err(e) => {
                // one bad figure id or infeasible plan degrades to an error
                // line instead of aborting the whole run
                eprintln!("figure '{id}': {e}");
                failed += 1;
            }
        }
    }
    i32::from(failed > 0)
}

fn cmd_optimize(args: &Args) -> i32 {
    use dfmodel::system::{chip, interconnect, memory, topology, SystemSpec};
    let chips = args.get_usize("chips", 8);
    let chip = match args.get_or("chip", "sn10") {
        "sn10" => chip::sn10(),
        "sn30" => chip::sn30(),
        "sn40l" => chip::sn40l(),
        "h100" => chip::h100(),
        "a100" => chip::a100(),
        "tpuv4" => chip::tpu_v4(),
        "wse2" => chip::wse2(),
        other => {
            eprintln!("unknown chip '{other}'");
            return 2;
        }
    };
    let link = match args.get_or("link", "pcie4") {
        "pcie4" => interconnect::pcie4(),
        "nvlink4" => interconnect::nvlink4(),
        other => {
            eprintln!("unknown link '{other}'");
            return 2;
        }
    };
    let mem = match args.get_or("mem", "ddr4") {
        "ddr4" => memory::ddr4(),
        "hbm3" => memory::hbm3(),
        other => {
            eprintln!("unknown memory '{other}'");
            return 2;
        }
    };
    let sys = SystemSpec::new(chip, mem, link.clone(), topology::ring(chips, &link));
    let cfg = match args.get_or("model", "gpt3-175b") {
        "gpt3-175b" => dfmodel::graph::gpt::gpt3_175b(),
        "gpt3-1t" => dfmodel::graph::gpt::gpt3_1t(),
        other => {
            eprintln!("unknown model '{other}'");
            return 2;
        }
    };
    println!("system: {}", sys.describe());
    match dfmodel::pipeline::llm_training(&cfg, &sys, args.get_f64("batch", 64.0)) {
        Some(r) => {
            println!("chosen degrees: TP={} PP={} DP={}", r.tp, r.pp, r.dp);
            println!("step time: {}", dfmodel::util::units::fmt_time(r.step_time));
            println!("utilization: {:.3}", r.utilization);
            let (c, m, n) = r.breakdown_frac();
            println!("breakdown: compute {c:.2} | memory {m:.2} | network {n:.2}");
            0
        }
        None => {
            eprintln!("no feasible mapping (capacity constraints)");
            1
        }
    }
}

fn cmd_dse(args: &Args) -> i32 {
    use dfmodel::dse::Workload;
    let w = match args.get_or("workload", "llm") {
        "llm" => Workload::Llm,
        "dlrm" => Workload::Dlrm,
        "hpl" => Workload::Hpl,
        "fft" => Workload::Fft,
        other => {
            eprintln!("unknown workload '{other}'");
            return 2;
        }
    };
    println!("{}", figures::dse_figs::dse_figure(w));
    0
}

fn cmd_serve(args: &Args) -> i32 {
    use dfmodel::serving::{evaluate, sn40l_x16, ServingPoint};
    let tp = args.get_usize("tp", 16);
    let pp = args.get_usize("pp", 1);
    let sys = sn40l_x16();
    let Some(m) = evaluate(
        &dfmodel::graph::llama::llama3_8b(),
        &sys,
        &ServingPoint {
            tp,
            pp,
            batch: args.get_f64("batch", 1.0),
            prompt_len: args.get_f64("prompt", 1024.0),
            context: args.get_f64("context", 1024.0),
        },
    ) else {
        eprintln!("infeasible split: tp {tp} x pp {pp} != {} chips", sys.n_chips);
        return 2;
    };
    println!("TTFT: {}", dfmodel::util::units::fmt_time(m.ttft));
    println!("prefill: {:.0} tok/s", m.prefill_tps);
    println!("TPOT: {}", dfmodel::util::units::fmt_time(m.tpot));
    println!("decode: {:.0} tok/s", m.decode_tps);
    0
}

/// Parse `--model 8b|70b|405b` (the Llama-3 serving family).
fn parse_model(args: &Args, default: &str) -> Result<dfmodel::graph::llama::LlamaConfig, String> {
    match args.get_or("model", default) {
        "8b" => Ok(dfmodel::graph::llama::llama3_8b()),
        "70b" => Ok(dfmodel::graph::llama::llama3_70b()),
        "405b" => Ok(dfmodel::graph::llama::llama3_405b()),
        other => Err(format!("unknown model '{other}' (known: 8b 70b 405b)")),
    }
}

/// Parse `--qps`: must be a positive, finite request rate.
fn parse_qps(args: &Args, default: f64) -> Result<f64, String> {
    let qps = args.get_f64("qps", default);
    if qps.is_finite() && qps > 0.0 {
        Ok(qps)
    } else {
        Err(format!("--qps must be a positive rate, got {qps}"))
    }
}

/// `dfmodel simulate` — request-level cluster serving simulation on SN40L
/// replicas of `--tp` × `--pp` chips each.
fn cmd_simulate(args: &Args) -> i32 {
    use dfmodel::cluster::engine::{simulate, ReplicaConfig, Slo};
    use dfmodel::cluster::workload::{Arrivals, LengthDist, TraceSpec};
    let (model, rate) = match (parse_model(args, "8b"), parse_qps(args, 4.0)) {
        (Ok(m), Ok(q)) => (m, q),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let tp = args.get_usize("tp", 16);
    let pp = args.get_usize("pp", 1);
    let mut sys = dfmodel::serving::sn40l_x16();
    sys.n_chips = tp * pp;
    let mut cfg = ReplicaConfig::new(model, sys, tp, pp);
    cfg.max_batch = args.get_usize("max-batch", 32);
    let replicas = args.get_usize("replicas", 1);
    let arrivals = match args.get_or("arrivals", "poisson") {
        "poisson" => Arrivals::Poisson { rate },
        "bursty" => Arrivals::Bursty {
            base: rate * 0.25,
            peak: rate * 1.75,
            period: args.get_f64("period", 60.0),
        },
        other => {
            eprintln!("unknown arrival process '{other}' (known: poisson bursty)");
            return 2;
        }
    };
    let spec = TraceSpec {
        seed: args.get_usize("seed", 17) as u64,
        n_requests: args.get_usize("requests", 200),
        arrivals,
        prompt: LengthDist { mean: args.get_f64("prompt", 1024.0), sigma: 0.4, min: 16, max: 8192 },
        output: LengthDist { mean: args.get_f64("output", 128.0), sigma: 0.6, min: 2, max: 2048 },
    };
    let slo = Slo { ttft: args.get_f64("slo-ttft", 1.0), tpot: args.get_f64("slo-tpot", 0.02) };
    println!(
        "simulating {} requests @ {rate} rps on {replicas} replica(s) of {} x{} (TP{tp}xPP{pp})",
        spec.n_requests, cfg.sys.chip.name, cfg.sys.n_chips
    );
    match simulate(&cfg, replicas, &spec.generate(), &slo) {
        Some(r) => {
            print!("{}", r.render());
            0
        }
        None => {
            eprintln!("infeasible configuration (tp*pp != chips, or weights exceed device memory)");
            1
        }
    }
}

/// `dfmodel plan` — cheapest fleet meeting a QPS + SLO target.
fn cmd_plan(args: &Args) -> i32 {
    use dfmodel::cluster::engine::Slo;
    use dfmodel::cluster::planner::{plan, render, PlanTarget, PlanTraffic};
    let (model, qps) = match (parse_model(args, "70b"), parse_qps(args, 2.0)) {
        (Ok(m), Ok(q)) => (m, q),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let target = PlanTarget {
        qps,
        slo: Slo { ttft: args.get_f64("slo-ttft", 2.0), tpot: args.get_f64("slo-tpot", 0.05) },
        attainment: args.get_f64("attainment", 0.9),
    };
    let traffic = PlanTraffic {
        seed: args.get_usize("seed", 17) as u64,
        n_requests: args.get_usize("requests", 300),
        ..Default::default()
    };
    let res = plan(&model, &target, &traffic);
    print!("{}", render(&res, args.get_usize("top", 12)));
    match res.best {
        Some(i) => {
            let c = &res.candidates[i];
            println!(
                "plan: {} x{} per replica, TP{}xPP{}, {} replica(s) = {} chips, ${:.2}/hr (capex ${:.0})",
                c.platform,
                c.group,
                c.tp,
                c.pp,
                c.replicas,
                c.chips_total,
                c.usd_per_hour,
                c.capex_usd
            );
            0
        }
        None => {
            eprintln!(
                "no fleet in the catalog meets {} rps at TTFT<={}s / TPOT<={}s ({}% attainment)",
                target.qps,
                target.slo.ttft,
                target.slo.tpot,
                target.attainment * 100.0
            );
            1
        }
    }
}

/// Parse `--topo <family> --chips N --link L` into a topology.
fn parse_topology(
    args: &Args,
) -> Result<(dfmodel::system::Topology, dfmodel::system::LinkTech), String> {
    use dfmodel::system::{interconnect, topology};
    let link = match args.get_or("link", "nvlink4") {
        "nvlink4" => interconnect::nvlink4(),
        "pcie4" => interconnect::pcie4(),
        "rdu" => interconnect::rdu_fabric(),
        other => return Err(format!("unknown link '{other}' (known: nvlink4 pcie4 rdu)")),
    };
    let family = args.get_or("topo", "torus2d");
    let chips = args.get_usize("chips", 16);
    match topology::by_name(family, chips, &link) {
        Some(t) => Ok((t, link)),
        None => Err(format!(
            "no '{family}' topology at {chips} chips \
             (families: ring torus2d torus3d dragonfly dgx1 dgx2; \
             dgx1 needs chips%8==0, dgx2 chips%16==0)"
        )),
    }
}

/// `dfmodel fabric` — link-level collective simulation: every algorithm
/// family vs the analytical α-β model on one topology.
fn cmd_fabric(args: &Args) -> i32 {
    use dfmodel::collective::{self, Collective};
    use dfmodel::fabric::{self, Algo, Routing, SimConfig};
    use dfmodel::util::units::{fmt_bw, fmt_time};
    let (topo, _link) = match parse_topology(args) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let coll = match args.get_or("coll", "allreduce") {
        "allreduce" => Collective::AllReduce,
        "allgather" => Collective::AllGather,
        "reducescatter" => Collective::ReduceScatter,
        "alltoall" => Collective::AllToAll,
        "broadcast" => Collective::Broadcast,
        "p2p" => Collective::P2P,
        other => {
            eprintln!(
                "unknown collective '{other}' \
                 (known: allreduce allgather reducescatter alltoall broadcast p2p)"
            );
            return 2;
        }
    };
    let Some(routing) = Routing::parse(args.get_or("routing", "dimorder")) else {
        eprintln!("unknown routing (known: dimorder adaptive)");
        return 2;
    };
    let bytes = args.get_f64("bytes", args.get_f64("mb", 64.0) * 1e6);
    let cfg = SimConfig {
        routing,
        seed: args.get_usize("seed", 0) as u64,
        ..Default::default()
    };
    let g = fabric::FabricGraph::new(&topo);
    println!(
        "fabric : {} | {} chips | {} nodes | {} links | bisection {} | routing {}",
        topo.name,
        topo.n_chips(),
        g.n_nodes(),
        g.links.len(),
        fmt_bw(topo.bisection_bytes_per_s()),
        routing.name()
    );
    let dims: Vec<&dfmodel::system::Dim> = topo.dims.iter().collect();
    let ana = collective::time_hier(coll, bytes, &dims);
    println!("collective: {coll:?} {:.2} MB/chip | analytical {}", bytes / 1e6, fmt_time(ana));
    let group: Vec<usize> = (0..topo.n_chips()).collect();
    let mut evals = fabric::evaluate_algos(&g, &group, coll, bytes, &cfg);
    if let Some(name) = args.get("algo") {
        let Some(a) = Algo::parse(name) else {
            eprintln!("unknown algo '{name}' (known: ring hd direct hier)");
            return 2;
        };
        evals.retain(|e| e.algo == a);
    }
    if evals.is_empty() {
        eprintln!("no feasible algorithm for this (collective, group)");
        return 1;
    }
    println!(
        "{:<8} {:>12} {:>10} {:>9} {:>8} {:>9}",
        "algo", "simulated", "vs-ana", "max-link", "msgs", "packets"
    );
    for e in &evals {
        println!(
            "{:<8} {:>12} {:>9.1}% {:>8.0}% {:>8} {:>9}",
            e.algo.name(),
            fmt_time(e.time),
            (e.time / ana - 1.0) * 100.0,
            e.max_link_util * 100.0,
            e.msgs,
            e.packets
        );
    }
    let best = &evals[0];
    println!(
        "best: {} at {} ({:+.1}% vs analytical)",
        best.algo.name(),
        fmt_time(best.time),
        (best.time / ana - 1.0) * 100.0
    );
    let trace_limit = args.get_usize("trace", 0);
    if trace_limit > 0 {
        let sched = dfmodel::fabric::build(&g, best.algo, coll, &group, bytes)
            .expect("best algo was feasible");
        let tcfg = SimConfig { trace_limit, ..cfg };
        let r = dfmodel::fabric::simulate(&g, &sched, &tcfg);
        println!("trace (first {} packet-hops, seed {}):", r.trace.len(), tcfg.seed);
        for line in &r.trace {
            println!("  {line}");
        }
    }
    0
}

/// `dfmodel topo` — chip/link counts and bisection bandwidth of a topology.
fn cmd_topo(args: &Args) -> i32 {
    use dfmodel::util::units::fmt_bw;
    let (topo, _link) = match parse_topology(args) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!("{}", topo.name);
    println!("chips      : {}", topo.n_chips());
    for (i, d) in topo.dims.iter().enumerate() {
        println!(
            "dim {i}      : {:?} x{} ({:?}) | {} per link | bisection {} links",
            d.kind,
            d.size,
            d.fabric,
            fmt_bw(d.link_bw),
            d.bisection_links()
        );
    }
    println!("links      : {:.0}", topo.total_links());
    println!("bisection  : {} one-way", fmt_bw(topo.bisection_bytes_per_s()));
    0
}

/// `dfmodel run --config exp.json` — declarative experiment launcher.
fn cmd_run(args: &Args) -> i32 {
    let Some(path) = args.get("config") else {
        eprintln!("run: need --config <file.json>");
        return 2;
    };
    match dfmodel::config::Experiment::load(std::path::Path::new(path)) {
        Ok(exp) => match exp.run() {
            Ok(result) => {
                println!("{}", result.pretty());
                0
            }
            Err(e) => {
                eprintln!("{e}");
                1
            }
        },
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

/// Load a runtime honoring `--backend interp|pjrt` (default: interp).
fn load_runtime(
    dir: &std::path::Path,
    pipelines: &[&str],
    args: &Args,
) -> Result<dfmodel::runtime::Runtime, dfmodel::util::error::Error> {
    match args.get_or("backend", "interp") {
        "interp" => dfmodel::runtime::Runtime::load(dir, pipelines),
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let b = dfmodel::runtime::pjrt::PjrtBackend::cpu()?;
            dfmodel::runtime::Runtime::load_with(dir, pipelines, &b)
        }
        other => Err(dfmodel::err!(
            "unknown backend '{other}'{}",
            if cfg!(feature = "pjrt") { "" } else { " (built without the pjrt feature)" }
        )),
    }
}

fn artifacts_dir() -> Result<std::path::PathBuf, dfmodel::util::error::Error> {
    dfmodel::runtime::find_artifacts()
        .ok_or_else(|| dfmodel::err!("artifacts/ not found — run `make artifacts` first"))
}

fn cmd_run_pipeline(args: &Args) -> i32 {
    let Some(name) = args.positional.first() else {
        eprintln!("run-pipeline: need a pipeline name (fused|kernel_by_kernel|vendor|dfmodel)");
        return 2;
    };
    let dir = match artifacts_dir() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    match load_runtime(&dir, &[name.as_str()], args) {
        Ok(rt) => {
            let x = match rt.reference_input() {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            match rt.run_pipeline(name, &x) {
                Ok((out, stats)) => {
                    println!(
                        "pipeline '{name}': {} steps, {:.1} KB intermediates, {:?}",
                        stats.steps,
                        stats.intermediate_bytes / 1e3,
                        stats.wall
                    );
                    println!("output[0..4] = {:?}", &out[..4.min(out.len())]);
                    0
                }
                Err(e) => {
                    eprintln!("{e}");
                    1
                }
            }
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_verify(args: &Args) -> i32 {
    let dir = match artifacts_dir() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    match load_runtime(&dir, &[], args) {
        Ok(rt) => {
            println!("backend: {}", rt.platform());
            let mut bad = 0;
            for name in ["fused", "kernel_by_kernel", "vendor", "dfmodel"] {
                match rt.verify_pipeline(name) {
                    Ok(err) => {
                        let ok = err < rt.manifest.tolerance.max(1e-3);
                        println!(
                            "{name:<18} max|err| = {err:.2e}  {}",
                            if ok { "OK" } else { "FAIL" }
                        );
                        if !ok {
                            bad += 1;
                        }
                    }
                    Err(e) => {
                        println!("{name:<18} ERROR: {e}");
                        bad += 1;
                    }
                }
            }
            i32::from(bad > 0)
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
