//! `dfmodel` CLI — the L3 leader entrypoint.
//!
//! Subcommands (scenario-driven ones accept `--scenario <file.json>` to
//! load a full `api::Scenario`, and `--json` for the machine-readable
//! report):
//!   catalog                       print the Table V chip catalog
//!   figure <id>|--all             regenerate paper figures/tables (results/)
//!   optimize [--chips N ...]      map a GPT workload and print the report
//!   map                           alias of optimize (the scenario goal name)
//!   dse --workload llm|dlrm|hpl|fft   run the 80-config sweep
//!   explore [--workload W --budget N --no-prune]  Pareto-frontier explorer
//!   explain [--scenario f.json|--workload W] [--top K] [--no-sensitivity]
//!                                 bottleneck attribution + optimizer audit
//!   serve [--tp N --pp N ...]     serving model (Fig. 20 style point)
//!   simulate [--qps R --requests N --fleet N --exact-percentiles ...]
//!                                 request-level cluster serving simulation
//!                                 (streams arrivals: N can be 10^6+ in
//!                                 constant memory; --fleet simulates that
//!                                 many replicas in one process)
//!   plan --qps R --slo-ttft S --slo-tpot S   SLO-aware capacity planner
//!   fabric [--topo F --chips N --coll C ...]  link-level collective simulation
//!   daemon [--addr H:P --workers N --cache-entries N --queue-cap N --max-body B]
//!                                 persistent HTTP evaluation service (dfmodeld)
//!   lint <file.json ...> [--json]  static checks on scenario/graph files
//!   topo [--topo F --chips N]     topology facts (links, bisection bandwidth)
//!   bench-check [--current F --baseline F]  CI bench-regression gate
//!   run --config exp.json         legacy declarative experiment launcher
//!   run-pipeline <name>           execute an AOT pipeline via the runtime
//!   verify                        verify every pipeline against the oracle
//!   version | --version           print the version
//!
//! Every scenario-driven subcommand also accepts `--trace <file>` (write a
//! Chrome trace-event JSON — open it in Perfetto / chrome://tracing) and
//! `--stats` (append the span tree + metrics to the report output); either
//! flag arms the in-tree `obs` instrumentation for that run.

use dfmodel::api::{Goal, Scenario, SystemCfg};
use dfmodel::figures;
use dfmodel::util::cli::{suggest, Args};

const SUBCOMMANDS: &[&str] = &[
    "catalog",
    "figure",
    "optimize",
    "map",
    "dse",
    "explore",
    "explain",
    "serve",
    "simulate",
    "plan",
    "fabric",
    "daemon",
    "lint",
    "topo",
    "bench-check",
    "run",
    "run-pipeline",
    "verify",
    "version",
];

fn usage() {
    eprintln!(
        "usage: dfmodel <{}> [options]\n\
         figures: {}\n\
         scenario subcommands (optimize/map dse explore explain serve simulate plan fabric)\n\
         accept --scenario <file.json>, --json, --trace <out.json> (Perfetto), and --stats",
        SUBCOMMANDS.join("|"),
        figures::ALL.join(" ")
    );
}

fn main() {
    let args = Args::from_env();
    if args.has_flag("version") {
        println!("dfmodel {}", env!("CARGO_PKG_VERSION"));
        std::process::exit(0);
    }
    let code = match args.subcommand.as_deref() {
        Some("catalog") => {
            print!("{}", figures::table5());
            0
        }
        Some("figure") => cmd_figure(&args),
        Some("optimize") | Some("map") => cmd_optimize(&args),
        Some("dse") => cmd_dse(&args),
        Some("explore") => cmd_explore(&args),
        Some("explain") => cmd_explain(&args),
        Some("serve") => cmd_serve(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("plan") => cmd_plan(&args),
        Some("fabric") => cmd_fabric(&args),
        Some("daemon") => cmd_daemon(&args),
        Some("lint") => cmd_lint(&args),
        Some("topo") => cmd_topo(&args),
        Some("bench-check") => cmd_bench_check(&args),
        Some("run") => cmd_run(&args),
        Some("run-pipeline") => cmd_run_pipeline(&args),
        Some("verify") => cmd_verify(&args),
        Some("version") => {
            println!("dfmodel {}", env!("CARGO_PKG_VERSION"));
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            if let Some(s) = suggest(other, SUBCOMMANDS) {
                eprintln!("did you mean '{s}'?");
            }
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn cmd_figure(args: &Args) -> i32 {
    let ids: Vec<String> = if args.has_flag("all") {
        figures::ALL.iter().map(|s| s.to_string()).collect()
    } else if args.positional.is_empty() {
        eprintln!("figure: need an id or --all (ids: {})", figures::ALL.join(" "));
        return 2;
    } else {
        args.positional.clone()
    };
    let mut failed = 0;
    for id in &ids {
        match figures::generate(id) {
            Ok(out) => println!("{out}"),
            Err(e) => {
                // one bad figure id or infeasible plan degrades to an error
                // line instead of aborting the whole run
                eprintln!("figure '{id}': {e}");
                if let Some(s) = suggest(id, figures::ALL) {
                    eprintln!("did you mean '{s}'?");
                }
                failed += 1;
            }
        }
    }
    i32::from(failed > 0)
}

/// Load `--scenario <file>` (validating its goal against the subcommand)
/// or build one from the flag set.
fn load_scenario(
    args: &Args,
    want: Goal,
    build: impl FnOnce(&Args) -> Result<Scenario, String>,
) -> Result<Scenario, String> {
    let s = match args.get("scenario") {
        Some(path) => Scenario::load(std::path::Path::new(path)).map_err(|e| e.to_string())?,
        None => {
            let s = build(args)?;
            s.check().map_err(|e| e.to_string())?;
            s
        }
    };
    if s.goal != want {
        return Err(format!(
            "scenario goal '{}' does not match this subcommand (expected '{}')",
            s.goal.name(),
            want.name()
        ));
    }
    Ok(s)
}

/// Print a report (`--json` switches to the JSON form) and derive the
/// exit code: a plan that found no feasible fleet is a failure exit.
fn print_report(args: &Args, r: &dfmodel::api::Report) -> i32 {
    if args.has_flag("json") {
        println!("{}", r.to_json().pretty());
    } else {
        print!("{}", r.render());
    }
    if let Some(p) = &r.plan {
        return i32::from(p.best.is_none());
    }
    0
}

/// Whether this invocation asked for instrumentation (`--trace <file>`
/// and/or `--stats`).
fn trace_requested(args: &Args) -> bool {
    args.get("trace").is_some() || args.has_flag("stats")
}

/// Write a capture as Chrome trace-event JSON — open the file in Perfetto
/// (ui.perfetto.dev) or chrome://tracing.
fn write_trace_file(path: &str, cap: &dfmodel::obs::Capture) -> Result<(), String> {
    std::fs::write(path, dfmodel::obs::chrome_trace(cap).pretty())
        .map_err(|e| format!("write {path}: {e}"))
}

/// Honor `--trace <file>` against an evaluated report's capture.
fn write_trace(args: &Args, r: &dfmodel::api::Report) -> Result<(), String> {
    match (args.get("trace"), &r.stats) {
        (Some(path), Some(cap)) => write_trace_file(path, cap),
        _ => Ok(()),
    }
}

/// Evaluate a scenario, arming the instrumentation capture when the
/// invocation asked for it, and write the `--trace` file if any.
fn evaluate_traced(args: &Args, s: &Scenario) -> Result<dfmodel::api::Report, String> {
    let mut s = s.clone();
    if trace_requested(args) {
        s.trace.enabled = true;
    }
    let r = s.evaluate().map_err(|e| e.to_string())?;
    write_trace(args, &r)?;
    Ok(r)
}

/// Evaluate + print a scenario. Infeasibility exits 1; config errors were
/// already caught at exit 2.
fn run_scenario(args: &Args, s: &Scenario) -> i32 {
    match evaluate_traced(args, s) {
        Ok(r) => print_report(args, &r),
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn scenario_optimize(args: &Args) -> Result<Scenario, String> {
    let system = SystemCfg::new(
        args.get_or("chip", "sn10"),
        args.get_or("mem", "ddr4"),
        args.get_or("link", "pcie4"),
    )
    .topo(args.get_or("topo", "ring"), args.get_usize("chips", 8));
    Ok(Scenario::llm(args.get_or("model", "gpt3-175b"))
        .batch(args.get_f64("batch", 64.0))
        .on(system))
}

fn cmd_optimize(args: &Args) -> i32 {
    match load_scenario(args, Goal::Map, scenario_optimize) {
        Ok(s) => run_scenario(args, &s),
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn cmd_dse(args: &Args) -> i32 {
    use dfmodel::dse::Workload;
    let w = if args.get("scenario").is_some() {
        match load_scenario(args, Goal::Map, |_| Err("unreachable".into())) {
            Ok(s) => match s.workload.dse_kind() {
                Some(w) => {
                    // the sweep covers the fixed §VI-C design space: only the
                    // workload family is taken from the scenario
                    eprintln!(
                        "dse: sweeping the 80-system §VI-C space for workload '{}' \
                         (the scenario's system/batch/knobs do not apply here)",
                        w.name()
                    );
                    w
                }
                None => {
                    eprintln!("scenario workload '{}' has no DSE axis", s.workload.describe());
                    return 2;
                }
            },
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        match args.get_or("workload", "llm") {
            "llm" => Workload::Llm,
            "dlrm" => Workload::Dlrm,
            "hpl" => Workload::Hpl,
            "fft" => Workload::Fft,
            other => {
                eprintln!("unknown workload '{other}' (known: llm dlrm hpl fft)");
                return 2;
            }
        }
    };
    // `--trace`/`--stats` capture the sweep's spans (the parallel map
    // splices worker spans back deterministically) and its metrics
    let session = trace_requested(args).then(dfmodel::obs::start_capture);
    if args.has_flag("json") {
        let points = dfmodel::api::sweep(w);
        println!("{}", dfmodel::api::design_points_json(w, &points).pretty());
    } else {
        println!("{}", figures::dse_figs::dse_figure(w));
    }
    if let Some(sess) = session {
        let cap = dfmodel::obs::finish_capture(sess);
        if let Some(path) = args.get("trace") {
            if let Err(e) = write_trace_file(path, &cap) {
                eprintln!("{e}");
                return 1;
            }
        }
        if args.has_flag("stats") {
            print!("{}", cap.span_tree());
            print!("{}", cap.metrics_text());
        }
    }
    0
}

fn scenario_explore(args: &Args) -> Result<Scenario, String> {
    let s = match args.get_or("workload", "llm") {
        "llm" => Scenario::llm("gpt3-1t").batch(2048.0),
        "dlrm" => Scenario::dlrm(),
        "hpl" => Scenario::hpl(),
        "fft" => Scenario::fft(),
        other => return Err(format!("unknown workload '{other}' (known: llm dlrm hpl fft)")),
    };
    // default axes are the §VI-C paper grid; knobs below tune the driver
    let opts = dfmodel::api::ExploreOptions {
        top: args.get_usize("top", 16),
        ..Default::default()
    };
    Ok(s.explore(opts))
}

/// `dfmodel explore` — Pareto-frontier design-space exploration with
/// bound-based pruning (`--no-prune` and `--budget N` override the
/// scenario's driver knobs).
fn cmd_explore(args: &Args) -> i32 {
    match load_scenario(args, Goal::Explore, scenario_explore) {
        Ok(mut s) => {
            if let Some(b) = args.get("budget") {
                match b.parse::<usize>() {
                    Ok(v) => s.explore.budget = Some(v),
                    Err(_) => {
                        eprintln!("--budget must be a candidate count, got '{b}'");
                        return 2;
                    }
                }
            }
            if args.has_flag("no-prune") {
                s.explore.prune = false;
            }
            run_scenario(args, &s)
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

/// `dfmodel explain` — bottleneck attribution, optimizer decision audit,
/// and knob sensitivities for one scenario. `--scenario <file>` explains a
/// committed scenario (map/serve/explore goals); `--workload llm|dlrm|hpl|fft`
/// explains the §VI-C paper workload on its reference system. `--top K`
/// sets the rejected-candidates / kernel depth, `--no-sensitivity` skips
/// the finite-difference sweep (several extra evaluations).
fn cmd_explain(args: &Args) -> i32 {
    let mut s = match args.get("scenario") {
        Some(path) => match Scenario::load(std::path::Path::new(path)) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        None => match figures::explain_figs::paper_scenario(args.get_or("workload", "llm")) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    match s.goal {
        Goal::Map | Goal::Serve | Goal::Explore => {}
        g => {
            eprintln!("explain supports the map/serve/explore goals, not '{}'", g.name());
            return 2;
        }
    }
    s.explain.enabled = true;
    if let Some(top) = args.get("top") {
        match top.parse::<usize>() {
            Ok(v) if v >= 1 => s.explain.top = v,
            _ => {
                eprintln!("--top must be a positive count, got '{top}'");
                return 2;
            }
        }
    }
    if args.has_flag("no-sensitivity") {
        s.explain.sensitivity = false;
    }
    run_scenario(args, &s)
}

fn scenario_serve(args: &Args) -> Result<Scenario, String> {
    Ok(Scenario::llama(args.get_or("model", "8b"))
        .serving_split(args.get_usize("tp", 16), args.get_usize("pp", 1))
        .batch(args.get_f64("batch", 1.0))
        .prompt_context(args.get_f64("prompt", 1024.0), args.get_f64("context", 1024.0)))
}

fn cmd_serve(args: &Args) -> i32 {
    match load_scenario(args, Goal::Serve, scenario_serve) {
        Ok(s) => run_scenario(args, &s),
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

/// Parse `--qps`: must be a positive, finite request rate.
fn parse_qps(args: &Args, default: f64) -> Result<f64, String> {
    let qps = args.get_f64("qps", default);
    if qps.is_finite() && qps > 0.0 {
        Ok(qps)
    } else {
        Err(format!("--qps must be a positive rate, got {qps}"))
    }
}

fn scenario_simulate(args: &Args) -> Result<Scenario, String> {
    let rate = parse_qps(args, 4.0)?;
    let tp = args.get_usize("tp", 16);
    let pp = args.get_usize("pp", 1);
    if tp == 0 || pp == 0 {
        return Err(format!("--tp/--pp must be positive, got tp={tp} pp={pp}"));
    }
    let mut s = Scenario::llama(args.get_or("model", "8b"))
        .on(SystemCfg::sn40l_x16().ring(tp * pp))
        .serving_split(tp, pp)
        .simulate_traffic(rate, args.get_usize("requests", 200))
        .slo(args.get_f64("slo-ttft", 1.0), args.get_f64("slo-tpot", 0.02));
    // --fleet is the preferred spelling; --replicas stays as an alias
    s.cluster.replicas = args.get_usize("fleet", args.get_usize("replicas", 1));
    s.cluster.exact_percentiles = args.has_flag("exact-percentiles");
    s.cluster.max_batch = args.get_usize("max-batch", 32);
    s.cluster.seed = args.get_usize("seed", 17) as u64;
    s.cluster.arrivals = args.get_or("arrivals", "poisson").to_string();
    s.cluster.period = args.get_f64("period", 60.0);
    s.cluster.prompt_mean = args.get_f64("prompt", 1024.0);
    s.cluster.output_mean = args.get_f64("output", 128.0);
    Ok(s)
}

/// `dfmodel simulate` — request-level cluster serving simulation.
fn cmd_simulate(args: &Args) -> i32 {
    match load_scenario(args, Goal::Simulate, scenario_simulate) {
        Ok(s) => run_scenario(args, &s),
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn scenario_plan(args: &Args) -> Result<Scenario, String> {
    let qps = parse_qps(args, 2.0)?;
    let mut s = Scenario::llama(args.get_or("model", "70b"))
        .plan_for(qps)
        .slo(args.get_f64("slo-ttft", 2.0), args.get_f64("slo-tpot", 0.05));
    s.cluster.attainment = args.get_f64("attainment", 0.9);
    s.cluster.requests = args.get_usize("requests", 300);
    s.cluster.seed = args.get_usize("seed", 17) as u64;
    s.cluster.top = args.get_usize("top", 12);
    Ok(s)
}

/// `dfmodel plan` — cheapest fleet meeting a QPS + SLO target.
fn cmd_plan(args: &Args) -> i32 {
    match load_scenario(args, Goal::Plan, scenario_plan) {
        Ok(s) => run_scenario(args, &s),
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn scenario_fabric(args: &Args) -> Result<Scenario, String> {
    let system = SystemCfg::new("h100", "hbm3", args.get_or("link", "nvlink4"))
        .topo(args.get_or("topo", "torus2d"), args.get_usize("chips", 16));
    let bytes = args.get_f64("bytes", args.get_f64("mb", 64.0) * 1e6);
    let mut s = Scenario::llm("gpt3-175b")
        .on(system)
        .fabric_sweep(args.get_or("coll", "allreduce"), bytes);
    s.fabric.routing = args.get_or("routing", "dimorder").to_string();
    s.fabric.seed = args.get_usize("seed", 0) as u64;
    s.fabric.algo = args.get("algo").map(|a| a.to_string());
    Ok(s)
}

/// `dfmodel fabric` — link-level collective simulation: every algorithm
/// family vs the analytical α-β model on one topology.
fn cmd_fabric(args: &Args) -> i32 {
    let s = match load_scenario(args, Goal::Fabric, scenario_fabric) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let r = match evaluate_traced(args, &s) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let code = print_report(args, &r);
    if code != 0 {
        return code;
    }
    let trace_limit = args.get_usize("trace-hops", 0);
    if trace_limit > 0 {
        if let Err(e) = print_trace(&s, &r, trace_limit) {
            eprintln!("trace-hops: {e}");
            return 1;
        }
    }
    0
}

/// Replay the winning algorithm with packet-hop tracing (`--trace-hops N`
/// — distinct from `--trace <file>`, the span/metric capture).
fn print_trace(s: &Scenario, r: &dfmodel::api::Report, limit: usize) -> Result<(), String> {
    use dfmodel::api::scenario::collective_by_name;
    use dfmodel::fabric::{self, Algo, Routing, SimConfig};
    let f = r.fabric.as_ref().ok_or("no fabric section in the report")?;
    let (topo, _link) = s.system.build_topology().map_err(|e| e.to_string())?;
    let coll = collective_by_name(&f.collective).map_err(|e| e.to_string())?;
    let algo = Algo::parse(&f.best).ok_or("unknown best algorithm")?;
    let routing = Routing::parse(&f.routing).ok_or("unknown routing")?;
    let g = fabric::FabricGraph::new(&topo);
    let group: Vec<usize> = (0..topo.n_chips()).collect();
    let sched = fabric::build(&g, algo, coll, &group, f.bytes)
        .ok_or("best algorithm no longer feasible")?;
    let tcfg =
        SimConfig { routing, seed: s.fabric.seed, trace_limit: limit, ..Default::default() };
    let res = fabric::simulate(&g, &sched, &tcfg);
    println!("trace (first {} packet-hops, seed {}):", res.trace.len(), tcfg.seed);
    for line in &res.trace {
        println!("  {line}");
    }
    Ok(())
}

/// `dfmodel daemon` — the persistent HTTP evaluation service (dfmodeld).
/// Serves `POST /v1/evaluate`, `GET /v1/health`, and `GET /v1/metrics`
/// until SIGINT/SIGTERM (or `POST /v1/shutdown`), then drains in-flight
/// work and exits 0. Exit 2 on unusable flags or an unbindable address.
fn cmd_daemon(args: &Args) -> i32 {
    use dfmodel::daemon::{signal, Config, Server, ServiceConfig};
    use dfmodel::util::cli::parse_addr;
    let addr = match parse_addr(args.get_or("addr", "127.0.0.1:8080")) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("daemon: {e}");
            return 2;
        }
    };
    let service = ServiceConfig {
        workers: args
            .get_usize("workers", dfmodel::util::threadpool::default_workers())
            .max(1),
        cache_entries: args.get_usize("cache-entries", 256),
        queue_cap: args.get_usize("queue-cap", 64).max(1),
        timeout: std::time::Duration::from_secs_f64(args.get_f64("timeout", 300.0)),
    };
    let cfg = Config {
        addr,
        service,
        max_body: args.get_usize("max-body", 8 * 1024 * 1024).max(1024),
    };
    let server = match Server::bind(&cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("daemon: cannot bind {}: {e}", cfg.addr);
            return 2;
        }
    };
    signal::install();
    match server.local_addr() {
        Ok(a) => eprintln!(
            "dfmodeld listening on http://{a} ({} workers, {} cache entries, queue {})",
            cfg.service.workers, cfg.service.cache_entries, cfg.service.queue_cap
        ),
        Err(e) => {
            eprintln!("daemon: {e}");
            return 2;
        }
    }
    match server.run() {
        Ok(()) => {
            eprintln!("dfmodeld: drained and stopped");
            0
        }
        Err(e) => {
            eprintln!("daemon: {e}");
            1
        }
    }
}

/// `dfmodel lint <file.json ...>` — static checks on scenario or
/// `{"graph": ...}` files without evaluating them. Exit 2 on unreadable or
/// syntactically-broken input, 1 when any file has a lint error, 0 when
/// everything is clean or warning-only. `--json` emits one object per file.
fn cmd_lint(args: &Args) -> i32 {
    use dfmodel::util::json::Json;
    if args.positional.is_empty() {
        eprintln!("lint: need one or more scenario/graph JSON files");
        return 2;
    }
    let mut reports = Vec::new();
    for path in &args.positional {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("read {path}: {e}");
                return 2;
            }
        };
        let j = match Json::parse(&text) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("{path}: {e}");
                return 2;
            }
        };
        reports.push((path, dfmodel::lint::lint_json(&j)));
    }
    if args.has_flag("json") {
        let items = reports.iter().map(|(path, r)| {
            Json::obj(vec![
                ("file", Json::from(path.as_str())),
                ("errors", Json::from(r.n_errors())),
                ("warnings", Json::from(r.n_warnings())),
                ("report", r.to_json()),
            ])
        });
        println!("{}", Json::arr(items).pretty());
    } else {
        for (path, r) in &reports {
            for d in &r.diags {
                println!("{path}: {}", d.render());
            }
            println!("{path}: {}", r.summary());
        }
    }
    i32::from(reports.iter().any(|(_, r)| r.has_errors()))
}

/// `dfmodel topo` — chip/link counts and bisection bandwidth of a topology.
fn cmd_topo(args: &Args) -> i32 {
    use dfmodel::util::units::fmt_bw;
    // chip/memory are irrelevant to the topology view; any valid pair works
    let system = SystemCfg::new("h100", "hbm3", args.get_or("link", "nvlink4"))
        .topo(args.get_or("topo", "torus2d"), args.get_usize("chips", 16));
    let (topo, _link) = match system.build_topology() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!("{}", topo.name);
    println!("chips      : {}", topo.n_chips());
    for (i, d) in topo.dims.iter().enumerate() {
        println!(
            "dim {i}      : {:?} x{} ({:?}) | {} per link | bisection {} links",
            d.kind,
            d.size,
            d.fabric,
            fmt_bw(d.link_bw.raw()),
            d.bisection_links()
        );
    }
    println!("links      : {:.0}", topo.total_links());
    println!("bisection  : {} one-way", fmt_bw(topo.bisection_bytes_per_s().raw()));
    0
}

/// `dfmodel bench-check` — the CI bench-regression gate: compare a merged
/// bench JSON (BENCH_7.json) against the committed baseline and fail on
/// >tolerance p50/throughput moves. Benches absent from the baseline are
/// skipped (bootstrap: copy a CI BENCH artifact into the baseline to arm
/// the gate).
fn cmd_bench_check(args: &Args) -> i32 {
    use dfmodel::util::bench::compare_to_baseline;
    use dfmodel::util::json::Json;
    let cur_path = args.get_or("current", "BENCH_7.json");
    let base_path = args.get_or("baseline", "ci/bench_baseline.json");
    let tolerance = args.get_f64("tolerance", 0.3);
    let load = |path: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| format!("{path}: {e}"))
    };
    let (current, baseline) = match (load(cur_path), load(base_path)) {
        (Ok(c), Ok(b)) => (c, b),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cmp = compare_to_baseline(&current, &baseline, tolerance);
    println!(
        "bench-check: {} entr{} compared against {base_path} (tolerance {:.0}%)",
        cmp.compared,
        if cmp.compared == 1 { "y" } else { "ies" },
        tolerance * 100.0
    );
    if cmp.compared == 0 {
        println!("  no baseline entries yet — copy a CI BENCH artifact into {base_path} to arm");
    }
    for r in &cmp.regressions {
        println!(
            "  REGRESSION {}::{} {}: baseline {:.0} -> current {:.0} ({:.2}x)",
            r.bench, r.name, r.metric, r.baseline, r.current, r.ratio
        );
    }
    i32::from(!cmp.regressions.is_empty())
}

/// `dfmodel run --config exp.json` — legacy declarative experiment
/// launcher (a shim over `--scenario`; see `config::Experiment`).
fn cmd_run(args: &Args) -> i32 {
    let Some(path) = args.get("config") else {
        eprintln!("run: need --config <file.json>");
        return 2;
    };
    match dfmodel::config::Experiment::load(std::path::Path::new(path)) {
        Ok(exp) => match exp.run() {
            Ok(result) => {
                println!("{}", result.pretty());
                0
            }
            Err(e) => {
                eprintln!("{e}");
                1
            }
        },
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

/// Load a runtime honoring `--backend interp|pjrt` (default: interp).
fn load_runtime(
    dir: &std::path::Path,
    pipelines: &[&str],
    args: &Args,
) -> Result<dfmodel::runtime::Runtime, dfmodel::util::error::Error> {
    match args.get_or("backend", "interp") {
        "interp" => dfmodel::runtime::Runtime::load(dir, pipelines),
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let b = dfmodel::runtime::pjrt::PjrtBackend::cpu()?;
            dfmodel::runtime::Runtime::load_with(dir, pipelines, &b)
        }
        other => Err(dfmodel::err!(
            "unknown backend '{other}'{}",
            if cfg!(feature = "pjrt") { "" } else { " (built without the pjrt feature)" }
        )),
    }
}

fn artifacts_dir() -> Result<std::path::PathBuf, dfmodel::util::error::Error> {
    dfmodel::runtime::find_artifacts()
        .ok_or_else(|| dfmodel::err!("artifacts/ not found — run `make artifacts` first"))
}

fn cmd_run_pipeline(args: &Args) -> i32 {
    let Some(name) = args.positional.first() else {
        eprintln!("run-pipeline: need a pipeline name (fused|kernel_by_kernel|vendor|dfmodel)");
        return 2;
    };
    let dir = match artifacts_dir() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    match load_runtime(&dir, &[name.as_str()], args) {
        Ok(rt) => {
            let x = match rt.reference_input() {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            match rt.run_pipeline(name, &x) {
                Ok((out, stats)) => {
                    println!(
                        "pipeline '{name}': {} steps, {:.1} KB intermediates, {:?}",
                        stats.steps,
                        stats.intermediate_bytes / 1e3,
                        stats.wall
                    );
                    println!("output[0..4] = {:?}", &out[..4.min(out.len())]);
                    0
                }
                Err(e) => {
                    eprintln!("{e}");
                    1
                }
            }
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_verify(args: &Args) -> i32 {
    let dir = match artifacts_dir() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    match load_runtime(&dir, &[], args) {
        Ok(rt) => {
            println!("backend: {}", rt.platform());
            let mut bad = 0;
            for name in ["fused", "kernel_by_kernel", "vendor", "dfmodel"] {
                match rt.verify_pipeline(name) {
                    Ok(err) => {
                        let ok = err < rt.manifest.tolerance.max(1e-3);
                        println!(
                            "{name:<18} max|err| = {err:.2e}  {}",
                            if ok { "OK" } else { "FAIL" }
                        );
                        if !ok {
                            bad += 1;
                        }
                    }
                    Err(e) => {
                        println!("{name:<18} ERROR: {e}");
                        bad += 1;
                    }
                }
            }
            i32::from(bad > 0)
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
