//! Deterministic discrete-event playback of a [`Schedule`] over a
//! [`FabricGraph`] with link-occupancy contention.
//!
//! Every link is a FIFO resource: a packet requesting link *l* at time *t*
//! starts serializing at `max(t, free[l])`, holds the link for
//! `bytes / bw`, and arrives at the far node `latency` later. Multi-hop
//! messages are split into equal packets (16–64, targeting
//! `pkt_bytes` each) so they cut through intermediate nodes instead of
//! store-and-forwarding the whole buffer; single-hop messages travel as one
//! packet, which makes ring schedules on ring dims *exactly* reproduce the
//! α-β formulas. A message completes when its last packet arrives;
//! dependent messages inject at the max completion time of their deps.
//!
//! Determinism: the event heap orders by (time, insertion sequence) — the
//! same idiom as `cluster::engine` — and adaptive-routing tie-breaks use a
//! seeded per-link priority, so one (graph, schedule, config) triple always
//! yields one event history (`SimResult::trace`).

use std::collections::{BinaryHeap, HashMap};

use super::algorithms::Schedule;
use super::graph::FabricGraph;
use crate::util::prng::Rng;

/// Routing discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Deterministic dimension-ordered shortest paths.
    DimOrder,
    /// Per-hop choice among shortest-path successors by earliest link
    /// availability (seeded tie-breaks).
    MinimalAdaptive,
}

impl Routing {
    pub fn name(self) -> &'static str {
        match self {
            Routing::DimOrder => "dimorder",
            Routing::MinimalAdaptive => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Option<Routing> {
        match s {
            "dimorder" | "dim-order" => Some(Routing::DimOrder),
            "adaptive" | "minimal-adaptive" => Some(Routing::MinimalAdaptive),
            _ => None,
        }
    }
}

/// Simulation knobs (the defaults match the calibration used in tests).
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub routing: Routing,
    /// Target packet size for multi-hop pipelining.
    pub pkt_bytes: f64,
    /// Packet-count bounds for multi-hop messages.
    pub min_pkts: u32,
    pub max_pkts: u32,
    /// Seed for adaptive-routing tie-break priorities (dim-order routing is
    /// seed-independent).
    pub seed: u64,
    /// Record the first N packet-hop events as human-readable trace lines.
    pub trace_limit: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            routing: Routing::DimOrder,
            pkt_bytes: 256e3,
            min_pkts: 16,
            max_pkts: 64,
            seed: 0,
            trace_limit: 0,
        }
    }
}

/// Outcome of one playback.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Completion time of the last message (seconds).
    pub time: f64,
    pub events: u64,
    pub packets: u64,
    pub msgs: usize,
    /// Busy fraction per link over the makespan.
    pub link_util: Vec<f64>,
    pub max_link_util: f64,
    pub mean_link_util: f64,
    pub trace: Vec<String>,
}

/// Heap entry ordered earliest-first by (time, insertion sequence).
#[derive(Debug, Clone, Copy)]
struct Entry {
    t: f64,
    seq: u64,
    msg: u32,
    node: u32,
    hop: u16,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: the max-heap pops the earliest entry first
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Clone, Default)]
struct MsgState {
    deps_left: u32,
    ready: f64,
    pkts_left: u32,
    pkt_bytes: f64,
    /// Dim-order route (empty under adaptive routing).
    path: Vec<u32>,
}

struct S<'a> {
    g: &'a FabricGraph,
    cfg: &'a SimConfig,
    sched: &'a Schedule,
    st: Vec<MsgState>,
    dependents: Vec<Vec<u32>>,
    free: Vec<f64>,
    busy: Vec<f64>,
    heap: BinaryHeap<Entry>,
    seq: u64,
    dist_cache: HashMap<usize, Vec<u32>>,
    /// Seeded per-link tie-break priorities for adaptive routing.
    pri: Vec<u64>,
    events: u64,
    packets: u64,
    end: f64,
    done: usize,
    trace: Vec<String>,
}

impl S<'_> {
    fn push(&mut self, t: f64, msg: u32, node: u32, hop: u16) {
        self.heap.push(Entry { t, seq: self.seq, msg, node, hop });
        self.seq += 1;
    }

    fn dists(&mut self, dst: usize) -> &Vec<u32> {
        let g = self.g;
        self.dist_cache.entry(dst).or_insert_with(|| g.dists_to(dst))
    }

    fn inject(&mut self, i: usize, t: f64) {
        let (src, dst, bytes) =
            (self.sched.msgs[i].src, self.sched.msgs[i].dst, self.sched.msgs[i].bytes);
        let (hops, path) = match self.cfg.routing {
            Routing::DimOrder => {
                let p = self.g.dim_order_path(src, dst);
                (p.len() as u32, p)
            }
            Routing::MinimalAdaptive => (self.dists(dst)[src], Vec::new()),
        };
        let n_pkts = if hops <= 1 {
            1
        } else {
            (((bytes / self.cfg.pkt_bytes).ceil() as u32)
                .clamp(self.cfg.min_pkts, self.cfg.max_pkts))
            .max(1)
        };
        {
            let s = &mut self.st[i];
            s.path = path;
            s.pkts_left = n_pkts;
            s.pkt_bytes = bytes / f64::from(n_pkts);
        }
        self.packets += u64::from(n_pkts);
        for _ in 0..n_pkts {
            self.push(t, i as u32, src as u32, 0);
        }
    }

    fn complete(&mut self, i: usize, t: f64) {
        let deps = std::mem::take(&mut self.dependents[i]);
        for j in deps {
            let j = j as usize;
            if t > self.st[j].ready {
                self.st[j].ready = t;
            }
            self.st[j].deps_left -= 1;
            if self.st[j].deps_left == 0 {
                let rt = self.st[j].ready;
                self.inject(j, rt);
            }
        }
    }

    /// Next link for one packet of message `i` standing at `node`.
    fn pick_link(&mut self, i: usize, node: usize, hop: u16) -> u32 {
        match self.cfg.routing {
            Routing::DimOrder => self.st[i].path[hop as usize],
            Routing::MinimalAdaptive => {
                let dst = self.sched.msgs[i].dst;
                let g = self.g;
                let dist = self.dist_cache.entry(dst).or_insert_with(|| g.dists_to(dst));
                let du = dist[node];
                let mut best = u32::MAX;
                let mut best_free = f64::INFINITY;
                let mut best_pri = u64::MAX;
                for &lix in &g.adj[node] {
                    let v = g.links[lix as usize].dst;
                    if dist[v] != u32::MAX && dist[v] + 1 == du {
                        let f = self.free[lix as usize];
                        let p = self.pri[lix as usize];
                        if f < best_free || (f == best_free && p < best_pri) {
                            best = lix;
                            best_free = f;
                            best_pri = p;
                        }
                    }
                }
                assert_ne!(best, u32::MAX, "no shortest-path successor at node {node}");
                best
            }
        }
    }

    fn step(&mut self, e: Entry) {
        self.events += 1;
        let i = e.msg as usize;
        if e.node as usize == self.sched.msgs[i].dst {
            self.st[i].pkts_left -= 1;
            if e.t > self.end {
                self.end = e.t;
            }
            if self.st[i].pkts_left == 0 {
                self.done += 1;
                self.complete(i, e.t);
            }
            return;
        }
        let l = self.pick_link(i, e.node as usize, e.hop);
        let link = self.g.links[l as usize];
        let size = self.st[i].pkt_bytes;
        let ts = if e.t > self.free[l as usize] { e.t } else { self.free[l as usize] };
        let tx = size / link.bw;
        self.free[l as usize] = ts + tx;
        self.busy[l as usize] += tx;
        if self.trace.len() < self.cfg.trace_limit {
            self.trace.push(format!(
                "t={:.4e} msg={} hop={} link={} {}->{}",
                e.t, e.msg, e.hop, l, link.src, link.dst
            ));
        }
        let arrive = self.free[l as usize] + link.latency;
        self.push(arrive, e.msg, link.dst as u32, e.hop + 1);
    }
}

/// Play `sched` over `g`. Panics on a dependency cycle (generator bug) —
/// `algorithms::build` never emits one.
pub fn simulate(g: &FabricGraph, sched: &Schedule, cfg: &SimConfig) -> SimResult {
    let _span = crate::obs::span("fabric.simulate");
    let n = sched.msgs.len();
    let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut st: Vec<MsgState> = vec![MsgState::default(); n];
    for (i, m) in sched.msgs.iter().enumerate() {
        st[i].deps_left = m.deps.len() as u32;
        for &d in &m.deps {
            assert!((d as usize) < i, "deps must reference earlier messages");
            dependents[d as usize].push(i as u32);
        }
    }
    let mut pri = vec![0u64; g.links.len()];
    if cfg.routing == Routing::MinimalAdaptive {
        let mut rng = Rng::new(cfg.seed);
        for p in pri.iter_mut() {
            *p = rng.next_u64();
        }
    }
    let mut s = S {
        g,
        cfg,
        sched,
        st,
        dependents,
        free: vec![0.0; g.links.len()],
        busy: vec![0.0; g.links.len()],
        heap: BinaryHeap::new(),
        seq: 0,
        dist_cache: HashMap::new(),
        pri,
        events: 0,
        packets: 0,
        end: 0.0,
        done: 0,
        trace: Vec::new(),
    };
    for i in 0..n {
        if s.st[i].deps_left == 0 {
            s.inject(i, 0.0);
        }
    }
    while let Some(e) = s.heap.pop() {
        s.step(e);
    }
    assert_eq!(s.done, n, "fabric schedule deadlocked: {}/{n} messages completed", s.done);
    let end = s.end;
    let link_util: Vec<f64> =
        s.busy.iter().map(|&b| if end > 0.0 { b / end } else { 0.0 }).collect();
    let max_link_util = link_util.iter().copied().fold(0.0f64, f64::max);
    let mean_link_util = if link_util.is_empty() {
        0.0
    } else {
        link_util.iter().sum::<f64>() / link_util.len() as f64
    };
    crate::obs::counter("fabric.events", s.events);
    crate::obs::counter("fabric.packets", s.packets);
    crate::obs::counter("fabric.msgs", n as u64);
    crate::obs::gauge("fabric.max_link_util", max_link_util);
    crate::obs::gauge("fabric.mean_link_util", mean_link_util);
    if crate::obs::enabled() {
        for &u in &link_util {
            crate::obs::observe("fabric.link_util", u);
        }
    }
    SimResult {
        time: end,
        events: s.events,
        packets: s.packets,
        msgs: n,
        link_util,
        max_link_util,
        mean_link_util,
        trace: s.trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{self, Collective};
    use crate::fabric::algorithms::{build, Algo};
    use crate::system::interconnect::nvlink4;
    use crate::system::topology;

    fn sim_ring_ar(k: usize, bytes: f64) -> SimResult {
        let t = topology::ring(k, &nvlink4());
        let g = FabricGraph::new(&t);
        let group: Vec<usize> = (0..k).collect();
        let s = build(&g, Algo::Ring, Collective::AllReduce, &group, bytes).unwrap();
        simulate(&g, &s, &SimConfig::default())
    }

    #[test]
    fn ring_allreduce_reproduces_the_alpha_beta_formula() {
        for k in [4, 8, 16] {
            for bytes in [1e6, 64e6] {
                let r = sim_ring_ar(k, bytes);
                let d = topology::Dim::new(topology::DimKind::Ring, k, &nvlink4());
                let payload = crate::util::units::Bytes::new(bytes);
                let ana = collective::time(Collective::AllReduce, payload, &d).raw();
                assert!(
                    (r.time - ana).abs() / ana < 1e-9,
                    "k={k} bytes={bytes}: sim {} vs ana {ana}",
                    r.time
                );
            }
        }
    }

    #[test]
    fn simulation_is_deterministic() {
        let a = sim_ring_ar(8, 32e6);
        let b = sim_ring_ar(8, 32e6);
        assert_eq!(a, b);
    }

    #[test]
    fn adaptive_routing_is_seed_deterministic_and_helps_congestion() {
        let t = topology::torus2d(4, 4, &nvlink4());
        let g = FabricGraph::new(&t);
        let group: Vec<usize> = (0..16).collect();
        let s = build(&g, Algo::Direct, Collective::AllToAll, &group, 8e6).unwrap();
        let mk = |seed| SimConfig {
            routing: Routing::MinimalAdaptive,
            seed,
            trace_limit: 64,
            ..Default::default()
        };
        let a1 = simulate(&g, &s, &mk(7));
        let a2 = simulate(&g, &s, &mk(7));
        assert_eq!(a1, a2, "same seed, same trace");
        assert_eq!(a1.trace.len(), 64);
        let dim = simulate(&g, &s, &SimConfig::default());
        // spreading over equal-length paths cannot hurt this pattern
        assert!(a1.time <= dim.time * 1.001, "adaptive {} vs dimorder {}", a1.time, dim.time);
    }

    #[test]
    fn empty_schedule_is_free() {
        let t = topology::ring(4, &nvlink4());
        let g = FabricGraph::new(&t);
        let s = build(&g, Algo::Ring, Collective::AllReduce, &[0], 1e6).unwrap();
        let r = simulate(&g, &s, &SimConfig::default());
        assert_eq!(r.time, 0.0);
        assert_eq!(r.events, 0);
    }

    #[test]
    fn utilization_is_bounded_and_positive_under_load() {
        let r = sim_ring_ar(8, 64e6);
        assert!(r.max_link_util > 0.5 && r.max_link_util <= 1.0 + 1e-9, "{}", r.max_link_util);
        assert!(r.mean_link_util > 0.0 && r.mean_link_util <= r.max_link_util);
        assert_eq!(r.link_util.len(), 16);
    }

    #[test]
    fn p2p_time_is_bandwidth_plus_latency() {
        let t = topology::ring(8, &nvlink4());
        let g = FabricGraph::new(&t);
        let group: Vec<usize> = (0..8).collect();
        let s = build(&g, Algo::Ring, Collective::P2P, &group, 1e7).unwrap();
        let r = simulate(&g, &s, &SimConfig::default());
        // 0 → 7 is one wraparound hop on the ring
        let d = topology::Dim::new(topology::DimKind::Ring, 8, &nvlink4());
        let ana = collective::time(Collective::P2P, crate::util::units::Bytes::new(1e7), &d).raw();
        assert!((r.time - ana).abs() / ana < 1e-9, "sim {} ana {ana}", r.time);
    }
}
