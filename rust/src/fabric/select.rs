//! Topology-aware algorithm selection and calibration.
//!
//! `evaluate_algos` sweeps every feasible algorithm family for one
//! (collective, payload, group) and ranks them by simulated time — small
//! latency-bound payloads favor direct/tree schedules, large
//! bandwidth-bound payloads favor ring/hierarchical ones, and the winner
//! depends on the topology (that is the point of the subsystem).
//!
//! `calibrate` turns those sweeps into a [`Calibration`] table the
//! analytical model consumes (`CollectiveModel::Calibrated`): for every dim
//! subset of a topology (bounded by `max_group`), the best simulated time
//! is recorded as a ratio over `collective::time_hier` at a payload grid.
//! `calibrate_system` wires the table into a [`SystemSpec`], which
//! `interchip::optimize`, `pipeline` and `dse::evaluate_point_calibrated`
//! then consult — the fabric's contention model flows into every
//! downstream mapping decision.

use super::algorithms::{self, Algo};
use super::graph::FabricGraph;
use super::sim::{simulate, SimConfig};
use crate::collective::{self, CalPoint, Calibration, Collective, CollectiveModel};
use crate::system::topology::{Dim, Topology};
use crate::system::SystemSpec;

/// One algorithm's simulated outcome for a (collective, payload, group).
#[derive(Debug, Clone)]
pub struct AlgoEval {
    pub algo: Algo,
    /// Simulated completion time (seconds).
    pub time: f64,
    pub max_link_util: f64,
    pub msgs: usize,
    pub packets: u64,
    pub events: u64,
}

/// Simulate every feasible algorithm, fastest first (ties keep the
/// `Algo::ALL` order, so results are deterministic).
pub fn evaluate_algos(
    g: &FabricGraph,
    group: &[usize],
    coll: Collective,
    bytes: f64,
    cfg: &SimConfig,
) -> Vec<AlgoEval> {
    let mut out = Vec::new();
    for algo in Algo::ALL {
        let Some(sched) = algorithms::build(g, algo, coll, group, bytes) else {
            continue;
        };
        let r = simulate(g, &sched, cfg);
        out.push(AlgoEval {
            algo,
            time: r.time,
            max_link_util: r.max_link_util,
            msgs: r.msgs,
            packets: r.packets,
            events: r.events,
        });
    }
    out.sort_by(|a, b| a.time.total_cmp(&b.time));
    out
}

/// The fastest algorithm for a (collective, payload, group), if any runs.
pub fn best(
    g: &FabricGraph,
    group: &[usize],
    coll: Collective,
    bytes: f64,
    cfg: &SimConfig,
) -> Option<AlgoEval> {
    evaluate_algos(g, group, coll, bytes, cfg).into_iter().next()
}

/// Calibration sweep configuration.
#[derive(Debug, Clone)]
pub struct CalibrateOpts {
    /// Payload grid (bytes per chip); ratios interpolate between points.
    pub payloads: Vec<f64>,
    pub colls: Vec<Collective>,
    pub sim: SimConfig,
    /// Skip dim subsets whose chip group exceeds this (simulation cost
    /// guard — on 1024-chip topologies only the sub-64-chip groups, which
    /// are what TP/PP assignments actually use, get calibrated).
    pub max_group: usize,
}

impl Default for CalibrateOpts {
    fn default() -> Self {
        CalibrateOpts {
            // latency-bound, mixed, bandwidth-bound
            payloads: vec![256e3, 4e6, 64e6],
            colls: vec![
                Collective::AllReduce,
                Collective::AllGather,
                Collective::ReduceScatter,
                Collective::AllToAll,
                Collective::Broadcast,
                Collective::P2P,
            ],
            sim: SimConfig::default(),
            max_group: 64,
        }
    }
}

/// Chips whose coordinates are 0 outside `dims_idx` — the canonical
/// subgroup spanned by those dims (every congruent subgroup is symmetric).
fn group_for(g: &FabricGraph, dims_idx: &[usize]) -> Vec<usize> {
    (0..g.n_chips)
        .filter(|&c| {
            g.coords(c).iter().enumerate().all(|(i, &x)| dims_idx.contains(&i) || x == 0)
        })
        .collect()
}

/// Build a calibration table for every dim subset of `t` (see module docs).
pub fn calibrate(t: &Topology, opts: &CalibrateOpts) -> Calibration {
    let g = FabricGraph::new(t);
    let nd = t.dims.len();
    let mut cal = Calibration::default();
    for mask in 1u32..(1u32 << nd) {
        let dims_idx: Vec<usize> = (0..nd).filter(|&i| mask >> i & 1 == 1).collect();
        if dims_idx.iter().any(|&i| t.dims[i].size <= 1) {
            continue; // canonical masks only: singleton dims never vary
        }
        let group = group_for(&g, &dims_idx);
        if group.len() < 2 || group.len() > opts.max_group {
            continue;
        }
        let dim_refs: Vec<&Dim> = dims_idx.iter().map(|&i| &t.dims[i]).collect();
        let key = collective::dims_key(&dim_refs);
        if cal.contains_key(&key) {
            continue; // a congruent subset was already swept
        }
        for &coll in &opts.colls {
            let mut pts = Vec::with_capacity(opts.payloads.len());
            for &s in &opts.payloads {
                let ana = collective::time_hier(coll, crate::util::units::Bytes::new(s), &dim_refs).raw();
                if ana <= 0.0 {
                    continue;
                }
                if let Some(b) = best(&g, &group, coll, s, &opts.sim) {
                    pts.push(CalPoint { bytes: s, ratio: b.time / ana });
                }
            }
            cal.insert(coll, key.clone(), pts);
        }
    }
    cal
}

/// `sys` with its collective model swapped for a fabric calibration of its
/// own topology — the entry point that threads simulation fidelity into
/// `interchip::optimize` and the DSE. (`pub(crate)` — the public seam is
/// `api::calibrate`.)
pub(crate) fn calibrate_system(sys: &SystemSpec, opts: &CalibrateOpts) -> SystemSpec {
    let cal = calibrate(&sys.topology, opts);
    sys.clone().with_collective_model(CollectiveModel::Calibrated(cal))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::interconnect::nvlink4;
    use crate::system::topology;

    #[test]
    fn selection_flips_between_latency_and_bandwidth_bound_payloads() {
        // the acceptance case: on a 16-chip ring, tiny payloads pick the
        // latency-light direct schedule, huge ones the bandwidth-optimal ring
        let t = topology::ring(16, &nvlink4());
        let g = FabricGraph::new(&t);
        let group: Vec<usize> = (0..16).collect();
        let cfg = SimConfig::default();
        let small = best(&g, &group, Collective::AllReduce, 32e3, &cfg).unwrap();
        let large = best(&g, &group, Collective::AllReduce, 256e6, &cfg).unwrap();
        assert_eq!(small.algo, Algo::Direct, "small payload: {:?}", small);
        assert_eq!(large.algo, Algo::Ring, "large payload: {:?}", large);
    }

    #[test]
    fn evaluate_algos_is_sorted_and_covers_all_families() {
        let t = topology::torus2d(4, 4, &nvlink4());
        let g = FabricGraph::new(&t);
        let group: Vec<usize> = (0..16).collect();
        let evals = evaluate_algos(&g, &group, Collective::AllReduce, 16e6, &SimConfig::default());
        assert_eq!(evals.len(), 4);
        assert!(evals.windows(2).all(|w| w[0].time <= w[1].time));
        assert!(evals.iter().all(|e| e.time > 0.0 && e.msgs > 0));
    }

    #[test]
    fn calibration_covers_ring_subsets_with_near_unity_ratio() {
        let t = topology::ring(8, &nvlink4());
        let cal = calibrate(&t, &CalibrateOpts::default());
        assert!(!cal.is_empty());
        let key = collective::dims_key(&[&t.dims[0]]);
        // the best ring-dim algorithm reproduces the analytical formula at
        // bandwidth-bound payloads (or beats it via direct at latency-bound)
        let r = cal.ratio(Collective::AllReduce, &key, 64e6).expect("calibrated");
        assert!(r > 0.5 && r < 1.1, "ratio {r}");
    }

    #[test]
    fn calibrate_system_swaps_the_model() {
        let link = nvlink4();
        let sys = SystemSpec::new(
            crate::system::chip::a100(),
            crate::system::memory::hbm3(),
            link.clone(),
            topology::ring(8, &link),
        );
        assert!(matches!(sys.collective_model, CollectiveModel::Analytical));
        let cal = calibrate_system(&sys, &CalibrateOpts::default());
        match &cal.collective_model {
            CollectiveModel::Calibrated(c) => assert!(!c.is_empty()),
            m => panic!("expected calibrated model, got {m:?}"),
        }
    }

    #[test]
    fn oversized_groups_are_skipped() {
        let t = topology::torus2d(16, 16, &nvlink4());
        let opts = CalibrateOpts { max_group: 8, ..Default::default() };
        let cal = calibrate(&t, &opts);
        assert!(cal.is_empty(), "16-chip dims exceed the 8-chip guard");
    }
}
