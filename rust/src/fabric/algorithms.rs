//! Collective-algorithm message schedules.
//!
//! A [`Schedule`] is a DAG of messages: each message departs its source
//! once all of its `deps` (earlier messages) have fully *arrived*; the
//! simulator supplies routing and contention. Four algorithm families per
//! collective (mirroring the NCCL/BlueConnect design space):
//!
//! * **ring** — pipelined chunked neighbor exchange over a snake
//!   (boustrophedon) order of the group, so every ring step is a single
//!   physical hop on tori. Bandwidth-optimal, `O(k)` latency steps.
//! * **halving-doubling** — recursive halving/doubling over power-of-two
//!   groups: `O(log k)` steps, but partners sit far apart on rings.
//! * **direct** — all-port scatter-style exchange with staggered
//!   destination order (chip *i* starts at peer *i+1*), matching the
//!   closed-form formulas on fully-connected and switch dims.
//! * **hier** — BlueConnect phase-per-dim decomposition with shrinking /
//!   growing payloads, ring sub-passes inside ring/cube-mesh dims and
//!   direct sub-passes inside fully-connected/switch dims; this is the
//!   schedule-level twin of `collective::time_hier`.
//!
//! Reduction compute is free, as in the analytical model: times are pure
//! network times.

use std::collections::BTreeMap;

use super::graph::{CUBE_RING, FabricGraph};
use crate::collective::Collective;
use crate::system::topology::{DimFabric, DimKind};

/// Algorithm family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    Ring,
    HalvingDoubling,
    Direct,
    Hier,
}

impl Algo {
    pub const ALL: [Algo; 4] = [Algo::Ring, Algo::HalvingDoubling, Algo::Direct, Algo::Hier];

    pub fn name(self) -> &'static str {
        match self {
            Algo::Ring => "ring",
            Algo::HalvingDoubling => "hd",
            Algo::Direct => "direct",
            Algo::Hier => "hier",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "ring" => Some(Algo::Ring),
            "hd" | "halving-doubling" => Some(Algo::HalvingDoubling),
            "direct" => Some(Algo::Direct),
            "hier" | "hierarchical" => Some(Algo::Hier),
            _ => None,
        }
    }
}

/// One message: `bytes` from chip `src` to chip `dst`, departing once every
/// message in `deps` (indices into the schedule, always earlier) arrived.
#[derive(Debug, Clone)]
pub struct Msg {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
    pub deps: Vec<u32>,
}

/// A complete message schedule for one collective.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub coll: Collective,
    pub algo: Algo,
    pub msgs: Vec<Msg>,
}

/// Pending per-chip dependencies between passes (BTreeMap: schedules must
/// be bit-identical run to run, so no hash-order iteration anywhere).
type Deps = BTreeMap<usize, Vec<u32>>;

struct B {
    msgs: Vec<Msg>,
}

impl B {
    fn send(&mut self, src: usize, dst: usize, bytes: f64, deps: Vec<u32>) -> u32 {
        debug_assert!(deps.iter().all(|&d| (d as usize) < self.msgs.len()));
        self.msgs.push(Msg { src, dst, bytes, deps });
        (self.msgs.len() - 1) as u32
    }
}

fn get_deps(init: &Deps, chip: usize) -> Vec<u32> {
    init.get(&chip).cloned().unwrap_or_default()
}

fn passthrough(init: &Deps, group: &[usize]) -> Deps {
    group.iter().map(|&c| (c, get_deps(init, c))).collect()
}

/// Dims in which the group's members differ.
fn varying_dims(g: &FabricGraph, group: &[usize]) -> Vec<usize> {
    let base = g.coords(group[0]);
    let mut vary = vec![false; g.dims().len()];
    for &c in &group[1..] {
        for (v, (a, b)) in vary.iter_mut().zip(g.coords(c).iter().zip(&base)) {
            if a != b {
                *v = true;
            }
        }
    }
    (0..vary.len()).filter(|&i| vary[i]).collect()
}

/// Boustrophedon order of the group over its varying dims: consecutive
/// members (wrap included, for even dim sizes) are physically adjacent on
/// tori, making ring passes single-hop.
fn snake_order(g: &FabricGraph, group: &[usize]) -> Vec<usize> {
    let mut gs: Vec<usize> = group.to_vec();
    gs.sort_unstable();
    let vd = varying_dims(g, &gs);
    let mut keyed: Vec<(usize, usize)> = gs
        .iter()
        .map(|&c| {
            let co = g.coords(c);
            let mut key = 0usize;
            let mut flip = false;
            for &di in vd.iter().rev() {
                let size = g.dims()[di].size;
                let x = if flip { size - 1 - co[di] } else { co[di] };
                key = key * size + x;
                flip ^= co[di] % 2 == 1;
            }
            (key, c)
        })
        .collect();
    keyed.sort_unstable();
    keyed.into_iter().map(|(_, c)| c).collect()
}

/// Pipelined ring pass (reduce-scatter and all-gather are cost-identical):
/// k−1 steps; in each, position i sends an S/k chunk to i+1, gated on its
/// receive from the previous step. Returns each chip's final receive.
fn ring_pass(b: &mut B, ring: &[usize], s: f64, init: &Deps) -> Deps {
    let k = ring.len();
    if k < 2 || s <= 0.0 {
        return passthrough(init, ring);
    }
    let chunk = s / k as f64;
    let mut prev: Vec<u32> = Vec::new();
    for step in 0..k - 1 {
        let mut cur = Vec::with_capacity(k);
        for i in 0..k {
            let deps =
                if step == 0 { get_deps(init, ring[i]) } else { vec![prev[(i + k - 1) % k]] };
            cur.push(b.send(ring[i], ring[(i + 1) % k], chunk, deps));
        }
        prev = cur;
    }
    ring.iter().enumerate().map(|(i, &c)| (c, vec![prev[(i + k - 1) % k]])).collect()
}

/// Direct all-port pass: every chip exchanges S/k chunks with every peer,
/// destinations staggered (chip i starts at peer i+1) so no receiver is hit
/// by all senders in the same slot. Returns each chip's receives.
fn direct_pass(b: &mut B, group: &[usize], s: f64, init: &Deps) -> Deps {
    let k = group.len();
    if k < 2 || s <= 0.0 {
        return passthrough(init, group);
    }
    let chunk = s / k as f64;
    let mut fin: Deps = group.iter().map(|&c| (c, Vec::new())).collect();
    for i in 0..k {
        for off in 1..k {
            let j = (i + off) % k;
            let m = b.send(group[i], group[j], chunk, get_deps(init, group[i]));
            fin.get_mut(&group[j]).expect("receiver in group").push(m);
        }
    }
    fin
}

/// Recursive halving (`halving = true`: distances k/2…1, sizes S/2…S/k) or
/// doubling (distances 1…k/2, sizes S/k…S/2) over a power-of-two group.
fn hd_pass(b: &mut B, group: &[usize], s: f64, init: &Deps, halving: bool) -> Deps {
    let k = group.len();
    if k < 2 || s <= 0.0 {
        return passthrough(init, group);
    }
    debug_assert!(k.is_power_of_two());
    let mut recv = passthrough(init, group);
    let mut dists: Vec<usize> = Vec::new();
    let mut d = 1;
    while d < k {
        dists.push(d);
        d *= 2;
    }
    if halving {
        dists.reverse();
    }
    for d in dists {
        let mut nxt = Deps::new();
        for i in 0..k {
            let p = i ^ d;
            let m = b.send(group[i], group[p], s * d as f64 / k as f64, get_deps(&recv, group[i]));
            nxt.entry(group[p]).or_default().push(m);
        }
        recv = nxt;
    }
    recv
}

/// Shift all-to-all: k−1 rounds, round r sends the S/k block to position
/// i+r, each round gated on the previous round's receive.
fn shift_a2a(b: &mut B, group: &[usize], s: f64, init: &Deps) -> Deps {
    let k = group.len();
    if k < 2 || s <= 0.0 {
        return passthrough(init, group);
    }
    let chunk = s / k as f64;
    let mut recv = passthrough(init, group);
    for r in 1..k {
        let mut nxt = Deps::new();
        for i in 0..k {
            let j = (i + r) % k;
            let m = b.send(group[i], group[j], chunk, get_deps(&recv, group[i]));
            nxt.entry(group[j]).or_default().push(m);
        }
        recv = nxt;
    }
    recv
}

/// Pipelined chain broadcast from position 0 around the order: chunked so
/// the chain streams instead of store-and-forwarding the full buffer.
fn chain_bcast(b: &mut B, ring: &[usize], s: f64, init: &Deps) -> Deps {
    let k = ring.len();
    if k < 2 || s <= 0.0 {
        return passthrough(init, ring);
    }
    let by_bytes = ((s / 4096.0).ceil() as usize).max(1);
    let m = (8 * k).clamp(16, 512).min(by_bytes);
    let chunk = s / m as f64;
    let mut fin: Deps = ring.iter().map(|&c| (c, Vec::new())).collect();
    fin.insert(ring[0], get_deps(init, ring[0]));
    let mut prev_hop: Vec<u32> = vec![0; k - 1];
    for c in 0..m {
        for h in 0..k - 1 {
            let deps = if h == 0 { get_deps(init, ring[0]) } else { vec![prev_hop[h - 1]] };
            let mid = b.send(ring[h], ring[h + 1], chunk, deps);
            prev_hop[h] = mid;
            if c == m - 1 {
                fin.insert(ring[h + 1], vec![mid]);
            }
        }
    }
    fin
}

/// Two-phase broadcast: scatter S/k chunks from the root, then direct
/// all-gather — this is what the closed-form FC/switch broadcast assumes.
fn scatter_ag_bcast(b: &mut B, group: &[usize], s: f64, init: &Deps) -> Deps {
    let k = group.len();
    if k < 2 || s <= 0.0 {
        return passthrough(init, group);
    }
    let chunk = s / k as f64;
    let mut got = Deps::new();
    got.insert(group[0], get_deps(init, group[0]));
    for &j in &group[1..] {
        let m = b.send(group[0], j, chunk, get_deps(init, group[0]));
        got.insert(j, vec![m]);
    }
    direct_pass(b, group, s, &got)
}

/// Binomial-tree broadcast over a power-of-two group.
fn tree_bcast(b: &mut B, group: &[usize], s: f64, init: &Deps) -> Deps {
    let k = group.len();
    if k < 2 || s <= 0.0 {
        return passthrough(init, group);
    }
    debug_assert!(k.is_power_of_two());
    let mut got = Deps::new();
    got.insert(group[0], get_deps(init, group[0]));
    let mut t = 1;
    while t < k {
        for i in 0..t {
            let m = b.send(group[i], group[i + t], s, get_deps(&got, group[i]));
            got.insert(group[i + t], vec![m]);
        }
        t *= 2;
    }
    got
}

/// Partition the group into its maximal lines along dim `di`, each sorted
/// by that dim's coordinate; lines sorted for determinism.
fn lines_of(g: &FabricGraph, group: &[usize], di: usize) -> Vec<Vec<usize>> {
    let mut by: BTreeMap<Vec<usize>, Vec<usize>> = BTreeMap::new();
    for &c in group {
        let mut co = g.coords(c);
        co[di] = 0;
        by.entry(co).or_default().push(c);
    }
    let mut lines: Vec<Vec<usize>> = by.into_values().collect();
    for l in &mut lines {
        l.sort_by_key(|&c| g.coords(c)[di]);
    }
    lines
}

/// Ring order inside one dim's line: the Hamiltonian cycle for cube-mesh
/// dims, coordinate order otherwise.
fn sub_order(g: &FabricGraph, line: &[usize], di: usize) -> Vec<usize> {
    if g.dims()[di].fabric == DimFabric::CubeMesh {
        CUBE_RING.iter().map(|&i| line[i]).collect()
    } else {
        line.to_vec()
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Pass {
    Rs,
    Ag,
    A2a,
}

/// One hierarchical phase over dim `di`: per-line sub-pass, ring-style
/// inside ring/cube-mesh dims, direct inside FC/switch dims.
fn run_phase(
    b: &mut B,
    g: &FabricGraph,
    group: &[usize],
    di: usize,
    pass: Pass,
    payload: f64,
    part: &Deps,
) -> Deps {
    let d = &g.dims()[di];
    let ring_like = d.kind == DimKind::Ring || d.fabric == DimFabric::CubeMesh;
    let mut nxt = Deps::new();
    for line in lines_of(g, group, di) {
        let fin = if ring_like {
            let o = sub_order(g, &line, di);
            match pass {
                Pass::Rs | Pass::Ag => ring_pass(b, &o, payload, part),
                Pass::A2a => shift_a2a(b, &o, payload, part),
            }
        } else {
            // FC/switch dims: the direct all-port pass serves RS, AG and A2A
            direct_pass(b, &line, payload, part)
        };
        nxt.extend(fin);
    }
    nxt
}

/// BlueConnect phase-per-dim hierarchical schedule. Requires the group to
/// be an axis-aligned product of full dim lines (what `ParallelismPlan` dim
/// assignments and `select::calibrate` subsets always are) — partial lines
/// would make the per-phase payload scaling and owner propagation wrong.
fn hier(b: &mut B, g: &FabricGraph, coll: Collective, group: &[usize], s: f64) {
    let vdims = varying_dims(g, group);
    if vdims.is_empty() {
        return;
    }
    debug_assert_eq!(
        group.len(),
        vdims.iter().map(|&di| g.dims()[di].size).product::<usize>(),
        "hier schedules need an axis-aligned product group"
    );
    let mut part = Deps::new();
    match coll {
        Collective::AllReduce => {
            let mut payload = s;
            for &di in &vdims {
                part = run_phase(b, g, group, di, Pass::Rs, payload, &part);
                payload /= g.dims()[di].size as f64;
            }
            for &di in vdims.iter().rev() {
                payload *= g.dims()[di].size as f64;
                part = run_phase(b, g, group, di, Pass::Ag, payload, &part);
            }
        }
        Collective::ReduceScatter => {
            let mut payload = s;
            for &di in &vdims {
                part = run_phase(b, g, group, di, Pass::Rs, payload, &part);
                payload /= g.dims()[di].size as f64;
            }
        }
        Collective::AllGather => {
            let total: f64 = vdims.iter().map(|&di| g.dims()[di].size as f64).product();
            let mut payload = s / total;
            for &di in vdims.iter().rev() {
                payload *= g.dims()[di].size as f64;
                part = run_phase(b, g, group, di, Pass::Ag, payload, &part);
            }
        }
        Collective::AllToAll => {
            for &di in &vdims {
                part = run_phase(b, g, group, di, Pass::A2a, s, &part);
            }
        }
        Collective::Broadcast => {
            let mut owners: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
            owners.insert(group[0]);
            for &di in &vdims {
                for line in lines_of(g, group, di) {
                    let Some(&root) = line.iter().find(|c| owners.contains(c)) else {
                        continue;
                    };
                    let o = sub_order(g, &line, di);
                    let pos = o.iter().position(|&c| c == root).expect("root in line");
                    let rot: Vec<usize> =
                        o[pos..].iter().chain(o[..pos].iter()).copied().collect();
                    let d = &g.dims()[di];
                    let fin = if d.kind == DimKind::FullyConnected
                        && d.fabric != DimFabric::CubeMesh
                    {
                        scatter_ag_bcast(b, &rot, s, &part)
                    } else {
                        chain_bcast(b, &rot, s, &part)
                    };
                    for (c, dps) in fin {
                        part.insert(c, dps);
                    }
                    owners.extend(line.iter().copied());
                }
            }
        }
        Collective::P2P => {
            b.send(group[0], *group.last().expect("non-empty"), s, Vec::new());
        }
    }
}

/// Build the message schedule for `algo` × `coll` over `group` (global chip
/// ids) at `bytes` per chip. `None` when the algorithm cannot run on this
/// group (halving-doubling needs a power-of-two size); an empty schedule
/// (time 0) for degenerate groups or payloads. `Algo::Hier` additionally
/// requires an axis-aligned product group (full lines along its varying
/// dims), which is what plan dim assignments and calibration subsets are.
pub fn build(
    g: &FabricGraph,
    algo: Algo,
    coll: Collective,
    group: &[usize],
    bytes: f64,
) -> Option<Schedule> {
    let mut b = B { msgs: Vec::new() };
    let k = group.len();
    if k >= 2 && bytes > 0.0 {
        if coll == Collective::P2P {
            b.send(group[0], group[k - 1], bytes, Vec::new());
        } else if algo == Algo::Hier {
            hier(&mut b, g, coll, group, bytes);
        } else {
            if algo == Algo::HalvingDoubling && !k.is_power_of_two() {
                return None;
            }
            let order = snake_order(g, group);
            let none = Deps::new();
            match coll {
                Collective::AllReduce => match algo {
                    Algo::Ring => {
                        let f = ring_pass(&mut b, &order, bytes, &none);
                        ring_pass(&mut b, &order, bytes, &f);
                    }
                    Algo::HalvingDoubling => {
                        let f = hd_pass(&mut b, &order, bytes, &none, true);
                        hd_pass(&mut b, &order, bytes, &f, false);
                    }
                    _ => {
                        let f = direct_pass(&mut b, &order, bytes, &none);
                        direct_pass(&mut b, &order, bytes, &f);
                    }
                },
                Collective::ReduceScatter => {
                    let _ = match algo {
                        Algo::Ring => ring_pass(&mut b, &order, bytes, &none),
                        Algo::HalvingDoubling => hd_pass(&mut b, &order, bytes, &none, true),
                        _ => direct_pass(&mut b, &order, bytes, &none),
                    };
                }
                Collective::AllGather => {
                    let _ = match algo {
                        Algo::Ring => ring_pass(&mut b, &order, bytes, &none),
                        Algo::HalvingDoubling => hd_pass(&mut b, &order, bytes, &none, false),
                        _ => direct_pass(&mut b, &order, bytes, &none),
                    };
                }
                Collective::AllToAll => {
                    let _ = match algo {
                        Algo::Direct => direct_pass(&mut b, &order, bytes, &none),
                        _ => shift_a2a(&mut b, &order, bytes, &none),
                    };
                }
                Collective::Broadcast => {
                    let _ = match algo {
                        Algo::Ring => chain_bcast(&mut b, &order, bytes, &none),
                        Algo::HalvingDoubling => tree_bcast(&mut b, &order, bytes, &none),
                        _ => scatter_ag_bcast(&mut b, &order, bytes, &none),
                    };
                }
                Collective::P2P => unreachable!("handled above"),
            }
        }
    }
    Some(Schedule { coll, algo, msgs: b.msgs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::interconnect::nvlink4;
    use crate::system::topology;

    fn torus() -> FabricGraph {
        FabricGraph::new(&topology::torus2d(4, 4, &nvlink4()))
    }

    #[test]
    fn ring_allreduce_message_count() {
        let g = torus();
        let group: Vec<usize> = (0..16).collect();
        let s = build(&g, Algo::Ring, Collective::AllReduce, &group, 1e6).unwrap();
        // RS + AG, each k(k−1) chunk messages
        assert_eq!(s.msgs.len(), 2 * 16 * 15);
        // every chunk is S/k
        assert!(s.msgs.iter().all(|m| (m.bytes - 1e6 / 16.0).abs() < 1e-9));
    }

    #[test]
    fn snake_order_is_adjacent_on_torus() {
        let g = torus();
        let group: Vec<usize> = (0..16).collect();
        let o = snake_order(&g, &group);
        for i in 0..o.len() {
            let a = o[i];
            let b = o[(i + 1) % o.len()];
            assert_eq!(g.dim_order_path(a, b).len(), 1, "{a}->{b} not adjacent");
        }
    }

    #[test]
    fn deps_always_point_backwards() {
        let g = torus();
        let group: Vec<usize> = (0..16).collect();
        for algo in Algo::ALL {
            for coll in [
                Collective::AllReduce,
                Collective::AllGather,
                Collective::ReduceScatter,
                Collective::AllToAll,
                Collective::Broadcast,
                Collective::P2P,
            ] {
                let Some(s) = build(&g, algo, coll, &group, 1e6) else { continue };
                for (i, m) in s.msgs.iter().enumerate() {
                    assert!(m.deps.iter().all(|&d| (d as usize) < i), "{algo:?} {coll:?}");
                    assert!(m.bytes > 0.0 && m.src != m.dst);
                }
            }
        }
    }

    #[test]
    fn hd_requires_power_of_two() {
        let g = FabricGraph::new(&topology::ring(6, &nvlink4()));
        let group: Vec<usize> = (0..6).collect();
        assert!(build(&g, Algo::HalvingDoubling, Collective::AllReduce, &group, 1e6).is_none());
        assert!(build(&g, Algo::Ring, Collective::AllReduce, &group, 1e6).is_some());
    }

    #[test]
    fn degenerate_groups_are_empty_schedules() {
        let g = torus();
        let s = build(&g, Algo::Ring, Collective::AllReduce, &[3], 1e6).unwrap();
        assert!(s.msgs.is_empty());
        let s = build(&g, Algo::Ring, Collective::AllReduce, &[0, 1], 0.0).unwrap();
        assert!(s.msgs.is_empty());
    }

    #[test]
    fn hier_alltoall_phases_per_dim() {
        let g = torus();
        let group: Vec<usize> = (0..16).collect();
        let s = build(&g, Algo::Hier, Collective::AllToAll, &group, 1e6).unwrap();
        // 2 phases × 4 lines × k(k−1) shift messages
        assert_eq!(s.msgs.len(), 2 * 4 * 4 * 3);
    }

    #[test]
    fn algo_names_roundtrip() {
        for a in Algo::ALL {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert!(Algo::parse("nope").is_none());
    }
}
