//! Link-level expansion of a [`Topology`] into an explicit node/link graph.
//!
//! Chips come first (ids `0..n_chips`, mixed-radix over the dim sizes with
//! dim 0 fastest); every Switch dim adds one crossbar node per line after
//! the chips. A chip pair differs in at most one dim, so each directed link
//! belongs to exactly one dim and `(src, dst)` identifies it uniquely.
//!
//! Routing:
//! * **dimension-ordered**: correct coordinates dim by dim in index order —
//!   minimal direction inside rings (ties go positive), direct hops inside
//!   fully-connected dims, up/down through the crossbar for switch dims,
//!   BFS next-hops (lowest-id tie-break) inside the DGX-1 cube-mesh. Every
//!   dim-ordered path is a shortest path in these product topologies.
//! * **minimal-adaptive** (`sim::Routing::MinimalAdaptive`): the simulator
//!   picks per hop among all shortest-path successors (`dists_to`) by
//!   earliest link availability.

use std::collections::{HashMap, VecDeque};

use crate::system::topology::{Dim, DimFabric, DimKind, Topology};

/// One directed link: bytes serialize at `bw`, then arrive `latency` later.
#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub src: usize,
    pub dst: usize,
    /// Bytes/s, one direction.
    pub bw: f64,
    /// Seconds per traversal.
    pub latency: f64,
}

/// The 16 undirected edges of the DGX-1 hybrid cube-mesh [2]: two
/// fully-connected quads plus the cube matching i↔i+4.
pub const CUBE_EDGES: [(usize, usize); 16] = [
    (0, 1),
    (0, 2),
    (0, 3),
    (1, 2),
    (1, 3),
    (2, 3),
    (4, 5),
    (4, 6),
    (4, 7),
    (5, 6),
    (5, 7),
    (6, 7),
    (0, 4),
    (1, 5),
    (2, 6),
    (3, 7),
];

/// A Hamiltonian cycle of the cube-mesh; ring sub-algorithms follow it so
/// every ring step is a single physical hop.
pub const CUBE_RING: [usize; 8] = [0, 1, 2, 3, 7, 6, 5, 4];

/// Explicit node/link expansion of one topology.
#[derive(Debug, Clone)]
pub struct FabricGraph {
    pub name: String,
    pub n_chips: usize,
    pub links: Vec<Link>,
    /// Outgoing link ids per node (chips first, then switch nodes).
    pub adj: Vec<Vec<u32>>,
    dims: Vec<Dim>,
    strides: Vec<usize>,
    /// First switch-node id per Switch dim.
    switch_base: Vec<Option<usize>>,
    /// Incoming link ids per node (for reverse BFS).
    radj: Vec<Vec<u32>>,
    link_ix: HashMap<(usize, usize), u32>,
    /// `cube_next[a][b]`: next cube-mesh coordinate from a toward b.
    cube_next: [[usize; 8]; 8],
}

impl FabricGraph {
    pub fn new(t: &Topology) -> Self {
        let dims = t.dims.clone();
        let mut strides = Vec::with_capacity(dims.len());
        let mut n = 1usize;
        for d in &dims {
            strides.push(n);
            n *= d.size;
        }
        let n_chips = n;
        let mut switch_base = vec![None; dims.len()];
        let mut n_nodes = n_chips;
        for (i, d) in dims.iter().enumerate() {
            if d.kind == DimKind::Switch && d.size > 1 && d.fabric == DimFabric::Kind {
                switch_base[i] = Some(n_nodes);
                n_nodes += n_chips / d.size;
            }
        }
        let mut g = FabricGraph {
            name: t.name.clone(),
            n_chips,
            links: Vec::new(),
            adj: vec![Vec::new(); n_nodes],
            dims,
            strides,
            switch_base,
            radj: vec![Vec::new(); n_nodes],
            link_ix: HashMap::new(),
            cube_next: cube_next_table(),
        };
        for di in 0..g.dims.len() {
            let d = g.dims[di].clone();
            if d.size <= 1 {
                continue;
            }
            let lines = g.lines(di);
            for line in lines {
                if d.fabric == DimFabric::CubeMesh {
                    assert_eq!(d.size, 8, "cube-mesh dims have exactly 8 nodes");
                    for &(a, b) in CUBE_EDGES.iter() {
                        g.add_link(line[a], line[b], &d);
                        g.add_link(line[b], line[a], &d);
                    }
                } else {
                    match d.kind {
                        DimKind::Ring => {
                            let k = d.size;
                            for c in 0..k {
                                g.add_link(line[c], line[(c + 1) % k], &d);
                                if k > 2 {
                                    g.add_link(line[c], line[(c + k - 1) % k], &d);
                                }
                            }
                        }
                        DimKind::FullyConnected => {
                            for a in 0..d.size {
                                for b in 0..d.size {
                                    if a != b {
                                        g.add_link(line[a], line[b], &d);
                                    }
                                }
                            }
                        }
                        DimKind::Switch => {
                            let sw = g.switch_node(di, line[0]);
                            for &c in &line {
                                g.add_link(c, sw, &d);
                                g.add_link(sw, c, &d);
                            }
                        }
                    }
                }
            }
        }
        g
    }

    fn add_link(&mut self, src: usize, dst: usize, d: &Dim) {
        let id = self.links.len() as u32;
        self.links.push(Link { src, dst, bw: d.link_bw.raw(), latency: d.latency.raw() });
        self.adj[src].push(id);
        self.radj[dst].push(id);
        let prev = self.link_ix.insert((src, dst), id);
        debug_assert!(prev.is_none(), "duplicate link {src}->{dst}");
    }

    pub fn n_nodes(&self) -> usize {
        self.adj.len()
    }

    pub fn dims(&self) -> &[Dim] {
        &self.dims
    }

    /// Per-dim coordinates of a chip.
    pub fn coords(&self, chip: usize) -> Vec<usize> {
        (0..self.dims.len()).map(|i| (chip / self.strides[i]) % self.dims[i].size).collect()
    }

    /// Chip id of a coordinate vector.
    pub fn chip_at(&self, coords: &[usize]) -> usize {
        coords.iter().zip(&self.strides).map(|(&c, &s)| c * s).sum()
    }

    /// Chip ids of every maximal line along dim `di`, each in coord order.
    pub fn lines(&self, di: usize) -> Vec<Vec<usize>> {
        let k = self.dims[di].size;
        let stride = self.strides[di];
        let n_lines = self.n_chips / k;
        (0..n_lines)
            .map(|r| {
                let base = (r / stride) * stride * k + r % stride;
                (0..k).map(|c| base + c * stride).collect()
            })
            .collect()
    }

    /// Crossbar node serving `chip`'s line along switch dim `di`.
    pub fn switch_node(&self, di: usize, chip: usize) -> usize {
        let stride = self.strides[di];
        let k = self.dims[di].size;
        let coord = (chip / stride) % k;
        let cid = chip - coord * stride;
        let rank = (cid / (stride * k)) * stride + cid % stride;
        self.switch_base[di].expect("not a switch dim") + rank
    }

    /// Dimension-ordered route `src → dst` as link ids (deterministic).
    pub fn dim_order_path(&self, src: usize, dst: usize) -> Vec<u32> {
        let mut path = Vec::new();
        let mut cur = self.coords(src);
        let dstc = self.coords(dst);
        let mut node = src;
        for (di, d) in self.dims.iter().enumerate() {
            let stride = self.strides[di];
            while cur[di] != dstc[di] {
                if d.fabric == DimFabric::CubeMesh {
                    let nxt = self.cube_next[cur[di]][dstc[di]];
                    let nn = node - cur[di] * stride + nxt * stride;
                    path.push(self.link_ix[&(node, nn)]);
                    node = nn;
                    cur[di] = nxt;
                    continue;
                }
                match d.kind {
                    DimKind::Ring => {
                        let k = d.size;
                        let fwd = (dstc[di] + k - cur[di]) % k;
                        let bwd = (cur[di] + k - dstc[di]) % k;
                        let nxt =
                            if fwd <= bwd { (cur[di] + 1) % k } else { (cur[di] + k - 1) % k };
                        let nn = node - cur[di] * stride + nxt * stride;
                        path.push(self.link_ix[&(node, nn)]);
                        node = nn;
                        cur[di] = nxt;
                    }
                    DimKind::FullyConnected => {
                        let nn = node - cur[di] * stride + dstc[di] * stride;
                        path.push(self.link_ix[&(node, nn)]);
                        node = nn;
                        cur[di] = dstc[di];
                    }
                    DimKind::Switch => {
                        let nn = node - cur[di] * stride + dstc[di] * stride;
                        let sw = self.switch_node(di, node);
                        path.push(self.link_ix[&(node, sw)]);
                        path.push(self.link_ix[&(sw, nn)]);
                        node = nn;
                        cur[di] = dstc[di];
                    }
                }
            }
        }
        path
    }

    /// BFS hop distances from every node to `dst` (`u32::MAX` unreachable).
    pub fn dists_to(&self, dst: usize) -> Vec<u32> {
        let mut dist = vec![u32::MAX; self.n_nodes()];
        dist[dst] = 0;
        let mut q = VecDeque::with_capacity(self.n_nodes());
        q.push_back(dst);
        while let Some(u) = q.pop_front() {
            for &lix in &self.radj[u] {
                let v = self.links[lix as usize].src;
                if dist[v] == u32::MAX {
                    dist[v] = dist[u] + 1;
                    q.push_back(v);
                }
            }
        }
        dist
    }
}

/// BFS next-hop table of the 8-node cube-mesh, lowest-id tie-break.
fn cube_next_table() -> [[usize; 8]; 8] {
    let mut adj = [[false; 8]; 8];
    for &(a, b) in CUBE_EDGES.iter() {
        adj[a][b] = true;
        adj[b][a] = true;
    }
    let mut next = [[0usize; 8]; 8];
    for dst in 0..8 {
        let mut dist = [usize::MAX; 8];
        dist[dst] = 0;
        let mut q = vec![dst];
        let mut qi = 0;
        while qi < q.len() {
            let u = q[qi];
            qi += 1;
            for v in 0..8 {
                if adj[u][v] && dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    q.push(v);
                }
            }
        }
        for u in 0..8 {
            next[u][dst] = if u == dst {
                u
            } else {
                (0..8).find(|&v| adj[u][v] && dist[v] + 1 == dist[u]).expect("connected mesh")
            };
        }
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::interconnect::nvlink4;
    use crate::system::topology;

    #[test]
    fn torus_expansion_counts() {
        let t = topology::torus2d(4, 4, &nvlink4());
        let g = FabricGraph::new(&t);
        assert_eq!(g.n_chips, 16);
        assert_eq!(g.n_nodes(), 16); // no switches
        // 2 dims × 16 chips × 2 directions
        assert_eq!(g.links.len(), 64);
        assert!(g.adj.iter().take(16).all(|a| a.len() == 4));
    }

    #[test]
    fn ring_of_two_has_one_link_per_direction() {
        let t = topology::ring(2, &nvlink4());
        let g = FabricGraph::new(&t);
        assert_eq!(g.links.len(), 2);
    }

    #[test]
    fn switch_dims_add_crossbar_nodes() {
        let t = topology::dgx2(4, &nvlink4()); // [Switch 16, Switch 4] = 64 chips
        let g = FabricGraph::new(&t);
        assert_eq!(g.n_chips, 64);
        // 4 crossbars for the 16-dim lines + 16 for the 4-dim lines
        assert_eq!(g.n_nodes(), 64 + 4 + 16);
        // every chip: 1 uplink per switch dim
        assert!(g.adj.iter().take(64).all(|a| a.len() == 2));
    }

    #[test]
    fn dgx1_local_dim_is_the_cube_mesh() {
        let t = topology::dgx1(1, &nvlink4());
        let g = FabricGraph::new(&t);
        assert_eq!(g.n_chips, 8);
        // 16 undirected edges = 32 directed links, degree 4 per GPU
        assert_eq!(g.links.len(), 32);
        assert!(g.adj.iter().take(8).all(|a| a.len() == 4));
        // 0 → 5 is not a mesh edge: exactly 2 hops
        assert_eq!(g.dim_order_path(0, 5).len(), 2);
        assert_eq!(g.dim_order_path(0, 1).len(), 1);
    }

    #[test]
    fn dim_order_paths_are_minimal_on_tori() {
        let t = topology::torus2d(4, 4, &nvlink4());
        let g = FabricGraph::new(&t);
        for src in 0..16 {
            let dists = g.dists_to(src);
            for dst in 0..16 {
                let p = g.dim_order_path(dst, src);
                assert_eq!(p.len() as u32, dists[dst], "{dst}->{src}");
                // path links actually chain from dst to src
                let mut node = dst;
                for &l in &p {
                    assert_eq!(g.links[l as usize].src, node);
                    node = g.links[l as usize].dst;
                }
                assert_eq!(node, src);
            }
        }
    }

    #[test]
    fn switch_paths_cross_the_crossbar() {
        let t = topology::dgx2(4, &nvlink4());
        let g = FabricGraph::new(&t);
        // same box: up + down
        assert_eq!(g.dim_order_path(0, 1).len(), 2);
        // different box: up+down intra, then up+down inter
        assert_eq!(g.dim_order_path(0, 17).len(), 4);
    }

    #[test]
    fn lines_partition_chips() {
        let t = topology::torus3d(4, 2, 2, &nvlink4());
        let g = FabricGraph::new(&t);
        for di in 0..3 {
            let lines = g.lines(di);
            let mut all: Vec<usize> = lines.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..16).collect::<Vec<_>>(), "dim {di}");
        }
    }
}
