//! Packet-level network-fabric simulator with topology-aware collective
//! algorithm selection — the validation-and-optimization layer under the
//! §IV-B interconnection-network model.
//!
//! The closed-form `collective` module prices every inter-chip decision
//! (TP/PP/DP assignment, sharding, the DSE heat maps, the cluster planner)
//! with α-β formulas that cannot see link contention, routing, or
//! algorithm choice. This module makes those costs *certifiable*:
//!
//! * [`graph`] expands any `system::topology::Topology` into an explicit
//!   node/link graph — tori, dragonfly, DGX-2 crossbars, and the real
//!   DGX-1 hybrid cube-mesh (which the analytical model shortcuts as
//!   fully-connected) — with dimension-ordered and minimal-adaptive
//!   routing;
//! * [`algorithms`] emits message schedules for ring, recursive
//!   halving/doubling, direct all-port, and hierarchical (BlueConnect
//!   phase-per-dim) variants of every collective the sharding layer emits;
//! * [`sim`] plays a schedule over the graph with link-occupancy
//!   contention, deterministically (same config → same trace), returning
//!   completion time plus per-link utilization;
//! * [`select`] sweeps algorithms per (collective, payload, topology) and
//!   distills a calibration table that `collective::CollectiveModel`
//!   carries into `interchip::optimize`, `pipeline`, and the DSE.
//!
//! Fidelity contract (enforced by `rust/tests/fabric_sim.rs`): ring
//! schedules on ring dims reproduce the α-β formulas exactly, and the best
//! algorithm on contention-free fully-connected/switch dims lands within
//! 15% of `collective::time` for AR/AG/RS/A2A/P2P. Broadcast is the known
//! exception: the analytical switch form assumes hardware multicast that
//! no software schedule reproduces, which the calibration path surfaces
//! honestly instead of hiding.

pub mod algorithms;
pub mod graph;
pub mod select;
pub mod sim;

pub use algorithms::{build, Algo, Msg, Schedule};
pub use graph::{FabricGraph, Link};
pub use select::{best, calibrate, evaluate_algos, AlgoEval, CalibrateOpts};
pub use sim::{simulate, Routing, SimConfig, SimResult};

/// `pub(crate)`: external callers go through `api::calibrate` or a
/// calibrated-fabric `api::Scenario` knob.
pub(crate) use select::calibrate_system;
