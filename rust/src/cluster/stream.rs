//! Streaming latency summaries: P² quantile estimation (Jain & Chlamtac,
//! CACM 1985) so million-request runs summarize TTFT/TPOT/queue delay in
//! O(1) memory — five markers per quantile — instead of retaining and
//! sorting per-request sample vectors.
//!
//! Accuracy (machine-validated against exact percentiles in
//! `python/tests/mirror_cluster.py` and pinned by `tests/cluster_scale.rs`):
//! on smooth unimodal latency distributions (exponential, log-normal) the
//! estimates land within 5% relative at p50/p95 and 10% at p99; on strongly
//! *bimodal* distributions — queue delay under saturated bursty traffic,
//! where most requests wait ~0 and burst crests wait ~1 s — the 5-marker
//! parabolic interpolation can be off by tens of percent. Runs that need
//! faithful tails on such shapes should keep the exact path
//! (`SimOptions::exact_percentiles`); everything else gets
//! request-count-independent memory.

use super::engine::Pcts;

/// Single-quantile P² estimator: five markers tracking the running
/// quantile, updated with parabolic (fallback linear) interpolation.
///
/// Until five observations arrive, the estimate is the exact sample
/// quantile of what has been seen (same nearest-rank convention as
/// [`super::engine::percentiles`]); with zero observations it is 0.
///
/// ```
/// use dfmodel::cluster::stream::P2Quantile;
/// let mut q = P2Quantile::new(0.5);
/// for i in 1..=1001 {
///     q.observe(f64::from(i));
/// }
/// // true median of 1..=1001 is 501; P² tracks it closely even on a
/// // monotone (worst-case-ordered) stream
/// assert!((q.estimate() - 501.0).abs() < 20.0);
/// ```
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights; during warmup (`count <= 5`) the sorted first
    /// samples live in `q[..count]`.
    q: [f64; 5],
    /// Actual marker positions (1-based ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: u64,
}

impl P2Quantile {
    /// An estimator for the `p`-quantile, `0 < p < 1`.
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p < 1.0, "P2Quantile needs 0 < p < 1, got {p}");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
        }
    }

    /// Observations seen so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fold one observation into the estimate.
    pub fn observe(&mut self, x: f64) {
        self.count += 1;
        if self.count <= 5 {
            // warmup: insertion-sort into the marker array
            let k = self.count as usize - 1;
            self.q[k] = x;
            let mut i = k;
            while i > 0 && self.q[i - 1] > self.q[i] {
                self.q.swap(i - 1, i);
                i -= 1;
            }
            return;
        }
        let (q, n) = (&mut self.q, &mut self.n);
        // locate the marker interval containing x, stretching the extremes
        let k = if x < q[0] {
            q[0] = x;
            0
        } else if x >= q[4] {
            if x > q[4] {
                q[4] = x;
            }
            3
        } else {
            let mut k = 0;
            while x >= q[k + 1] {
                k += 1;
            }
            k
        };
        for ni in n.iter_mut().skip(k + 1) {
            *ni += 1.0;
        }
        for (npi, dni) in self.np.iter_mut().zip(self.dn) {
            *npi += dni;
        }
        // nudge interior markers toward their desired positions
        for i in 1..4 {
            let d = self.np[i] - n[i];
            if (d >= 1.0 && n[i + 1] - n[i] > 1.0) || (d <= -1.0 && n[i - 1] - n[i] < -1.0) {
                let ds = if d > 0.0 { 1.0 } else { -1.0 };
                let qp = q[i]
                    + ds / (n[i + 1] - n[i - 1])
                        * ((n[i] - n[i - 1] + ds) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                            + (n[i + 1] - n[i] - ds) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]));
                if q[i - 1] < qp && qp < q[i + 1] {
                    q[i] = qp; // parabolic
                } else {
                    let j = if ds > 0.0 { i + 1 } else { i - 1 };
                    q[i] += ds * (q[j] - q[i]) / (n[j] - n[i]); // linear
                }
                n[i] += ds;
            }
        }
    }

    /// Current estimate of the `p`-quantile.
    pub fn estimate(&self) -> f64 {
        match self.count {
            0 => 0.0,
            c if c <= 5 => {
                // exact nearest-rank on the sorted warmup samples
                let len = c as usize;
                self.q[(self.p * (len - 1) as f64).round() as usize]
            }
            _ => self.q[2],
        }
    }
}

/// Streaming replacement for the exact `Pcts` summary: running mean plus
/// P² estimators for p50/p95/p99, in constant memory.
///
/// ```
/// use dfmodel::cluster::stream::StreamingPcts;
/// let mut s = StreamingPcts::new();
/// for i in 1..=100 {
///     s.observe(f64::from(i));
/// }
/// let p = s.pcts();
/// assert!((p.mean - 50.5).abs() < 1e-9); // the mean is exact
/// assert!((p.p50 - 50.0).abs() < 5.0);
/// ```
#[derive(Debug, Clone)]
pub struct StreamingPcts {
    count: u64,
    sum: f64,
    p50: P2Quantile,
    p95: P2Quantile,
    p99: P2Quantile,
}

impl Default for StreamingPcts {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingPcts {
    /// An empty summary.
    pub fn new() -> Self {
        StreamingPcts {
            count: 0,
            sum: 0.0,
            p50: P2Quantile::new(0.50),
            p95: P2Quantile::new(0.95),
            p99: P2Quantile::new(0.99),
        }
    }

    /// Fold one sample into all three quantile estimators and the mean.
    pub fn observe(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.p50.observe(x);
        self.p95.observe(x);
        self.p99.observe(x);
    }

    /// Samples seen so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The summary: exact mean, P²-estimated percentiles (all-zero when no
    /// samples arrived, matching the exact path's empty-slice convention).
    pub fn pcts(&self) -> Pcts {
        if self.count == 0 {
            return Pcts { mean: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
        }
        Pcts {
            mean: self.sum / self.count as f64,
            p50: self.p50.estimate(),
            p95: self.p95.estimate(),
            p99: self.p99.estimate(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::engine::percentiles;
    use crate::util::prng::Rng;

    #[test]
    fn tracks_exact_percentiles_on_exponential_samples() {
        let mut rng = Rng::new(100);
        let samples: Vec<f64> = (0..20_000).map(|_| rng.exp(2.0)).collect();
        let mut s = StreamingPcts::new();
        for &x in &samples {
            s.observe(x);
        }
        let est = s.pcts();
        let exact = percentiles(samples);
        assert!((est.mean - exact.mean).abs() / exact.mean < 1e-12, "mean is exact");
        assert!((est.p50 - exact.p50).abs() / exact.p50 < 0.05);
        assert!((est.p95 - exact.p95).abs() / exact.p95 < 0.05);
        assert!((est.p99 - exact.p99).abs() / exact.p99 < 0.10);
    }

    #[test]
    fn warmup_is_exact_and_empty_is_zero() {
        let mut s = StreamingPcts::new();
        for x in [5.0, 1.0, 4.0, 2.0] {
            s.observe(x);
        }
        let exact = percentiles(vec![5.0, 1.0, 4.0, 2.0]);
        assert_eq!(s.pcts(), exact, "n <= 5 must fall back to exact quantiles");
        let z = StreamingPcts::new();
        assert_eq!(z.pcts(), Pcts { mean: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 });
    }

    #[test]
    fn quantile_rejects_degenerate_p() {
        for p in [0.0, 1.0, -0.5] {
            assert!(std::panic::catch_unwind(|| P2Quantile::new(p)).is_err());
        }
    }

    #[test]
    fn extremes_stretch_the_outer_markers() {
        let mut q = P2Quantile::new(0.5);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0, 100.0, -7.0] {
            q.observe(x);
        }
        assert_eq!(q.count(), 7);
        // markers absorbed both extremes without losing the median's scale
        let m = q.estimate();
        assert!((1.0..=5.0).contains(&m), "median estimate {m} out of band");
    }
}
