//! SLO-aware capacity planner: sweep (chip platform × TP×PP split × replica
//! count) in parallel, simulate each candidate fleet against the target
//! traffic, and return the cheapest configuration whose goodput meets the
//! QPS + SLO target — the first coupling of the §VI cost catalog to the
//! §VIII serving model.
//!
//! Every candidate is judged on **simulated** SLO attainment over identical
//! traffic: the trace is described once as a [`TraceSpec`] and each
//! candidate replays it through the engine's streaming path
//! ([`super::engine::simulate_stream`]), so attainment/goodput are exact
//! event-history facts while memory stays O(in-flight) per worker — the
//! analytical model only seeds the replica-count search.

use super::engine::{simulate_stream, ReplicaConfig, SimOptions, SimReport, Slo};
use super::workload::{Arrivals, LengthDist, TraceSpec};
use crate::graph::llama::LlamaConfig;
use crate::serving::{self, ServingSystem};
use crate::system::{chip, interconnect, memory, ChipSpec, LinkTech, MemoryTech};
use crate::util::table::Table;
use crate::util::threadpool::parallel_map;
use crate::util::units::fmt_time;

/// A serving platform: an accelerator paired with the device memory and
/// fabric it ships with.
#[derive(Debug, Clone)]
pub struct Platform {
    /// Accelerator chip.
    pub chip: ChipSpec,
    /// Device-memory technology each chip ships with.
    pub mem: MemoryTech,
    /// Intra-replica fabric.
    pub link: LinkTech,
}

impl Platform {
    /// One replica: a `group`-chip instance of this platform.
    pub fn replica(&self, group: usize) -> ServingSystem {
        ServingSystem {
            chip: self.chip.clone(),
            mem_bw: self.mem.bandwidth.raw(),
            mem_cap: self.mem.capacity.raw(),
            link: self.link.clone(),
            n_chips: group,
        }
    }
}

/// The serving-platform catalog: Table V's DRAM-backed chips plus the §VIII
/// SN40L (WSE-2 has no device DRAM in this model and is excluded).
pub fn catalog() -> Vec<Platform> {
    vec![
        Platform { chip: chip::h100(), mem: memory::hbm3(), link: interconnect::nvlink4() },
        Platform { chip: chip::tpu_v4(), mem: memory::hbm3(), link: interconnect::pcie4() },
        Platform {
            chip: chip::sn40l(),
            mem: memory::sn40l_hbm(),
            link: interconnect::rdu_fabric(),
        },
        Platform { chip: chip::sn30(), mem: memory::ddr4(), link: interconnect::pcie4() },
    ]
}

/// What the fleet must achieve.
#[derive(Debug, Clone, Copy)]
pub struct PlanTarget {
    /// Offered load, requests/s.
    pub qps: f64,
    /// Latency bounds a request must meet to count toward goodput.
    pub slo: Slo,
    /// Required fraction of completed requests meeting both SLOs.
    pub attainment: f64,
}

/// Traffic shape used for the planning simulations.
#[derive(Debug, Clone, Copy)]
pub struct PlanTraffic {
    /// Trace seed — all candidates replay the same seeded trace.
    pub seed: u64,
    /// Simulated trace length per candidate, requests.
    pub n_requests: usize,
    /// Prompt-length distribution.
    pub prompt: LengthDist,
    /// Output-length distribution.
    pub output: LengthDist,
}

impl Default for PlanTraffic {
    fn default() -> Self {
        PlanTraffic {
            seed: 17,
            n_requests: 300,
            prompt: LengthDist { mean: 1024.0, sigma: 0.4, min: 16, max: 8192 },
            output: LengthDist { mean: 128.0, sigma: 0.6, min: 2, max: 2048 },
        }
    }
}

/// One evaluated fleet configuration.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    /// Chip name of the platform.
    pub platform: String,
    /// Chips per replica.
    pub group: usize,
    /// Tensor-parallel width within a replica.
    pub tp: usize,
    /// Pipeline-parallel depth within a replica.
    pub pp: usize,
    /// Replicas in the fleet.
    pub replicas: usize,
    /// `group × replicas`.
    pub chips_total: usize,
    /// Fleet purchase price, USD.
    pub capex_usd: f64,
    /// 3-year-amortized capex plus electricity at $0.12/kWh.
    pub usd_per_hour: f64,
    /// Whether the simulated fleet met attainment with zero drops.
    pub meets_target: bool,
    /// The simulation backing the verdict (streaming path: exact counts,
    /// P² percentiles, no per-request vector).
    pub report: SimReport,
}

/// Fleet cost: capex (chips + device memory + one ring link per chip in
/// each replica) and the amortized $/hr.
pub fn fleet_cost(p: &Platform, group: usize, replicas: usize) -> (f64, f64) {
    let links = if group > 1 { group } else { 0 } as f64;
    let replica_capex = p.chip.price_usd * group as f64
        + p.mem.price_usd() * group as f64
        + p.link.price_usd * links;
    let replica_w =
        p.chip.power_w * group as f64 + p.mem.power_w() * group as f64 + p.link.power_w * links;
    let capex = (replica_capex * replicas as f64).raw();
    let watts = (replica_w * replicas as f64).raw();
    let usd_per_hour = capex / (3.0 * 365.0 * 24.0) + watts / 1000.0 * 0.12;
    (capex, usd_per_hour)
}

/// All (tp, pp) factorizations of a group size.
pub fn splits(group: usize) -> Vec<(usize, usize)> {
    (1..=group).filter(|tp| group % tp == 0).map(|tp| (tp, group / tp)).collect()
}

/// Analytic seed for the replica-count search: full-batch decode tokens/s
/// divided by the mean output length. It ignores prefill time, so it lower-
/// bounds the fleet; the simulation loop corrects it upward.
fn seed_replicas(cfg: &ReplicaConfig, target: &PlanTarget, traffic: &PlanTraffic) -> Option<usize> {
    let ctx = traffic.prompt.mean + 0.5 * traffic.output.mean;
    let m = serving::evaluate(
        &cfg.model,
        &cfg.sys,
        &serving::ServingPoint {
            tp: cfg.tp,
            pp: cfg.pp,
            batch: cfg.max_batch as f64,
            prompt_len: 1.0,
            context: ctx,
        },
    )
    .ok()?;
    let req_per_s = m.decode_tps / traffic.output.mean;
    if req_per_s <= 0.0 {
        return None;
    }
    Some(((target.qps / req_per_s).ceil() as usize).max(1))
}

/// Evaluate one (platform, group, tp, pp): search replica counts upward
/// from the analytic seed until the simulated fleet meets the target (or
/// give up and report the last attempt as failing). Growth is ×1.5 per
/// attempt — the seed underestimates by the prefill share, which is a
/// bounded factor, so a fixed number of multiplicative steps covers it at
/// any qps (an additive +1 search would not).
fn evaluate_candidate(
    model: &LlamaConfig,
    p: &Platform,
    group: usize,
    tp: usize,
    pp: usize,
    target: &PlanTarget,
    traffic: &PlanTraffic,
    spec: &TraceSpec,
) -> Option<FleetPlan> {
    let cfg = ReplicaConfig::new(*model, p.replica(group), tp, pp);
    cfg.kv_budget_bytes()?; // weights must fit the group
    let mut replicas = seed_replicas(&cfg, target, traffic)?;
    let mut last: Option<(usize, SimReport, bool)> = None;
    for _ in 0..6 {
        let report =
            simulate_stream(&cfg, replicas, spec, &target.slo, &SimOptions::default()).ok()?;
        let ok = report.slo_attainment >= target.attainment
            && report.n_completed == report.n_offered;
        last = Some((replicas, report, ok));
        if ok {
            break;
        }
        replicas = (replicas + replicas / 2).max(replicas + 1);
    }
    let (replicas, report, meets_target) = last?;
    let (capex_usd, usd_per_hour) = fleet_cost(p, group, replicas);
    Some(FleetPlan {
        platform: p.chip.name.clone(),
        group,
        tp,
        pp,
        replicas,
        chips_total: group * replicas,
        capex_usd,
        usd_per_hour,
        meets_target,
        report,
    })
}

/// The planner's output: every evaluated fleet, cheapest first.
#[derive(Debug, Clone)]
pub struct PlanResult {
    /// Every evaluated fleet, cheapest first.
    pub candidates: Vec<FleetPlan>,
    /// Index into `candidates` of the cheapest plan meeting the target.
    pub best: Option<usize>,
}

/// Sweep the candidate space (catalog × group sizes × TP×PP splits) with
/// `util::threadpool::parallel_map` and rank by $/hr.
pub fn plan(model: &LlamaConfig, target: &PlanTarget, traffic: &PlanTraffic) -> PlanResult {
    let groups = [4usize, 8, 16];
    let mut cands: Vec<(Platform, usize, usize, usize)> = Vec::new();
    for p in catalog() {
        for &g in &groups {
            for (tp, pp) in splits(g) {
                cands.push((p.clone(), g, tp, pp));
            }
        }
    }
    // one shared trace spec: every candidate replays identical traffic
    // from the seed without any worker materializing it
    let spec = TraceSpec {
        seed: traffic.seed,
        n_requests: traffic.n_requests,
        arrivals: Arrivals::Poisson { rate: target.qps },
        prompt: traffic.prompt,
        output: traffic.output,
    };
    let mut candidates: Vec<FleetPlan> = parallel_map(&cands, |(p, g, tp, pp)| {
        evaluate_candidate(model, p, *g, *tp, *pp, target, traffic, &spec)
    })
    .into_iter()
    .flatten()
    .collect();
    candidates.sort_by(|a, b| {
        a.usd_per_hour.total_cmp(&b.usd_per_hour).then(a.chips_total.cmp(&b.chips_total))
    });
    let best = candidates.iter().position(|c| c.meets_target);
    PlanResult { candidates, best }
}

/// Render the ranked fleets (top `limit`) as an ASCII table.
pub fn render(res: &PlanResult, limit: usize) -> String {
    let mut t = Table::new(
        "Capacity plan — cheapest fleets first",
        &["fleet", "chips", "$/hr", "capex $", "SLO att.", "TTFT p99", "TPOT p99", "meets"],
    );
    for (i, c) in res.candidates.iter().take(limit).enumerate() {
        let marker = if Some(i) == res.best { " <== plan" } else { "" };
        t.row(&[
            format!("{}x{} TP{}xPP{} r{}", c.platform, c.group, c.tp, c.pp, c.replicas),
            format!("{}", c.chips_total),
            format!("{:.2}", c.usd_per_hour),
            format!("{:.0}", c.capex_usd),
            format!("{:.1}%", c.report.slo_attainment * 100.0),
            fmt_time(c.report.ttft.p99),
            fmt_time(c.report.tpot.p99),
            format!("{}{}", if c.meets_target { "yes" } else { "no" }, marker),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_factorize_exactly() {
        assert_eq!(splits(4), vec![(1, 4), (2, 2), (4, 1)]);
        assert_eq!(splits(16).len(), 5);
        for (tp, pp) in splits(16) {
            assert_eq!(tp * pp, 16);
        }
    }

    #[test]
    fn fleet_cost_scales_linearly_in_replicas() {
        let p = &catalog()[0];
        let (c1, h1) = fleet_cost(p, 8, 1);
        let (c3, h3) = fleet_cost(p, 8, 3);
        assert!((c3 / c1 - 3.0).abs() < 1e-9);
        assert!((h3 / h1 - 3.0).abs() < 1e-9);
        assert!(c1 > 8.0 * p.chip.price_usd.raw(), "memory and links must add cost");
    }

    #[test]
    fn catalog_platforms_build_feasible_replicas() {
        for p in catalog() {
            let sys = p.replica(8);
            assert_eq!(sys.n_chips, 8);
            assert!(sys.mem_bw > 0.0 && sys.mem_cap > 0.0);
        }
    }
}
