//! Calendar queue: a bucketed earliest-first event scheduler (Brown 1988)
//! tuned to the engine's step-time granularity.
//!
//! Timestamps are hashed into a circular array of fixed-width buckets
//! ("days" of a repeating "year"); `pop` scans only the current day's
//! bucket for its earliest entry, so with a width near the typical event
//! spacing both operations are O(1) amortized — against the binary heap's
//! O(log n) — and, more importantly here, pops never touch entries outside
//! one bucket.
//!
//! Ordering contract: entries pop in ascending `(time, insertion sequence)`
//! order — **exactly** the order of the PR-2 `BinaryHeap` engine's reversed
//! `(t, seq)` max-heap, so the two schedulers are interchangeable and the
//! `matches_reference_heap_order` test proves it on seeded traces
//! (duplicate timestamps included).
//!
//! Sparse stretches (e.g. a long idle gap until the next prefill finishes)
//! are handled by the classic direct-search fallback: after scanning one
//! full calendar year of empty days, the queue jumps straight to the
//! earliest remaining day instead of stepping day by day.

/// A bucketed earliest-first queue of `(time, payload)` events with FIFO
/// tie-breaking on equal timestamps.
///
/// Times must be finite and non-negative (simulation clocks start at 0).
#[derive(Debug, Clone)]
pub struct CalendarQueue<T> {
    /// `buckets[d & mask]` holds every live entry whose day ≡ d (mod len).
    buckets: Vec<Vec<Entry<T>>>,
    mask: u64,
    width: f64,
    /// Current drain day: every entry of an earlier day has been popped.
    day: u64,
    len: usize,
    seq: u64,
}

#[derive(Debug, Clone)]
struct Entry<T> {
    t: f64,
    seq: u64,
    v: T,
}

impl<T> CalendarQueue<T> {
    /// A queue with `width`-second days and at least `min_buckets` buckets
    /// (rounded up to a power of two, floor 8). Pick `width` near the
    /// smallest common event spacing — the engine uses its batch-1 decode
    /// step — and `min_buckets` near the expected number of live events.
    pub fn new(width: f64, min_buckets: usize) -> Self {
        assert!(width.is_finite() && width > 0.0, "calendar bucket width must be positive");
        let nb = min_buckets.max(8).next_power_of_two();
        CalendarQueue {
            buckets: (0..nb).map(|_| Vec::new()).collect(),
            mask: (nb - 1) as u64,
            width,
            day: 0,
            len: 0,
            seq: 0,
        }
    }

    fn day_of(&self, t: f64) -> u64 {
        // t >= 0 and the as-cast saturates, so this is floor(t / width)
        (t / self.width) as u64
    }

    /// Insert an event at time `t`. Equal-timestamp events pop in insertion
    /// order.
    pub fn push(&mut self, t: f64, v: T) {
        debug_assert!(t.is_finite() && t >= 0.0, "event time must be finite and >= 0");
        let d = self.day_of(t);
        if d < self.day {
            // defensive rewind; unreachable from the engine (it only ever
            // schedules at or after the current clock)
            self.day = d;
        }
        self.buckets[(d & self.mask) as usize].push(Entry { t, seq: self.seq, v });
        self.seq += 1;
        self.len += 1;
    }

    /// Live events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no events are queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Advance `day` to the next day holding an entry and locate that day's
    /// earliest `(t, seq)` entry. `None` when empty.
    fn find_next(&mut self) -> Option<(usize, usize)> {
        if self.len == 0 {
            return None;
        }
        let mut scanned = 0usize;
        loop {
            let b = (self.day & self.mask) as usize;
            let mut best: Option<usize> = None;
            for (i, e) in self.buckets[b].iter().enumerate() {
                if self.day_of(e.t) == self.day
                    && best.map_or(true, |j| {
                        let bj = &self.buckets[b][j];
                        e.t.total_cmp(&bj.t).then(e.seq.cmp(&bj.seq)).is_lt()
                    })
                {
                    best = Some(i);
                }
            }
            if let Some(i) = best {
                return Some((b, i));
            }
            self.day += 1;
            scanned += 1;
            if scanned > self.buckets.len() {
                // a whole empty year: every remaining entry lies beyond the
                // scanned range — jump straight to the earliest one
                self.day = self
                    .buckets
                    .iter()
                    .flatten()
                    .map(|e| self.day_of(e.t))
                    .min()
                    .expect("len > 0 but no entries");
                scanned = 0;
            }
        }
    }

    /// Timestamp of the earliest event without removing it.
    pub fn peek_time(&mut self) -> Option<f64> {
        self.find_next().map(|(b, i)| self.buckets[b][i].t)
    }

    /// Remove and return the earliest event as `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let (b, i) = self.find_next()?;
        let e = self.buckets[b].swap_remove(i);
        self.len -= 1;
        Some((e.t, e.v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// The PR-2 reference scheduler: a heap ordered by (t, seq).
    #[derive(Default)]
    struct HeapQueue {
        heap: BinaryHeap<Reverse<(u64, u64, u64)>>, // (t.to_bits(), seq, v)
        seq: u64,
    }

    impl HeapQueue {
        fn push(&mut self, t: f64, v: u64) {
            // non-negative finite f64s order identically to their bits
            self.heap.push(Reverse((t.to_bits(), self.seq, v)));
            self.seq += 1;
        }
        fn pop(&mut self) -> Option<(f64, u64)> {
            self.heap.pop().map(|Reverse((b, _, v))| (f64::from_bits(b), v))
        }
    }

    #[test]
    fn matches_reference_heap_order() {
        for seed in [1u64, 7, 42, 99] {
            let mut rng = Rng::new(seed);
            let mut cq = CalendarQueue::new(0.001, 8);
            let mut hq = HeapQueue::default();
            let (mut t, mut last_t, mut n) = (0.0f64, 0.0f64, 0u64);
            let mut got = Vec::new();
            let mut want = Vec::new();
            for _ in 0..5000 {
                if rng.f64() < 0.6 || hq.heap.is_empty() {
                    let tt = if rng.f64() < 0.1 && n > 0 {
                        last_t // exact duplicate: exercises the FIFO tie-break
                    } else {
                        t += rng.exp(3.0);
                        t + rng.exp(0.5)
                    };
                    last_t = tt;
                    cq.push(tt, n);
                    hq.push(tt, n);
                    n += 1;
                } else {
                    got.push(cq.pop().unwrap());
                    want.push(hq.pop().unwrap());
                }
            }
            while let Some(w) = hq.pop() {
                got.push(cq.pop().unwrap());
                want.push(w);
            }
            assert!(cq.is_empty());
            assert_eq!(got, want, "seed {seed}: calendar order must equal heap order");
        }
    }

    #[test]
    fn sparse_gaps_use_the_direct_search_fallback() {
        let mut cq = CalendarQueue::new(1e-3, 8);
        // events separated by >> nb * width: every pop crosses a full year
        for i in 0..20u64 {
            cq.push(i as f64 * 1000.0, i);
        }
        for i in 0..20u64 {
            assert_eq!(cq.pop(), Some((i as f64 * 1000.0, i)));
        }
        assert!(cq.pop().is_none());
    }

    #[test]
    fn peek_matches_pop() {
        let mut cq = CalendarQueue::new(0.5, 8);
        cq.push(3.0, 'c');
        cq.push(1.0, 'a');
        cq.push(2.0, 'b');
        assert_eq!(cq.peek_time(), Some(1.0));
        assert_eq!(cq.pop(), Some((1.0, 'a')));
        assert_eq!(cq.peek_time(), Some(2.0));
        assert_eq!(cq.len(), 2);
    }
}
