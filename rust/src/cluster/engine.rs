//! Deterministic discrete-event cluster engine: request arrivals feed
//! per-replica continuous-batching schedulers (iteration-level, Orca-style
//! prefill/decode interleaving) whose step durations come from the §VIII-A
//! analytical serving model — the simulator's per-step cost oracle.
//!
//! Determinism: the event heap orders by (time, insertion sequence), every
//! scheduling decision breaks ties by index, and the only randomness lives
//! in the seeded trace — so one (config, trace) pair always produces one
//! event history.

use std::collections::{BinaryHeap, VecDeque};
use std::fmt::Write as _;

use super::workload::Request;
use crate::graph::llama::LlamaConfig;
use crate::serving::{self, ServingPoint, ServingSystem};
use crate::util::error::{Context as _, Result};
use crate::util::units::fmt_time;
use crate::{ensure, err};

/// One replica's static configuration: the model served with TP×PP over a
/// chip group, plus the scheduler's batching/KV policy.
#[derive(Debug, Clone)]
pub struct ReplicaConfig {
    pub model: LlamaConfig,
    pub sys: ServingSystem,
    pub tp: usize,
    pub pp: usize,
    /// Iteration-level cap on concurrently running sequences.
    pub max_batch: usize,
    /// Fraction of post-weights device memory usable by the KV cache.
    pub kv_headroom: f64,
}

impl ReplicaConfig {
    pub fn new(model: LlamaConfig, sys: ServingSystem, tp: usize, pp: usize) -> Self {
        ReplicaConfig { model, sys, tp, pp, max_batch: 32, kv_headroom: 0.9 }
    }

    /// KV-cache budget: group device memory minus resident weights, derated
    /// by the headroom factor. `None` when the weights alone do not fit.
    pub fn kv_budget_bytes(&self) -> Option<f64> {
        let free = self.sys.mem_total() - self.model.weight_bytes();
        (free > 0.0).then(|| free * self.kv_headroom)
    }

    fn point(&self, batch: f64, prompt_len: f64, context: f64) -> ServingPoint {
        ServingPoint { tp: self.tp, pp: self.pp, batch, prompt_len, context }
    }
}

/// Latency SLOs a request must meet to count toward goodput.
#[derive(Debug, Clone, Copy)]
pub struct Slo {
    /// Time-to-first-token bound, seconds.
    pub ttft: f64,
    /// Mean time-per-output-token bound, seconds.
    pub tpot: f64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    Arrival(usize),
    StepDone(usize),
}

/// Heap entry ordered earliest-first by (time, insertion sequence); the
/// sequence tie-break keeps equal-timestamp processing FIFO.
#[derive(Debug, Clone, Copy)]
struct Entry {
    t: f64,
    seq: u64,
    ev: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed so the max-heap pops the earliest entry first
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The step a replica currently has in flight.
#[derive(Debug, Clone)]
enum StepKind {
    /// Whole-prompt passes for newly admitted requests.
    Prefill(Vec<usize>),
    /// One decode iteration: one token for every running request.
    Decode(Vec<usize>),
}

#[derive(Debug, Default)]
struct Replica {
    queue: VecDeque<usize>,
    running: Vec<usize>,
    pending_prefill: Vec<usize>,
    kv_used: f64,
    /// Requests dispatched here and not yet finished (for load balancing).
    resident: usize,
    current: Option<StepKind>,
}

#[derive(Debug, Clone, Copy)]
struct ReqState {
    generated: usize,
    kv_reserved: f64,
    admitted: Option<f64>,
    first_token: Option<f64>,
    finished: Option<f64>,
    rejected: bool,
}

/// Per-request outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestMetrics {
    pub id: usize,
    /// Arrival → admission into a batch.
    pub queue_time: f64,
    /// Arrival → first token.
    pub ttft: f64,
    /// Mean time per output token after the first; 0 for 1-token outputs.
    pub tpot: f64,
    /// Arrival → last token.
    pub e2e: f64,
    pub output: usize,
}

/// Percentile summary of one latency metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pcts {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Summarize samples (sorts in place; all-zero summary when empty).
pub fn percentiles(samples: &mut [f64]) -> Pcts {
    if samples.is_empty() {
        return Pcts { mean: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let at = |p: f64| samples[(p * (samples.len() - 1) as f64).round() as usize];
    Pcts { mean, p50: at(0.50), p95: at(0.95), p99: at(0.99) }
}

/// Aggregate simulation outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub n_offered: usize,
    pub n_completed: usize,
    /// Requests whose KV need alone exceeds a replica's budget.
    pub n_rejected: usize,
    pub makespan: f64,
    pub queue: Pcts,
    pub ttft: Pcts,
    pub tpot: Pcts,
    pub throughput_rps: f64,
    /// SLO-meeting completions per second.
    pub goodput_rps: f64,
    /// Fraction of completed requests meeting both SLOs.
    pub slo_attainment: f64,
    pub output_tokens_per_s: f64,
    /// Peak KV residency as a fraction of the per-replica budget.
    pub kv_peak_frac: f64,
    pub events: u64,
    pub steps: u64,
    pub per_request: Vec<RequestMetrics>,
}

impl SimReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "requests : {} offered | {} completed | {} rejected | makespan {}",
            self.n_offered,
            self.n_completed,
            self.n_rejected,
            fmt_time(self.makespan)
        );
        let _ = writeln!(
            s,
            "rates    : {:.2} rps throughput | {:.2} rps goodput | {:.1}% in SLO | {:.0} tok/s out",
            self.throughput_rps,
            self.goodput_rps,
            self.slo_attainment * 100.0,
            self.output_tokens_per_s
        );
        let _ = writeln!(
            s,
            "engine   : {} events | {} steps | KV peak {:.1}%",
            self.events,
            self.steps,
            self.kv_peak_frac * 100.0
        );
        for (name, p) in [("queue", &self.queue), ("TTFT", &self.ttft), ("TPOT", &self.tpot)] {
            let _ = writeln!(
                s,
                "{name:<9}: mean {} | p50 {} | p95 {} | p99 {}",
                fmt_time(p.mean),
                fmt_time(p.p50),
                fmt_time(p.p95),
                fmt_time(p.p99)
            );
        }
        s
    }
}

struct Sim<'a> {
    cfg: &'a ReplicaConfig,
    requests: &'a [Request],
    budget: f64,
    kv_per_tok: f64,
    reps: Vec<Replica>,
    state: Vec<ReqState>,
    heap: BinaryHeap<Entry>,
    seq: u64,
    events: u64,
    steps: u64,
    kv_peak: f64,
    now: f64,
}

impl Sim<'_> {
    fn push(&mut self, t: f64, ev: Event) {
        self.heap.push(Entry { t, seq: self.seq, ev });
        self.seq += 1;
    }

    /// Admit queued requests (FCFS, bounded by the batch cap and the KV
    /// budget) and launch the next step on replica `ri` if it is idle.
    fn start_step(&mut self, ri: usize, t: f64) {
        if self.reps[ri].current.is_some() {
            return;
        }
        loop {
            let rep = &mut self.reps[ri];
            if rep.running.len() + rep.pending_prefill.len() >= self.cfg.max_batch {
                break;
            }
            let Some(&i) = rep.queue.front() else { break };
            let need = (self.requests[i].prompt + self.requests[i].output) as f64 * self.kv_per_tok;
            if rep.kv_used + need > self.budget {
                break;
            }
            rep.queue.pop_front();
            rep.kv_used += need;
            rep.pending_prefill.push(i);
            self.state[i].kv_reserved = need;
            self.state[i].admitted = Some(t);
        }
        self.kv_peak = self.kv_peak.max(self.reps[ri].kv_used);
        let (kind, dt) = if !self.reps[ri].pending_prefill.is_empty() {
            let members = std::mem::take(&mut self.reps[ri].pending_prefill);
            let batch = members.len() as f64;
            let prompt = members.iter().map(|&i| self.requests[i].prompt).max().unwrap() as f64;
            let pt = self.cfg.point(batch, prompt, prompt);
            let m = serving::evaluate(&self.cfg.model, &self.cfg.sys, &pt)
                .expect("split feasibility was checked before the run");
            (StepKind::Prefill(members), m.ttft)
        } else if !self.reps[ri].running.is_empty() {
            let members = self.reps[ri].running.clone();
            let batch = members.len() as f64;
            let context = members
                .iter()
                .map(|&i| (self.requests[i].prompt + self.state[i].generated) as f64)
                .sum::<f64>()
                / batch;
            let pt = self.cfg.point(batch, 1.0, context);
            let m = serving::evaluate(&self.cfg.model, &self.cfg.sys, &pt)
                .expect("split feasibility was checked before the run");
            (StepKind::Decode(members), m.tpot)
        } else {
            return; // replica idles until the next arrival
        };
        if crate::obs::enabled() {
            let occupancy = match &kind {
                StepKind::Prefill(m) | StepKind::Decode(m) => m.len(),
            };
            crate::obs::observe("cluster.batch_occupancy", occupancy as f64);
            crate::obs::observe("cluster.queue_depth", self.reps[ri].queue.len() as f64);
        }
        self.reps[ri].current = Some(kind);
        self.steps += 1;
        self.push(t + dt, Event::StepDone(ri));
    }

    fn finish_request(&mut self, ri: usize, i: usize, t: f64) {
        self.state[i].finished = Some(t);
        self.reps[ri].kv_used -= self.state[i].kv_reserved;
        self.reps[ri].resident -= 1;
    }

    fn step_done(&mut self, ri: usize, t: f64) {
        let kind = self.reps[ri].current.take().expect("completion without a step in flight");
        match kind {
            StepKind::Prefill(members) => {
                for i in members {
                    self.state[i].first_token = Some(t);
                    self.state[i].generated = 1;
                    if self.state[i].generated >= self.requests[i].output {
                        self.finish_request(ri, i, t);
                    } else {
                        self.reps[ri].running.push(i);
                    }
                }
            }
            StepKind::Decode(members) => {
                let mut still = Vec::with_capacity(members.len());
                for i in members {
                    self.state[i].generated += 1;
                    if self.state[i].generated >= self.requests[i].output {
                        self.finish_request(ri, i, t);
                    } else {
                        still.push(i);
                    }
                }
                self.reps[ri].running = still;
            }
        }
        self.start_step(ri, t);
    }
}

/// Simulate `replicas` identical replicas serving `requests` (arrivals join
/// the least-loaded replica, ties broken by index). Errors — with the
/// reason — when the configuration is infeasible: TP×PP does not cover the
/// chip group, or the model weights exceed the group's device memory.
pub fn simulate(
    cfg: &ReplicaConfig,
    replicas: usize,
    requests: &[Request],
    slo: &Slo,
) -> Result<SimReport> {
    let _span = crate::obs::span("cluster.simulate");
    ensure!(replicas > 0, "cluster simulation needs at least one replica");
    // probe the oracle once so infeasibility surfaces here, not mid-run
    serving::evaluate(&cfg.model, &cfg.sys, &cfg.point(1.0, 1.0, 1.0))
        .context("replica configuration")?;
    let budget = cfg.kv_budget_bytes().ok_or_else(|| {
        err!(
            "model weights ({:.1} GB) exceed the replica's device memory ({:.1} GB across {} \
             chips)",
            cfg.model.weight_bytes() / 1e9,
            cfg.sys.mem_total() / 1e9,
            cfg.sys.n_chips
        )
    })?;
    let mut sim = Sim {
        cfg,
        requests,
        budget,
        kv_per_tok: cfg.model.kv_bytes_per_token(),
        reps: (0..replicas).map(|_| Replica::default()).collect(),
        state: vec![
            ReqState {
                generated: 0,
                kv_reserved: 0.0,
                admitted: None,
                first_token: None,
                finished: None,
                rejected: false,
            };
            requests.len()
        ],
        heap: BinaryHeap::new(),
        seq: 0,
        events: 0,
        steps: 0,
        kv_peak: 0.0,
        now: 0.0,
    };
    for (i, r) in requests.iter().enumerate() {
        sim.push(r.arrival, Event::Arrival(i));
    }
    while let Some(Entry { t, ev, .. }) = sim.heap.pop() {
        sim.events += 1;
        sim.now = t;
        match ev {
            Event::Arrival(i) => {
                let need = (requests[i].prompt + requests[i].output) as f64 * sim.kv_per_tok;
                if need > sim.budget {
                    sim.state[i].rejected = true;
                    continue;
                }
                let ri = (0..replicas).min_by_key(|&r| (sim.reps[r].resident, r)).unwrap();
                sim.reps[ri].resident += 1;
                sim.reps[ri].queue.push_back(i);
                sim.start_step(ri, t);
            }
            Event::StepDone(ri) => sim.step_done(ri, t),
        }
    }

    let mut per = Vec::with_capacity(requests.len());
    let (mut q, mut tt, mut tp) = (Vec::new(), Vec::new(), Vec::new());
    let mut good = 0usize;
    let mut tokens = 0.0;
    let mut rejected = 0usize;
    for (i, r) in requests.iter().enumerate() {
        let s = &sim.state[i];
        if s.rejected {
            rejected += 1;
            continue;
        }
        let (Some(first), Some(done), Some(adm)) = (s.first_token, s.finished, s.admitted) else {
            continue;
        };
        let ttft = first - r.arrival;
        let tpot = if r.output > 1 { (done - first) / (r.output - 1) as f64 } else { 0.0 };
        q.push(adm - r.arrival);
        tt.push(ttft);
        if r.output > 1 {
            tp.push(tpot);
        }
        tokens += r.output as f64;
        if ttft <= slo.ttft && (r.output <= 1 || tpot <= slo.tpot) {
            good += 1;
        }
        per.push(RequestMetrics {
            id: r.id,
            queue_time: adm - r.arrival,
            ttft,
            tpot,
            e2e: done - r.arrival,
            output: r.output,
        });
    }
    let makespan = sim.now.max(1e-30);
    crate::obs::counter("cluster.events", sim.events);
    crate::obs::counter("cluster.steps", sim.steps);
    crate::obs::counter("cluster.admission_rejects", rejected as u64);
    crate::obs::gauge("cluster.kv_peak_frac", sim.kv_peak / budget);
    Ok(SimReport {
        n_offered: requests.len(),
        n_completed: per.len(),
        n_rejected: rejected,
        makespan,
        queue: percentiles(&mut q),
        ttft: percentiles(&mut tt),
        tpot: percentiles(&mut tp),
        throughput_rps: per.len() as f64 / makespan,
        goodput_rps: good as f64 / makespan,
        slo_attainment: if per.is_empty() { 0.0 } else { good as f64 / per.len() as f64 },
        output_tokens_per_s: tokens / makespan,
        kv_peak_frac: sim.kv_peak / budget,
        events: sim.events,
        steps: sim.steps,
        per_request: per,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::workload::TraceSpec;
    use crate::graph::llama::llama3_8b;
    use crate::serving::sn40l_x16;

    fn cfg() -> ReplicaConfig {
        ReplicaConfig::new(llama3_8b(), sn40l_x16(), 16, 1)
    }

    fn slo() -> Slo {
        Slo { ttft: 1.0, tpot: 0.02 }
    }

    #[test]
    fn all_requests_complete_and_metrics_are_sane() {
        let requests = TraceSpec::poisson(2, 4.0, 120).generate();
        let r = simulate(&cfg(), 1, &requests, &slo()).expect("feasible");
        assert_eq!(r.n_completed, 120);
        assert_eq!(r.n_rejected, 0);
        assert!(r.makespan > 0.0);
        assert!(r.ttft.p50 > 0.0 && r.ttft.p99 >= r.ttft.p50);
        assert!(r.tpot.p50 > 0.0 && r.tpot.p99 >= r.tpot.p50);
        assert!(r.kv_peak_frac > 0.0 && r.kv_peak_frac <= 1.0);
        assert!(r.events >= r.steps);
        for m in &r.per_request {
            assert!(m.queue_time >= 0.0 && m.ttft >= m.queue_time && m.e2e >= m.ttft);
        }
    }

    #[test]
    fn more_replicas_cut_latency_under_load() {
        let requests = TraceSpec::poisson(6, 30.0, 200).generate();
        let one = simulate(&cfg(), 1, &requests, &slo()).unwrap();
        let four = simulate(&cfg(), 4, &requests, &slo()).unwrap();
        assert!(four.ttft.p99 < one.ttft.p99, "{} vs {}", four.ttft.p99, one.ttft.p99);
        assert!(four.slo_attainment >= one.slo_attainment);
    }

    #[test]
    fn infeasible_configs_are_descriptive_errors() {
        let requests = TraceSpec::poisson(1, 1.0, 10).generate();
        // split does not cover the group
        let mut bad = cfg();
        bad.tp = 4;
        let e = simulate(&bad, 1, &requests, &slo()).unwrap_err();
        assert!(e.to_string().contains("TP4xPP1"), "{e}");
        // weights alone exceed device memory
        let mut tiny = cfg();
        tiny.sys.mem_cap = 1e6;
        let e = simulate(&tiny, 1, &requests, &slo()).unwrap_err();
        assert!(e.to_string().contains("device memory"), "{e}");
        // zero replicas
        let e = simulate(&cfg(), 0, &requests, &slo()).unwrap_err();
        assert!(e.to_string().contains("replica"), "{e}");
    }

    #[test]
    fn oversized_requests_are_rejected_not_stuck() {
        let mut requests = TraceSpec::poisson(4, 2.0, 20).generate();
        // a prompt so large its KV reservation alone exceeds the budget
        requests[5].prompt = 80_000_000;
        let r = simulate(&cfg(), 1, &requests, &slo()).unwrap();
        assert_eq!(r.n_rejected, 1);
        assert_eq!(r.n_completed, 19);
    }

    #[test]
    fn percentiles_of_known_samples() {
        let mut v: Vec<f64> = (1..=100).map(f64::from).collect();
        let p = percentiles(&mut v);
        assert_eq!(p.p50, 51.0);
        assert_eq!(p.p95, 95.0);
        assert_eq!(p.p99, 99.0);
        assert!((p.mean - 50.5).abs() < 1e-12);
        let z = percentiles(&mut []);
        assert_eq!(z.p99, 0.0);
    }
}
