//! Deterministic discrete-event cluster engine: request arrivals feed
//! per-replica continuous-batching schedulers (iteration-level, Orca-style
//! prefill/decode interleaving) whose step durations come from the §VIII-A
//! analytical serving model — the simulator's per-step cost oracle.
//!
//! Determinism: events process in ascending (time, insertion sequence)
//! order, every scheduling decision breaks ties by index, and the only
//! randomness lives in the seeded trace — so one (config, trace) pair
//! always produces one event history.
//!
//! Scale (PR 10): the hot loop is O(1) in request count. Arrivals stream
//! lazily from the trace source instead of being pre-queued, step
//! completions live in a [`super::calendar::CalendarQueue`] holding at most
//! one entry per replica, per-request state lives in a
//! [`crate::util::arena::Arena`] slab whose slots recycle as requests
//! finish, and latency summaries default to streaming P² estimators
//! ([`super::stream::StreamingPcts`]). The exact path — retained samples,
//! exact percentiles, per-request metrics — stays available via
//! [`SimOptions::exact_percentiles`] and is what the slice-based
//! [`simulate`] entry point uses.

use std::collections::VecDeque;
use std::fmt::Write as _;

use super::calendar::CalendarQueue;
use super::stream::StreamingPcts;
use super::workload::{Request, TraceSpec};
use crate::graph::llama::LlamaConfig;
use crate::serving::{self, ServingPoint, ServingSystem};
use crate::util::arena::Arena;
use crate::util::error::{Context as _, Result};
use crate::util::units::fmt_time;
use crate::{ensure, err};

/// One replica's static configuration: the model served with TP×PP over a
/// chip group, plus the scheduler's batching/KV policy.
#[derive(Debug, Clone)]
pub struct ReplicaConfig {
    /// Model served by every replica.
    pub model: LlamaConfig,
    /// The chip group (accelerator, device memory, fabric) of one replica.
    pub sys: ServingSystem,
    /// Tensor-parallel width.
    pub tp: usize,
    /// Pipeline-parallel depth.
    pub pp: usize,
    /// Iteration-level cap on concurrently running sequences.
    pub max_batch: usize,
    /// Fraction of post-weights device memory usable by the KV cache.
    pub kv_headroom: f64,
}

impl ReplicaConfig {
    /// A replica of `model` on `sys` split TP×PP, with the default batching
    /// policy (batch cap 32, KV headroom 0.9).
    pub fn new(model: LlamaConfig, sys: ServingSystem, tp: usize, pp: usize) -> Self {
        ReplicaConfig { model, sys, tp, pp, max_batch: 32, kv_headroom: 0.9 }
    }

    /// KV-cache budget: group device memory minus resident weights, derated
    /// by the headroom factor. `None` when the weights alone do not fit.
    pub fn kv_budget_bytes(&self) -> Option<f64> {
        let free = self.sys.mem_total() - self.model.weight_bytes();
        (free > 0.0).then(|| free * self.kv_headroom)
    }

    fn point(&self, batch: f64, prompt_len: f64, context: f64) -> ServingPoint {
        ServingPoint { tp: self.tp, pp: self.pp, batch, prompt_len, context }
    }
}

/// Latency SLOs a request must meet to count toward goodput.
#[derive(Debug, Clone, Copy)]
pub struct Slo {
    /// Time-to-first-token bound, seconds.
    pub ttft: f64,
    /// Mean time-per-output-token bound, seconds.
    pub tpot: f64,
}

/// Knobs for a simulation run.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimOptions {
    /// Retain every latency sample and compute exact percentiles (plus
    /// per-request metrics). Costs O(requests) memory; the default
    /// streaming path costs O(replicas + in-flight requests). Use for
    /// small runs, pinned tests, or distributions where P² error is
    /// documented to degrade (see [`super::stream`]).
    pub exact_percentiles: bool,
}

/// The step a replica currently has in flight.
#[derive(Debug, Clone, Copy)]
enum StepKind {
    /// Whole-prompt passes for the newly admitted batch (`stepping`).
    Prefill,
    /// One decode iteration: one token for every running request.
    Decode,
}

#[derive(Debug, Default)]
struct Replica {
    /// Dispatched but not yet admitted (arena handles, FCFS).
    queue: VecDeque<u32>,
    /// Admitted and decoding.
    running: Vec<u32>,
    /// Admitted, awaiting the next prefill launch.
    pending_prefill: Vec<u32>,
    /// Members of an in-flight prefill (swapped with `pending_prefill` at
    /// launch so neither Vec reallocates).
    stepping: Vec<u32>,
    kv_used: f64,
    /// Requests dispatched here and not yet finished (for load balancing).
    resident: usize,
    current: Option<StepKind>,
}

/// Per-request state while the request is in flight; lives in the arena
/// and is freed the moment the last token is produced.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    id: usize,
    arrival: f64,
    prompt: usize,
    output: usize,
    generated: usize,
    kv_reserved: f64,
    admitted: f64,
    first_token: f64,
}

/// Per-request outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestMetrics {
    /// Trace id of the request.
    pub id: usize,
    /// Arrival → admission into a batch.
    pub queue_time: f64,
    /// Arrival → first token.
    pub ttft: f64,
    /// Mean time per output token after the first; 0 for 1-token outputs.
    pub tpot: f64,
    /// Arrival → last token.
    pub e2e: f64,
    /// Output length, tokens.
    pub output: usize,
}

/// Percentile summary of one latency metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pcts {
    /// Arithmetic mean (exact on both summary paths).
    pub mean: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
}

/// Summarize samples exactly (all-zero summary when empty). Takes the
/// vector by value: it must sort, and taking ownership keeps that from
/// silently reordering a caller's buffer behind its back.
pub fn percentiles(mut samples: Vec<f64>) -> Pcts {
    if samples.is_empty() {
        return Pcts { mean: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let at = |p: f64| samples[(p * (samples.len() - 1) as f64).round() as usize];
    Pcts { mean, p50: at(0.50), p95: at(0.95), p99: at(0.99) }
}

/// Aggregate simulation outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Requests offered by the trace.
    pub n_offered: usize,
    /// Requests that produced their full output.
    pub n_completed: usize,
    /// Requests whose KV need alone exceeds a replica's budget.
    pub n_rejected: usize,
    /// Time of the last event, seconds.
    pub makespan: f64,
    /// Queue-delay summary (arrival → admission).
    pub queue: Pcts,
    /// Time-to-first-token summary.
    pub ttft: Pcts,
    /// Time-per-output-token summary (multi-token outputs only).
    pub tpot: Pcts,
    /// Completions per second.
    pub throughput_rps: f64,
    /// SLO-meeting completions per second.
    pub goodput_rps: f64,
    /// Fraction of completed requests meeting both SLOs.
    pub slo_attainment: f64,
    /// Generated tokens per second across the fleet.
    pub output_tokens_per_s: f64,
    /// Peak KV residency as a fraction of the per-replica budget.
    pub kv_peak_frac: f64,
    /// Events processed (arrivals + step completions).
    pub events: u64,
    /// Batched model steps launched (prefill + decode iterations).
    pub steps: u64,
    /// High-water mark of simultaneously in-flight requests — the engine's
    /// memory footprint in request-state units, independent of trace
    /// length.
    pub peak_in_flight: usize,
    /// Whether `queue`/`ttft`/`tpot` are exact or P² streaming estimates.
    pub exact_percentiles: bool,
    /// Per-request metrics, sorted by id. Empty on the streaming path —
    /// retaining them is exactly the O(requests) memory it avoids.
    pub per_request: Vec<RequestMetrics>,
}

impl SimReport {
    /// Multi-line human-readable summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "requests : {} offered | {} completed | {} rejected | makespan {}",
            self.n_offered,
            self.n_completed,
            self.n_rejected,
            fmt_time(self.makespan)
        );
        let _ = writeln!(
            s,
            "rates    : {:.2} rps throughput | {:.2} rps goodput | {:.1}% in SLO | {:.0} tok/s out",
            self.throughput_rps,
            self.goodput_rps,
            self.slo_attainment * 100.0,
            self.output_tokens_per_s
        );
        let _ = writeln!(
            s,
            "engine   : {} events | {} steps | KV peak {:.1}% | {} in-flight peak{}",
            self.events,
            self.steps,
            self.kv_peak_frac * 100.0,
            self.peak_in_flight,
            if self.exact_percentiles { "" } else { " | P2 percentiles" }
        );
        for (name, p) in [("queue", &self.queue), ("TTFT", &self.ttft), ("TPOT", &self.tpot)] {
            let _ = writeln!(
                s,
                "{name:<9}: mean {} | p50 {} | p95 {} | p99 {}",
                fmt_time(p.mean),
                fmt_time(p.p50),
                fmt_time(p.p95),
                fmt_time(p.p99)
            );
        }
        s
    }
}

/// Latency-sample accumulator: retained vectors (exact) or P² markers
/// (streaming, constant memory).
enum Sums {
    Exact { q: Vec<f64>, tt: Vec<f64>, tp: Vec<f64>, per: Vec<RequestMetrics> },
    Streaming { q: StreamingPcts, tt: StreamingPcts, tp: StreamingPcts },
}

struct Sim<'a> {
    cfg: &'a ReplicaConfig,
    slo: Slo,
    budget: f64,
    kv_per_tok: f64,
    reps: Vec<Replica>,
    pool: Arena<InFlight>,
    cq: CalendarQueue<usize>,
    sums: Sums,
    events: u64,
    steps: u64,
    kv_peak: f64,
    now: f64,
    offered: usize,
    rejected: usize,
    completed: usize,
    good: usize,
    tokens: f64,
}

impl Sim<'_> {
    /// Fold one finished request into the summaries and free nothing —
    /// the caller has already removed `s` from the arena.
    fn record(&mut self, s: &InFlight, t: f64) {
        let queue_time = s.admitted - s.arrival;
        let ttft = s.first_token - s.arrival;
        let tpot =
            if s.output > 1 { (t - s.first_token) / (s.output - 1) as f64 } else { 0.0 };
        self.completed += 1;
        self.tokens += s.output as f64;
        if ttft <= self.slo.ttft && (s.output <= 1 || tpot <= self.slo.tpot) {
            self.good += 1;
        }
        match &mut self.sums {
            Sums::Exact { q, tt, tp, per } => {
                q.push(queue_time);
                tt.push(ttft);
                if s.output > 1 {
                    tp.push(tpot);
                }
                per.push(RequestMetrics {
                    id: s.id,
                    queue_time,
                    ttft,
                    tpot,
                    e2e: t - s.arrival,
                    output: s.output,
                });
            }
            Sums::Streaming { q, tt, tp } => {
                q.observe(queue_time);
                tt.observe(ttft);
                if s.output > 1 {
                    tp.observe(tpot);
                }
            }
        }
    }

    /// Admit queued requests (FCFS, bounded by the batch cap and the KV
    /// budget) and launch the next step on replica `ri` if it is idle.
    fn start_step(&mut self, ri: usize, t: f64) {
        if self.reps[ri].current.is_some() {
            return;
        }
        loop {
            let rep = &self.reps[ri];
            if rep.running.len() + rep.pending_prefill.len() >= self.cfg.max_batch {
                break;
            }
            let Some(&h) = rep.queue.front() else { break };
            let need = {
                let s = &self.pool[h];
                (s.prompt + s.output) as f64 * self.kv_per_tok
            };
            if rep.kv_used + need > self.budget {
                break;
            }
            let rep = &mut self.reps[ri];
            rep.queue.pop_front();
            rep.kv_used += need;
            rep.pending_prefill.push(h);
            let s = self.pool.get_mut(h);
            s.kv_reserved = need;
            s.admitted = t;
        }
        self.kv_peak = self.kv_peak.max(self.reps[ri].kv_used);
        let rep = &self.reps[ri];
        let (kind, occupancy, dt) = if !rep.pending_prefill.is_empty() {
            let batch = rep.pending_prefill.len() as f64;
            let prompt =
                rep.pending_prefill.iter().map(|&h| self.pool[h].prompt).max().unwrap() as f64;
            let pt = self.cfg.point(batch, prompt, prompt);
            let m = serving::evaluate(&self.cfg.model, &self.cfg.sys, &pt)
                .expect("split feasibility was checked before the run");
            (StepKind::Prefill, rep.pending_prefill.len(), m.ttft)
        } else if !rep.running.is_empty() {
            let batch = rep.running.len() as f64;
            let context = rep
                .running
                .iter()
                .map(|&h| {
                    let s = &self.pool[h];
                    (s.prompt + s.generated) as f64
                })
                .sum::<f64>()
                / batch;
            let pt = self.cfg.point(batch, 1.0, context);
            let m = serving::evaluate(&self.cfg.model, &self.cfg.sys, &pt)
                .expect("split feasibility was checked before the run");
            (StepKind::Decode, rep.running.len(), m.tpot)
        } else {
            return; // replica idles until the next arrival
        };
        if crate::obs::enabled() {
            crate::obs::observe("cluster.batch_occupancy", occupancy as f64);
            crate::obs::observe("cluster.queue_depth", self.reps[ri].queue.len() as f64);
        }
        let rep = &mut self.reps[ri];
        if matches!(kind, StepKind::Prefill) {
            // hand the launch batch to `stepping`; the (empty, cleared)
            // previous buffer comes back so neither Vec reallocates
            std::mem::swap(&mut rep.pending_prefill, &mut rep.stepping);
        }
        rep.current = Some(kind);
        self.steps += 1;
        self.cq.push(t + dt, ri);
    }

    fn step_done(&mut self, ri: usize, t: f64) {
        let kind = self.reps[ri].current.take().expect("completion without a step in flight");
        let mut freed = 0.0;
        let mut done = 0usize;
        match kind {
            StepKind::Prefill => {
                let mut stepping = std::mem::take(&mut self.reps[ri].stepping);
                for &h in &stepping {
                    let s = self.pool.get_mut(h);
                    s.first_token = t;
                    s.generated = 1;
                    if s.generated >= s.output {
                        let s = self.pool.remove(h);
                        freed += s.kv_reserved;
                        done += 1;
                        self.record(&s, t);
                    } else {
                        self.reps[ri].running.push(h);
                    }
                }
                stepping.clear();
                self.reps[ri].stepping = stepping;
            }
            StepKind::Decode => {
                let mut running = std::mem::take(&mut self.reps[ri].running);
                let mut keep = 0usize;
                for idx in 0..running.len() {
                    let h = running[idx];
                    let s = self.pool.get_mut(h);
                    s.generated += 1;
                    if s.generated >= s.output {
                        let s = self.pool.remove(h);
                        freed += s.kv_reserved;
                        done += 1;
                        self.record(&s, t);
                    } else {
                        running[keep] = h; // in-place compaction, order kept
                        keep += 1;
                    }
                }
                running.truncate(keep);
                self.reps[ri].running = running;
            }
        }
        self.reps[ri].kv_used -= freed;
        self.reps[ri].resident -= done;
        self.start_step(ri, t);
    }
}

/// Core event loop over a lazily streamed arrival source. Arrivals are
/// merged against the calendar queue's earliest step completion (an
/// arrival at exactly a completion's timestamp goes first, replicating the
/// old heap's sequence ordering where every arrival predated every
/// completion entry), so the queue never holds more than one entry per
/// replica and memory stays independent of trace length.
fn run(
    cfg: &ReplicaConfig,
    replicas: usize,
    mut source: impl Iterator<Item = Request>,
    slo: &Slo,
    opts: &SimOptions,
) -> Result<SimReport> {
    let _span = crate::obs::span("cluster.simulate");
    ensure!(replicas > 0, "cluster simulation needs at least one replica");
    // probe the oracle once so infeasibility surfaces here, not mid-run;
    // the batch-1 decode step is also the calendar queue's day width —
    // the finest event grain the engine schedules at
    let probe = serving::evaluate(&cfg.model, &cfg.sys, &cfg.point(1.0, 1.0, 1.0))
        .context("replica configuration")?;
    let budget = cfg.kv_budget_bytes().ok_or_else(|| {
        err!(
            "model weights ({:.1} GB) exceed the replica's device memory ({:.1} GB across {} \
             chips)",
            cfg.model.weight_bytes() / 1e9,
            cfg.sys.mem_total() / 1e9,
            cfg.sys.n_chips
        )
    })?;
    let mut sim = Sim {
        cfg,
        slo: *slo,
        budget,
        kv_per_tok: cfg.model.kv_bytes_per_token(),
        reps: (0..replicas).map(|_| Replica::default()).collect(),
        pool: Arena::with_capacity(replicas * cfg.max_batch),
        cq: CalendarQueue::new(probe.tpot.max(1e-9), 2 * replicas),
        sums: if opts.exact_percentiles {
            Sums::Exact { q: Vec::new(), tt: Vec::new(), tp: Vec::new(), per: Vec::new() }
        } else {
            Sums::Streaming {
                q: StreamingPcts::new(),
                tt: StreamingPcts::new(),
                tp: StreamingPcts::new(),
            }
        },
        events: 0,
        steps: 0,
        kv_peak: 0.0,
        now: 0.0,
        offered: 0,
        rejected: 0,
        completed: 0,
        good: 0,
        tokens: 0.0,
    };
    let mut pending = source.next();
    loop {
        let qt = sim.cq.peek_time();
        let arrival_first = match (&pending, qt) {
            (Some(r), Some(q)) => r.arrival <= q,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break,
        };
        if arrival_first {
            let r = pending.take().expect("arrival_first implies a pending arrival");
            pending = source.next();
            sim.events += 1;
            sim.now = r.arrival;
            sim.offered += 1;
            let need = (r.prompt + r.output) as f64 * sim.kv_per_tok;
            if need > sim.budget {
                sim.rejected += 1;
                continue;
            }
            let h = sim.pool.insert(InFlight {
                id: r.id,
                arrival: r.arrival,
                prompt: r.prompt,
                output: r.output,
                generated: 0,
                kv_reserved: 0.0,
                admitted: 0.0,
                first_token: 0.0,
            });
            let ri = (0..replicas).min_by_key(|&x| (sim.reps[x].resident, x)).unwrap();
            sim.reps[ri].resident += 1;
            sim.reps[ri].queue.push_back(h);
            sim.start_step(ri, r.arrival);
        } else {
            let (t, ri) = sim.cq.pop().expect("peek_time returned Some");
            sim.events += 1;
            sim.now = t;
            sim.step_done(ri, t);
        }
    }

    let makespan = sim.now.max(1e-30);
    crate::obs::counter("cluster.events", sim.events);
    crate::obs::counter("cluster.steps", sim.steps);
    crate::obs::counter("cluster.admission_rejects", sim.rejected as u64);
    crate::obs::gauge("cluster.kv_peak_frac", sim.kv_peak / budget);
    let (queue, ttft, tpot, per) = match sim.sums {
        Sums::Exact { q, tt, tp, mut per } => {
            per.sort_by_key(|m| m.id);
            (percentiles(q), percentiles(tt), percentiles(tp), per)
        }
        Sums::Streaming { q, tt, tp } => (q.pcts(), tt.pcts(), tp.pcts(), Vec::new()),
    };
    Ok(SimReport {
        n_offered: sim.offered,
        n_completed: sim.completed,
        n_rejected: sim.rejected,
        makespan,
        queue,
        ttft,
        tpot,
        throughput_rps: sim.completed as f64 / makespan,
        goodput_rps: sim.good as f64 / makespan,
        slo_attainment: if sim.completed == 0 {
            0.0
        } else {
            sim.good as f64 / sim.completed as f64
        },
        output_tokens_per_s: sim.tokens / makespan,
        kv_peak_frac: sim.kv_peak / budget,
        events: sim.events,
        steps: sim.steps,
        peak_in_flight: sim.pool.peak(),
        exact_percentiles: opts.exact_percentiles,
        per_request: per,
    })
}

/// Simulate `replicas` identical replicas serving `requests` (arrivals join
/// the least-loaded replica, ties broken by index) on the **exact** summary
/// path: retained samples, exact percentiles, per-request metrics. Errors —
/// with the reason — when the configuration is infeasible: TP×PP does not
/// cover the chip group, or the model weights exceed the group's device
/// memory.
///
/// For traces past ~10⁵ requests, prefer [`simulate_stream`]: this entry
/// holds every latency sample in memory.
///
/// ```
/// use dfmodel::cluster::engine::{simulate, ReplicaConfig, Slo};
/// use dfmodel::cluster::workload::TraceSpec;
/// use dfmodel::graph::llama::llama3_8b;
/// use dfmodel::serving::sn40l_x16;
///
/// let cfg = ReplicaConfig::new(llama3_8b(), sn40l_x16(), 16, 1);
/// let trace = TraceSpec::poisson(7, 4.0, 50).generate();
/// let report = simulate(&cfg, 1, &trace, &Slo { ttft: 1.0, tpot: 0.02 }).unwrap();
/// assert_eq!(report.n_completed, 50);
/// assert!(report.ttft.p99 >= report.ttft.p50);
/// ```
pub fn simulate(
    cfg: &ReplicaConfig,
    replicas: usize,
    requests: &[Request],
    slo: &Slo,
) -> Result<SimReport> {
    // arrival-order view of the slice; the stable sort preserves slice
    // order on ties, replicating the old event heap's (time, insertion
    // sequence) contract for any input ordering
    let mut idx: Vec<usize> = (0..requests.len()).collect();
    idx.sort_by(|&a, &b| requests[a].arrival.total_cmp(&requests[b].arrival));
    run(
        cfg,
        replicas,
        idx.into_iter().map(|i| requests[i]),
        slo,
        &SimOptions { exact_percentiles: true },
    )
}

/// Simulate the trace described by `spec` without materializing it:
/// arrivals stream straight from the seeded generator, so memory stays
/// O(replicas + in-flight requests) no matter how many requests the spec
/// describes — this is the entry point for million-request runs and the
/// planner. Summaries follow `opts` (P² streaming by default).
pub fn simulate_stream(
    cfg: &ReplicaConfig,
    replicas: usize,
    spec: &TraceSpec,
    slo: &Slo,
    opts: &SimOptions,
) -> Result<SimReport> {
    run(cfg, replicas, spec.stream(), slo, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::workload::TraceSpec;
    use crate::graph::llama::llama3_8b;
    use crate::serving::sn40l_x16;

    fn cfg() -> ReplicaConfig {
        ReplicaConfig::new(llama3_8b(), sn40l_x16(), 16, 1)
    }

    fn slo() -> Slo {
        Slo { ttft: 1.0, tpot: 0.02 }
    }

    #[test]
    fn all_requests_complete_and_metrics_are_sane() {
        let requests = TraceSpec::poisson(2, 4.0, 120).generate();
        let r = simulate(&cfg(), 1, &requests, &slo()).expect("feasible");
        assert_eq!(r.n_completed, 120);
        assert_eq!(r.n_rejected, 0);
        assert!(r.makespan > 0.0);
        assert!(r.ttft.p50 > 0.0 && r.ttft.p99 >= r.ttft.p50);
        assert!(r.tpot.p50 > 0.0 && r.tpot.p99 >= r.tpot.p50);
        assert!(r.kv_peak_frac > 0.0 && r.kv_peak_frac <= 1.0);
        assert!(r.events >= r.steps);
        assert!(r.peak_in_flight > 0 && r.peak_in_flight <= 120);
        assert!(r.exact_percentiles);
        for m in &r.per_request {
            assert!(m.queue_time >= 0.0 && m.ttft >= m.queue_time && m.e2e >= m.ttft);
        }
    }

    #[test]
    fn more_replicas_cut_latency_under_load() {
        let requests = TraceSpec::poisson(6, 30.0, 200).generate();
        let one = simulate(&cfg(), 1, &requests, &slo()).unwrap();
        let four = simulate(&cfg(), 4, &requests, &slo()).unwrap();
        assert!(four.ttft.p99 < one.ttft.p99, "{} vs {}", four.ttft.p99, one.ttft.p99);
        assert!(four.slo_attainment >= one.slo_attainment);
    }

    #[test]
    fn infeasible_configs_are_descriptive_errors() {
        let requests = TraceSpec::poisson(1, 1.0, 10).generate();
        // split does not cover the group
        let mut bad = cfg();
        bad.tp = 4;
        let e = simulate(&bad, 1, &requests, &slo()).unwrap_err();
        assert!(e.to_string().contains("TP4xPP1"), "{e}");
        // weights alone exceed device memory
        let mut tiny = cfg();
        tiny.sys.mem_cap = 1e6;
        let e = simulate(&tiny, 1, &requests, &slo()).unwrap_err();
        assert!(e.to_string().contains("device memory"), "{e}");
        // zero replicas
        let e = simulate(&cfg(), 0, &requests, &slo()).unwrap_err();
        assert!(e.to_string().contains("replica"), "{e}");
    }

    #[test]
    fn oversized_requests_are_rejected_not_stuck() {
        let mut requests = TraceSpec::poisson(4, 2.0, 20).generate();
        // a prompt so large its KV reservation alone exceeds the budget
        requests[5].prompt = 80_000_000;
        let r = simulate(&cfg(), 1, &requests, &slo()).unwrap();
        assert_eq!(r.n_rejected, 1);
        assert_eq!(r.n_completed, 19);
    }

    #[test]
    fn percentiles_of_known_samples() {
        let v: Vec<f64> = (1..=100).map(f64::from).collect();
        let p = percentiles(v);
        assert_eq!(p.p50, 51.0);
        assert_eq!(p.p95, 95.0);
        assert_eq!(p.p99, 99.0);
        assert!((p.mean - 50.5).abs() < 1e-12);
        let z = percentiles(Vec::new());
        assert_eq!(z.p99, 0.0);
    }

    #[test]
    fn streaming_path_matches_exact_counts_and_stays_small() {
        let spec = TraceSpec::poisson(11, 6.0, 3000);
        let exact = simulate(&cfg(), 2, &spec.generate(), &slo()).unwrap();
        let stream =
            simulate_stream(&cfg(), 2, &spec, &slo(), &SimOptions::default()).unwrap();
        // counts, event history, and exact scalars are identical — only the
        // percentile estimator differs
        assert_eq!(stream.n_completed, exact.n_completed);
        assert_eq!(stream.n_offered, exact.n_offered);
        assert_eq!(stream.events, exact.events);
        assert_eq!(stream.steps, exact.steps);
        assert_eq!(stream.makespan, exact.makespan);
        assert_eq!(stream.slo_attainment, exact.slo_attainment);
        assert_eq!(stream.peak_in_flight, exact.peak_in_flight);
        assert_eq!(stream.ttft.mean, exact.ttft.mean, "means are exact on both paths");
        assert!(stream.per_request.is_empty() && !stream.exact_percentiles);
        assert!(
            stream.peak_in_flight < 200,
            "in-flight peak {} must track load, not trace length",
            stream.peak_in_flight
        );
        // P² estimates land near the exact percentiles on this smooth trace
        for (e, s) in [(exact.ttft, stream.ttft), (exact.tpot, stream.tpot)] {
            assert!((s.p50 - e.p50).abs() / e.p50 < 0.05, "{} vs {}", s.p50, e.p50);
            assert!((s.p95 - e.p95).abs() / e.p95 < 0.10, "{} vs {}", s.p95, e.p95);
        }
    }

    #[test]
    fn streaming_exact_option_reproduces_the_slice_path() {
        let spec = TraceSpec::poisson(8, 5.0, 500);
        let a = simulate(&cfg(), 2, &spec.generate(), &slo()).unwrap();
        let b = simulate_stream(
            &cfg(),
            2,
            &spec,
            &slo(),
            &SimOptions { exact_percentiles: true },
        )
        .unwrap();
        assert_eq!(a.per_request, b.per_request);
        assert_eq!(a.ttft, b.ttft);
        assert_eq!(a.queue, b.queue);
        assert_eq!(a.tpot, b.tpot);
    }
}
