//! Request-level cluster serving simulation (§VIII-A extended to open-loop
//! traffic): the analytical serving model predicts steady-state TTFT/TPOT
//! for one batch; this subsystem wraps it in a deterministic discrete-event
//! simulator so arrivals, queueing, continuous batching, and KV-cache
//! pressure are modeled too, and adds an SLO-aware capacity planner.
//!
//! * [`workload`] — seeded request generators: Poisson and bursty/diurnal
//!   arrivals, log-normal prompt/output-length distributions.
//! * [`engine`] — event-driven replica engine: iteration-level continuous
//!   batching with prefill/decode interleaving, KV-capacity admission
//!   control, per-request TTFT/TPOT/queue-time, percentiles and goodput.
//! * [`planner`] — sweeps (chip platform × TP×PP × replica count) and
//!   returns the cheapest fleet meeting a target QPS + SLO.

pub mod engine;
pub mod planner;
pub mod workload;

pub use engine::{percentiles, simulate, Pcts, ReplicaConfig, RequestMetrics, SimReport, Slo};
pub use planner::{plan, FleetPlan, PlanResult, PlanTarget, PlanTraffic, Platform};
pub use workload::{Arrivals, LengthDist, Request, TraceSpec};
