//! Request-level cluster serving simulation (§VIII-A extended to open-loop
//! traffic): the analytical serving model predicts steady-state TTFT/TPOT
//! for one batch; this subsystem wraps it in a deterministic discrete-event
//! simulator so arrivals, queueing, continuous batching, and KV-cache
//! pressure are modeled too, and adds an SLO-aware capacity planner.
//!
//! Built for scale (DESIGN.md §Cluster at scale): the engine streams
//! arrivals from the seeded generator, schedules step completions through a
//! calendar queue, keeps request state in a recycling arena, and summarizes
//! latency with streaming P² estimators — so one process simulates a
//! million requests across a whole fleet in memory independent of trace
//! length.
//!
//! * [`workload`] — seeded request generators: Poisson and bursty/diurnal
//!   arrivals, log-normal prompt/output-length distributions, streamed or
//!   materialized.
//! * [`calendar`] — bucketed earliest-first event scheduler with the exact
//!   ordering contract of the binary heap it replaced.
//! * [`engine`] — event-driven replica engine: iteration-level continuous
//!   batching with prefill/decode interleaving, KV-capacity admission
//!   control, per-request TTFT/TPOT/queue-time, percentiles and goodput.
//! * [`stream`] — P² streaming quantile estimators backing the engine's
//!   constant-memory summary path.
//! * [`planner`] — sweeps (chip platform × TP×PP × replica count) and
//!   returns the cheapest fleet meeting a target QPS + SLO, judging every
//!   candidate by simulated (not analytical) SLO attainment.

#![forbid(unsafe_code)]
#![deny(missing_docs)]

pub mod calendar;
pub mod engine;
pub mod planner;
pub mod stream;
pub mod workload;

pub use calendar::CalendarQueue;
pub use engine::{
    percentiles, simulate, simulate_stream, Pcts, ReplicaConfig, RequestMetrics, SimOptions,
    SimReport, Slo,
};
pub use planner::{plan, FleetPlan, PlanResult, PlanTarget, PlanTraffic, Platform};
pub use stream::{P2Quantile, StreamingPcts};
pub use workload::{Arrivals, LengthDist, Request, TraceIter, TraceSpec};
