//! Synthetic request traffic for the cluster simulator: seeded arrival
//! processes (Poisson and bursty/diurnal via Lewis thinning) and sampled
//! prompt/output-length distributions, all driven by `util::prng` so every
//! trace regenerates bit-identically from its seed.

use crate::util::prng::Rng;

/// One inference request in a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Position in the trace (0-based); stable across regeneration.
    pub id: usize,
    /// Arrival time, seconds from trace start.
    pub arrival: f64,
    /// Prompt length, tokens.
    pub prompt: usize,
    /// Output length, tokens (the first token is produced by prefill).
    pub output: usize,
}

/// Arrival-process shape.
#[derive(Debug, Clone, Copy)]
pub enum Arrivals {
    /// Memoryless arrivals at a constant rate (requests/s).
    Poisson { rate: f64 },
    /// Rate modulated sinusoidally between `base` and `peak` over `period`
    /// seconds — a compressed diurnal cycle with bursty crests.
    Bursty { base: f64, peak: f64, period: f64 },
}

impl Arrivals {
    /// Instantaneous rate at time `t` (requests/s).
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            Arrivals::Poisson { rate } => rate,
            Arrivals::Bursty { base, peak, period } => {
                base + (peak - base) * 0.5 * (1.0 + (2.0 * std::f64::consts::PI * t / period).sin())
            }
        }
    }

    /// Mean rate over a full cycle.
    pub fn mean_rate(&self) -> f64 {
        match *self {
            Arrivals::Poisson { rate } => rate,
            Arrivals::Bursty { base, peak, .. } => 0.5 * (base + peak),
        }
    }

    fn peak_rate(&self) -> f64 {
        match *self {
            Arrivals::Poisson { rate } => rate,
            Arrivals::Bursty { peak, .. } => peak,
        }
    }

    /// Next arrival strictly after `t`: inversion for Poisson, Lewis
    /// thinning against the peak rate for the modulated process.
    pub fn next_after(&self, mut t: f64, rng: &mut Rng) -> f64 {
        let lmax = self.peak_rate();
        assert!(lmax > 0.0, "arrival rate must be positive");
        loop {
            t += rng.exp(lmax);
            if rng.f64() * lmax <= self.rate_at(t) {
                return t;
            }
        }
    }
}

/// Token-length distribution: log-normal around `mean` (σ in log space),
/// rounded and clamped to `[min, max]`.
#[derive(Debug, Clone, Copy)]
pub struct LengthDist {
    /// Mean length, tokens (the log-normal is parameterized to hit this).
    pub mean: f64,
    /// Log-space standard deviation; 0 degenerates to `mean` exactly.
    pub sigma: f64,
    /// Lower clamp, tokens (raised to 1 if given as 0).
    pub min: usize,
    /// Upper clamp, tokens.
    pub max: usize,
}

impl LengthDist {
    /// Degenerate distribution: every sample is exactly `n` tokens.
    pub fn fixed(n: usize) -> Self {
        LengthDist { mean: n as f64, sigma: 0.0, min: n, max: n }
    }

    /// Draw one length.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let v = rng.lognormal_mean(self.mean, self.sigma);
        (v.round() as usize).clamp(self.min.max(1), self.max)
    }
}

/// A reproducible synthetic workload: everything needed to regenerate the
/// same request trace from the seed.
#[derive(Debug, Clone, Copy)]
pub struct TraceSpec {
    /// PRNG seed; the whole trace is a pure function of this spec.
    pub seed: u64,
    /// Trace length, requests.
    pub n_requests: usize,
    /// Arrival process.
    pub arrivals: Arrivals,
    /// Prompt-length distribution.
    pub prompt: LengthDist,
    /// Output-length distribution.
    pub output: LengthDist,
}

impl TraceSpec {
    /// Chatbot-flavored default: ~1k-token prompts, ~128-token outputs,
    /// Poisson arrivals at `rate` requests/s.
    pub fn poisson(seed: u64, rate: f64, n_requests: usize) -> Self {
        TraceSpec {
            seed,
            n_requests,
            arrivals: Arrivals::Poisson { rate },
            prompt: LengthDist { mean: 1024.0, sigma: 0.4, min: 16, max: 8192 },
            output: LengthDist { mean: 128.0, sigma: 0.6, min: 2, max: 2048 },
        }
    }

    /// Generate the trace: `n_requests` requests in arrival order.
    pub fn generate(&self) -> Vec<Request> {
        self.stream().collect()
    }

    /// Stream the trace one request at a time without materializing it —
    /// the same requests as [`TraceSpec::generate`], bit for bit, in
    /// constant memory. This is what lets the engine's streaming path
    /// simulate 10⁶-request traces without ever holding them.
    pub fn stream(&self) -> TraceIter {
        TraceIter { spec: *self, rng: Rng::new(self.seed), t: 0.0, next_id: 0 }
    }
}

/// Iterator over a [`TraceSpec`]'s requests (see [`TraceSpec::stream`]).
#[derive(Debug, Clone)]
pub struct TraceIter {
    spec: TraceSpec,
    rng: Rng,
    t: f64,
    next_id: usize,
}

impl Iterator for TraceIter {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.next_id >= self.spec.n_requests {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.t = self.spec.arrivals.next_after(self.t, &mut self.rng);
        Some(Request {
            id,
            arrival: self.t,
            prompt: self.spec.prompt.sample(&mut self.rng),
            output: self.spec.output.sample(&mut self.rng),
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.spec.n_requests - self.next_id;
        (left, Some(left))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_seeded_and_ordered() {
        let spec = TraceSpec::poisson(9, 4.0, 400);
        let a = spec.generate();
        assert_eq!(a, spec.generate(), "same seed must regenerate the trace");
        assert_ne!(a, TraceSpec::poisson(10, 4.0, 400).generate());
        for w in a.windows(2) {
            assert!(w[1].arrival > w[0].arrival, "arrivals must be increasing");
        }
    }

    #[test]
    fn lengths_respect_bounds() {
        let spec = TraceSpec::poisson(3, 4.0, 2000);
        for r in spec.generate() {
            assert!((16..=8192).contains(&r.prompt));
            assert!((2..=2048).contains(&r.output));
        }
        let mut rng = Rng::new(1);
        assert_eq!(LengthDist::fixed(777).sample(&mut rng), 777);
    }

    #[test]
    fn bursty_rate_oscillates_between_base_and_peak() {
        let a = Arrivals::Bursty { base: 2.0, peak: 10.0, period: 60.0 };
        for i in 0..600 {
            let r = a.rate_at(f64::from(i) * 0.37);
            assert!((2.0 - 1e-9..=10.0 + 1e-9).contains(&r));
        }
        assert!((a.mean_rate() - 6.0).abs() < 1e-12);
        assert!((a.rate_at(15.0) - 10.0).abs() < 1e-9, "crest at period/4");
    }

    #[test]
    fn stream_equals_generate() {
        let spec = TraceSpec::poisson(21, 5.0, 500);
        let streamed: Vec<Request> = spec.stream().collect();
        assert_eq!(streamed, spec.generate(), "stream() must replay generate() bit for bit");
        assert_eq!(spec.stream().size_hint(), (500, Some(500)));
        let mut it = spec.stream();
        it.next();
        assert_eq!(it.size_hint(), (499, Some(499)));
    }

    #[test]
    fn bursty_thinning_hits_the_mean_rate() {
        let spec = TraceSpec {
            seed: 5,
            n_requests: 3000,
            arrivals: Arrivals::Bursty { base: 2.0, peak: 10.0, period: 30.0 },
            prompt: LengthDist::fixed(128),
            output: LengthDist::fixed(16),
        };
        let trace = spec.generate();
        let rate = trace.len() as f64 / trace.last().unwrap().arrival;
        assert!((rate / 6.0 - 1.0).abs() < 0.15, "empirical rate {rate:.2} vs mean 6.0");
    }
}
