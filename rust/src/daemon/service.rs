//! Transport-independent daemon core: the bounded worker pool, the shared
//! LRU result cache keyed by canonical Scenario JSON, lint pre-flight, and
//! the metrics registry behind `GET /v1/metrics`. `tests/daemon.rs` also
//! drives a [`Service`] in-process (no socket) to pin cache and tracing
//! behavior deterministically.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::Scenario;
use crate::lint;
use crate::obs;
use crate::obs::metrics::{Hist, Metric};
use crate::util::json::Json;
use crate::util::lru::Lru;
use crate::util::threadpool::{SubmitError, ThreadPool};

/// Pool/cache sizing (the `dfmodel daemon` flags minus the listen address).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads evaluating scenarios.
    pub workers: usize,
    /// LRU result-cache entries; 0 disables caching (the uncached bench).
    pub cache_entries: usize,
    /// Accepted-but-not-started request bound; overflow → 429.
    pub queue_cap: usize,
    /// Per-request evaluation budget; overrun → 503 (the job itself keeps
    /// running to completion on its worker).
    pub timeout: Duration,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            workers: crate::util::threadpool::default_workers(),
            cache_entries: 256,
            queue_cap: 64,
            timeout: Duration::from_secs(300),
        }
    }
}

/// One endpoint outcome: an HTTP status plus a JSON body.
#[derive(Debug, Clone, PartialEq)]
pub struct Reply {
    pub status: u16,
    pub body: String,
}

fn error_body(status: u16, msg: &str) -> Reply {
    Reply {
        status,
        body: Json::obj(vec![("error", Json::from(msg))]).pretty(),
    }
}

/// Scenario JSON → pretty Report JSON (or a client-addressable error).
/// Injectable so the backpressure/timeout/drain tests can substitute a
/// gated evaluator with deterministic timing.
pub type Evaluator = dyn Fn(&Json) -> Result<String, String> + Send + Sync;

/// The production evaluator: exactly the CLI path
/// (`Scenario::from_json` → `Scenario::evaluate` → pretty report JSON), so
/// HTTP output is byte-identical to `dfmodel <goal> --scenario ... --json`.
fn evaluate_scenario(j: &Json) -> Result<String, String> {
    let s = Scenario::from_json(j).map_err(|e| e.to_string())?;
    let report = s.evaluate().map_err(|e| e.to_string())?;
    Ok(report.to_json().pretty())
}

/// Thread-safe metrics for the daemon itself. The per-request `obs` spans
/// flow through the thread-local capture (when one is armed); this registry
/// is process-wide and always on, since `/v1/metrics` must answer without
/// any capture session. Rendering mirrors `obs::Capture::metrics_text` /
/// `metrics_json` so both surfaces read the same.
pub struct Registry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    fn new() -> Registry {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
        self.inner.lock().expect("metrics registry poisoned")
    }

    /// Bump a counter by `delta` (created at 0 on first use). As in
    /// `obs::Capture`, the first event under a name decides its kind;
    /// mismatched later events are ignored.
    pub fn add(&self, name: &str, delta: u64) {
        let mut m = self.lock();
        if let Metric::Counter(c) = m.entry(name.to_string()).or_insert(Metric::Counter(0)) {
            *c += delta;
        }
    }

    /// Record one histogram sample.
    pub fn observe(&self, name: &str, v: f64) {
        let mut m = self.lock();
        if let Metric::Histogram(h) =
            m.entry(name.to_string()).or_insert_with(|| Metric::Histogram(Hist::new()))
        {
            h.add(v);
        }
    }

    /// Current counter value (0 when absent) — test/assertion helper.
    pub fn counter_value(&self, name: &str) -> u64 {
        match self.lock().get(name) {
            Some(Metric::Counter(c)) => *c,
            _ => 0,
        }
    }

    /// Text rendering, same shape as `obs::Capture::metrics_text`.
    pub fn to_text(&self) -> String {
        use std::fmt::Write as _;
        let m = self.lock();
        let mut s = String::new();
        let _ = writeln!(s, "stats    : {} metric(s)", m.len());
        for (name, metric) in m.iter() {
            let _ = match metric {
                Metric::Counter(c) => writeln!(s, "  {name} = {c}"),
                Metric::Gauge(v) => writeln!(s, "  {name} = {v:.6}"),
                Metric::Histogram(h) => writeln!(
                    s,
                    "  {name}: n={} mean={:.4e} min={:.4e} max={:.4e}",
                    h.count,
                    h.mean(),
                    h.min,
                    h.max
                ),
            };
        }
        s
    }

    /// JSON rendering, same shape as `obs::Capture::metrics_json`
    /// (`{"kind": "counter", "value": N}` etc. per metric name).
    pub fn to_json(&self) -> Json {
        let m = self.lock();
        Json::Obj(
            m.iter()
                .map(|(name, metric)| {
                    let v = match metric {
                        Metric::Counter(c) => Json::obj(vec![
                            ("kind", Json::from("counter")),
                            ("value", Json::from(*c as f64)),
                        ]),
                        Metric::Gauge(g) => Json::obj(vec![
                            ("kind", Json::from("gauge")),
                            ("value", Json::from(*g)),
                        ]),
                        Metric::Histogram(h) => Json::obj(vec![
                            ("kind", Json::from("histogram")),
                            ("count", Json::from(h.count as f64)),
                            ("sum", Json::from(h.sum)),
                            ("min", Json::from(h.min)),
                            ("max", Json::from(h.max)),
                            (
                                "buckets",
                                Json::arr(h.buckets.iter().map(|&(ub, c)| {
                                    Json::arr([Json::from(ub), Json::from(c as f64)])
                                })),
                            ),
                        ]),
                    };
                    (name.clone(), v)
                })
                .collect(),
        )
    }
}

/// The daemon core: listener-independent request handling.
pub struct Service {
    pool: ThreadPool,
    /// Canonical Scenario JSON (`Json::sorted()`, compact) → pretty Report
    /// JSON. `None` when caching is disabled.
    cache: Option<Mutex<Lru<String, String>>>,
    eval: Arc<Evaluator>,
    metrics: Registry,
    timeout: Duration,
}

impl Service {
    /// Production service: the real `Scenario::evaluate` path.
    pub fn new(cfg: &ServiceConfig) -> Service {
        Service::with_evaluator(cfg, Arc::new(evaluate_scenario))
    }

    /// Test seam: same queue/cache/timeout machinery around any evaluator.
    pub fn with_evaluator(cfg: &ServiceConfig, eval: Arc<Evaluator>) -> Service {
        Service {
            pool: ThreadPool::new(cfg.workers, cfg.queue_cap),
            cache: (cfg.cache_entries > 0)
                .then(|| Mutex::new(Lru::new(cfg.cache_entries))),
            eval,
            metrics: Registry::new(),
            timeout: cfg.timeout,
        }
    }

    /// Daemon-side metrics (also the `/v1/metrics` payload source).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// `GET /v1/health` body.
    pub fn health(&self) -> Reply {
        Reply {
            status: 200,
            body: Json::obj(vec![
                ("status", Json::from("ok")),
                ("service", Json::from("dfmodeld")),
                ("version", Json::from(env!("CARGO_PKG_VERSION"))),
            ])
            .pretty(),
        }
    }

    /// `GET /v1/metrics` body (text by default, JSON on `format=json`).
    pub fn metrics_reply(&self, json: bool) -> Reply {
        if json {
            Reply { status: 200, body: self.metrics.to_json().pretty() }
        } else {
            Reply { status: 200, body: self.metrics.to_text() }
        }
    }

    /// `POST /v1/evaluate`: Scenario JSON in, Report JSON out.
    ///
    /// Flow: parse → canonical-key cache probe → lint pre-flight (errors →
    /// 422 with the DF-XNNN diagnostics) → bounded submit (full → 429) →
    /// wait with timeout (→ 503) → cache fill. The evaluation itself runs
    /// under `obs::record_task` and is spliced back onto *this* thread's
    /// capture (when armed), so recorded traces are independent of which
    /// worker ran the job.
    pub fn evaluate(&self, body: &[u8]) -> Reply {
        self.metrics.add("daemon.evaluate.requests", 1);
        let text = match std::str::from_utf8(body) {
            Ok(t) => t,
            Err(_) => {
                self.metrics.add("daemon.evaluate.errors", 1);
                return error_body(400, "request body is not UTF-8");
            }
        };
        let j = match Json::parse(text) {
            Ok(j) => j,
            Err(e) => {
                self.metrics.add("daemon.evaluate.errors", 1);
                return error_body(400, &e.to_string());
            }
        };
        // key on the canonicalized document: key order and formatting
        // differences between clients still hit the same entry
        let canonical = j.sorted().to_string();
        if let Some(cache) = &self.cache {
            let mut cache = cache.lock().expect("result cache poisoned");
            if let Some(hit) = cache.get(&canonical) {
                self.metrics.add("daemon.cache.hits", 1);
                return Reply { status: 200, body: hit.clone() };
            }
            self.metrics.add("daemon.cache.misses", 1);
        }
        // lint pre-flight on the connection thread: malformed scenarios
        // never occupy a worker (`"lint": false` opts out, as in the CLI)
        if j.get("lint").and_then(Json::as_bool) != Some(false) {
            let report = lint::lint_json(&j);
            if report.has_errors() {
                self.metrics.add("daemon.evaluate.lint_rejected", 1);
                return Reply {
                    status: 422,
                    body: Json::obj(vec![
                        ("error", Json::from("scenario fails lint")),
                        ("lint", report.to_json()),
                    ])
                    .pretty(),
                };
            }
        }
        self.metrics.observe("daemon.queue.depth", self.pool.queue_depth() as f64);
        let eval = Arc::clone(&self.eval);
        let tracing = obs::enabled();
        let started = Instant::now();
        let submitted = self.pool.try_submit(move || {
            if tracing {
                let (r, log) = obs::record_task(|| eval(&j));
                (r, Some(log))
            } else {
                (eval(&j), None)
            }
        });
        let handle = match submitted {
            Ok(h) => h,
            Err(SubmitError::Full) => {
                self.metrics.add("daemon.rejected.queue_full", 1);
                return error_body(429, "request queue full, retry later");
            }
            Err(SubmitError::Closed) => {
                return error_body(503, "service shutting down");
            }
        };
        self.metrics.add("daemon.evaluate.submitted", 1);
        let (out, log) = match handle.wait_timeout(self.timeout) {
            None => {
                self.metrics.add("daemon.rejected.timeout", 1);
                return error_body(503, "evaluation timed out");
            }
            Some(Err(e)) => {
                // worker panic — surfaced, never a lost request
                self.metrics.add("daemon.evaluate.errors", 1);
                return error_body(500, &e.to_string());
            }
            Some(Ok(pair)) => pair,
        };
        obs::splice_tasks(log); // no-op unless this thread has a capture armed
        self.metrics.observe("daemon.evaluate.latency_seconds", started.elapsed().as_secs_f64());
        match out {
            Ok(report) => {
                if let Some(cache) = &self.cache {
                    cache.lock().expect("result cache poisoned").insert(canonical, report.clone());
                }
                self.metrics.add("daemon.evaluate.ok", 1);
                Reply { status: 200, body: report }
            }
            Err(msg) => {
                self.metrics.add("daemon.evaluate.errors", 1);
                error_body(422, &msg)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ServiceConfig {
        ServiceConfig { workers: 2, cache_entries: 8, queue_cap: 8, ..ServiceConfig::default() }
    }

    /// Evaluator that echoes the canonicalized input (cheap, deterministic).
    fn echo() -> Arc<Evaluator> {
        Arc::new(|j: &Json| Ok(j.sorted().to_string()))
    }

    #[test]
    fn registry_renders_like_capture_metrics() {
        let r = Registry::new();
        r.add("daemon.cache.hits", 2);
        r.observe("daemon.queue.depth", 3.0);
        let text = r.to_text();
        assert!(text.starts_with("stats    : 2 metric(s)\n"), "got: {text}");
        assert!(text.contains("  daemon.cache.hits = 2\n"));
        assert!(text.contains("daemon.queue.depth: n=1"));
        let j = r.to_json();
        assert_eq!(
            j.get("daemon.cache.hits").and_then(|m| m.get("value")).and_then(Json::as_f64),
            Some(2.0)
        );
        assert_eq!(
            j.get("daemon.queue.depth").and_then(|m| m.get("kind")).and_then(Json::as_str),
            Some("histogram")
        );
        assert_eq!(r.counter_value("daemon.cache.hits"), 2);
        assert_eq!(r.counter_value("absent"), 0);
    }

    #[test]
    fn malformed_bodies_are_400() {
        let svc = Service::with_evaluator(&tiny_cfg(), echo());
        assert_eq!(svc.evaluate(&[0xff, 0xfe]).status, 400);
        assert_eq!(svc.evaluate(b"{ not json").status, 400);
        assert_eq!(svc.metrics().counter_value("daemon.evaluate.errors"), 2);
    }

    #[test]
    fn cache_hits_on_canonically_equal_bodies() {
        let svc = Service::with_evaluator(&tiny_cfg(), echo());
        // lint:false so the echo evaluator sees arbitrary JSON
        let a = br#"{"lint": false, "b": 1, "a": 2}"#;
        let b = br#"{"a": 2,
                     "lint": false, "b": 1}"#; // same document, other order
        let first = svc.evaluate(a);
        assert_eq!(first.status, 200);
        assert_eq!(svc.metrics().counter_value("daemon.cache.misses"), 1);
        let second = svc.evaluate(b);
        assert_eq!(second, first, "cache hit must return the identical body");
        assert_eq!(svc.metrics().counter_value("daemon.cache.hits"), 1);
        assert_eq!(
            svc.metrics().counter_value("daemon.evaluate.ok"),
            1,
            "second request must not re-evaluate"
        );
    }

    #[test]
    fn evaluator_error_is_422_and_panic_is_500() {
        let failing: Arc<Evaluator> = Arc::new(|_| Err("no such goal".into()));
        let svc = Service::with_evaluator(&tiny_cfg(), failing);
        let r = svc.evaluate(br#"{"lint": false}"#);
        assert_eq!(r.status, 422);
        assert!(r.body.contains("no such goal"));

        let panicking: Arc<Evaluator> = Arc::new(|_| panic!("worker bug"));
        let svc = Service::with_evaluator(&tiny_cfg(), panicking);
        let r = svc.evaluate(br#"{"lint": false}"#);
        assert_eq!(r.status, 500);
        assert!(r.body.contains("worker panicked"), "got: {}", r.body);
        // the pool survives a panicking job
        assert_eq!(svc.metrics().counter_value("daemon.evaluate.errors"), 1);
    }

    #[test]
    fn cache_disabled_when_zero_entries() {
        let cfg = ServiceConfig { cache_entries: 0, ..tiny_cfg() };
        let svc = Service::with_evaluator(&cfg, echo());
        let body = br#"{"lint": false, "x": 1}"#;
        assert_eq!(svc.evaluate(body).status, 200);
        assert_eq!(svc.evaluate(body).status, 200);
        assert_eq!(svc.metrics().counter_value("daemon.cache.hits"), 0);
        assert_eq!(svc.metrics().counter_value("daemon.cache.misses"), 0);
        assert_eq!(svc.metrics().counter_value("daemon.evaluate.ok"), 2);
    }
}
