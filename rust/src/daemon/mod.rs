//! `dfmodeld`: the persistent optimization service behind `dfmodel daemon`
//! (the ROADMAP "production-scale deployment" serving layer).
//!
//! Architecture (DESIGN.md §Daemon): a nonblocking `TcpListener` accept
//! loop hands each connection to a short-lived connection thread, which
//! parses the request ([`http`]) and calls into the shared [`Service`] —
//! lint pre-flight, the canonical-JSON LRU result cache, and a bounded
//! queue feeding the `util::threadpool` workers that run
//! `Scenario::evaluate`. Endpoints:
//!
//! | route                | outcome                                        |
//! |----------------------|------------------------------------------------|
//! | `POST /v1/evaluate`  | Scenario JSON → Report JSON (the CLI's bytes)  |
//! | `GET /v1/health`     | liveness probe                                 |
//! | `GET /v1/metrics`    | daemon counters/histograms (text; `?format=json`) |
//! | `POST /v1/shutdown`  | graceful stop (what CI uses; SIGINT is equivalent) |
//!
//! Error taxonomy: 400 malformed HTTP/JSON, 404/405 bad route, 413 body
//! over `--max-body`, 422 lint or evaluation rejection, 429 queue full
//! (backpressure), 500 worker panic, 503 per-request timeout or shutdown.
//! Graceful shutdown (SIGINT/SIGTERM/`/v1/shutdown`) stops accepting,
//! drains connection threads and queued work, then joins the pool.

pub mod http;
pub mod service;
pub mod signal;

pub use service::{Reply, Service, ServiceConfig};

use std::io::{self, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};

use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Poll period of the nonblocking accept loop (also the shutdown-notice
/// latency ceiling).
const ACCEPT_POLL: Duration = Duration::from_millis(20);

/// Per-connection socket read budget: a client that stalls mid-request
/// cannot pin a connection thread past it.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Everything `dfmodel daemon` exposes as flags.
#[derive(Debug, Clone)]
pub struct Config {
    pub addr: SocketAddr,
    pub service: ServiceConfig,
    /// Largest accepted request body; beyond it → 413.
    pub max_body: usize,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            addr: SocketAddr::from(([127, 0, 0, 1], 8080)),
            service: ServiceConfig::default(),
            max_body: 8 * 1024 * 1024,
        }
    }
}

/// A bound (not yet serving) daemon.
pub struct Server {
    listener: TcpListener,
    service: Arc<Service>,
    stop: Arc<AtomicBool>,
    max_body: usize,
}

impl Server {
    /// Bind with the production `Scenario::evaluate` service.
    pub fn bind(cfg: &Config) -> io::Result<Server> {
        Server::bind_with(cfg, Service::new(&cfg.service))
    }

    /// Bind around an externally-built service (the tests inject gated
    /// evaluators to pin 429/503/drain behavior deterministically).
    pub fn bind_with(cfg: &Config, service: Service) -> io::Result<Server> {
        let listener = TcpListener::bind(cfg.addr)?;
        Ok(Server {
            listener,
            service: Arc::new(service),
            stop: Arc::new(AtomicBool::new(false)),
            max_body: cfg.max_body,
        })
    }

    /// Actual bound address (resolves `--addr host:0` ephemeral ports).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Serve until the stop flag or a SIGINT/SIGTERM latches, then drain:
    /// stop accepting, join every connection thread (each finishes its
    /// in-flight request), and join the worker pool.
    pub fn run(self) -> io::Result<()> {
        self.listener.set_nonblocking(true)?;
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::Relaxed) && !signal::interrupted() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let service = Arc::clone(&self.service);
                    let stop = Arc::clone(&self.stop);
                    let max_body = self.max_body;
                    conns.push(std::thread::spawn(move || {
                        handle_connection(stream, &service, &stop, max_body);
                    }));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    conns.retain(|h| !h.is_finished());
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => return Err(e),
            }
        }
        // refuse new connections (listener closes on drop), drain in-flight
        drop(self.listener);
        for h in conns {
            let _ = h.join();
        }
        // last Arc owner: dropping the service joins the worker pool
        drop(self.service);
        Ok(())
    }

    /// Spawn [`Server::run`] on a background thread (the test harness path;
    /// the CLI calls `run` inline).
    pub fn start(self) -> io::Result<Handle> {
        let addr = self.local_addr()?;
        let stop = Arc::clone(&self.stop);
        let join = std::thread::spawn(move || self.run());
        Ok(Handle { addr, stop, join })
    }
}

/// Control handle for a backgrounded server.
pub struct Handle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: JoinHandle<io::Result<()>>,
}

impl Handle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request a graceful stop and block until the drain completes.
    pub fn stop(self) -> io::Result<()> {
        self.stop.store(true, Ordering::Relaxed);
        self.join
            .join()
            .unwrap_or_else(|_| Err(io::Error::other("server thread panicked")))
    }
}

/// JSON error body with proper string escaping.
fn err_reply(status: u16, msg: &str) -> Reply {
    Reply { status, body: Json::obj(vec![("error", Json::from(msg))]).pretty() }
}

/// One connection: parse, route, respond, close.
fn handle_connection(
    stream: TcpStream,
    service: &Service,
    stop: &AtomicBool,
    max_body: usize,
) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let reply = route(&mut reader, &mut writer, service, stop, max_body);
    // the metrics text surface is the one non-JSON body the daemon emits
    let response = if reply.body.starts_with('{') || reply.body.starts_with('[') {
        http::Response::json(reply.status, reply.body)
    } else {
        http::Response::text(reply.status, reply.body)
    };
    let _ = response.write_to(&mut writer);
}

/// Parse one request off the reader and produce the reply for it.
fn route(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    service: &Service,
    stop: &AtomicBool,
    max_body: usize,
) -> Reply {
    let head = match http::read_head(reader) {
        Ok(h) => h,
        Err(e) => return err_reply(400, &e.to_string()),
    };
    let (path, query) = head.path_query();
    match (head.method.as_str(), path) {
        ("GET", "/v1/health") => service.health(),
        ("GET", "/v1/metrics") => {
            let json = query.is_some_and(|q| q.split('&').any(|kv| kv == "format=json"));
            service.metrics_reply(json)
        }
        ("POST", "/v1/evaluate") => {
            if head.expects_continue() {
                // curl sends Expect: 100-continue for larger scenario
                // bodies and waits for this interim line before the payload
                let _ = write!(writer, "HTTP/1.1 100 Continue\r\n\r\n");
                let _ = writer.flush();
            }
            match http::read_body(reader, &head, max_body) {
                Ok(http::BodyOutcome::Ok(body)) => service.evaluate(&body),
                Ok(http::BodyOutcome::TooLarge(n)) => err_reply(
                    413,
                    &format!("body of {n} bytes exceeds the {max_body}-byte limit"),
                ),
                Ok(http::BodyOutcome::Unsupported(msg)) => err_reply(400, msg),
                Err(e) => err_reply(400, &e.to_string()),
            }
        }
        ("POST", "/v1/shutdown") => {
            stop.store(true, Ordering::Relaxed);
            Reply { status: 200, body: "{\"status\": \"stopping\"}".to_string() }
        }
        (_, "/v1/health" | "/v1/metrics" | "/v1/evaluate" | "/v1/shutdown") => {
            err_reply(405, "method not allowed")
        }
        _ => err_reply(404, &format!("no route for {path}")),
    }
}
