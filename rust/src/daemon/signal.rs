//! SIGINT/SIGTERM → a process-wide flag, without the `libc` crate: the
//! C `signal(2)` entry point is declared by hand and the handler only
//! stores to an `AtomicBool` (async-signal-safe). The daemon's accept loop
//! polls the flag from a nonblocking listener, so the handler never needs
//! to interrupt a blocking syscall reliably (`SA_RESTART` semantics don't
//! matter here).

use std::sync::atomic::{AtomicBool, Ordering};

static INTERRUPTED: AtomicBool = AtomicBool::new(false);

/// True once SIGINT/SIGTERM (or [`raise_interrupt`]) was seen.
pub fn interrupted() -> bool {
    INTERRUPTED.load(Ordering::Relaxed)
}

/// Set the flag programmatically (tests, and the `/v1/shutdown` endpoint
/// path on non-unix builds).
pub fn raise_interrupt() {
    INTERRUPTED.store(true, Ordering::Relaxed);
}

#[cfg(unix)]
mod imp {
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        // POSIX signal(2); usize stands in for the handler pointer.
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_signal(_signum: i32) {
        // only an atomic store: async-signal-safe
        super::INTERRUPTED.store(true, Ordering::Relaxed);
    }

    /// Route SIGINT and SIGTERM to the flag.
    pub fn install() {
        unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    /// No signal routing off unix; ctrl-c kills the process and the
    /// `/v1/shutdown` endpoint remains the graceful path.
    pub fn install() {}
}

/// Install the handlers (idempotent).
pub fn install() {
    imp::install();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_starts_clear_and_latches() {
        install(); // must not crash or alter the flag
        raise_interrupt();
        assert!(interrupted());
    }
}
