//! Hand-rolled HTTP/1.1 request/response plumbing (hyper/axum are
//! unavailable offline — DESIGN.md §Substitutions), in the same spirit as
//! the in-tree HLO parser: just enough of the grammar for the daemon's
//! three JSON endpoints. One request per connection (`Connection: close`),
//! `Content-Length` bodies only (no chunked transfer), plus the tiny
//! blocking client the tests and the daemon bench drive the server with.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Cap on request-line/header sizes: nothing legitimate the daemon serves
/// comes close, and it bounds memory for garbage input.
const MAX_HEAD_LINE: usize = 8 * 1024;
const MAX_HEADERS: usize = 100;

/// Parsed request head: method, target, and lowercased header names.
pub struct Head {
    pub method: String,
    /// Raw request target, query string included (e.g. `/v1/metrics?format=json`).
    pub target: String,
    pub headers: Vec<(String, String)>,
}

impl Head {
    /// Header value by lowercase name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Declared body length (0 when the header is absent).
    pub fn content_length(&self) -> io::Result<usize> {
        match self.header("content-length") {
            None => Ok(0),
            Some(v) => v
                .trim()
                .parse()
                .map_err(|_| bad_request(format!("invalid Content-Length '{v}'"))),
        }
    }

    /// True when the client asked for `100 Continue` before sending the
    /// body (curl does this for larger POSTs).
    pub fn expects_continue(&self) -> bool {
        self.header("expect").is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
    }

    /// Path without the query string, and the query string (if any).
    pub fn path_query(&self) -> (&str, Option<&str>) {
        match self.target.split_once('?') {
            Some((p, q)) => (p, Some(q)),
            None => (&self.target, None),
        }
    }
}

fn bad_request(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Read one CRLF- (or bare-LF-) terminated line, bounded by [`MAX_HEAD_LINE`].
fn read_line<R: BufRead>(r: &mut R) -> io::Result<String> {
    let mut buf = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte)? {
            0 => break, // EOF mid-line: return what we have
            _ => {
                if byte[0] == b'\n' {
                    break;
                }
                buf.push(byte[0]);
                if buf.len() > MAX_HEAD_LINE {
                    return Err(bad_request("header line too long".into()));
                }
            }
        }
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| bad_request("non-UTF-8 header line".into()))
}

/// Parse the request line and headers (the body stays on the reader).
pub fn read_head<R: BufRead>(r: &mut R) -> io::Result<Head> {
    let line = read_line(r)?;
    if line.is_empty() {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "empty request"));
    }
    let mut parts = line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if v.starts_with("HTTP/1") => (m.to_string(), t.to_string()),
        _ => return Err(bad_request(format!("malformed request line '{line}'"))),
    };
    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?;
        if line.is_empty() {
            break; // blank line terminates the head
        }
        if headers.len() >= MAX_HEADERS {
            return Err(bad_request("too many headers".into()));
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(bad_request(format!("malformed header '{line}'")));
        };
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    Ok(Head { method, target, headers })
}

/// Body read outcomes the caller maps to HTTP statuses.
pub enum BodyOutcome {
    Ok(Vec<u8>),
    /// Declared length exceeds the server's `--max-body` → 413.
    TooLarge(usize),
    /// `Transfer-Encoding: chunked` (unsupported) → 400.
    Unsupported(&'static str),
}

/// Read the request body per the head's framing headers.
pub fn read_body<R: BufRead>(r: &mut R, head: &Head, max_body: usize) -> io::Result<BodyOutcome> {
    if head.header("transfer-encoding").is_some_and(|v| !v.eq_ignore_ascii_case("identity")) {
        return Ok(BodyOutcome::Unsupported("chunked transfer encoding not supported"));
    }
    let len = head.content_length()?;
    if len > max_body {
        return Ok(BodyOutcome::TooLarge(len));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(BodyOutcome::Ok(body))
}

/// One response, always `Connection: close`.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body }
    }

    pub fn text(status: u16, body: String) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8", body }
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len()
        )?;
        w.write_all(self.body.as_bytes())?;
        w.flush()
    }
}

/// Reason phrase for the statuses the daemon emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        100 => "Continue",
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Minimal blocking HTTP client for one round trip — what `tests/daemon.rs`
/// and `benches/daemon.rs` hit the loopback listener with. Returns
/// `(status, body)`.
pub fn roundtrip(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(120)))?;
    let body = body.unwrap_or("");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: dfmodeld\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut r = BufReader::new(stream);
    let status_line = read_line(&mut r)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad_request(format!("malformed status line '{status_line}'")))?;
    let mut content_length: Option<usize> = None;
    loop {
        let line = read_line(&mut r)?;
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().ok();
            }
        }
    }
    let body = match content_length {
        Some(n) => {
            let mut buf = vec![0u8; n];
            r.read_exact(&mut buf)?;
            String::from_utf8_lossy(&buf).into_owned()
        }
        None => {
            let mut buf = String::new();
            r.read_to_string(&mut buf)?;
            buf
        }
    };
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn head_of(raw: &str) -> io::Result<Head> {
        read_head(&mut Cursor::new(raw.as_bytes()))
    }

    #[test]
    fn parses_request_line_and_headers() {
        let h = head_of(
            "POST /v1/evaluate?x=1 HTTP/1.1\r\nHost: a\r\nContent-Length: 12\r\nExpect: 100-continue\r\n\r\n",
        )
        .unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.path_query(), ("/v1/evaluate", Some("x=1")));
        assert_eq!(h.content_length().unwrap(), 12);
        assert!(h.expects_continue());
        assert_eq!(h.header("host"), Some("a"));
        assert_eq!(h.header("missing"), None);
    }

    #[test]
    fn tolerates_bare_lf_and_no_query() {
        let h = head_of("GET /v1/health HTTP/1.1\nHost: b\n\n").unwrap();
        assert_eq!(h.method, "GET");
        assert_eq!(h.path_query(), ("/v1/health", None));
        assert_eq!(h.content_length().unwrap(), 0);
        assert!(!h.expects_continue());
    }

    #[test]
    fn rejects_garbage() {
        assert!(head_of("not http at all\r\n\r\n").is_err());
        assert!(head_of("GET /x HTTP/1.1\r\nbroken header line\r\n\r\n").is_err());
        let h = head_of("GET /x HTTP/1.1\r\nContent-Length: twelve\r\n\r\n").unwrap();
        assert!(h.content_length().is_err());
    }

    #[test]
    fn body_framing_and_limits() {
        let raw = "POST /e HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let mut c = Cursor::new(raw.as_bytes());
        let h = read_head(&mut c).unwrap();
        match read_body(&mut c, &h, 1024).unwrap() {
            BodyOutcome::Ok(b) => assert_eq!(b, b"hello"),
            _ => panic!("expected body"),
        }
        let mut c = Cursor::new(raw.as_bytes());
        let h = read_head(&mut c).unwrap();
        assert!(matches!(read_body(&mut c, &h, 4).unwrap(), BodyOutcome::TooLarge(5)));
        let raw = "POST /e HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        let mut c = Cursor::new(raw.as_bytes());
        let h = read_head(&mut c).unwrap();
        assert!(matches!(read_body(&mut c, &h, 1024).unwrap(), BodyOutcome::Unsupported(_)));
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        Response::json(422, "{\"error\":\"x\"}".into()).write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 422 Unprocessable Entity\r\n"), "got: {s}");
        assert!(s.contains("Content-Length: 13\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("{\"error\":\"x\"}"));
    }
}
