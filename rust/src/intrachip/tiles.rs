//! Compute-tile allocation and the per-kernel utilization model u_c (§V-B.1).
//!
//! Each tile is modeled as a 128×128 MXU-like systolic array (the paper's
//! `t_flop` per tile); the utilization factor follows the SCALE-Sim-style
//! empirical model [73]: matmul utilization degrades when a GEMM dimension
//! under-fills the array, vector kernels run on the (slower) vector path.

use crate::graph::KernelKind;

/// Systolic-array edge (elements): full MXU utilization needs all GEMM
/// dimensions ≥ this.
pub const ARRAY_DIM: f64 = 128.0;

/// Fraction of a tile's peak FLOP/s available to non-matmul (vector) work.
pub const VECTOR_FRACTION: f64 = 0.25;

/// Utilization of one kernel on the MXU tiles, independent of tile count
/// (dimension under-fill; the paper's u_c).
pub fn utilization(kind: &KernelKind) -> f64 {
    match *kind {
        KernelKind::Gemm { m, k, n, .. } => {
            let fill = |d: f64| (d / ARRAY_DIM).min(1.0);
            // batch dim adds no under-fill penalty (tiles iterate over it)
            (fill(m) * fill(n) * fill(k)).max(1e-3)
        }
        KernelKind::FusedLayer { .. } => 0.85, // internally well-blocked GEMMs
        KernelKind::Softmax { .. }
        | KernelKind::Elementwise { .. }
        | KernelKind::LayerNorm { .. } => VECTOR_FRACTION,
        KernelKind::Embedding { .. } => 0.05, // gather-dominated
        KernelKind::Fft { .. } => 0.30,       // butterfly irregularity
        KernelKind::Transpose { .. } => VECTOR_FRACTION,
    }
}

/// Allocate `total` tiles across kernels to minimize the pipeline's
/// critical kernel time  max_k f_eff[k] / (tiles[k] · t_flop)  where
/// f_eff = flops / u_c (§V-B.1). Water-filling: proportional allocation by
/// largest remainder, then greedy repair moves.
///
/// Returns (tiles per kernel, critical time numerator max f_eff/tiles).
/// None if there are more kernels than tiles.
pub fn allocate_tiles(f_eff: &[f64], total: usize) -> Option<(Vec<usize>, f64)> {
    let n = f_eff.len();
    if n == 0 {
        return Some((vec![], 0.0));
    }
    if n > total {
        return None;
    }
    let sum: f64 = f_eff.iter().sum();
    if sum <= 0.0 {
        // zero-FLOP partition (pure data movement): spread evenly
        let mut tiles = vec![total / n; n];
        for t in tiles.iter_mut().take(total % n) {
            *t += 1;
        }
        return Some((tiles, 0.0));
    }

    // proportional share with a floor of 1
    let mut tiles: Vec<usize> =
        f_eff.iter().map(|&f| ((f / sum) * total as f64).floor().max(1.0) as usize).collect();
    // fix overshoot from the floor-of-1 (steal from the most over-provisioned)
    while tiles.iter().sum::<usize>() > total {
        let i = (0..n)
            .filter(|&i| tiles[i] > 1)
            .min_by(|&a, &b| {
                let ta = f_eff[a] / (tiles[a] - 1) as f64;
                let tb = f_eff[b] / (tiles[b] - 1) as f64;
                ta.total_cmp(&tb)
            })?;
        tiles[i] -= 1;
    }
    // hand out remaining tiles to the current bottleneck
    let mut left = total - tiles.iter().sum::<usize>();
    while left > 0 {
        let i = (0..n)
            .max_by(|&a, &b| {
                (f_eff[a] / tiles[a] as f64).total_cmp(&(f_eff[b] / tiles[b] as f64))
            })
            .unwrap();
        tiles[i] += 1;
        left -= 1;
    }
    // greedy repair: move a tile from the laxest to the bottleneck while the
    // critical time improves. Bounded: the proportional start is already
    // near-optimal, and an unbounded loop degenerates to one-tile-at-a-time
    // shuffling on huge-tile chips (WSE: 850k tiles).
    for _ in 0..2 * n {
        let crit = |ts: &[usize]| {
            (0..n).map(|i| f_eff[i] / ts[i] as f64).fold(0.0f64, f64::max)
        };
        let before = crit(&tiles);
        let hot = (0..n)
            .max_by(|&a, &b| (f_eff[a] / tiles[a] as f64).total_cmp(&(f_eff[b] / tiles[b] as f64)))
            .unwrap();
        // best donor: kernel whose time stays below `before` after losing one
        let donor = (0..n)
            .filter(|&i| i != hot && tiles[i] > 1)
            .min_by(|&a, &b| {
                let ta = f_eff[a] / (tiles[a] - 1) as f64;
                let tb = f_eff[b] / (tiles[b] - 1) as f64;
                ta.total_cmp(&tb)
            });
        let Some(d) = donor else { break };
        tiles[d] -= 1;
        tiles[hot] += 1;
        if crit(&tiles) + 1e-18 >= before {
            tiles[d] += 1;
            tiles[hot] -= 1;
            break;
        }
    }
    let crit = (0..n).map(|i| f_eff[i] / tiles[i] as f64).fold(0.0f64, f64::max);
    Some((tiles, crit))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn gemm_utilization_saturates() {
        let big = KernelKind::Gemm { b: 1.0, m: 4096.0, k: 4096.0, n: 4096.0 };
        assert_eq!(utilization(&big), 1.0);
        let gemv = KernelKind::Gemm { b: 1.0, m: 1.0, k: 4096.0, n: 4096.0 };
        assert!((utilization(&gemv) - 1.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn vector_kernels_fraction() {
        assert_eq!(
            utilization(&KernelKind::Softmax { rows: 10.0, cols: 10.0 }),
            VECTOR_FRACTION
        );
    }

    #[test]
    fn allocation_proportional() {
        let (tiles, crit) = allocate_tiles(&[300.0, 100.0], 4).unwrap();
        assert_eq!(tiles, vec![3, 1]);
        assert!((crit - 100.0).abs() < 1e-12);
    }

    #[test]
    fn allocation_respects_floor() {
        let (tiles, _) = allocate_tiles(&[1e12, 1.0, 1.0], 8).unwrap();
        assert!(tiles.iter().all(|&t| t >= 1));
        assert_eq!(tiles.iter().sum::<usize>(), 8);
        assert_eq!(tiles[0], 6);
    }

    #[test]
    fn more_kernels_than_tiles_infeasible() {
        assert!(allocate_tiles(&[1.0, 1.0, 1.0], 2).is_none());
    }

    #[test]
    fn zero_flop_partition() {
        let (tiles, crit) = allocate_tiles(&[0.0, 0.0], 6).unwrap();
        assert_eq!(tiles.iter().sum::<usize>(), 6);
        assert_eq!(crit, 0.0);
    }

    #[test]
    fn allocation_never_worse_than_even_split_property() {
        check("waterfill-beats-even", 100, |rng| {
            let n = 1 + rng.below(6);
            let total = n + rng.below(64);
            let f: Vec<f64> = (0..n).map(|_| rng.uniform(1.0, 1e6)).collect();
            let (tiles, crit) = allocate_tiles(&f, total).unwrap();
            assert_eq!(tiles.iter().sum::<usize>(), total);
            assert!(tiles.iter().all(|&t| t >= 1));
            // even split baseline
            let mut even = vec![total / n; n];
            for t in even.iter_mut().take(total % n) {
                *t += 1;
            }
            let crit_even =
                (0..n).map(|i| f[i] / even[i] as f64).fold(0.0f64, f64::max);
            assert!(crit <= crit_even + 1e-9, "crit {crit} even {crit_even} f {f:?}");
        });
    }
}
