//! Intra-chip optimization (§V): subdivide one chip's assigned subgraph
//! into partitions that execute sequentially; within a partition all
//! kernels are fused on-chip and fully pipelined (dataflow execution,
//! Fig. 2C). Kernel-by-kernel chips (GPUs/TPUs) are modeled as the forced
//! one-kernel-per-partition assignment (Fig. 2D).
//!
//! Per-partition critical time = max(t_comp, t_mem, t_net) (§V-B.4);
//! objective = minimize Σ over partitions — solved exactly by contiguous DP
//! over topological order with SRAM/DRAM capacity feasibility.

pub mod optimizer;
pub mod tiles;

pub use optimizer::IntraChipOptions;

/// `pub(crate)`: external callers go through `api::map_chip` or a
/// `api::Scenario` — the facade is the only public optimization seam.
pub(crate) use optimizer::optimize_intra;

use crate::assign::Assignment;
use crate::graph::DataflowGraph;

/// Metrics of one on-chip partition.
#[derive(Debug, Clone, Copy, Default)]
pub struct PartitionMetrics {
    pub t_comp: f64,
    pub t_mem: f64,
    pub t_net: f64,
    /// SRAM bytes used by intra-partition tensors + resident weights.
    pub sram_used: f64,
    /// DRAM bytes transferred per pipeline input (matrix D traffic).
    pub dram_traffic: f64,
}

impl PartitionMetrics {
    pub fn t_cri(&self) -> f64 {
        self.t_comp.max(self.t_mem).max(self.t_net)
    }
}

/// Result of the intra-chip pass ((4) in Fig. 1).
#[derive(Debug, Clone)]
pub struct IntraChipMapping {
    pub assignment: Assignment,
    /// Tiles allocated to each kernel (within its partition).
    pub tiles: Vec<usize>,
    pub partitions: Vec<PartitionMetrics>,
    /// Σ_p max(t_comp, t_mem, t_net) — the §V objective (seconds per
    /// pipeline input).
    pub total_time: f64,
}

impl IntraChipMapping {
    /// Aggregate DRAM traffic per pipeline input.
    pub fn total_dram_traffic(&self) -> f64 {
        self.partitions.iter().map(|p| p.dram_traffic).sum()
    }

    /// Aggregate compute/memory/network split (for the Fig. 11/13/15/17
    /// latency breakdowns): each partition contributes its critical time
    /// attributed to its bottleneck resource.
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let (mut c, mut m, mut n) = (0.0, 0.0, 0.0);
        for p in &self.partitions {
            let t = p.t_cri();
            if t <= 0.0 {
                continue;
            }
            if p.t_comp >= p.t_mem && p.t_comp >= p.t_net {
                c += t;
            } else if p.t_mem >= p.t_net {
                m += t;
            } else {
                n += t;
            }
        }
        (c, m, n)
    }

    /// Names of kernels in each partition (for the §VII mapping tables).
    pub fn partition_names(&self, g: &DataflowGraph) -> Vec<Vec<String>> {
        self.assignment
            .members()
            .iter()
            .filter(|m| !m.is_empty())
            .map(|m| m.iter().map(|&k| g.kernels[k].name.clone()).collect())
            .collect()
    }
}
