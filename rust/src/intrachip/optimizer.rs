//! The intra-chip optimizer: exact contiguous DP over topological order
//! minimizing Σ max(t_comp, t_mem, t_net) under SRAM/DRAM constraints.

use super::tiles::{allocate_tiles, utilization};
use super::{IntraChipMapping, PartitionMetrics};
use crate::assign::Assignment;
use crate::graph::DataflowGraph;
use crate::solver;
use crate::system::{ChipSpec, ExecutionModel, MemoryTech};

/// Achievable-efficiency derate of kernel-by-kernel execution: launch/sync
/// overhead and imperfect intra-kernel overlap (Calculon's 0.62 achievable
/// MFU). Shared with the explorer's pruning bound
/// (`explore::bound`), which is only sound while it uses the same
/// ceilings as this optimizer.
pub const EXEC_EFF_KERNEL_BY_KERNEL: f64 = 0.62;

/// Achievable-efficiency derate of a fused spatial pipeline (~0.9 of the
/// per-kind-derated peak). See [`EXEC_EFF_KERNEL_BY_KERNEL`].
pub const EXEC_EFF_DATAFLOW: f64 = 0.90;

#[derive(Debug, Clone)]
pub struct IntraChipOptions {
    /// Maximum number of sequential partitions (`p_max`); defaults to one
    /// per kernel.
    pub p_max: usize,
    /// Per-kernel network time charged to the kernel's partition (from the
    /// inter-chip pass: h_n + incoming h_m); empty = zero.
    pub net_time: Vec<f64>,
    /// Force the kernel-by-kernel (non-dataflow) mapping regardless of the
    /// chip's execution model (used for baseline comparisons).
    pub force_kernel_by_kernel: bool,
    /// Force a specific assignment (e.g. the §VII-B vendor mapping) and
    /// only compute its metrics.
    pub force_assignment: Option<Vec<usize>>,
}

impl Default for IntraChipOptions {
    fn default() -> Self {
        IntraChipOptions {
            p_max: usize::MAX,
            net_time: Vec::new(),
            force_kernel_by_kernel: false,
            force_assignment: None,
        }
    }
}

/// Run the §V optimization for one chip's (already sharded) subgraph.
/// Returns None when no feasible partitioning exists (capacity exceeded).
/// (`pub(crate)` — the public seam is `api::map_chip`.)
pub(crate) fn optimize_intra(
    g: &DataflowGraph,
    chip: &ChipSpec,
    memory: &MemoryTech,
    opts: &IntraChipOptions,
) -> Option<IntraChipMapping> {
    let order = g.topo_order().expect("graph must be a DAG");
    let n = g.n_kernels();
    let net = if opts.net_time.is_empty() { vec![0.0; n] } else { opts.net_time.clone() };
    assert_eq!(net.len(), n);

    // Per-kernel effective FLOP (f' / u_c) in topo order.
    let f_eff: Vec<f64> =
        order.iter().map(|k| g.kernels[k.0].flops / utilization(&g.kernels[k.0].kind)).collect();
    let weights: Vec<f64> = order.iter().map(|k| g.kernels[k.0].weight_bytes).collect();
    let net_pos: Vec<f64> = order.iter().map(|k| net[k.0]).collect();

    // topo position of each kernel
    let mut pos = vec![0usize; n];
    for (p, k) in order.iter().enumerate() {
        pos[k.0] = p;
    }
    // tensor spans in topo positions
    let spans: Vec<(usize, usize, f64)> = g
        .tensors
        .iter()
        .map(|t| {
            let (a, b) = (pos[t.src.0], pos[t.dst.0]);
            (a.min(b), a.max(b), t.bytes)
        })
        .collect();

    // prefix sums
    let mut pre_feff = vec![0.0f64; n + 1];
    let mut pre_w = vec![0.0f64; n + 1];
    let mut pre_net = vec![0.0f64; n + 1];
    for i in 0..n {
        pre_feff[i + 1] = pre_feff[i] + f_eff[i];
        pre_w[i + 1] = pre_w[i] + weights[i];
        pre_net[i + 1] = pre_net[i] + net_pos[i];
    }

    let kbk = opts.force_kernel_by_kernel || chip.execution == ExecutionModel::KernelByKernel;
    let exec_eff = if kbk { EXEC_EFF_KERNEL_BY_KERNEL } else { EXEC_EFF_DATAFLOW };

    let evaluate = |a: usize, b: usize| -> Option<PartitionMetrics> {
        segment_metrics(
            g, chip, memory, &order, &spans, &pre_feff, &pre_w, &pre_net, a, b, exec_eff, kbk,
        )
    };

    let (assignment, metrics) = if let Some(part) = &opts.force_assignment {
        // metrics of a given (contiguous-in-topo-order) assignment
        let p_max = part.iter().max().copied().unwrap_or(0) + 1;
        let asg = Assignment::new(part.clone(), p_max);
        let mut bounds = Vec::new();
        let part_of_pos: Vec<usize> = order.iter().map(|k| part[k.0]).collect();
        let mut prev = usize::MAX;
        for (p, &pp) in part_of_pos.iter().enumerate() {
            if pp != prev {
                bounds.push(p);
                prev = pp;
            }
        }
        let mut ms = Vec::new();
        for (si, &start) in bounds.iter().enumerate() {
            let end = bounds.get(si + 1).copied().unwrap_or(n);
            ms.push(evaluate(start, end)?);
        }
        (asg, ms)
    } else if kbk {
        // non-dataflow: one kernel per partition, in topo order
        let mut part = vec![0usize; n];
        for (p, k) in order.iter().enumerate() {
            part[k.0] = p;
        }
        let asg = Assignment::new(part, n);
        let mut ms = Vec::new();
        for p in 0..n {
            ms.push(evaluate(p, p + 1)?);
        }
        if crate::explain::enabled() {
            record_partition_winner(&ms, "kernel-by-kernel");
        }
        (asg, ms)
    } else {
        // dataflow: exact DP over contiguous topo ranges. The segment-cost
        // table is precomputed once — the DP probes each (a, b) at every
        // part-count level and segment evaluation (tile water-filling) is
        // the expensive part (§Perf: ~30x on WSE-scale tile counts).
        let p_max = opts.p_max.min(n);
        let table: Vec<Vec<f64>> = (0..n)
            .map(|a| {
                (a + 1..=n)
                    .map(|b| match evaluate(a, b) {
                        Some(m) => m.t_cri(),
                        None => f64::INFINITY,
                    })
                    .collect()
            })
            .collect();
        let cost = |a: usize, b: usize| table[a][b - a - 1];
        let (dp_total, bounds) = solver::partition_min_sum(n, p_max, cost)?;
        let part_of_pos = solver::bounds_to_assignment(n, &bounds);
        let mut part = vec![0usize; n];
        for (p, k) in order.iter().enumerate() {
            part[k.0] = part_of_pos[p];
        }
        let asg = Assignment::new(part, bounds.len());
        let mut ms = Vec::new();
        for (si, &start) in bounds.iter().enumerate() {
            let end = bounds.get(si + 1).copied().unwrap_or(n);
            ms.push(evaluate(start, end)?);
        }
        if crate::explain::enabled() {
            record_partition_winner(&ms, "fused DP");
            // rejected candidates: merging each adjacent partition pair —
            // what the fusion DP weighed and turned down (or was forbidden
            // from by the SRAM/tile capacity constraints)
            for bi in 1..bounds.len() {
                let (a, mid) = (bounds[bi - 1], bounds[bi]);
                let end = bounds.get(bi + 1).copied().unwrap_or(n);
                let merged = cost(a, end);
                let cand = format!("merge P{}+P{}", bi - 1, bi);
                if merged.is_finite() {
                    let score = dp_total - cost(a, mid) - cost(mid, end) + merged;
                    let dom = evaluate(a, end)
                        .map_or("sram-capacity", |m| {
                            crate::explain::attribution::partition_bound(&m)
                        });
                    crate::explain::ledger::record_candidate(
                        "intrachip.partition",
                        cand,
                        Some(score),
                        dom,
                    );
                } else {
                    crate::explain::ledger::record_candidate(
                        "intrachip.partition",
                        cand,
                        None,
                        "sram-capacity",
                    );
                }
            }
        }
        (asg, ms)
    };

    // tile allocation per partition, reported per kernel
    let mut tiles = vec![0usize; n];
    {
        let mut bounds = Vec::new();
        let part_of_pos: Vec<usize> = order.iter().map(|k| assignment.part[k.0]).collect();
        let mut prev = usize::MAX;
        for (p, &pp) in part_of_pos.iter().enumerate() {
            if pp != prev {
                bounds.push(p);
                prev = pp;
            }
        }
        for (si, &start) in bounds.iter().enumerate() {
            let end = bounds.get(si + 1).copied().unwrap_or(n);
            let fe = &f_eff[start..end];
            if let Some((alloc, _)) = allocate_tiles(fe, chip.tiles) {
                for (off, t) in alloc.iter().enumerate() {
                    tiles[order[start + off].0] = *t;
                }
            }
        }
    }

    let total_time = metrics.iter().map(|m| m.t_cri()).sum();
    Some(IntraChipMapping { assignment, tiles, partitions: metrics, total_time })
}

/// Record the winning intra-chip partitioning into the explain ledger
/// (callers gate on `explain::enabled`).
fn record_partition_winner(ms: &[PartitionMetrics], kind: &str) {
    let total: f64 = ms.iter().map(PartitionMetrics::t_cri).sum();
    let dom = ms
        .iter()
        .max_by(|a, b| a.t_cri().partial_cmp(&b.t_cri()).unwrap_or(std::cmp::Ordering::Equal))
        .map_or("compute", crate::explain::attribution::partition_bound);
    crate::explain::ledger::record_winner(
        "intrachip.partition",
        format!("{kind} ({} partitions)", ms.len()),
        total,
        dom,
    );
}

/// Metrics + feasibility of the topo segment [a, b) as one fused partition.
#[allow(clippy::too_many_arguments)]
fn segment_metrics(
    g: &DataflowGraph,
    chip: &ChipSpec,
    memory: &MemoryTech,
    order: &[crate::graph::KernelId],
    spans: &[(usize, usize, f64)],
    pre_feff: &[f64],
    pre_w: &[f64],
    pre_net: &[f64],
    a: usize,
    b: usize,
    exec_eff: f64,
    kbk: bool,
) -> Option<PartitionMetrics> {
    let len = b - a;
    if len == 0 {
        return None;
    }
    // tiles: every fused kernel needs at least one
    if len > chip.tiles {
        return None;
    }
    let f_eff = &pre_feff[a..=b];
    let fe: Vec<f64> = (0..len).map(|i| f_eff[i + 1] - f_eff[i]).collect();
    let (_alloc, crit) = allocate_tiles(&fe, chip.tiles)?;
    let t_comp = crit / chip.tflop_per_tile.raw() / exec_eff;

    // SRAM: intra-partition tensors (matrix B) + resident weights.
    let mut sram_tensors = 0.0;
    let mut dram_traffic = 0.0;
    for &(s, d, bytes) in spans {
        let inside = s >= a && d < b;
        if inside {
            sram_tensors += bytes;
        } else {
            // matrix D: stored by the producer partition and loaded by the
            // consumer partition — counts once on each side
            let src_in = s >= a && s < b;
            let dst_in = d >= a && d < b;
            if src_in {
                dram_traffic += bytes;
            }
            if dst_in {
                dram_traffic += bytes;
            }
        }
    }
    let weights = pre_w[b] - pre_w[a];
    let sram_free = (chip.sram_bytes.raw() - sram_tensors).max(0.0);
    if sram_tensors > chip.sram_bytes.raw() {
        return None; // streaming tensors can't be spilled in a fused pipeline
    }
    // Fig. 2D semantics: kernel-by-kernel execution loads the kernel's
    // weights from DRAM on every invocation; a fused spatial pipeline keeps
    // weights resident in SRAM (streaming only the excess).
    let (weight_stream, sram_used) = if kbk {
        (weights, sram_tensors)
    } else {
        ((weights - sram_free).max(0.0), sram_tensors + weights.min(sram_free))
    };
    dram_traffic += weight_stream;

    let t_mem = dram_traffic / memory.bandwidth.raw();
    let t_net = pre_net[b] - pre_net[a];
    let _ = (g, order);
    Some(PartitionMetrics { t_comp, t_mem, t_net, sram_used, dram_traffic })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gpt::{gpt_layer_graph, GptConfig};
    use crate::system::{chip, memory};

    /// A GPT-175B-like layer sharded 8-way (≈ per-chip sizes of §VII).
    fn sharded_layer() -> DataflowGraph {
        let cfg = GptConfig {
            layers: 96,
            d_model: 12288.0 / 8.0, // crude 8-way shard of the feature dim
            n_heads: 12.0,
            seq: 2048.0,
            d_ff: 4.0 * 12288.0 / 8.0,
            vocab: 50257.0,
            dtype_bytes: 2.0,
        };
        gpt_layer_graph(&cfg, 1.0)
    }

    #[test]
    fn dataflow_fuses_and_beats_kernel_by_kernel() {
        let g = sharded_layer();
        let sn10 = chip::sn10();
        let ddr = memory::ddr4();
        let df = optimize_intra(&g, &sn10, &ddr, &IntraChipOptions::default()).unwrap();
        let kbk = optimize_intra(
            &g,
            &sn10,
            &ddr,
            &IntraChipOptions { force_kernel_by_kernel: true, ..Default::default() },
        )
        .unwrap();
        // the dataflow mapping fuses (fewer partitions than kernels)
        assert!(df.assignment.n_used() < g.n_kernels());
        assert_eq!(kbk.assignment.n_used(), g.n_kernels());
        // fusion reduces DRAM traffic and total time (§VII: 4.05x class)
        assert!(df.total_dram_traffic() < kbk.total_dram_traffic());
        assert!(
            df.total_time < kbk.total_time,
            "dataflow {} vs kbk {}",
            df.total_time,
            kbk.total_time
        );
    }

    #[test]
    fn kernel_by_kernel_forced_for_gpu() {
        let g = sharded_layer();
        let h100 = chip::h100();
        let hbm = memory::hbm3();
        let m = optimize_intra(&g, &h100, &hbm, &IntraChipOptions::default()).unwrap();
        assert_eq!(m.assignment.n_used(), g.n_kernels());
    }

    #[test]
    fn sram_constraint_limits_fusion() {
        let g = sharded_layer();
        let mut tiny = chip::sn10();
        tiny.sram_bytes = crate::util::units::Bytes::new(10e6); // 10 MB: scores tile alone won't fit fused
        let ddr = memory::ddr4();
        let small = optimize_intra(&g, &tiny, &ddr, &IntraChipOptions::default()).unwrap();
        let big = optimize_intra(&g, &chip::sn10(), &ddr, &IntraChipOptions::default()).unwrap();
        assert!(small.assignment.n_used() >= big.assignment.n_used());
        assert!(small.total_dram_traffic() >= big.total_dram_traffic());
    }

    #[test]
    fn forced_assignment_metrics() {
        let g = sharded_layer();
        // vendor-style 4 partitions over the 14 kernels (topo order):
        // [LN1,Q,K,V] [MHA1,SM,MHA2,Proj,Add1] [LN2,FFN0,GeLU] [FFN1,Add2]
        let order = g.topo_order().unwrap();
        let mut part = vec![0usize; g.n_kernels()];
        for (p, k) in order.iter().enumerate() {
            part[k.0] = match p {
                0..=3 => 0,
                4..=8 => 1,
                9..=11 => 2,
                _ => 3,
            };
        }
        let m = optimize_intra(
            &g,
            &chip::sn10(),
            &memory::ddr4(),
            &IntraChipOptions { force_assignment: Some(part), ..Default::default() },
        )
        .unwrap();
        assert_eq!(m.partitions.len(), 4);
        assert!(m.total_time > 0.0);
    }

    #[test]
    fn optimal_not_worse_than_any_forced() {
        let g = sharded_layer();
        let sn10 = chip::sn10();
        let ddr = memory::ddr4();
        let opt = optimize_intra(&g, &sn10, &ddr, &IntraChipOptions::default()).unwrap();
        for splits in [2usize, 3, 5, 7] {
            let order = g.topo_order().unwrap();
            let n = g.n_kernels();
            let mut part = vec![0usize; n];
            for (p, k) in order.iter().enumerate() {
                part[k.0] = (p * splits / n).min(splits - 1);
            }
            if let Some(forced) = optimize_intra(
                &g,
                &sn10,
                &ddr,
                &IntraChipOptions { force_assignment: Some(part), ..Default::default() },
            ) {
                assert!(
                    opt.total_time <= forced.total_time + 1e-15,
                    "DP ({}) must beat {splits}-way uniform ({})",
                    opt.total_time,
                    forced.total_time
                );
            }
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let g = sharded_layer();
        let m =
            optimize_intra(&g, &chip::sn10(), &memory::ddr4(), &IntraChipOptions::default())
                .unwrap();
        let (c, me, n) = m.breakdown();
        assert!((c + me + n - m.total_time).abs() / m.total_time < 1e-9);
    }

    #[test]
    fn tiles_fully_allocated_per_partition() {
        let g = sharded_layer();
        let sn10 = chip::sn10();
        let m = optimize_intra(&g, &sn10, &memory::ddr4(), &IntraChipOptions::default()).unwrap();
        for members in m.assignment.members().iter().filter(|m| !m.is_empty()) {
            let total: usize = members.iter().map(|&k| m.tiles[k]).sum();
            assert_eq!(total, sn10.tiles, "partition under/over-allocated");
        }
    }
}
