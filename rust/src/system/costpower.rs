//! Fig. 9: silicon power (and price) versus compute throughput, with the
//! paper's polynomial regression. We refit the quadratic to the catalog
//! points by least squares and expose both the paper's published
//! coefficients and our fit (the bench prints both).

use super::chip::{table_v, ChipSpec};
use crate::util::units::TFLOPS;

/// Quadratic y = a·x² + b·x + c.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quadratic {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl Quadratic {
    pub fn eval(&self, x: f64) -> f64 {
        self.a * x * x + self.b * x + self.c
    }
}

/// The paper's published regression (power in kW vs throughput in TFLOPS):
/// Y = 3e-7·X² − 4.3e-4·X + 0.04.
pub fn paper_power_curve() -> Quadratic {
    Quadratic { a: 3e-7, b: -4.3e-4, c: 0.04 }
}

/// Least-squares quadratic fit through (x, y) points (normal equations on
/// the 3×3 Vandermonde system, solved by Gaussian elimination).
pub fn polyfit2(points: &[(f64, f64)]) -> Quadratic {
    assert!(points.len() >= 3, "need >= 3 points for a quadratic");
    // Accumulate the normal-equation moments.
    let mut s = [0.0f64; 5]; // Σ x^0..x^4
    let mut t = [0.0f64; 3]; // Σ y·x^0..x^2
    for &(x, y) in points {
        let mut xp = 1.0;
        for k in 0..5 {
            s[k] += xp;
            if k < 3 {
                t[k] += y * xp;
            }
            xp *= x;
        }
    }
    // Solve [[s4 s3 s2], [s3 s2 s1], [s2 s1 s0]] [a b c]^T = [t2 t1 t0]^T.
    let mut m = [
        [s[4], s[3], s[2], t[2]],
        [s[3], s[2], s[1], t[1]],
        [s[2], s[1], s[0], t[0]],
    ];
    for col in 0..3 {
        // partial pivot
        let piv = (col..3).max_by(|&i, &j| m[i][col].abs().total_cmp(&m[j][col].abs())).unwrap();
        m.swap(col, piv);
        assert!(m[col][col].abs() > 1e-30, "singular fit system");
        for row in 0..3 {
            if row != col {
                let f = m[row][col] / m[col][col];
                for k in col..4 {
                    m[row][k] -= f * m[col][k];
                }
            }
        }
    }
    Quadratic { a: m[0][3] / m[0][0], b: m[1][3] / m[1][1], c: m[2][3] / m[2][2] }
}

/// (TFLOPS, kW) points for the Table V chips.
pub fn chip_power_points() -> Vec<(f64, f64)> {
    table_v().iter().map(|c| (c.compute_flops().raw() / TFLOPS, c.power_w.raw() / 1000.0)).collect()
}

/// (TFLOPS, k$) points for the Table V chips.
pub fn chip_price_points() -> Vec<(f64, f64)> {
    table_v().iter().map(|c| (c.compute_flops().raw() / TFLOPS, c.price_usd.raw() / 1000.0)).collect()
}

/// Convenience: evaluate a fitted curve for a chip.
pub fn fitted_power_kw(chip: &ChipSpec, fit: &Quadratic) -> f64 {
    fit.eval(chip.compute_flops().raw() / TFLOPS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polyfit_recovers_exact_quadratic() {
        let q = Quadratic { a: 2.0, b: -1.0, c: 0.5 };
        let pts: Vec<(f64, f64)> = [-2.0, -1.0, 0.0, 1.0, 3.0]
            .iter()
            .map(|&x| (x, q.eval(x)))
            .collect();
        let fit = polyfit2(&pts);
        assert!((fit.a - q.a).abs() < 1e-9);
        assert!((fit.b - q.b).abs() < 1e-9);
        assert!((fit.c - q.c).abs() < 1e-9);
    }

    #[test]
    fn catalog_fit_is_superlinear() {
        let fit = polyfit2(&chip_power_points());
        assert!(fit.a > 0.0, "quadratic term must be positive: {fit:?}");
        // doubling top-end throughput more than doubles power
        let p1 = fit.eval(4000.0);
        let p2 = fit.eval(8000.0);
        assert!(p2 > 2.0 * p1);
    }

    #[test]
    fn paper_curve_matches_wse_scale() {
        // the paper's curve puts a 7.5 PFLOPS chip in the ~13-17 kW band
        let y = paper_power_curve().eval(7500.0);
        assert!((13.0..18.0).contains(&y), "y = {y}");
    }

    #[test]
    fn fit_close_to_catalog_points() {
        let pts = chip_power_points();
        let fit = polyfit2(&pts);
        for (x, y) in pts {
            let e = (fit.eval(x) - y).abs() / y.max(0.1);
            assert!(e < 1.5, "poor fit at x={x}: {e}");
        }
    }
}
