//! Interconnection-network topologies, composed hierarchically from 1-D
//! dimensions (§IV-C, the ASTRA-sim compositional approach [71]): a
//! multi-dimensional topology is a list of 1-D dims (ring, fully-connected,
//! switch); each dim is assigned to exactly one parallelization strategy.
//!
//! The paper's five evaluated topologies: 2-D torus, 3-D torus, dragonfly
//! [47], DGX-1 [2], DGX-2 [51].

use super::interconnect::LinkTech;

/// The 1-D building blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DimKind {
    /// Bidirectional ring: 1 link per node per direction.
    Ring,
    /// All-pairs direct links: n−1 links per node.
    FullyConnected,
    /// Central crossbar switch: 1 uplink per node, non-blocking.
    Switch,
}

/// One network dimension: `size` chips connected by `kind` with per-link
/// bandwidth/latency from the link technology.
#[derive(Debug, Clone)]
pub struct Dim {
    pub kind: DimKind,
    pub size: usize,
    /// Per-link, per-direction bandwidth (bytes/s).
    pub link_bw: f64,
    /// Per-hop latency (s).
    pub latency: f64,
}

impl Dim {
    pub fn new(kind: DimKind, size: usize, link: &LinkTech) -> Self {
        assert!(size >= 1);
        Dim { kind, size, link_bw: link.bandwidth, latency: link.latency }
    }

    /// Links contributed per node in this dimension (for price/power).
    pub fn links_per_node(&self) -> f64 {
        match self.kind {
            DimKind::Ring => {
                if self.size > 1 {
                    2.0
                } else {
                    0.0
                }
            }
            DimKind::FullyConnected => (self.size - 1) as f64,
            // node uplink + its share of the switch (counted as 1 extra)
            DimKind::Switch => {
                if self.size > 1 {
                    2.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// A hierarchical topology: the cartesian product of its dims.
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub dims: Vec<Dim>,
}

impl Topology {
    pub fn new(name: &str, dims: Vec<Dim>) -> Self {
        assert!(!dims.is_empty(), "topology needs at least one dim");
        Topology { name: name.into(), dims }
    }

    pub fn n_chips(&self) -> usize {
        self.dims.iter().map(|d| d.size).product()
    }

    /// Total link count (for system price/power).
    pub fn total_links(&self) -> f64 {
        let n = self.n_chips() as f64;
        // Each node contributes links_per_node per dim; each link shared by
        // two endpoints (switch uplinks count fully).
        self.dims.iter().map(|d| d.links_per_node() * n / 2.0).sum()
    }

    pub fn dim_sizes(&self) -> Vec<usize> {
        self.dims.iter().map(|d| d.size).collect()
    }
}

/// 2-D torus: X × Y rings.
pub fn torus2d(x: usize, y: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("2D-torus[{x}x{y}]"),
        vec![Dim::new(DimKind::Ring, x, link), Dim::new(DimKind::Ring, y, link)],
    )
}

/// 3-D torus: X × Y × Z rings.
pub fn torus3d(x: usize, y: usize, z: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("3D-torus[{x}x{y}x{z}]"),
        vec![
            Dim::new(DimKind::Ring, x, link),
            Dim::new(DimKind::Ring, y, link),
            Dim::new(DimKind::Ring, z, link),
        ],
    )
}

/// Dragonfly [47]: fully-connected groups, fully-connected globally.
pub fn dragonfly(group: usize, n_groups: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("dragonfly[{group}x{n_groups}]"),
        vec![
            Dim::new(DimKind::FullyConnected, group, link),
            Dim::new(DimKind::FullyConnected, n_groups, link),
        ],
    )
}

/// DGX-1 [2]: 8-GPU NVLink hybrid-cube-mesh (modeled as fully-connected) +
/// scale-out switch fabric.
pub fn dgx1(n_nodes: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("DGX-1[8x{n_nodes}]"),
        vec![
            Dim::new(DimKind::FullyConnected, 8, link),
            Dim::new(DimKind::Switch, n_nodes, link),
        ],
    )
}

/// DGX-2 [51]: 16 GPUs behind NVSwitch + scale-out switch fabric.
pub fn dgx2(n_nodes: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("DGX-2[16x{n_nodes}]"),
        vec![
            Dim::new(DimKind::Switch, 16, link),
            Dim::new(DimKind::Switch, n_nodes, link),
        ],
    )
}

/// 1-D ring of n chips (the §VII default 8×1 ring).
pub fn ring(n: usize, link: &LinkTech) -> Topology {
    Topology::new(&format!("ring[{n}]"), vec![Dim::new(DimKind::Ring, n, link)])
}

/// The paper's five 1024-chip DSE topologies (§VI-C) for a link tech.
pub fn dse_topologies_1024(link: &LinkTech) -> Vec<Topology> {
    vec![
        torus2d(32, 32, link),
        torus3d(16, 8, 8, link),
        dragonfly(32, 32, link),
        dgx1(128, link),
        dgx2(64, link),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::interconnect::nvlink4;

    #[test]
    fn chip_counts() {
        let l = nvlink4();
        assert_eq!(torus2d(32, 32, &l).n_chips(), 1024);
        assert_eq!(torus3d(16, 8, 8, &l).n_chips(), 1024);
        assert_eq!(dragonfly(32, 32, &l).n_chips(), 1024);
        assert_eq!(dgx1(128, &l).n_chips(), 1024);
        assert_eq!(dgx2(64, &l).n_chips(), 1024);
        for t in dse_topologies_1024(&l) {
            assert_eq!(t.n_chips(), 1024, "{}", t.name);
        }
    }

    #[test]
    fn links_ordering() {
        let l = nvlink4();
        // dragonfly (fully-connected both levels) uses far more links than
        // a 2-D torus of the same size — the Fig. 10 cost/power overhead
        let df = dragonfly(32, 32, &l).total_links();
        let t2 = torus2d(32, 32, &l).total_links();
        assert!(df > 5.0 * t2, "dragonfly {df} vs torus {t2}");
    }

    #[test]
    fn single_chip_dims() {
        let l = nvlink4();
        let t = ring(1, &l);
        assert_eq!(t.n_chips(), 1);
        assert_eq!(t.total_links(), 0.0);
    }

    #[test]
    fn dim_links_per_node() {
        let l = nvlink4();
        assert_eq!(Dim::new(DimKind::Ring, 8, &l).links_per_node(), 2.0);
        assert_eq!(Dim::new(DimKind::FullyConnected, 8, &l).links_per_node(), 7.0);
        assert_eq!(Dim::new(DimKind::Switch, 8, &l).links_per_node(), 2.0);
    }
}
