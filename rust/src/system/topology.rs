//! Interconnection-network topologies, composed hierarchically from 1-D
//! dimensions (§IV-C, the ASTRA-sim compositional approach [71]): a
//! multi-dimensional topology is a list of 1-D dims (ring, fully-connected,
//! switch); each dim is assigned to exactly one parallelization strategy.
//!
//! The paper's five evaluated topologies: 2-D torus, 3-D torus, dragonfly
//! [47], DGX-1 [2], DGX-2 [51].

use super::interconnect::LinkTech;
use crate::util::units::{BytesPerSec, Seconds};

/// The 1-D building blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DimKind {
    /// Bidirectional ring: 1 link per node per direction.
    Ring,
    /// All-pairs direct links: n−1 links per node.
    FullyConnected,
    /// Central crossbar switch: 1 uplink per node, non-blocking.
    Switch,
}

/// Physical realization of a dim inside the `fabric` link-level graph. The
/// closed-form `collective` model always keys off `kind`; the simulator
/// keys off this, so a dim can keep an analytical shortcut (DGX-1 modeled
/// as fully-connected) while the fabric expands the true wiring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DimFabric {
    /// Expand per `kind`: ring / all-pairs / star through a crossbar node.
    Kind,
    /// The DGX-1 8-GPU NVLink hybrid cube-mesh [2]: two fully-connected
    /// quads {0..3}, {4..7} plus the cube matching i↔i+4 (size must be 8).
    CubeMesh,
}

/// One network dimension: `size` chips connected by `kind` with per-link
/// bandwidth/latency from the link technology.
#[derive(Debug, Clone)]
pub struct Dim {
    pub kind: DimKind,
    pub size: usize,
    /// Per-link, per-direction bandwidth.
    pub link_bw: BytesPerSec,
    /// Per-hop latency.
    pub latency: Seconds,
    /// Link-level wiring used by the fabric simulator.
    pub fabric: DimFabric,
}

impl Dim {
    pub fn new(kind: DimKind, size: usize, link: &LinkTech) -> Self {
        assert!(size >= 1);
        Dim {
            kind,
            size,
            link_bw: link.bandwidth,
            latency: link.latency,
            fabric: DimFabric::Kind,
        }
    }

    /// One-way bisection capacity of this dim in links (multiply by
    /// `link_bw` for bytes/s): the minimum directed link count crossing a
    /// balanced split of the dim's nodes.
    pub fn bisection_links(&self) -> f64 {
        if self.size <= 1 {
            return 0.0;
        }
        if self.fabric == DimFabric::CubeMesh {
            // quad|quad split severs only the 4 matching edges
            return 4.0;
        }
        match self.kind {
            DimKind::Ring => {
                if self.size == 2 {
                    1.0
                } else {
                    2.0
                }
            }
            DimKind::FullyConnected => ((self.size / 2) * ((self.size + 1) / 2)) as f64,
            DimKind::Switch => (self.size / 2) as f64,
        }
    }

    /// Links contributed per node in this dimension (for price/power).
    pub fn links_per_node(&self) -> f64 {
        match self.kind {
            DimKind::Ring => {
                if self.size > 1 {
                    2.0
                } else {
                    0.0
                }
            }
            DimKind::FullyConnected => (self.size - 1) as f64,
            // node uplink + its share of the switch (counted as 1 extra)
            DimKind::Switch => {
                if self.size > 1 {
                    2.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// A hierarchical topology: the cartesian product of its dims.
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub dims: Vec<Dim>,
}

impl Topology {
    pub fn new(name: &str, dims: Vec<Dim>) -> Self {
        assert!(!dims.is_empty(), "topology needs at least one dim");
        Topology { name: name.into(), dims }
    }

    pub fn n_chips(&self) -> usize {
        self.dims.iter().map(|d| d.size).product()
    }

    /// Total link count (for system price/power).
    pub fn total_links(&self) -> f64 {
        let n = self.n_chips() as f64;
        // Each node contributes links_per_node per dim; each link shared by
        // two endpoints (switch uplinks count fully).
        self.dims.iter().map(|d| d.links_per_node() * n / 2.0).sum()
    }

    pub fn dim_sizes(&self) -> Vec<usize> {
        self.dims.iter().map(|d| d.size).collect()
    }

    /// One-way bisection bandwidth: the worst balanced cut runs
    /// perpendicular to one dim, crossed by that dim's bisection links in
    /// each of the `n_chips / size` parallel lines. 0 for a single chip.
    pub fn bisection_bytes_per_s(&self) -> BytesPerSec {
        let n = self.n_chips() as f64;
        let worst = self
            .dims
            .iter()
            .filter(|d| d.size > 1)
            .map(|d| d.bisection_links() * d.link_bw * n / d.size as f64)
            .fold(BytesPerSec::new(f64::INFINITY), BytesPerSec::min);
        if worst.is_finite() {
            worst
        } else {
            BytesPerSec::ZERO
        }
    }
}

/// 2-D torus: X × Y rings.
pub fn torus2d(x: usize, y: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("2D-torus[{x}x{y}]"),
        vec![Dim::new(DimKind::Ring, x, link), Dim::new(DimKind::Ring, y, link)],
    )
}

/// 3-D torus: X × Y × Z rings.
pub fn torus3d(x: usize, y: usize, z: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("3D-torus[{x}x{y}x{z}]"),
        vec![
            Dim::new(DimKind::Ring, x, link),
            Dim::new(DimKind::Ring, y, link),
            Dim::new(DimKind::Ring, z, link),
        ],
    )
}

/// Dragonfly [47]: fully-connected groups, fully-connected globally.
pub fn dragonfly(group: usize, n_groups: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("dragonfly[{group}x{n_groups}]"),
        vec![
            Dim::new(DimKind::FullyConnected, group, link),
            Dim::new(DimKind::FullyConnected, n_groups, link),
        ],
    )
}

/// DGX-1 [2]: 8-GPU NVLink hybrid-cube-mesh + scale-out switch fabric.
///
/// The closed-form `collective` model keeps the historical fully-connected
/// *shortcut* for the intra-node dim (every per-kind formula below treats
/// it as all-pairs); the dim is tagged `DimFabric::CubeMesh` so the fabric
/// simulator expands the true 16-edge hybrid cube-mesh and the `fabric`
/// figure quantifies the shortcut's optimism (~4× on large all-reduces).
pub fn dgx1(n_nodes: usize, link: &LinkTech) -> Topology {
    let mut local = Dim::new(DimKind::FullyConnected, 8, link);
    local.fabric = DimFabric::CubeMesh;
    Topology::new(
        &format!("DGX-1[8x{n_nodes}]"),
        vec![local, Dim::new(DimKind::Switch, n_nodes, link)],
    )
}

/// DGX-2 [51]: 16 GPUs behind NVSwitch + scale-out switch fabric.
pub fn dgx2(n_nodes: usize, link: &LinkTech) -> Topology {
    Topology::new(
        &format!("DGX-2[16x{n_nodes}]"),
        vec![
            Dim::new(DimKind::Switch, 16, link),
            Dim::new(DimKind::Switch, n_nodes, link),
        ],
    )
}

/// 1-D ring of n chips (the §VII default 8×1 ring).
pub fn ring(n: usize, link: &LinkTech) -> Topology {
    Topology::new(&format!("ring[{n}]"), vec![Dim::new(DimKind::Ring, n, link)])
}

/// The topology family names [`by_name`] understands.
pub const FAMILIES: &[&str] = &["ring", "torus2d", "torus3d", "dragonfly", "dgx1", "dgx2"];

/// Build a topology family by name at a total chip count, using balanced
/// factorizations (`torus2d 16` → 4×4, `torus3d 16` → 4×2×2). `None` when
/// the family name is unknown or the count does not fit it (DGX-1 needs a
/// multiple of 8, DGX-2 of 16). This is the `dfmodel fabric`/`topo` entry.
pub fn by_name(family: &str, chips: usize, link: &LinkTech) -> Option<Topology> {
    if chips == 0 {
        return None;
    }
    match family {
        "ring" => Some(ring(chips, link)),
        "torus2d" => {
            let (x, y) = factor2(chips);
            Some(torus2d(x, y, link))
        }
        "torus3d" => {
            let (x, y, z) = factor3(chips);
            Some(torus3d(x, y, z, link))
        }
        "dragonfly" => {
            let (g, n) = factor2(chips);
            Some(dragonfly(g, n, link))
        }
        "dgx1" => (chips % 8 == 0).then(|| dgx1(chips / 8, link)),
        "dgx2" => (chips % 16 == 0).then(|| dgx2(chips / 16, link)),
        _ => None,
    }
}

/// Nearest-to-square divisor pair x·y == n with x ≥ y.
fn factor2(n: usize) -> (usize, usize) {
    let mut y = (n as f64).sqrt().floor() as usize;
    y = y.max(1);
    while y > 1 && n % y != 0 {
        y -= 1;
    }
    (n / y, y)
}

/// Nearest-to-cube divisor triple x·y·z == n with x ≥ y ≥ z.
fn factor3(n: usize) -> (usize, usize, usize) {
    let mut z = (n as f64).cbrt().floor() as usize;
    z = z.max(1);
    while z > 1 && n % z != 0 {
        z -= 1;
    }
    let (x, y) = factor2(n / z);
    (x, y, z)
}

/// The paper's five 1024-chip DSE topologies (§VI-C) for a link tech.
pub fn dse_topologies_1024(link: &LinkTech) -> Vec<Topology> {
    vec![
        torus2d(32, 32, link),
        torus3d(16, 8, 8, link),
        dragonfly(32, 32, link),
        dgx1(128, link),
        dgx2(64, link),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::interconnect::nvlink4;

    #[test]
    fn chip_counts() {
        let l = nvlink4();
        assert_eq!(torus2d(32, 32, &l).n_chips(), 1024);
        assert_eq!(torus3d(16, 8, 8, &l).n_chips(), 1024);
        assert_eq!(dragonfly(32, 32, &l).n_chips(), 1024);
        assert_eq!(dgx1(128, &l).n_chips(), 1024);
        assert_eq!(dgx2(64, &l).n_chips(), 1024);
        for t in dse_topologies_1024(&l) {
            assert_eq!(t.n_chips(), 1024, "{}", t.name);
        }
    }

    #[test]
    fn links_ordering() {
        let l = nvlink4();
        // dragonfly (fully-connected both levels) uses far more links than
        // a 2-D torus of the same size — the Fig. 10 cost/power overhead
        let df = dragonfly(32, 32, &l).total_links();
        let t2 = torus2d(32, 32, &l).total_links();
        assert!(df > 5.0 * t2, "dragonfly {df} vs torus {t2}");
    }

    #[test]
    fn single_chip_dims() {
        let l = nvlink4();
        let t = ring(1, &l);
        assert_eq!(t.n_chips(), 1);
        assert_eq!(t.total_links(), 0.0);
    }

    #[test]
    fn dim_links_per_node() {
        let l = nvlink4();
        assert_eq!(Dim::new(DimKind::Ring, 8, &l).links_per_node(), 2.0);
        assert_eq!(Dim::new(DimKind::FullyConnected, 8, &l).links_per_node(), 7.0);
        assert_eq!(Dim::new(DimKind::Switch, 8, &l).links_per_node(), 2.0);
    }

    #[test]
    fn bisection_per_dim_kind() {
        let l = nvlink4();
        assert_eq!(Dim::new(DimKind::Ring, 8, &l).bisection_links(), 2.0);
        assert_eq!(Dim::new(DimKind::Ring, 2, &l).bisection_links(), 1.0);
        assert_eq!(Dim::new(DimKind::Ring, 1, &l).bisection_links(), 0.0);
        assert_eq!(Dim::new(DimKind::FullyConnected, 8, &l).bisection_links(), 16.0);
        assert_eq!(Dim::new(DimKind::FullyConnected, 5, &l).bisection_links(), 6.0);
        assert_eq!(Dim::new(DimKind::Switch, 8, &l).bisection_links(), 4.0);
        // the DGX-1 cube-mesh is cut at its 4 matching edges
        let cube = &dgx1(1, &l).dims[0];
        assert_eq!(cube.fabric, DimFabric::CubeMesh);
        assert_eq!(cube.bisection_links(), 4.0);
    }

    #[test]
    fn bisection_of_topologies() {
        let l = nvlink4();
        let bw = l.bandwidth;
        // 32×32 torus: 2 links × 32 parallel rows in the worst direction
        let t2 = torus2d(32, 32, &l);
        assert!((t2.bisection_bytes_per_s() - 64.0 * bw).abs().raw() < 1e-3);
        // a single chip has no bisection
        assert_eq!(ring(1, &l).bisection_bytes_per_s().raw(), 0.0);
        // dragonfly's all-pairs global dim dwarfs the torus cut
        assert!(dragonfly(32, 32, &l).bisection_bytes_per_s() > t2.bisection_bytes_per_s());
        // DGX-1: intra-node cube-mesh cut = 4·bw × (n/8) lines
        let d1 = dgx1(128, &l);
        assert!((d1.bisection_bytes_per_s() - 4.0 * bw * 128.0).abs().raw() < 1e-3);
    }

    #[test]
    fn by_name_families() {
        let l = nvlink4();
        let cases = [
            ("ring", 7),
            ("torus2d", 16),
            ("torus3d", 16),
            ("dragonfly", 12),
            ("dgx1", 64),
            ("dgx2", 64),
        ];
        for (fam, chips) in cases {
            let t = by_name(fam, chips, &l).expect(fam);
            assert_eq!(t.n_chips(), chips, "{fam}");
        }
        assert_eq!(by_name("torus2d", 16, &l).unwrap().dim_sizes(), vec![4, 4]);
        assert_eq!(by_name("torus3d", 16, &l).unwrap().dim_sizes(), vec![4, 2, 2]);
        assert!(by_name("dgx1", 12, &l).is_none());
        assert!(by_name("nope", 8, &l).is_none());
        assert!(by_name("ring", 0, &l).is_none());
    }
}
