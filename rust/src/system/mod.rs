//! System specification (§III, Fig. 5 right side): accelerator chips,
//! memory technologies, interconnect technologies, and interconnection
//! network topologies, hierarchically composed (ASTRA-sim style, §IV-C).

pub mod chip;
pub mod costpower;
pub mod interconnect;
pub mod memory;
pub mod topology;

pub use chip::{ChipSpec, ExecutionModel};
pub use interconnect::LinkTech;
pub use memory::MemoryTech;
pub use topology::{Dim, DimFabric, DimKind, Topology};

use crate::collective::CollectiveModel;
use crate::util::units::{BytesPerSec, Dollars, FlopPerSec, Watts};

/// A complete system design point: `n_chips` accelerators of one kind, each
/// with one memory technology, connected by one link technology arranged in
/// one topology.
#[derive(Debug, Clone)]
pub struct SystemSpec {
    pub chip: ChipSpec,
    pub memory: MemoryTech,
    pub link: LinkTech,
    pub topology: Topology,
    /// Collective-cost model the optimizer passes consult: analytical by
    /// default; `fabric::select::calibrate_system` swaps in a
    /// simulation-calibrated one.
    pub collective_model: CollectiveModel,
}

impl SystemSpec {
    pub fn new(chip: ChipSpec, memory: MemoryTech, link: LinkTech, topology: Topology) -> Self {
        let s = SystemSpec {
            chip,
            memory,
            link,
            topology,
            collective_model: CollectiveModel::Analytical,
        };
        s.validate();
        s
    }

    /// Same system with a different collective-cost model.
    pub fn with_collective_model(mut self, model: CollectiveModel) -> Self {
        self.collective_model = model;
        self
    }

    pub fn n_chips(&self) -> usize {
        self.topology.n_chips()
    }

    fn validate(&self) {
        assert!(self.n_chips() >= 1, "empty topology");
        assert!(self.chip.compute_flops() > FlopPerSec::ZERO);
        assert!(self.memory.bandwidth > BytesPerSec::ZERO);
        assert!(self.link.bandwidth > BytesPerSec::ZERO);
    }

    /// Aggregate peak compute of the whole system.
    pub fn peak_flops(&self) -> FlopPerSec {
        self.chip.compute_flops() * self.n_chips() as f64
    }

    /// Total system price (chips + memory + links), for cost-efficiency
    /// heat maps (Figs 10/12/14/16).
    pub fn price_usd(&self) -> Dollars {
        let chips = self.chip.price_usd * self.n_chips() as f64;
        let mem = self.memory.price_usd() * self.n_chips() as f64;
        let links = self.link.price_usd * self.topology.total_links() as f64;
        chips + mem + links
    }

    /// Total system power.
    pub fn power_w(&self) -> Watts {
        let chips = self.chip.power_w * self.n_chips() as f64;
        let mem = self.memory.power_w() * self.n_chips() as f64;
        let links = self.link.power_w * self.topology.total_links() as f64;
        chips + mem + links
    }

    pub fn describe(&self) -> String {
        format!(
            "{} x{} | {} | {} | {}",
            self.chip.name,
            self.n_chips(),
            self.memory.name,
            self.link.name,
            self.topology.name
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SystemSpec {
        SystemSpec::new(
            chip::h100(),
            memory::hbm3(),
            interconnect::nvlink4(),
            topology::torus2d(4, 2, &interconnect::nvlink4()),
        )
    }

    #[test]
    fn aggregates() {
        let s = spec();
        assert_eq!(s.n_chips(), 8);
        assert!((s.peak_flops().raw() - 8.0 * 993e12).abs() / s.peak_flops().raw() < 1e-12);
        assert!(s.price_usd() > 8.0 * s.chip.price_usd * 0.99);
        assert!(s.power_w() > 8.0 * s.chip.power_w * 0.99);
    }

    #[test]
    fn describe_mentions_parts() {
        let d = spec().describe();
        assert!(d.contains("H100") && d.contains("HBM3") && d.contains("NVLink4"));
    }
}
