//! Off-chip memory technology catalog (§VI-C and the 3-D study §VIII-C).
//!
//! DFModel's memory model needs per-chip bandwidth (`d_bw`) and capacity
//! (`d_cap`); price/power are per-GB figures from [39], [43] used for the
//! efficiency heat maps.

use crate::util::units::{Bytes, BytesPerSec, Dollars, Watts, GB, TB};

#[derive(Debug, Clone)]
pub struct MemoryTech {
    pub name: String,
    /// Per-chip bandwidth (`d_bw`).
    pub bandwidth: BytesPerSec,
    /// Per-chip capacity (`d_cap`).
    pub capacity: Bytes,
    /// $/GB (from [39], [43]) — a per-GB *rate*, not a plain dollar
    /// quantity, so it stays a raw `f64`.
    pub price_per_gb: f64,
    /// W/GB active power (per-GB rate; raw `f64` like `price_per_gb`).
    pub power_per_gb: f64,
}

impl MemoryTech {
    pub fn price_usd(&self) -> Dollars {
        Dollars::new(self.capacity.raw() / GB * self.price_per_gb)
    }

    pub fn power_w(&self) -> Watts {
        Watts::new(self.capacity.raw() / GB * self.power_per_gb)
    }
}

/// DDR4 (the paper: 200 GB/s [1]); large capacity, cheap per GB.
pub fn ddr4() -> MemoryTech {
    MemoryTech {
        name: "DDR4".into(),
        bandwidth: BytesPerSec::new(200.0 * GB),
        capacity: Bytes::new(1.0 * TB),
        price_per_gb: 4.0,
        power_per_gb: 0.35,
    }
}

/// HBM3 (the paper: 3000 GB/s [39]); small capacity, expensive per GB.
pub fn hbm3() -> MemoryTech {
    MemoryTech {
        name: "HBM3".into(),
        bandwidth: BytesPerSec::new(3000.0 * GB),
        capacity: Bytes::new(96.0 * GB),
        price_per_gb: 15.0,
        power_per_gb: 3.5,
    }
}

/// SN40L's fast device-memory tier (§VIII-A): 1.6 TB/s, 64 GB per chip.
/// Single source for both the serving platform (`serving::sn40l_x16`) and
/// the cluster planner's catalog, so the two layers cannot drift.
pub fn sn40l_hbm() -> MemoryTech {
    MemoryTech {
        name: "HBM-SN40L".into(),
        bandwidth: BytesPerSec::new(1.6 * TB),
        capacity: Bytes::new(64.0 * GB),
        price_per_gb: 15.0,
        power_per_gb: 3.5,
    }
}

// ---- §VIII-C 3-D memory study (SN40L with three memory generations) ----

/// 2-D DDR: 100 GB/s.
pub fn mem2d_ddr() -> MemoryTech {
    MemoryTech {
        name: "2D-DDR".into(),
        bandwidth: BytesPerSec::new(100.0 * GB),
        capacity: Bytes::new(1.0 * TB),
        price_per_gb: 4.0,
        power_per_gb: 0.35,
    }
}

/// 2.5-D HBM on interposer: 1 TB/s (bandwidth ∝ die perimeter).
pub fn mem25d_hbm() -> MemoryTech {
    MemoryTech {
        name: "2.5D-HBM".into(),
        bandwidth: BytesPerSec::new(1.0 * TB),
        capacity: Bytes::new(96.0 * GB),
        price_per_gb: 15.0,
        power_per_gb: 3.0,
    }
}

/// 3-D stacked memory: 100 TB/s (bandwidth ∝ die area, [22]).
pub fn mem3d_stacked() -> MemoryTech {
    MemoryTech {
        name: "3D-stacked".into(),
        bandwidth: BytesPerSec::new(100.0 * TB),
        capacity: Bytes::new(48.0 * GB),
        price_per_gb: 40.0,
        power_per_gb: 6.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_ladder() {
        assert!(ddr4().bandwidth < hbm3().bandwidth);
        assert!(mem2d_ddr().bandwidth < mem25d_hbm().bandwidth);
        assert!(mem25d_hbm().bandwidth < mem3d_stacked().bandwidth);
        assert_eq!(hbm3().bandwidth.raw(), 3000.0 * GB);
        assert_eq!(mem3d_stacked().bandwidth.raw(), 100.0 * TB);
    }

    #[test]
    fn price_power_aggregation() {
        let m = hbm3();
        assert!((m.price_usd().raw() - 96.0 * 15.0).abs() < 1e-6);
        assert!((m.power_w().raw() - 96.0 * 3.5).abs() < 1e-6);
    }
}
