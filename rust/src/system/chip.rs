//! Accelerator chip catalog (Table V plus the §VII/§VIII SambaNova parts).
//!
//! `tiles` × `tflop_per_tile` reproduces the paper's `t_lim` × `t_flop`
//! compute model (§IV-B.1). Power/price are the values the paper collects
//! from vendor disclosures [6], [10], [39], [42], [69]; where a number is
//! not public we use a documented estimate consistent with the paper's
//! efficiency ratios (Fig. 9's superlinear trend).

use crate::util::units::{Bytes, Dollars, FlopPerSec, Watts, GB, MB, TFLOPS};

/// Intra-chip execution style (§II-B): dataflow chips may fuse multiple
/// kernels into a spatial pipeline; kernel-by-kernel chips may not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionModel {
    Dataflow,
    KernelByKernel,
}

/// One accelerator chip.
#[derive(Debug, Clone)]
pub struct ChipSpec {
    pub name: String,
    /// Compute tiles (`t_lim`): SMs / MXUs / PCUs / WSE cores.
    pub tiles: usize,
    /// Peak FLOP/s per tile (`t_flop`), half precision.
    pub tflop_per_tile: FlopPerSec,
    /// On-chip SRAM capacity (`s_cap`).
    pub sram_bytes: Bytes,
    pub execution: ExecutionModel,
    pub power_w: Watts,
    pub price_usd: Dollars,
}

impl ChipSpec {
    /// Peak chip compute (`t_lim` × `t_flop`).
    pub fn compute_flops(&self) -> FlopPerSec {
        self.tiles as f64 * self.tflop_per_tile
    }
}

/// NVIDIA H100 GPU: 993 TFLOPS, 113 MB SRAM (Table V); 132 SMs.
pub fn h100() -> ChipSpec {
    ChipSpec {
        name: "H100".into(),
        tiles: 132,
        tflop_per_tile: FlopPerSec::new(993.0 * TFLOPS / 132.0),
        sram_bytes: Bytes::new(113.0 * MB),
        execution: ExecutionModel::KernelByKernel,
        power_w: Watts::new(700.0),
        price_usd: Dollars::new(30_000.0),
    }
}

/// Google TPU v4: 275 TFLOPS, 160 MB SRAM (Table V); 8 MXU groups.
pub fn tpu_v4() -> ChipSpec {
    ChipSpec {
        name: "TPUv4".into(),
        tiles: 8,
        tflop_per_tile: FlopPerSec::new(275.0 * TFLOPS / 8.0),
        sram_bytes: Bytes::new(160.0 * MB),
        execution: ExecutionModel::KernelByKernel,
        power_w: Watts::new(192.0),
        price_usd: Dollars::new(9_000.0),
    }
}

/// SambaNova SN30 RDU: 614 TFLOPS, 640 MB SRAM (Table V); 1280 PCUs.
pub fn sn30() -> ChipSpec {
    ChipSpec {
        name: "SN30".into(),
        tiles: 1280,
        tflop_per_tile: FlopPerSec::new(614.0 * TFLOPS / 1280.0),
        sram_bytes: Bytes::new(640.0 * MB),
        execution: ExecutionModel::Dataflow,
        power_w: Watts::new(450.0),
        price_usd: Dollars::new(25_000.0),
    }
}

/// Cerebras WSE-2: 7500 TFLOPS, 40 GB SRAM (Table V); 850k cores.
pub fn wse2() -> ChipSpec {
    ChipSpec {
        name: "WSE-2".into(),
        tiles: 850_000,
        tflop_per_tile: FlopPerSec::new(7500.0 * TFLOPS / 850_000.0),
        sram_bytes: Bytes::new(40.0 * GB),
        execution: ExecutionModel::Dataflow,
        power_w: Watts::new(15_000.0),
        price_usd: Dollars::new(2_500_000.0),
    }
}

/// SambaNova SN10 RDU (§VII): 307.2 TFLOPS bf16, 320 MB SRAM; 640 PCUs.
pub fn sn10() -> ChipSpec {
    ChipSpec {
        name: "SN10".into(),
        tiles: 640,
        tflop_per_tile: FlopPerSec::new(307.2 * TFLOPS / 640.0),
        sram_bytes: Bytes::new(320.0 * MB),
        execution: ExecutionModel::Dataflow,
        power_w: Watts::new(300.0),
        price_usd: Dollars::new(18_000.0),
    }
}

/// SambaNova SN40L RDU (§VIII): 640 TFLOPS bf16, 520 MB SRAM; 1040 PCUs.
pub fn sn40l() -> ChipSpec {
    ChipSpec {
        name: "SN40L".into(),
        tiles: 1040,
        tflop_per_tile: FlopPerSec::new(640.0 * TFLOPS / 1040.0),
        sram_bytes: Bytes::new(520.0 * MB),
        execution: ExecutionModel::Dataflow,
        power_w: Watts::new(500.0),
        price_usd: Dollars::new(28_000.0),
    }
}

/// NVIDIA A100 GPU (Figs 6/8 validation): 312 TFLOPS bf16, 40 MB L2+smem.
pub fn a100() -> ChipSpec {
    ChipSpec {
        name: "A100".into(),
        tiles: 108,
        tflop_per_tile: FlopPerSec::new(312.0 * TFLOPS / 108.0),
        sram_bytes: Bytes::new(40.0 * MB),
        execution: ExecutionModel::KernelByKernel,
        power_w: Watts::new(400.0),
        price_usd: Dollars::new(15_000.0),
    }
}

/// The four Table V chips in paper order.
pub fn table_v() -> Vec<ChipSpec> {
    vec![h100(), tpu_v4(), sn30(), wse2()]
}

/// A parameterized "generic accelerator" for the Fig. 19 and Fig. 22
/// sweeps (compute throughput and SRAM as free variables).
pub fn custom(
    name: &str,
    compute_flops: f64,
    sram_bytes: f64,
    execution: ExecutionModel,
) -> ChipSpec {
    let tiles = 1024;
    ChipSpec {
        name: name.into(),
        tiles,
        tflop_per_tile: FlopPerSec::new(compute_flops / tiles as f64),
        sram_bytes: Bytes::new(sram_bytes),
        execution,
        power_w: Watts::new(costpower_estimate_w(compute_flops)),
        price_usd: Dollars::new(costpower_estimate_usd(compute_flops)),
    }
}

/// Fig. 9 regression (power in kW as a function of TFLOPS):
/// Y = 3e-7·X² − 4.3e-4·X + 0.04, clamped to a small floor.
pub fn costpower_estimate_w(compute_flops: f64) -> f64 {
    let x = compute_flops / TFLOPS;
    let kw = 3e-7 * x * x - 4.3e-4 * x + 0.04;
    (kw * 1000.0).max(50.0)
}

/// Price follows the same superlinear trend (§VI-C); scale anchored so a
/// ~1 PFLOPS chip lands near $30k.
pub fn costpower_estimate_usd(compute_flops: f64) -> f64 {
    costpower_estimate_w(compute_flops) * 45.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_v_matches_paper() {
        let chips = table_v();
        let specs: Vec<(f64, f64)> =
            chips.iter().map(|c| (c.compute_flops().raw() / TFLOPS, c.sram_bytes.raw())).collect();
        assert!((specs[0].0 - 993.0).abs() < 0.5);
        assert!((specs[0].1 - 113.0 * MB).abs() < 1.0);
        assert!((specs[1].0 - 275.0).abs() < 0.5);
        assert!((specs[1].1 - 160.0 * MB).abs() < 1.0);
        assert!((specs[2].0 - 614.0).abs() < 0.5);
        assert!((specs[2].1 - 640.0 * MB).abs() < 1.0);
        assert!((specs[3].0 - 7500.0).abs() < 0.5);
        assert!((specs[3].1 - 40.0 * GB).abs() < 1.0);
    }

    #[test]
    fn execution_models() {
        assert_eq!(h100().execution, ExecutionModel::KernelByKernel);
        assert_eq!(tpu_v4().execution, ExecutionModel::KernelByKernel);
        assert_eq!(sn30().execution, ExecutionModel::Dataflow);
        assert_eq!(wse2().execution, ExecutionModel::Dataflow);
    }

    #[test]
    fn sn10_matches_section_vii() {
        let c = sn10();
        assert!((c.compute_flops().raw() - 307.2 * TFLOPS).abs() / TFLOPS < 0.1);
        assert!((c.sram_bytes.raw() - 320.0 * MB).abs() < 1.0);
    }

    #[test]
    fn power_regression_superlinear() {
        // doubling throughput should more than double power at the high end
        let p1 = costpower_estimate_w(3000.0 * TFLOPS);
        let p2 = costpower_estimate_w(6000.0 * TFLOPS);
        assert!(p2 > 2.0 * p1);
        // WSE-scale lands in the tens of kW
        let wse = costpower_estimate_w(7500.0 * TFLOPS);
        assert!(wse > 10_000.0 && wse < 25_000.0, "wse power = {wse}");
    }

    #[test]
    fn custom_chip() {
        let c = custom("X", 300.0 * TFLOPS, 300.0 * MB, ExecutionModel::Dataflow);
        assert!((c.compute_flops().raw() - 300.0 * TFLOPS).abs() < 1.0);
        assert!(c.power_w >= Watts::new(50.0));
    }
}
