//! Interconnect (link) technology catalog (§VI-C): PCIe Gen4 and NVLink4,
//! with price/power per link from [11], [82].

use crate::util::units::{BytesPerSec, Dollars, Seconds, Watts, GB, NS};

#[derive(Debug, Clone)]
pub struct LinkTech {
    pub name: String,
    /// Per-link, per-direction bandwidth (`n_bw` per dim link).
    pub bandwidth: BytesPerSec,
    /// Per-hop latency.
    pub latency: Seconds,
    /// Price per link.
    pub price_usd: Dollars,
    /// Power per link.
    pub power_w: Watts,
}

/// PCIe Gen 4 x16: 25 GB/s [1].
pub fn pcie4() -> LinkTech {
    LinkTech {
        name: "PCIe4".into(),
        bandwidth: BytesPerSec::new(25.0 * GB),
        latency: Seconds::new(500.0 * NS),
        price_usd: Dollars::new(100.0),
        power_w: Watts::new(8.0),
    }
}

/// NVLink 4: 900 GB/s [12].
pub fn nvlink4() -> LinkTech {
    LinkTech {
        name: "NVLink4".into(),
        bandwidth: BytesPerSec::new(900.0 * GB),
        latency: Seconds::new(150.0 * NS),
        price_usd: Dollars::new(600.0),
        power_w: Watts::new(25.0),
    }
}

/// The §VIII-A SN40L fabric: 25 GB/s, 150 ns.
pub fn rdu_fabric() -> LinkTech {
    LinkTech {
        name: "RDU-fabric".into(),
        bandwidth: BytesPerSec::new(25.0 * GB),
        latency: Seconds::new(150.0 * NS),
        price_usd: Dollars::new(120.0),
        power_w: Watts::new(8.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_values() {
        assert_eq!(pcie4().bandwidth.raw(), 25.0 * GB);
        assert_eq!(nvlink4().bandwidth.raw(), 900.0 * GB);
        assert!(nvlink4().latency < pcie4().latency);
        assert!(nvlink4().price_usd > pcie4().price_usd);
    }
}
