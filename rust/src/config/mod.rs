//! JSON experiment configuration — **deprecated shim** over the
//! [`crate::api`] facade.
//!
//! `Experiment` predates [`crate::api::Scenario`] and is kept only so
//! `dfmodel run --config exp.json` and existing config files keep working:
//! parsing delegates to `Scenario::parse` (the legacy
//! `workload`/`system`/`options` schema is a subset of the scenario
//! schema), and `run()` delegates to `Scenario::evaluate`, reshaped into
//! the legacy flat result object. New code should use the facade directly:
//!
//! ```json
//! {
//!   "workload": {"kind": "gpt", "model": "gpt3-175b", "batch": 64},
//!   "system": {
//!     "chip": "sn10", "memory": "ddr4", "link": "pcie4",
//!     "topology": {"kind": "ring", "dims": [8]}
//!   },
//!   "options": {"force_tp": 8, "force_pp": 1, "force_dp": 1,
//!                "state_bytes_per_weight_byte": 8.0}
//! }
//! ```

use crate::api::scenario::BuiltWorkload;
use crate::api::{Goal, Scenario};
use crate::ensure;
use crate::graph::gpt;
use crate::graph::DataflowGraph;
use crate::interchip::InterChipOptions;
use crate::system::SystemSpec;
use crate::util::error::Result;
use crate::util::json::Json;

/// A parsed experiment specification (legacy view of a [`Scenario`]).
///
/// `workload`/`system`/`options` are a **read-only resolved view** for
/// inspection; `run()` evaluates `scenario`, so mutate that (or use the
/// facade builder) to change what runs.
#[derive(Debug, Clone)]
pub struct Experiment {
    /// The facade scenario this experiment shims over.
    pub scenario: Scenario,
    pub workload: WorkloadSpec,
    pub system: SystemSpec,
    pub options: InterChipOptions,
}

#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    /// LLM training: model config + global batch.
    Gpt { cfg: gpt::GptConfig, batch: f64 },
    /// Single/multi-pass graphs.
    Graph { graph: DataflowGraph, passes: f64, max_dp: usize },
}

impl Experiment {
    pub fn parse(text: &str) -> Result<Experiment> {
        Experiment::from_scenario(Scenario::parse(text)?)
    }

    pub fn load(path: &std::path::Path) -> Result<Experiment> {
        Experiment::from_scenario(Scenario::load(path)?)
    }

    /// Build the legacy view (resolved workload/system/options) of a
    /// `Map`-goal scenario.
    pub fn from_scenario(scenario: Scenario) -> Result<Experiment> {
        ensure!(
            scenario.goal == Goal::Map,
            "the legacy config shim only drives map-goal scenarios; use \
             `--scenario` on the '{}' subcommand instead",
            scenario.goal.name()
        );
        // builder-constructed scenarios may not have been validated yet;
        // checking here keeps run()'s feasible:false path for genuine
        // infeasibility only (config errors stay errors)
        scenario.check()?;
        let workload = match scenario.workload.build(&scenario.knobs)? {
            BuiltWorkload::Gpt { cfg, batch } => WorkloadSpec::Gpt { cfg, batch },
            BuiltWorkload::Graph { graph, passes, max_dp } => {
                WorkloadSpec::Graph { graph, passes, max_dp }
            }
        };
        let system = scenario.system.build()?;
        let options = scenario.knobs.interchip_options();
        Ok(Experiment { scenario, workload, system, options })
    }

    /// Run the experiment and return a machine-readable result object (the
    /// legacy flat shape; `Scenario::evaluate` + `Report::to_json` is the
    /// richer replacement).
    pub fn run(&self) -> Result<Json> {
        let report = match self.scenario.evaluate() {
            Ok(r) => r,
            // an infeasible mapping keeps the legacy feasible:false shape;
            // any other failure (e.g. a name mutated to garbage after
            // parsing) stays an error instead of masquerading as infeasible
            Err(e) if e.to_string().starts_with("no feasible mapping") => {
                return Ok(Json::obj(vec![("feasible", Json::Bool(false))]));
            }
            Err(e) => return Err(e),
        };
        let (tp, pp, dp) = report.degrees().unwrap_or((1, 1, 1));
        let perf = report.perf.as_ref().expect("map goal fills perf");
        let (c, m, n) = perf.breakdown;
        Ok(Json::obj(vec![
            ("feasible", Json::Bool(true)),
            ("system", Json::from(report.system.clone())),
            ("tp", Json::from(tp)),
            ("pp", Json::from(pp)),
            ("dp", Json::from(dp)),
            ("step_time_s", Json::from(perf.step_time)),
            ("utilization", Json::from(perf.utilization)),
            ("achieved_flops", Json::from(perf.achieved_flops)),
            (
                "breakdown",
                Json::obj(vec![
                    ("compute", Json::from(c)),
                    ("memory", Json::from(m)),
                    ("network", Json::from(n)),
                ]),
            ),
            ("price_usd", Json::from(self.system.price_usd().raw())),
            ("power_w", Json::from(self.system.power_w().raw())),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "workload": {"kind": "gpt", "model": "gpt3-175b", "batch": 64},
      "system": {"chip": "sn10", "memory": "ddr4", "link": "pcie4",
                 "topology": {"kind": "ring", "dims": [8]}},
      "options": {"force_tp": 8, "force_pp": 1, "force_dp": 1}
    }"#;

    #[test]
    fn parses_and_runs_sample() {
        let e = Experiment::parse(SAMPLE).unwrap();
        assert_eq!(e.system.n_chips(), 8);
        assert_eq!(e.options.force_degrees, Some((8, 1, 1)));
        let r = e.run().unwrap();
        assert_eq!(r.get("feasible"), Some(&Json::Bool(true)));
        assert_eq!(r.get("tp").unwrap().as_usize(), Some(8));
        assert!(r.get("utilization").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn defaults_fill_in() {
        let e = Experiment::parse("{}").unwrap();
        assert_eq!(e.system.n_chips(), 8);
        matches!(e.workload, WorkloadSpec::Gpt { .. });
    }

    #[test]
    fn topology_variants_parse() {
        for (k, d, n) in [
            ("torus2d", "[4, 2]", 8),
            ("torus3d", "[2, 2, 2]", 8),
            ("dragonfly", "[4, 4]", 16),
            ("dgx1", "[4]", 32),
            ("dgx2", "[2]", 32),
        ] {
            let cfg = format!(
                r#"{{"system": {{"topology": {{"kind": "{k}", "dims": {d}}}}}}}"#
            );
            let e = Experiment::parse(&cfg).unwrap();
            assert_eq!(e.system.n_chips(), n, "{k}");
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(Experiment::parse(r#"{"system": {"chip": "zz80"}}"#).is_err());
        assert!(Experiment::parse(r#"{"workload": {"kind": "prolog"}}"#).is_err());
        assert!(
            Experiment::parse(r#"{"options": {"force_tp": 8}}"#).is_err(),
            "partial force degrees must be rejected"
        );
        assert!(Experiment::parse("not json").is_err());
    }

    #[test]
    fn non_gpt_workloads_parse() {
        for kind in ["dlrm", "hpl", "fft", "moe"] {
            let cfg = format!(r#"{{"workload": {{"kind": "{kind}"}}}}"#);
            let e = Experiment::parse(&cfg).unwrap();
            matches!(e.workload, WorkloadSpec::Graph { .. });
        }
    }

    #[test]
    fn infeasible_run_reports_cleanly() {
        let cfg = r#"{
          "workload": {"kind": "gpt", "model": "gpt-100t"},
          "system": {"chip": "sn10", "topology": {"kind": "ring", "dims": [2]}}
        }"#;
        let e = Experiment::parse(cfg).unwrap();
        let r = e.run().unwrap();
        assert_eq!(r.get("feasible"), Some(&Json::Bool(false)));
    }

    #[test]
    fn shim_run_matches_facade_report() {
        let e = Experiment::parse(SAMPLE).unwrap();
        let legacy = e.run().unwrap();
        let report = e.scenario.evaluate().unwrap();
        assert_eq!(
            legacy.get("utilization").unwrap().as_f64(),
            report.utilization(),
            "shim and facade must agree"
        );
    }
}
