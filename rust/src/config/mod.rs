//! JSON experiment configuration: declarative (workload, system, options)
//! specs so sweeps and one-off studies are launchable without recompiling —
//! `dfmodel run --config exp.json`.
//!
//! Schema (all sections optional where a default exists):
//! ```json
//! {
//!   "workload": {"kind": "gpt", "model": "gpt3-175b", "batch": 64},
//!   "system": {
//!     "chip": "sn10", "memory": "ddr4", "link": "pcie4",
//!     "topology": {"kind": "ring", "dims": [8]}
//!   },
//!   "options": {"force_tp": 8, "force_pp": 1, "force_dp": 1,
//!                "state_bytes_per_weight_byte": 8.0}
//! }
//! ```

use crate::graph::{dlrm, fft, gpt, hpl, DataflowGraph};
use crate::interchip::InterChipOptions;
use crate::system::{chip, interconnect, memory, topology, ChipSpec, SystemSpec};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::{bail, err};

/// A parsed experiment specification.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub workload: WorkloadSpec,
    pub system: SystemSpec,
    pub options: InterChipOptions,
}

#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    /// LLM training: model config + global batch.
    Gpt { cfg: gpt::GptConfig, batch: f64 },
    /// Single/multi-pass graphs.
    Graph { graph: DataflowGraph, passes: f64, max_dp: usize },
}

impl Experiment {
    pub fn parse(text: &str) -> Result<Experiment> {
        let j = Json::parse(text).map_err(|e| err!("config: {e}"))?;
        let workload = parse_workload(j.get("workload").unwrap_or(&Json::Null))?;
        let system = parse_system(j.get("system").unwrap_or(&Json::Null))?;
        let options = parse_options(j.get("options").unwrap_or(&Json::Null))?;
        Ok(Experiment { workload, system, options })
    }

    pub fn load(path: &std::path::Path) -> Result<Experiment> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err!("read {}: {e}", path.display()))?;
        Experiment::parse(&text)
    }

    /// Run the experiment and return a machine-readable result object.
    pub fn run(&self) -> Result<Json> {
        let result = match &self.workload {
            WorkloadSpec::Gpt { cfg, batch } => {
                crate::pipeline::llm_training_opts(cfg, &self.system, *batch, &self.options)
            }
            WorkloadSpec::Graph { graph, passes, max_dp } => {
                crate::pipeline::workload_pass(graph, &self.system, *passes, *max_dp)
            }
        };
        let Some(r) = result else {
            return Ok(Json::obj(vec![("feasible", Json::Bool(false))]));
        };
        let (c, m, n) = r.breakdown_frac();
        Ok(Json::obj(vec![
            ("feasible", Json::Bool(true)),
            ("system", Json::from(self.system.describe())),
            ("tp", Json::from(r.tp)),
            ("pp", Json::from(r.pp)),
            ("dp", Json::from(r.dp)),
            ("step_time_s", Json::from(r.step_time)),
            ("utilization", Json::from(r.utilization)),
            ("achieved_flops", Json::from(r.achieved_flops)),
            (
                "breakdown",
                Json::obj(vec![
                    ("compute", Json::from(c)),
                    ("memory", Json::from(m)),
                    ("network", Json::from(n)),
                ]),
            ),
            ("price_usd", Json::from(self.system.price_usd())),
            ("power_w", Json::from(self.system.power_w())),
        ]))
    }
}

fn parse_workload(j: &Json) -> Result<WorkloadSpec> {
    let kind = j.get("kind").and_then(|v| v.as_str()).unwrap_or("gpt");
    match kind {
        "gpt" => {
            let model = j.get("model").and_then(|v| v.as_str()).unwrap_or("gpt3-175b");
            let cfg = match model {
                "gpt3-175b" => gpt::gpt3_175b(),
                "gpt3-1t" => gpt::gpt3_1t(),
                "gpt-100t" => gpt::gpt_100t(),
                "custom" => gpt::GptConfig {
                    layers: j.get("layers").and_then(|v| v.as_usize()).unwrap_or(96),
                    d_model: j.get("d_model").and_then(|v| v.as_f64()).unwrap_or(12288.0),
                    n_heads: j.get("n_heads").and_then(|v| v.as_f64()).unwrap_or(96.0),
                    seq: j.get("seq").and_then(|v| v.as_f64()).unwrap_or(2048.0),
                    d_ff: j.get("d_ff").and_then(|v| v.as_f64()).unwrap_or(4.0 * 12288.0),
                    vocab: j.get("vocab").and_then(|v| v.as_f64()).unwrap_or(50257.0),
                    dtype_bytes: j.get("dtype_bytes").and_then(|v| v.as_f64()).unwrap_or(2.0),
                },
                other => bail!("unknown gpt model '{other}'"),
            };
            let batch = j.get("batch").and_then(|v| v.as_f64()).unwrap_or(64.0);
            Ok(WorkloadSpec::Gpt { cfg, batch })
        }
        "dlrm" => {
            let batch = j.get("batch").and_then(|v| v.as_f64()).unwrap_or(65_536.0);
            Ok(WorkloadSpec::Graph {
                graph: dlrm::dlrm_graph(&dlrm::dlrm_793b(), batch),
                passes: 3.0,
                max_dp: j.get("max_dp").and_then(|v| v.as_usize()).unwrap_or(64),
            })
        }
        "hpl" => Ok(WorkloadSpec::Graph {
            graph: hpl::hpl_graph(&hpl::hpl_5m()),
            passes: 1.0,
            max_dp: 1,
        }),
        "fft" => Ok(WorkloadSpec::Graph {
            graph: fft::fft_graph(&fft::fft_1t()),
            passes: 1.0,
            max_dp: 1,
        }),
        "moe" => {
            let cfg = crate::graph::moe::moe_gpt_1t();
            let batch = j.get("batch").and_then(|v| v.as_f64()).unwrap_or(1.0);
            Ok(WorkloadSpec::Graph {
                graph: crate::graph::moe::moe_layer_graph(&cfg, batch),
                passes: 3.0,
                max_dp: j.get("max_dp").and_then(|v| v.as_usize()).unwrap_or(64),
            })
        }
        other => bail!("unknown workload kind '{other}'"),
    }
}

fn parse_chip(name: &str) -> Result<ChipSpec> {
    Ok(match name {
        "h100" => chip::h100(),
        "a100" => chip::a100(),
        "tpuv4" => chip::tpu_v4(),
        "sn10" => chip::sn10(),
        "sn30" => chip::sn30(),
        "sn40l" => chip::sn40l(),
        "wse2" => chip::wse2(),
        other => bail!("unknown chip '{other}'"),
    })
}

fn parse_system(j: &Json) -> Result<SystemSpec> {
    let c = parse_chip(j.get("chip").and_then(|v| v.as_str()).unwrap_or("sn10"))?;
    let mem = match j.get("memory").and_then(|v| v.as_str()).unwrap_or("ddr4") {
        "ddr4" => memory::ddr4(),
        "hbm3" => memory::hbm3(),
        "2d-ddr" => memory::mem2d_ddr(),
        "2.5d-hbm" => memory::mem25d_hbm(),
        "3d-stacked" => memory::mem3d_stacked(),
        other => bail!("unknown memory '{other}'"),
    };
    let link = match j.get("link").and_then(|v| v.as_str()).unwrap_or("pcie4") {
        "pcie4" => interconnect::pcie4(),
        "nvlink4" => interconnect::nvlink4(),
        "rdu" => interconnect::rdu_fabric(),
        other => bail!("unknown link '{other}'"),
    };
    let t = j.get("topology").unwrap_or(&Json::Null);
    let kind = t.get("kind").and_then(|v| v.as_str()).unwrap_or("ring");
    let dims: Vec<usize> = t
        .get("dims")
        .and_then(|v| v.as_array())
        .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
        .unwrap_or_else(|| vec![8]);
    let topo = match (kind, dims.as_slice()) {
        ("ring", [n]) => topology::ring(*n, &link),
        ("torus2d", [x, y]) => topology::torus2d(*x, *y, &link),
        ("torus3d", [x, y, z]) => topology::torus3d(*x, *y, *z, &link),
        ("dragonfly", [g, n]) => topology::dragonfly(*g, *n, &link),
        ("dgx1", [n]) => topology::dgx1(*n, &link),
        ("dgx2", [n]) => topology::dgx2(*n, &link),
        (k, d) => bail!("bad topology {k} with dims {d:?}"),
    };
    Ok(SystemSpec::new(c, mem, link, topo))
}

fn parse_options(j: &Json) -> Result<InterChipOptions> {
    let mut o = InterChipOptions::default();
    if let Some(v) = j.get("state_bytes_per_weight_byte").and_then(|v| v.as_f64()) {
        o.state_bytes_per_weight_byte = v;
    }
    let tp = j.get("force_tp").and_then(|v| v.as_usize());
    let pp = j.get("force_pp").and_then(|v| v.as_usize());
    let dp = j.get("force_dp").and_then(|v| v.as_usize());
    if let (Some(tp), Some(pp), Some(dp)) = (tp, pp, dp) {
        o.force_degrees = Some((tp, pp, dp));
    } else if tp.is_some() || pp.is_some() || dp.is_some() {
        bail!("force_tp/force_pp/force_dp must be given together");
    }
    if let Some(v) = j.get("max_pp").and_then(|v| v.as_usize()) {
        o.max_pp = v;
    }
    if let Some(v) = j.get("max_dp").and_then(|v| v.as_usize()) {
        o.max_dp = v;
    }
    Ok(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "workload": {"kind": "gpt", "model": "gpt3-175b", "batch": 64},
      "system": {"chip": "sn10", "memory": "ddr4", "link": "pcie4",
                 "topology": {"kind": "ring", "dims": [8]}},
      "options": {"force_tp": 8, "force_pp": 1, "force_dp": 1}
    }"#;

    #[test]
    fn parses_and_runs_sample() {
        let e = Experiment::parse(SAMPLE).unwrap();
        assert_eq!(e.system.n_chips(), 8);
        assert_eq!(e.options.force_degrees, Some((8, 1, 1)));
        let r = e.run().unwrap();
        assert_eq!(r.get("feasible"), Some(&Json::Bool(true)));
        assert_eq!(r.get("tp").unwrap().as_usize(), Some(8));
        assert!(r.get("utilization").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn defaults_fill_in() {
        let e = Experiment::parse("{}").unwrap();
        assert_eq!(e.system.n_chips(), 8);
        matches!(e.workload, WorkloadSpec::Gpt { .. });
    }

    #[test]
    fn topology_variants_parse() {
        for (k, d, n) in [
            ("torus2d", "[4, 2]", 8),
            ("torus3d", "[2, 2, 2]", 8),
            ("dragonfly", "[4, 4]", 16),
            ("dgx1", "[4]", 32),
            ("dgx2", "[2]", 32),
        ] {
            let cfg = format!(
                r#"{{"system": {{"topology": {{"kind": "{k}", "dims": {d}}}}}}}"#
            );
            let e = Experiment::parse(&cfg).unwrap();
            assert_eq!(e.system.n_chips(), n, "{k}");
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(Experiment::parse(r#"{"system": {"chip": "zz80"}}"#).is_err());
        assert!(Experiment::parse(r#"{"workload": {"kind": "prolog"}}"#).is_err());
        assert!(
            Experiment::parse(r#"{"options": {"force_tp": 8}}"#).is_err(),
            "partial force degrees must be rejected"
        );
        assert!(Experiment::parse("not json").is_err());
    }

    #[test]
    fn non_gpt_workloads_parse() {
        for kind in ["dlrm", "hpl", "fft", "moe"] {
            let cfg = format!(r#"{{"workload": {{"kind": "{kind}"}}}}"#);
            let e = Experiment::parse(&cfg).unwrap();
            matches!(e.workload, WorkloadSpec::Graph { .. });
        }
    }

    #[test]
    fn infeasible_run_reports_cleanly() {
        let cfg = r#"{
          "workload": {"kind": "gpt", "model": "gpt-100t"},
          "system": {"chip": "sn10", "topology": {"kind": "ring", "dims": [2]}}
        }"#;
        let e = Experiment::parse(cfg).unwrap();
        let r = e.run().unwrap();
        assert_eq!(r.get("feasible"), Some(&Json::Bool(false)));
    }
}
