//! Design-space exploration (§VI-C): the cartesian sweep over accelerators
//! (Table V) × interconnection topologies × memory/interconnect
//! technologies, evaluated for the four workloads — the data behind the
//! Figs 10–17 heat maps and latency breakdowns — plus the Fig. 19
//! SRAM×DRAM-bandwidth sweep and the Fig. 22 3-D-memory sweep.

use std::sync::OnceLock;

use crate::graph::{dlrm, fft, gpt, hpl};
use crate::pipeline;
use crate::system::{chip, interconnect, memory, topology, ChipSpec, SystemSpec};
use crate::util::threadpool::parallel_map;

/// The four evaluated workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// GPT3 1T training.
    Llm,
    /// 793B DLRM training iteration.
    Dlrm,
    /// 5M² HPL solve.
    Hpl,
    /// 1T-point FFT.
    Fft,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Llm => "GPT3-1T",
            Workload::Dlrm => "DLRM-793B",
            Workload::Hpl => "HPL-5M",
            Workload::Fft => "FFT-1T",
        }
    }

    pub fn all() -> [Workload; 4] {
        [Workload::Llm, Workload::Dlrm, Workload::Hpl, Workload::Fft]
    }
}

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub chip: String,
    pub topo: String,
    pub mem: String,
    pub link: String,
    /// Throughput utilization (achieved / peak).
    pub utilization: f64,
    /// Achieved GFLOP/s per dollar.
    pub cost_eff: f64,
    /// Achieved GFLOP/s per watt.
    pub power_eff: f64,
    /// (compute, memory, network) fractional latency breakdown.
    pub breakdown: (f64, f64, f64),
}

/// Evaluate one workload on one system; None when infeasible.
///
/// `pub(crate)`: external callers go through `api::evaluate_design` or a
/// `api::Scenario` (the facade is the only public seam).
pub(crate) fn evaluate_point(w: Workload, sys: &SystemSpec) -> Option<DesignPoint> {
    let r = match w {
        Workload::Llm => pipeline::llm_training(&gpt::gpt3_1t(), sys, 2048.0)?,
        Workload::Dlrm => {
            let g = dlrm::dlrm_graph(&dlrm::dlrm_793b(), 65_536.0);
            pipeline::workload_pass(&g, sys, 3.0, 64)?
        }
        Workload::Hpl => {
            let g = hpl::hpl_graph(&hpl::hpl_5m());
            pipeline::workload_pass(&g, sys, 1.0, 1)?
        }
        Workload::Fft => {
            let g = fft::fft_graph(&fft::fft_1t());
            pipeline::workload_pass(&g, sys, 1.0, 1)?
        }
    };
    Some(DesignPoint {
        chip: sys.chip.name.clone(),
        topo: sys.topology.name.clone(),
        mem: sys.memory.name.clone(),
        link: sys.link.name.clone(),
        utilization: r.utilization,
        cost_eff: r.achieved_flops / 1e9 / sys.price_usd(),
        power_eff: r.achieved_flops / 1e9 / sys.power_w(),
        breakdown: r.breakdown_frac(),
    })
}

/// `evaluate_point` with the system's collective costs recalibrated by the
/// fabric simulator first (`CollectiveModel::Calibrated`): the same sweep,
/// but every TP/PP/DP and sharding decision is priced with simulated
/// contention instead of the closed-form shortcut. Subsets larger than
/// `opts.max_group` keep the analytical costs.
///
/// `pub(crate)`: the public seam is `api::evaluate_design_calibrated`.
pub(crate) fn evaluate_point_calibrated(
    w: Workload,
    sys: &SystemSpec,
    opts: &crate::fabric::CalibrateOpts,
) -> Option<DesignPoint> {
    let calibrated = crate::fabric::calibrate_system(sys, opts);
    evaluate_point(w, &calibrated)
}

/// The 4 memory × interconnect combinations of §VI-C, built once and
/// cached — sweeps call this per design point, so the fresh-`Vec`-per-call
/// version allocated 4 specs × 80 points × every sweep for nothing.
pub fn mem_link_combos() -> &'static [(memory::MemoryTech, interconnect::LinkTech)] {
    static COMBOS: OnceLock<Vec<(memory::MemoryTech, interconnect::LinkTech)>> = OnceLock::new();
    COMBOS.get_or_init(|| {
        vec![
            (memory::ddr4(), interconnect::pcie4()),
            (memory::ddr4(), interconnect::nvlink4()),
            (memory::hbm3(), interconnect::pcie4()),
            (memory::hbm3(), interconnect::nvlink4()),
        ]
    })
}

/// All 80 system specs of the §VI-C design space (4 chips × 5 topologies ×
/// 4 mem/link combos) at 1024 accelerators, built once and cached.
pub fn dse_systems_1024() -> &'static [SystemSpec] {
    static SYSTEMS: OnceLock<Vec<SystemSpec>> = OnceLock::new();
    SYSTEMS.get_or_init(|| {
        let mut out = Vec::new();
        for c in chip::table_v() {
            for (mem, link) in mem_link_combos() {
                for topo in topology::dse_topologies_1024(link) {
                    out.push(SystemSpec::new(c.clone(), mem.clone(), link.clone(), topo));
                }
            }
        }
        out
    })
}

/// Run the full sweep for one workload (parallel across design points).
/// Infeasible points are reported with NaN utilization so heat maps show
/// the gap.
pub fn sweep(w: Workload) -> Vec<DesignPoint> {
    let systems = dse_systems_1024();
    parallel_map(systems, |sys| {
        evaluate_point(w, sys).unwrap_or(DesignPoint {
            chip: sys.chip.name.clone(),
            topo: sys.topology.name.clone(),
            mem: sys.memory.name.clone(),
            link: sys.link.name.clone(),
            utilization: f64::NAN,
            cost_eff: f64::NAN,
            power_eff: f64::NAN,
            breakdown: (f64::NAN, f64::NAN, f64::NAN),
        })
    })
}

// ---------------------------------------------------------------------------
// Fig. 19: dataflow vs non-dataflow across SRAM capacity × DRAM bandwidth.
// ---------------------------------------------------------------------------

/// One Fig. 19 cell: utilizations of the dataflow and non-dataflow mapping.
#[derive(Debug, Clone)]
pub struct Fig19Cell {
    pub sram_mb: f64,
    pub dram_gbs: f64,
    pub dataflow_util: f64,
    pub non_dataflow_util: f64,
}

/// The Fig. 19 experiment: GPT3 175B on 8 accelerators (4×2 torus),
/// 300 TFLOPS chips; sweep SRAM {150, 300, 500} MB × DRAM bw
/// {100, 300, 600} GB/s.
pub fn fig19_sweep() -> Vec<Fig19Cell> {
    use crate::util::units::{GB, MB, TFLOPS};
    let cfg = gpt::gpt3_175b();
    let link = interconnect::pcie4();
    let mut cells = Vec::new();
    for &sram in &[150.0, 300.0, 500.0] {
        for &bw in &[100.0, 300.0, 600.0] {
            let run = |exec| {
                let c = chip::custom("sweep", 300.0 * TFLOPS, sram * MB, exec);
                let mut mem = memory::ddr4();
                mem.bandwidth = bw * GB;
                let sys = SystemSpec::new(c, mem, link.clone(), topology::torus2d(4, 2, &link));
                pipeline::llm_training(&cfg, &sys, 64.0).map(|r| r.utilization)
            };
            let df = run(crate::system::ExecutionModel::Dataflow).unwrap_or(f64::NAN);
            let kbk = run(crate::system::ExecutionModel::KernelByKernel).unwrap_or(f64::NAN);
            cells.push(Fig19Cell {
                sram_mb: sram,
                dram_gbs: bw,
                dataflow_util: df,
                non_dataflow_util: kbk,
            });
        }
    }
    cells
}

// ---------------------------------------------------------------------------
// Fig. 22: 3-D memory — compute-tile percentage sweep on a 100T GPT model.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig22Cell {
    pub mem_name: String,
    pub compute_pct: f64,
    /// Achieved training throughput (FLOP/s) across the system.
    pub achieved: f64,
}

/// SN40L-like chip with 2080 iso-area units split between compute tiles and
/// SRAM units (§VIII-C).
fn unit_chip(compute_pct: f64) -> ChipSpec {
    use crate::util::units::{MB, TFLOPS};
    let units = 2080.0;
    let compute_units = (units * compute_pct).round();
    let mem_units = units - compute_units;
    // calibration: 1040 compute units = 640 TFLOPS; 1040 mem units = 520 MB
    let flops = 640.0 * TFLOPS * compute_units / 1040.0;
    let sram = 520.0 * MB * mem_units / 1040.0;
    ChipSpec {
        name: format!("SN40L-{:.0}%", compute_pct * 100.0),
        tiles: compute_units.max(1.0) as usize,
        tflop_per_tile: flops / compute_units.max(1.0),
        sram_bytes: sram.max(1.0),
        execution: crate::system::ExecutionModel::Dataflow,
        power_w: 500.0,
        price_usd: 28_000.0,
    }
}

/// Sweep compute percentage {20..80%} × three memory generations on 1024
/// chips training the 100T model.
pub fn fig22_sweep() -> Vec<Fig22Cell> {
    let cfg = gpt::gpt_100t();
    let mems =
        [memory::mem2d_ddr(), memory::mem25d_hbm(), memory::mem3d_stacked()];
    let link = interconnect::rdu_fabric();
    let mut out = Vec::new();
    for mem in &mems {
        for pct in [0.2, 0.35, 0.5, 0.65, 0.8] {
            let c = unit_chip(pct);
            // §VIII-C studies memory *bandwidth*: capacity is provisioned
            // (SN40L pairs the fast tier with large DDR) and only bf16
            // weights stay resident (state factor 2).
            let mut mem = mem.clone();
            mem.capacity = 1e12;
            let sys = SystemSpec::new(
                c,
                mem.clone(),
                link.clone(),
                topology::torus2d(32, 32, &link),
            );
            let opts = crate::interchip::InterChipOptions {
                state_bytes_per_weight_byte: 2.0,
                ..Default::default()
            };
            let achieved = pipeline::llm_training_opts(&cfg, &sys, 4096.0, &opts)
                .map(|r| r.achieved_flops)
                .unwrap_or(f64::NAN);
            out.push(Fig22Cell { mem_name: mem.name.clone(), compute_pct: pct, achieved });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_space_has_80_points() {
        assert_eq!(dse_systems_1024().len(), 80);
        for s in dse_systems_1024() {
            assert_eq!(s.n_chips(), 1024);
        }
    }

    #[test]
    fn llm_point_evaluates_on_good_system() {
        let link = interconnect::nvlink4();
        let sys = SystemSpec::new(
            chip::h100(),
            memory::hbm3(),
            link.clone(),
            topology::torus2d(32, 32, &link),
        );
        let p = evaluate_point(Workload::Llm, &sys).expect("feasible");
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
        assert!(p.cost_eff > 0.0 && p.power_eff > 0.0);
    }

    #[test]
    fn fft_needs_fast_network() {
        // §VI-C.4: NVLink systems beat PCIe systems by a large factor
        let mk = |link: interconnect::LinkTech| {
            SystemSpec::new(
                chip::tpu_v4(),
                memory::hbm3(),
                link.clone(),
                topology::torus2d(32, 32, &link),
            )
        };
        let fast = evaluate_point(Workload::Fft, &mk(interconnect::nvlink4())).unwrap();
        let slow = evaluate_point(Workload::Fft, &mk(interconnect::pcie4())).unwrap();
        assert!(
            fast.utilization / slow.utilization > 3.0,
            "nvlink {} vs pcie {}",
            fast.utilization,
            slow.utilization
        );
    }

    #[test]
    fn hpl_high_utilization_everywhere() {
        // §VI-C.3: HPL is dense — even PCIe+DDR systems do well
        let link = interconnect::pcie4();
        let sys = SystemSpec::new(
            chip::tpu_v4(),
            memory::ddr4(),
            link.clone(),
            topology::torus2d(32, 32, &link),
        );
        let p = evaluate_point(Workload::Hpl, &sys).unwrap();
        assert!(p.utilization > 0.5, "HPL util = {}", p.utilization);
    }

    #[test]
    fn fig19_grid_shape_and_trends() {
        let cells = fig19_sweep();
        assert_eq!(cells.len(), 9);
        // dataflow is an upper bound of non-dataflow everywhere (§VII-E)
        for c in &cells {
            if c.dataflow_util.is_finite() && c.non_dataflow_util.is_finite() {
                assert!(
                    c.dataflow_util >= c.non_dataflow_util * 0.999,
                    "{c:?}"
                );
            }
        }
        // non-dataflow gains from DRAM bandwidth at fixed SRAM
        let small_bw = cells.iter().find(|c| c.sram_mb == 300.0 && c.dram_gbs == 100.0).unwrap();
        let big_bw = cells.iter().find(|c| c.sram_mb == 300.0 && c.dram_gbs == 600.0).unwrap();
        assert!(big_bw.non_dataflow_util > small_bw.non_dataflow_util);
    }

    #[test]
    fn fig22_3d_memory_prefers_more_compute() {
        let cells = fig22_sweep();
        let best_for = |mem: &str| {
            cells
                .iter()
                .filter(|c| c.mem_name == mem && c.achieved.is_finite())
                .max_by(|a, b| a.achieved.total_cmp(&b.achieved))
                .map(|c| c.compute_pct)
                .unwrap_or(f64::NAN)
        };
        let b2d = best_for("2D-DDR");
        let b3d = best_for("3D-stacked");
        assert!(
            b3d >= b2d,
            "3D memory should prefer >= compute fraction: 2D {b2d} 3D {b3d}"
        );
    }
}
