//! Design-space exploration (§VI-C): the cartesian sweep over accelerators
//! (Table V) × interconnection topologies × memory/interconnect
//! technologies, evaluated for the four workloads — the data behind the
//! Figs 10–17 heat maps and latency breakdowns — plus the Fig. 19
//! SRAM×DRAM-bandwidth sweep and the Fig. 22 3-D-memory sweep.
//!
//! The fixed grids here are thin instantiations of the parameterized
//! explorer (`crate::explore`): each sweep is a committed
//! `SearchSpace` preset run exhaustively (no pruning), so open-ended
//! spaces and the paper's tables share one evaluation path.

use std::sync::OnceLock;

use crate::graph::{dlrm, fft, gpt, hpl};
use crate::interchip::InterChipOptions;
use crate::pipeline;
use crate::system::{chip, interconnect, memory, topology, ExecutionModel, SystemSpec};

/// The four evaluated workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// GPT3 1T training.
    Llm,
    /// 793B DLRM training iteration.
    Dlrm,
    /// 5M² HPL solve.
    Hpl,
    /// 1T-point FFT.
    Fft,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Llm => "GPT3-1T",
            Workload::Dlrm => "DLRM-793B",
            Workload::Hpl => "HPL-5M",
            Workload::Fft => "FFT-1T",
        }
    }

    pub fn all() -> [Workload; 4] {
        [Workload::Llm, Workload::Dlrm, Workload::Hpl, Workload::Fft]
    }
}

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub chip: String,
    pub topo: String,
    pub mem: String,
    pub link: String,
    /// True when the chip executes dataflow-fused (the RDU/WSE class).
    pub dataflow: bool,
    /// Throughput utilization (achieved / peak).
    pub utilization: f64,
    /// Achieved GFLOP/s per dollar.
    pub cost_eff: f64,
    /// Achieved GFLOP/s per watt.
    pub power_eff: f64,
    /// Absolute achieved FLOP/s of the whole system.
    pub achieved_flops: f64,
    /// (compute, memory, network) fractional latency breakdown.
    pub breakdown: (f64, f64, f64),
}

impl DesignPoint {
    /// The NaN-filled marker for an infeasible system (heat maps show the
    /// gap; the explorer's frontier skips non-finite points).
    pub fn infeasible(sys: &SystemSpec) -> DesignPoint {
        DesignPoint {
            chip: sys.chip.name.clone(),
            topo: sys.topology.name.clone(),
            mem: sys.memory.name.clone(),
            link: sys.link.name.clone(),
            dataflow: sys.chip.execution == ExecutionModel::Dataflow,
            utilization: f64::NAN,
            cost_eff: f64::NAN,
            power_eff: f64::NAN,
            achieved_flops: f64::NAN,
            breakdown: (f64::NAN, f64::NAN, f64::NAN),
        }
    }

    fn from_step(r: &pipeline::StepResult, sys: &SystemSpec) -> DesignPoint {
        DesignPoint {
            chip: sys.chip.name.clone(),
            topo: sys.topology.name.clone(),
            mem: sys.memory.name.clone(),
            link: sys.link.name.clone(),
            dataflow: sys.chip.execution == ExecutionModel::Dataflow,
            utilization: r.utilization,
            cost_eff: r.achieved_flops / 1e9 / sys.price_usd().raw(),
            power_eff: r.achieved_flops / 1e9 / sys.power_w().raw(),
            achieved_flops: r.achieved_flops,
            breakdown: r.breakdown_frac(),
        }
    }
}

/// Evaluate one workload on one system; None when infeasible.
///
/// `pub(crate)`: external callers go through `api::evaluate_design` or a
/// `api::Scenario` (the facade is the only public seam).
pub(crate) fn evaluate_point(w: Workload, sys: &SystemSpec) -> Option<DesignPoint> {
    evaluate_point_cfg(w, sys, None, None, None)
}

/// [`evaluate_point`] with the explorer's knobs: GPT architecture override
/// (the Fig. 19/22 models), batch override, and training-state factor.
/// Every `None` keeps the fixed §VI-C behavior bit for bit.
pub(crate) fn evaluate_point_cfg(
    w: Workload,
    sys: &SystemSpec,
    gpt_cfg: Option<&gpt::GptConfig>,
    batch: Option<f64>,
    state_bytes_per_weight_byte: Option<f64>,
) -> Option<DesignPoint> {
    let r = match w {
        Workload::Llm => {
            let cfg = gpt_cfg.copied().unwrap_or_else(gpt::gpt3_1t);
            let b = batch.unwrap_or(2048.0);
            match state_bytes_per_weight_byte {
                None => pipeline::llm_training(&cfg, sys, b)?,
                Some(s) => {
                    let opts = InterChipOptions {
                        state_bytes_per_weight_byte: s,
                        ..Default::default()
                    };
                    pipeline::llm_training_opts(&cfg, sys, b, &opts)?
                }
            }
        }
        Workload::Dlrm => {
            let g = dlrm::dlrm_graph(&dlrm::dlrm_793b(), batch.unwrap_or(65_536.0));
            graph_pass(&g, sys, 3.0, 64, state_bytes_per_weight_byte)?
        }
        Workload::Hpl => {
            let g = hpl::hpl_graph(&hpl::hpl_5m());
            graph_pass(&g, sys, 1.0, 1, state_bytes_per_weight_byte)?
        }
        Workload::Fft => {
            let g = fft::fft_graph(&fft::fft_1t());
            graph_pass(&g, sys, 1.0, 1, state_bytes_per_weight_byte)?
        }
    };
    Some(DesignPoint::from_step(&r, sys))
}

fn graph_pass(
    g: &crate::graph::DataflowGraph,
    sys: &SystemSpec,
    passes: f64,
    max_dp: usize,
    state_bytes_per_weight_byte: Option<f64>,
) -> Option<pipeline::StepResult> {
    match state_bytes_per_weight_byte {
        None => pipeline::workload_pass(g, sys, passes, max_dp),
        Some(s) => {
            let opts = InterChipOptions {
                max_dp,
                state_bytes_per_weight_byte: s,
                ..Default::default()
            };
            pipeline::workload_pass_opts(g, sys, passes, &opts)
        }
    }
}

/// `evaluate_point` with the system's collective costs recalibrated by the
/// fabric simulator first (`CollectiveModel::Calibrated`): the same sweep,
/// but every TP/PP/DP and sharding decision is priced with simulated
/// contention instead of the closed-form shortcut. Subsets larger than
/// `opts.max_group` keep the analytical costs.
///
/// `pub(crate)`: the public seam is `api::evaluate_design_calibrated`.
pub(crate) fn evaluate_point_calibrated(
    w: Workload,
    sys: &SystemSpec,
    opts: &crate::fabric::CalibrateOpts,
) -> Option<DesignPoint> {
    let calibrated = crate::fabric::calibrate_system(sys, opts);
    evaluate_point(w, &calibrated)
}

/// The 4 memory × interconnect combinations of §VI-C, built once and
/// cached — sweeps call this per design point, so the fresh-`Vec`-per-call
/// version allocated 4 specs × 80 points × every sweep for nothing.
pub fn mem_link_combos() -> &'static [(memory::MemoryTech, interconnect::LinkTech)] {
    static COMBOS: OnceLock<Vec<(memory::MemoryTech, interconnect::LinkTech)>> = OnceLock::new();
    COMBOS.get_or_init(|| {
        vec![
            (memory::ddr4(), interconnect::pcie4()),
            (memory::ddr4(), interconnect::nvlink4()),
            (memory::hbm3(), interconnect::pcie4()),
            (memory::hbm3(), interconnect::nvlink4()),
        ]
    })
}

/// All 80 system specs of the §VI-C design space (4 chips × 5 topologies ×
/// 4 mem/link combos) at 1024 accelerators, built once and cached.
/// `explore::SearchSpace::paper_grid` enumerates the same systems in the
/// same order (pinned by `tests/explore.rs`).
pub fn dse_systems_1024() -> &'static [SystemSpec] {
    static SYSTEMS: OnceLock<Vec<SystemSpec>> = OnceLock::new();
    SYSTEMS.get_or_init(|| {
        let mut out = Vec::new();
        for c in chip::table_v() {
            for (mem, link) in mem_link_combos() {
                for topo in topology::dse_topologies_1024(link) {
                    out.push(SystemSpec::new(c.clone(), mem.clone(), link.clone(), topo));
                }
            }
        }
        out
    })
}

/// Run the full §VI-C sweep for one workload (parallel across design
/// points) — the exhaustive explorer over the [`paper grid`] preset.
/// Infeasible points are reported with NaN utilization so heat maps show
/// the gap.
///
/// [`paper grid`]: crate::explore::SearchSpace::paper_grid
pub fn sweep(w: Workload) -> Vec<DesignPoint> {
    crate::explore::explore(
        &crate::explore::SearchSpace::paper_grid(w),
        &crate::explore::ExploreSettings::exhaustive(),
    )
    .expect("the committed paper grid is a valid search space")
    .points
}

// ---------------------------------------------------------------------------
// Fig. 19: dataflow vs non-dataflow across SRAM capacity × DRAM bandwidth.
// ---------------------------------------------------------------------------

/// One Fig. 19 cell: utilizations of the dataflow and non-dataflow mapping.
#[derive(Debug, Clone)]
pub struct Fig19Cell {
    pub sram_mb: f64,
    pub dram_gbs: f64,
    pub dataflow_util: f64,
    pub non_dataflow_util: f64,
}

/// The Fig. 19 experiment: GPT3 175B on 8 accelerators (4×2 torus),
/// 300 TFLOPS chips; sweep SRAM {150, 300, 500} MB × DRAM bw
/// {100, 300, 600} GB/s — the exhaustive explorer over
/// `explore::SearchSpace::fig19_grid`.
pub fn fig19_sweep() -> Vec<Fig19Cell> {
    let out = crate::explore::explore(
        &crate::explore::SearchSpace::fig19_grid(),
        &crate::explore::ExploreSettings::exhaustive(),
    )
    .expect("the committed fig19 grid is a valid search space");
    // enumeration order: chips (SRAM-major, dataflow before kernel-by-
    // kernel) × DRAM bandwidth
    let srams = [150.0, 300.0, 500.0];
    let bws = [100.0, 300.0, 600.0];
    assert_eq!(out.points.len(), srams.len() * 2 * bws.len());
    let mut cells = Vec::new();
    for (si, &sram) in srams.iter().enumerate() {
        for (bi, &bw) in bws.iter().enumerate() {
            let df = &out.points[(2 * si) * bws.len() + bi];
            let kbk = &out.points[(2 * si + 1) * bws.len() + bi];
            cells.push(Fig19Cell {
                sram_mb: sram,
                dram_gbs: bw,
                dataflow_util: df.utilization,
                non_dataflow_util: kbk.utilization,
            });
        }
    }
    cells
}

// ---------------------------------------------------------------------------
// Fig. 22: 3-D memory — compute-tile percentage sweep on a 100T GPT model.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig22Cell {
    pub mem_name: String,
    pub compute_pct: f64,
    /// Achieved training throughput (FLOP/s) across the system.
    pub achieved: f64,
}

/// Sweep compute percentage {20..80%} × three memory generations on 1024
/// chips training the 100T model (§VIII-C) — the exhaustive explorer over
/// `explore::SearchSpace::fig22_grid`.
pub fn fig22_sweep() -> Vec<Fig22Cell> {
    let out = crate::explore::explore(
        &crate::explore::SearchSpace::fig22_grid(),
        &crate::explore::ExploreSettings::exhaustive(),
    )
    .expect("the committed fig22 grid is a valid search space");
    // enumeration order: chips (compute percentage) × memory generation
    let pcts = [0.2, 0.35, 0.5, 0.65, 0.8];
    let n_mems = 3;
    assert_eq!(out.points.len(), pcts.len() * n_mems);
    let mut cells = Vec::new();
    for mi in 0..n_mems {
        for (pi, &pct) in pcts.iter().enumerate() {
            let p = &out.points[pi * n_mems + mi];
            cells.push(Fig22Cell {
                mem_name: p.mem.clone(),
                compute_pct: pct,
                achieved: p.achieved_flops,
            });
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_space_has_80_points() {
        assert_eq!(dse_systems_1024().len(), 80);
        for s in dse_systems_1024() {
            assert_eq!(s.n_chips(), 1024);
        }
    }

    #[test]
    fn llm_point_evaluates_on_good_system() {
        let link = interconnect::nvlink4();
        let sys = SystemSpec::new(
            chip::h100(),
            memory::hbm3(),
            link.clone(),
            topology::torus2d(32, 32, &link),
        );
        let p = evaluate_point(Workload::Llm, &sys).expect("feasible");
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
        assert!(p.cost_eff > 0.0 && p.power_eff > 0.0);
        assert!(p.achieved_flops > 0.0);
        assert!(!p.dataflow, "H100 is a kernel-by-kernel chip");
    }

    #[test]
    fn infeasible_point_is_nan_marked() {
        let link = interconnect::pcie4();
        let sys = SystemSpec::new(
            chip::sn10(),
            memory::ddr4(),
            link.clone(),
            topology::ring(8, &link),
        );
        let p = DesignPoint::infeasible(&sys);
        assert_eq!(p.chip, sys.chip.name);
        assert!(p.dataflow);
        assert!(p.utilization.is_nan());
        assert!(p.cost_eff.is_nan());
        assert!(p.power_eff.is_nan());
        assert!(p.achieved_flops.is_nan());
    }

    #[test]
    fn fft_needs_fast_network() {
        // §VI-C.4: NVLink systems beat PCIe systems by a large factor
        let mk = |link: interconnect::LinkTech| {
            SystemSpec::new(
                chip::tpu_v4(),
                memory::hbm3(),
                link.clone(),
                topology::torus2d(32, 32, &link),
            )
        };
        let fast = evaluate_point(Workload::Fft, &mk(interconnect::nvlink4())).unwrap();
        let slow = evaluate_point(Workload::Fft, &mk(interconnect::pcie4())).unwrap();
        assert!(
            fast.utilization / slow.utilization > 3.0,
            "nvlink {} vs pcie {}",
            fast.utilization,
            slow.utilization
        );
    }

    #[test]
    fn hpl_high_utilization_everywhere() {
        // §VI-C.3: HPL is dense — even PCIe+DDR systems do well
        let link = interconnect::pcie4();
        let sys = SystemSpec::new(
            chip::tpu_v4(),
            memory::ddr4(),
            link.clone(),
            topology::torus2d(32, 32, &link),
        );
        let p = evaluate_point(Workload::Hpl, &sys).unwrap();
        assert!(p.utilization > 0.5, "HPL util = {}", p.utilization);
    }

    #[test]
    fn fig19_grid_shape_and_trends() {
        let cells = fig19_sweep();
        assert_eq!(cells.len(), 9);
        // dataflow is an upper bound of non-dataflow everywhere (§VII-E)
        for c in &cells {
            if c.dataflow_util.is_finite() && c.non_dataflow_util.is_finite() {
                assert!(
                    c.dataflow_util >= c.non_dataflow_util * 0.999,
                    "{c:?}"
                );
            }
        }
        // non-dataflow gains from DRAM bandwidth at fixed SRAM
        let small_bw = cells.iter().find(|c| c.sram_mb == 300.0 && c.dram_gbs == 100.0).unwrap();
        let big_bw = cells.iter().find(|c| c.sram_mb == 300.0 && c.dram_gbs == 600.0).unwrap();
        assert!(big_bw.non_dataflow_util > small_bw.non_dataflow_util);
    }

    #[test]
    fn fig22_3d_memory_prefers_more_compute() {
        let cells = fig22_sweep();
        let best_for = |mem: &str| {
            cells
                .iter()
                .filter(|c| c.mem_name == mem && c.achieved.is_finite())
                .max_by(|a, b| a.achieved.total_cmp(&b.achieved))
                .map(|c| c.compute_pct)
                .unwrap_or(f64::NAN)
        };
        let b2d = best_for("2D-DDR");
        let b3d = best_for("3D-stacked");
        assert!(
            b3d >= b2d,
            "3D memory should prefer >= compute fraction: 2D {b2d} 3D {b3d}"
        );
    }
}
