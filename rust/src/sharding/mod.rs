//! Kernel sharding strategies and tensor layout conversions (§IV-B, Fig. 4).
//!
//! TP sharding a kernel across `tp` chips introduces two communication
//! types: (a) communication *inherent* to the chosen scheme (e.g. the
//! all-reduce of a partial-sum GEMM — Fig. 4A) and (b) *layout conversion*
//! between a producer's output layout and a consumer's expected input
//! layout (Fig. 4B). The per-scheme costs populate the paper's `c_i`
//! vectors; the pairwise conversion costs populate the `C_j` matrices.

use crate::collective::{Collective, CollectiveModel};
use crate::graph::{Kernel, KernelKind};
use crate::system::topology::Dim;
use crate::util::units::{Bytes, Seconds};

/// Distribution of a tensor across the TP group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// Full copy on every chip.
    Replicated,
    /// Sharded along the row (token/batch) dimension.
    Row,
    /// Sharded along the column (feature) dimension.
    Col,
    /// Sharded along attention heads (or any batch dimension).
    Head,
    /// Each chip holds a partial sum of the full tensor.
    Partial,
}

/// One sharding scheme for a kernel (one entry of `c_i`).
#[derive(Debug, Clone)]
pub struct ShardScheme {
    pub name: &'static str,
    /// Per-chip FLOP = kernel FLOP × this factor.
    pub flops_factor: f64,
    /// Per-chip resident weight bytes = kernel weights × this factor.
    pub weight_factor: f64,
    /// Per-chip activation bytes of the output = tensor bytes × this.
    pub out_bytes_factor: f64,
    /// Inherent collective: (op, bytes factor on the *output* tensor size).
    pub inherent: Option<(Collective, f64)>,
    /// Weight-tensor communication the scheme implies (Fig. 4A: replicating
    /// a weight operand costs a broadcast): (op, factor on weight bytes).
    pub weight_comm: Option<(Collective, f64)>,
    /// Layout this scheme requires on its (activation) inputs.
    pub in_layout: Layout,
    /// Layout this scheme produces.
    pub out_layout: Layout,
}

impl ShardScheme {
    #[allow(clippy::too_many_arguments)]
    fn new(
        name: &'static str,
        flops_factor: f64,
        weight_factor: f64,
        out_bytes_factor: f64,
        inherent: Option<(Collective, f64)>,
        weight_comm: Option<(Collective, f64)>,
        in_layout: Layout,
        out_layout: Layout,
    ) -> Self {
        ShardScheme {
            name,
            flops_factor,
            weight_factor,
            out_bytes_factor,
            inherent,
            weight_comm,
            in_layout,
            out_layout,
        }
    }
}

/// Enumerate the sharding schemes of a kernel for a TP degree (§IV-B).
/// With tp == 1 only the trivial scheme exists.
pub fn schemes_for(kind: &KernelKind, tp: usize) -> Vec<ShardScheme> {
    use Collective::*;
    use Layout::*;
    let t = tp as f64;
    if tp <= 1 {
        return vec![ShardScheme::new("local", 1.0, 1.0, 1.0, None, None, Replicated, Replicated)];
    }
    let inv = 1.0 / t;
    match kind {
        KernelKind::Gemm { b, .. } => {
            if *b > 1.0 {
                // Batched GEMM (attention score/context): both operands are
                // activations, so the only valid shardings keep each batch
                // (head) element local — shard the batch dim or replicate.
                let mut v = Vec::new();
                if *b >= t {
                    v.push(ShardScheme::new("head", inv, 1.0, inv, None, None, Head, Head));
                }
                v.push(ShardScheme::new("rep", 1.0, 1.0, 1.0, None, None, Replicated, Replicated));
                v
            } else {
                vec![
                    // Fig. 4A scheme A: shard rows of A, replicate weights.
                    ShardScheme::new("row", inv, 1.0, inv, None, Some((Broadcast, 1.0)), Row, Row),
                    // Megatron column parallelism: shard the weight columns.
                    ShardScheme::new("col", inv, inv, inv, None, None, Replicated, Col),
                    // Fig. 4A scheme B: shard the contraction dim → partials.
                    ShardScheme::new("kdim", inv, inv, 1.0, None, None, Col, Partial),
                    // no sharding at all: weights still replicated → broadcast
                    ShardScheme::new("rep", 1.0, 1.0, 1.0, None, Some((Broadcast, 1.0)), Replicated, Replicated),
                ]
            }
        }
        KernelKind::FusedLayer { .. } => vec![
            // Internally Megatron-sharded layer: weights/compute divided,
            // activations replicated at the boundary, and the layer's two
            // forward all-reduces surface as inherent communication.
            ShardScheme::new(
                "megatron",
                inv,
                inv,
                1.0,
                Some((AllReduce, 2.0)),
                None,
                Replicated,
                Replicated,
            ),
            ShardScheme::new(
                "rep",
                1.0,
                1.0,
                1.0,
                None,
                Some((Broadcast, 1.0)),
                Replicated,
                Replicated,
            ),
        ],
        KernelKind::Softmax { .. } => vec![
            ShardScheme::new("head", inv, 1.0, inv, None, None, Head, Head),
            ShardScheme::new("row", inv, 1.0, inv, None, None, Row, Row),
            ShardScheme::new("rep", 1.0, 1.0, 1.0, None, None, Replicated, Replicated),
        ],
        KernelKind::Elementwise { .. } => vec![
            ShardScheme::new("row", inv, 1.0, inv, None, None, Row, Row),
            ShardScheme::new("col", inv, 1.0, inv, None, None, Col, Col),
            ShardScheme::new("head", inv, 1.0, inv, None, None, Head, Head),
            ShardScheme::new("rep", 1.0, 1.0, 1.0, None, None, Replicated, Replicated),
        ],
        KernelKind::LayerNorm { .. } => vec![
            // LN reduces across features: needs full rows locally.
            ShardScheme::new("row", inv, 1.0, inv, None, None, Row, Row),
            ShardScheme::new("rep", 1.0, 1.0, 1.0, None, None, Replicated, Replicated),
        ],
        KernelKind::Embedding { .. } => vec![
            // tables sharded across chips; pooled vectors exchanged all-to-all
            ShardScheme::new("table", inv, inv, inv, Some((AllToAll, inv)), None, Row, Row),
            ShardScheme::new("rep", 1.0, 1.0, 1.0, None, Some((Broadcast, 1.0)), Replicated, Replicated),
        ],
        KernelKind::Fft { .. } => vec![
            // pencil decomposition: local 1-D FFTs, no inherent comm
            ShardScheme::new("pencil", inv, 1.0, inv, None, None, Row, Row),
            ShardScheme::new("rep", 1.0, 1.0, 1.0, None, None, Replicated, Replicated),
        ],
        KernelKind::Transpose { .. } => vec![
            // global transpose = all-to-all of the sharded volume
            ShardScheme::new("alltoall", inv, 1.0, inv, Some((AllToAll, inv)), None, Row, Row),
            ShardScheme::new("rep", 1.0, 1.0, 1.0, None, None, Replicated, Replicated),
        ],
    }
}

/// Layout-conversion collective required to feed a `to` consumer from a
/// `from` producer (one entry of `C_j`); None = free.
pub fn conversion_op(from: Layout, to: Layout) -> Option<Collective> {
    use Collective::*;
    use Layout::*;
    match (from, to) {
        _ if from == to => None,
        // a replicated tensor can be sliced locally into any sharding
        (Replicated, _) => None,
        // head-sharding of [heads, s, hd] merges to a feature(column)-shard
        // of [s, h]: the same chips hold the same elements — free (this is
        // what lets the optimizer discover Megatron's 2-allreduce forward)
        (Head, Col) | (Col, Head) => None,
        // partial sums must be combined; reduce-scatter if the consumer
        // wants a sharded layout (Megatron sequence-parallel), all-reduce
        // for a replicated one
        (Partial, Replicated) => Some(AllReduce),
        (Partial, _) => Some(ReduceScatter),
        // gather shards to reconstruct the full tensor
        (_, Replicated) => Some(AllGather),
        // resharding along a different axis
        (_, _) => Some(AllToAll),
    }
}

/// Time of the layout conversion over the TP dims. `bytes` is the full
/// (unsharded) tensor size.
///
/// Payload conventions match `collective::time` (which takes the *logical
/// full tensor size*): all-reduce and reduce-scatter operate on full-size
/// partial buffers; all-gather reconstructs the full size; only all-to-all
/// re-shards per-chip shards of S/tp.
pub fn conversion_time(from: Layout, to: Layout, bytes: f64, tp_dims: &[&Dim]) -> Seconds {
    conversion_time_model(&CollectiveModel::Analytical, from, to, bytes, tp_dims)
}

/// `conversion_time` under a caller-chosen collective-cost model (the
/// fabric-calibrated path threads through here).
pub fn conversion_time_model(
    model: &CollectiveModel,
    from: Layout,
    to: Layout,
    bytes: f64,
    tp_dims: &[&Dim],
) -> Seconds {
    let tp: usize = tp_dims.iter().map(|d| d.size).product();
    match conversion_op(from, to) {
        None => Seconds::ZERO,
        Some(op) => {
            // tensor sizes arrive as raw graph-domain `f64`s; they pick up
            // a dimension here, at the entry to the collective model
            let payload = match op {
                Collective::AllToAll => bytes / tp.max(1) as f64,
                _ => bytes,
            };
            model.time_hier(op, Bytes::new(payload), tp_dims)
        }
    }
}

/// Inherent communication time of a scheme (one entry of `c_i`):
/// output-tensor collective (e.g. the partial-sum all-reduce) plus the
/// weight-operand communication (Fig. 4A's broadcast of a replicated
/// weight tensor). `out_bytes`/`weight_bytes` are full (unsharded) sizes.
pub fn inherent_time(
    scheme: &ShardScheme,
    out_bytes: f64,
    weight_bytes: f64,
    tp_dims: &[&Dim],
) -> Seconds {
    inherent_time_model(&CollectiveModel::Analytical, scheme, out_bytes, weight_bytes, tp_dims)
}

/// `inherent_time` under a caller-chosen collective-cost model.
pub fn inherent_time_model(
    model: &CollectiveModel,
    scheme: &ShardScheme,
    out_bytes: f64,
    weight_bytes: f64,
    tp_dims: &[&Dim],
) -> Seconds {
    let t_out = match scheme.inherent {
        None => Seconds::ZERO,
        Some((op, factor)) => model.time_hier(op, Bytes::new(out_bytes * factor), tp_dims),
    };
    let t_w = match scheme.weight_comm {
        None => Seconds::ZERO,
        Some((op, factor)) => model.time_hier(op, Bytes::new(weight_bytes * factor), tp_dims),
    };
    t_out + t_w
}

/// Per-chip FLOP of a kernel under a scheme.
pub fn sharded_flops(kernel: &Kernel, scheme: &ShardScheme) -> f64 {
    kernel.flops * scheme.flops_factor
}

/// Per-chip weight bytes of a kernel under a scheme.
pub fn sharded_weights(kernel: &Kernel, scheme: &ShardScheme) -> f64 {
    kernel.weight_bytes * scheme.weight_factor
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::interconnect::nvlink4;
    use crate::system::topology::{Dim, DimKind};

    fn ring8() -> Dim {
        Dim::new(DimKind::Ring, 8, &nvlink4())
    }

    #[test]
    fn tp1_has_single_trivial_scheme() {
        let s = schemes_for(&KernelKind::Gemm { b: 1.0, m: 1.0, k: 1.0, n: 1.0 }, 1);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].flops_factor, 1.0);
        assert!(s[0].inherent.is_none());
    }

    #[test]
    fn gemm_schemes_cover_fig4() {
        let s = schemes_for(&KernelKind::Gemm { b: 1.0, m: 8.0, k: 8.0, n: 8.0 }, 8);
        let names: Vec<_> = s.iter().map(|x| x.name).collect();
        assert!(names.contains(&"row") && names.contains(&"col") && names.contains(&"kdim"));
        // no head scheme for b=1
        assert!(!names.contains(&"head"));
        // batched gemm gets the head scheme
        let s = schemes_for(&KernelKind::Gemm { b: 96.0, m: 8.0, k: 8.0, n: 8.0 }, 8);
        assert!(s.iter().any(|x| x.name == "head"));
    }

    #[test]
    fn kdim_scheme_produces_partials() {
        let s = schemes_for(&KernelKind::Gemm { b: 1.0, m: 8.0, k: 8.0, n: 8.0 }, 8);
        let kdim = s.iter().find(|x| x.name == "kdim").unwrap();
        assert_eq!(kdim.out_layout, Layout::Partial);
        assert_eq!(kdim.out_bytes_factor, 1.0); // each chip holds a full-size partial
    }

    #[test]
    fn conversion_identity_and_replicated_are_free() {
        for l in [Layout::Row, Layout::Col, Layout::Head, Layout::Replicated] {
            assert_eq!(conversion_op(l, l), None);
            assert_eq!(conversion_op(Layout::Replicated, l), None);
        }
    }

    #[test]
    fn conversion_partial_needs_reduction() {
        assert_eq!(conversion_op(Layout::Partial, Layout::Replicated), Some(Collective::AllReduce));
        assert_eq!(
            conversion_op(Layout::Partial, Layout::Row),
            Some(Collective::ReduceScatter)
        );
    }

    #[test]
    fn conversion_reshard_is_alltoall() {
        assert_eq!(conversion_op(Layout::Row, Layout::Col), Some(Collective::AllToAll));
        assert_eq!(conversion_op(Layout::Head, Layout::Row), Some(Collective::AllToAll));
    }

    #[test]
    fn conversion_gather_to_replicated() {
        assert_eq!(conversion_op(Layout::Row, Layout::Replicated), Some(Collective::AllGather));
    }

    #[test]
    fn conversion_time_scales_with_bytes() {
        let d = ring8();
        let t1 = conversion_time(Layout::Partial, Layout::Replicated, 1e9, &[&d]);
        let t2 = conversion_time(Layout::Partial, Layout::Replicated, 2e9, &[&d]);
        assert!(t2 > 1.9 * t1);
        assert_eq!(conversion_time(Layout::Row, Layout::Row, 1e9, &[&d]), Seconds::ZERO);
    }

    #[test]
    fn embedding_inherent_alltoall() {
        let s = schemes_for(&KernelKind::Embedding { lookups: 1.0, dim: 1.0 }, 8);
        let table = s.iter().find(|x| x.name == "table").unwrap();
        assert!(matches!(table.inherent, Some((Collective::AllToAll, _))));
        let d = ring8();
        assert!(inherent_time(table, 1e9, 0.0, &[&d]) > Seconds::ZERO);
    }

    #[test]
    fn sharded_flops_and_weights() {
        let k = Kernel {
            name: "g".into(),
            kind: KernelKind::Gemm { b: 1.0, m: 8.0, k: 8.0, n: 8.0 },
            flops: 1024.0,
            weight_bytes: 128.0,
        };
        let s = schemes_for(&k.kind, 8);
        let col = s.iter().find(|x| x.name == "col").unwrap();
        assert_eq!(sharded_flops(&k, col), 128.0);
        assert_eq!(sharded_weights(&k, col), 16.0);
        let row = s.iter().find(|x| x.name == "row").unwrap();
        assert_eq!(sharded_weights(&k, row), 128.0); // weights replicated
    }
}
