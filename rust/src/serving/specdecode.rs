//! Speculative decoding model (§VIII-B): a small draft model proposes
//! tokens, the large target model verifies them in one parallel pass.
//!
//! * Sequence-based [50]: the draft emits K tokens autoregressively; the
//!   expected accepted length at per-token acceptance rate a is the
//!   truncated geometric sum (1 − a^{K+1}) / (1 − a).
//! * Tree-based (SpecInfer [58]): the draft expands a tree of 2^K tokens,
//!   boosting the effective acceptance via path diversity but paying an
//!   exponential draft-generation cost — the Fig. 21 trade-off.

use super::{evaluate, ServingPoint, ServingSystem};
use crate::graph::llama::LlamaConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    Sequence,
    Tree,
}

#[derive(Debug, Clone, Copy)]
pub struct SpecDecodePoint {
    /// Draft window size K.
    pub window: usize,
    /// Per-token acceptance rate of the draft model.
    pub acceptance: f64,
    pub scheme: Scheme,
}

/// Expected tokens committed per verification step.
pub fn expected_accepted(window: usize, acceptance: f64) -> f64 {
    // Σ_{i=0..K} a^i = (1 - a^{K+1}) / (1 - a); +1 for the bonus token the
    // verifier always produces is folded into the i = 0 term.
    let a = acceptance.clamp(0.0, 0.999_999);
    (1.0 - a.powi(window as i32 + 1)) / (1.0 - a)
}

/// Effective acceptance under tree expansion: each position has two
/// alternatives on average, so a token fails only if both branches fail.
pub fn tree_acceptance(acceptance: f64) -> f64 {
    1.0 - (1.0 - acceptance) * (1.0 - acceptance)
}

/// Decoding throughput (tokens/s) of a (draft, target) pair on `sys`.
pub fn throughput(
    draft: &LlamaConfig,
    target: &LlamaConfig,
    sys: &ServingSystem,
    pt: &SpecDecodePoint,
) -> f64 {
    let sp = ServingPoint {
        tp: sys.n_chips,
        pp: 1,
        batch: 1.0,
        prompt_len: 1024.0,
        context: 2048.0,
    };
    let tpot_draft = evaluate(draft, sys, &sp).expect("tp = n_chips is always feasible").tpot;
    let tpot_target = evaluate(target, sys, &sp).expect("tp = n_chips is always feasible").tpot;

    match pt.scheme {
        Scheme::Sequence => {
            let e = expected_accepted(pt.window, pt.acceptance);
            let t_draft = pt.window as f64 * tpot_draft;
            // verification = one target pass over K+1 tokens (memory-bound:
            // ≈ one decode step)
            e / (t_draft + tpot_target)
        }
        Scheme::Tree => {
            let e = expected_accepted(pt.window, tree_acceptance(pt.acceptance));
            // the draft must emit 2^K − 1 tree tokens autoregressively along
            // each path (exponential generation cost — the §VIII-B overhead)
            let tree_tokens = (1u64 << pt.window.min(30)) as f64 - 1.0;
            let t_draft = tpot_draft * tree_tokens;
            // verifying a 2^K-token tree widens the target pass: tree
            // attention + KV handling grow with the token count
            let t_verify = tpot_target * (1.0 + 0.05 * (tree_tokens + 1.0));
            e / (t_draft + t_verify)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::llama::{llama3_405b, llama3_70b, llama3_8b, llama_68m};
    use crate::serving::sn40l_x16;

    #[test]
    fn expected_accepted_limits() {
        assert!((expected_accepted(4, 0.0) - 1.0).abs() < 1e-12);
        // near-perfect acceptance commits ~K+1 tokens
        assert!((expected_accepted(4, 0.999999) - 5.0).abs() < 1e-3);
        // monotone in both arguments
        assert!(expected_accepted(6, 0.8) > expected_accepted(3, 0.8));
        assert!(expected_accepted(4, 0.9) > expected_accepted(4, 0.5));
    }

    #[test]
    fn spec_decode_beats_vanilla_with_good_draft() {
        let sys = sn40l_x16();
        let target = llama3_405b();
        let vanilla = {
            let sp = ServingPoint { tp: 16, pp: 1, batch: 1.0, prompt_len: 1024.0, context: 2048.0 };
            1.0 / evaluate(&target, &sys, &sp).unwrap().tpot
        };
        let spec = throughput(
            &llama3_8b(),
            &target,
            &sys,
            &SpecDecodePoint { window: 4, acceptance: 0.8, scheme: Scheme::Sequence },
        );
        assert!(spec > vanilla, "spec {spec:.1} <= vanilla {vanilla:.1}");
    }

    #[test]
    fn large_draft_has_too_much_overhead() {
        // §VIII-B: the 70B draft is worse than the 8B draft
        let sys = sn40l_x16();
        let target = llama3_405b();
        let pt = SpecDecodePoint { window: 4, acceptance: 0.8, scheme: Scheme::Sequence };
        let with_8b = throughput(&llama3_8b(), &target, &sys, &pt);
        let with_70b = throughput(&llama3_70b(), &target, &sys, &pt);
        assert!(with_8b > with_70b);
    }

    #[test]
    fn tree_prefers_tiny_draft_and_short_window() {
        let sys = sn40l_x16();
        let target = llama3_405b();
        // tree with the 68M draft at K=2 beats tree with the 8B draft at K=6
        let small_short = throughput(
            &llama_68m(),
            &target,
            &sys,
            &SpecDecodePoint { window: 2, acceptance: 0.7, scheme: Scheme::Tree },
        );
        let big_long = throughput(
            &llama3_8b(),
            &target,
            &sys,
            &SpecDecodePoint { window: 6, acceptance: 0.7, scheme: Scheme::Tree },
        );
        assert!(small_short > big_long);
    }

    #[test]
    fn sequence_improves_with_window_and_acceptance() {
        let sys = sn40l_x16();
        let target = llama3_405b();
        let t = |w, a| {
            throughput(
                &llama3_8b(),
                &target,
                &sys,
                &SpecDecodePoint { window: w, acceptance: a, scheme: Scheme::Sequence },
            )
        };
        assert!(t(6, 0.9) > t(2, 0.9));
        assert!(t(4, 0.9) > t(4, 0.6));
    }
}
