//! LLM serving model (§VIII-A): prefill (TTFT, prefill throughput) and
//! autoregressive decode (TPOT, decode throughput) for a model served with
//! TP×PP over a chip group.
//!
//! Prefill resembles a training forward pass (compute-bound at long
//! prompts); decode streams the weights + KV cache from device memory
//! every token (memory-bound) and its TP all-reduces are latency-bound
//! (tiny payloads) — exactly the Fig. 20 observations.

pub mod specdecode;

use crate::ensure;
use crate::graph::llama::LlamaConfig;
use crate::system::{ChipSpec, LinkTech};
use crate::util::error::Result;

/// The serving platform: a group of identical accelerators.
#[derive(Debug, Clone)]
pub struct ServingSystem {
    pub chip: ChipSpec,
    /// Device-memory bandwidth the decode path streams from (bytes/s).
    pub mem_bw: f64,
    /// Device-memory capacity per chip (bytes) — bounds resident weights
    /// plus KV cache for the cluster simulator's admission control.
    pub mem_cap: f64,
    pub link: LinkTech,
    pub n_chips: usize,
}

impl ServingSystem {
    /// Total device memory across the chip group.
    pub fn mem_total(&self) -> f64 {
        self.mem_cap * self.n_chips as f64
    }
}

/// The §VIII-A platform: 16 SN40L, 25 GB/s fabric, 150 ns latency,
/// HBM-class 1.6 TB/s / 64 GB device memory per chip
/// (`system::memory::sn40l_hbm`).
pub fn sn40l_x16() -> ServingSystem {
    let hbm = crate::system::memory::sn40l_hbm();
    ServingSystem {
        chip: crate::system::chip::sn40l(),
        mem_bw: hbm.bandwidth.raw(),
        mem_cap: hbm.capacity.raw(),
        link: crate::system::interconnect::rdu_fabric(),
        n_chips: 16,
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ServingPoint {
    pub tp: usize,
    pub pp: usize,
    pub batch: f64,
    pub prompt_len: f64,
    /// Decode context length (tokens already in the KV cache).
    pub context: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct ServingMetrics {
    /// Time to first token (whole prefill pass), seconds.
    pub ttft: f64,
    /// System prefill throughput, tokens/s.
    pub prefill_tps: f64,
    /// Time per output token, seconds.
    pub tpot: f64,
    /// System decode throughput, tokens/s.
    pub decode_tps: f64,
    /// (compute, memory, network) share of the prefill critical path.
    pub prefill_breakdown: (f64, f64, f64),
    pub decode_breakdown: (f64, f64, f64),
}

impl ServingMetrics {
    /// The two serving phases as attribution rows: (name, seconds,
    /// (compute, memory, network) fractions). TTFT carries the prefill
    /// breakdown, TPOT the decode breakdown.
    pub fn phase_rows(&self) -> [(&'static str, f64, (f64, f64, f64)); 2] {
        [
            ("prefill", self.ttft, self.prefill_breakdown),
            ("decode", self.tpot, self.decode_breakdown),
        ]
    }
}

/// Dataflow-chip achievable efficiency on the prefill GEMMs.
const PREFILL_EFF: f64 = 0.8;

/// Evaluate one (model, platform, TP×PP) serving point. Errors (with the
/// reason) when the split does not cover the chip group (tp·pp ≠ n_chips),
/// so sweeps and the cluster planner can skip — and report — infeasible
/// points.
pub fn evaluate(
    model: &LlamaConfig,
    sys: &ServingSystem,
    pt: &ServingPoint,
) -> Result<ServingMetrics> {
    ensure!(
        pt.tp > 0 && pt.pp > 0 && pt.tp * pt.pp == sys.n_chips,
        "infeasible serving split: TP{}xPP{} does not cover the {}-chip group",
        pt.tp,
        pt.pp,
        sys.n_chips
    );
    let tp = pt.tp as f64;
    let pp = pt.pp as f64;
    let layers = model.layers as f64;
    let layers_per_stage = (layers / pp).ceil();

    // ---- prefill ----
    let tokens = pt.batch * pt.prompt_len;
    let flops_layer = 2.0 * model.params_per_layer() * tokens / tp
        + 4.0 * pt.prompt_len * model.d_model * tokens / tp;
    let t_comp = flops_layer / (sys.chip.compute_flops().raw() * PREFILL_EFF);
    // weights stream once per layer activation (they exceed SRAM at stack
    // scale); activations stay on-chip in the fused pipeline
    let w_layer_chip = model.params_per_layer() * model.dtype_bytes / tp;
    let t_mem = w_layer_chip / sys.mem_bw;
    // 2 all-reduces per layer of the activation slice
    let ar_bytes = tokens * model.d_model * model.dtype_bytes;
    let t_net = if pt.tp > 1 {
        2.0 * (2.0 * (tp - 1.0) / tp * ar_bytes / sys.link.bandwidth.raw()
            + 2.0 * (tp - 1.0) * sys.link.latency.raw())
    } else {
        0.0
    };
    let t_layer_prefill = t_comp.max(t_mem).max(t_net);
    // serialization through the pipeline + inter-stage hops
    let p2p = tokens * model.d_model * model.dtype_bytes / tp / sys.link.bandwidth.raw()
        + sys.link.latency.raw();
    let ttft = layers * t_layer_prefill + (pp - 1.0) * p2p;
    let stage_time = layers_per_stage * t_layer_prefill;
    let prefill_tps = tokens / stage_time;

    // ---- decode ----
    let w_stage_chip = model.params_per_layer() * layers_per_stage * model.dtype_bytes / tp;
    let kv_stage_chip =
        pt.batch * pt.context * model.kv_bytes_per_token() * layers_per_stage / layers / tp;
    let t_mem_stage = (w_stage_chip + kv_stage_chip) / sys.mem_bw;
    let dec_flops_stage =
        2.0 * model.params_per_layer() * layers_per_stage * pt.batch / tp;
    let t_comp_stage = dec_flops_stage / (sys.chip.compute_flops().raw() * 0.3);
    let ar_dec = pt.batch * model.d_model * model.dtype_bytes;
    let t_net_stage = if pt.tp > 1 {
        layers_per_stage
            * 2.0
            * (2.0 * (tp - 1.0) / tp * ar_dec / sys.link.bandwidth.raw()
                + 2.0 * (tp - 1.0) * sys.link.latency.raw())
    } else {
        0.0
    };
    let t_stage_dec = t_mem_stage.max(t_comp_stage) + t_net_stage + if pp > 1.0 { p2p } else { 0.0 };
    let tpot = pp * t_stage_dec;
    // pp stages work on different in-flight batches concurrently
    let decode_tps = pt.batch * pp / tpot;

    let nz = |a: f64, b: f64, c: f64| {
        let t = (a + b + c).max(1e-30);
        (a / t, b / t, c / t)
    };
    Ok(ServingMetrics {
        ttft,
        prefill_tps,
        tpot,
        decode_tps,
        prefill_breakdown: nz(t_comp, t_mem, t_net),
        decode_breakdown: nz(t_comp_stage, t_mem_stage, t_net_stage / layers_per_stage.max(1.0)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::llama::{llama3_70b, llama3_8b};

    fn base_pt() -> ServingPoint {
        ServingPoint { tp: 16, pp: 1, batch: 1.0, prompt_len: 1024.0, context: 1024.0 }
    }

    #[test]
    fn validates_against_measured_sn40l_decode() {
        // §VIII-A: modeled 1188 tok/s vs measured 1100 tok/s for Llama3 8B
        // decode on 16 SN40L at TP=16/PP=1 — our model must land in that
        // band (within 15% of the measurement).
        let m = evaluate(&llama3_8b(), &sn40l_x16(), &base_pt()).unwrap();
        let err = (m.decode_tps - 1100.0).abs() / 1100.0;
        assert!(err < 0.15, "decode_tps = {:.0}, err = {err:.2}", m.decode_tps);
    }

    #[test]
    fn tp_reduces_latency_pp_raises_throughput() {
        // Fig. 20 observations 1 & 2: TP lowers TPOT; PP raises decode
        // throughput at the cost of latency.
        let model = llama3_8b();
        let sys = sn40l_x16();
        let tp16 = evaluate(&model, &sys, &base_pt()).unwrap();
        let tp4pp4 = evaluate(&model, &sys, &ServingPoint { tp: 4, pp: 4, ..base_pt() }).unwrap();
        assert!(tp16.tpot < tp4pp4.tpot);
        assert!(tp4pp4.decode_tps > tp16.decode_tps);
    }

    #[test]
    fn mismatched_split_is_descriptive_error() {
        let sys = sn40l_x16();
        for (tp, pp) in [(3, 2), (16, 16), (0, 16), (5, 3)] {
            let e = evaluate(&llama3_8b(), &sys, &ServingPoint { tp, pp, ..base_pt() })
                .expect_err("tp*pp != 16 must be infeasible");
            let msg = e.to_string();
            assert!(
                msg.contains(&format!("TP{tp}xPP{pp}")) && msg.contains("16-chip"),
                "unhelpful error for tp={tp} pp={pp}: {msg}"
            );
        }
    }

    #[test]
    fn tp_reduces_ttft_on_fast_fabric() {
        // With a fast fabric (NVLink-class) prefill is compute-bound and
        // the paper's "TP decreases TTFT" holds; on the 25 GB/s RDU fabric
        // prefill is network-serialization-bound (Fig. 20 obs. 3) and TP
        // cannot shrink TTFT — both regimes are asserted here.
        let model = llama3_8b();
        let mut sys = sn40l_x16();
        sys.link = crate::system::interconnect::nvlink4();
        let tp16 = evaluate(&model, &sys, &base_pt()).unwrap();
        let tp4pp4 = evaluate(&model, &sys, &ServingPoint { tp: 4, pp: 4, ..base_pt() }).unwrap();
        assert!(tp16.ttft < tp4pp4.ttft, "{} vs {}", tp16.ttft, tp4pp4.ttft);
        let slow = sn40l_x16();
        let (_, _, net) = evaluate(&model, &slow, &base_pt()).unwrap().prefill_breakdown;
        assert!(net > 0.5, "slow-fabric prefill should be network-bound");
    }

    #[test]
    fn decode_is_memory_or_network_bound() {
        let m = evaluate(&llama3_8b(), &sn40l_x16(), &base_pt()).unwrap();
        let (c, mem, net) = m.decode_breakdown;
        assert!(mem + net > c, "decode must not be compute-bound");
    }

    #[test]
    fn prefill_is_compute_heavy_at_long_prompts() {
        let pt = ServingPoint { prompt_len: 8192.0, batch: 8.0, ..base_pt() };
        let m = evaluate(&llama3_8b(), &sn40l_x16(), &pt).unwrap();
        let (c, mem, _net) = m.prefill_breakdown;
        assert!(c > mem, "prefill at long prompts should be compute-heavy");
    }

    #[test]
    fn bigger_model_slower() {
        let small = evaluate(&llama3_8b(), &sn40l_x16(), &base_pt()).unwrap();
        let big = evaluate(&llama3_70b(), &sn40l_x16(), &base_pt()).unwrap();
        assert!(big.tpot > small.tpot);
        assert!(big.ttft > small.ttft);
    }
}
