//! Optimization toolkit replacing Gurobi (DESIGN.md §Substitutions).
//!
//! The paper's two MIP formulations minimize (a) the max per-stage critical
//! time over contiguous pipeline partitionings (§IV) and (b) the sum of
//! per-partition critical times over contiguous fusion partitionings (§V),
//! with per-kernel discrete choices (sharding schemes, tile counts) nested
//! inside. On the evaluated graphs both reduce to exact dynamic programs;
//! this module provides those DPs plus a simulated-annealing fallback for
//! non-contiguous exploration and an exhaustive assignment enumerator used
//! by the tests to certify optimality on small instances.

use crate::util::prng::Rng;

/// Exact DP: split items 0..n into at most `max_parts` contiguous segments
/// minimizing the SUM of segment costs. `cost(a, b)` is the cost of segment
/// [a, b); return `f64::INFINITY` for infeasible segments.
///
/// Returns (total cost, boundaries) where boundaries are the segment start
/// indices (first is always 0). O(n² · 1) — `max_parts` only caps the count.
pub fn partition_min_sum<F: Fn(usize, usize) -> f64>(
    n: usize,
    max_parts: usize,
    cost: F,
) -> Option<(f64, Vec<usize>)> {
    assert!(n > 0 && max_parts > 0);
    let inf = f64::INFINITY;
    // dp[p][i] = best cost of covering 0..i with exactly <= p parts
    // rolling over p to keep memory O(n).
    let mut dp = vec![inf; n + 1];
    let mut back = vec![vec![usize::MAX; n + 1]; max_parts + 1];
    dp[0] = 0.0;
    let mut best: Option<(f64, usize)> = None;
    let mut prev = dp.clone();
    for p in 1..=max_parts {
        std::mem::swap(&mut prev, &mut dp);
        dp.iter_mut().for_each(|v| *v = inf);
        dp[0] = 0.0;
        for i in 1..=n {
            for j in 0..i {
                if prev[j].is_finite() {
                    let c = cost(j, i);
                    let cand = prev[j] + c;
                    if cand < dp[i] {
                        dp[i] = cand;
                        back[p][i] = j;
                    }
                }
            }
        }
        if dp[n].is_finite() && best.map_or(true, |(b, _)| dp[n] < b) {
            best = Some((dp[n], p));
        }
    }
    let (total, parts) = best?;
    // trace back boundaries
    let mut bounds = Vec::new();
    let (mut p, mut i) = (parts, n);
    while i > 0 {
        let j = back[p][i];
        bounds.push(j);
        i = j;
        p -= 1;
    }
    bounds.reverse();
    Some((total, bounds))
}

/// Exact DP: split items 0..n into at most `max_parts` contiguous segments
/// minimizing the MAX segment cost. Same conventions as `partition_min_sum`.
pub fn partition_min_max<F: Fn(usize, usize) -> f64>(
    n: usize,
    max_parts: usize,
    cost: F,
) -> Option<(f64, Vec<usize>)> {
    assert!(n > 0 && max_parts > 0);
    let inf = f64::INFINITY;
    let mut prev = vec![inf; n + 1];
    let mut dp = vec![inf; n + 1];
    let mut back = vec![vec![usize::MAX; n + 1]; max_parts + 1];
    prev[0] = 0.0;
    let mut best: Option<(f64, usize)> = None;
    for p in 1..=max_parts {
        dp.iter_mut().for_each(|v| *v = inf);
        dp[0] = 0.0;
        for i in 1..=n {
            for j in 0..i {
                if prev[j].is_finite() {
                    let c = cost(j, i).max(prev[j]);
                    if c < dp[i] {
                        dp[i] = c;
                        back[p][i] = j;
                    }
                }
            }
        }
        if dp[n].is_finite() && best.map_or(true, |(b, _)| dp[n] < b) {
            best = Some((dp[n], p));
        }
        std::mem::swap(&mut prev, &mut dp);
    }
    let (total, parts) = best?;
    let mut bounds = Vec::new();
    // `prev` holds the dp of the last p; re-trace via back tables
    let (mut p, mut i) = (parts, n);
    while i > 0 {
        let j = back[p][i];
        bounds.push(j);
        i = j;
        p -= 1;
    }
    bounds.reverse();
    Some((total, bounds))
}

/// Convert segment boundaries (start indices) into a per-item partition id.
pub fn bounds_to_assignment(n: usize, bounds: &[usize]) -> Vec<usize> {
    let mut part = vec![0usize; n];
    for (p, &start) in bounds.iter().enumerate() {
        let end = bounds.get(p + 1).copied().unwrap_or(n);
        for item in part.iter_mut().take(end).skip(start) {
            *item = p;
        }
    }
    part
}

/// Discrete coordinate-descent / iterated-conditional-modes over per-item
/// label choices with pairwise costs, with `restarts` random restarts.
/// Exact on chains when `sweeps` is large enough; the tests certify against
/// exhaustive search on small instances.
///
/// `n_labels[i]` = number of choices for item i;
/// `unary(i, l)` = standalone cost; `pair_sum(i, labels)` = total pairwise
/// cost of item i's label against its current neighbours.
pub struct Ics<'a> {
    pub n_labels: &'a [usize],
    pub unary: &'a dyn Fn(usize, usize) -> f64,
    /// cost contribution of item i given the full label vector
    pub local: &'a dyn Fn(usize, &[usize]) -> f64,
    /// full objective (for accepting sweeps / restarts)
    pub total: &'a dyn Fn(&[usize]) -> f64,
}

pub fn coordinate_descent(ics: &Ics, restarts: usize, sweeps: usize, seed: u64) -> (f64, Vec<usize>) {
    let n = ics.n_labels.len();
    let mut rng = Rng::new(seed);
    let mut best_labels: Vec<usize> = vec![0; n];
    let mut best_cost = f64::INFINITY;
    for r in 0..restarts.max(1) {
        let mut labels: Vec<usize> = if r == 0 {
            vec![0; n] // deterministic start: first scheme everywhere
        } else {
            (0..n).map(|i| rng.below(ics.n_labels[i])).collect()
        };
        for _ in 0..sweeps {
            let mut changed = false;
            for i in 0..n {
                let mut best_l = labels[i];
                let mut best_c = (ics.unary)(i, labels[i]) + (ics.local)(i, &labels);
                for l in 0..ics.n_labels[i] {
                    if l == labels[i] {
                        continue;
                    }
                    let old = labels[i];
                    labels[i] = l;
                    let c = (ics.unary)(i, l) + (ics.local)(i, &labels);
                    if c < best_c - 1e-15 {
                        best_c = c;
                        best_l = l;
                    }
                    labels[i] = old;
                }
                if best_l != labels[i] {
                    labels[i] = best_l;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        let cost = (ics.total)(&labels);
        if cost < best_cost {
            best_cost = cost;
            best_labels = labels;
        }
    }
    (best_cost, best_labels)
}

/// Exhaustively enumerate all label vectors (certification on small
/// instances; also the exact path when the product of choices is small).
pub fn exhaustive_labels<F: FnMut(&[usize]) -> f64>(
    n_labels: &[usize],
    mut objective: F,
) -> (f64, Vec<usize>) {
    let n = n_labels.len();
    let mut labels = vec![0usize; n];
    let mut best = (f64::INFINITY, labels.clone());
    loop {
        let c = objective(&labels);
        if c < best.0 {
            best = (c, labels.clone());
        }
        // odometer increment
        let mut i = 0;
        loop {
            if i == n {
                return best;
            }
            labels[i] += 1;
            if labels[i] < n_labels[i] {
                break;
            }
            labels[i] = 0;
            i += 1;
        }
    }
}

/// Number of label vectors an exhaustive enumeration would visit.
pub fn label_space_size(n_labels: &[usize]) -> f64 {
    n_labels.iter().map(|&c| c as f64).product()
}

/// Simulated annealing over per-item labels (fallback for large coupled
/// instances; not needed for the paper's graphs but kept for generality).
pub fn anneal(
    n_labels: &[usize],
    total: &dyn Fn(&[usize]) -> f64,
    iters: usize,
    seed: u64,
) -> (f64, Vec<usize>) {
    let n = n_labels.len();
    let mut rng = Rng::new(seed);
    let mut labels: Vec<usize> = (0..n).map(|i| rng.below(n_labels[i])).collect();
    let mut cost = total(&labels);
    let mut best = (cost, labels.clone());
    let t0: f64 = 1.0;
    for it in 0..iters {
        let temp = t0 * (1.0 - it as f64 / iters as f64).max(1e-3);
        let i = rng.below(n);
        if n_labels[i] <= 1 {
            continue;
        }
        let old = labels[i];
        let mut new = rng.below(n_labels[i]);
        if new == old {
            new = (new + 1) % n_labels[i];
        }
        labels[i] = new;
        let c = total(&labels);
        let accept = c <= cost || rng.f64() < ((cost - c) / (temp * cost.abs().max(1e-12))).exp();
        if accept {
            cost = c;
            if c < best.0 {
                best = (c, labels.clone());
            }
        } else {
            labels[i] = old;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn min_sum_trivial_single_segment() {
        let (c, b) = partition_min_sum(5, 1, |a, b| (b - a) as f64).unwrap();
        assert_eq!(c, 5.0);
        assert_eq!(b, vec![0]);
    }

    #[test]
    fn min_sum_prefers_splitting_when_cheaper() {
        // cost = (len)^2 -> splitting always helps
        let (c, b) = partition_min_sum(6, 3, |a, b| ((b - a) * (b - a)) as f64).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(c, 12.0); // 2^2 * 3
    }

    #[test]
    fn min_sum_respects_infeasible_segments() {
        // segments longer than 2 are infeasible
        let (c, b) =
            partition_min_sum(6, 6, |a, b| if b - a > 2 { f64::INFINITY } else { 1.0 }).unwrap();
        assert_eq!(c, 3.0);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn min_sum_infeasible_returns_none() {
        let r = partition_min_sum(4, 1, |a, b| if b - a > 2 { f64::INFINITY } else { 1.0 });
        assert!(r.is_none());
    }

    #[test]
    fn min_max_balances_segments() {
        let w = [3.0, 1.0, 1.0, 1.0, 3.0];
        let cost = |a: usize, b: usize| w[a..b].iter().sum::<f64>();
        let (c, bounds) = partition_min_max(5, 3, cost).unwrap();
        assert_eq!(c, 3.0);
        assert_eq!(bounds.len(), 3);
        assert_eq!(bounds, vec![0, 1, 4]);
    }

    #[test]
    fn min_max_vs_brute_force_property() {
        check("minmax-dp-optimal", 60, |rng| {
            let n = 2 + rng.below(7);
            let parts = 1 + rng.below(4);
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 10.0)).collect();
            let cost = |a: usize, b: usize| w[a..b].iter().sum::<f64>();
            let dp = partition_min_max(n, parts, cost).unwrap().0;
            // brute force over all boundary subsets
            let mut best = f64::INFINITY;
            let masks = 1u32 << (n - 1);
            for m in 0..masks {
                if (m.count_ones() as usize) >= parts {
                    continue;
                }
                let mut maxseg = 0.0f64;
                let mut start = 0;
                for i in 0..n {
                    let end_here = i == n - 1 || (m >> i) & 1 == 1;
                    if end_here {
                        maxseg = maxseg.max(cost(start, i + 1));
                        start = i + 1;
                    }
                }
                best = best.min(maxseg);
            }
            assert!((dp - best).abs() < 1e-9, "dp {dp} brute {best} w {w:?}");
        });
    }

    #[test]
    fn min_sum_vs_brute_force_property() {
        check("minsum-dp-optimal", 60, |rng| {
            let n = 2 + rng.below(7);
            let parts = 1 + rng.below(4);
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 10.0)).collect();
            // segment cost = max element * len (arbitrary nonlinear)
            let cost = |a: usize, b: usize| {
                w[a..b].iter().cloned().fold(0.0f64, f64::max) * (b - a) as f64
            };
            let dp = partition_min_sum(n, parts, cost).unwrap().0;
            let mut best = f64::INFINITY;
            let masks = 1u32 << (n - 1);
            for m in 0..masks {
                if (m.count_ones() as usize) >= parts {
                    continue;
                }
                let mut tot = 0.0f64;
                let mut start = 0;
                for i in 0..n {
                    if i == n - 1 || (m >> i) & 1 == 1 {
                        tot += cost(start, i + 1);
                        start = i + 1;
                    }
                }
                best = best.min(tot);
            }
            assert!((dp - best).abs() < 1e-9, "dp {dp} brute {best}");
        });
    }

    #[test]
    fn bounds_to_assignment_roundtrip() {
        let part = bounds_to_assignment(6, &[0, 2, 5]);
        assert_eq!(part, vec![0, 0, 1, 1, 1, 2]);
    }

    #[test]
    fn exhaustive_finds_global_min() {
        let n_labels = [3usize, 3, 3];
        let (c, l) = exhaustive_labels(&n_labels, |ls| {
            ls.iter().map(|&x| (x as f64 - 1.5).powi(2)).sum()
        });
        assert_eq!(l, vec![1, 1, 1]); // closest to 1.5 among {0,1,2} (ties -> first found)
        assert!((c - 3.0 * 0.25).abs() < 1e-12);
    }

    #[test]
    fn coordinate_descent_matches_exhaustive_on_chain() {
        check("icm-chain-optimal", 25, |rng| {
            let n = 2 + rng.below(4);
            let k = 2 + rng.below(2);
            let n_labels: Vec<usize> = vec![k; n];
            // random chain MRF
            let unary_tbl: Vec<Vec<f64>> =
                (0..n).map(|_| (0..k).map(|_| rng.uniform(0.0, 3.0)).collect()).collect();
            let pair_tbl: Vec<Vec<Vec<f64>>> = (0..n.saturating_sub(1))
                .map(|_| {
                    (0..k)
                        .map(|_| (0..k).map(|_| rng.uniform(0.0, 3.0)).collect())
                        .collect()
                })
                .collect();
            let total = |ls: &[usize]| -> f64 {
                let mut c: f64 = ls.iter().enumerate().map(|(i, &l)| unary_tbl[i][l]).sum();
                for i in 0..n - 1 {
                    c += pair_tbl[i][ls[i]][ls[i + 1]];
                }
                c
            };
            let (ex, _) = exhaustive_labels(&n_labels, |ls| total(ls));
            let unary = |i: usize, l: usize| unary_tbl[i][l];
            let local = |i: usize, ls: &[usize]| {
                let mut c = 0.0;
                if i > 0 {
                    c += pair_tbl[i - 1][ls[i - 1]][ls[i]];
                }
                if i + 1 < n {
                    c += pair_tbl[i][ls[i]][ls[i + 1]];
                }
                c
            };
            let ics = Ics { n_labels: &n_labels, unary: &unary, local: &local, total: &total };
            let (cd, _) = coordinate_descent(&ics, 8, 50, 7);
            assert!((cd - ex).abs() < 1e-9, "cd {cd} exhaustive {ex}");
        });
    }

    #[test]
    fn anneal_improves_over_random() {
        let n_labels = vec![4usize; 8];
        let total = |ls: &[usize]| ls.iter().map(|&l| l as f64).sum::<f64>();
        let (c, l) = anneal(&n_labels, &total, 3000, 42);
        assert_eq!(c, 0.0);
        assert!(l.iter().all(|&x| x == 0));
    }

    #[test]
    fn label_space_size_products() {
        assert_eq!(label_space_size(&[3, 4, 5]), 60.0);
    }
}
