//! Dataflow-graph IR (§III-B): vertices are compute kernels, edges are
//! tensors. A workload is a DAG; DFModel partitions it across chips
//! (inter-chip, §IV) and within a chip (intra-chip, §V).
//!
//! Conventions: FLOP and bytes are `f64` in base units; every tensor has a
//! single producer and single consumer (§IV-C — multi-consumer tensors are
//! replicated by the builders).

pub mod builder;
pub mod dlrm;
pub mod fft;
pub mod gpt;
pub mod hpl;
pub mod llama;
pub mod moe;

pub use builder::GraphBuilder;

/// Index of a kernel (vertex) in its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KernelId(pub usize);

/// Index of a tensor (edge) in its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub usize);

/// What a kernel computes — drives FLOP counting, sharding-scheme
/// enumeration (§IV-B), and the compute-utilization model (§V-B).
#[derive(Debug, Clone, PartialEq)]
pub enum KernelKind {
    /// C[b×m,n] = A[b×m,k] · B[k,n]; `batch` multiplies the m dimension.
    Gemm { b: f64, m: f64, k: f64, n: f64 },
    /// Row softmax over `rows` rows of `cols` elements.
    Softmax { rows: f64, cols: f64 },
    /// Pointwise op over `elems` elements (`flop_per_elem` each).
    Elementwise { elems: f64, flop_per_elem: f64 },
    /// LayerNorm over `rows` rows of `cols` (≈8 flop/elem).
    LayerNorm { rows: f64, cols: f64 },
    /// Sparse embedding-bag lookup: `lookups` gathers of `dim`-wide rows.
    Embedding { lookups: f64, dim: f64 },
    /// 1-D FFT stage: `batch` transforms of `points` points (5·N·log2 N).
    Fft { points: f64, batch: f64 },
    /// Data movement only (transposes / layout shuffles): zero FLOP.
    Transpose { elems: f64 },
    /// An aggregated transformer layer (coarse inter-chip granularity):
    /// internally Megatron-sharded, so its TP scheme carries the layer's
    /// two forward all-reduces as inherent communication.
    FusedLayer { tokens: f64, width: f64 },
}

impl KernelKind {
    /// Floating-point operations implied by the kind.
    pub fn flops(&self) -> f64 {
        match *self {
            KernelKind::Gemm { b, m, k, n } => 2.0 * b * m * k * n,
            KernelKind::Softmax { rows, cols } => 5.0 * rows * cols,
            KernelKind::Elementwise { elems, flop_per_elem } => elems * flop_per_elem,
            KernelKind::LayerNorm { rows, cols } => 8.0 * rows * cols,
            KernelKind::Embedding { lookups, dim } => lookups * dim, // adds
            KernelKind::Fft { points, batch } => 5.0 * points * batch * points.log2().max(1.0),
            KernelKind::Transpose { .. } => 0.0,
            // 12·h² MACs per token (QKV+Proj+FFN) = 24·h² FLOP
            KernelKind::FusedLayer { tokens, width } => 24.0 * tokens * width * width,
        }
    }

    /// True for kernels whose inner loop maps onto the MXU/systolic array
    /// (used by the utilization model).
    pub fn is_matmul_like(&self) -> bool {
        matches!(self, KernelKind::Gemm { .. } | KernelKind::FusedLayer { .. })
    }
}

/// A compute kernel (graph vertex).
#[derive(Debug, Clone)]
pub struct Kernel {
    pub name: String,
    pub kind: KernelKind,
    /// FLOP for one pipeline input (pre-sharding); derived from `kind` but
    /// overridable by builders that aggregate (e.g. HPL step folding).
    pub flops: f64,
    /// Resident parameter bytes (weights stay on-chip/in DRAM for the
    /// kernel's lifetime; counted against SRAM when the kernel is fused).
    pub weight_bytes: f64,
}

/// A tensor (graph edge): single producer, single consumer.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub src: KernelId,
    pub dst: KernelId,
    /// Size in bytes for one pipeline input (pre-sharding).
    pub bytes: f64,
}

/// Validation failures for hand-built graphs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    BadKernelId(usize),
    Cycle(String),
    SelfLoop(String),
    Empty,
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::BadKernelId(id) => write!(f, "kernel id {id} out of range"),
            GraphError::Cycle(k) => write!(f, "graph has a cycle involving kernel '{k}'"),
            GraphError::SelfLoop(t) => write!(f, "tensor '{t}' is a self-loop"),
            GraphError::Empty => write!(f, "graph is empty"),
        }
    }
}

impl std::error::Error for GraphError {}

/// The workload dataflow graph.
#[derive(Debug, Clone, Default)]
pub struct DataflowGraph {
    pub name: String,
    pub kernels: Vec<Kernel>,
    pub tensors: Vec<Tensor>,
}

impl DataflowGraph {
    pub fn n_kernels(&self) -> usize {
        self.kernels.len()
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    pub fn kernel(&self, id: KernelId) -> &Kernel {
        &self.kernels[id.0]
    }

    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id.0]
    }

    /// Total FLOP over all kernels (one pipeline input).
    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }

    /// Total tensor traffic in bytes (one pipeline input).
    pub fn total_tensor_bytes(&self) -> f64 {
        self.tensors.iter().map(|t| t.bytes).sum()
    }

    /// Total resident weight bytes.
    pub fn total_weight_bytes(&self) -> f64 {
        self.kernels.iter().map(|k| k.weight_bytes).sum()
    }

    /// Outgoing tensors per kernel.
    pub fn out_edges(&self, k: KernelId) -> impl Iterator<Item = (TensorId, &Tensor)> {
        self.tensors
            .iter()
            .enumerate()
            .filter(move |(_, t)| t.src == k)
            .map(|(i, t)| (TensorId(i), t))
    }

    /// Incoming tensors per kernel.
    pub fn in_edges(&self, k: KernelId) -> impl Iterator<Item = (TensorId, &Tensor)> {
        self.tensors
            .iter()
            .enumerate()
            .filter(move |(_, t)| t.dst == k)
            .map(|(i, t)| (TensorId(i), t))
    }

    /// Structural validation: ids in range, no self-loops, acyclic.
    pub fn validate(&self) -> Result<(), GraphError> {
        if self.kernels.is_empty() {
            return Err(GraphError::Empty);
        }
        for t in &self.tensors {
            if t.src.0 >= self.kernels.len() {
                return Err(GraphError::BadKernelId(t.src.0));
            }
            if t.dst.0 >= self.kernels.len() {
                return Err(GraphError::BadKernelId(t.dst.0));
            }
            if t.src == t.dst {
                return Err(GraphError::SelfLoop(t.name.clone()));
            }
        }
        self.topo_order().map(|_| ())
    }

    /// Kahn topological order; error (naming a cycle member) if cyclic.
    pub fn topo_order(&self) -> Result<Vec<KernelId>, GraphError> {
        let n = self.kernels.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for t in &self.tensors {
            indeg[t.dst.0] += 1;
            adj[t.src.0].push(t.dst.0);
        }
        // Stable queue: lowest id first, so builder insertion order is the
        // canonical topo order (the optimizers rely on this determinism).
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<usize>> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| std::cmp::Reverse(i))
            .collect();
        let mut order = Vec::with_capacity(n);
        while let Some(std::cmp::Reverse(u)) = heap.pop() {
            order.push(KernelId(u));
            for &v in &adj[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    heap.push(std::cmp::Reverse(v));
                }
            }
        }
        if order.len() != n {
            let stuck = indeg.iter().position(|&d| d > 0).unwrap();
            return Err(GraphError::Cycle(self.kernels[stuck].name.clone()));
        }
        Ok(order)
    }

    /// True if kernel `a` reaches kernel `b` through tensor edges.
    pub fn reaches(&self, a: KernelId, b: KernelId) -> bool {
        let n = self.kernels.len();
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for t in &self.tensors {
            adj[t.src.0].push(t.dst.0);
        }
        let mut seen = vec![false; n];
        let mut stack = vec![a.0];
        while let Some(u) = stack.pop() {
            if u == b.0 {
                return true;
            }
            if std::mem::replace(&mut seen[u], true) {
                continue;
            }
            stack.extend(adj[u].iter().copied());
        }
        false
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} kernels, {} tensors, {:.3e} FLOP, {:.3e} B activations, {:.3e} B weights",
            self.name,
            self.n_kernels(),
            self.n_tensors(),
            self.total_flops(),
            self.total_tensor_bytes(),
            self.total_weight_bytes()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(n: usize) -> DataflowGraph {
        let mut b = GraphBuilder::new("chain");
        let mut prev = None;
        for i in 0..n {
            let k = b.kernel(
                &format!("k{i}"),
                KernelKind::Elementwise { elems: 100.0, flop_per_elem: 1.0 },
                0.0,
            );
            if let Some(p) = prev {
                b.tensor("t", p, k, 64.0);
            }
            prev = Some(k);
        }
        b.build()
    }

    #[test]
    fn topo_order_of_chain_is_insertion_order() {
        let g = chain(5);
        assert!(g.validate().is_ok());
        let order = g.topo_order().unwrap();
        assert_eq!(order, (0..5).map(KernelId).collect::<Vec<_>>());
    }

    #[test]
    fn cycle_detected() {
        let mut g = chain(3);
        g.tensors.push(Tensor {
            name: "back".into(),
            src: KernelId(2),
            dst: KernelId(0),
            bytes: 1.0,
        });
        assert!(matches!(g.validate(), Err(GraphError::Cycle(_))));
    }

    #[test]
    fn self_loop_detected() {
        let mut g = chain(2);
        g.tensors.push(Tensor {
            name: "loop".into(),
            src: KernelId(1),
            dst: KernelId(1),
            bytes: 1.0,
        });
        assert_eq!(g.validate(), Err(GraphError::SelfLoop("loop".into())));
    }

    #[test]
    fn bad_id_detected() {
        let mut g = chain(2);
        g.tensors.push(Tensor {
            name: "bad".into(),
            src: KernelId(0),
            dst: KernelId(9),
            bytes: 1.0,
        });
        assert_eq!(g.validate(), Err(GraphError::BadKernelId(9)));
    }

    #[test]
    fn empty_graph_invalid() {
        let g = DataflowGraph::default();
        assert_eq!(g.validate(), Err(GraphError::Empty));
    }

    #[test]
    fn kind_flops() {
        assert_eq!(KernelKind::Gemm { b: 1.0, m: 2.0, k: 3.0, n: 4.0 }.flops(), 48.0);
        assert_eq!(KernelKind::Softmax { rows: 2.0, cols: 10.0 }.flops(), 100.0);
        assert_eq!(KernelKind::Transpose { elems: 50.0 }.flops(), 0.0);
        let fft = KernelKind::Fft { points: 1024.0, batch: 2.0 };
        assert!((fft.flops() - 5.0 * 1024.0 * 2.0 * 10.0).abs() < 1e-6);
    }

    #[test]
    fn reachability() {
        let g = chain(4);
        assert!(g.reaches(KernelId(0), KernelId(3)));
        assert!(!g.reaches(KernelId(3), KernelId(0)));
    }

    #[test]
    fn totals_accumulate() {
        let g = chain(3);
        assert_eq!(g.total_flops(), 300.0);
        assert_eq!(g.total_tensor_bytes(), 128.0);
    }

    #[test]
    fn edges_iterators() {
        let g = chain(3);
        assert_eq!(g.out_edges(KernelId(0)).count(), 1);
        assert_eq!(g.in_edges(KernelId(0)).count(), 0);
        assert_eq!(g.in_edges(KernelId(1)).count(), 1);
    }
}
