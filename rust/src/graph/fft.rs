//! Distributed FFT workload builder (the paper's 1T-point FFT [44], [76]):
//! 3-D volumetric (pencil) decomposition — three 1-D FFT stages along x/y/z
//! with two global transposes between them. The transposes are the
//! all-to-all exchanges that make FFT network-bound on slow interconnects
//! (Figs 16/17).

use super::{DataflowGraph, GraphBuilder, KernelKind};

#[derive(Debug, Clone, Copy)]
pub struct FftConfig {
    /// Total points (the paper's headline: 1e12).
    pub points: f64,
    pub dtype_bytes: f64, // complex64 = 8 bytes
}

pub fn fft_1t() -> FftConfig {
    FftConfig { points: 1e12, dtype_bytes: 8.0 }
}

impl FftConfig {
    /// Points along one axis of the cubic volume.
    pub fn axis(&self) -> f64 {
        self.points.cbrt().round()
    }

    /// Total FLOP: 5·N·log2(N) for a complex transform.
    pub fn total_flops(&self) -> f64 {
        5.0 * self.points * self.points.log2()
    }

    /// Bytes moved by each global transpose (the whole volume).
    pub fn transpose_bytes(&self) -> f64 {
        self.points * self.dtype_bytes
    }
}

/// Pencil-decomposed 3-D FFT graph: FFTx → T1 → FFTy → T2 → FFTz.
pub fn fft_graph(cfg: &FftConfig) -> DataflowGraph {
    let mut b = GraphBuilder::new(&format!("fft[{:.0e}pt]", cfg.points));
    let n1 = cfg.axis();
    let batch = cfg.points / n1; // pencils per stage
    let vol = cfg.transpose_bytes();

    let fx = b.kernel("FFTx", KernelKind::Fft { points: n1, batch }, 0.0);
    let t1 = b.kernel("Transpose1", KernelKind::Transpose { elems: cfg.points }, 0.0);
    let fy = b.kernel("FFTy", KernelKind::Fft { points: n1, batch }, 0.0);
    let t2 = b.kernel("Transpose2", KernelKind::Transpose { elems: cfg.points }, 0.0);
    let fz = b.kernel("FFTz", KernelKind::Fft { points: n1, batch }, 0.0);

    b.tensor("x_out", fx, t1, vol);
    b.tensor("t1_out", t1, fy, vol);
    b.tensor("y_out", fy, t2, vol);
    b.tensor("t2_out", t2, fz, vol);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_flops_sum_to_5nlogn() {
        let cfg = fft_1t();
        let g = fft_graph(&cfg);
        let got = g.total_flops();
        let want = cfg.total_flops();
        // 3 stages of 5·N·log2(N^(1/3)) = 5·N·log2(N)
        assert!((got / want - 1.0).abs() < 0.01, "got {got:.4e} want {want:.4e}");
    }

    #[test]
    fn graph_structure() {
        let g = fft_graph(&fft_1t());
        g.validate().unwrap();
        assert_eq!(g.n_kernels(), 5);
        assert_eq!(g.n_tensors(), 4);
        let transposes = g.kernels.iter().filter(|k| k.flops == 0.0).count();
        assert_eq!(transposes, 2);
    }

    #[test]
    fn axis_is_cube_root() {
        assert_eq!(fft_1t().axis(), 1e4);
    }
}
