//! Llama-3 family configurations (serving case studies, §VIII-A/B) and
//! prefill/decode graph builders.
//!
//! Llama differs from GPT-3 in three ways that matter to the model:
//! grouped-query attention (fewer K/V heads), SwiGLU FFN (three weight
//! matrices), and no biases. Decode processes one token per sequence with
//! the KV cache streamed from memory — the memory-bound regime of Fig. 20.

use super::{DataflowGraph, GraphBuilder, KernelKind};

#[derive(Debug, Clone, Copy)]
pub struct LlamaConfig {
    pub layers: usize,
    pub d_model: f64,
    pub n_heads: f64,
    pub n_kv_heads: f64,
    pub d_ff: f64,
    pub vocab: f64,
    pub dtype_bytes: f64,
}

pub fn llama3_8b() -> LlamaConfig {
    LlamaConfig {
        layers: 32,
        d_model: 4096.0,
        n_heads: 32.0,
        n_kv_heads: 8.0,
        d_ff: 14336.0,
        vocab: 128256.0,
        dtype_bytes: 2.0,
    }
}

pub fn llama3_70b() -> LlamaConfig {
    LlamaConfig {
        layers: 80,
        d_model: 8192.0,
        n_heads: 64.0,
        n_kv_heads: 8.0,
        d_ff: 28672.0,
        vocab: 128256.0,
        dtype_bytes: 2.0,
    }
}

pub fn llama3_405b() -> LlamaConfig {
    LlamaConfig {
        layers: 126,
        d_model: 16384.0,
        n_heads: 128.0,
        n_kv_heads: 8.0,
        d_ff: 53248.0,
        vocab: 128256.0,
        dtype_bytes: 2.0,
    }
}

/// The 68M draft model used by SpecInfer-style tree decoding (§VIII-B).
pub fn llama_68m() -> LlamaConfig {
    LlamaConfig {
        layers: 2,
        d_model: 768.0,
        n_heads: 12.0,
        n_kv_heads: 12.0,
        d_ff: 3072.0,
        vocab: 32000.0,
        dtype_bytes: 2.0,
    }
}

impl LlamaConfig {
    pub fn head_dim(&self) -> f64 {
        self.d_model / self.n_heads
    }

    /// Per-layer params: Q (h²), O (h²), K/V (2·h·kv_dim), SwiGLU (3·h·d_ff).
    pub fn params_per_layer(&self) -> f64 {
        let kv_dim = self.n_kv_heads * self.head_dim();
        2.0 * self.d_model * self.d_model
            + 2.0 * self.d_model * kv_dim
            + 3.0 * self.d_model * self.d_ff
    }

    pub fn params(&self) -> f64 {
        self.layers as f64 * self.params_per_layer()
            + 2.0 * self.vocab * self.d_model // embed + lm head
    }

    /// FLOP to process one token through the whole stack (fwd).
    pub fn fwd_flops_per_token(&self, context: f64) -> f64 {
        // 2 FLOP per param-MAC + attention over `context` tokens
        2.0 * self.params()
            + self.layers as f64 * 4.0 * context * self.d_model
    }

    /// KV-cache bytes per token of context.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.layers as f64 * self.n_kv_heads * self.head_dim() * self.dtype_bytes
    }

    pub fn weight_bytes(&self) -> f64 {
        self.params() * self.dtype_bytes
    }
}

/// Prefill graph: a whole prompt of `prompt_len` tokens through one layer
/// (the serving model multiplies per-layer times by `layers`). Structure
/// mirrors `gpt::add_layer` with GQA-sized K/V and SwiGLU.
pub fn prefill_layer_graph(cfg: &LlamaConfig, batch: f64, prompt_len: f64) -> DataflowGraph {
    let mut b = GraphBuilder::new("llama-prefill-layer");
    let (h, f) = (cfg.d_model, cfg.d_ff);
    let kv_dim = cfg.n_kv_heads * cfg.head_dim();
    let t = batch * prompt_len;
    let dt = cfg.dtype_bytes;
    let act = t * h * dt;

    let ln = b.kernel("RMSNorm", KernelKind::LayerNorm { rows: t, cols: h }, h * dt);
    let q = b.kernel("Q", KernelKind::Gemm { b: 1.0, m: t, k: h, n: h }, h * h * dt);
    let k = b.kernel("K", KernelKind::Gemm { b: 1.0, m: t, k: h, n: kv_dim }, h * kv_dim * dt);
    let v = b.kernel("V", KernelKind::Gemm { b: 1.0, m: t, k: h, n: kv_dim }, h * kv_dim * dt);
    b.replicate("ln_out", ln, &[q, k, v], act);

    let attn = b.kernel(
        "Attn",
        KernelKind::Gemm { b: batch * cfg.n_heads, m: prompt_len, k: cfg.head_dim(), n: 2.0 * prompt_len },
        0.0,
    );
    b.tensor("q_out", q, attn, act);
    b.tensor("k_out", k, attn, t * kv_dim * dt);
    b.tensor("v_out", v, attn, t * kv_dim * dt);

    let o = b.kernel("O", KernelKind::Gemm { b: 1.0, m: t, k: h, n: h }, h * h * dt);
    b.tensor("attn_out", attn, o, act);

    let gate = b.kernel("Gate", KernelKind::Gemm { b: 1.0, m: t, k: h, n: f }, h * f * dt);
    let up = b.kernel("Up", KernelKind::Gemm { b: 1.0, m: t, k: h, n: f }, h * f * dt);
    b.replicate("o_out", o, &[gate, up], act);
    let silu = b.kernel("SiLUMul", KernelKind::Elementwise { elems: t * f, flop_per_elem: 6.0 }, 0.0);
    b.tensor("gate_out", gate, silu, t * f * dt);
    b.tensor("up_out", up, silu, t * f * dt);
    let down = b.kernel("Down", KernelKind::Gemm { b: 1.0, m: t, k: f, n: h }, f * h * dt);
    b.tensor("silu_out", silu, down, t * f * dt);
    b.build()
}

/// Decode step graph for one layer: batch sequences × one new token each,
/// attending over `context` cached tokens. GEMV-shaped — memory-bound.
pub fn decode_layer_graph(cfg: &LlamaConfig, batch: f64, context: f64) -> DataflowGraph {
    prefill_layer_graph_inner_decode(cfg, batch, context)
}

fn prefill_layer_graph_inner_decode(cfg: &LlamaConfig, batch: f64, context: f64) -> DataflowGraph {
    let mut b = GraphBuilder::new("llama-decode-layer");
    let (h, f) = (cfg.d_model, cfg.d_ff);
    let kv_dim = cfg.n_kv_heads * cfg.head_dim();
    let dt = cfg.dtype_bytes;
    let act = batch * h * dt;

    let ln = b.kernel("RMSNorm", KernelKind::LayerNorm { rows: batch, cols: h }, h * dt);
    let q = b.kernel("Q", KernelKind::Gemm { b: 1.0, m: batch, k: h, n: h }, h * h * dt);
    let k = b.kernel("K", KernelKind::Gemm { b: 1.0, m: batch, k: h, n: kv_dim }, h * kv_dim * dt);
    let v = b.kernel("V", KernelKind::Gemm { b: 1.0, m: batch, k: h, n: kv_dim }, h * kv_dim * dt);
    b.replicate("ln_out", ln, &[q, k, v], act);

    // score + context GEMVs against the KV cache: weight_bytes models the
    // cache bytes that must stream from memory every step.
    let kv_cache_bytes = batch * context * cfg.kv_bytes_per_token() / cfg.layers as f64;
    let attn = b.kernel(
        "Attn",
        KernelKind::Gemm { b: batch * cfg.n_heads, m: 1.0, k: cfg.head_dim(), n: 2.0 * context },
        kv_cache_bytes,
    );
    b.tensor("q_out", q, attn, act);
    b.tensor("k_out", k, attn, batch * kv_dim * dt);
    b.tensor("v_out", v, attn, batch * kv_dim * dt);

    let o = b.kernel("O", KernelKind::Gemm { b: 1.0, m: batch, k: h, n: h }, h * h * dt);
    b.tensor("attn_out", attn, o, act);
    let gate = b.kernel("Gate", KernelKind::Gemm { b: 1.0, m: batch, k: h, n: f }, h * f * dt);
    let up = b.kernel("Up", KernelKind::Gemm { b: 1.0, m: batch, k: h, n: f }, h * f * dt);
    b.replicate("o_out", o, &[gate, up], act);
    let silu = b.kernel("SiLUMul", KernelKind::Elementwise { elems: batch * f, flop_per_elem: 6.0 }, 0.0);
    b.tensor("gate_out", gate, silu, batch * f * dt);
    b.tensor("up_out", up, silu, batch * f * dt);
    let down = b.kernel("Down", KernelKind::Gemm { b: 1.0, m: batch, k: f, n: h }, f * h * dt);
    b.tensor("silu_out", silu, down, batch * f * dt);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_near_published() {
        assert!((llama3_8b().params() / 8.0e9 - 1.0).abs() < 0.1);
        assert!((llama3_70b().params() / 70.6e9 - 1.0).abs() < 0.1);
        assert!((llama3_405b().params() / 405e9 - 1.0).abs() < 0.1);
        let m = llama_68m().params();
        assert!((m / 68e6 - 1.0).abs() < 0.6, "68M params = {m:.3e}");
    }

    #[test]
    fn prefill_graph_validates() {
        let g = prefill_layer_graph(&llama3_8b(), 1.0, 1024.0);
        g.validate().unwrap();
        assert_eq!(g.n_kernels(), 10);
    }

    #[test]
    fn decode_is_memory_heavy() {
        let cfg = llama3_8b();
        let g = decode_layer_graph(&cfg, 16.0, 2048.0);
        g.validate().unwrap();
        // bytes (weights + kv) per FLOP far above prefill's
        let decode_oi = g.total_flops() / g.total_weight_bytes();
        let p = prefill_layer_graph(&cfg, 1.0, 1024.0);
        let prefill_oi = p.total_flops() / p.total_weight_bytes();
        assert!(prefill_oi > 20.0 * decode_oi, "prefill {prefill_oi} decode {decode_oi}");
    }

    #[test]
    fn kv_cache_bytes() {
        let cfg = llama3_8b();
        // 2 * 32 layers * 8 kv heads * 128 head_dim * 2 bytes
        assert_eq!(cfg.kv_bytes_per_token(), 2.0 * 32.0 * 8.0 * 128.0 * 2.0);
    }
}
