//! GPT / LLM workload builders (Fig. 2A): the per-layer transformer
//! dataflow graph plus model-scale configurations used in the evaluation
//! (GPT3 175B, GPT3 1T, the §VIII-C 100T projection, and the Llama3 family
//! for serving).

use super::{DataflowGraph, GraphBuilder, KernelId, KernelKind};

/// Model-architecture description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GptConfig {
    pub layers: usize,
    pub d_model: f64,
    pub n_heads: f64,
    pub seq: f64,
    pub d_ff: f64,
    pub vocab: f64,
    /// Bytes per parameter/activation element (2 = bf16).
    pub dtype_bytes: f64,
}

impl GptConfig {
    pub fn head_dim(&self) -> f64 {
        self.d_model / self.n_heads
    }

    /// Parameter count: QKV+Proj (4h²) + FFN (2·h·d_ff) per layer.
    pub fn params(&self) -> f64 {
        let per_layer = 4.0 * self.d_model * self.d_model + 2.0 * self.d_model * self.d_ff;
        self.layers as f64 * per_layer
    }

    /// Forward FLOP per token: 2·params + attention term (4·s·h per layer
    /// counted once per token: 2 score + 2 context matmuls).
    pub fn fwd_flops_per_token(&self) -> f64 {
        2.0 * self.params() + self.layers as f64 * 4.0 * self.seq * self.d_model
    }

    /// Training FLOP per token (fwd + 2× bwd — the standard 3× rule the
    /// paper's referenced models use).
    pub fn train_flops_per_token(&self) -> f64 {
        3.0 * self.fwd_flops_per_token()
    }

    /// KV-cache bytes per token (serving): 2 tensors × h per layer.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.layers as f64 * self.d_model * self.dtype_bytes
    }
}

/// GPT3 175B (Brown et al. [16]): 96 layers, h = 12288, 96 heads, seq 2048.
pub fn gpt3_175b() -> GptConfig {
    GptConfig {
        layers: 96,
        d_model: 12288.0,
        n_heads: 96.0,
        seq: 2048.0,
        d_ff: 4.0 * 12288.0,
        vocab: 50257.0,
        dtype_bytes: 2.0,
    }
}

/// GPT3 1T (Calculon's trillion-parameter configuration):
/// 128 layers, h = 25600, 160 heads, seq 2048 → ≈1.01e12 params.
pub fn gpt3_1t() -> GptConfig {
    GptConfig {
        layers: 128,
        d_model: 25600.0,
        n_heads: 160.0,
        seq: 2048.0,
        d_ff: 4.0 * 25600.0,
        vocab: 51200.0,
        dtype_bytes: 2.0,
    }
}

/// Projected 100T model (§VIII-C, scaling law from Megatron [62]):
/// 512 layers, h = 128000 → ≈1.01e14 params.
pub fn gpt_100t() -> GptConfig {
    GptConfig {
        layers: 512,
        d_model: 128_000.0,
        n_heads: 1000.0,
        seq: 2048.0,
        d_ff: 4.0 * 128_000.0,
        vocab: 51200.0,
        dtype_bytes: 2.0,
    }
}

/// Names of the 14 per-layer kernels in graph order (Fig. 2A).
pub const LAYER_KERNELS: [&str; 14] = [
    "LN1", "Q", "K", "V", "MHA1", "Softmax", "MHA2", "Proj", "Add1", "LN2", "FFN0", "GeLU",
    "FFN1", "Add2",
];

/// Append one transformer layer's 14-kernel subgraph to `b`.
///
/// `input` is the kernel whose output feeds this layer (None for the first
/// layer — the graph input). Returns the layer's final kernel (Add2).
/// `batch` = sequences per pipeline input (microbatch).
pub fn add_layer(
    b: &mut GraphBuilder,
    cfg: &GptConfig,
    batch: f64,
    layer: usize,
    input: Option<KernelId>,
) -> KernelId {
    let (h, s, f, heads) = (cfg.d_model, cfg.seq, cfg.d_ff, cfg.n_heads);
    let hd = cfg.head_dim();
    let t = batch * s; // tokens per pipeline input
    let dt = cfg.dtype_bytes;
    let act = t * h * dt; // [tokens, h] activation bytes
    let l = |n: &str| format!("L{layer}.{n}");

    let ln1 = b.kernel(&l("LN1"), KernelKind::LayerNorm { rows: t, cols: h }, 2.0 * h * dt);
    if let Some(prev) = input {
        b.tensor(&l("in"), prev, ln1, act);
    }
    let q = b.kernel(&l("Q"), KernelKind::Gemm { b: 1.0, m: t, k: h, n: h }, h * h * dt);
    let k = b.kernel(&l("K"), KernelKind::Gemm { b: 1.0, m: t, k: h, n: h }, h * h * dt);
    let v = b.kernel(&l("V"), KernelKind::Gemm { b: 1.0, m: t, k: h, n: h }, h * h * dt);
    b.replicate(&l("ln1_out"), ln1, &[q, k, v], act);

    let mha1 =
        b.kernel(&l("MHA1"), KernelKind::Gemm { b: batch * heads, m: s, k: hd, n: s }, 0.0);
    b.tensor(&l("q_out"), q, mha1, act);
    b.tensor(&l("k_out"), k, mha1, act);

    let sm = b.kernel(&l("Softmax"), KernelKind::Softmax { rows: batch * heads * s, cols: s }, 0.0);
    let scores = batch * heads * s * s * dt;
    b.tensor(&l("scores"), mha1, sm, scores);

    let mha2 =
        b.kernel(&l("MHA2"), KernelKind::Gemm { b: batch * heads, m: s, k: s, n: hd }, 0.0);
    b.tensor(&l("probs"), sm, mha2, scores);
    b.tensor(&l("v_out"), v, mha2, act);

    let proj = b.kernel(&l("Proj"), KernelKind::Gemm { b: 1.0, m: t, k: h, n: h }, h * h * dt);
    b.tensor(&l("attn"), mha2, proj, act);

    let add1 = b.kernel(&l("Add1"), KernelKind::Elementwise { elems: t * h, flop_per_elem: 1.0 }, 0.0);
    b.tensor(&l("proj_out"), proj, add1, act);
    if let Some(prev) = input {
        // residual: the layer input also feeds Add1 (replicated edge)
        b.tensor(&l("residual1"), prev, add1, act);
    }

    let ln2 = b.kernel(&l("LN2"), KernelKind::LayerNorm { rows: t, cols: h }, 2.0 * h * dt);
    let ffn0 = b.kernel(&l("FFN0"), KernelKind::Gemm { b: 1.0, m: t, k: h, n: f }, h * f * dt);
    let gelu = b.kernel(&l("GeLU"), KernelKind::Elementwise { elems: t * f, flop_per_elem: 10.0 }, 0.0);
    let ffn1 = b.kernel(&l("FFN1"), KernelKind::Gemm { b: 1.0, m: t, k: f, n: h }, f * h * dt);
    let add2 = b.kernel(&l("Add2"), KernelKind::Elementwise { elems: t * h, flop_per_elem: 1.0 }, 0.0);

    b.replicate(&l("add1_out"), add1, &[ln2, add2], act);
    b.tensor(&l("ln2_out"), ln2, ffn0, act);
    b.tensor(&l("ffn0_out"), ffn0, gelu, t * f * dt);
    b.tensor(&l("gelu_out"), gelu, ffn1, t * f * dt);
    b.tensor(&l("ffn1_out"), ffn1, add2, act);
    add2
}

/// Fine-grained graph: `layers` × 14 kernels (Fig. 2A replicated).
pub fn gpt_graph(cfg: &GptConfig, batch: f64, layers: usize) -> DataflowGraph {
    assert!(layers >= 1);
    let mut b = GraphBuilder::new(&format!("gpt[{layers}L,h={}]", cfg.d_model));
    let mut prev = None;
    for l in 0..layers {
        prev = Some(add_layer(&mut b, cfg, batch, l, prev));
    }
    b.build()
}

/// Single-layer graph (the unit of intra-chip optimization, §V / §VII).
pub fn gpt_layer_graph(cfg: &GptConfig, batch: f64) -> DataflowGraph {
    gpt_graph(cfg, batch, 1)
}

/// Coarse graph: one aggregated kernel per transformer layer (the unit of
/// inter-chip PP partitioning at model scale, like Calculon/Megatron treat
/// stages as layer groups).
pub fn gpt_coarse_graph(cfg: &GptConfig, batch: f64) -> DataflowGraph {
    let mut b = GraphBuilder::new(&format!("gpt-coarse[{}L]", cfg.layers));
    let t = batch * cfg.seq;
    let act = t * cfg.d_model * cfg.dtype_bytes;
    let layer_flops = cfg.fwd_flops_per_token() * t / cfg.layers as f64;
    let layer_weights = cfg.params() / cfg.layers as f64 * cfg.dtype_bytes;
    let mut prev: Option<KernelId> = None;
    for l in 0..cfg.layers {
        let k = b.kernel_with_flops(
            &format!("layer{l}"),
            KernelKind::FusedLayer { tokens: t, width: cfg.d_model },
            layer_flops,
            layer_weights,
        );
        if let Some(p) = prev {
            b.tensor(&format!("act{l}"), p, k, act);
        }
        prev = Some(k);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_hit_published_param_counts() {
        let p175 = gpt3_175b().params();
        assert!((p175 / 175e9 - 1.0).abs() < 0.02, "175B params = {p175:.3e}");
        let p1t = gpt3_1t().params();
        assert!((p1t / 1e12 - 1.0).abs() < 0.02, "1T params = {p1t:.3e}");
        let p100t = gpt_100t().params();
        assert!((p100t / 100e12 - 1.0).abs() < 0.02, "100T params = {p100t:.3e}");
    }

    #[test]
    fn layer_graph_matches_fig2a() {
        let g = gpt_layer_graph(&gpt3_175b(), 1.0);
        assert_eq!(g.n_kernels(), 14);
        g.validate().unwrap();
        for name in LAYER_KERNELS {
            assert!(
                g.kernels.iter().any(|k| k.name.ends_with(name)),
                "missing kernel {name}"
            );
        }
    }

    #[test]
    fn graph_flops_match_analytic_formula() {
        let cfg = gpt3_175b();
        let batch = 4.0;
        let g = gpt_layer_graph(&cfg, batch);
        let per_layer_analytic =
            cfg.fwd_flops_per_token() * batch * cfg.seq / cfg.layers as f64;
        let ratio = g.total_flops() / per_layer_analytic;
        // graph includes softmax/LN/GeLU extras the closed form omits
        assert!((0.98..1.05).contains(&ratio), "ratio = {ratio}");
    }

    #[test]
    fn coarse_graph_preserves_totals() {
        let cfg = gpt3_1t();
        let g = gpt_coarse_graph(&cfg, 1.0);
        assert_eq!(g.n_kernels(), cfg.layers);
        let want = cfg.fwd_flops_per_token() * cfg.seq;
        assert!((g.total_flops() / want - 1.0).abs() < 1e-9);
        let wbytes = g.total_weight_bytes();
        assert!((wbytes / (cfg.params() * 2.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn multilayer_graph_chains() {
        let g = gpt_graph(&gpt3_175b(), 1.0, 3);
        assert_eq!(g.n_kernels(), 42);
        g.validate().unwrap();
        // layer boundaries: Add2 of layer l feeds LN1 and Add1 of layer l+1
        let add2_l0 = g.kernels.iter().position(|k| k.name == "L0.Add2").unwrap();
        let ln1_l1 = g.kernels.iter().position(|k| k.name == "L1.LN1").unwrap();
        assert!(g.reaches(KernelId(add2_l0), KernelId(ln1_l1)));
    }

    #[test]
    fn kv_cache_formula() {
        let cfg = gpt3_175b();
        // 2 * layers * h * 2 bytes
        assert_eq!(cfg.kv_bytes_per_token(), 2.0 * 96.0 * 12288.0 * 2.0);
    }
}
