//! High-Performance LINPACK workload builder (the paper's 5M² HPL [4]):
//! right-looking blocked LU factorization. Per block-step k of panel width
//! nb over trailing matrix size m = N - k·nb:
//!   Panel (getrf)   — m·nb² FLOP class, modeled as a thin GEMM
//!   TRSM            — nb²·m triangular solves, GEMM-like
//!   Update (gemm)   — 2·m²·nb FLOP, the dominant term
//!
//! Steps are folded into `groups` aggregated step-groups so the graph stays
//! optimizer-sized while preserving the exact 2/3·N³ total FLOP; a test
//! asserts the invariant.

use super::{DataflowGraph, GraphBuilder, KernelKind};

#[derive(Debug, Clone, Copy)]
pub struct HplConfig {
    /// Matrix dimension N (the paper's headline run: 5e6).
    pub n: f64,
    /// Panel/block width.
    pub nb: f64,
    /// Number of aggregated step-groups in the graph.
    pub groups: usize,
    pub dtype_bytes: f64, // HPL is fp64
}

pub fn hpl_5m() -> HplConfig {
    HplConfig { n: 5e6, nb: 512.0, groups: 32, dtype_bytes: 8.0 }
}

impl HplConfig {
    /// Total LU FLOP: 2/3·N³ (+ lower-order N² terms we ignore).
    pub fn total_flops(&self) -> f64 {
        2.0 / 3.0 * self.n * self.n * self.n
    }

    /// Matrix storage bytes.
    pub fn matrix_bytes(&self) -> f64 {
        self.n * self.n * self.dtype_bytes
    }
}

/// Build the blocked-LU dataflow graph: `groups` sequential step-groups of
/// {Panel → TRSM → Update}, with the trailing-matrix tensor flowing between
/// groups.
pub fn hpl_graph(cfg: &HplConfig) -> DataflowGraph {
    let mut b = GraphBuilder::new(&format!("hpl[N={:.0}]", cfg.n));
    let steps_total = (cfg.n / cfg.nb).floor();
    let steps_per_group = steps_total / cfg.groups as f64;

    let mut prev = None;
    for g in 0..cfg.groups {
        // Average trailing size over this group's steps (exact integral of
        // the per-step m = N - k·nb over the group, so totals are preserved).
        let k_lo = g as f64 * steps_per_group;
        let k_hi = (g + 1) as f64 * steps_per_group;
        // ∫ (N - k·nb)² dk over [k_lo, k_hi) — gives exact Σ 2·m²·nb FLOP.
        let integral_m2 = {
            let f = |k: f64| {
                let m = cfg.n - k * cfg.nb;
                -m * m * m / (3.0 * cfg.nb)
            };
            f(k_hi) - f(k_lo)
        };
        let update_flops = 2.0 * cfg.nb * integral_m2;
        let m_avg = cfg.n - (k_lo + k_hi) / 2.0 * cfg.nb;

        let panel = b.kernel_with_flops(
            &format!("G{g}.Panel"),
            KernelKind::Gemm { b: 1.0, m: m_avg, k: cfg.nb, n: cfg.nb },
            steps_per_group * m_avg * cfg.nb * cfg.nb,
            0.0,
        );
        let trsm = b.kernel_with_flops(
            &format!("G{g}.TRSM"),
            KernelKind::Gemm { b: 1.0, m: cfg.nb, k: cfg.nb, n: m_avg },
            steps_per_group * cfg.nb * cfg.nb * m_avg,
            0.0,
        );
        let update = b.kernel_with_flops(
            &format!("G{g}.Update"),
            KernelKind::Gemm { b: 1.0, m: m_avg, k: cfg.nb, n: m_avg },
            update_flops,
            0.0,
        );

        // Panel columns broadcast to TRSM; L/U panels feed the update.
        let panel_bytes = m_avg * cfg.nb * cfg.dtype_bytes;
        b.tensor(&format!("G{g}.panel_out"), panel, trsm, panel_bytes);
        b.tensor(&format!("G{g}.u_panel"), trsm, update, panel_bytes);
        if let Some(p) = prev {
            // trailing matrix carried between groups
            b.tensor(&format!("G{g}.trailing"), p, panel, m_avg * m_avg * cfg.dtype_bytes);
        }
        prev = Some(update);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_flops_sum_to_two_thirds_n_cubed() {
        let cfg = hpl_5m();
        let g = hpl_graph(&cfg);
        let update: f64 = g
            .kernels
            .iter()
            .filter(|k| k.name.ends_with("Update"))
            .map(|k| k.flops)
            .sum();
        let want = cfg.total_flops();
        assert!((update / want - 1.0).abs() < 0.01, "update = {update:.4e}, want {want:.4e}");
    }

    #[test]
    fn graph_validates_and_chains() {
        let cfg = HplConfig { n: 1e5, nb: 256.0, groups: 8, dtype_bytes: 8.0 };
        let g = hpl_graph(&cfg);
        g.validate().unwrap();
        assert_eq!(g.n_kernels(), 3 * 8);
        // later groups have smaller trailing updates
        let flops: Vec<f64> = g
            .kernels
            .iter()
            .filter(|k| k.name.ends_with("Update"))
            .map(|k| k.flops)
            .collect();
        assert!(flops.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn matrix_bytes() {
        assert_eq!(hpl_5m().matrix_bytes(), 5e6 * 5e6 * 8.0);
    }
}
