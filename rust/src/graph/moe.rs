//! Mixture-of-Experts workload extension (the paper's future-work
//! direction: "designing large-scale systems for future workloads").
//!
//! An MoE transformer layer replaces the dense FFN with `n_experts` expert
//! FFNs of which each token visits `top_k`; the router's token shuffle is
//! an all-to-all at the inter-chip level — modeled here as Embedding-style
//! kernels whose table sharding carries the dispatch/combine all-to-alls.
//! This exercises the same machinery the DLRM workload does, with the
//! attention block of a GPT layer in front.

use super::{DataflowGraph, GraphBuilder, KernelKind};

#[derive(Debug, Clone, Copy)]
pub struct MoeConfig {
    pub layers: usize,
    pub d_model: f64,
    pub n_heads: f64,
    pub seq: f64,
    pub d_ff: f64,
    pub n_experts: f64,
    pub top_k: f64,
    pub vocab: f64,
    pub dtype_bytes: f64,
}

/// A ~1T-total-parameter MoE with GPT3-medium dense dims (Switch-style:
/// most parameters in experts, ~13B active per token).
pub fn moe_gpt_1t() -> MoeConfig {
    MoeConfig {
        layers: 24,
        d_model: 4096.0,
        n_heads: 32.0,
        seq: 2048.0,
        d_ff: 16384.0,
        n_experts: 256.0,
        top_k: 2.0,
        vocab: 50257.0,
        dtype_bytes: 2.0,
    }
}

impl MoeConfig {
    pub fn head_dim(&self) -> f64 {
        self.d_model / self.n_heads
    }

    /// Total parameters: attention (4h²) + experts (2·h·d_ff each).
    pub fn params(&self) -> f64 {
        let per_layer = 4.0 * self.d_model * self.d_model
            + self.n_experts * 2.0 * self.d_model * self.d_ff;
        self.layers as f64 * per_layer
    }

    /// Parameters touched per token (top_k experts + attention).
    pub fn active_params(&self) -> f64 {
        let per_layer =
            4.0 * self.d_model * self.d_model + self.top_k * 2.0 * self.d_model * self.d_ff;
        self.layers as f64 * per_layer
    }
}

/// One MoE transformer layer: attention block (as in Fig. 2A) + router
/// dispatch → expert FFNs → combine.
pub fn moe_layer_graph(cfg: &MoeConfig, batch: f64) -> DataflowGraph {
    let mut b = GraphBuilder::new(&format!("moe[{}e,top{}]", cfg.n_experts, cfg.top_k));
    let (h, s, f) = (cfg.d_model, cfg.seq, cfg.d_ff);
    let t = batch * s;
    let dt = cfg.dtype_bytes;
    let act = t * h * dt;

    // ---- attention block (condensed: QKV, attention, proj) ----
    let ln1 = b.kernel("LN1", KernelKind::LayerNorm { rows: t, cols: h }, 2.0 * h * dt);
    let qkv = b.kernel(
        "QKV",
        KernelKind::Gemm { b: 1.0, m: t, k: h, n: 3.0 * h },
        3.0 * h * h * dt,
    );
    b.tensor("ln1_out", ln1, qkv, act);
    let attn = b.kernel(
        "Attn",
        KernelKind::Gemm { b: batch * cfg.n_heads, m: s, k: cfg.head_dim(), n: 2.0 * s },
        0.0,
    );
    b.tensor("qkv_out", qkv, attn, 3.0 * act);
    let proj = b.kernel("Proj", KernelKind::Gemm { b: 1.0, m: t, k: h, n: h }, h * h * dt);
    b.tensor("attn_out", attn, proj, act);

    // ---- router: gating GEMM + all-to-all token dispatch ----
    let ln2 = b.kernel("LN2", KernelKind::LayerNorm { rows: t, cols: h }, 2.0 * h * dt);
    b.tensor("proj_out", proj, ln2, act);
    let gate = b.kernel(
        "Router",
        KernelKind::Gemm { b: 1.0, m: t, k: h, n: cfg.n_experts },
        h * cfg.n_experts * dt,
    );
    b.tensor("ln2_out", ln2, gate, act);
    // dispatch: every token's hidden state travels to its experts' chips —
    // Embedding kind so the "table" sharding scheme emits the all-to-all
    let dispatch = b.kernel(
        "Dispatch",
        KernelKind::Embedding { lookups: t * cfg.top_k, dim: h },
        0.0,
    );
    b.tensor("gate_out", gate, dispatch, t * cfg.top_k * h * dt);

    // ---- experts (aggregated): top_k FFN passes per token ----
    let expert_tokens = t * cfg.top_k;
    let ffn0 = b.kernel(
        "ExpFFN0",
        KernelKind::Gemm { b: 1.0, m: expert_tokens, k: h, n: f },
        cfg.n_experts * h * f * dt,
    );
    b.tensor("disp_out", dispatch, ffn0, expert_tokens * h * dt);
    let gelu = b.kernel(
        "ExpGeLU",
        KernelKind::Elementwise { elems: expert_tokens * f, flop_per_elem: 10.0 },
        0.0,
    );
    b.tensor("ffn0_out", ffn0, gelu, expert_tokens * f * dt);
    let ffn1 = b.kernel(
        "ExpFFN1",
        KernelKind::Gemm { b: 1.0, m: expert_tokens, k: f, n: h },
        cfg.n_experts * f * h * dt,
    );
    b.tensor("gelu_out", gelu, ffn1, expert_tokens * f * dt);

    // ---- combine: all-to-all back + weighted sum ----
    let combine = b.kernel(
        "Combine",
        KernelKind::Embedding { lookups: expert_tokens, dim: h },
        0.0,
    );
    b.tensor("ffn1_out", ffn1, combine, expert_tokens * h * dt);
    let add = b.kernel("Add", KernelKind::Elementwise { elems: t * h, flop_per_elem: 2.0 }, 0.0);
    b.tensor("comb_out", combine, add, act);
    b.build()
}

/// Expert-parallel degree limit: experts can be sharded at most n_experts
/// ways (the analogue of the heads limit for attention TP).
pub fn max_expert_parallel(cfg: &MoeConfig) -> usize {
    cfg.n_experts as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::{chip, interconnect, memory, topology, SystemSpec};

    #[test]
    fn params_total_and_active() {
        let cfg = moe_gpt_1t();
        let p = cfg.params();
        assert!((p / 0.83e12 - 1.0).abs() < 0.15, "total params = {p:.3e}");
        // sparse activation: active ≪ total
        assert!(cfg.active_params() < p / 50.0);
    }

    #[test]
    fn graph_validates() {
        let g = moe_layer_graph(&moe_gpt_1t(), 1.0);
        g.validate().unwrap();
        assert_eq!(g.n_kernels(), 12);
        // experts dominate the weights
        let expert_w: f64 = g
            .kernels
            .iter()
            .filter(|k| k.name.starts_with("Exp"))
            .map(|k| k.weight_bytes)
            .sum();
        assert!(expert_w / g.total_weight_bytes() > 0.95);
    }

    #[test]
    fn moe_is_network_sensitive_like_dlrm() {
        // the dispatch/combine all-to-alls make MoE benefit from NVLink
        let g = moe_layer_graph(&moe_gpt_1t(), 8.0);
        let mk = |link: crate::system::LinkTech| {
            SystemSpec::new(
                chip::h100(),
                memory::hbm3(),
                link.clone(),
                topology::torus2d(8, 8, &link),
            )
        };
        let slow = crate::pipeline::workload_pass(&g, &mk(interconnect::pcie4()), 3.0, 8);
        let fast = crate::pipeline::workload_pass(&g, &mk(interconnect::nvlink4()), 3.0, 8);
        let (Some(s), Some(f)) = (slow, fast) else {
            panic!("MoE mapping must be feasible");
        };
        assert!(f.utilization > 1.5 * s.utilization, "nvlink {} pcie {}", f.utilization, s.utilization);
    }

    #[test]
    fn expert_parallel_limit() {
        assert_eq!(max_expert_parallel(&moe_gpt_1t()), 256);
    }
}
