//! DLRM workload builder (the paper's 793B deep-learning recommendation
//! model [34], [61]): embedding-bag lookups (sharded tables → all-to-all
//! exchange), bottom MLP over dense features, pairwise feature interaction,
//! and the top MLP.

use super::{DataflowGraph, GraphBuilder, KernelKind};

#[derive(Debug, Clone, Copy)]
pub struct DlrmConfig {
    /// Number of sparse embedding tables.
    pub tables: f64,
    /// Embedding vector width.
    pub emb_dim: f64,
    /// Rows per table (sized so tables dominate the 793B parameter count).
    pub rows_per_table: f64,
    /// Lookups (pooled indices) per table per sample.
    pub pooling: f64,
    /// Dense-feature width into the bottom MLP.
    pub dense_features: f64,
    /// Bottom MLP layer widths.
    pub bottom_mlp: [f64; 3],
    /// Top MLP layer widths.
    pub top_mlp: [f64; 4],
    pub dtype_bytes: f64,
}

/// The 793B configuration from Mudigere et al. [61]: parameters are almost
/// entirely embeddings (tables × rows × dim ≈ 793e9).
pub fn dlrm_793b() -> DlrmConfig {
    DlrmConfig {
        tables: 856.0,
        emb_dim: 128.0,
        rows_per_table: 7.236e6, // 856 * 7.236e6 * 128 ≈ 793e9
        pooling: 20.0,
        dense_features: 13.0,
        bottom_mlp: [512.0, 256.0, 128.0],
        top_mlp: [1024.0, 1024.0, 512.0, 1.0],
        dtype_bytes: 2.0,
    }
}

impl DlrmConfig {
    pub fn embedding_params(&self) -> f64 {
        self.tables * self.rows_per_table * self.emb_dim
    }

    pub fn mlp_params(&self) -> f64 {
        let mut p = 0.0;
        let mut prev = self.dense_features;
        for w in self.bottom_mlp {
            p += prev * w;
            prev = w;
        }
        // top MLP input: interaction features + bottom output
        let inter_in = self.interaction_width() + self.bottom_mlp[2];
        let mut prev = inter_in;
        for w in self.top_mlp {
            p += prev * w;
            prev = w;
        }
        p
    }

    /// Pairwise-interaction output width: C(tables+1, 2).
    pub fn interaction_width(&self) -> f64 {
        let f = self.tables + 1.0;
        f * (f - 1.0) / 2.0
    }

    pub fn params(&self) -> f64 {
        self.embedding_params() + self.mlp_params()
    }
}

/// Build the per-batch DLRM dataflow graph.
///
/// The Embedding kernel's output tensor is the one that needs the
/// all-to-all at the inter-chip level (tables are sharded across chips, each
/// chip needs every sample's pooled vectors) — its sharding schemes carry
/// that cost (see `sharding::schemes_for`).
pub fn dlrm_graph(cfg: &DlrmConfig, batch: f64) -> DataflowGraph {
    let mut b = GraphBuilder::new(&format!("dlrm[{}tables]", cfg.tables));
    let dt = cfg.dtype_bytes;

    // Sparse side: pooled embedding-bag lookups over all tables.
    let emb = b.kernel(
        "EmbLookup",
        KernelKind::Embedding { lookups: batch * cfg.tables * cfg.pooling, dim: cfg.emb_dim },
        cfg.embedding_params() * dt,
    );

    // Dense side: bottom MLP (3 GEMMs + ReLU folded into flop/elem).
    let mut prev_w = cfg.dense_features;
    let mut prev_k = b.kernel(
        "BotMLP0",
        KernelKind::Gemm { b: 1.0, m: batch, k: prev_w, n: cfg.bottom_mlp[0] },
        prev_w * cfg.bottom_mlp[0] * dt,
    );
    prev_w = cfg.bottom_mlp[0];
    for (i, &w) in cfg.bottom_mlp.iter().enumerate().skip(1) {
        let k = b.kernel(
            &format!("BotMLP{i}"),
            KernelKind::Gemm { b: 1.0, m: batch, k: prev_w, n: w },
            prev_w * w * dt,
        );
        b.tensor(&format!("bot{i}"), prev_k, k, batch * prev_w * dt);
        prev_k = k;
        prev_w = w;
    }

    // Feature interaction: per-sample [F, D] x [D, F] pairwise dots where
    // F = tables + 1 (pooled embeddings + bottom-MLP output).
    let f = cfg.tables + 1.0;
    let inter = b.kernel(
        "Interact",
        KernelKind::Gemm { b: batch, m: f, k: cfg.emb_dim, n: f },
        0.0,
    );
    b.tensor("emb_out", emb, inter, batch * cfg.tables * cfg.emb_dim * dt);
    b.tensor("bot_out", prev_k, inter, batch * cfg.emb_dim * dt);

    // Top MLP over [interaction features ++ bottom output].
    let mut prev_w = cfg.interaction_width() + cfg.bottom_mlp[2];
    let mut prev_k = inter;
    let mut prev_bytes = batch * prev_w * dt;
    for (i, &w) in cfg.top_mlp.iter().enumerate() {
        let k = b.kernel(
            &format!("TopMLP{i}"),
            KernelKind::Gemm { b: 1.0, m: batch, k: prev_w, n: w },
            prev_w * w * dt,
        );
        b.tensor(&format!("top{i}"), prev_k, k, prev_bytes);
        prev_k = k;
        prev_w = w;
        prev_bytes = batch * w * dt;
    }

    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_hit_793b() {
        let cfg = dlrm_793b();
        let p = cfg.params();
        assert!((p / 793e9 - 1.0).abs() < 0.01, "params = {p:.4e}");
        // embeddings dominate
        assert!(cfg.embedding_params() / p > 0.99);
    }

    #[test]
    fn graph_structure() {
        let cfg = dlrm_793b();
        let g = dlrm_graph(&cfg, 1024.0);
        g.validate().unwrap();
        // EmbLookup + 3 bottom + interact + 4 top = 9 kernels
        assert_eq!(g.n_kernels(), 9);
        assert!(g.kernels.iter().any(|k| k.name == "EmbLookup"));
        assert!(g.kernels.iter().any(|k| k.name == "Interact"));
    }

    #[test]
    fn flops_scale_with_batch() {
        let cfg = dlrm_793b();
        let f1 = dlrm_graph(&cfg, 1024.0).total_flops();
        let f2 = dlrm_graph(&cfg, 2048.0).total_flops();
        assert!((f2 / f1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn embedding_weights_dominate_graph_weights() {
        let cfg = dlrm_793b();
        let g = dlrm_graph(&cfg, 512.0);
        let w = g.total_weight_bytes();
        assert!((w / (cfg.params() * 2.0) - 1.0).abs() < 0.01);
    }
}
