//! Fluent construction of dataflow graphs with single-producer /
//! single-consumer tensors (§IV-C). Multi-consumer fan-out is expressed by
//! `replicate`, which emits one edge per consumer — exactly the paper's
//! "tensors used by multiple consumers are replicated" rule.

use super::{DataflowGraph, Kernel, KernelId, KernelKind, Tensor};

#[derive(Debug, Clone)]
pub struct GraphBuilder {
    graph: DataflowGraph,
}

impl GraphBuilder {
    pub fn new(name: &str) -> Self {
        GraphBuilder {
            graph: DataflowGraph { name: name.to_string(), ..Default::default() },
        }
    }

    /// Add a kernel; FLOP derived from the kind.
    pub fn kernel(&mut self, name: &str, kind: KernelKind, weight_bytes: f64) -> KernelId {
        let flops = kind.flops();
        self.kernel_with_flops(name, kind, flops, weight_bytes)
    }

    /// Add a kernel with an explicit FLOP override (aggregated kernels).
    pub fn kernel_with_flops(
        &mut self,
        name: &str,
        kind: KernelKind,
        flops: f64,
        weight_bytes: f64,
    ) -> KernelId {
        assert!(flops >= 0.0 && weight_bytes >= 0.0, "negative kernel cost");
        let id = KernelId(self.graph.kernels.len());
        self.graph.kernels.push(Kernel { name: name.to_string(), kind, flops, weight_bytes });
        id
    }

    /// Connect `src -> dst` with a tensor of `bytes`.
    pub fn tensor(&mut self, name: &str, src: KernelId, dst: KernelId, bytes: f64) {
        assert!(bytes >= 0.0, "negative tensor size");
        self.graph.tensors.push(Tensor { name: name.to_string(), src, dst, bytes });
    }

    /// Fan a producer's output to several consumers (replication rule).
    pub fn replicate(&mut self, name: &str, src: KernelId, dsts: &[KernelId], bytes: f64) {
        for (i, &dst) in dsts.iter().enumerate() {
            self.tensor(&format!("{name}.rep{i}"), src, dst, bytes);
        }
    }

    /// Current number of kernels (for builders that compose subgraphs).
    pub fn len(&self) -> usize {
        self.graph.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graph.kernels.is_empty()
    }

    /// Finish; panics if the graph fails validation (builders are internal,
    /// a malformed build is a bug, not an input error).
    pub fn build(self) -> DataflowGraph {
        if let Err(e) = self.graph.validate() {
            panic!("builder produced invalid graph '{}': {e}", self.graph.name);
        }
        self.graph
    }

    /// Finish without validation (for deliberately-broken test graphs).
    pub fn build_unchecked(self) -> DataflowGraph {
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicate_emits_one_edge_per_consumer() {
        let mut b = GraphBuilder::new("g");
        let s = b.kernel("src", KernelKind::Elementwise { elems: 1.0, flop_per_elem: 1.0 }, 0.0);
        let c1 = b.kernel("c1", KernelKind::Elementwise { elems: 1.0, flop_per_elem: 1.0 }, 0.0);
        let c2 = b.kernel("c2", KernelKind::Elementwise { elems: 1.0, flop_per_elem: 1.0 }, 0.0);
        b.replicate("t", s, &[c1, c2], 10.0);
        let g = b.build();
        assert_eq!(g.n_tensors(), 2);
        assert!(g.tensors.iter().all(|t| t.bytes == 10.0 && t.src == s));
    }

    #[test]
    #[should_panic(expected = "invalid graph")]
    fn build_panics_on_cycle() {
        let mut b = GraphBuilder::new("g");
        let a = b.kernel("a", KernelKind::Elementwise { elems: 1.0, flop_per_elem: 1.0 }, 0.0);
        let c = b.kernel("b", KernelKind::Elementwise { elems: 1.0, flop_per_elem: 1.0 }, 0.0);
        b.tensor("f", a, c, 1.0);
        b.tensor("r", c, a, 1.0);
        b.build();
    }

    #[test]
    fn flops_derived_from_kind() {
        let mut b = GraphBuilder::new("g");
        let k = b.kernel("gemm", KernelKind::Gemm { b: 1.0, m: 8.0, k: 8.0, n: 8.0 }, 42.0);
        let g = b.build_unchecked();
        assert_eq!(g.kernel(k).flops, 1024.0);
        assert_eq!(g.kernel(k).weight_bytes, 42.0);
    }
}
