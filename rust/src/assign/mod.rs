//! Assignment matrices **A, B, D, L, H** (§III-B, Fig. 3, Eqs. 1–4).
//!
//! `A` assigns each kernel to exactly one partition (A·1 = 1). The derived
//! matrices are computed with the paper's exact boolean formulations:
//!
//! * Eq. 1  B[j,:] = A[src,:] ∧ A[dst,:]          (intra-partition tensors)
//! * Eq. 2  D[j,:] = A[src,:] ⊕ A[dst,:]          (cross-partition tensors)
//! * Eq. 3  L[j,:] = (A[src]·U_src ⊕ A[dst]·U_dst) ⊕ (A[src] ∧ A[dst])
//! * Eq. 4  H[j,:] = A[src,:]                     (source placement)
//!
//! The optimizers work on the compact form (`part[kernel] = partition`);
//! the boolean matrices exist for model fidelity and are property-tested
//! against the compact accessors.

use crate::graph::DataflowGraph;

/// A kernel→partition assignment (compact matrix A).
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    /// part[i] = partition of kernel i; every entry < p_max.
    pub part: Vec<usize>,
    pub p_max: usize,
}

pub type BoolMat = Vec<Vec<bool>>;

impl Assignment {
    pub fn new(part: Vec<usize>, p_max: usize) -> Self {
        assert!(p_max >= 1);
        assert!(part.iter().all(|&p| p < p_max), "partition index out of range");
        Assignment { part, p_max }
    }

    /// All kernels in one partition.
    pub fn single_partition(n: usize) -> Self {
        Assignment { part: vec![0; n], p_max: 1 }
    }

    /// Each kernel in its own partition (the kernel-by-kernel mapping).
    pub fn one_per_kernel(n: usize) -> Self {
        Assignment { part: (0..n).collect(), p_max: n.max(1) }
    }

    /// Matrix A: [n × p_max] one-hot rows.
    pub fn matrix_a(&self) -> BoolMat {
        self.part
            .iter()
            .map(|&p| (0..self.p_max).map(|j| j == p).collect())
            .collect()
    }

    /// Eq. 1 — matrix B: tensor j lives in partition p iff both endpoints do.
    pub fn matrix_b(&self, g: &DataflowGraph) -> BoolMat {
        g.tensors
            .iter()
            .map(|t| {
                let (s, d) = (self.part[t.src.0], self.part[t.dst.0]);
                (0..self.p_max).map(|p| s == p && d == p).collect()
            })
            .collect()
    }

    /// Eq. 2 — matrix D: XOR of the endpoint one-hots.
    pub fn matrix_d(&self, g: &DataflowGraph) -> BoolMat {
        g.tensors
            .iter()
            .map(|t| {
                let (s, d) = (self.part[t.src.0], self.part[t.dst.0]);
                (0..self.p_max).map(|p| (s == p) != (d == p)).collect()
            })
            .collect()
    }

    /// Eq. 3 — matrix L: lifetime of cross-partition tensors.
    /// Computed with the paper's upper-triangular trick:
    /// U_src[i,j] = i ≤ j, U_dst[i,j] = i < j.
    pub fn matrix_l(&self, g: &DataflowGraph) -> BoolMat {
        g.tensors
            .iter()
            .map(|t| {
                let (s, d) = (self.part[t.src.0], self.part[t.dst.0]);
                (0..self.p_max)
                    .map(|p| {
                        let src_prefix = s <= p; // (A[src] · U_src)[p]
                        let dst_prefix = d < p; // (A[dst] · U_dst)[p]
                        let within = s == p && d == p; // A[src] ∧ A[dst]
                        (src_prefix != dst_prefix) != within
                    })
                    .collect()
            })
            .collect()
    }

    /// Eq. 4 — matrix H: tensor placed with its producer.
    pub fn matrix_h(&self, g: &DataflowGraph) -> BoolMat {
        g.tensors
            .iter()
            .map(|t| {
                let s = self.part[t.src.0];
                (0..self.p_max).map(|p| s == p).collect()
            })
            .collect()
    }

    // ---- compact accessors used by the optimizers (must agree with the
    // boolean matrices; see the property tests) ----

    /// Tensor stays within a partition? Returns it.
    pub fn intra_partition(&self, src: usize, dst: usize) -> Option<usize> {
        let (s, d) = (self.part[src], self.part[dst]);
        (s == d).then_some(s)
    }

    /// Partitions a cross-partition tensor occupies (Eq. 3 semantics):
    /// inclusive [src, dst] when src ≤ dst; empty when within one partition.
    pub fn lifetime(&self, src: usize, dst: usize) -> std::ops::Range<usize> {
        let (s, d) = (self.part[src], self.part[dst]);
        if s == d {
            0..0
        } else if s < d {
            s..d + 1
        } else {
            // backward edge (does not occur under precedence-feasible
            // assignments): Eq. 3's boolean algebra yields (dst, src) —
            // exclusive of both endpoints' own partitions on the src side
            d + 1..s
        }
    }

    /// Kernels per partition.
    pub fn members(&self) -> Vec<Vec<usize>> {
        let mut m = vec![Vec::new(); self.p_max];
        for (k, &p) in self.part.iter().enumerate() {
            m[p].push(k);
        }
        m
    }

    /// Number of non-empty partitions.
    pub fn n_used(&self) -> usize {
        self.members().iter().filter(|m| !m.is_empty()).count()
    }

    /// Row-sum-1 invariant of matrix A (trivially true by construction for
    /// the compact form; kept for the fidelity test).
    pub fn check_one_hot(&self) -> bool {
        self.matrix_a().iter().all(|row| row.iter().filter(|&&b| b).count() == 1)
    }

    /// Precedence feasibility: producers in earlier-or-equal partitions.
    pub fn respects_precedence(&self, g: &DataflowGraph) -> bool {
        g.tensors.iter().all(|t| self.part[t.src.0] <= self.part[t.dst.0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, KernelKind};
    use crate::util::check::check;
    use crate::util::prng::Rng;

    fn diamond() -> DataflowGraph {
        // a -> b, a -> c, b -> d, c -> d  (Fig. 3-like shape)
        let mut b = GraphBuilder::new("diamond");
        let ids: Vec<_> = (0..4)
            .map(|i| {
                b.kernel(
                    &format!("k{i}"),
                    KernelKind::Elementwise { elems: 1.0, flop_per_elem: 1.0 },
                    0.0,
                )
            })
            .collect();
        b.tensor("ab", ids[0], ids[1], 8.0);
        b.tensor("ac", ids[0], ids[2], 8.0);
        b.tensor("bd", ids[1], ids[3], 8.0);
        b.tensor("cd", ids[2], ids[3], 8.0);
        b.build()
    }

    #[test]
    fn matrix_a_one_hot() {
        let a = Assignment::new(vec![0, 1, 1, 2], 4);
        assert!(a.check_one_hot());
        let m = a.matrix_a();
        assert!(m[0][0] && m[1][1] && m[2][1] && m[3][2]);
    }

    #[test]
    fn matrix_b_intra_partition() {
        let g = diamond();
        let a = Assignment::new(vec![0, 0, 1, 1], 2);
        let b = a.matrix_b(&g);
        // ab intra in partition 0; cd intra in partition 1; ac, bd cross
        assert!(b[0][0] && !b[0][1]);
        assert!(!b[1].iter().any(|&x| x));
        assert!(!b[2].iter().any(|&x| x));
        assert!(b[3][1]);
    }

    #[test]
    fn matrix_d_cross_partition_xor() {
        let g = diamond();
        let a = Assignment::new(vec![0, 0, 1, 1], 2);
        let d = a.matrix_d(&g);
        // ac crosses 0 -> 1: D row = [1, 1]
        assert_eq!(d[1], vec![true, true]);
        // ab intra: all false
        assert_eq!(d[0], vec![false, false]);
    }

    #[test]
    fn matrix_l_lifetime_spans_inclusive() {
        let g = diamond();
        // a in p0, b in p1, c in p2, d in p3
        let a = Assignment::new(vec![0, 1, 2, 3], 4);
        let l = a.matrix_l(&g);
        // tensor ac: 0 -> 2 must occupy partitions 0, 1, 2
        assert_eq!(l[1], vec![true, true, true, false]);
        // tensor bd: 1 -> 3 occupies 1, 2, 3
        assert_eq!(l[2], vec![false, true, true, true]);
    }

    #[test]
    fn matrix_l_empty_for_intra() {
        let g = diamond();
        let a = Assignment::new(vec![0, 0, 0, 0], 2);
        let l = a.matrix_l(&g);
        assert!(l.iter().all(|row| row.iter().all(|&x| !x)));
    }

    #[test]
    fn matrix_h_source_placement() {
        let g = diamond();
        let a = Assignment::new(vec![0, 1, 1, 2], 3);
        let h = a.matrix_h(&g);
        assert!(h[0][0]); // ab placed with a
        assert!(h[2][1]); // bd placed with b
    }

    #[test]
    fn precedence_check() {
        let g = diamond();
        assert!(Assignment::new(vec![0, 1, 1, 2], 3).respects_precedence(&g));
        assert!(!Assignment::new(vec![2, 1, 1, 0], 3).respects_precedence(&g));
    }

    #[test]
    fn compact_lifetime_agrees_with_matrix_l() {
        let g = diamond();
        check("lifetime-agrees", 200, |rng: &mut Rng| {
            let p_max = 1 + rng.below(6);
            let part: Vec<usize> = (0..4).map(|_| rng.below(p_max)).collect();
            let a = Assignment::new(part, p_max);
            let l = a.matrix_l(&g);
            for (j, t) in g.tensors.iter().enumerate() {
                let range = a.lifetime(t.src.0, t.dst.0);
                for p in 0..p_max {
                    assert_eq!(
                        l[j][p],
                        range.contains(&p),
                        "tensor {j} partition {p} assignment {:?}",
                        a.part
                    );
                }
            }
        });
    }

    #[test]
    fn b_and_d_are_disjoint_and_cover() {
        let g = diamond();
        check("b-d-disjoint", 200, |rng: &mut Rng| {
            let p_max = 1 + rng.below(5);
            let part: Vec<usize> = (0..4).map(|_| rng.below(p_max)).collect();
            let a = Assignment::new(part, p_max);
            let (b, d) = (a.matrix_b(&g), a.matrix_d(&g));
            for j in 0..g.n_tensors() {
                let b_any = b[j].iter().any(|&x| x);
                let d_any = d[j].iter().any(|&x| x);
                assert!(b_any != d_any, "tensor must be intra xor cross");
                // D rows have exactly 0 or 2 set bits; B rows 0 or 1
                let d_count = d[j].iter().filter(|&&x| x).count();
                assert!(d_count == 0 || d_count == 2);
                let b_count = b[j].iter().filter(|&&x| x).count();
                assert!(b_count <= 1);
            }
        });
    }

    #[test]
    fn members_partition_the_kernels() {
        let a = Assignment::new(vec![1, 0, 1, 2], 3);
        let m = a.members();
        assert_eq!(m[0], vec![1]);
        assert_eq!(m[1], vec![0, 2]);
        assert_eq!(m[2], vec![3]);
        assert_eq!(a.n_used(), 3);
    }
}
