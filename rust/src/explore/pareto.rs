//! Exact Pareto-frontier extraction over the three maximized DSE
//! objectives — (utilization, cost efficiency, power efficiency) — plus the
//! nondominated archive the pruning loop maintains.

/// `a` strictly Pareto-dominates `b` (maximization): at least as good on
/// every objective and strictly better on at least one.
pub fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    a[0] >= b[0]
        && a[1] >= b[1]
        && a[2] >= b[2]
        && (a[0] > b[0] || a[1] > b[1] || a[2] > b[2])
}

/// Indices of the exact Pareto frontier among `objs`. Non-finite vectors
/// (infeasible NaN points) never join the frontier. Ties are kept: two
/// identical vectors are both on the frontier, so the result is a pure
/// function of the multiset of objective vectors.
pub fn pareto_frontier(objs: &[[f64; 3]]) -> Vec<usize> {
    let feasible: Vec<usize> = objs
        .iter()
        .enumerate()
        .filter(|(_, o)| o.iter().all(|v| v.is_finite()))
        .map(|(i, _)| i)
        .collect();
    feasible
        .iter()
        .copied()
        .filter(|&i| !feasible.iter().any(|&j| j != i && dominates(&objs[j], &objs[i])))
        .collect()
}

/// Insert `v` into a minimal nondominated archive: dominated or duplicate
/// entries are dropped, entries `v` dominates are evicted.
pub(crate) fn archive_insert(archive: &mut Vec<[f64; 3]>, v: [f64; 3]) {
    if v.iter().any(|x| !x.is_finite()) {
        return;
    }
    if archive.iter().any(|a| dominates(a, &v) || a == &v) {
        return;
    }
    archive.retain(|a| !dominates(&v, a));
    archive.push(v);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_is_strict() {
        assert!(dominates(&[1.0, 1.0, 1.0], &[1.0, 1.0, 0.5]));
        assert!(!dominates(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]), "equal never dominates");
        assert!(!dominates(&[2.0, 0.5, 1.0], &[1.0, 1.0, 1.0]), "trade-off never dominates");
    }

    #[test]
    fn frontier_laws_on_synthetic_points() {
        let objs = [
            [1.0, 1.0, 1.0], // frontier
            [0.5, 0.5, 0.5], // dominated by 0
            [2.0, 0.1, 0.1], // frontier (best utilization)
            [1.0, 1.0, 1.0], // duplicate of 0: stays on the frontier
            [f64::NAN, 1.0, 1.0], // infeasible
            [0.1, 3.0, 0.2], // frontier (best cost efficiency)
        ];
        let f = pareto_frontier(&objs);
        assert_eq!(f, vec![0, 2, 3, 5]);
        for &i in &f {
            for &j in &f {
                assert!(i == j || !dominates(&objs[i], &objs[j]));
            }
        }
    }

    #[test]
    fn frontier_of_empty_and_infeasible() {
        assert!(pareto_frontier(&[]).is_empty());
        assert!(pareto_frontier(&[[f64::NAN, 0.0, 0.0]]).is_empty());
    }

    #[test]
    fn archive_stays_minimal() {
        let mut a = Vec::new();
        archive_insert(&mut a, [1.0, 1.0, 1.0]);
        archive_insert(&mut a, [0.5, 0.5, 0.5]); // dominated: dropped
        assert_eq!(a.len(), 1);
        archive_insert(&mut a, [1.0, 1.0, 1.0]); // duplicate: dropped
        assert_eq!(a.len(), 1);
        archive_insert(&mut a, [2.0, 2.0, 2.0]); // evicts the first
        assert_eq!(a, vec![[2.0, 2.0, 2.0]]);
        archive_insert(&mut a, [0.1, 9.0, 0.1]); // trade-off: kept
        assert_eq!(a.len(), 2);
        archive_insert(&mut a, [f64::NAN, 9.0, 9.0]); // non-finite: ignored
        assert_eq!(a.len(), 2);
    }
}
