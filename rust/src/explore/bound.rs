//! Roofline upper bounds for explorer pruning.
//!
//! A candidate can be skipped without running the two-level optimizer when
//! an upper bound on everything it could achieve is already strictly
//! dominated by an *evaluated* design point: the bound over-estimates every
//! objective, so the candidate's true point is dominated by the same
//! evaluated point and can never join the Pareto frontier.
//!
//! The bounds are floors of the performance model itself, with slack:
//!
//! * **compute ceiling** — the intra-chip pass derates peak by the
//!   execution-efficiency factor (the shared
//!   `intrachip::optimizer::EXEC_EFF_*` constants: 0.62 kernel-by-kernel,
//!   0.90 dataflow) and by per-kind utilization ≤ 1, so achieved/peak can
//!   never exceed the derate; [`COMPUTE_MARGIN`] covers the small
//!   useful-vs-modeled FLOP accounting mismatches.
//! * **memory roof (kernel-by-kernel only)** — every kernel invocation
//!   reloads its weights and crosses DRAM with its tensors (Fig. 2D), so
//!   per-chip traffic is at least `(weights + activations) / n_chips` per
//!   unit of work while per-chip FLOP is `useful / n_chips`: utilization is
//!   capped by `OI · d_bw / chip_peak`. Dataflow chips can fuse partitions
//!   and keep weights resident across sequential partitions, so no sound
//!   memory floor exists for them — their roof is infinite.

use crate::dse::Workload;
use crate::graph::{dlrm, fft, gpt, hpl, DataflowGraph};
use crate::intrachip::optimizer::{EXEC_EFF_DATAFLOW, EXEC_EFF_KERNEL_BY_KERNEL};
use crate::system::{ExecutionModel, SystemSpec};

use super::{SearchSpace, WorkloadSpec};

/// Slack over the execution-efficiency ceiling (per-kind utilization
/// rounding, pipeline-fill accounting).
pub const COMPUTE_MARGIN: f64 = 1.15;

/// Slack over the kernel-by-kernel memory roof (sharding unevenness,
/// activation-byte undercounting on the coarse graph).
pub const MEM_MARGIN: f64 = 1.5;

/// Workload aggregates behind the pruning bound, computed once per explore
/// run from the workload's dataflow graph.
#[derive(Debug, Clone, Copy)]
pub struct BoundProfile {
    /// FLOP per unit of work (one sequence for LLM, one pass otherwise).
    pub useful_flops: f64,
    /// Resident weight bytes of the whole model.
    pub weight_bytes: f64,
    /// Inter-kernel tensor bytes per unit of work.
    pub activation_bytes: f64,
}

fn profile_of(g: &DataflowGraph) -> BoundProfile {
    BoundProfile {
        useful_flops: g.total_flops(),
        weight_bytes: g.total_weight_bytes(),
        activation_bytes: g.total_tensor_bytes(),
    }
}

impl BoundProfile {
    /// Aggregates covering a whole search space. For LLM the batch cancels
    /// out of the roofline ratios; for DLRM operational intensity *grows*
    /// with batch (weights amortize over more items), so the profile is
    /// built at the largest batch on the axis — the bound then
    /// over-estimates every candidate regardless of its batch override.
    pub fn for_space(space: &SearchSpace) -> BoundProfile {
        let mut spec = space.workload;
        if spec.kind == Workload::Dlrm {
            let base = spec.batch.unwrap_or(65_536.0);
            let max = space.batches.iter().flatten().fold(base, |m, &b| m.max(b));
            spec.batch = Some(max);
        }
        BoundProfile::for_workload(&spec)
    }

    /// Aggregates for one explorer workload (batch overrides cancel out of
    /// the roofline ratios, so the profile is batch-independent for LLM).
    pub fn for_workload(spec: &WorkloadSpec) -> BoundProfile {
        match spec.kind {
            Workload::Llm => {
                let cfg = spec.gpt.unwrap_or_else(gpt::gpt3_1t);
                profile_of(&gpt::gpt_coarse_graph(&cfg, 1.0))
            }
            Workload::Dlrm => {
                profile_of(&dlrm::dlrm_graph(&dlrm::dlrm_793b(), spec.batch.unwrap_or(65_536.0)))
            }
            Workload::Hpl => profile_of(&hpl::hpl_graph(&hpl::hpl_5m())),
            Workload::Fft => profile_of(&fft::fft_graph(&fft::fft_1t())),
        }
    }

    /// Upper bound on the utilization any mapping of this workload can
    /// achieve on `sys` (≤ 1).
    pub fn utilization_bound(&self, sys: &SystemSpec) -> f64 {
        let kbk = sys.chip.execution == ExecutionModel::KernelByKernel;
        let exec_eff = if kbk { EXEC_EFF_KERNEL_BY_KERNEL } else { EXEC_EFF_DATAFLOW };
        let mem = if kbk {
            let traffic = self.weight_bytes + self.activation_bytes;
            if traffic > 0.0 {
                self.useful_flops / traffic * sys.memory.bandwidth.raw()
                    / sys.chip.compute_flops().raw()
                    * MEM_MARGIN
            } else {
                f64::INFINITY
            }
        } else {
            f64::INFINITY
        };
        (exec_eff * COMPUTE_MARGIN).min(mem).min(1.0)
    }

    /// Upper bounds on (utilization, cost efficiency, power efficiency):
    /// for a fixed system all three scale with achieved FLOP/s, so one
    /// utilization bound caps the whole objective vector.
    pub fn objective_bounds(&self, sys: &SystemSpec) -> [f64; 3] {
        let u = self.utilization_bound(sys);
        let achieved = (u * sys.peak_flops()).raw();
        [u, achieved / 1e9 / sys.price_usd().raw(), achieved / 1e9 / sys.power_w().raw()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::{chip, interconnect, memory, topology, ChipSpec, MemoryTech};

    fn spec() -> WorkloadSpec {
        WorkloadSpec {
            kind: Workload::Llm,
            gpt: None,
            batch: None,
            state_bytes_per_weight_byte: None,
        }
    }

    fn sys(c: ChipSpec, mem: MemoryTech) -> SystemSpec {
        let link = interconnect::nvlink4();
        SystemSpec::new(c, mem, link.clone(), topology::torus2d(4, 4, &link))
    }

    #[test]
    fn bounds_respect_execution_ceilings() {
        let p = BoundProfile::for_workload(&spec());
        let kbk = p.utilization_bound(&sys(chip::h100(), memory::hbm3()));
        let df = p.utilization_bound(&sys(chip::sn30(), memory::hbm3()));
        assert!(kbk <= EXEC_EFF_KERNEL_BY_KERNEL * COMPUTE_MARGIN + 1e-12, "kbk bound {kbk}");
        assert!(df <= 1.0 && df > 0.9, "df bound {df}");
    }

    #[test]
    fn kbk_bound_monotone_in_memory_bandwidth() {
        let p = BoundProfile::for_workload(&spec());
        let slow = p.utilization_bound(&sys(chip::h100(), memory::ddr4()));
        let fast = p.utilization_bound(&sys(chip::h100(), memory::hbm3()));
        assert!(slow <= fast, "slower DRAM cannot raise the bound: {slow} vs {fast}");
    }

    #[test]
    fn objective_bounds_scale_with_peak_over_price_and_power() {
        let p = BoundProfile::for_workload(&spec());
        let s = sys(chip::h100(), memory::hbm3());
        let [u, c, w] = p.objective_bounds(&s);
        assert!((c - u * s.peak_flops().raw() / 1e9 / s.price_usd().raw()).abs() < 1e-9);
        assert!((w - u * s.peak_flops().raw() / 1e9 / s.power_w().raw()).abs() < 1e-9);
    }

    #[test]
    fn space_profile_covers_the_largest_dlrm_batch() {
        let mut space = SearchSpace::paper_grid(Workload::Dlrm);
        space.batches = vec![None, Some(1_000_000.0)];
        let p = BoundProfile::for_space(&space);
        let big = BoundProfile::for_workload(&WorkloadSpec {
            kind: Workload::Dlrm,
            gpt: None,
            batch: Some(1_000_000.0),
            state_bytes_per_weight_byte: None,
        });
        assert_eq!(p.useful_flops, big.useful_flops);
        let small = BoundProfile::for_space(&SearchSpace::paper_grid(Workload::Dlrm));
        let oi = |p: &BoundProfile| p.useful_flops / (p.weight_bytes + p.activation_bytes);
        assert!(
            oi(&p) > oi(&small),
            "operational intensity must grow with batch: {} vs {}",
            oi(&p),
            oi(&small)
        );
    }

    #[test]
    fn profiles_exist_for_all_workloads() {
        for w in Workload::all() {
            let p = BoundProfile::for_workload(&WorkloadSpec {
                kind: w,
                gpt: None,
                batch: None,
                state_bytes_per_weight_byte: None,
            });
            assert!(p.useful_flops > 0.0, "{w:?}");
            assert!(p.activation_bytes > 0.0, "{w:?}");
        }
    }
}
