//! # Parameterized multi-objective design-space exploration
//!
//! The §VI-C evaluation sweeps a *fixed* 80-system grid; this module
//! generalizes it to a declarative [`SearchSpace`] — sets over chip
//! compute/SRAM/execution, DRAM technology (with bandwidth/capacity
//! overrides), link technology, topology family, chip count, and
//! per-workload batch — evaluated in parallel with:
//!
//! * **bound-based pruning** — a candidate whose roofline upper bound
//!   ([`BoundProfile`]) is already strictly dominated by an evaluated
//!   design point is skipped: the bound over-estimates every objective, so
//!   the candidate can never reach the Pareto frontier;
//! * **memoized evaluation** — results are cached on the canonicalized
//!   `SystemSpec` (plus effective batch), so axes that alias to the same
//!   system evaluate once;
//! * **deterministic scheduling** — candidates are processed in fixed
//!   chunks ordered by descending utilization bound, so counters and the
//!   frontier are identical for any worker count.
//!
//! The output [`ExploreOutcome`] carries every evaluated [`DesignPoint`],
//! the exact Pareto frontier over (utilization, cost efficiency, power
//! efficiency), and the dataflow/non-dataflow frontier ratios behind the
//! paper's 1.52×/1.59×/1.6× headline claims. The fixed `dse::sweep`,
//! `dse::fig19_sweep`, and `dse::fig22_sweep` grids are thin instantiations
//! of the presets here ([`SearchSpace::paper_grid`] and friends).

pub mod bound;
pub mod pareto;

pub use bound::BoundProfile;
pub use pareto::{dominates, pareto_frontier};

use std::collections::{BTreeMap, HashSet};
use std::fmt::Write as _;

use crate::api::scenario::{chip_by_name, link_by_name, memory_by_name};
use crate::dse::{self, DesignPoint, Workload};
use crate::graph::gpt::{self, GptConfig};
use crate::system::{chip, topology, ChipSpec, ExecutionModel, MemoryTech, SystemSpec};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::lru::Lru;
use crate::util::threadpool::{parallel_map, parallel_map_workers};
use crate::util::units::{Bytes, BytesPerSec, Dollars, FlopPerSec, Watts, GB, MB, TFLOPS};
use crate::{ensure, err};

/// One chip-axis value: a catalog part by name, or a parameterized
/// accelerator in the Fig. 19/22 style (compute and SRAM as free variables,
/// power/price defaulting to the Fig. 9 regressions).
#[derive(Debug, Clone, PartialEq)]
pub enum ChipCfg {
    /// Catalog chip (`h100 a100 tpuv4 sn10 sn30 sn40l wse2`).
    Named(String),
    /// Parameterized accelerator.
    Custom {
        name: String,
        compute_tflops: f64,
        sram_mb: f64,
        /// Dataflow (fused spatial pipelines) vs kernel-by-kernel.
        dataflow: bool,
        /// Compute tiles; defaults to `chip::custom`'s 1024.
        tiles: Option<usize>,
        /// Power override (W); defaults to the Fig. 9 regression.
        power_w: Option<f64>,
        /// Price override ($); defaults to the Fig. 9-derived estimate.
        price_usd: Option<f64>,
    },
}

impl ChipCfg {
    pub fn named(name: &str) -> ChipCfg {
        ChipCfg::Named(name.into())
    }

    pub fn build(&self) -> Result<ChipSpec> {
        match self {
            ChipCfg::Named(n) => chip_by_name(n),
            ChipCfg::Custom {
                name,
                compute_tflops,
                sram_mb,
                dataflow,
                tiles,
                power_w,
                price_usd,
            } => {
                ensure!(*compute_tflops > 0.0, "chip '{name}': compute_tflops must be positive");
                ensure!(*sram_mb > 0.0, "chip '{name}': sram_mb must be positive");
                let tiles = tiles.unwrap_or(1024);
                ensure!(tiles >= 1, "chip '{name}': tiles must be >= 1");
                let flops = compute_tflops * TFLOPS;
                Ok(ChipSpec {
                    name: name.clone(),
                    tiles,
                    tflop_per_tile: FlopPerSec::new(flops / tiles as f64),
                    sram_bytes: Bytes::new(sram_mb * MB),
                    execution: if *dataflow {
                        ExecutionModel::Dataflow
                    } else {
                        ExecutionModel::KernelByKernel
                    },
                    power_w: Watts::new(power_w.unwrap_or_else(|| chip::costpower_estimate_w(flops))),
                    price_usd: Dollars::new(
                        price_usd.unwrap_or_else(|| chip::costpower_estimate_usd(flops)),
                    ),
                })
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ChipCfg::Named(n) => Json::from(n.as_str()),
            ChipCfg::Custom {
                name,
                compute_tflops,
                sram_mb,
                dataflow,
                tiles,
                power_w,
                price_usd,
            } => {
                let mut kv = vec![
                    ("name", Json::from(name.as_str())),
                    ("compute_tflops", Json::from(*compute_tflops)),
                    ("sram_mb", Json::from(*sram_mb)),
                    ("dataflow", Json::from(*dataflow)),
                ];
                if let Some(t) = tiles {
                    kv.push(("tiles", Json::from(*t)));
                }
                if let Some(p) = power_w {
                    kv.push(("power_w", Json::from(*p)));
                }
                if let Some(p) = price_usd {
                    kv.push(("price_usd", Json::from(*p)));
                }
                Json::obj(kv)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<ChipCfg> {
        if let Some(s) = j.as_str() {
            return Ok(ChipCfg::Named(s.into()));
        }
        let name = j.get("name").and_then(|v| v.as_str()).unwrap_or("custom").to_string();
        let compute_tflops = j
            .get("compute_tflops")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| err!("custom chip '{name}' needs compute_tflops"))?;
        let sram_mb = j
            .get("sram_mb")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| err!("custom chip '{name}' needs sram_mb"))?;
        Ok(ChipCfg::Custom {
            name,
            compute_tflops,
            sram_mb,
            dataflow: j.get("dataflow").and_then(|v| v.as_bool()).unwrap_or(true),
            tiles: j.get("tiles").and_then(|v| v.as_usize()),
            power_w: j.get("power_w").and_then(|v| v.as_f64()),
            price_usd: j.get("price_usd").and_then(|v| v.as_f64()),
        })
    }
}

/// One memory-axis value: a catalog technology, optionally with bandwidth
/// and/or capacity overridden (the Fig. 19/22 sweep style).
#[derive(Debug, Clone, PartialEq)]
pub struct MemCfg {
    pub name: String,
    /// Override per-chip bandwidth (GB/s).
    pub bandwidth_gbs: Option<f64>,
    /// Override per-chip capacity (GB).
    pub capacity_gb: Option<f64>,
}

impl MemCfg {
    pub fn named(name: &str) -> MemCfg {
        MemCfg { name: name.into(), bandwidth_gbs: None, capacity_gb: None }
    }

    pub fn build(&self) -> Result<MemoryTech> {
        let mut m = memory_by_name(&self.name)?;
        if let Some(b) = self.bandwidth_gbs {
            ensure!(b > 0.0, "memory '{}': bandwidth_gbs must be positive", self.name);
            m.bandwidth = BytesPerSec::new(b * GB);
        }
        if let Some(c) = self.capacity_gb {
            ensure!(c > 0.0, "memory '{}': capacity_gb must be positive", self.name);
            m.capacity = Bytes::new(c * GB);
        }
        Ok(m)
    }

    pub fn to_json(&self) -> Json {
        if self.bandwidth_gbs.is_none() && self.capacity_gb.is_none() {
            return Json::from(self.name.as_str());
        }
        let mut kv = vec![("name", Json::from(self.name.as_str()))];
        if let Some(b) = self.bandwidth_gbs {
            kv.push(("bandwidth_gbs", Json::from(b)));
        }
        if let Some(c) = self.capacity_gb {
            kv.push(("capacity_gb", Json::from(c)));
        }
        Json::obj(kv)
    }

    pub fn from_json(j: &Json) -> Result<MemCfg> {
        if let Some(s) = j.as_str() {
            return Ok(MemCfg::named(s));
        }
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err!("memory axis object needs a name"))?;
        Ok(MemCfg {
            name: name.into(),
            bandwidth_gbs: j.get("bandwidth_gbs").and_then(|v| v.as_f64()),
            capacity_gb: j.get("capacity_gb").and_then(|v| v.as_f64()),
        })
    }
}

/// The workload under exploration: one of the four §VI-C axes, with the GPT
/// architecture, batch, and training-state factor as free knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadSpec {
    pub kind: Workload,
    /// GPT architecture override for `Llm` (default: the §VI-C gpt3-1t).
    pub gpt: Option<GptConfig>,
    /// Default batch (sequences for LLM, items for DLRM); `None` keeps the
    /// paper's fixed value (2048 sequences / 65536 items).
    pub batch: Option<f64>,
    /// DRAM bytes of training state per byte of bf16 weights. `None`
    /// keeps each workload's historical default: 8 (weights + grads +
    /// fp32 moments) for LLM training, 2 (bf16 weights + grads) for the
    /// fixed graph workloads (DLRM/HPL/FFT).
    pub state_bytes_per_weight_byte: Option<f64>,
}

impl WorkloadSpec {
    /// The paper's fixed workload (default architecture and batch).
    pub fn paper(kind: Workload) -> WorkloadSpec {
        WorkloadSpec { kind, gpt: None, batch: None, state_bytes_per_weight_byte: None }
    }
}

/// A declarative multi-axis design space: the cartesian product of the
/// axes, in fixed nesting order batch → chip → memory → link → chip count →
/// topology family (so [`SearchSpace::paper_grid`] enumerates the §VI-C
/// systems in their historical order).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpace {
    pub workload: WorkloadSpec,
    pub chips: Vec<ChipCfg>,
    pub mems: Vec<MemCfg>,
    /// Link technologies by name (`pcie4 nvlink4 rdu`).
    pub links: Vec<String>,
    /// Topology family names (`topology::by_name`); a (family, count) pair
    /// the family cannot realize (e.g. dgx1 at a non-multiple of 8) is
    /// skipped.
    pub topologies: Vec<String>,
    pub chip_counts: Vec<usize>,
    /// Per-candidate batch override axis; `None` defers to the workload.
    pub batches: Vec<Option<f64>>,
}

/// One enumerated point of a [`SearchSpace`].
#[derive(Debug, Clone)]
pub struct Candidate {
    pub batch: Option<f64>,
    pub sys: SystemSpec,
}

impl SearchSpace {
    /// The §VI-C 80-system grid (4 chips × 4 mem/link combos × 5 topologies
    /// at 1024 chips) for one workload — `dse::sweep`'s space.
    pub fn paper_grid(w: Workload) -> SearchSpace {
        SearchSpace {
            workload: WorkloadSpec::paper(w),
            chips: ["h100", "tpuv4", "sn30", "wse2"].iter().map(|c| ChipCfg::named(c)).collect(),
            mems: vec![MemCfg::named("ddr4"), MemCfg::named("hbm3")],
            links: vec!["pcie4".into(), "nvlink4".into()],
            topologies: ["torus2d", "torus3d", "dragonfly", "dgx1", "dgx2"]
                .iter()
                .map(|t| (*t).to_string())
                .collect(),
            chip_counts: vec![1024],
            batches: vec![None],
        }
    }

    /// The Fig. 19 grid: GPT3-175B (batch 64) on 8 chips, 300-TFLOPS
    /// accelerators with SRAM {150, 300, 500} MB in both execution styles ×
    /// DDR bandwidth {100, 300, 600} GB/s — `dse::fig19_sweep`'s space.
    pub fn fig19_grid() -> SearchSpace {
        let mut chips = Vec::new();
        for sram in [150.0, 300.0, 500.0] {
            for dataflow in [true, false] {
                chips.push(ChipCfg::Custom {
                    name: format!("sweep-{}-{sram:.0}MB", if dataflow { "df" } else { "kbk" }),
                    compute_tflops: 300.0,
                    sram_mb: sram,
                    dataflow,
                    tiles: None,
                    power_w: None,
                    price_usd: None,
                });
            }
        }
        SearchSpace {
            workload: WorkloadSpec {
                kind: Workload::Llm,
                gpt: Some(gpt::gpt3_175b()),
                batch: Some(64.0),
                state_bytes_per_weight_byte: None,
            },
            chips,
            mems: [100.0, 300.0, 600.0]
                .iter()
                .map(|&bw| MemCfg {
                    name: "ddr4".into(),
                    bandwidth_gbs: Some(bw),
                    capacity_gb: None,
                })
                .collect(),
            links: vec!["pcie4".into()],
            topologies: vec!["torus2d".into()],
            chip_counts: vec![8],
            batches: vec![None],
        }
    }

    /// The Fig. 22 grid: GPT-100T (batch 4096, bf16-only state) on 1024
    /// SN40L-like chips whose 2080 iso-area units split between compute and
    /// SRAM {20..80%}, × three memory generations with provisioned capacity
    /// — `dse::fig22_sweep`'s space.
    pub fn fig22_grid() -> SearchSpace {
        let chips = [0.2, 0.35, 0.5, 0.65, 0.8]
            .iter()
            .map(|&pct| {
                let units = 2080.0;
                let compute_units = (units * pct).round();
                let mem_units = units - compute_units;
                // calibration as §VIII-C: 1040 compute units = 640 TFLOPS;
                // 1040 mem units = 520 MB
                ChipCfg::Custom {
                    name: format!("SN40L-{:.0}%", pct * 100.0),
                    compute_tflops: 640.0 * compute_units / 1040.0,
                    sram_mb: (520.0 * MB * mem_units / 1040.0).max(1.0) / MB,
                    dataflow: true,
                    tiles: Some(compute_units.max(1.0) as usize),
                    power_w: Some(500.0),
                    price_usd: Some(28_000.0),
                }
            })
            .collect();
        SearchSpace {
            workload: WorkloadSpec {
                kind: Workload::Llm,
                gpt: Some(gpt::gpt_100t()),
                batch: Some(4096.0),
                state_bytes_per_weight_byte: Some(2.0),
            },
            chips,
            mems: ["2d-ddr", "2.5d-hbm", "3d-stacked"]
                .iter()
                .map(|&m| MemCfg {
                    name: m.into(),
                    bandwidth_gbs: None,
                    capacity_gb: Some(1000.0),
                })
                .collect(),
            links: vec!["rdu".into()],
            topologies: vec!["torus2d".into()],
            chip_counts: vec![1024],
            batches: vec![None],
        }
    }

    /// Enumerate every buildable candidate, validating axis values.
    pub fn candidates(&self) -> Result<Vec<Candidate>> {
        ensure!(!self.chips.is_empty(), "search space needs at least one chip");
        ensure!(!self.mems.is_empty(), "search space needs at least one memory technology");
        ensure!(!self.links.is_empty(), "search space needs at least one link technology");
        ensure!(!self.topologies.is_empty(), "search space needs at least one topology family");
        ensure!(!self.chip_counts.is_empty(), "search space needs at least one chip count");
        ensure!(!self.batches.is_empty(), "search space needs at least one batch entry");
        for f in &self.topologies {
            ensure!(
                topology::FAMILIES.contains(&f.as_str()),
                "unknown topology family '{f}' (known: {})",
                topology::FAMILIES.join(" ")
            );
        }
        for &n in &self.chip_counts {
            ensure!(n >= 1, "chip count must be >= 1");
        }
        for b in self.batches.iter().flatten() {
            ensure!(b.is_finite() && *b > 0.0, "batch override must be positive, got {b}");
        }
        let chips: Vec<ChipSpec> = self.chips.iter().map(ChipCfg::build).collect::<Result<_>>()?;
        let mems: Vec<MemoryTech> = self.mems.iter().map(MemCfg::build).collect::<Result<_>>()?;
        let links = self
            .links
            .iter()
            .map(|l| link_by_name(l))
            .collect::<Result<Vec<_>>>()?;
        let mut out = Vec::new();
        for &batch in &self.batches {
            for c in &chips {
                for mem in &mems {
                    for link in &links {
                        for &n in &self.chip_counts {
                            for family in &self.topologies {
                                if let Some(topo) = topology::by_name(family, n, link) {
                                    out.push(Candidate {
                                        batch,
                                        sys: SystemSpec::new(
                                            c.clone(),
                                            mem.clone(),
                                            link.clone(),
                                            topo,
                                        ),
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        ensure!(!out.is_empty(), "search space produced no buildable candidates");
        Ok(out)
    }
}

/// Driver knobs, orthogonal to the space itself.
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreSettings {
    /// Skip candidates whose roofline bound is dominated by an evaluated
    /// point (never drops a frontier point — see [`bound`]).
    pub prune: bool,
    /// Stop evaluating after visiting this many candidates (the rest are
    /// reported as budget-skipped).
    pub budget: Option<usize>,
    /// Candidates per deterministic scheduling chunk.
    pub chunk: usize,
    /// Worker override for the parallel map (`None`: DFMODEL_THREADS /
    /// available parallelism).
    pub workers: Option<usize>,
}

impl Default for ExploreSettings {
    fn default() -> Self {
        ExploreSettings { prune: true, budget: None, chunk: 16, workers: None }
    }
}

impl ExploreSettings {
    /// Evaluate every candidate (no pruning, no budget) — the sweep-parity
    /// mode the fixed `dse` grids run under.
    pub fn exhaustive() -> ExploreSettings {
        ExploreSettings { prune: false, ..Default::default() }
    }
}

/// Per-axis-value coverage counters: how the candidates sharing one axis
/// value (one chip, one memory technology, ...) split across evaluated /
/// cache-hit / pruned / budget-skipped. Sorted by axis (chip, mem, link,
/// topo) then value, so the rows are deterministic for any worker count.
#[derive(Debug, Clone, PartialEq)]
pub struct AxisStat {
    /// Axis name: `chip`, `mem`, `link`, or `topo`.
    pub axis: String,
    /// The axis value (the built spec's canonical name).
    pub value: String,
    pub evaluated: usize,
    pub cache_hits: usize,
    pub pruned: usize,
    pub skipped_budget: usize,
}

impl AxisStat {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("axis", Json::from(self.axis.as_str())),
            ("value", Json::from(self.value.as_str())),
            ("evaluated", Json::from(self.evaluated)),
            ("cache_hits", Json::from(self.cache_hits)),
            ("pruned", Json::from(self.pruned)),
            ("skipped_budget", Json::from(self.skipped_budget)),
        ])
    }
}

/// Everything one explore run produced.
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    pub workload: Workload,
    /// Enumerated candidates of the space.
    pub candidates: usize,
    /// Unique optimizer evaluations performed.
    pub evaluated: usize,
    /// Candidates answered from the memoized cache.
    pub cache_hits: usize,
    /// Candidates skipped by the dominated-bound rule.
    pub pruned: usize,
    /// Candidates skipped by the evaluation budget.
    pub skipped_budget: usize,
    /// Visited candidates with no feasible mapping.
    pub infeasible: usize,
    /// Visited candidates in enumeration order (infeasible = NaN point).
    pub points: Vec<DesignPoint>,
    /// Effective batch override per point (parallel to `points`; `None`
    /// for workloads with a fixed problem size).
    pub point_batches: Vec<Option<f64>>,
    /// Indices into `points` of the exact Pareto frontier over
    /// (utilization, cost efficiency, power efficiency).
    pub frontier: Vec<usize>,
    /// Per-objective maxima of the *bounds* of pruned candidates, split by
    /// execution class (`[dataflow, kernel-by-kernel]`) — folded into
    /// [`ExploreOutcome::frontier_ratios`] so pruning can only understate
    /// the reported dataflow advantage, never inflate it.
    pub pruned_bound_maxima: [Option<[f64; 3]>; 2],
    /// Per-axis-value coverage rows (see [`AxisStat`]).
    pub axes: Vec<AxisStat>,
}

impl ExploreOutcome {
    /// Feasible evaluated points (frontier + dominated).
    pub fn feasible(&self) -> usize {
        self.points.iter().filter(|p| p.utilization.is_finite()).count()
    }

    /// Feasible evaluated points not on the frontier.
    pub fn dominated(&self) -> usize {
        self.feasible() - self.frontier.len()
    }

    pub fn frontier_points(&self) -> Vec<&DesignPoint> {
        self.frontier.iter().map(|&i| &self.points[i]).collect()
    }

    /// Dataflow / non-dataflow ratios of the per-objective feasible maxima
    /// (utilization, cost efficiency, power efficiency) — the §VI-C
    /// headline comparison. The non-dataflow denominator folds in the
    /// bounds of pruned candidates (bound ≥ actual), so with pruning the
    /// ratios are conservative: never larger than the exhaustive run's.
    /// `None` when either execution class is entirely absent.
    pub fn frontier_ratios(&self) -> Option<[f64; 3]> {
        let best = |dataflow: bool| -> Option<[f64; 3]> {
            let mut m: Option<[f64; 3]> = None;
            for p in &self.points {
                if p.dataflow != dataflow || !p.utilization.is_finite() {
                    continue;
                }
                let m = m.get_or_insert([f64::MIN, f64::MIN, f64::MIN]);
                m[0] = m[0].max(p.utilization);
                m[1] = m[1].max(p.cost_eff);
                m[2] = m[2].max(p.power_eff);
            }
            m
        };
        let d = best(true)?;
        let mut k = best(false);
        if let Some(pb) = self.pruned_bound_maxima[1] {
            k = Some(match k {
                Some(k) => [k[0].max(pb[0]), k[1].max(pb[1]), k[2].max(pb[2])],
                None => pb,
            });
        }
        let k = k?;
        Some([d[0] / k[0], d[1] / k[1], d[2] / k[2]])
    }
}

/// One-line attribution tags for the top frontier points (the explain
/// layer's explore surface): system naming, utilization, and the binding
/// resource from the latency breakdown. Ordered like
/// `ExploreReport::from_outcome` (utilization descending, `top` rows).
pub fn frontier_tags(out: &ExploreOutcome, top: usize) -> Vec<String> {
    let mut idx = out.frontier.clone();
    idx.sort_by(|&a, &b| {
        let (pa, pb) = (&out.points[a], &out.points[b]);
        pb.utilization
            .total_cmp(&pa.utilization)
            .then(pb.cost_eff.total_cmp(&pa.cost_eff))
            .then(pa.chip.cmp(&pb.chip))
    });
    idx.iter()
        .take(top)
        .map(|&i| {
            let p = &out.points[i];
            let (c, m, n) = p.breakdown;
            let bound = if c >= m && c >= n {
                "compute"
            } else if m >= n {
                "memory"
            } else {
                "network"
            };
            format!(
                "{}/{}/{}/{}: util {:.1}% ({bound}-bound)",
                p.chip,
                p.mem,
                p.link,
                p.topo,
                100.0 * p.utilization
            )
        })
        .collect()
}

/// The batch a candidate actually trains with — `None` for HPL/FFT, whose
/// paper problem sizes are fixed (a batch axis then aliases in the cache
/// instead of forcing duplicate evaluations).
fn effective_batch(spec: &WorkloadSpec, c: &Candidate) -> Option<f64> {
    match spec.kind {
        Workload::Llm | Workload::Dlrm => c.batch.or(spec.batch),
        Workload::Hpl | Workload::Fft => None,
    }
}

/// Evaluate one candidate through the same path as `dse::evaluate_point`.
pub(crate) fn evaluate_candidate(spec: &WorkloadSpec, c: &Candidate) -> Option<DesignPoint> {
    dse::evaluate_point_cfg(
        spec.kind,
        &c.sys,
        spec.gpt.as_ref(),
        effective_batch(spec, c),
        spec.state_bytes_per_weight_byte,
    )
}

/// Canonicalized memoization key: effective batch + every semantic field of
/// the system spec (floats by bit pattern, so aliasing axes hit exactly).
fn cache_key(spec: &WorkloadSpec, c: &Candidate) -> String {
    let s = &c.sys;
    let mut k = String::new();
    match effective_batch(spec, c) {
        Some(b) => {
            let _ = write!(k, "b{:x};", b.to_bits());
        }
        None => k.push_str("bdef;"),
    }
    let _ = write!(
        k,
        "c:{}:{}:{:x}:{:x}:{:?}:{:x}:{:x};",
        s.chip.name,
        s.chip.tiles,
        s.chip.tflop_per_tile.to_bits(),
        s.chip.sram_bytes.to_bits(),
        s.chip.execution,
        s.chip.power_w.to_bits(),
        s.chip.price_usd.to_bits()
    );
    let _ = write!(
        k,
        "m:{}:{:x}:{:x}:{:x}:{:x};",
        s.memory.name,
        s.memory.bandwidth.to_bits(),
        s.memory.capacity.to_bits(),
        s.memory.price_per_gb.to_bits(),
        s.memory.power_per_gb.to_bits()
    );
    let _ = write!(
        k,
        "l:{}:{:x}:{:x}:{:x}:{:x};",
        s.link.name,
        s.link.bandwidth.to_bits(),
        s.link.latency.to_bits(),
        s.link.price_usd.to_bits(),
        s.link.power_w.to_bits()
    );
    let _ = write!(k, "t:{}", s.topology.name);
    for d in &s.topology.dims {
        let _ = write!(
            k,
            ":{:?}x{}@{:x}+{:x}/{:?}",
            d.kind,
            d.size,
            d.link_bw.to_bits(),
            d.latency.to_bits(),
            d.fabric
        );
    }
    k
}

/// Run the explorer: enumerate, (optionally) prune, evaluate in parallel,
/// and extract the exact Pareto frontier. Deterministic for any worker
/// count: scheduling order and chunk boundaries are functions of the space
/// alone, and pruning only consults points from previous chunks.
pub fn explore(space: &SearchSpace, settings: &ExploreSettings) -> Result<ExploreOutcome> {
    let _span = crate::obs::span("explore");
    let cands = space.candidates()?;
    let n = cands.len();
    let profile = if settings.prune { Some(BoundProfile::for_space(space)) } else { None };
    let bounds: Vec<[f64; 3]> = match &profile {
        Some(p) => cands.iter().map(|c| p.objective_bounds(&c.sys)).collect(),
        None => Vec::new(),
    };
    let mut order: Vec<usize> = (0..n).collect();
    if profile.is_some() {
        // strongest upper bounds first: the frontier seeds early, so later
        // chunks prune against real evaluated points
        order.sort_by(|&a, &b| bounds[b][0].total_cmp(&bounds[a][0]).then(a.cmp(&b)));
    }
    // without pruning or a budget there is nothing to decide between
    // chunks: one maximal chunk keeps the sweep fully parallel
    let chunk =
        if settings.prune || settings.budget.is_some() { settings.chunk.max(1) } else { n };

    /// What happened to one enumerated candidate — feeds the per-axis rows.
    #[derive(Clone, Copy)]
    enum Fate {
        Evaluated,
        CacheHit,
        Pruned,
        SkippedBudget,
    }

    // unbounded: one run never revisits enough keys to need eviction, and
    // eviction would break the "each distinct system evaluated once" pin
    let mut cache: Lru<String, Option<DesignPoint>> = Lru::unbounded();
    let mut results: Vec<Option<Option<DesignPoint>>> = vec![None; n];
    let mut archive: Vec<[f64; 3]> = Vec::new();
    let mut pruned_bound_maxima: [Option<[f64; 3]>; 2] = [None, None];
    let mut fates: Vec<Option<Fate>> = vec![None; n];
    let (mut evaluated, mut cache_hits) = (0usize, 0usize);
    let (mut pruned, mut skipped_budget) = (0usize, 0usize);
    let mut visited = 0usize;

    for sched in order.chunks(chunk) {
        let mut todo: Vec<usize> = Vec::new();
        for &i in sched {
            if matches!(settings.budget, Some(b) if visited >= b) {
                skipped_budget += 1;
                fates[i] = Some(Fate::SkippedBudget);
                continue;
            }
            if profile.is_some() && archive.iter().any(|f| pareto::dominates(f, &bounds[i])) {
                pruned += 1;
                fates[i] = Some(Fate::Pruned);
                let kbk = cands[i].sys.chip.execution == ExecutionModel::KernelByKernel;
                let e = pruned_bound_maxima[usize::from(kbk)].get_or_insert([f64::MIN; 3]);
                for (slot, b) in e.iter_mut().zip(bounds[i]) {
                    *slot = slot.max(b);
                }
                continue;
            }
            visited += 1;
            todo.push(i);
        }
        // evaluate each distinct system once, in first-occurrence order
        let mut seen: HashSet<String> = HashSet::new();
        let mut fresh: Vec<(String, usize)> = Vec::new();
        let mut key_of: Vec<(usize, String)> = Vec::with_capacity(todo.len());
        for &i in &todo {
            let key = cache_key(&space.workload, &cands[i]);
            if !cache.contains(&key) && seen.insert(key.clone()) {
                fresh.push((key.clone(), i));
            }
            key_of.push((i, key));
        }
        let eval = |(_, i): &(String, usize)| evaluate_candidate(&space.workload, &cands[*i]);
        let outs = match settings.workers {
            Some(w) => parallel_map_workers(&fresh, w, eval),
            None => parallel_map(&fresh, eval),
        };
        evaluated += fresh.len();
        cache_hits += todo.len() - fresh.len();
        for &i in &todo {
            fates[i] = Some(Fate::CacheHit);
        }
        for &(_, i) in &fresh {
            fates[i] = Some(Fate::Evaluated);
        }
        for ((key, _), out) in fresh.iter().zip(outs) {
            cache.insert(key.clone(), out);
        }
        for (i, key) in key_of {
            let r = cache.get(&key).cloned().unwrap_or(None);
            if let Some(p) = &r {
                pareto::archive_insert(&mut archive, [p.utilization, p.cost_eff, p.power_eff]);
            }
            results[i] = Some(r);
        }
    }

    let mut points = Vec::new();
    let mut point_batches = Vec::new();
    let mut infeasible = 0usize;
    for (i, r) in results.into_iter().enumerate() {
        if let Some(r) = r {
            match r {
                Some(p) => points.push(p),
                None => {
                    infeasible += 1;
                    points.push(DesignPoint::infeasible(&cands[i].sys));
                }
            }
            point_batches.push(effective_batch(&space.workload, &cands[i]));
        }
    }
    let objs: Vec<[f64; 3]> =
        points.iter().map(|p| [p.utilization, p.cost_eff, p.power_eff]).collect();
    let frontier = pareto::pareto_frontier(&objs);

    // Per-axis coverage rows, keyed (axis rank, value) so the order is a
    // function of the space alone — worker count and scheduling never
    // reorder them.
    let mut by_axis: BTreeMap<(u8, String), AxisStat> = BTreeMap::new();
    for (i, fate) in fates.iter().enumerate() {
        let Some(f) = *fate else { continue };
        let s = &cands[i].sys;
        let labels = [
            (0u8, "chip", s.chip.name.as_str()),
            (1, "mem", s.memory.name.as_str()),
            (2, "link", s.link.name.as_str()),
            (3, "topo", s.topology.name.as_str()),
        ];
        for (rank, axis, value) in labels {
            let e = by_axis.entry((rank, value.to_string())).or_insert_with(|| AxisStat {
                axis: axis.to_string(),
                value: value.to_string(),
                evaluated: 0,
                cache_hits: 0,
                pruned: 0,
                skipped_budget: 0,
            });
            match f {
                Fate::Evaluated => e.evaluated += 1,
                Fate::CacheHit => e.cache_hits += 1,
                Fate::Pruned => e.pruned += 1,
                Fate::SkippedBudget => e.skipped_budget += 1,
            }
        }
    }
    let axes: Vec<AxisStat> = by_axis.into_values().collect();

    crate::obs::counter("explore.evaluated", evaluated as u64);
    crate::obs::counter("explore.cache_hits", cache_hits as u64);
    crate::obs::counter("explore.pruned", pruned as u64);
    crate::obs::counter("explore.skipped_budget", skipped_budget as u64);
    if crate::obs::enabled() {
        for a in &axes {
            crate::obs::counter(
                &format!("explore.axis.{}.{}.evaluated", a.axis, a.value),
                a.evaluated as u64,
            );
        }
    }

    Ok(ExploreOutcome {
        workload: space.workload.kind,
        candidates: n,
        evaluated,
        cache_hits,
        pruned,
        skipped_budget,
        infeasible,
        points,
        point_batches,
        frontier,
        pruned_bound_maxima,
        axes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chip_cfg_builds_and_roundtrips() {
        let named = ChipCfg::named("h100");
        assert_eq!(named.build().unwrap().name, "H100");
        assert_eq!(ChipCfg::from_json(&named.to_json()).unwrap(), named);

        let custom = ChipCfg::Custom {
            name: "x".into(),
            compute_tflops: 300.0,
            sram_mb: 256.0,
            dataflow: false,
            tiles: Some(512),
            power_w: Some(111.0),
            price_usd: None,
        };
        let c = custom.build().unwrap();
        assert_eq!(c.tiles, 512);
        assert_eq!(c.execution, ExecutionModel::KernelByKernel);
        assert_eq!(c.power_w, Watts::new(111.0));
        assert!(c.price_usd > Dollars::ZERO, "price falls back to the Fig. 9 estimate");
        assert_eq!(ChipCfg::from_json(&custom.to_json()).unwrap(), custom);

        assert!(ChipCfg::named("z80").build().is_err());
        assert!(ChipCfg::from_json(&Json::obj(vec![("name", Json::from("y"))])).is_err());
    }

    #[test]
    fn mem_cfg_overrides_and_roundtrips() {
        let m = MemCfg { name: "ddr4".into(), bandwidth_gbs: Some(300.0), capacity_gb: None };
        let built = m.build().unwrap();
        assert_eq!(built.name, "DDR4");
        assert_eq!(built.bandwidth.raw(), 300.0 * GB);
        assert_eq!(MemCfg::from_json(&m.to_json()).unwrap(), m);
        assert_eq!(MemCfg::from_json(&Json::from("hbm3")).unwrap(), MemCfg::named("hbm3"));
        assert!(MemCfg::named("sram9000").build().is_err());
    }

    #[test]
    fn paper_grid_enumerates_80_candidates_in_order() {
        let cands = SearchSpace::paper_grid(Workload::Llm).candidates().unwrap();
        assert_eq!(cands.len(), 80);
        // chip-major order, five topologies per (mem, link) combo
        assert_eq!(cands[0].sys.chip.name, "H100");
        assert_eq!(cands[0].sys.memory.name, "DDR4");
        assert_eq!(cands[0].sys.link.name, "PCIe4");
        assert!(cands[0].sys.topology.name.starts_with("2D-torus"));
        assert_eq!(cands[20].sys.chip.name, "TPUv4");
        for c in &cands {
            assert_eq!(c.sys.n_chips(), 1024);
        }
    }

    #[test]
    fn fig_grids_have_expected_shapes() {
        assert_eq!(SearchSpace::fig19_grid().candidates().unwrap().len(), 18);
        let f22 = SearchSpace::fig22_grid().candidates().unwrap();
        assert_eq!(f22.len(), 15);
        for c in &f22 {
            assert_eq!(c.sys.memory.capacity.raw(), 1000.0 * GB);
        }
    }

    #[test]
    fn invalid_spaces_are_rejected() {
        let mut s = SearchSpace::paper_grid(Workload::Llm);
        s.topologies = vec!["moebius".into()];
        assert!(s.candidates().is_err());
        let mut s = SearchSpace::paper_grid(Workload::Llm);
        s.batches = vec![Some(-1.0)];
        assert!(s.candidates().is_err());
        let mut s = SearchSpace::paper_grid(Workload::Llm);
        s.chips.clear();
        assert!(s.candidates().is_err());
        // dgx1 cannot realize 10 chips: the combo is skipped, not an error
        let mut s = SearchSpace::paper_grid(Workload::Llm);
        s.topologies = vec!["dgx1".into(), "ring".into()];
        s.chip_counts = vec![10];
        let c = s.candidates().unwrap();
        assert!(c.iter().all(|c| c.sys.topology.name.starts_with("ring")));
    }

    #[test]
    fn unrealizable_combos_everywhere_is_an_error() {
        let mut s = SearchSpace::paper_grid(Workload::Llm);
        s.topologies = vec!["dgx2".into()];
        s.chip_counts = vec![10];
        assert!(s.candidates().is_err());
    }
}
