//! In-tree substrates replacing crates unavailable in the offline registry
//! (see DESIGN.md §Substitutions): JSON, CLI parsing, ASCII tables/heatmaps,
//! PRNG, LRU cache, slab arena, thread pool, bench harness, unit
//! formatting, property checking.

pub mod arena;
pub mod bench;
pub mod check;
pub mod cli;
pub mod error;
pub mod json;
pub mod lru;
pub mod prng;
pub mod table;
pub mod threadpool;
pub mod units;
